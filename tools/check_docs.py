"""Docs-integrity check: the documentation contract CI (and tier-1, via
tests/test_docs.py) enforces.

Asserts that
  * README.md exists and contains every required section anchor,
  * DESIGN.md contains the §8 (sharded serving) anchor — and every other
    section its docstring citations rely on,
  * every intra-repo relative link in the checked docs resolves to a real
    file (fenced code blocks are ignored; http(s)/mailto/#fragment links
    are skipped).

Run from anywhere:

    python tools/check_docs.py
"""
from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Section anchors the README must carry (the contract the repo's other
# docs and the ISSUE/CI pipeline point at).
README_ANCHORS = (
    "## What SLAY is",
    "## Install",
    "## Verify (tier 1)",
    "## Benchmarks",
    "## Repo layout",
    "## Design notes",
)

# DESIGN.md section anchors cited by docstrings across src/repro.
DESIGN_ANCHORS = (
    "## §1", "## §2", "## §3", "## §4", "## §5", "## §6", "## §7", "## §8",
    "## §9", "## §10", "## §11", "## §12", "## §13", "## §14",
)

# Docs whose relative links must resolve.
LINK_CHECKED = ("README.md", "DESIGN.md", "ROADMAP.md")

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE = re.compile(r"```.*?```", re.DOTALL)


def _fail(errors: list[str], msg: str):
    errors.append(msg)


def check(repo: str = REPO) -> list[str]:
    errors: list[str] = []

    readme = os.path.join(repo, "README.md")
    if not os.path.exists(readme):
        _fail(errors, "README.md missing")
    else:
        text = open(readme).read()
        for anchor in README_ANCHORS:
            if anchor not in text:
                _fail(errors, f"README.md: missing anchor {anchor!r}")

    design = os.path.join(repo, "DESIGN.md")
    if not os.path.exists(design):
        _fail(errors, "DESIGN.md missing")
    else:
        text = open(design).read()
        for anchor in DESIGN_ANCHORS:
            if anchor not in text:
                _fail(errors, f"DESIGN.md: missing anchor {anchor!r}")

    for name in LINK_CHECKED:
        path = os.path.join(repo, name)
        if not os.path.exists(path):
            continue                      # absence reported above if fatal
        body = _FENCE.sub("", open(path).read())
        for target in _LINK.findall(body):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            rel = target.split("#")[0]
            if not rel:
                continue
            if not os.path.exists(os.path.join(os.path.dirname(path), rel)):
                _fail(errors, f"{name}: broken relative link -> {target}")

    return errors


def main() -> int:
    errors = check()
    if errors:
        for e in errors:
            print(f"DOCS FAIL: {e}", file=sys.stderr)
        return 1
    print(f"docs OK: README anchors={len(README_ANCHORS)}, "
          f"DESIGN anchors={len(DESIGN_ANCHORS)}, "
          f"links checked in {', '.join(LINK_CHECKED)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
