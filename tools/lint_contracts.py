#!/usr/bin/env python
"""Contract analyzer CLI — the `static-analysis` CI gate (DESIGN.md §14).

Runs the three `repro.analysis` passes plus the style fallback and fails
(exit 1) on any finding not covered by the committed suppressions
baseline:

    python tools/lint_contracts.py --all            # everything (CI)
    python tools/lint_contracts.py --jitlint        # AST rules only
    python tools/lint_contracts.py --vmem           # Pallas VMEM budget
    python tools/lint_contracts.py --hlo            # compiled-HLO contracts
    python tools/lint_contracts.py --style          # ruff-fallback subset
    python tools/lint_contracts.py --update-vmem-baseline

The --hlo pass compiles the serving engine's donate_argnums entry points
on a forced-8-device host mesh (data=4) for both cache regimes and
asserts zero collectives, zero host callbacks, and full donation
aliasing through the op-level HLO parser. Because jax pins its device
count at first import, the forced-device flag is set *before* jax loads
— keep the env setup above every repro/jax import.

When GITHUB_STEP_SUMMARY is set, a markdown findings table is appended
there (the CI job summary); stdout always carries the plain listing.
"""
from __future__ import annotations

import argparse
import os
import sys

_NEEDS_DEVICES = any(a in ("--hlo", "--all") for a in sys.argv[1:])
if _NEEDS_DEVICES and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (os.path.join(_ROOT, "src"), _ROOT):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from repro.analysis import findings as flib  # noqa: E402
from repro.analysis import jitlint, style, vmem  # noqa: E402
from repro.analysis import hlo as hlo_lib  # noqa: E402

_SCAN_SUBDIRS = ("src", "benchmarks", "tests", "tools")


def run_jitlint() -> list:
    return jitlint.scan(_ROOT, subdirs=_SCAN_SUBDIRS)


def run_style() -> list:
    opts = jitlint.Options()
    files = jitlint.iter_python_files(_ROOT, _SCAN_SUBDIRS, opts)
    return style.scan_files(files)


def run_vmem(update_baseline: bool = False) -> list:
    footprints = vmem.probe_footprints()
    if update_baseline:
        vmem.write_vmem_baseline(footprints)
        print(f"wrote {vmem.DEFAULT_BASELINE} "
              f"({len(footprints)} kernels)")
        return []
    return vmem.check(footprints)


def run_hlo() -> list:
    """Compile the serving contract surfaces and check HLO001/002/DON001.

    Both cache regimes on the sharded (data=4) mesh: "slay" exercises the
    constant-state decode, "softmax" the KV-ring decode. Engines are
    built exactly like tests/sharded_driver.py's so the compiled text
    matches what serves.
    """
    import jax

    from repro import configs
    from repro.configs.base import ServingConfig
    from repro.launch.mesh import make_serving_mesh
    from repro.models import api
    from repro.serving.engine import ContinuousServingEngine

    if jax.device_count() < 4:
        return [flib.Finding(
            rule="HLO000", path="tools/lint_contracts.py", line=0,
            message=f"--hlo needs >= 4 devices (forced host devices), "
                    f"got {jax.device_count()}")]

    out = []
    mesh = make_serving_mesh(4)
    for kind in ("slay", "softmax"):
        cfg = configs.get_smoke_config("slayformer-124m", attn_kind=kind)
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        eng = ContinuousServingEngine(
            cfg, params, mesh,
            serving=ServingConfig(num_slots=4, max_len=64, prefill_chunk=4,
                                  macro_ticks=8))
        for name, (text, donated) in eng.contract_lowerings().items():
            label = f"{name}[{kind}]"
            module = hlo_lib.parse_hlo(text)
            out += hlo_lib.check_no_collectives(module, label)
            out += hlo_lib.check_no_host_ops(module, label)
            out += hlo_lib.check_donation(module, donated, label)
            print(f"  hlo: {label}: {len(module.instructions)} ops, "
                  f"{len(module.donated_params())}/{donated} donated")
    return out


def emit(all_findings, suppressed, stale) -> None:
    for f in all_findings:
        print(f.render())
    if suppressed:
        print(f"({len(suppressed)} finding(s) suppressed by baseline)")
    for s in stale:
        print(f"stale suppression (matched nothing): {s.rule} {s.path} "
              f"[{s.symbol or '-'}] — delete it")
    summary_path = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary_path:
        with open(summary_path, "a") as fh:
            fh.write(flib.format_table(all_findings,
                                       title="Contract analyzer findings"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--all", action="store_true",
                    help="run every pass (the CI gate)")
    ap.add_argument("--jitlint", action="store_true")
    ap.add_argument("--vmem", action="store_true")
    ap.add_argument("--hlo", action="store_true")
    ap.add_argument("--style", action="store_true")
    ap.add_argument("--update-vmem-baseline", action="store_true",
                    help="regenerate analysis/vmem_baseline.json and exit")
    ap.add_argument("--baseline", default=flib.DEFAULT_BASELINE,
                    help="suppressions baseline JSON")
    args = ap.parse_args(argv)

    if args.update_vmem_baseline:
        run_vmem(update_baseline=True)
        return 0

    passes = []
    if args.all or args.jitlint:
        passes.append(("jitlint", run_jitlint))
    if args.all or args.vmem:
        passes.append(("vmem", run_vmem))
    if args.all or args.hlo:
        passes.append(("hlo", run_hlo))
    if args.all or args.style:
        passes.append(("style", run_style))
    if not passes:
        ap.error("pick at least one pass (or --all)")

    findings = []
    for name, fn in passes:
        print(f"[{name}]")
        got = fn()
        print(f"  {len(got)} finding(s)")
        findings += got

    sups = (flib.load_baseline(args.baseline)
            if os.path.exists(args.baseline) else [])
    unsuppressed, suppressed, stale = flib.apply_baseline(findings, sups)
    # A suppression can only be declared stale when every pass ran — a
    # subset run simply never produces the findings it covers.
    stale = stale if args.all else []
    emit(unsuppressed, suppressed, stale)
    if unsuppressed:
        print(f"FAIL: {len(unsuppressed)} unsuppressed finding(s)")
        return 1
    if stale:
        print(f"FAIL: {len(stale)} stale suppression(s)")
        return 1
    print("lint_contracts OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
