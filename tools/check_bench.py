#!/usr/bin/env python3
"""Bench-regression gate: diff BENCH_serving.json against the committed
baseline (``benchmarks/baseline/BENCH_serving.json``) and fail on
regressions in the backend-independent tick metrics.

Only *tick-domain* metrics are gated — they are deterministic functions of
the seeded trace and the scheduling code, so they are trendable on any
backend (CI runs CPU smoke). Wall-clock metrics (``*_per_s``) are noisy on
CPU and stay ungated (inspectable from the uploaded artifact instead).

Per-metric tolerance: a row regresses when it is worse than baseline by
more than ``max(rel_tol * baseline, abs_floor)`` in the metric's bad
direction. The tolerances absorb minor scheduling shifts; a deliberate
change that moves a gated metric re-baselines instead:

    PYTHONPATH=src python -m benchmarks.run --suite serving --smoke
    python tools/check_bench.py --update

and commits the refreshed baseline alongside the change that moved it.
Chaos rows are skipped (degraded-mode rates are asserted by the chaos
contract step, not trended here). A row present in the baseline but
missing from the current run fails (a silently dropped regime is itself a
regression); a new row not yet in the baseline passes with a note.

Exit status: 0 = no regressions, 1 = regression or missing row.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_CURRENT = os.path.join(_REPO, "BENCH_serving.json")
_BASELINE = os.path.join(_REPO, "benchmarks", "baseline",
                         "BENCH_serving.json")

# metric -> (better, rel_tol, abs_floor). "higher"/"lower" is the GOOD
# direction; improvement is never flagged.
_METRICS = {
    "tokens_per_dispatch": ("higher", 0.10, 0.25),
    "host_syncs_per_token": ("lower", 0.10, 0.005),
    "mean_slot_occupancy": ("higher", 0.10, 0.02),
    "ttft_ticks_p50": ("lower", 0.15, 2.0),
    "ttft_ticks_p95": ("lower", 0.15, 2.0),
}


def _rows(payload: dict) -> dict:
    """(regime, load) -> row. Chaos and crash-recovery rows are excluded
    (their degraded-mode/recovery contracts are asserted by the chaos CI
    step, not trended); ``load`` defaults to 0.0 so rows from suites
    without a load sweep never KeyError the gate."""
    return {(r["regime"], float(r.get("load", 0.0))): r
            for r in payload["results"]
            if not r["regime"].startswith(("chaos", "crash"))}


def _check_metric(metric: str, base: float, cur: float) -> tuple[str, float]:
    """-> (status, delta). status: 'ok' | 'better' | 'REGRESSION'."""
    better, rel, floor = _METRICS[metric]
    tol = max(rel * abs(base), floor)
    delta = cur - base
    worse = delta < -tol if better == "higher" else delta > tol
    improved = delta > tol if better == "higher" else delta < -tol
    return ("REGRESSION" if worse else "better" if improved else "ok",
            delta)


def compare(baseline: dict, current: dict) -> tuple[list[str], bool]:
    """-> (markdown table lines, any_regression)."""
    base_rows, cur_rows = _rows(baseline), _rows(current)
    lines = ["| regime | load | metric | baseline | current | Δ | status |",
             "|---|---|---|---|---|---|---|"]
    bad = False
    for key in sorted(base_rows, key=str):
        regime, load = key
        if key not in cur_rows:
            lines.append(f"| {regime} | {load:g} | — | — | — | — | "
                         f"**MISSING ROW** |")
            bad = True
            continue
        for metric in _METRICS:
            b, c = base_rows[key].get(metric), cur_rows[key].get(metric)
            if b is None or c is None:
                continue       # e.g. a regime with no TTFT percentile
            status, delta = _check_metric(metric, float(b), float(c))
            if status == "REGRESSION":
                bad = True
            if status != "ok":
                status = (f"**{status}**" if status == "REGRESSION"
                          else status)
            lines.append(f"| {regime} | {load:g} | {metric} | {b:.4g} | "
                         f"{c:.4g} | {delta:+.4g} | {status} |")
    for key in sorted(set(cur_rows) - set(base_rows), key=str):
        lines.append(f"| {key[0]} | {key[1]:g} | — | — | — | — | "
                     f"new row (not in baseline) |")
    return lines, bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--current", default=_CURRENT,
                    help="bench JSON from this run")
    ap.add_argument("--baseline", default=_BASELINE,
                    help="committed baseline JSON")
    ap.add_argument("--update", action="store_true",
                    help="overwrite the baseline with --current "
                         "(re-baselining a deliberate change)")
    args = ap.parse_args(argv)
    if args.update:
        os.makedirs(os.path.dirname(args.baseline), exist_ok=True)
        shutil.copyfile(args.current, args.baseline)
        print(f"baseline updated: {args.baseline}")
        return 0
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.current) as f:
        current = json.load(f)
    lines, bad = compare(baseline, current)
    table = "\n".join(lines)
    verdict = ("bench regression vs baseline — see table; if deliberate, "
               "re-baseline with tools/check_bench.py --update"
               if bad else "bench metrics within tolerance of baseline")
    print(f"## Serving bench vs baseline\n\n{table}\n\n{verdict}")
    step = os.environ.get("GITHUB_STEP_SUMMARY")
    if step:       # surfaced on the workflow run page
        with open(step, "a") as f:
            f.write(f"## Serving bench vs baseline\n\n{table}\n\n"
                    f"{verdict}\n")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
