"""Paper Fig. 9-12 / App. L.3: quadrature error vs node count R, node/weight
concentration, and the kernel-reconstruction decomposition (quadrature error
vs random-feature error)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchResult
from repro.core import quadrature as qd
from repro.core.features import (SlayFeatureConfig, init_feature_params,
                                 slay_features, normalize)


def run(quick: bool = True) -> list[BenchResult]:
    eps = 1e-3
    x = np.linspace(-1.0, 0.95, 256)
    exact = qd.exact_spherical_yat(x, eps)
    results = []
    for r in (1, 2, 3, 4, 6, 8, 12, 16):
        approx = qd.quadrature_kernel(x, r, eps)
        err = float(np.mean(np.abs(approx - exact)
                            / (np.abs(exact) + 1e-2)))
        results.append(BenchResult(f"fig9/R{r}/mean_rel_err", err, "ratio"))
    # Node concentration (Fig. 10/11): share of total weight in first node.
    for r in (3, 8):
        s, w = qd.yat_quadrature(r, eps)
        results.append(BenchResult(f"fig10/R{r}/first_node_weight_share",
                                   float(w[0] / w.sum()), "ratio"))
    # Error decomposition (Fig. 13/14): with the exact poly map, increasing
    # PRF budget D isolates the quadrature error floor.
    d, R = 16, 3
    key = jax.random.PRNGKey(0)
    q = normalize(jax.random.normal(key, (32, d)))
    k = normalize(jax.random.normal(jax.random.PRNGKey(1), (32, d)))
    xs = np.asarray(jnp.einsum("id,jd->ij", q, k))
    quad = qd.quadrature_kernel(xs, R, eps)
    for D in ((64, 512) if quick else (64, 256, 1024, 4096)):
        cfg = SlayFeatureConfig(head_dim=d, poly_kind="exact", num_prf=D,
                                num_quad_nodes=R, eps=eps)
        params = init_feature_params(jax.random.PRNGKey(2), cfg)
        est = np.asarray(jnp.einsum(
            "im,jm->ij", slay_features(q, params, cfg),
            slay_features(k, params, cfg)))
        rf_err = float(np.mean(np.abs(est - quad) / (np.abs(quad) + 1e-2)))
        results.append(BenchResult(f"fig14/D{D}/rf_err_vs_quad", rf_err,
                                   "ratio"))
    return results


if __name__ == "__main__":
    for r in run(quick=False):
        print(r.csv())
