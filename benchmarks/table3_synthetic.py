"""Paper Table 3 / Table 8: synthetic-task accuracy by mechanism.

Trains a tiny 2-layer model per (mechanism x task) under identical budgets
and reports masked-answer accuracy. Quick mode: 1 representative task per
category; full mode: the whole 22-task suite."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchResult, tiny_lm_config, train_lm
from repro.data import tasks
from repro.models import api

QUICK_TASKS = ("copy", "counting", "distant_match", "retrieval",
               "majority", "induction", "noisy_copy", "histogram")
MECHS = ("softmax", "yat_spherical", "slay", "favor", "elu1")


def _batches(task, vocab, B, L, seed=0):
    rng = np.random.default_rng(seed)
    while True:
        b = tasks.generate(task, rng, B, L, vocab)
        yield {"tokens": jnp.asarray(b["tokens"]),
               "labels": jnp.asarray(b["labels"]),
               "mask": b["mask"]}


def _masked_loss_cfg(cfg):
    return cfg  # loss_fn averages all positions; mask handled in eval only


def evaluate(params, cfg, task, vocab, B=64, L=48, seed=123) -> float:
    rng = np.random.default_rng(seed)
    b = tasks.generate(task, rng, B, L, vocab)
    logits, _ = api.forward(params, cfg, {"tokens": jnp.asarray(b["tokens"])})
    return tasks.accuracy(np.asarray(logits, np.float32), b["labels"],
                          b["mask"])


def run(quick: bool = True) -> list[BenchResult]:
    task_list = QUICK_TASKS if quick else tasks.ALL_TASKS
    steps = 80 if quick else 300
    B, L, vocab = 32, 48, 32
    results = []
    for mech in MECHS:
        cfg = tiny_lm_config(attn_kind=mech, vocab_size=vocab)
        accs = {}
        for task in task_list:
            batches = (b for b in _batches(task, vocab, B, L))
            # strip mask for the train step (loss over all positions)
            train_batches = ({"tokens": b["tokens"], "labels": b["labels"]}
                             for b in batches)
            params, losses = train_lm(cfg, train_batches, steps)
            acc = evaluate(params, cfg, task, vocab)
            accs[task] = acc
            results.append(BenchResult(f"table3/{mech}/{task}/acc", acc,
                                       "accuracy",
                                       {"final_loss": losses[-1]}))
        results.append(BenchResult(
            f"table3/{mech}/mean_acc",
            float(np.mean(list(accs.values()))), "accuracy"))
    return results


if __name__ == "__main__":
    for r in run(quick=True):
        print(r.csv())
