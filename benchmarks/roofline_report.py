"""§Roofline table generator: reads the dry-run grid JSON (produced by
``python -m repro.launch.dryrun --all --out results/dryrun_grid.json``) and
emits the per-(arch x shape x mesh) roofline table in markdown + CSV.

This benchmark does NOT recompile the grid (that is the dry-run's job, in
its own 512-device process); it post-processes the recorded artifact."""
from __future__ import annotations

import json
import os

from benchmarks.common import BenchResult

GRID = os.environ.get("DRYRUN_GRID", "results/dryrun_grid.json")


def markdown_table(records: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | dom | t_comp(s) | t_mem(s) | t_coll(s) "
           "| useful_ratio | roofline_frac |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for r in records:
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"{r['status']} | - | - | - | - | - |")
            continue
        ro = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {ro['dominant']} "
            f"| {ro['t_compute_s']:.3g} | {ro['t_memory_s']:.3g} "
            f"| {ro['t_collective_s']:.3g} | {ro['useful_flop_ratio']:.3f} "
            f"| {ro['roofline_fraction']:.3f} |")
    return hdr + "\n".join(rows)


def run(quick: bool = True) -> list[BenchResult]:
    if not os.path.exists(GRID):
        return [BenchResult("roofline/grid_missing", 0.0, "n/a",
                            {"hint": f"run dryrun --all --out {GRID}"})]
    with open(GRID) as f:
        records = json.load(f)
    results = []
    for r in records:
        if r.get("status") != "ok":
            continue
        ro = r["roofline"]
        tag = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        results.append(BenchResult(f"{tag}/fraction",
                                   ro["roofline_fraction"], "ratio",
                                   {"dominant": ro["dominant"]}))
    os.makedirs("results", exist_ok=True)
    with open("results/roofline_table.md", "w") as f:
        f.write(markdown_table(records))
    return results


if __name__ == "__main__":
    for r in run():
        print(r.csv())
