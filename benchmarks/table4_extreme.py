"""Paper Table 4: extreme multi-label classification (Eurlex-4K-style).

Eurlex itself is not available offline, so we synthesize an extreme-label
problem with the same statistical signature: a large, Zipf-distributed label
space where each label is triggered by a sparse set of indicator tokens.
The model is a small attention encoder + label head; we compare SLAY vs
FAVOR+ (the paper's comparison) under identical budgets and report P@k and
propensity-scored PSP@k."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchResult
from repro.core import baselines as bl
from repro.core.features import SlayFeatureConfig, init_feature_params
from repro.core.slay import slay_attention

V, NUM_LABELS, L = 512, 256, 64


def _dataset(rng, n, labels_per_doc=4):
    """Each label owns 3 indicator tokens; docs contain indicators of their
    labels plus noise. Label marginals are Zipf (extreme-classification
    signature)."""
    owners = rng.integers(3, V, (NUM_LABELS, 3))
    p = (np.arange(1, NUM_LABELS + 1) ** -1.0)
    p /= p.sum()
    X = rng.integers(3, V, (n, L))
    Y = np.zeros((n, NUM_LABELS), np.float32)
    for i in range(n):
        labs = rng.choice(NUM_LABELS, labels_per_doc, replace=False, p=p)
        Y[i, labs] = 1.0
        pos = rng.choice(L, labels_per_doc * 3, replace=False)
        X[i, pos] = owners[labs].reshape(-1)
    return X, Y, p


def _encoder_apply(params, tokens, mech, attn_params, cfg):
    x = params["emb"][tokens]                       # (B, L, d)
    h = x.reshape(*x.shape[:-1], 4, 16)             # 4 heads x 16
    if mech == "slay":
        y = slay_attention(attn_params, h, h, h, cfg, causal=False)
    else:
        y = bl.linear_baseline_attention("favor", attn_params, h, h, h,
                                         causal=False)
    y = y.reshape(*x.shape)
    pooled = jnp.mean(x + y, axis=1)
    return pooled @ params["w"]                     # (B, NUM_LABELS)


def _precision_at_k(scores, Y, k, weights=None):
    idx = np.argsort(-scores, axis=1)[:, :k]
    hits = np.take_along_axis(Y, idx, axis=1)
    if weights is None:
        return float(hits.mean())
    w = weights[idx]
    denom = np.sort(weights)[::-1][:k].sum()
    return float((hits * w).sum(1).mean() / (denom / 1.0))


def run(quick: bool = True) -> list[BenchResult]:
    rng = np.random.default_rng(0)
    n_train, n_test = (512, 256) if quick else (2048, 512)
    steps = 150 if quick else 600
    Xtr, Ytr, p = _dataset(rng, n_train)
    Xte, Yte, _ = _dataset(rng, n_test)
    # Propensity weights (Jain et al. style): rarer labels weigh more.
    freq = Ytr.sum(0) + 1
    prop = 1.0 + (np.log(n_train) - 1) * (freq / n_train) ** -0.5 * 0.1
    results = []
    for mech in ("slay", "favor"):
        key = jax.random.PRNGKey(1)
        cfg = SlayFeatureConfig(head_dim=16)
        attn_params = (init_feature_params(key, cfg) if mech == "slay"
                       else bl.favor_init(key, 16))
        ks = jax.random.split(key, 2)
        params = {"emb": 0.1 * jax.random.normal(ks[0], (V, 64)),
                  "w": 0.1 * jax.random.normal(ks[1], (64, NUM_LABELS))}

        def loss_fn(params, xb, yb):
            logits = _encoder_apply(params, xb, mech, attn_params, cfg)
            return jnp.mean(
                jnp.sum(jax.nn.log_sigmoid(logits) * yb
                        + jax.nn.log_sigmoid(-logits) * (1 - yb), -1)) * -1

        @jax.jit
        def step(params, xb, yb):
            l, g = jax.value_and_grad(loss_fn)(params, xb, yb)
            return jax.tree.map(lambda p, gg: p - 0.05 * gg, params, g), l

        B = 64
        for i in range(steps):
            sl = np.arange(i * B, (i + 1) * B) % n_train
            params, l = step(params, jnp.asarray(Xtr[sl]),
                             jnp.asarray(Ytr[sl]))
        scores = np.asarray(jax.jit(
            lambda p, x: _encoder_apply(p, x, mech, attn_params, cfg))(
                params, jnp.asarray(Xte)))
        for k in (1, 3, 5):
            results.append(BenchResult(f"table4/{mech}/P@{k}",
                                       _precision_at_k(scores, Yte, k),
                                       "precision"))
            results.append(BenchResult(
                f"table4/{mech}/PSP@{k}",
                _precision_at_k(scores, Yte, k, weights=prop), "psp"))
    return results


if __name__ == "__main__":
    for r in run(quick=True):
        print(r.csv())
