"""Shared benchmark utilities: wall-clock timing of jit'd callables and a
tiny trainable transformer used by the mechanism-comparison benchmarks."""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import api
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train.loop import TrainConfig, make_train_step


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time (ms) of fn(*args) after jit warmup."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) * 1e3)
    return float(np.median(times))


@dataclasses.dataclass
class BenchResult:
    name: str
    value: float
    unit: str
    extra: dict = dataclasses.field(default_factory=dict)

    def csv(self) -> str:
        return f"{self.name},{self.value:.6g},{self.unit}"


def tiny_lm_config(attn_kind: str = "slay", **overrides):
    """A trainable-on-CPU SLAYformer-family model used for the Table-3/4/5
    style comparisons (paper model scaled down, same structure)."""
    base = configs.get_smoke_config("slayformer-124m",
                                    attn_kind=attn_kind)
    import dataclasses as dc
    defaults = dict(num_layers=2, d_model=96, num_heads=4, num_kv_heads=4,
                    d_ff=256, vocab_size=64, dtype="float32")
    defaults.update(overrides)
    return dc.replace(base, **defaults)


def train_lm(cfg, batches, steps: int, lr: float = 3e-3, seed: int = 0):
    """Train and return (params, history of losses)."""
    params = api.init_params(cfg, jax.random.PRNGKey(seed))
    opt_cfg = AdamWConfig(lr=lr, warmup_steps=max(steps // 20, 1),
                          total_steps=steps)
    step_fn = jax.jit(make_train_step(
        cfg, opt_cfg, TrainConfig(microbatches=1, remat=False)))
    opt = adamw_init(params, opt_cfg)
    ef = jnp.zeros(())
    losses = []
    for i, batch in zip(range(steps), batches):
        params, opt, ef, metrics = step_fn(params, opt, ef, batch)
        losses.append(float(metrics["loss"]))
    return params, losses


MECHANISMS = ("softmax", "yat", "yat_spherical", "slay", "favor",
              "cosformer", "elu1")
LINEAR_MECHS = ("slay", "favor", "cosformer", "elu1")
