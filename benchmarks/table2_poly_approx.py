"""Paper Table 2 / Table 6: polynomial-approximation quality + latency.

For each variant we compute kernel-normalized attention outputs against the
EXACT spherical-Yat attention oracle (tied projections, identical inputs)
and report Rel-L2 / cosine / MSE / forward latency, at three feature-budget
scales (Table 6's Small/Medium/Large, CPU-scaled)."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import BenchResult, time_fn
from repro.core import kernels
from repro.core import linear_attention as la
from repro.core.features import (SlayFeatureConfig, init_feature_params,
                                 slay_features)

VARIANTS = ("anchor", "laplace", "exact", "nystrom", "tensorsketch", "rm")
SCALES = {           # T (tokens), R, D (prf), P (anchors)
    "small": (128, 2, 8, 8),
    "medium": (256, 2, 16, 16),
    "large": (256, 3, 32, 32),
}


def _attention_outputs(variant: str, scale: str, d: int = 32,
                       fusion: str = "tensor", seed: int = 0):
    T, R, D, P = SCALES[scale]
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (1, T, 2, d))
    k = jax.random.normal(ks[1], (1, T, 2, d))
    v = jax.random.normal(ks[2], (1, T, 2, d))
    exact = kernels.yat_attention(q, k, v, causal=True, spherical=True)

    cfg = SlayFeatureConfig(head_dim=d, num_anchors=P, num_prf=D,
                            num_quad_nodes=R, poly_kind=variant,
                            fusion=fusion)
    params = init_feature_params(ks[3], cfg)

    def fwd(q, k, v):
        qf = slay_features(q, params, cfg)
        kf = slay_features(k, params, cfg)
        return la.causal_chunked(qf, kf, v, chunk_size=64)

    fwd_j = jax.jit(fwd)
    approx = fwd_j(q, k, v)
    lat = time_fn(fwd_j, q, k, v)
    return np.asarray(exact, np.float64), np.asarray(approx, np.float64), lat


def run(quick: bool = True) -> list[BenchResult]:
    results = []
    scales = ("large",) if quick else tuple(SCALES)
    for scale in scales:
        for variant in VARIANTS:
            ex, ap, lat = _attention_outputs(variant, scale)
            diff = ap - ex
            rel = np.linalg.norm(diff) / (np.linalg.norm(ex) + 1e-12)
            cos = float((ex * ap).sum()
                        / (np.linalg.norm(ex) * np.linalg.norm(ap) + 1e-12))
            mse = float((diff ** 2).mean())
            tag = f"table2/{scale}/{variant}"
            results += [
                BenchResult(f"{tag}/rel_l2", float(rel), "ratio",
                            {"cos": cos, "mse": mse}),
                BenchResult(f"{tag}/latency", lat, "ms"),
            ]
        # Hadamard-fusion reference row (paper includes it as a baseline).
        ex, ap, lat = _attention_outputs("anchor", scale, fusion="hadamard")
        rel = np.linalg.norm(ap - ex) / (np.linalg.norm(ex) + 1e-12)
        results += [
            BenchResult(f"table2/{scale}/hadamard/rel_l2", float(rel),
                        "ratio"),
            BenchResult(f"table2/{scale}/hadamard/latency", lat, "ms"),
        ]
    return results


if __name__ == "__main__":
    for r in run(quick=False):
        print(r.csv())
