"""Paper Table 5 / Fig. 3: full-model LM comparison across attention
mechanisms at a matched token budget (CPU-scaled SLAYformer).

Every mechanism shares the identical architecture, optimizer, data and
token budget — only the attention differs — mirroring the paper's
controlled setup. Reports final validation loss and perplexity."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (BenchResult, MECHANISMS, tiny_lm_config,
                               train_lm)
from repro.data.pipeline import DataConfig, batch_iterator, make_batch
from repro.models import api


def _val_loss(params, cfg, dcfg, steps=4, start=10_000):
    losses = []
    for s in range(start, start + steps):
        b = make_batch(dcfg, s)
        loss, _ = api.loss_fn(params, cfg, b)
        losses.append(float(loss))
    return float(np.mean(losses))


def run(quick: bool = True) -> list[BenchResult]:
    steps = 60 if quick else 400
    B, L = 8, 64
    results = []
    mechs = (("softmax", "yat_spherical", "slay", "favor")
             if quick else MECHANISMS)
    for mech in mechs:
        cfg = tiny_lm_config(attn_kind=mech, vocab_size=128)
        dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=L,
                          global_batch=B, seed=1)
        batches = (b for _, b in batch_iterator(dcfg))
        params, losses = train_lm(cfg, batches, steps)
        val = _val_loss(params, cfg, dcfg)
        results += [
            BenchResult(f"table5/{mech}/val_loss", val, "nats",
                        {"train_final": losses[-1]}),
            BenchResult(f"table5/{mech}/ppl", float(np.exp(val)), "ppl"),
        ]
    return results


if __name__ == "__main__":
    for r in run(quick=True):
        print(r.csv())
