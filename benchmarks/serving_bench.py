"""Serving benchmark: continuous batching under a Poisson arrival trace.

Replays a seeded Poisson request trace (exponential inter-arrival times, in
engine ticks) through :class:`repro.serving.ContinuousServingEngine` at
several load levels and emits ``BENCH_serving.json`` (repo root) — the
serving perf trajectory CI tracks per PR:

* ``decode_tokens_per_s`` / ``total_tokens_per_s`` — wall-clock throughput
  (noisy on CPU; structural on TPU),
* ``ttft_ticks_p50`` / ``p95`` — time-to-first-token in engine ticks, a
  backend-independent measure of scheduling latency (queueing + chunked
  prefill) that survives CPU timing noise,
* ``mean_slot_occupancy`` / ``mean_queue_depth`` — pool pressure,
* ``host_syncs_per_token`` / ``tokens_per_dispatch`` /
  ``dispatches_per_decode_tick`` — the decode hot-loop sync cadence under
  K-tick macro-stepping (backend-independent: the win the on-device loop
  buys regardless of accelerator), plus ``jit_cache_entries`` per row —
  the recompile budget CI gates on.

Both cache regimes run: the constant-state SLAY path (slot overwrite
eviction) and the KV-ring softmax baseline (same scheduler, O(max_len)
slot state), so the JSON shows the serving asymmetry directly.

    PYTHONPATH=src python -m benchmarks.run --suite serving
    PYTHONPATH=src python -m benchmarks.run --suite serving --smoke
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np

from benchmarks.common import BenchResult
from repro import configs
from repro.configs.base import ServingConfig
from repro.launch.mesh import make_host_mesh
from repro.models import api
from repro.serving.engine import ContinuousServingEngine, Request

_JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_serving.json")

# (requests, max_new, prompt range); load = arrival rate in requests/tick.
# max_new >= 2*macro_ticks so every trace amortizes the K-tick macro-step
# (the host_syncs_per_token <= 1/K contract CI asserts on).
_MACRO_TICKS = 8
_SMOKE = {"n": 4, "max_new": 16, "prompt": (3, 8), "loads": (0.25, 1.0),
          "num_slots": 2, "max_len": 32, "prefill_chunk": 4}
_QUICK = {"n": 10, "max_new": 16, "prompt": (4, 16), "loads": (0.1, 0.5),
          "num_slots": 4, "max_len": 64, "prefill_chunk": 8}
_FULL = {"n": 32, "max_new": 24, "prompt": (8, 48),
         "loads": (0.05, 0.2, 0.8), "num_slots": 8, "max_len": 128,
         "prefill_chunk": 16}


def _poisson_trace(rng, n: int, rate: float, prompt_range, vocab: int,
                   max_new: int) -> list[Request]:
    """n requests with exp(rate) inter-arrival ticks and random prompts."""
    t = 0.0
    reqs = []
    lo, hi = prompt_range
    for _ in range(n):
        t += rng.exponential(1.0 / rate)
        plen = int(rng.integers(lo, hi + 1))
        prompt = rng.integers(3, vocab, size=plen).astype(np.int32)
        reqs.append(Request(prompt, max_new_tokens=max_new,
                            arrival_time=t))
    return reqs


def run(quick: bool = True, smoke: bool = False):
    p = _SMOKE if smoke else (_QUICK if quick else _FULL)
    mesh = make_host_mesh()
    results = []
    rows = []
    for regime, attn_kind in (("constant_state", "slay"),
                              ("kv_ring", "softmax")):
        cfg = configs.get_smoke_config("slayformer-124m",
                                       attn_kind=attn_kind)
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        for load in p["loads"]:
            rng = np.random.default_rng(1234)
            reqs = _poisson_trace(rng, p["n"], load, p["prompt"],
                                  cfg.vocab_size, p["max_new"])
            eng = ContinuousServingEngine(
                cfg, params, mesh,
                serving=ServingConfig(num_slots=p["num_slots"],
                                      max_len=p["max_len"],
                                      prefill_chunk=p["prefill_chunk"],
                                      macro_ticks=_MACRO_TICKS))
            outs, summary = eng.run(reqs)
            assert summary["requests_completed"] == p["n"]
            # Hot-loop contract (backend-independent): one pooled dispatch
            # covers >= 1 decode tick, and the decode loop syncs to host
            # at most once per K generated tokens.
            assert summary["dispatches_per_decode_tick"] <= 1.0 + 1e-9
            assert summary["host_syncs_per_token"] <= 1.0 / _MACRO_TICKS \
                + 1e-9, summary["host_syncs_per_token"]
            jit_entries = eng.jit_cache_entries()
            # Missing key = jax introspection unavailable, not a recompile.
            assert jit_entries.get("macro_decode", 1) == 1, jit_entries
            tag = f"serving/{regime}/load{load:g}"
            for key in ("decode_tokens_per_s", "ttft_ticks_p50",
                        "ttft_ticks_p95", "mean_slot_occupancy",
                        "mean_queue_depth", "host_syncs_per_token",
                        "tokens_per_dispatch"):
                unit = ("tok/s" if "per_s" in key
                        else "ticks" if "ttft" in key else "ratio")
                results.append(BenchResult(
                    f"{tag}/{key}", float(summary[key]), unit,
                    extra={"regime": regime, "load": load}))
            rows.append({"regime": regime, "load": load,
                         "num_slots": p["num_slots"],
                         "requests": p["n"],
                         "jit_cache_entries": jit_entries, **summary})

    payload = {
        "meta": {
            "backend": jax.default_backend(),
            "smoke": smoke, "quick": quick,
            "params": {**p, "macro_ticks": _MACRO_TICKS},
            "note": ("ttft/occupancy are in engine ticks (backend-"
                     "independent scheduling trajectory); *_per_s are "
                     "wall-clock and only meaningful on TPU"),
        },
        "results": rows,
    }
    with open(_JSON_PATH, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    return results
