"""Serving benchmark: continuous batching under a Poisson arrival trace.

Replays a seeded Poisson request trace (exponential inter-arrival times, in
engine ticks) through :class:`repro.serving.ContinuousServingEngine` at
several load levels and emits ``BENCH_serving.json`` (repo root) — the
serving perf trajectory CI tracks per PR:

* ``decode_tokens_per_s`` / ``total_tokens_per_s`` — wall-clock throughput
  (noisy on CPU; structural on TPU),
* ``ttft_ticks_p50`` / ``p95`` — time-to-first-token in engine ticks, a
  backend-independent measure of scheduling latency (queueing + chunked
  prefill) that survives CPU timing noise,
* ``mean_slot_occupancy`` / ``mean_queue_depth`` — pool pressure,
* ``host_syncs_per_token`` / ``tokens_per_dispatch`` /
  ``dispatches_per_decode_tick`` — the decode hot-loop sync cadence under
  K-tick macro-stepping (backend-independent: the win the on-device loop
  buys regardless of accelerator), plus ``jit_cache_entries`` per row —
  the recompile budget CI gates on.

Both cache regimes run: the constant-state SLAY path (slot overwrite
eviction) and the KV-ring softmax baseline (same scheduler, O(max_len)
slot state), so the JSON shows the serving asymmetry directly. Two
scan-carry rows (``ssm_scan`` = mamba2, ``hybrid_scan`` = hymba) track
exact chunked-prefill continuation for the SSD families (DESIGN.md §9) —
their bucket counters must read zero (fallback retired; CI asserts it).
A ``constant_state_sharded`` row replays the last constant_state trace on
a mesh=(data=N,) slot-sharded pool in a forced-multi-device subprocess
(``benchmarks/serving_sharded_row.py``); every row carries a
``stream_digest`` (sha256 of the rid-ordered token streams) and the CI
contract step asserts the sharded digest equals the single-shard one —
the DESIGN.md §8 byte-identical-stream contract.

The speculative family (DESIGN.md §13) rides every run: an ``exact_yat``
greedy baseline (yat_spherical verifier config, plain decode) and a
``spec_constant_state`` draft-verify row on the same trace — the linear
SLAY regime drafts ``spec_gamma`` tokens per slot, the exact verifier
scores them in one chunked dispatch. The CI spec-decode contract asserts
``stream_digest`` equality between the two rows (greedy speculative ≡
greedy exact, byte-identical), ``tokens_per_dispatch > macro_ticks``,
and ``draft_acceptance_rate >= 0.5``. Both rows replay one pinned
contract trace (fixed geometry + seed, identical at every tier): the
byte-identity contract presumes a unique fp32 argmax at every emitted
position, and random smoke weights can manufacture exact top-2 logit
ties that the two differently-shaped scorer programs may legally break
either way (DESIGN.md §13), so the pinned seed is checked tie-free.

Three DESIGN.md §11 rows ride every run: ``kv_ring_paged`` replays the
kv_ring trace with the page-table layer on (its ``stream_digest`` must
equal the unpaged row's; ``pages_peak`` / ``final_pages_in_use`` expose
pool pressure and the no-leak contract), and a ``prefix_cold`` /
``prefix_cached`` pair replays a shared-system-prefix trace cold and then
against a cache warmed by a throwaway engine — the cached row must
full-hit every request (``prefix_hit_rate == 1.0``), stream
byte-identically, and beat the cold row's ``ttft_ticks_p50``. The tick
metrics of every non-chaos row are additionally gated against the
committed baseline by ``tools/check_bench.py`` (re-baseline deliberate
shifts with ``--update``).

``--chaos`` appends degraded-mode rows (DESIGN.md §10): a ``chaos_nan``
row replays the constant_state trace under a seeded
:class:`repro.serving.faults.FaultInjector` that NaNs one live slot every
``chaos_nan_every`` ticks — measuring fault-detection latency (bounded by
the K-tick fault plane), retry success, and byte-identical parity of
every successfully-finished stream against the fault-free baseline — and
a ``chaos_overload`` row drives an all-at-once burst through the
``shed_oldest`` overload policy with one impossible deadline, measuring
shed and deadline-miss rates. The CI ``chaos-serving`` step asserts the
leak contract (``final_occupancy == 0``) and ``fault_retries_succeeded
>= 1`` from these rows. ``--chaos`` also runs the crash-recovery drills
(DESIGN.md §12): per decode regime, a journaled + checkpointed engine is
killed mid-flight by the seeded crash injector, restored from disk, and
driven to completion — ``crash_recovery_*`` rows record recovery wall
time, tokens replayed through the journal-dedup horizon, journal bytes
per token, and the ``streams_byte_identical`` flag the CI crash contract
step asserts (alongside zero leaked slots/pages).

    PYTHONPATH=src python -m benchmarks.run --suite serving
    PYTHONPATH=src python -m benchmarks.run --suite serving --smoke
    PYTHONPATH=src python -m benchmarks.run --suite serving --smoke --chaos
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import subprocess
import sys
import tempfile

import jax
import numpy as np

from benchmarks.common import BenchResult
from repro import configs
from repro.configs.base import ServingConfig
from repro.launch.mesh import make_host_mesh
from repro.models import api
from repro.serving import journal as journal_lib
from repro.serving.engine import ContinuousServingEngine, Request
from repro.serving.faults import (EngineCrash, FaultInjector,
                                  detection_latencies)
from repro.serving.prefix_cache import PrefixCache

_JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_serving.json")

# (requests, max_new, prompt range); load = arrival rate in requests/tick.
# max_new >= 2*macro_ticks so every trace amortizes the K-tick macro-step
# (the host_syncs_per_token <= 1/K contract CI asserts on).
_MACRO_TICKS = 8
# chaos_nan_every: chaos-row NaN-injection cadence (ticks). Full keeps the
# headline 1-corruption-per-64-ticks rate; smoke/quick shrink it so the
# shorter traces still see >= 1 fault (the CI chaos contract requires a
# successful retry, so every tier must actually fault).
_SMOKE = {"n": 4, "max_new": 16, "prompt": (3, 8), "loads": (0.25, 1.0),
          "num_slots": 2, "max_len": 32, "prefill_chunk": 4,
          "page_size": 8, "chaos_nan_every": 6}
_QUICK = {"n": 10, "max_new": 16, "prompt": (4, 16), "loads": (0.1, 0.5),
          "num_slots": 4, "max_len": 64, "prefill_chunk": 8,
          "page_size": 16, "chaos_nan_every": 12}
_FULL = {"n": 32, "max_new": 24, "prompt": (8, 48),
         "loads": (0.05, 0.2, 0.8), "num_slots": 8, "max_len": 128,
         "prefill_chunk": 16, "page_size": 16, "chaos_nan_every": 64}


def _poisson_trace(rng, n: int, rate: float, prompt_range, vocab: int,
                   max_new: int) -> list[Request]:
    """n requests with exp(rate) inter-arrival ticks and random prompts."""
    t = 0.0
    reqs = []
    lo, hi = prompt_range
    for _ in range(n):
        t += rng.exponential(1.0 / rate)
        plen = int(rng.integers(lo, hi + 1))
        prompt = rng.integers(3, vocab, size=plen).astype(np.int32)
        reqs.append(Request(prompt, max_new_tokens=max_new,
                            arrival_time=t))
    return reqs


def _prefix_trace(rng, n: int, rate: float, chunk: int, vocab: int,
                  max_new: int) -> list[Request]:
    """Repeated-system-prompt trace (DESIGN.md §11): every prompt is one
    shared 2-chunk system prefix plus a short unique suffix — the shape
    the content-addressed prefix cache is built for."""
    sysp = rng.integers(3, vocab, size=2 * chunk).astype(np.int32)
    t = 0.0
    reqs = []
    for _ in range(n):
        t += rng.exponential(1.0 / rate)
        s = int(rng.integers(1, chunk + 1))
        suffix = rng.integers(3, vocab, size=s).astype(np.int32)
        reqs.append(Request(np.concatenate([sysp, suffix]),
                            max_new_tokens=max_new, arrival_time=t))
    return reqs


def _stream_digest(outs: dict) -> str:
    """sha256 over the rid-ordered token streams — the byte-identity
    fingerprint the §8 sharded/unsharded contract compares."""
    h = hashlib.sha256()
    for rid in sorted(outs):
        h.update(np.int64(rid).tobytes())
        h.update(np.asarray(outs[rid], np.int32).tobytes())
    return h.hexdigest()


def _sharded_row(p: dict, load: float) -> dict:
    """Run the constant_state trace on a slot-sharded mesh=(data=N,) pool.

    jax pins its device count at first init, so the parent process cannot
    force a multi-device CPU itself — the row runs in a subprocess with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` and comes back
    as JSON on stdout.
    """
    data = 4 if p["num_slots"] % 4 == 0 else 2
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    # Append (not overwrite): the child must see the parent's XLA flags
    # plus the forced device count, or numerics-affecting flags would
    # make the byte-identity digest comparison spuriously fail.
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={data}"
                        ).strip()
    env["PYTHONPATH"] = os.path.join(repo, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    lo, hi = p["prompt"]
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.serving_sharded_row",
         "--load", str(load), "--n", str(p["n"]),
         "--max-new", str(p["max_new"]),
         "--prompt-lo", str(lo), "--prompt-hi", str(hi),
         "--num-slots", str(p["num_slots"]),
         "--max-len", str(p["max_len"]),
         "--prefill-chunk", str(p["prefill_chunk"]),
         "--macro-ticks", str(_MACRO_TICKS), "--data", str(data)],
        capture_output=True, text=True, timeout=1800, env=env, cwd=repo)
    if proc.returncode != 0:
        raise RuntimeError(
            f"sharded serving row failed:\n{proc.stdout}\n{proc.stderr}")
    return json.loads(proc.stdout.splitlines()[-1])


def _trace_row(cfg, params, mesh, p: dict, load: float, regime: str,
               results: list, rows: list, *, page_size: int = 0,
               prefix_cache=None, reqs=None, speculative: bool = False,
               spec_gamma: int = 2):
    """Run one (config, load) Poisson trace; append BenchResults + a JSON
    row, asserting the backend-independent hot-loop contract.

    ``page_size`` pages the slot pool (``*_paged``/``prefix_*`` rows);
    ``prefix_cache`` shares a pre-warmed PrefixCache (``prefix_cached``
    row); ``reqs`` overrides the default Poisson trace; ``speculative``
    turns on draft-verify decoding (``spec_*`` rows, DESIGN.md §13)."""
    if reqs is None:
        rng = np.random.default_rng(1234)
        reqs = _poisson_trace(rng, p["n"], load, p["prompt"],
                              cfg.vocab_size, p["max_new"])
    eng = ContinuousServingEngine(
        cfg, params, mesh,
        serving=ServingConfig(num_slots=p["num_slots"],
                              max_len=p["max_len"],
                              prefill_chunk=p["prefill_chunk"],
                              macro_ticks=_MACRO_TICKS,
                              page_size=page_size,
                              speculative=speculative,
                              spec_gamma=spec_gamma),
        prefix_cache=prefix_cache)
    outs, summary = eng.run(reqs)
    assert summary["requests_completed"] == p["n"]
    # Hot-loop contract (backend-independent): one pooled dispatch
    # covers >= 1 decode tick, and the decode loop syncs to host
    # at most once per K generated tokens.
    assert summary["dispatches_per_decode_tick"] <= 1.0 + 1e-9
    assert summary["host_syncs_per_token"] <= 1.0 / _MACRO_TICKS \
        + 1e-9, summary["host_syncs_per_token"]
    jit_entries = eng.jit_cache_entries()
    # Missing key = jax introspection unavailable, not a recompile.
    # In speculative mode the hot loop is the spec macro-step instead.
    hot = "spec_macro" if speculative else "macro_decode"
    assert jit_entries.get(hot, 1) == 1, jit_entries
    tag = f"serving/{regime}/load{load:g}"
    for key in ("decode_tokens_per_s", "ttft_ticks_p50",
                "ttft_ticks_p95", "mean_slot_occupancy",
                "mean_queue_depth", "host_syncs_per_token",
                "tokens_per_dispatch"):
        unit = ("tok/s" if "per_s" in key
                else "ticks" if "ttft" in key else "ratio")
        results.append(BenchResult(
            f"{tag}/{key}", float(summary[key]), unit,
            extra={"regime": regime, "load": load}))
    rows.append({"regime": regime, "load": load,
                 "num_slots": p["num_slots"],
                 "requests": p["n"],
                 "prefix_hit_rate": summary["prefix_hits"] / p["n"],
                 "stream_digest": _stream_digest(outs),
                 "jit_cache_entries": jit_entries, **summary})
    return outs


def _chaos_rows(cfg, params, mesh, p: dict, load: float, base_outs: dict,
                results: list, rows: list):
    """Degraded-mode rows (DESIGN.md §10), both deterministic given the
    trace + injector seeds, so their rates are trendable per PR.

    ``chaos_nan``: the exact constant_state Poisson trace, with the
    injector NaN-ing one live slot's device state every
    ``chaos_nan_every`` ticks. Asserted here (and re-asserted from the
    JSON by CI): every request terminates, no slot leaks, >= 1 fault is
    detected, every faulted request finishes ``eos``/``length`` after at
    most one retry or is terminated as ``fault`` — and every successful
    stream (retried ones included) is byte-identical to the fault-free
    baseline, because sampling keyed on (seed, rid, token-index) makes
    retry-from-scratch transparent.

    ``chaos_overload``: the same requests arriving all at once into a
    half-sized admission queue under ``shed_oldest``, the last request
    carrying an impossible 2-tick total deadline, and the injector
    cancelling a live request periodically — exercising shed, deadline,
    and cancelled exits in one row.
    """
    rng = np.random.default_rng(1234)
    reqs = _poisson_trace(rng, p["n"], load, p["prompt"],
                          cfg.vocab_size, p["max_new"])
    sv = ServingConfig(num_slots=p["num_slots"], max_len=p["max_len"],
                       prefill_chunk=p["prefill_chunk"],
                       macro_ticks=_MACRO_TICKS, fault_retries=1)
    inj = FaultInjector(seed=418, nan_every=p["chaos_nan_every"])
    eng = ContinuousServingEngine(cfg, params, mesh, serving=sv,
                                  fault_injector=inj)
    outs, summary = eng.run(reqs)
    assert summary["final_occupancy"] == 0, summary
    assert summary["requests_terminated"] == p["n"], summary
    assert summary["faults_detected"] >= 1, summary
    for rid, st in eng.metrics.per_request.items():
        assert st.finish_reason in ("eos", "length", "fault"), st
        assert st.retries <= sv.fault_retries, st
        if st.finish_reason in ("eos", "length"):
            np.testing.assert_array_equal(outs[rid], base_outs[rid])
    lat = detection_latencies(inj.log, eng.metrics.fault_events)
    assert lat, (inj.log, eng.metrics.fault_events)
    chaos_extra = {
        "chaos_nan_every": p["chaos_nan_every"],
        "faults_injected": sum(1 for e in inj.log if e["kind"] == "nan"),
        "fault_detect_latency_ticks_mean": float(np.mean(lat)),
        "fault_detect_latency_ticks_max": int(np.max(lat)),
    }
    rows.append({"regime": "chaos_nan", "load": load,
                 "num_slots": p["num_slots"], "requests": p["n"],
                 "stream_digest": _stream_digest(outs),
                 "jit_cache_entries": eng.jit_cache_entries(),
                 **chaos_extra, **summary})
    for key in ("faults_detected", "fault_retries_succeeded"):
        results.append(BenchResult(
            f"serving/chaos_nan/load{load:g}/{key}",
            float(summary[key]), "count",
            extra={"regime": "chaos_nan", "load": load}))
    results.append(BenchResult(
        f"serving/chaos_nan/load{load:g}/fault_detect_latency_ticks_max",
        float(chaos_extra["fault_detect_latency_ticks_max"]), "ticks",
        extra={"regime": "chaos_nan", "load": load}))

    burst = [dataclasses.replace(r, arrival_time=0.0) for r in reqs]
    burst[-1] = dataclasses.replace(burst[-1], deadline_ticks=2.0)
    svo = ServingConfig(num_slots=p["num_slots"], max_len=p["max_len"],
                        prefill_chunk=p["prefill_chunk"],
                        macro_ticks=_MACRO_TICKS,
                        max_queue=max(p["n"] // 2, 1),
                        overload_policy="shed_oldest")
    inj2 = FaultInjector(seed=419, cancel_every=3 * _MACRO_TICKS)
    eng2 = ContinuousServingEngine(cfg, params, mesh, serving=svo,
                                   fault_injector=inj2)
    for r in burst:
        eng2.submit(r)
    outs2, s2 = eng2.run()
    assert s2["final_occupancy"] == 0, s2
    assert s2["requests_terminated"] == p["n"], s2
    assert s2["finish_reasons"].get("shed", 0) >= 1, s2
    assert s2["finish_reasons"].get("deadline", 0) >= 1, s2
    rows.append({"regime": "chaos_overload", "load": load,
                 "num_slots": p["num_slots"], "requests": p["n"],
                 "max_queue": svo.max_queue,
                 "stream_digest": _stream_digest(outs2),
                 "jit_cache_entries": eng2.jit_cache_entries(), **s2})
    for key in ("shed_rate", "deadline_miss_rate"):
        results.append(BenchResult(
            f"serving/chaos_overload/load{load:g}/{key}",
            float(s2[key]), "ratio",
            extra={"regime": "chaos_overload", "load": load}))


def _crash_recovery_rows(mesh, p: dict, load: float, regimes, results,
                         rows):
    """Crash-recovery drills (DESIGN.md §12): kill-and-restore per regime.

    Each regime's exact Poisson trace replays against a journaled +
    periodically-checkpointed engine; the seeded crash injector kills the
    process state mid-flight (an exception with no flush and no cleanup —
    the host dies with dirty buffers, a fair stand-in for ``kill -9``);
    the engine then restores from disk and finishes. Asserted here and
    re-asserted from the JSON by the CI chaos contract step:

    * ``streams_byte_identical`` — the merged restored streams' digest
      equals the fault-free row's (the §12 byte-identity contract),
    * ``tokens_replayed > 0`` — the crash landed mid-stream, so recovery
      actually regenerated and deduped journaled tokens (a vacuous drill
      that crashed before any emission would pass identity for free),
    * zero leaked slots/pages/queue entries after the recovered drain.

    Recovery cost shows up as ``recovery_wall_s`` (journal replay +
    checkpoint load + re-prefill on the restore path) and
    ``journal_bytes_per_token`` (durability overhead per emitted token).
    """
    for name, cfg, params, page_size in regimes:
        base_row = next(r for r in rows if r["regime"] == name
                        and r["load"] == load)
        sv = ServingConfig(num_slots=p["num_slots"], max_len=p["max_len"],
                           prefill_chunk=p["prefill_chunk"],
                           macro_ticks=_MACRO_TICKS, page_size=page_size,
                           checkpoint_every_ticks=_MACRO_TICKS)
        reqs = _poisson_trace(np.random.default_rng(1234), p["n"], load,
                              p["prompt"], cfg.vocab_size, p["max_new"])
        with tempfile.TemporaryDirectory(prefix="slay-crash-") as d:
            jr = journal_lib.Journal(
                os.path.join(d, journal_lib.JOURNAL_NAME))
            inj = FaultInjector(seed=808, crash_window=(10, 16))
            eng = ContinuousServingEngine(cfg, params, mesh, serving=sv,
                                          fault_injector=inj, journal=jr)
            crash_tick = None
            try:
                eng.run(reqs)
            except EngineCrash as e:
                crash_tick = e.tick
            assert crash_tick is not None, \
                f"{name}: crash injector never fired (trace too short?)"
            eng2 = ContinuousServingEngine.restore(d, cfg, params, mesh,
                                                   serving=sv)
            rec = eng2.recovery
            outs, s2 = eng2.run()
        identical = _stream_digest(outs) == base_row["stream_digest"]
        assert identical, f"{name}: restored streams diverged"
        assert s2["tokens_replayed"] > 0, (name, s2["tokens_replayed"])
        assert s2["final_occupancy"] == 0 == s2["final_queue_depth"], s2
        assert s2["final_pages_in_use"] == 0, s2
        regime = f"crash_recovery_{name}"
        extra = {
            "crash_tick": int(crash_tick),
            "recovery_wall_s": float(rec["wall_s"]),
            "checkpoint_used": bool(rec["checkpoint_used"]),
            "checkpoint_tick": rec["checkpoint_tick"],
            "resident_resumed": rec["resident_resumed"],
            "requeued": rec["requeued"],
            "terminal_from_journal": rec["terminal_from_journal"],
            "journal_records": rec["journal_records"],
            "journal_bytes_per_token":
                s2["journal_bytes"] / max(s2["tokens_generated"], 1),
            "streams_byte_identical": identical,
        }
        rows.append({"regime": regime, "load": load,
                     "num_slots": p["num_slots"], "requests": p["n"],
                     "stream_digest": _stream_digest(outs),
                     **extra, **s2})
        for key, unit in (("recovery_wall_s", "s"),
                          ("journal_bytes_per_token", "bytes/tok")):
            results.append(BenchResult(
                f"serving/{regime}/load{load:g}/{key}",
                float(extra[key]), unit,
                extra={"regime": regime, "load": load}))
        results.append(BenchResult(
            f"serving/{regime}/load{load:g}/tokens_replayed",
            float(s2["tokens_replayed"]), "tokens",
            extra={"regime": regime, "load": load}))


def run(quick: bool = True, smoke: bool = False, chaos: bool = False):
    p = _SMOKE if smoke else (_QUICK if quick else _FULL)
    mesh = make_host_mesh()
    results = []
    rows = []
    cs_cfg = cs_params = cs_outs = None
    kv_cfg = kv_params = None
    for regime, attn_kind in (("constant_state", "slay"),
                              ("kv_ring", "softmax")):
        cfg = configs.get_smoke_config("slayformer-124m",
                                       attn_kind=attn_kind)
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        for load in p["loads"]:
            outs = _trace_row(cfg, params, mesh, p, load, regime,
                              results, rows)
            if regime == "constant_state":
                # Chaos parity baseline: the fault-free streams of the
                # last constant_state load.
                cs_cfg, cs_params, cs_outs = cfg, params, outs
            else:
                kv_cfg, kv_params = cfg, params

    # Paged-pool row (DESIGN.md §11): the exact kv_ring trace with the KV
    # rings drawn from a shared page pool. Streams must be byte-identical
    # to the unpaged row — paging is a memory-layout change, never a
    # numerics change — and a drained engine leaks zero pages.
    load = p["loads"][-1]
    _trace_row(kv_cfg, kv_params, mesh, p, load, "kv_ring_paged",
               results, rows, page_size=p["page_size"])
    paged_row = rows[-1]
    kv_row = next(r for r in rows if r["regime"] == "kv_ring"
                  and r["load"] == load)
    assert paged_row["stream_digest"] == kv_row["stream_digest"], \
        (paged_row["stream_digest"], kv_row["stream_digest"])
    assert paged_row["final_pages_in_use"] == 0, paged_row
    assert paged_row["pages_peak"] >= 1, paged_row

    # Prefix-cache rows (DESIGN.md §11): a repeated-system-prompt trace,
    # cold (no cache) vs cached (a warm-up engine populates a shared
    # PrefixCache; the measured engine then hits on every admission).
    # Streams must be byte-identical cold-vs-cached — seeding from a
    # snapshot preserves the suffix chunk schedule and sampling is keyed
    # (seed, rid, idx) — while cached TTFT drops (prefill work skipped).
    def prefix_reqs():
        return _prefix_trace(np.random.default_rng(99), p["n"], load,
                             p["prefill_chunk"], kv_cfg.vocab_size,
                             p["max_new"])

    _trace_row(kv_cfg, kv_params, mesh, p, load, "prefix_cold",
               results, rows, page_size=p["page_size"],
               reqs=prefix_reqs())
    cold_row = rows[-1]
    shared = PrefixCache(64 * 1024 * 1024)
    warm = ContinuousServingEngine(
        kv_cfg, kv_params, mesh,
        serving=ServingConfig(num_slots=p["num_slots"],
                              max_len=p["max_len"],
                              prefill_chunk=p["prefill_chunk"],
                              macro_ticks=_MACRO_TICKS,
                              page_size=p["page_size"]),
        prefix_cache=shared)
    warm.run(prefix_reqs())
    _trace_row(kv_cfg, kv_params, mesh, p, load, "prefix_cached",
               results, rows, page_size=p["page_size"],
               prefix_cache=shared, reqs=prefix_reqs())
    cached_row = rows[-1]
    assert cached_row["stream_digest"] == cold_row["stream_digest"], \
        (cached_row["stream_digest"], cold_row["stream_digest"])
    assert cached_row["prefix_hit_rate"] == 1.0, cached_row
    assert cold_row["prefix_hit_rate"] == 0.0, cold_row
    assert cached_row["ttft_ticks_p50"] < cold_row["ttft_ticks_p50"], \
        (cached_row["ttft_ticks_p50"], cold_row["ttft_ticks_p50"])
    for r in (cold_row, cached_row):
        assert r["final_pages_in_use"] == 0, r
    for key, row in (("prefix_hit_rate", cached_row),
                     ("prefix_tokens_reused", cached_row)):
        results.append(BenchResult(
            f"serving/prefix_cached/load{load:g}/{key}",
            float(row[key]), "ratio" if "rate" in key else "tokens",
            extra={"regime": "prefix_cached", "load": load}))

    # Scan-carry prefill rows (DESIGN.md §9): ssm/hybrid serve through
    # exact chunked-prefill continuation — the bucketed masked-prefill
    # fallback is retired for them, so the bucket counters must stay at
    # zero (the CI serving contract step re-asserts this from the JSON)
    # and prefill progresses chunk-by-chunk in the tick trajectory.
    for regime, arch in (("ssm_scan", "mamba2-780m"),
                         ("hybrid_scan", "hymba-1.5b")):
        cfg = configs.get_smoke_config(arch)
        assert api.supports_chunked_prefill(cfg), arch
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        load = p["loads"][-1]
        _trace_row(cfg, params, mesh, p, load, regime, results, rows)
        row = rows[-1]
        assert row["bucket_misses"] == 0 == row["bucket_hits"], row
        assert row["prefill_ticks"] > 0, row

    # Sharded-pool variant (DESIGN.md §8): same trace as the last
    # constant_state load, slot pool sharded over mesh=(data=N,). The
    # digest must match the single-shard row byte-for-byte — asserted
    # here and re-asserted from the JSON by the CI contract step.
    load = p["loads"][-1]
    sharded = _sharded_row(p, load)
    base = next(r for r in rows
                if r["regime"] == "constant_state" and r["load"] == load)
    assert sharded["stream_digest"] == base["stream_digest"], \
        (sharded["stream_digest"], base["stream_digest"])
    rows.append(sharded)
    results.append(BenchResult(
        f"serving/constant_state_sharded/load{load:g}/slot_shards",
        float(sharded["slot_shards"]), "shards",
        extra={"regime": "constant_state_sharded", "load": load}))

    # Speculative-decoding family (DESIGN.md §13): an exact-yat greedy
    # baseline row plus a draft-verify row on the same config — linear
    # SLAY drafts spec_gamma tokens per slot, the exact yat verifier
    # scores them in one chunked dispatch. The contract asserted here and
    # re-asserted from the JSON by the CI spec-decode step: the accepted
    # streams are byte-identical to plain greedy exact decode (the
    # accept/resample correction emits exactly the verifier's argmax) and
    # the amortization is real — tokens/dispatch materially above
    # macro_ticks, draft acceptance >= 0.5. The small SLAY feature bank
    # keeps the draft steps cheap; it is the *verifier's* features that
    # set output quality, so the baseline uses the same trunk.
    #
    # The family runs on a pinned contract trace (geometry + seed below),
    # identical at every bench tier. Byte-identity presumes the verifier's
    # fp32 argmax is unique at every emitted position: decode_step and
    # verify_chunk are different XLA programs (shapes (S,1,V) vs
    # (S,gamma+1,V)), so an *exact* top-2 logit tie — measure-zero for
    # trained weights but easy to hit with random smoke weights — can
    # legally resolve either way. The pinned seed was checked tie-free;
    # see DESIGN.md §13 for the contract's fine print.
    spec_load = 1.0
    sp = {**p, "n": 4, "max_new": 16, "prompt": (3, 8),
          "num_slots": 2, "max_len": 32, "prefill_chunk": 4}
    spec_cfg = configs.get_smoke_config("slayformer-124m",
                                        attn_kind="yat_spherical",
                                        slay_anchors=16, slay_prf=32)
    spec_params = api.init_params(spec_cfg, jax.random.PRNGKey(0))

    def spec_reqs():
        return _poisson_trace(np.random.default_rng(2024), sp["n"], spec_load,
                              sp["prompt"], spec_cfg.vocab_size,
                              sp["max_new"])

    _trace_row(spec_cfg, spec_params, mesh, sp, spec_load, "exact_yat",
               results, rows, reqs=spec_reqs())
    exact_row = rows[-1]
    _trace_row(spec_cfg, spec_params, mesh, sp, spec_load, "spec_constant_state",
               results, rows, reqs=spec_reqs(),
               speculative=True, spec_gamma=2)
    spec_row = rows[-1]
    assert spec_row["stream_digest"] == exact_row["stream_digest"], \
        (spec_row["stream_digest"], exact_row["stream_digest"])
    assert spec_row["tokens_per_dispatch"] > _MACRO_TICKS, \
        spec_row["tokens_per_dispatch"]
    assert spec_row["draft_acceptance_rate"] >= 0.5, \
        spec_row["draft_acceptance_rate"]
    for key, unit in (("draft_acceptance_rate", "ratio"),
                      ("draft_tokens_proposed", "tokens"),
                      ("tokens_per_dispatch", "ratio")):
        results.append(BenchResult(
            f"serving/spec_constant_state/load{spec_load:g}/{key}",
            float(spec_row[key]), unit,
            extra={"regime": "spec_constant_state", "load": spec_load}))

    if chaos:
        _chaos_rows(cs_cfg, cs_params, mesh, p, load, cs_outs,
                    results, rows)
        # Crash-recovery drills (DESIGN.md §12): one kill-and-restore per
        # decode regime, byte-identity asserted against the fault-free
        # rows above (same trace, same load).
        _crash_recovery_rows(
            mesh, p, load,
            [("constant_state", cs_cfg, cs_params, 0),
             ("kv_ring", kv_cfg, kv_params, 0),
             ("kv_ring_paged", kv_cfg, kv_params, p["page_size"])],
            results, rows)

    payload = {
        "meta": {
            "backend": jax.default_backend(),
            "smoke": smoke, "quick": quick, "chaos": chaos,
            "params": {**p, "macro_ticks": _MACRO_TICKS},
            "note": ("ttft/occupancy are in engine ticks (backend-"
                     "independent scheduling trajectory); *_per_s are "
                     "wall-clock and only meaningful on TPU"),
        },
        "results": rows,
    }
    with open(_JSON_PATH, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    return results
