"""Paper Fig. 2 / Fig. 21: latency / memory / throughput vs sequence length.

Quadratic mechanisms (softmax, exact Yat) blow up in L; linear mechanisms
(SLAY, FAVOR+, ELU+1, cosformer) stay ~linear. On CPU we measure wall time
of the isolated attention op (embedding dim 256, 8 heads, batch 1 — the
paper's benchmark setting, length-scaled to CPU) and report an analytic
peak-memory proxy (attention-matrix bytes vs feature-state bytes)."""
from __future__ import annotations

import jax

from benchmarks.common import BenchResult, time_fn
from repro.core import baselines as bl
from repro.core import kernels
from repro.core.features import SlayFeatureConfig, init_feature_params
from repro.core.slay import slay_attention

HEADS, DH = 8, 32   # embedding dim 256 split over 8 heads (paper setup)


def _mech_fn(mech: str, key):
    cfg = SlayFeatureConfig(head_dim=DH)
    if mech == "slay":
        params = init_feature_params(key, cfg)
        return jax.jit(lambda q, k, v: slay_attention(
            params, q, k, v, cfg, causal=True, chunk_size=128))
    if mech == "favor":
        params = bl.favor_init(key, DH)
        return jax.jit(lambda q, k, v: bl.linear_baseline_attention(
            "favor", params, q, k, v, chunk_size=128))
    if mech in ("cosformer", "elu1"):
        return jax.jit(lambda q, k, v: bl.linear_baseline_attention(
            mech, None, q, k, v, chunk_size=128))
    if mech == "softmax":
        return jax.jit(lambda q, k, v: kernels.softmax_attention(
            q, k, v, causal=True))
    if mech == "yat":
        return jax.jit(lambda q, k, v: kernels.yat_attention(
            q, k, v, causal=True))
    raise ValueError(mech)


def _mem_bytes(mech: str, L: int) -> float:
    """Analytic peak attention-state bytes (the paper's Fig. 2 middle)."""
    if mech in ("softmax", "yat"):
        return HEADS * L * L * 4.0               # explicit L x L scores
    m = SlayFeatureConfig(head_dim=DH).feature_dim if mech == "slay" else \
        (64 if mech == "favor" else 2 * DH if mech == "cosformer" else DH)
    return HEADS * (L * m + m * DH) * 4.0        # features + running state


def run(quick: bool = True) -> list[BenchResult]:
    lengths = (256, 1024, 4096) if quick else (256, 1024, 4096, 16384)
    mechs = ("softmax", "yat", "slay", "favor", "elu1", "cosformer")
    results = []
    key = jax.random.PRNGKey(0)
    for mech in mechs:
        fn = _mech_fn(mech, key)
        for L in lengths:
            if mech in ("softmax", "yat") and L > 4096:
                results.append(BenchResult(
                    f"fig2/{mech}/L{L}/latency", float("nan"), "ms",
                    {"oom": True}))
                continue
            ks = jax.random.split(jax.random.fold_in(key, L), 3)
            q = jax.random.normal(ks[0], (1, L, HEADS, DH))
            k = jax.random.normal(ks[1], (1, L, HEADS, DH))
            v = jax.random.normal(ks[2], (1, L, HEADS, DH))
            lat = time_fn(fn, q, k, v, warmup=1, iters=3)
            results += [
                BenchResult(f"fig2/{mech}/L{L}/latency", lat, "ms"),
                BenchResult(f"fig2/{mech}/L{L}/throughput", L / lat * 1e3,
                            "tok/s"),
                BenchResult(f"fig2/{mech}/L{L}/attn_state", _mem_bytes(mech, L),
                            "bytes"),
            ]
    return results


if __name__ == "__main__":
    for r in run(quick=False):
        print(r.csv())
