"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run              # quick suite
    PYTHONPATH=src python -m benchmarks.run --full       # paper-scale sweep
    PYTHONPATH=src python -m benchmarks.run --only table2,fig9
    PYTHONPATH=src python -m benchmarks.run --suite kernels   # kernel bench
    PYTHONPATH=src python -m benchmarks.run --suite serving --smoke  # CI
    PYTHONPATH=src python -m benchmarks.run --suite serving --smoke --chaos

Prints ``name,value,unit`` CSV lines and writes results/benchmarks.json.
``--smoke`` runs tiny shapes with 1 rep — CI's per-PR artifact pass; only
suites that implement it (kernels, serving) accept the flag.
"""
from __future__ import annotations

import argparse
import inspect
import json
import os
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, 1 rep (CI artifact pass)")
    ap.add_argument("--chaos", action="store_true",
                    help="serving suite: append degraded-mode chaos rows "
                         "(fault injection, overload) to BENCH_serving.json")
    ap.add_argument("--only", default=None,
                    help="comma-separated module keys (table2,fig2,...)")
    ap.add_argument("--suite", default=None,
                    help="named group: paper (default) | kernels | serving")
    args = ap.parse_args(argv)
    if args.smoke and args.full:
        ap.error("--smoke and --full are mutually exclusive")

    from benchmarks import (fig2_scaling, fig9_quadrature, kernel_bench,
                            roofline_report, serving_bench,
                            table2_poly_approx, table3_synthetic,
                            table4_extreme, table5_slayformer)
    suites = {
        "table2": table2_poly_approx,
        "fig2": fig2_scaling,
        "fig9": fig9_quadrature,
        "table3": table3_synthetic,
        "table4": table4_extreme,
        "table5": table5_slayformer,
        "roofline": roofline_report,
        "kernels": kernel_bench,
        "serving": serving_bench,
    }
    # The kernel/serving benches are opt-in (their own suite groups); the
    # default / "paper" group runs everything else.
    groups = {"paper": set(suites) - {"kernels", "serving"},
              "kernels": {"kernels"}, "serving": {"serving"}}
    only = set(args.only.split(",")) if args.only else None
    if args.suite:
        if args.suite not in groups:
            ap.error(f"unknown --suite {args.suite!r} "
                     f"(choose from {sorted(groups)})")
        only = groups[args.suite] if only is None else only & groups[args.suite]
        if not only:
            ap.error(f"--only {args.only!r} selects nothing inside "
                     f"--suite {args.suite!r}")
    elif only is None:
        only = groups["paper"]
    all_results = []
    for key, mod in suites.items():
        if only and key not in only:
            continue
        t0 = time.monotonic()
        print(f"# --- {key} ({mod.__name__}) ---", flush=True)
        kwargs = {"quick": not args.full}
        sig = inspect.signature(mod.run).parameters
        if "smoke" in sig:
            kwargs["smoke"] = args.smoke
        elif args.smoke:
            print(f"# {key}: no --smoke support, skipping", flush=True)
            continue
        if "chaos" in sig:
            kwargs["chaos"] = args.chaos
        elif args.chaos:
            print(f"# {key}: no --chaos support, skipping", flush=True)
            continue
        try:
            results = mod.run(**kwargs)
        except Exception as e:  # noqa: BLE001 — report per-suite failures
            print(f"{key}/SUITE_FAILED,{type(e).__name__},{e}",
                  file=sys.stderr)
            raise
        for r in results:
            print(r.csv(), flush=True)
            all_results.append({"name": r.name, "value": r.value,
                                "unit": r.unit, **r.extra})
        print(f"# {key} done in {time.monotonic() - t0:.1f}s", flush=True)

    os.makedirs("results", exist_ok=True)
    with open("results/benchmarks.json", "w") as f:
        json.dump(all_results, f, indent=1)
    print(f"# wrote results/benchmarks.json ({len(all_results)} rows)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
