"""One sharded-pool serving-bench row, in its own forced-multi-device
process.

``benchmarks/serving_bench.py`` spawns this module with
``XLA_FLAGS=--xla_force_host_platform_device_count=<data>`` (jax pins the
device count at first init, so the parent bench process — a plain CPU or
TPU runtime — cannot build a data>1 mesh itself). It replays the *same*
seeded Poisson trace as the parent's constant_state row on a
mesh=(data=N,) slot-sharded pool and prints the result row as JSON on
stdout; the parent merges it into ``BENCH_serving.json`` and the CI
contract step asserts its ``stream_digest`` equals the single-shard
row's — the DESIGN.md §8 byte-identical-stream contract, enforced on
every PR.
"""
from __future__ import annotations

import argparse
import json
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--load", type=float, required=True)
    ap.add_argument("--n", type=int, required=True)
    ap.add_argument("--max-new", type=int, required=True)
    ap.add_argument("--prompt-lo", type=int, required=True)
    ap.add_argument("--prompt-hi", type=int, required=True)
    ap.add_argument("--num-slots", type=int, required=True)
    ap.add_argument("--max-len", type=int, required=True)
    ap.add_argument("--prefill-chunk", type=int, required=True)
    ap.add_argument("--macro-ticks", type=int, required=True)
    ap.add_argument("--data", type=int, required=True)
    args = ap.parse_args()

    import jax
    import numpy as np

    from benchmarks.serving_bench import _poisson_trace, _stream_digest
    from repro import configs
    from repro.configs.base import ServingConfig
    from repro.launch.mesh import make_serving_mesh
    from repro.models import api
    from repro.serving.engine import ContinuousServingEngine

    cfg = configs.get_smoke_config("slayformer-124m", attn_kind="slay")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1234)
    reqs = _poisson_trace(rng, args.n, args.load,
                          (args.prompt_lo, args.prompt_hi),
                          cfg.vocab_size, args.max_new)
    eng = ContinuousServingEngine(
        cfg, params, make_serving_mesh(args.data),
        serving=ServingConfig(num_slots=args.num_slots,
                              max_len=args.max_len,
                              prefill_chunk=args.prefill_chunk,
                              macro_ticks=args.macro_ticks,
                              slot_shards=args.data))
    outs, summary = eng.run(reqs)
    assert summary["requests_completed"] == args.n
    assert summary["slot_shards"] == args.data, summary["slot_shards"]
    row = {"regime": "constant_state_sharded", "load": args.load,
           "num_slots": args.num_slots, "requests": args.n,
           "mesh_devices": jax.device_count(),
           "stream_digest": _stream_digest(outs),
           "jit_cache_entries": eng.jit_cache_entries(), **summary}
    json.dump(row, sys.stdout)
    sys.stdout.write("\n")


if __name__ == "__main__":
    main()
