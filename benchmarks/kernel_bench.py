"""Kernel benchmark: fused megakernel vs two-dispatch vs jnp, fwd and fwd+bwd.

Times the three SLAY causal-attention execution paths across sequence
lengths and emits ``BENCH_kernels.json`` (repo root) so subsequent PRs have
a perf trajectory:

* ``fused``     — `kernels.slay_fused`: Ψ computed in VMEM inside the
                  attention kernel; zero feature HBM traffic by construction.
* ``two_dispatch`` — `kernels.feature_map` then `kernels.slay_scan` with the
                  Ψ(Q)/Ψ(K) round-trip through HBM in between.
* ``jnp``       — the `repro.core` reference (XLA-fused, no Pallas).

Each path is timed forward-only and forward+backward (`jax.grad` w.r.t.
q, k, v — the Pallas paths differentiate through their custom VJPs).

Besides wall-clock, every row carries an analytic HBM-roofline accounting
(`roofline` key): bytes of per-head feature traffic (`psi_hbm_bytes` —
exactly 0 for the fused path) and total per-pass tensor traffic, from the
model in DESIGN.md §3. On CPU the kernels run in interpret mode — absolute
times are meaningless there; the JSON structure and the roofline numbers
are backend-independent.

    PYTHONPATH=src python -m benchmarks.run --suite kernels
    PYTHONPATH=src python -m benchmarks.run --suite kernels --full  # TPU sweep
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from benchmarks.common import BenchResult, time_fn
from repro.core import linear_attention as la
from repro.core.features import (SlayFeatureConfig, init_feature_params,
                                 slay_features)
from repro.kernels import feature_map, slay_fused, slay_scan

_JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_kernels.json")

# Smoke: one tiny L, 1 rep (CI artifact pass); quick: CPU interpret mode
# (structure / trajectory); full: paper-style sweep L ∈ 1k…64k for TPU.
_SMOKE_LS = (128,)
_QUICK_LS = (256, 512)
_FULL_LS = (1_024, 4_096, 16_384, 65_536)


def _roofline(bh: int, bk: int, L: int, d: int, dv: int, m: int,
              path: str) -> dict:
    """Analytic HBM bytes per forward pass (fp32). DESIGN.md §3.

    Common traffic: read q (bh·L·d), k/v (bk·L·(d+dv)), write y (bh·L·dv).
    The two-dispatch path additionally WRITES Ψ(Q)/Ψ(K) ((bh+bk)·L·m) from
    the feature kernel and re-READS them in the scan kernel. The fused path
    never materializes Ψ in HBM: psi bytes ≡ 0 by construction.
    """
    f32 = 4
    io = (bh * L * d + bk * L * (d + dv) + bh * L * dv) * f32
    # two_dispatch and jnp both pay the round-trip (XLA materializes the
    # features across the scan boundary too); only fused avoids it.
    psi = 0 if path == "fused" else 2 * (bh + bk) * L * m * f32
    return {"io_hbm_bytes": io, "psi_hbm_bytes": psi,
            "total_hbm_bytes": io + psi}


def run(quick: bool = True, smoke: bool = False):
    interpret = jax.default_backend() != "tpu"
    Ls = _SMOKE_LS if smoke else (_QUICK_LS if quick else _FULL_LS)
    iters = 1 if smoke else 3
    bh, bk = 4, 2
    d = dv = 64
    chunk = 128
    cfg = SlayFeatureConfig(head_dim=d, num_anchors=8, num_prf=16,
                            num_quad_nodes=3)  # m = 384
    m = cfg.feature_dim
    params = init_feature_params(jax.random.PRNGKey(0), cfg)
    anchors, omegas = params["anchors"], params["omegas"]

    def fused_fwd(q, k, v):
        return slay_fused.fused_causal_attention(
            q, k, v, anchors, omegas, cfg, chunk_size=chunk,
            interpret=interpret)

    def two_dispatch_fwd(q, k, v):
        qf = feature_map.slay_feature_map(
            q.reshape(-1, d), anchors, omegas, cfg, block_tokens=chunk,
            interpret=interpret).reshape(bh, -1, m)
        kf = feature_map.slay_feature_map(
            k.reshape(-1, d), anchors, omegas, cfg, block_tokens=chunk,
            interpret=interpret).reshape(bk, -1, m)
        return slay_scan.causal_linear_attention(
            qf, kf, v, chunk_size=chunk, interpret=interpret)

    def jnp_fwd(q, k, v):
        g = bh // bk
        qf = slay_features(q, params, cfg)
        kf = slay_features(k, params, cfg)
        qq = qf.reshape(bk, g, qf.shape[1], m).transpose(0, 2, 1, 3)
        y = la.causal_chunked(qq, kf[:, :, None, :], v[:, :, None, :],
                              chunk_size=chunk)
        return y.transpose(0, 2, 1, 3).reshape(bh, -1, dv)

    paths = {"fused": fused_fwd, "two_dispatch": two_dispatch_fwd,
             "jnp": jnp_fwd}
    results = []
    rows = []
    for L in Ls:
        kq, kk, kv = jax.random.split(jax.random.PRNGKey(L), 3)
        q = jax.random.normal(kq, (bh, L, d))
        k = jax.random.normal(kk, (bk, L, d))
        v = jax.random.normal(kv, (bk, L, dv))
        for name, fn in paths.items():
            fwd = jax.jit(fn)
            grad = jax.jit(jax.grad(
                lambda q, k, v, f=fn: jnp.sum(f(q, k, v)),
                argnums=(0, 1, 2)))
            t_fwd = time_fn(fwd, q, k, v, warmup=1, iters=iters)
            t_fb = time_fn(grad, q, k, v, warmup=1, iters=iters)
            roof = _roofline(bh, bk, L, d, dv, m, name)
            for phase, t in (("fwd", t_fwd), ("fwd_bwd", t_fb)):
                results.append(BenchResult(
                    f"kernels/{name}/{phase}/L{L}", t, "ms",
                    extra={"L": L, "path": name, "phase": phase,
                           "roofline": roof}))
            rows.append({"L": L, "path": name, "fwd_ms": t_fwd,
                         "fwd_bwd_ms": t_fb, **roof})

    payload = {
        "meta": {
            "backend": jax.default_backend(),
            "interpret": interpret,
            "quick": quick,
            "smoke": smoke,
            "shape": {"bh": bh, "bk": bk, "d": d, "dv": dv, "m": m,
                      "chunk": chunk, "P": cfg.num_anchors,
                      "D": cfg.num_prf, "R": cfg.num_quad_nodes},
            "note": ("interpret-mode timings are structural only; "
                     "psi_hbm_bytes is the analytic feature round-trip "
                     "(0 for fused — Ψ never leaves VMEM)"),
        },
        "results": rows,
    }
    with open(_JSON_PATH, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    return results
