"""Quickstart: SLAY attention in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

1. Builds the SLAY feature map (anchor poly x PRFs x Gauss-Laguerre nodes).
2. Runs linear-time attention and compares against exact spherical-Yat
   attention (the quadratic oracle it approximates).
3. Trains a 2-layer SLAYformer for 30 steps on synthetic data.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kernels
from repro.core.features import SlayFeatureConfig
from repro.core.slay import slay_attention, slay_init
from repro.models import api
from repro import configs
from repro.data.pipeline import DataConfig, batch_iterator
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train.loop import TrainConfig, make_train_step


def demo_attention():
    print("=== 1. SLAY linear attention vs exact spherical Yat ===")
    key = jax.random.PRNGKey(0)
    B, L, H, d = 1, 256, 4, 64
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, L, H, d))
    k = jax.random.normal(ks[1], (B, L, H, d))
    v = jax.random.normal(ks[2], (B, L, H, d))

    cfg = SlayFeatureConfig(head_dim=d)   # P=8 anchors, D=16 PRFs, R=3 nodes
    params = slay_init(ks[3], cfg)
    y_slay = slay_attention(params, q, k, v, cfg, causal=True)
    y_exact = kernels.yat_attention(q, k, v, causal=True, spherical=True)
    rel = float(jnp.linalg.norm(y_slay - y_exact)
                / jnp.linalg.norm(y_exact))
    print(f"feature dim m = {cfg.feature_dim} per head "
          f"(vs L = {L} keys materialized by the quadratic kernel)")
    print(f"attention-output rel-L2 vs exact: {rel:.3f} "
          f"(paper Table 2 reports ~0.5 at matched budgets)\n")


def demo_training():
    print("=== 2. Train a tiny SLAYformer ===")
    cfg = configs.get_smoke_config("slayformer-124m")
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=30)
    step = jax.jit(make_train_step(
        cfg, opt_cfg, TrainConfig(microbatches=1, remat=False)))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params, opt_cfg)
    ef = jnp.zeros(())
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
    for i, batch in batch_iterator(dcfg):
        if i >= 30:
            break
        params, opt, ef, m = step(params, opt, ef, batch)
        if i % 10 == 0:
            print(f"step {i:3d}  loss {float(m['loss']):.4f}  "
                  f"grad_norm {float(m['grad_norm']):.3f}")
    print("done — loss is decreasing under linear-time attention.\n")


if __name__ == "__main__":
    demo_attention()
    demo_training()
