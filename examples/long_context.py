"""Long-context decode with SLAY: process a 32k-token prompt through the
linear-attention state and decode with O(1) memory — then contrast with the
quadratic path's L^2 cost curve (paper Fig. 2 / §3.2).

    PYTHONPATH=src python examples/long_context.py [--prompt-len 32768]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kernels
from repro.core.features import SlayFeatureConfig
from repro.core.slay import (slay_decode_step, slay_init,
                             slay_prefill_state)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--prompt-len", type=int, default=32768)
    ap.add_argument("--decode-steps", type=int, default=16)
    ap.add_argument("--head-dim", type=int, default=64)
    ap.add_argument("--heads", type=int, default=8)
    args = ap.parse_args()

    d, H, L = args.head_dim, args.heads, args.prompt_len
    cfg = SlayFeatureConfig(head_dim=d)
    key = jax.random.PRNGKey(0)
    params = slay_init(key, cfg)
    ks = jax.random.split(key, 3)
    k = jax.random.normal(ks[0], (1, L, H, d), jnp.bfloat16)
    v = jax.random.normal(ks[1], (1, L, H, d), jnp.bfloat16)

    print(f"prompt: {L} tokens x {H} heads x {d} dims")
    t0 = time.perf_counter()
    state = jax.jit(lambda k, v: slay_prefill_state(params, k, v, cfg))(k, v)
    jax.block_until_ready(state)
    t_pre = time.perf_counter() - t0
    state_bytes = sum(np.prod(x.shape) * 4 for x in (state.s, state.z))
    kv_bytes = 2 * L * H * d * 2
    print(f"prefill (linear absorb): {t_pre:.2f}s")
    print(f"SLAY decode state: {state_bytes / 1e6:.2f} MB "
          f"(m={cfg.feature_dim} features/head)")
    print(f"equivalent KV cache:  {kv_bytes / 1e6:.2f} MB "
          f"({kv_bytes / state_bytes:.1f}x larger, grows with L)")

    dec = jax.jit(lambda q, k1, v1, s: slay_decode_step(
        params, q, k1, v1, s, cfg))
    q1 = jax.random.normal(ks[2], (1, H, d), jnp.bfloat16)
    y, state = dec(q1, q1, q1, state)   # warmup
    t0 = time.perf_counter()
    for _ in range(args.decode_steps):
        y, state = dec(q1, q1, q1, state)
    jax.block_until_ready(y)
    per_tok = (time.perf_counter() - t0) / args.decode_steps * 1e3
    print(f"decode: {per_tok:.2f} ms/token — independent of the {L}-token "
          "context (O(m*dv) per step)")

    # Quadratic comparison at small L (it would OOM at 32k on real HBM).
    Ls = [256, 512, 1024]
    print("\nquadratic spherical-Yat attention cost curve (for contrast):")
    for Lq in Ls:
        kk = k[:, :Lq].astype(jnp.float32)
        vv = v[:, :Lq].astype(jnp.float32)
        qq = jax.random.normal(key, (1, Lq, H, d))
        f = jax.jit(lambda q, k, v: kernels.yat_attention(
            q, k, v, causal=True, spherical=True))
        out = f(qq, kk, vv)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        jax.block_until_ready(f(qq, kk, vv))
        print(f"  L={Lq:5d}: {(time.perf_counter() - t0) * 1e3:8.1f} ms, "
              f"scores matrix {H * Lq * Lq * 4 / 1e6:.1f} MB")


if __name__ == "__main__":
    main()
