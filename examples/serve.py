"""Serving demo: continuous batching (slot pool, staggered arrivals,
streaming) or the lockstep reference, with SLAY's constant-size recurrent
state (no KV cache growth).

    PYTHONPATH=src python examples/serve.py                  # continuous
    PYTHONPATH=src python examples/serve.py --lockstep
    PYTHONPATH=src python examples/serve.py --arch phi4-mini-3.8b --smoke
"""
import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.configs.base import ServingConfig
from repro.launch.mesh import make_host_mesh
from repro.models import api
from repro.serving.engine import (ContinuousServingEngine, Request,
                                  ServingEngine)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="slayformer-124m",
                    choices=list(configs.ALL_ARCHS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--attn-kind", default=None)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lockstep", action="store_true",
                    help="lockstep reference instead of continuous batching")
    ap.add_argument("--slots", type=int, default=2)
    args = ap.parse_args()

    overrides = {"attn_kind": args.attn_kind} if args.attn_kind else {}
    cfg = configs.get_smoke_config(args.arch, **overrides)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    mesh = make_host_mesh()

    rng = np.random.default_rng(0)
    reqs = [Request(rng.integers(3, cfg.vocab_size,
                                 size=rng.integers(4, 12)).astype(np.int32),
                    max_new_tokens=args.max_new,
                    arrival_time=float(2 * i))
            for i in range(args.batch)]
    print(f"serving {len(reqs)} requests on {cfg.name} "
          f"(attn={cfg.attn_kind})...")
    t0 = time.perf_counter()
    if args.lockstep:
        engine = ServingEngine(cfg, params, mesh, max_len=256)
        outs = engine.generate(reqs, temperature=0.8)
    else:
        engine = ContinuousServingEngine(
            cfg, params, mesh,
            serving=ServingConfig(num_slots=args.slots, max_len=256,
                                  prefill_chunk=8, temperature=0.8))
        out_map, summary = engine.run(reqs)
        outs = [out_map[i] for i in range(len(reqs))]
        print(f"  pool: {args.slots} slots | occupancy "
              f"{summary['mean_slot_occupancy']:.2f} | TTFT p50 "
              f"{summary['ttft_ticks_p50']} ticks | "
              f"{summary['decode_tokens_per_s']:.1f} decode tok/s")
    dt = time.perf_counter() - t0
    total = sum(len(o) for o in outs)
    for i, o in enumerate(outs):
        print(f"req {i}: prompt_len={len(reqs[i].prompt)} -> {o[:12]}...")
    print(f"\n{total} tokens in {dt:.2f}s ({total / dt:.1f} tok/s batched)")

    # The long-context pitch: decode state size is context-independent.
    c_small = api.abstract_cache(cfg, args.batch, 256)
    c_huge = api.abstract_cache(cfg, args.batch, 524_288)

    def nbytes(tree):
        return sum(np.prod(x.shape) * x.dtype.itemsize
                   for x in jax.tree.leaves(tree))
    print(f"decode-state bytes @256 ctx:  {nbytes(c_small):,}")
    print(f"decode-state bytes @524288 ctx: {nbytes(c_huge):,} "
          f"(constant — the paper's O(1) long-context memory)")


if __name__ == "__main__":
    main()
