"""Serving demo: continuous batching (slot pool, staggered arrivals,
streaming) or the lockstep reference, with SLAY's constant-size recurrent
state (no KV cache growth).

    PYTHONPATH=src python examples/serve.py                  # continuous
    PYTHONPATH=src python examples/serve.py --lockstep
    PYTHONPATH=src python examples/serve.py --arch phi4-mini-3.8b --smoke

Sharded slot pool (DESIGN.md §8) — on CPU, force a multi-device runtime
first (jax pins its device count at first init):

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
        PYTHONPATH=src python examples/serve.py --slot-shards 4 --slots 4

The token streams printed are byte-identical to the unsharded run: the
sampler is keyed on (seed, rid, token-index), never on slot or shard
placement.

Paged slot memory + prefix cache (DESIGN.md §11) — page the KV rings so
short requests pin only the pages they need, and reuse shared prompt
prefixes across requests by state-snapshot copy; both preserve stream
byte-identity:

    PYTHONPATH=src python examples/serve.py --attn-kind softmax \\
        --page-size 16 --prefix-cache 64

Speculative decoding (DESIGN.md §13) — the linear SLAY regime drafts
gamma tokens per slot, the exact verifier scores them in one chunked
dispatch, and the accept/resample correction keeps the emitted streams
byte-identical to plain exact decode at temperature 0 (and exactly
verifier-distributed when sampled):

    PYTHONPATH=src python examples/serve.py --speculative --spec-gamma 2
"""
import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.configs.base import ServingConfig
from repro.launch.mesh import make_host_mesh, make_serving_mesh
from repro.models import api
from repro.serving.engine import (AdmissionError, ContinuousServingEngine,
                                  Request, ServingEngine)
from repro.serving.prefix_cache import PrefixCache


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="slayformer-124m",
                    choices=list(configs.ALL_ARCHS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--attn-kind", default=None)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lockstep", action="store_true",
                    help="lockstep reference instead of continuous batching")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--slot-shards", type=int, default=0,
                    help="shard the slot pool N-way over the mesh `data` "
                         "axis (DESIGN.md §8); needs >= N devices")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bound the admission queue (0 = unbounded); with "
                         "the default reject_new policy, overflow raises a "
                         "typed AdmissionError this demo catches")
    ap.add_argument("--overload-policy", default="reject_new",
                    choices=("reject_new", "shed_oldest", "queue_wait"))
    ap.add_argument("--page-size", type=int, default=0,
                    help="page the pooled KV rings into fixed-size pages "
                         "(DESIGN.md §11); 0 = unpaged. Ignored for "
                         "constant-state kinds (nothing to page)")
    ap.add_argument("--prefix-cache", type=int, default=0, metavar="MB",
                    help="content-addressed prompt-prefix cache budget in "
                         "MB (DESIGN.md §11); 0 = off. Repeated/shared "
                         "prompt prefixes seed their slot from a stored "
                         "snapshot instead of re-prefilling")
    ap.add_argument("--speculative", action="store_true",
                    help="draft-verify decode (DESIGN.md §13): linear SLAY "
                         "drafts, the exact verifier scores gamma+1 tokens "
                         "per dispatch. Needs an exact attn kind; defaults "
                         "the verifier to yat_spherical if --attn-kind is "
                         "not given")
    ap.add_argument("--spec-gamma", type=int, default=2,
                    help="draft tokens per speculative round")
    args = ap.parse_args()
    if args.speculative and args.prefix_cache:
        ap.error("--speculative and --prefix-cache are mutually exclusive "
                 "(DESIGN.md §13)")
    if args.speculative and args.lockstep:
        ap.error("--speculative needs the continuous engine")

    overrides = {"attn_kind": args.attn_kind} if args.attn_kind else {}
    if args.speculative and not args.attn_kind:
        # Exact verifier + a deliberately small SLAY draft trunk so the
        # demo's draft steps stay cheap on CPU.
        overrides = {"attn_kind": "yat_spherical",
                     "slay_anchors": 16, "slay_prf": 32}
    cfg = configs.get_smoke_config(args.arch, **overrides)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    # DESIGN §8 walkthrough, step 1 — the mesh: the `data` axis carries
    # slot parallelism at serving time. make_serving_mesh(N) takes the
    # first N devices; with N=1 this is the plain host mesh.
    if args.slot_shards > 1:
        mesh = make_serving_mesh(args.slot_shards)
    else:
        mesh = make_host_mesh()

    rng = np.random.default_rng(0)
    reqs = [Request(rng.integers(3, cfg.vocab_size,
                                 size=rng.integers(4, 12)).astype(np.int32),
                    max_new_tokens=args.max_new,
                    arrival_time=float(2 * i))
            for i in range(args.batch)]
    print(f"serving {len(reqs)} requests on {cfg.name} "
          f"(attn={cfg.attn_kind})...")
    t0 = time.perf_counter()
    if args.lockstep:
        engine = ServingEngine(cfg, params, mesh, max_len=256)
        outs = engine.generate(reqs, temperature=0.8)
    else:
        # DESIGN §8 walkthrough, step 2 — the engine: slot_shards > 1
        # shards the pool cache, every per-slot control vector, and the
        # (K, S) macro-step token buffer over `data` in static contiguous
        # slot blocks. Admission balances across shards; eviction is a
        # shard-local slot overwrite; the K-tick decode scan runs with
        # zero cross-shard collectives (engine.decode_hlo() shows the
        # compiled proof).
        pc = (PrefixCache(args.prefix_cache * 1024 * 1024)
              if args.prefix_cache else None)
        engine = ContinuousServingEngine(
            cfg, params, mesh, prefix_cache=pc,
            serving=ServingConfig(num_slots=args.slots, max_len=256,
                                  prefill_chunk=8, temperature=0.8,
                                  slot_shards=args.slot_shards,
                                  max_queue=args.max_queue,
                                  overload_policy=args.overload_policy,
                                  page_size=args.page_size,
                                  speculative=args.speculative,
                                  spec_gamma=args.spec_gamma))
        # Typed admission (DESIGN.md §10): a refused request raises an
        # AdmissionError subclass carrying queue_depth/max_queue, so a
        # caller can back off or report precisely — no message parsing.
        admitted = []                      # (rid, request) pairs
        for r in reqs:
            try:
                admitted.append((engine.submit(r), r))
            except AdmissionError as e:
                print(f"  refused ({type(e).__name__}, queue "
                      f"{e.queue_depth}/{e.max_queue}): {e}")
        out_map, summary = engine.run()
        outs = [out_map[rid] for rid, _ in admitted]
        reqs = [r for _, r in admitted]
        print(f"  finish reasons: {summary['finish_reasons']}")
        # DESIGN §8 walkthrough, step 3 — the contract: rerun this script
        # with/without --slot-shards and diff the token lines below; they
        # are byte-identical (slot_shards in the summary confirms the
        # pool really sharded rather than hitting the divisibility
        # fallback).
        print(f"  pool: {args.slots} slots x {summary['slot_shards']} "
              f"shard(s) | occupancy "
              f"{summary['mean_slot_occupancy']:.2f} | TTFT p50 "
              f"{summary['ttft_ticks_p50']} ticks | "
              f"{summary['decode_tokens_per_s']:.1f} decode tok/s")
        # DESIGN §11: page-pool pressure and prefix-cache reuse, when on.
        if summary["num_pages"]:
            print(f"  pages: {summary['pages_peak']}/"
                  f"{summary['num_pages']} peak in use "
                  f"({args.page_size} rows/page); leaked "
                  f"{summary['final_pages_in_use']}")
        if pc is not None:
            print(f"  prefix cache: {summary['prefix_hits']} hits, "
                  f"{summary['prefix_tokens_reused']} prompt tokens "
                  f"reused | {pc.stats()}")
        # DESIGN §13: draft-verify amortization — one verifier dispatch
        # emits up to K * (gamma + 1) tokens.
        if summary["speculative"]:
            print(f"  speculative: gamma={summary['spec_gamma']} | "
                  f"acceptance {summary['draft_acceptance_rate']:.3f} "
                  f"({summary['draft_tokens_accepted']}/"
                  f"{summary['draft_tokens_proposed']} drafts) | "
                  f"{summary['tokens_per_dispatch']:.1f} tok/dispatch")
    dt = time.perf_counter() - t0
    total = sum(len(o) for o in outs)
    for i, o in enumerate(outs):
        print(f"req {i}: prompt_len={len(reqs[i].prompt)} -> {o[:12]}...")
    print(f"\n{total} tokens in {dt:.2f}s ({total / dt:.1f} tok/s batched)")

    # The long-context pitch: decode state size is context-independent.
    c_small = api.abstract_cache(cfg, args.batch, 256)
    c_huge = api.abstract_cache(cfg, args.batch, 524_288)

    def nbytes(tree):
        return sum(np.prod(x.shape) * x.dtype.itemsize
                   for x in jax.tree.leaves(tree))
    print(f"decode-state bytes @256 ctx:  {nbytes(c_small):,}")
    print(f"decode-state bytes @524288 ctx: {nbytes(c_huge):,} "
          f"(constant — the paper's O(1) long-context memory)")


if __name__ == "__main__":
    main()
