"""End-to-end training driver: SLAYformer on synthetic LM data with the
full production substrate — sharded params, microbatching, remat,
checkpointing, resume, straggler watchdog.

CPU-reduced preset (default) trains a ~10M model for 200 steps in a few
minutes; the paper preset (--preset paper) is the 124M GPT-2-small-scale
SLAYformer from Table 5; --arch selects any of the 10 assigned
architectures (reduced smoke variant with --smoke).

    PYTHONPATH=src python examples/train_slayformer.py
    PYTHONPATH=src python examples/train_slayformer.py --steps 500 \
        --attn-kind softmax          # quadratic baseline, same budget
"""
import argparse
import dataclasses
import logging

from repro import configs
from repro.data.pipeline import DataConfig, batch_iterator
from repro.launch.mesh import make_host_mesh
from repro.optim.adamw import AdamWConfig
from repro.train.loop import TrainConfig, Trainer

logging.basicConfig(level=logging.INFO, format="%(message)s")


def build_config(args):
    if args.preset == "paper":
        cfg = configs.get_config("slayformer-124m")
    elif args.smoke or args.preset == "cpu":
        cfg = configs.get_smoke_config(args.arch)
        if args.preset == "cpu" and args.arch == "slayformer-124m":
            cfg = dataclasses.replace(cfg, num_layers=4, d_model=128,
                                      num_heads=4, num_kv_heads=4, d_ff=512,
                                      vocab_size=512, dtype="float32")
    else:
        cfg = configs.get_config(args.arch)
    if args.attn_kind:
        cfg = dataclasses.replace(cfg, attn_kind=args.attn_kind)
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="slayformer-124m",
                    choices=list(configs.ALL_ARCHS))
    ap.add_argument("--preset", default="cpu", choices=["cpu", "paper",
                                                        "full"])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--attn-kind", default=None)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/slayformer_ckpt")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    cfg = build_config(args)
    print(f"arch={cfg.name} attn={cfg.attn_kind} layers={cfg.num_layers} "
          f"d_model={cfg.d_model} params~{cfg.param_count_dense / 1e6:.1f}M")

    mesh = make_host_mesh()
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                          total_steps=args.steps)
    train_cfg = TrainConfig(microbatches=args.microbatches, remat=False,
                            compress_grads=args.compress_grads,
                            ckpt_dir=args.ckpt_dir, ckpt_every=50)
    trainer = Trainer(cfg, opt_cfg, train_cfg, mesh)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch)
    history = trainer.run(batch_iterator(dcfg, start_step=trainer.step),
                          num_steps=args.steps, log_every=10)
    if history:
        first, last = history[0]["loss"], history[-1]["loss"]
        print(f"\nloss {first:.4f} -> {last:.4f} over {len(history)} steps "
              f"(resume from step {trainer.step} by re-running)")


if __name__ == "__main__":
    main()
