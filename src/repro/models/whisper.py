"""Whisper-style encoder-decoder backbone (audio frontend is a stub).

Per the assignment, the conv-mel frontend is NOT modeled: ``input_specs``
provides precomputed frame embeddings (B, enc_seq, d_model). The backbone is
faithful: bidirectional encoder self-attention, causal decoder
self-attention, and decoder->encoder cross-attention. Under the SLAY backend
all three linearize (cross-attention uses the plain non-causal reordering,
paper App. I) — self-attn caches are constant-size at decode and the
cross-attention state is a single (m x dv) summary of the whole encoding.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import linear_attention as la
from repro.core.slay import slay_init
from repro.distributed.sharding import constrain
from repro.models import attention as attn
from repro.models.layers import (ParamSpec, axes_of, embed, embed_spec, mlp,
                                 mlp_specs, realize, rmsnorm, rmsnorm_spec,
                                 rope, stack_specs, unembed)
from repro.models.transformer import attn_proj_specs, _merge_cache


def _enc_layer_specs(cfg: ArchConfig) -> dict:
    return {"pre_attn": rmsnorm_spec(cfg.d_model),
            "pre_mlp": rmsnorm_spec(cfg.d_model),
            "attn": attn_proj_specs(cfg),
            "mlp": mlp_specs(cfg.d_model, cfg.d_ff, cfg.gated_mlp)}


def _dec_layer_specs(cfg: ArchConfig) -> dict:
    t = _enc_layer_specs(cfg)
    t["pre_cross"] = rmsnorm_spec(cfg.d_model)
    t["cross"] = attn_proj_specs(cfg)
    return t


def model_specs(cfg: ArchConfig) -> dict:
    return {
        "embed": embed_spec(cfg.vocab_size, cfg.d_model),
        "enc_pos": ParamSpec((cfg.enc_seq, cfg.d_model), (None, "embed"),
                             scale=0.02),
        "enc_layers": stack_specs(_enc_layer_specs(cfg), cfg.enc_layers),
        "enc_norm": rmsnorm_spec(cfg.d_model),
        "dec_layers": stack_specs(_dec_layer_specs(cfg), cfg.num_layers),
        "final_norm": rmsnorm_spec(cfg.d_model),
    }


def init_params(cfg: ArchConfig, key: jax.Array) -> dict:
    k_model, k_slay = jax.random.split(key)
    params = realize(model_specs(cfg), k_model, cfg.activation_dtype)
    if cfg.attn_kind == "slay":
        params["slay"] = slay_init(k_slay, cfg.slay_config())
    return params


def param_axes(cfg: ArchConfig) -> dict:
    axes = axes_of(model_specs(cfg))
    if cfg.attn_kind == "slay":
        axes["slay"] = {"anchors": (None, None), "omegas": (None, None)}
    return axes


_AHEAD = ("act_batch", "act_seq", "act_heads", None)
_ARES = ("act_batch", "act_seq", "act_embed")


def _qkv(lp: dict, x, positions, cfg: ArchConfig, *, use_rope: bool):
    q = constrain(jnp.einsum("bld,dhk->blhk", x, lp["wq"]), _AHEAD)
    k = constrain(jnp.einsum("bld,dhk->blhk", x, lp["wk"]), _AHEAD)
    v = constrain(jnp.einsum("bld,dhk->blhk", x, lp["wv"]), _AHEAD)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def encode(params: dict, cfg: ArchConfig, frame_embeds: jnp.ndarray):
    """frame_embeds (B, T, d) -> encoder output (B, T, d)."""
    x = frame_embeds + params["enc_pos"].astype(frame_embeds.dtype)
    spec = cfg.attention_spec()
    slay_params = jax.lax.stop_gradient(params.get("slay"))
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None]

    def body(x, lp):
        x = constrain(x, _ARES)
        xa = rmsnorm(lp["pre_attn"], x)
        q, k, v = _qkv(lp["attn"], xa, positions, cfg, use_rope=False)
        y = attn.full_attention(spec, slay_params, q, k, v, causal=False)
        x = x + constrain(jnp.einsum("blhk,hkd->bld", y, lp["attn"]["wo"]),
                          _ARES)
        x = x + mlp(lp["mlp"], rmsnorm(lp["pre_mlp"], x), cfg.gated_mlp)
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rmsnorm(params["enc_norm"], x)


def forward(params: dict, cfg: ArchConfig, tokens: jnp.ndarray,
            frame_embeds: jnp.ndarray, *, remat: bool = False):
    """Teacher-forced decoder over encoded audio. Returns (logits, aux=0)."""
    enc = encode(params, cfg, frame_embeds)
    x = embed(params["embed"], tokens).astype(cfg.activation_dtype)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None]
    spec = cfg.attention_spec()
    slay_params = jax.lax.stop_gradient(params.get("slay"))

    def body(x, lp):
        x = constrain(x, _ARES)
        xa = rmsnorm(lp["pre_attn"], x)
        q, k, v = _qkv(lp["attn"], xa, positions, cfg, use_rope=True)
        y = attn.full_attention(spec, slay_params, q, k, v, causal=True)
        x = x + constrain(jnp.einsum("blhk,hkd->bld", y, lp["attn"]["wo"]),
                          _ARES)
        xc = rmsnorm(lp["pre_cross"], x)
        qc = constrain(jnp.einsum("bld,dhk->blhk", xc, lp["cross"]["wq"]),
                       _AHEAD)
        kc = constrain(jnp.einsum("bld,dhk->blhk", enc, lp["cross"]["wk"]),
                       _AHEAD)
        vc = constrain(jnp.einsum("bld,dhk->blhk", enc, lp["cross"]["wv"]),
                       _AHEAD)
        yc = attn.cross_attention(spec, slay_params, qc, kc, vc)
        x = x + constrain(jnp.einsum("blhk,hkd->bld", yc, lp["cross"]["wo"]),
                          _ARES)
        x = x + mlp(lp["mlp"], rmsnorm(lp["pre_mlp"], x), cfg.gated_mlp)
        return x, None

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = rmsnorm(params["final_norm"], x)
    return unembed(params["embed"], x), jnp.zeros((), jnp.float32)


def loss_fn(params: dict, cfg: ArchConfig, batch: dict, *,
            remat: bool = False):
    logits, aux = forward(params, cfg, batch["tokens"],
                          batch["frame_embeds"], remat=remat)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["labels"][..., None],
                               axis=-1)[..., 0]
    nll = jnp.mean(logz - gold)
    return nll, {"nll": nll, "moe_aux": aux}


class WhisperCache(NamedTuple):
    self_attn: attn.AttnCache        # stacked (num_layers, ...)
    cross_s: jnp.ndarray             # (nl, B, Hkv, m, dv) fp32 (or kv cache)
    cross_z: jnp.ndarray             # (nl, B, Hkv, m)
    pos: jnp.ndarray


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> WhisperCache:
    nl, dh = cfg.num_layers, cfg.resolved_head_dim
    spec = cfg.attention_spec()
    m = (spec.slay.feature_dim if spec.kind == "slay"
         else attn._baseline_dim(spec, dh)) if spec.is_linear else cfg.enc_seq
    if spec.is_linear:
        a = attn.AttnCache(
            None, None, jnp.zeros((nl, batch), jnp.int32),
            jnp.zeros((nl, batch, cfg.num_kv_heads, m, dh), jnp.float32),
            jnp.zeros((nl, batch, cfg.num_kv_heads, m), jnp.float32))
        cs = jnp.zeros((nl, batch, cfg.num_kv_heads, m, dh), jnp.float32)
        cz = jnp.zeros((nl, batch, cfg.num_kv_heads, m), jnp.float32)
    else:
        a = attn.AttnCache(
            jnp.zeros((nl, batch, max_len, cfg.num_kv_heads, dh),
                      cfg.activation_dtype),
            jnp.zeros((nl, batch, max_len, cfg.num_kv_heads, dh),
                      cfg.activation_dtype),
            jnp.zeros((nl, batch), jnp.int32), None, None)
        # Softmax cross: store encoder k/v per layer.
        cs = jnp.zeros((nl, batch, cfg.enc_seq, cfg.num_kv_heads, dh),
                       jnp.float32)
        cz = jnp.zeros((nl, batch, cfg.enc_seq, cfg.num_kv_heads, dh),
                       jnp.float32)
    return WhisperCache(a, cs, cz, jnp.zeros((batch,), jnp.int32))


def reset_slot(cfg: ArchConfig, cache: WhisperCache,
               slot: int) -> WhisperCache:
    """Zero one slot of a pooled decode cache (eviction); see
    transformer.reset_slot."""
    a = jax.tree.map(lambda x: x.at[:, slot].set(0), cache.self_attn)
    return WhisperCache(a, cache.cross_s.at[:, slot].set(0),
                        cache.cross_z.at[:, slot].set(0),
                        cache.pos.at[slot].set(0))


def write_slot(cfg: ArchConfig, cache: WhisperCache, src: WhisperCache,
               slot: int) -> WhisperCache:
    """Install a batch=1 request cache into a pooled cache slot."""
    a = jax.tree.map(lambda dst, s: dst.at[:, slot].set(s[:, 0]),
                     cache.self_attn, src.self_attn)
    return WhisperCache(a, cache.cross_s.at[:, slot].set(src.cross_s[:, 0]),
                        cache.cross_z.at[:, slot].set(src.cross_z[:, 0]),
                        cache.pos.at[slot].set(src.pos[0]))


def slot_state_finite(cfg: ArchConfig, cache: WhisperCache) -> jnp.ndarray:
    """(B,) bool — per-slot finiteness over self-attn state and the cached
    cross-attention summaries; see transformer.slot_state_finite."""
    B = cache.pos.shape[0]
    ok = jnp.ones((B,), bool)
    for leaf in jax.tree.leaves((cache.self_attn, cache.cross_s,
                                 cache.cross_z)):
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            continue
        axes = tuple(i for i in range(leaf.ndim) if i != 1)
        ok = ok & jnp.all(jnp.isfinite(leaf), axis=axes)
    return ok


def corrupt_slot(cfg: ArchConfig, cache: WhisperCache,
                 slot: int) -> WhisperCache:
    """NaN one slot's float state (chaos-harness fault injection); see
    transformer.corrupt_slot."""
    def nan_row(x):
        if not jnp.issubdtype(x.dtype, jnp.floating):
            return x
        return x.at[:, slot].set(jnp.nan)

    return WhisperCache(jax.tree.map(nan_row, cache.self_attn),
                        nan_row(cache.cross_s), nan_row(cache.cross_z),
                        cache.pos)


def supports_chunked_prefill(cfg: ArchConfig) -> bool:
    """Encoder-decoder prefill re-encodes audio; no incremental form."""
    return False


def context_capacity(cfg: ArchConfig, max_len: int) -> int | None:
    """Linear decoders carry constant-size state (unbounded context);
    softmax decoders are capped by the self-attention ring."""
    return None if cfg.attention_spec().is_linear else max_len


def supports_masked_prefill(cfg: ArchConfig) -> bool:
    """No ``true_len`` masking for encdec (the audio encoding dominates the
    prefill compile anyway; prompt-length bucketing buys nothing)."""
    return False


def prefill_chunk(params: dict, cfg: ArchConfig, cache: WhisperCache,
                  tokens: jnp.ndarray):
    raise NotImplementedError(
        f"chunked prefill unsupported for {cfg.name}: gate "
        f"family='encdec' — prefill encodes the audio frames whole "
        f"(cross-attention state has no chunk-by-chunk continuation); "
        f"serve encdec via whole-prompt prefill")


def prefill(params: dict, cfg: ArchConfig, tokens: jnp.ndarray,
            frame_embeds: jnp.ndarray, *, max_len: int | None = None,
            true_len=None):
    """Encode audio + absorb the prompt; returns (logits, WhisperCache)."""
    if true_len is not None:
        raise NotImplementedError("true_len-masked prefill unsupported "
                                  "for encdec")
    enc = encode(params, cfg, frame_embeds)
    B, L = tokens.shape
    x = embed(params["embed"], tokens).astype(cfg.activation_dtype)
    positions = jnp.arange(L, dtype=jnp.int32)[None]
    spec = cfg.attention_spec()
    slay_params = params.get("slay")
    cache0 = init_cache(cfg, B, max_len if max_len else L + 64)

    def body(x, scanned):
        lp = scanned["params"]
        x = constrain(x, _ARES)
        xa = rmsnorm(lp["pre_attn"], x)
        q, k, v = _qkv(lp["attn"], xa, positions, cfg, use_rope=True)
        y = attn.full_attention(spec, slay_params, q, k, v, causal=True)
        nac = _merge_cache(scanned["attn"],
                           attn.prefill_cache(spec, slay_params, k, v,
                                              scanned["attn"]))
        x = x + constrain(jnp.einsum("blhk,hkd->bld", y, lp["attn"]["wo"]),
                          _ARES)
        xc = rmsnorm(lp["pre_cross"], x)
        qc = constrain(jnp.einsum("bld,dhk->blhk", xc, lp["cross"]["wq"]),
                       _AHEAD)
        kc = constrain(jnp.einsum("bld,dhk->blhk", enc, lp["cross"]["wk"]),
                       _AHEAD)
        vc = constrain(jnp.einsum("bld,dhk->blhk", enc, lp["cross"]["wv"]),
                       _AHEAD)
        yc = attn.cross_attention(spec, slay_params, qc, kc, vc)
        if spec.is_linear:
            from repro.core.features import slay_features
            kf = (slay_features(kc, slay_params, spec.slay)
                  if spec.kind == "slay" else attn._features(
                      spec, slay_params, kc))
            st = la.prefill_state(kf, vc)
            cs, cz = st.s, st.z
        else:
            cs, cz = kc.astype(jnp.float32), vc.astype(jnp.float32)
        x = x + jnp.einsum("blhk,hkd->bld", yc, lp["cross"]["wo"])
        x = x + mlp(lp["mlp"], rmsnorm(lp["pre_mlp"], x), cfg.gated_mlp)
        return x, {"attn": nac, "cs": cs, "cz": cz}

    x, ys = jax.lax.scan(body, x, {"params": params["dec_layers"],
                                   "attn": cache0.self_attn})
    x = rmsnorm(params["final_norm"], x[:, -1])
    logits = unembed(params["embed"], x)
    return logits[:, None], WhisperCache(ys["attn"], ys["cs"], ys["cz"],
                                         jnp.full((B,), L, jnp.int32))


def decode_step(params: dict, cfg: ArchConfig, cache: WhisperCache,
                tokens: jnp.ndarray, active=None):
    """One decoder token with cached encoder cross-state.

    ``active`` (B,) masks continuous-batching pool slots: drained rows keep
    their self-attention cache and ``pos`` bit-identical (the cross state
    is static — read-only — so it needs no masking).
    """
    x = embed(params["embed"], tokens[:, 0]).astype(cfg.activation_dtype)
    spec = cfg.attention_spec()
    slay_params = params.get("slay")
    pos = cache.pos
    act = None if active is None else active.astype(bool)

    def body(x, scanned):
        lp = scanned["params"]
        xa = rmsnorm(lp["pre_attn"], x)
        q = jnp.einsum("bd,dhk->bhk", xa, lp["attn"]["wq"])
        k = jnp.einsum("bd,dhk->bhk", xa, lp["attn"]["wk"])
        v = jnp.einsum("bd,dhk->bhk", xa, lp["attn"]["wv"])
        p1 = pos[:, None]                     # (B, 1) per-slot positions
        q = rope(q[:, None], p1, cfg.rope_theta)[:, 0]
        k = rope(k[:, None], p1, cfg.rope_theta)[:, 0]
        y, nac = attn.decode_step(spec, slay_params, q, k, v,
                                  scanned["attn"], active=act)
        x = x + jnp.einsum("bhk,hkd->bd", y, lp["attn"]["wo"])
        xc = rmsnorm(lp["pre_cross"], x)
        qc = jnp.einsum("bd,dhk->bhk", xc, lp["cross"]["wq"])
        if spec.is_linear:
            qf = attn._features(spec, slay_params, qc)
            # Read out the fixed cross state (no update — encoder is static).
            st = la.LinearState(scanned["cs"], scanned["cz"])
            hkv = cfg.num_kv_heads
            qg = qf.reshape(*qf.shape[:-2], hkv,
                            qf.shape[-2] // hkv, qf.shape[-1])
            num = jnp.einsum("...kgm,...kmd->...kgd", qg, st.s)
            den = jnp.einsum("...kgm,...km->...kg", qg, st.z)
            yc = (num / (den[..., None] + 1e-6)).reshape(
                *qc.shape[:-1], st.s.shape[-1]).astype(x.dtype)
        else:
            kc, vc = scanned["cs"].astype(x.dtype), scanned["cz"].astype(
                x.dtype)
            dh = qc.shape[-1]
            logits = jnp.einsum("bhd,bshd->bhs", qc, kc) / jnp.sqrt(
                jnp.asarray(dh, x.dtype))
            probs = jax.nn.softmax(logits.astype(jnp.float32), -1
                                   ).astype(x.dtype)
            yc = jnp.einsum("bhs,bshd->bhd", probs, vc)
        x = x + jnp.einsum("bhk,hkd->bd", yc, lp["cross"]["wo"])
        x = x + mlp(lp["mlp"], rmsnorm(lp["pre_mlp"], x), cfg.gated_mlp)
        return x, {"attn": nac}

    x, ys = jax.lax.scan(body, x, {"params": params["dec_layers"],
                                   "attn": cache.self_attn,
                                   "cs": cache.cross_s, "cz": cache.cross_z})
    x = rmsnorm(params["final_norm"], x)
    logits = unembed(params["embed"], x)
    step = 1 if act is None else act.astype(jnp.int32)
    return logits[:, None], WhisperCache(ys["attn"], cache.cross_s,
                                         cache.cross_z, pos + step)
