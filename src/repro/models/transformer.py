"""Unified decoder LM covering the dense / MoE / SSM / hybrid families.

One parameterized implementation serves phi4-mini, qwen3, granite (MQA),
gemma2 (local/global alternation + softcaps), hymba (parallel attn+mamba),
mamba2 (attention-free), phi3.5-moe and grok-1 (top-2 MoE), and internvl2
(vision-prefix stub). Layers are *stacked* and driven by ``lax.scan`` so the
HLO stays O(1) in depth — essential for 64-80 layer dry-run compiles — and
so XLA's latency-hiding scheduler can overlap layer-i compute with the
weight all-gathers of layer i+1 under FSDP.

The paper's SLAY mechanism is the default attention backend
(cfg.attn_kind == "slay"); every mechanism in repro.models.attention can be
swapped in via config without touching model code.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ArchConfig
from repro.core.slay import slay_init
from repro.distributed.sharding import constrain
from repro.models import attention as attn
from repro.models import ssm
from repro.models.layers import (ParamSpec, axes_of, embed, embed_spec,
                                 mlp, mlp_specs, moe, moe_specs, realize,
                                 rmsnorm, rmsnorm_spec, rope, stack_specs,
                                 unembed)


# ---------------------------------------------------------------------------
# Parameter templates
# ---------------------------------------------------------------------------


def attn_proj_specs(cfg: ArchConfig) -> dict:
    dh = cfg.resolved_head_dim
    t = {
        "wq": ParamSpec((cfg.d_model, cfg.num_heads, dh),
                        ("embed", "heads", None)),
        "wk": ParamSpec((cfg.d_model, cfg.num_kv_heads, dh),
                        ("embed", "kv_heads", None)),
        "wv": ParamSpec((cfg.d_model, cfg.num_kv_heads, dh),
                        ("embed", "kv_heads", None)),
        "wo": ParamSpec((cfg.num_heads, dh, cfg.d_model),
                        ("heads", None, "embed")),
    }
    if cfg.qk_norm:
        t["q_norm"] = ParamSpec((dh,), (None,), init="zeros")
        t["k_norm"] = ParamSpec((dh,), (None,), init="zeros")
    return t


def layer_specs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    if cfg.family == "ssm":
        return {"pre": rmsnorm_spec(d),
                "ssd": ssm.ssd_specs(d, cfg.ssm_state, cfg.ssm_expand,
                                     cfg.ssm_head_dim, cfg.ssm_ngroups,
                                     cfg.ssm_conv_width)}
    t = {"pre_attn": rmsnorm_spec(d), "pre_mlp": rmsnorm_spec(d),
         "attn": attn_proj_specs(cfg)}
    if cfg.moe_experts:
        t["moe"] = moe_specs(d, cfg.d_ff, cfg.moe_experts)
    else:
        t["mlp"] = mlp_specs(d, cfg.d_ff, cfg.gated_mlp)
    if cfg.family == "hybrid":
        t["ssd"] = ssm.ssd_specs(d, cfg.ssm_state, cfg.ssm_expand,
                                 cfg.ssm_head_dim, cfg.ssm_ngroups,
                                 cfg.ssm_conv_width)
    return t


def model_specs(cfg: ArchConfig) -> dict:
    specs = {
        "embed": embed_spec(cfg.vocab_size, cfg.d_model),
        "final_norm": rmsnorm_spec(cfg.d_model),
        "layers": stack_specs(layer_specs(cfg), cfg.num_layers),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = ParamSpec((cfg.vocab_size, cfg.d_model),
                                     ("vocab", "embed"), scale=1.0)
    return specs


def init_params(cfg: ArchConfig, key: jax.Array) -> dict:
    k_model, k_slay = jax.random.split(key)
    dtype = cfg.activation_dtype
    params = realize(model_specs(cfg), k_model, dtype)
    if cfg.family != "ssm" and cfg.attn_kind == "slay":
        params["slay"] = slay_init(k_slay, cfg.slay_config())
    elif cfg.family != "ssm" and cfg.attn_kind == "favor":
        from repro.core.baselines import favor_init
        params["slay"] = favor_init(k_slay, cfg.resolved_head_dim)
    return params


def param_axes(cfg: ArchConfig) -> dict:
    axes = axes_of(model_specs(cfg))
    if cfg.family != "ssm" and cfg.attn_kind in ("slay", "favor"):
        # Random projections: tiny, replicated.
        if cfg.attn_kind == "slay":
            axes["slay"] = {"anchors": (None, None), "omegas": (None, None)}
        else:
            axes["slay"] = {"proj": (None, None)}
    return axes


def _layer_kinds(cfg: ArchConfig) -> np.ndarray:
    """Per-layer flag: 1 = local sliding-window softmax, 0 = primary attn."""
    if cfg.local_global_period and cfg.local_window:
        idx = np.arange(cfg.num_layers)
        return (idx % cfg.local_global_period
                != cfg.local_global_period - 1).astype(np.int32)
    return np.zeros(cfg.num_layers, np.int32)


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------


def _attn_full(cfg: ArchConfig, lp: dict, slay_params, x, positions,
               is_local):
    """One layer's attention over the full sequence."""
    xa = rmsnorm(lp["pre_attn"], x)
    _ahead = ("act_batch", "act_seq", "act_heads", None)
    q = constrain(jnp.einsum("bld,dhk->blhk", xa, lp["attn"]["wq"]), _ahead)
    k = constrain(jnp.einsum("bld,dhk->blhk", xa, lp["attn"]["wk"]), _ahead)
    v = constrain(jnp.einsum("bld,dhk->blhk", xa, lp["attn"]["wv"]), _ahead)
    if cfg.qk_norm:
        q = rmsnorm(lp["attn"]["q_norm"], q)
        k = rmsnorm(lp["attn"]["k_norm"], k)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    spec_g = cfg.attention_spec(local=False)
    if cfg.local_global_period and cfg.local_window:
        spec_l = cfg.attention_spec(local=True)
        y = jax.lax.cond(
            is_local == 1,
            lambda: attn.full_attention(spec_l, None, q, k, v, causal=True),
            lambda: attn.full_attention(spec_g, slay_params, q, k, v,
                                        causal=True))
    else:
        y = attn.full_attention(spec_g, slay_params, q, k, v, causal=True)
    y = constrain(y, _ahead)
    return constrain(jnp.einsum("blhk,hkd->bld", y, lp["attn"]["wo"]),
                     ("act_batch", "act_seq", "act_embed"))


def _layer_fwd(cfg: ArchConfig, slay_params, carry, scanned):
    x, aux = carry
    lp, is_local, positions = scanned["params"], scanned["kind"], scanned["pos"]
    if cfg.family == "ssm":
        x = x + ssm.ssd_forward(
            lp["ssd"], rmsnorm(lp["pre"], x), d_state=cfg.ssm_state,
            expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim,
            ngroups=cfg.ssm_ngroups, conv_width=cfg.ssm_conv_width,
            chunk_size=cfg.chunk_size)
        return (x, aux), None
    x = constrain(x, ("act_batch", "act_seq", "act_embed"))
    a = _attn_full(cfg, lp, slay_params, x, positions, is_local)
    # Named for the save-collectives remat policy (§Perf): saving the
    # post-all-reduce tensors lets the backward recompute skip re-running
    # the forward TP collectives.
    a = checkpoint_name(a, "attn_out")
    if cfg.family == "hybrid":
        # Hymba: parallel attention + mamba heads on the same input, averaged.
        m = ssm.ssd_forward(
            lp["ssd"], rmsnorm(lp["pre_attn"], x), d_state=cfg.ssm_state,
            expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim,
            ngroups=cfg.ssm_ngroups, conv_width=cfg.ssm_conv_width,
            chunk_size=cfg.chunk_size)
        a = 0.5 * (a + m)
    x = x + a
    xm = rmsnorm(lp["pre_mlp"], x)
    if cfg.moe_experts:
        y, moe_aux = moe(lp["moe"], xm, cfg.moe_experts, cfg.moe_top_k)
        aux = aux + moe_aux
    else:
        y = mlp(lp["mlp"], xm, cfg.gated_mlp)
    y = checkpoint_name(y, "mlp_out")
    return (x + y, aux), None


def forward(params: dict, cfg: ArchConfig, tokens: jnp.ndarray, *,
            patch_embeds=None, remat: bool = False) -> tuple[jnp.ndarray,
                                                             jnp.ndarray]:
    """tokens (B, Lt) -> logits (B, L, V), aux loss. Vision prefix embeds
    (B, P, d) are concatenated ahead of the token embeddings (stub frontend).
    """
    x = embed(params["embed"], tokens).astype(cfg.activation_dtype)
    if patch_embeds is not None:
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x], axis=1)
    x = constrain(x, ("act_batch", "act_seq", "act_embed"))
    L = x.shape[1]
    positions = jnp.arange(L, dtype=jnp.int32)[None, :]
    slay_params = jax.lax.stop_gradient(params.get("slay"))
    kinds = jnp.asarray(_layer_kinds(cfg))
    pos_b = jnp.broadcast_to(positions, (cfg.num_layers, *positions.shape))

    def body(carry, scanned):
        return _layer_fwd(cfg, slay_params, carry, scanned)

    if remat:
        # remat may be True/"nothing" (recompute everything) or
        # "save_collectives" (keep post-all-reduce layer outputs so the
        # backward pass does not re-run the forward TP collectives).
        if remat == "save_collectives":
            policy = jax.checkpoint_policies.save_only_these_names(
                "attn_out", "mlp_out")
        else:
            policy = jax.checkpoint_policies.nothing_saveable
        body = jax.checkpoint(body, policy=policy)
    (x, aux), _ = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)),
        {"params": params["layers"], "kind": kinds, "pos": pos_b})
    x = rmsnorm(params["final_norm"], x)
    table = params.get("unembed", params["embed"])
    logits = unembed(table, x, cfg.final_logit_softcap)
    logits = constrain(logits, ("act_batch", "act_seq", "act_vocab"))
    return logits, aux


def loss_fn(params: dict, cfg: ArchConfig, batch: dict, *,
            remat: bool = False) -> tuple[jnp.ndarray, dict]:
    """Next-token cross-entropy (+ MoE load-balance aux)."""
    logits, aux = forward(params, cfg, batch["tokens"],
                          patch_embeds=batch.get("patch_embeds"),
                          remat=remat)
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:   # vision prefix: text tail only
        logits = logits[:, logits.shape[1] - labels.shape[1]:]
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = jnp.mean(logz - gold)
    total = nll + 0.01 * aux
    return total, {"nll": nll, "moe_aux": aux}


# ---------------------------------------------------------------------------
# Serving: prefill + decode with stacked per-layer caches
# ---------------------------------------------------------------------------


class DecodeCache(NamedTuple):
    """Stacked (num_layers leading) per-layer decode state.

    ``pos`` is per slot — (B,) int32 — so a serving slot pool can hold
    sequences of different lengths (continuous batching): each slot's ring
    writes, validity masks, and RoPE phases advance independently.

    ``pages`` is None for ordinary caches. For a *paged* serving pool
    (DESIGN.md §11) it holds a ``serving.pages.PageState`` and the KV ring
    leaves are page-indexed ``(nl, P, page, Hkv, dh)`` instead of
    slot-indexed ``(nl, S, kv_len, Hkv, dh)``; the decode step gathers
    each slot's pages to the dense ring layout, runs the unchanged
    attention update, and scatters back — byte-identical by construction.
    """

    attn: attn.AttnCache | None
    ssm: ssm.SsmState | None
    pos: jnp.ndarray                # (B,) int32 tokens seen per slot
    pages: object | None = None     # serving.pages.PageState when paged


def _pages_mod():
    # Lazy: keeps models -> serving import edges out of module init time
    # (serving imports models.api; the cycle only resolves at call time).
    from repro.serving import pages
    return pages


def _needs_kv(cfg: ArchConfig, max_len: int) -> bool:
    spec = cfg.attention_spec()
    mixed_local = bool(cfg.local_global_period and cfg.local_window)
    return (not spec.is_linear) or mixed_local


def supports_paging(cfg: ArchConfig) -> bool:
    """Whether the pooled decode cache can page its KV rings (§11).

    True only where paging buys anything: a non-windowed exact quadratic
    ring (softmax / exact yat), which is the one state whose per-slot size
    scales with context. Constant-state kinds (linear SLAY — a single
    (S, z) accumulator) and SSM/hybrid scan carries are O(1) per slot, so
    they bypass paging entirely; windowed rings are already bounded by the
    window and wrap in place.
    """
    if cfg.family in ("ssm", "hybrid", "encdec"):
        return False
    if cfg.local_window or cfg.frontend:
        return False
    return not cfg.attention_spec().is_linear


def context_capacity(cfg: ArchConfig, max_len: int) -> int | None:
    """Max context rows (prefix + prompt + decode budget) a slot can hold.

    ``None`` means unbounded: constant-state decode (linear kinds, SSM)
    carries O(1) state regardless of context, and windowed rings wrap
    exactly — only a *non-windowed quadratic* ring hard-caps admission at
    its ``max_len`` allocation. This is what lets oversized linear-vision
    prompts admit (absorbed chunk-by-chunk) instead of being rejected.
    """
    if cfg.family == "ssm":
        return None
    if cfg.attention_spec().is_linear or cfg.local_window:
        return None
    return max_len


def init_cache(cfg: ArchConfig, batch: int, max_len: int, *,
               page_size: int = 0, num_pages: int = 0,
               shards: int = 1) -> DecodeCache:
    """Allocate the decode cache (union layout when layers are mixed).

    With ``page_size > 0`` (and a config that :func:`supports_paging`) the
    KV ring leaves are allocated page-indexed — ``(nl, num_pages,
    page_size, Hkv, dh)`` physical pages shared by all ``batch`` slots —
    and a fresh all-free ``PageState`` rides in ``cache.pages``.
    """
    nl = cfg.num_layers
    dh = cfg.resolved_head_dim
    dtype = cfg.activation_dtype
    a_cache = None
    s_cache = None
    page_state = None
    if cfg.family != "ssm":
        spec = cfg.attention_spec()
        kv_len = (min(max_len, cfg.local_window)
                  if cfg.local_window else max_len)
        m = spec.slay.feature_dim if spec.kind == "slay" else \
            attn._baseline_dim(spec, dh)
        lin_needed = spec.is_linear
        paged = page_size > 0 and supports_paging(cfg)
        if paged:
            if kv_len % page_size:
                raise ValueError(
                    f"page_size={page_size} must divide kv_len={kv_len}")
            lp = kv_len // page_size
            np_ = num_pages if num_pages else batch * lp
            k = jnp.zeros((nl, np_, page_size, cfg.num_kv_heads, dh), dtype)
            v = jnp.zeros((nl, np_, page_size, cfg.num_kv_heads, dh), dtype)
            page_state = _pages_mod().init_state(batch, np_, lp,
                                                 shards=shards)
        else:
            k = jnp.zeros((nl, batch, kv_len, cfg.num_kv_heads, dh), dtype) \
                if _needs_kv(cfg, max_len) else None
            v = jnp.zeros((nl, batch, kv_len, cfg.num_kv_heads, dh), dtype) \
                if _needs_kv(cfg, max_len) else None
        s = jnp.zeros((nl, batch, cfg.num_kv_heads, m, dh), jnp.float32) \
            if lin_needed else None
        z = jnp.zeros((nl, batch, cfg.num_kv_heads, m), jnp.float32) \
            if lin_needed else None
        a_cache = attn.AttnCache(k, v, jnp.zeros((nl, batch), jnp.int32),
                                 s, z)
    if cfg.family in ("ssm", "hybrid"):
        st = ssm.ssd_init_state((batch,), cfg.d_model, cfg.ssm_state,
                                cfg.ssm_expand, cfg.ssm_head_dim,
                                cfg.ssm_ngroups, cfg.ssm_conv_width)
        s_cache = ssm.SsmState(jnp.zeros((nl, *st.h.shape), jnp.float32),
                               jnp.zeros((nl, *st.conv.shape), jnp.float32))
    return DecodeCache(a_cache, s_cache, jnp.zeros((batch,), jnp.int32),
                       page_state)


def _state_passthrough(new, old, act):
    """jnp.where-select ``new`` vs ``old`` state leaves on the (B,) active
    mask — the reference-path analogue of the Pallas kernel's masked
    state RMW (drained slots keep their bytes bit-identical)."""
    if act is None:
        return new

    def sel(n, o):
        a = act.reshape(act.shape + (1,) * (n.ndim - 1))
        return jnp.where(a, n, o)

    return jax.tree.map(sel, new, old)


def decode_step(params: dict, cfg: ArchConfig, cache: DecodeCache,
                tokens: jnp.ndarray,
                active: jnp.ndarray | None = None
                ) -> tuple[jnp.ndarray, DecodeCache]:
    """One autoregressive step. tokens (B, 1) -> logits (B, 1, V).

    ``active`` (B,) bool/int is the continuous-batching slot mask: drained
    slots pass their whole per-layer state through unchanged (attention
    caches, SSM carries, per-slot ``pos``) and contribute zero attention/
    SSM output — the jitted pool dispatch stays one fixed-shape call while
    idle slots stop advancing. Their logits rows are meaningless and must
    be masked by the caller (the engine samples only active rows).
    """
    x = embed(params["embed"], tokens[:, 0]).astype(cfg.activation_dtype)
    pos = cache.pos
    act = None if active is None else active.astype(bool)
    slay_params = params.get("slay")
    kinds = jnp.asarray(_layer_kinds(cfg))

    def body(x, scanned):
        lp = scanned["params"]
        is_local = scanned["kind"]
        new = {}
        if cfg.family == "ssm":
            y, st = ssm.ssd_decode_step(
                lp["ssd"], rmsnorm(lp["pre"], x), scanned["ssm"],
                d_state=cfg.ssm_state, expand=cfg.ssm_expand,
                head_dim=cfg.ssm_head_dim, ngroups=cfg.ssm_ngroups,
                conv_width=cfg.ssm_conv_width)
            new["ssm"] = _state_passthrough(st, scanned["ssm"], act)
            if act is not None:
                y = jnp.where(act[:, None], y, 0).astype(y.dtype)
            return x + y, new
        xa = rmsnorm(lp["pre_attn"], x)
        q = jnp.einsum("bd,dhk->bhk", xa, lp["attn"]["wq"])
        k = jnp.einsum("bd,dhk->bhk", xa, lp["attn"]["wk"])
        v = jnp.einsum("bd,dhk->bhk", xa, lp["attn"]["wv"])
        if cfg.qk_norm:
            q = rmsnorm(lp["attn"]["q_norm"], q)
            k = rmsnorm(lp["attn"]["k_norm"], k)
        p1 = pos[:, None]                     # (B, 1) per-slot positions
        q = rope(q[:, None], p1, cfg.rope_theta)[:, 0]
        k = rope(k[:, None], p1, cfg.rope_theta)[:, 0]
        spec_g = cfg.attention_spec(local=False)
        ac = scanned["attn"]
        if cfg.local_global_period and cfg.local_window:
            spec_l = cfg.attention_spec(local=True)

            def _local():
                y, c = attn.decode_step(spec_l, None, q, k, v, ac,
                                        active=act)
                return y, _merge_cache(ac, c)

            def _global():
                y, c = attn.decode_step(spec_g, slay_params, q, k, v, ac,
                                        active=act)
                return y, _merge_cache(ac, c)

            y, nac = jax.lax.cond(is_local == 1, _local, _global)
        elif cache.pages is not None:
            # Paged pool (§11): gather this layer's pages to the dense
            # (B, kv_len, Hkv, dh) ring the unpaged path uses, run the
            # unchanged attention update on it, scatter owned pages back.
            # `cache.pages` enters the scan as a constant (closure).
            pg = _pages_mod()
            dense = ac._replace(k=pg.gather_ring(ac.k, cache.pages),
                                v=pg.gather_ring(ac.v, cache.pages))
            y, nd = attn.decode_step(spec_g, slay_params, q, k, v, dense,
                                     active=act)
            nac = nd._replace(
                k=pg.scatter_ring(ac.k, nd.k, cache.pages),
                v=pg.scatter_ring(ac.v, nd.v, cache.pages))
        else:
            y, nac = attn.decode_step(spec_g, slay_params, q, k, v, ac,
                                      active=act)
        a = jnp.einsum("bhk,hkd->bd", y, lp["attn"]["wo"])
        new["attn"] = nac
        if cfg.family == "hybrid":
            m, st = ssm.ssd_decode_step(
                lp["ssd"], xa, scanned["ssm"], d_state=cfg.ssm_state,
                expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim,
                ngroups=cfg.ssm_ngroups, conv_width=cfg.ssm_conv_width)
            if act is not None:
                m = jnp.where(act[:, None], m, 0).astype(m.dtype)
            a = 0.5 * (a + m)
            new["ssm"] = _state_passthrough(st, scanned["ssm"], act)
        x = x + a
        xm = rmsnorm(lp["pre_mlp"], x)
        if cfg.moe_experts:
            y2, _ = moe(lp["moe"], xm[:, None, :], cfg.moe_experts,
                        cfg.moe_top_k)
            y2 = y2[:, 0]
        else:
            y2 = mlp(lp["mlp"], xm, cfg.gated_mlp)
        return x + y2, new

    scanned = {"params": params["layers"], "kind": kinds}
    if cache.attn is not None:
        scanned["attn"] = cache.attn
    if cache.ssm is not None:
        scanned["ssm"] = cache.ssm
    x, new = jax.lax.scan(body, x, scanned)
    x = rmsnorm(params["final_norm"], x)
    table = params.get("unembed", params["embed"])
    logits = unembed(table, x, cfg.final_logit_softcap)
    step = 1 if act is None else act.astype(jnp.int32)
    return logits[:, None, :], DecodeCache(
        new.get("attn"), new.get("ssm"), pos + step, cache.pages)


def supports_masked_prefill(cfg: ArchConfig) -> bool:
    """Whether prefill accepts ``true_len`` (length-bucketed right-padding).

    Exact for pure-attention decoders: causality keeps the valid prefix's
    activations byte-identical under right padding, and the cache masks pad
    contributions out (zero key features / zero KV rows outside the ``pos``
    horizon). SSM/hybrid carries decay through pad steps (no exact masked
    form) and windowed KV rings would evict in-window history, so those
    fall back to per-length compilation.
    """
    return cfg.family not in ("ssm", "hybrid", "encdec") \
        and not cfg.local_window


def prefill(params: dict, cfg: ArchConfig, tokens: jnp.ndarray, *,
            patch_embeds=None, max_len: int | None = None,
            true_len: jnp.ndarray | None = None
            ) -> tuple[jnp.ndarray, DecodeCache]:
    """Process a full prompt; return last-token logits + a primed cache.

    ``max_len`` sizes the KV ring buffer exactly when given (so a pooled
    serving cache and a per-request prefill cache agree shape-for-shape);
    when omitted, prompt + 64 tokens of decode headroom. Linear/SSM state
    paths are length-independent either way. Implemented as forward for
    logits + per-layer cache construction in a second scan (keeps the hot
    forward path allocation-free).

    ``true_len`` (B,) int32 (traced) marks the real sequence length of a
    right-padded prompt — the length-bucketed serving fallback compiles
    once per pow-2 bucket instead of once per distinct prompt length.
    Logits are read at ``true_len - 1`` and the cache excludes every pad
    position exactly (see :func:`supports_masked_prefill`).
    """
    if true_len is not None and not supports_masked_prefill(cfg):
        raise NotImplementedError(
            f"true_len-masked prefill unsupported for {cfg.name} "
            f"(family={cfg.family}, local_window={cfg.local_window})")
    B = tokens.shape[0]
    x = embed(params["embed"], tokens).astype(cfg.activation_dtype)
    if patch_embeds is not None:
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x], axis=1)
    L = x.shape[1]
    positions = jnp.arange(L, dtype=jnp.int32)[None, :]
    valid = None if true_len is None else \
        positions < true_len[:, None]                     # (B, L)
    slay_params = params.get("slay")
    kinds = jnp.asarray(_layer_kinds(cfg))
    cache0 = init_cache(cfg, B, max_len if max_len else L + 64)

    def body(carry, scanned):
        x, _aux = carry
        lp, is_local = scanned["params"], scanned["kind"]
        new = {}
        if cfg.family == "ssm":
            xn = rmsnorm(lp["pre"], x)
            y = ssm.ssd_forward(
                lp["ssd"], xn, d_state=cfg.ssm_state, expand=cfg.ssm_expand,
                head_dim=cfg.ssm_head_dim, ngroups=cfg.ssm_ngroups,
                conv_width=cfg.ssm_conv_width, chunk_size=cfg.chunk_size)
            new["ssm"] = _ssd_prefill_state(cfg, lp["ssd"], xn)
            return ((x + y, _aux), new)
        x = constrain(x, ("act_batch", "act_seq", "act_embed"))
        xa = rmsnorm(lp["pre_attn"], x)
        _ahead = ("act_batch", "act_seq", "act_heads", None)
        q = constrain(jnp.einsum("bld,dhk->blhk", xa, lp["attn"]["wq"]),
                      _ahead)
        k = constrain(jnp.einsum("bld,dhk->blhk", xa, lp["attn"]["wk"]),
                      _ahead)
        v = constrain(jnp.einsum("bld,dhk->blhk", xa, lp["attn"]["wv"]),
                      _ahead)
        if cfg.qk_norm:
            q = rmsnorm(lp["attn"]["q_norm"], q)
            k = rmsnorm(lp["attn"]["k_norm"], k)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        spec_g = cfg.attention_spec(local=False)
        ac = scanned["attn"]
        if cfg.local_global_period and cfg.local_window:
            spec_l = cfg.attention_spec(local=True)

            def _local():
                y = attn.full_attention(spec_l, None, q, k, v)
                c = attn.prefill_cache(spec_l, None, k, v, ac, valid)
                return y, _merge_cache(ac, c)

            def _global():
                y = attn.full_attention(spec_g, slay_params, q, k, v)
                c = attn.prefill_cache(spec_g, slay_params, k, v, ac, valid)
                return y, _merge_cache(ac, c)

            y, nac = jax.lax.cond(is_local == 1, _local, _global)
        else:
            y = attn.full_attention(spec_g, slay_params, q, k, v)
            nac = _merge_cache(ac, attn.prefill_cache(spec_g, slay_params,
                                                      k, v, ac, valid))
        y = constrain(y, _ahead)
        a = constrain(jnp.einsum("blhk,hkd->bld", y, lp["attn"]["wo"]),
                      ("act_batch", "act_seq", "act_embed"))
        new["attn"] = nac
        if cfg.family == "hybrid":
            m = ssm.ssd_forward(
                lp["ssd"], xa, d_state=cfg.ssm_state, expand=cfg.ssm_expand,
                head_dim=cfg.ssm_head_dim, ngroups=cfg.ssm_ngroups,
                conv_width=cfg.ssm_conv_width, chunk_size=cfg.chunk_size)
            a = 0.5 * (a + m)
            new["ssm"] = _ssd_prefill_state(cfg, lp["ssd"], xa)
        x = x + a
        xm = rmsnorm(lp["pre_mlp"], x)
        if cfg.moe_experts:
            y2, moe_aux = moe(lp["moe"], xm, cfg.moe_experts, cfg.moe_top_k)
            _aux = _aux + moe_aux
        else:
            y2 = mlp(lp["mlp"], xm, cfg.gated_mlp)
        return ((x + y2, _aux), new)

    scanned = {"params": params["layers"], "kind": kinds}
    if cache0.attn is not None:
        scanned["attn"] = cache0.attn
    if cache0.ssm is not None:
        scanned["ssm"] = cache0.ssm
    (x, _), new = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), scanned)
    if true_len is None:
        x_last = x[:, -1]
        pos = jnp.full((B,), L, jnp.int32)
    else:
        # Last *real* token of each right-padded row (causality guarantees
        # its activations are identical to the unpadded prompt's).
        idx = jnp.maximum(true_len - 1, 0)[:, None, None]
        x_last = jnp.take_along_axis(x, idx, axis=1)[:, 0]
        pos = true_len.astype(jnp.int32)
    x = rmsnorm(params["final_norm"], x_last)
    table = params.get("unembed", params["embed"])
    logits = unembed(table, x, cfg.final_logit_softcap)
    return logits[:, None, :], DecodeCache(
        new.get("attn"), new.get("ssm"), pos)


def reset_slot(cfg: ArchConfig, cache: DecodeCache, slot: int,
               pages=None) -> DecodeCache:
    """Zero one slot of a pooled decode cache (eviction).

    Constant-state path: the (S, z) accumulators zero — a single overwrite,
    the serving asymmetry SLAY buys us. KV path: the slot's ring zeroes and
    its pos resets, which is equivalent to eviction because validity is
    derived from pos. Every other slot's bytes are untouched, so the cache
    sharding (slot-stable by construction) never changes.

    Paged pool: the slot's *owned pages* zero (so a quarantined slot's NaN
    never survives into a page's next owner) and the freed table/owner
    vectors the host allocator computed are installed via ``pages``.
    """
    if cache.pages is not None:
        pg = _pages_mod()
        a = cache.attn._replace(
            k=pg.write_zero_pages(cache.attn.k, slot, cache.pages),
            v=pg.write_zero_pages(cache.attn.v, slot, cache.pages),
            pos=cache.attn.pos.at[:, slot].set(0))
        return DecodeCache(a, cache.ssm, cache.pos.at[slot].set(0),
                           pages if pages is not None else cache.pages)
    z1 = jax.tree.map(lambda x: x.at[:, slot].set(0), cache.attn)
    zs = jax.tree.map(lambda x: x.at[:, slot].set(0), cache.ssm)
    return DecodeCache(z1, zs, cache.pos.at[slot].set(0), cache.pages)


def write_slot(cfg: ArchConfig, cache: DecodeCache, src: DecodeCache,
               slot: int, pages=None) -> DecodeCache:
    """Install a single-sequence cache (batch=1, e.g. a freshly prefilled
    request) into slot ``slot`` of a pooled cache (admission). Pool and
    source must be built from the same cfg/max_len so leaf shapes agree.

    Paged pool: ``pages`` carries the post-allocation ``PageState`` (the
    host allocator assigned this slot its pages at admission); every owned
    page is overwritten in full from the dense batch=1 source ring."""
    if cache.pages is not None:
        pg = _pages_mod()
        st = pages if pages is not None else cache.pages
        a = cache.attn._replace(
            k=pg.write_slot_pages(cache.attn.k, src.attn.k, slot, st),
            v=pg.write_slot_pages(cache.attn.v, src.attn.v, slot, st),
            pos=cache.attn.pos.at[:, slot].set(src.attn.pos[:, 0]))
        return DecodeCache(a, cache.ssm,
                           cache.pos.at[slot].set(src.pos[0]), st)
    wa = jax.tree.map(lambda dst, s: dst.at[:, slot].set(s[:, 0]),
                      cache.attn, src.attn)
    ws = jax.tree.map(lambda dst, s: dst.at[:, slot].set(s[:, 0]),
                      cache.ssm, src.ssm)
    return DecodeCache(wa, ws, cache.pos.at[slot].set(src.pos[0]),
                       cache.pages)


def slot_state_finite(cfg: ArchConfig, cache: DecodeCache) -> jnp.ndarray:
    """(B,) bool — every float decode-state leaf of each slot is finite.

    The NaN/Inf quarantine probe (DESIGN.md §10): reduces each stacked
    ``(num_layers, B, ...)`` float leaf (KV rings, (S, z) accumulators,
    SSM scan/conv carries) over every non-slot axis. Integer leaves
    (positions, ring cursors) cannot be non-finite and are skipped. The
    reduction is per-slot, so under a slot-sharded pool it partitions
    into shard-local work — no collectives enter the §8 decode contract.
    """
    B = cache.pos.shape[0]
    if cache.pages is not None:
        # Per-page finiteness, attributed to the owning slot — free pages
        # (stale bytes from an evicted owner) never taint a live slot.
        return _pages_mod().pages_finite(
            [cache.attn.k, cache.attn.v], cache.pages, B)
    ok = jnp.ones((B,), bool)
    for leaf in jax.tree.leaves((cache.attn, cache.ssm)):
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            continue
        axes = tuple(i for i in range(leaf.ndim) if i != 1)
        ok = ok & jnp.all(jnp.isfinite(leaf), axis=axes)
    return ok


def corrupt_slot(cfg: ArchConfig, cache: DecodeCache,
                 slot: int) -> DecodeCache:
    """Overwrite one slot's float state with NaN — the chaos harness's
    fault-injection primitive (``serving.faults``; never on a production
    path). Mirrors :func:`reset_slot`'s slot-stable, shard-local update
    shape; integer leaves (positions) are left intact so the fault is a
    pure numeric corruption, not a bookkeeping one."""
    if cache.pages is not None:
        pg = _pages_mod()
        a = cache.attn._replace(
            k=pg.corrupt_slot_pages(cache.attn.k, slot, cache.pages),
            v=pg.corrupt_slot_pages(cache.attn.v, slot, cache.pages))
        return DecodeCache(a, cache.ssm, cache.pos, cache.pages)

    def nan_row(x):
        if not jnp.issubdtype(x.dtype, jnp.floating):
            return x
        return x.at[:, slot].set(jnp.nan)

    return DecodeCache(jax.tree.map(nan_row, cache.attn),
                       jax.tree.map(nan_row, cache.ssm), cache.pos,
                       cache.pages)


def supports_chunked_prefill(cfg: ArchConfig) -> bool:
    """Chunked prefill continuation covers every decoder-only config:
    linear kinds seed the fp32 (S, z) recurrence, softmax and the exact
    quadratic yat kinds attend ring prefix + masked intra-chunk scores,
    and ssm/hybrid carry the SSD scan state plus the causal-conv tail
    across chunk boundaries (``ssm.ssd_prefill_chunk``, DESIGN.md §9).
    Modality frontends chunk too: the vision patch prefix feeds through
    ``prefill_chunk(embeds=...)`` piece by piece — same continuation, the
    chunk input is just pre-embedded. Encdec is gated in
    ``whisper.supports_chunked_prefill``."""
    return True


def prefill_chunk(params: dict, cfg: ArchConfig, cache: DecodeCache,
                  tokens: jnp.ndarray, *,
                  embeds: jnp.ndarray | None = None
                  ) -> tuple[jnp.ndarray, DecodeCache]:
    """Absorb one prompt chunk into an existing decode cache.

    tokens (B, Lc); ``cache`` holds the state of the previously absorbed
    prefix (per-slot ``pos``). Returns last-token logits (B, 1, V) and the
    advanced cache — so a prompt fed chunk-by-chunk ends in the same state
    (exactly for the fp32 linear/SSM recurrences; up to fp roundoff for
    the quadratic kinds) as a whole-prompt :func:`prefill`, letting the
    serving engine interleave prefill progress with decode ticks instead
    of stalling the pool. SSM/hybrid layers carry their (nh, hd, ds) scan
    state and (W-1, conv_dim) causal-conv tail across chunks
    (DESIGN.md §9).

    ``embeds`` (B, Lc, d_model) feeds a pre-embedded chunk instead of
    token ids — how a vision patch prefix is absorbed chunk-by-chunk
    (``tokens`` is ignored when given). The continuation is position-
    driven, so prefix-embed chunks and token chunks interleave exactly.
    """
    if embeds is not None:
        x = embeds.astype(cfg.activation_dtype)
        B, Lc = x.shape[0], x.shape[1]
    else:
        B, Lc = tokens.shape
        x = embed(params["embed"], tokens).astype(cfg.activation_dtype)
    positions = cache.pos[:, None] + jnp.arange(Lc, dtype=jnp.int32)[None, :]
    slay_params = params.get("slay")
    kinds = jnp.asarray(_layer_kinds(cfg))

    def _ssd_chunk(lp, xn, st):
        # Clamp the scan tile to the chunk length (exact: the continuation
        # is chunk-size invariant) so short serving chunks don't zero-pad
        # up to cfg.chunk_size — mirrors the linear path's clamp.
        return ssm.ssd_prefill_chunk(
            lp["ssd"], xn, st, d_state=cfg.ssm_state, expand=cfg.ssm_expand,
            head_dim=cfg.ssm_head_dim, ngroups=cfg.ssm_ngroups,
            conv_width=cfg.ssm_conv_width,
            chunk_size=max(min(cfg.chunk_size, Lc), 1))

    def body(x, scanned):
        lp, is_local = scanned["params"], scanned["kind"]
        new = {}
        if cfg.family == "ssm":
            y, st = _ssd_chunk(lp, rmsnorm(lp["pre"], x), scanned["ssm"])
            new["ssm"] = st
            return x + y, new
        xa = rmsnorm(lp["pre_attn"], x)
        q = jnp.einsum("bld,dhk->blhk", xa, lp["attn"]["wq"])
        k = jnp.einsum("bld,dhk->blhk", xa, lp["attn"]["wk"])
        v = jnp.einsum("bld,dhk->blhk", xa, lp["attn"]["wv"])
        if cfg.qk_norm:
            q = rmsnorm(lp["attn"]["q_norm"], q)
            k = rmsnorm(lp["attn"]["k_norm"], k)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        spec_g = cfg.attention_spec(local=False)
        ac = scanned["attn"]
        if cfg.local_global_period and cfg.local_window:
            spec_l = cfg.attention_spec(local=True)

            def _local():
                y, c = attn.prefill_chunk(spec_l, None, q, k, v, ac)
                return y, _merge_cache(ac, c)

            def _global():
                y, c = attn.prefill_chunk(spec_g, slay_params, q, k, v, ac)
                return y, _merge_cache(ac, c)

            y, nac = jax.lax.cond(is_local == 1, _local, _global)
        else:
            y, nac = attn.prefill_chunk(spec_g, slay_params, q, k, v, ac)
        a = jnp.einsum("blhk,hkd->bld", y, lp["attn"]["wo"])
        new["attn"] = nac
        if cfg.family == "hybrid":
            m, st = _ssd_chunk(lp, xa, scanned["ssm"])
            a = 0.5 * (a + m)
            new["ssm"] = st
        x = x + a
        xm = rmsnorm(lp["pre_mlp"], x)
        if cfg.moe_experts:
            y2, _ = moe(lp["moe"], xm, cfg.moe_experts, cfg.moe_top_k)
        else:
            y2 = mlp(lp["mlp"], xm, cfg.gated_mlp)
        return x + y2, new

    scanned = {"params": params["layers"], "kind": kinds}
    if cache.attn is not None:
        scanned["attn"] = cache.attn
    if cache.ssm is not None:
        scanned["ssm"] = cache.ssm
    x, new = jax.lax.scan(body, x, scanned)
    x = rmsnorm(params["final_norm"], x[:, -1])
    table = params.get("unembed", params["embed"])
    logits = unembed(table, x, cfg.final_logit_softcap)
    return logits[:, None, :], DecodeCache(new.get("attn"), new.get("ssm"),
                                           cache.pos + Lc, cache.pages)


def supports_speculative(cfg: ArchConfig) -> bool:
    """Whether a config can be the *verifier* of draft-verify speculative
    decoding (DESIGN.md §13).

    Two structural requirements: (1) rejected-suffix rollback must be a
    pure per-slot ``pos`` rewind, which holds only for a non-windowed
    exact quadratic KV ring (validity is derived from ``pos``; stale rows
    past the accept horizon become invisible and are overwritten in
    place) — linear kinds fold tokens irreversibly into the (S, z)
    accumulator and SSM/hybrid carries cannot un-absorb a step; (2) the
    draft swap (``attn_kind -> "slay"``) must leave the rest of the
    parameter tree identical so one params pytree serves both regimes,
    which rules out encdec and modality frontends. Windowed/mixed-window
    rings are excluded with (1): an in-window eviction is not rewindable.
    """
    if cfg.family in ("ssm", "hybrid", "encdec") or cfg.frontend:
        return False
    if cfg.local_window or cfg.local_global_period:
        return False
    return not cfg.attention_spec().is_linear


def verify_chunk(params: dict, cfg: ArchConfig, cache: DecodeCache,
                 tokens: jnp.ndarray,
                 active: jnp.ndarray | None = None
                 ) -> tuple[jnp.ndarray, DecodeCache]:
    """Score a candidate token block: tokens (B, Lc) -> logits (B, Lc, V).

    The speculative verifier (DESIGN.md §13): same §9-exact chunked
    continuation as :func:`prefill_chunk`, but returning the *full*
    per-position logits — row j is the verifier's next-token distribution
    after absorbing tokens[:, :j+1] on top of the cached prefix — and
    masking per slot like :func:`decode_step`: drained slots pass their
    cache bytes and ``pos`` through untouched (paged slots scatter their
    own gathered rows back unchanged). The advanced cache has absorbed
    all ``Lc`` candidates; the caller rewinds to the accept horizon with
    :func:`rollback_slots`.
    """
    B, Lc = tokens.shape
    x = embed(params["embed"], tokens).astype(cfg.activation_dtype)
    positions = cache.pos[:, None] + jnp.arange(Lc, dtype=jnp.int32)[None, :]
    act = None if active is None else active.astype(bool)
    slay_params = params.get("slay")

    # Verifier configs are single-spec (supports_speculative excludes
    # local/global mixes), so no per-layer kind dispatch here.
    def body(x, scanned):
        lp = scanned["params"]
        new = {}
        xa = rmsnorm(lp["pre_attn"], x)
        q = jnp.einsum("bld,dhk->blhk", xa, lp["attn"]["wq"])
        k = jnp.einsum("bld,dhk->blhk", xa, lp["attn"]["wk"])
        v = jnp.einsum("bld,dhk->blhk", xa, lp["attn"]["wv"])
        if cfg.qk_norm:
            q = rmsnorm(lp["attn"]["q_norm"], q)
            k = rmsnorm(lp["attn"]["k_norm"], k)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        spec_g = cfg.attention_spec(local=False)
        ac = scanned["attn"]
        if cache.pages is not None:
            # Paged pool (§11): gather -> exact chunk update -> per-slot
            # passthrough on the *dense* view (leaves stay (B, ...)) ->
            # scatter. A drained slot's pages get their own gathered rows
            # written back — byte-identical, so "untouched" holds.
            pg = _pages_mod()
            dense = ac._replace(k=pg.gather_ring(ac.k, cache.pages),
                                v=pg.gather_ring(ac.v, cache.pages))
            y, nd = attn.prefill_chunk(spec_g, slay_params, q, k, v, dense)
            nd = _state_passthrough(nd, dense, act)
            nac = nd._replace(
                k=pg.scatter_ring(ac.k, nd.k, cache.pages),
                v=pg.scatter_ring(ac.v, nd.v, cache.pages))
        else:
            y, nac = attn.prefill_chunk(spec_g, slay_params, q, k, v, ac)
            nac = _state_passthrough(nac, ac, act)
        a = jnp.einsum("blhk,hkd->bld", y, lp["attn"]["wo"])
        new["attn"] = nac
        x = x + a
        xm = rmsnorm(lp["pre_mlp"], x)
        if cfg.moe_experts:
            y2, _ = moe(lp["moe"], xm, cfg.moe_experts, cfg.moe_top_k)
        else:
            y2 = mlp(lp["mlp"], xm, cfg.gated_mlp)
        return x + y2, new

    scanned = {"params": params["layers"], "attn": cache.attn}
    x, new = jax.lax.scan(body, x, scanned)
    x = rmsnorm(params["final_norm"], x)
    table = params.get("unembed", params["embed"])
    logits = unembed(table, x, cfg.final_logit_softcap)
    step = Lc if act is None else Lc * act.astype(jnp.int32)
    return logits, DecodeCache(new["attn"], cache.ssm, cache.pos + step,
                               cache.pages)


def rollback_slots(cfg: ArchConfig, cache: DecodeCache,
                   new_pos: jnp.ndarray) -> DecodeCache:
    """Rewind per-slot context horizons to ``new_pos`` (B,) int32 (§13).

    KV-ring validity is derived from ``pos`` alone (attention masks rows
    at or beyond the horizon), so rejecting a speculative suffix moves no
    ring bytes: rows past the accept horizon become invisible and the
    next absorb overwrites them in place. A paged pool's page table is
    untouched — admission sized the slot's pages for the full horizon
    plus verify overshoot, so there is nothing to free (and nothing that
    can leak; the §11 audit checks the table, not row contents).
    """
    new_pos = new_pos.astype(jnp.int32)
    a = cache.attn
    if a is not None:
        a = a._replace(pos=jnp.broadcast_to(new_pos[None, :], a.pos.shape))
    return DecodeCache(a, cache.ssm, new_pos, cache.pages)


def _merge_cache(template: attn.AttnCache, new: attn.AttnCache):
    """Fill unused union-cache slots from the template so pytree structure
    stays constant across mixed local/linear layers."""
    return attn.AttnCache(
        new.k if new.k is not None else template.k,
        new.v if new.v is not None else template.v,
        new.pos if new.pos is not None else template.pos,
        new.s if new.s is not None else template.s,
        new.z if new.z is not None else template.z,
    )


def _ssd_prefill_state(cfg: ArchConfig, lp: dict, xn: jnp.ndarray):
    """Recompute the final SSD state for a prompt (prefill).

    Runs the chunked scan again keeping only the carry — XLA CSEs this with
    the forward pass when fused in the same jit.
    """
    d_model = xn.shape[-1]
    z, xs, b, c, dt, d_inner, nheads = ssm._split_proj(
        lp, xn, d_model, cfg.ssm_state, cfg.ssm_expand, cfg.ssm_head_dim,
        cfg.ssm_ngroups)
    full = jnp.concatenate([xs, b, c], -1)
    xbc, _ = ssm._causal_conv(lp, full, cfg.ssm_conv_width)
    xs, b, c = jnp.split(xbc, [d_inner, d_inner + cfg.ssm_ngroups
                               * cfg.ssm_state], -1)
    B, L = xn.shape[0], xn.shape[1]
    xh = xs.reshape(B, L, nheads, cfg.ssm_head_dim).astype(jnp.float32)
    bh = b.reshape(B, L, cfg.ssm_ngroups, cfg.ssm_state).astype(jnp.float32)
    dtp = jax.nn.softplus(dt.astype(jnp.float32)
                          + lp["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(lp["a_log"].astype(jnp.float32))
    la_ = dtp * a
    # Final state: sum_u exp(sum_{t>u} la_t) dt_u x_u B_u^T
    rev_cum = jnp.cumsum(la_[:, ::-1], axis=1)[:, ::-1] - la_  # tail sums
    w = jnp.exp(rev_cum) * dtp                                  # (B,L,nh)
    g = nheads // cfg.ssm_ngroups
    bg = jnp.repeat(bh, g, axis=-2)
    h = jnp.einsum("blhd,blhs->bhds", xh * w[..., None], bg)
    conv = jax.lax.dynamic_slice_in_dim(
        full, L - (cfg.ssm_conv_width - 1), cfg.ssm_conv_width - 1,
        axis=1).astype(jnp.float32)            # (B, W-1, conv_dim)
    return ssm.SsmState(h, conv)
