"""Mamba-2 SSD (state-space duality) block, chunk-parallel, TPU-friendly.

The SSD recurrence per head (state h in R^{hd x ds}):

    h_t = exp(dt_t * A) h_{t-1} + dt_t * x_t B_t^T ,   y_t = h_t C_t + D x_t

is computed with the same chunk-parallel decomposition as SLAY's causal
linear attention (intra-chunk quadratic + inter-chunk carried state), which
is exactly the "duality" of the SSD paper: within a chunk the recurrence is
a masked, decay-weighted attention on (C, B); across chunks the state is a
compact (nheads, headdim, dstate) carry. All contractions are MXU-shaped
matmuls; decay weights are rank-1 outer products of cumulative log-decays.

Shapes: x (B, L, nh, hd), b/c (B, L, ng, ds) broadcast over heads,
dt (B, L, nh) [post-softplus], a_log (nh,). All accumulation fp32.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec


class SsmState(NamedTuple):
    h: jnp.ndarray     # (..., nh, hd, ds) fp32
    conv: jnp.ndarray  # (..., W-1, conv_dim) rolling conv inputs


def ssd_specs(d_model: int, d_state: int, expand: int = 2,
              head_dim: int = 64, ngroups: int = 1, conv_width: int = 4):
    d_inner = expand * d_model
    nheads = d_inner // head_dim
    conv_dim = d_inner + 2 * ngroups * d_state
    return {
        "in_proj": ParamSpec(
            (d_model, 2 * d_inner + 2 * ngroups * d_state + nheads),
            ("embed", "mlp")),
        "conv_w": ParamSpec((conv_width, conv_dim), (None, "mlp"),
                            scale=0.5),
        "conv_b": ParamSpec((conv_dim,), ("mlp",), init="zeros"),
        "a_log": ParamSpec((nheads,), (None,), init="ones"),
        "dt_bias": ParamSpec((nheads,), (None,), init="zeros"),
        "d_skip": ParamSpec((nheads,), (None,), init="ones"),
        "norm": ParamSpec((d_inner,), ("mlp",), init="zeros"),
        "out_proj": ParamSpec((d_inner, d_model), ("mlp", "embed")),
    }


def _split_proj(params, x, d_model, d_state, expand, head_dim, ngroups):
    d_inner = expand * d_model
    nheads = d_inner // head_dim
    zxbcdt = x @ params["in_proj"]
    z, xs, b, c, dt = jnp.split(
        zxbcdt,
        [d_inner, 2 * d_inner, 2 * d_inner + ngroups * d_state,
         2 * d_inner + 2 * ngroups * d_state], axis=-1)
    return z, xs, b, c, dt, d_inner, nheads


def _causal_conv(params, u, w: int, tail=None):
    """Depthwise causal conv, width w. u (..., L, C) -> (out, new tail).

    ``tail`` (..., W-1, C) is the previous chunk's pre-conv rows (chunked
    prefill continuation); None means a fresh sequence start (zero left
    context). The new tail is the last W-1 rows of [tail, u] — what the
    next chunk's first positions need as their left context."""
    if tail is None:
        tail = jnp.zeros((*u.shape[:-2], w - 1, u.shape[-1]), u.dtype)
    hist = jnp.concatenate([tail.astype(u.dtype), u], axis=-2)
    out = sum(hist[..., i:i + u.shape[-2], :] * params["conv_w"][i]
              for i in range(w))
    return jax.nn.silu(out + params["conv_b"]), hist[..., u.shape[-2]:, :]


def ssd_forward(params: dict, x: jnp.ndarray, *, d_state: int,
                expand: int = 2, head_dim: int = 64, ngroups: int = 1,
                conv_width: int = 4, chunk_size: int = 256) -> jnp.ndarray:
    """Full-sequence SSD block. x (B, L, d_model) -> (B, L, d_model).

    Implemented as :func:`ssd_prefill_chunk` from the zero state — the
    training forward and the serving chunked-prefill continuation are the
    same code path, so chunk-by-chunk prefill is structurally exact
    (DESIGN.md §9)."""
    state = ssd_init_state((x.shape[0],), x.shape[-1], d_state, expand,
                           head_dim, ngroups, conv_width)
    y, _ = ssd_prefill_chunk(
        params, x, state, d_state=d_state, expand=expand, head_dim=head_dim,
        ngroups=ngroups, conv_width=conv_width, chunk_size=chunk_size)
    return y


def ssd_prefill_chunk(params: dict, x: jnp.ndarray, state: SsmState, *,
                      d_state: int, expand: int = 2, head_dim: int = 64,
                      ngroups: int = 1, conv_width: int = 4,
                      chunk_size: int = 256
                      ) -> tuple[jnp.ndarray, SsmState]:
    """Absorb an arbitrary-length prompt chunk into an ``SsmState``.

    x (B, Lc, d_model) -> (y (B, Lc, d_model), new state). Two carries
    cross the chunk boundary (DESIGN.md §9): the (nh, hd, ds) fp32 scan
    state, which seeds the chunked scan's recurrence exactly (position t
    of this chunk reads the prefix state decayed by exp(cum_t), identical
    to the whole-prompt schedule), and the (W-1, conv_dim) causal-conv
    tail — the last W-1 pre-conv projections of the prefix, so the first
    W-1 positions of this chunk see their true left context instead of
    the zero padding a fresh sequence starts from. The conv runs in the
    activation dtype over [tail, chunk] (:func:`_causal_conv`); the fp32
    tail round-trips the activation dtype exactly. Feeding a prompt
    chunk-by-chunk therefore reproduces :func:`ssd_forward` for any chunk
    schedule, ragged tails included (the scan zero-pads internally with
    dt = 0, see :func:`_ssd_chunked`).
    """
    d_model = x.shape[-1]
    z, xs, b, c, dt, d_inner, nheads = _split_proj(
        params, x, d_model, d_state, expand, head_dim, ngroups)
    u = jnp.concatenate([xs, b, c], -1)                 # (B, Lc, conv_dim)
    B, L = x.shape[0], x.shape[-2]
    xbc, tail = _causal_conv(params, u, conv_width, tail=state.conv)
    xs, b, c = jnp.split(xbc, [d_inner, d_inner + ngroups * d_state], -1)

    xh = xs.reshape(B, L, nheads, head_dim)
    bh = b.reshape(B, L, ngroups, d_state)
    ch = c.reshape(B, L, ngroups, d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B,L,nh)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))              # (nh,)

    y, h = _ssd_chunked(xh, bh, ch, dt, a, chunk_size,
                        init_h=state.h, return_state=True)  # (B,L,nh,hd)
    y = y + params["d_skip"].astype(jnp.float32)[:, None] * xh.astype(
        jnp.float32)
    y = y.reshape(B, L, d_inner).astype(x.dtype)
    # Gated RMS norm (mamba2's norm-before-out-proj).
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), -1, keepdims=True)
    y = (yf * jax.lax.rsqrt(var + 1e-6)
         * (1.0 + params["norm"].astype(jnp.float32))).astype(x.dtype)
    return y @ params["out_proj"], SsmState(h, tail.astype(jnp.float32))


def _ssd_chunked(xh, bh, ch, dt, a, chunk: int, *, init_h=None,
                 return_state: bool = False):
    """Chunk-parallel SSD scan. Returns (B, L, nh, hd) fp32 (optionally
    plus the final (B, nh, hd, ds) carry).

    ``init_h`` seeds the inter-chunk carry (chunked prefill continuation).
    Ragged L is zero-padded to a chunk multiple inside the kernel-shaped
    scan: padded steps carry dt = 0, so their log-decay is 0 (the decay
    factor exp(0) = 1 is the identity) and their dt-weighted score/state
    contributions vanish exactly — the final carry and every real row's
    output are untouched, for any L and chunk.
    """
    B, L, nh, hd = xh.shape
    ng, ds = bh.shape[-2], bh.shape[-1]
    if L % chunk:
        pad = chunk - L % chunk
        pad4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        out = _ssd_chunked(
            jnp.pad(xh, pad4), jnp.pad(bh, pad4), jnp.pad(ch, pad4),
            jnp.pad(dt, ((0, 0), (0, pad), (0, 0))), a, chunk,
            init_h=init_h, return_state=return_state)
        if return_state:
            return out[0][:, :L], out[1]
        return out[:, :L]
    C, T = L // chunk, chunk
    g = nh // ng  # heads per group

    xc = xh.reshape(B, C, T, nh, hd).astype(jnp.float32)
    bc = bh.reshape(B, C, T, ng, ds).astype(jnp.float32)
    cc = ch.reshape(B, C, T, ng, ds).astype(jnp.float32)
    dtc = dt.reshape(B, C, T, nh)
    # Per-step log decay and intra-chunk cumulative sums.
    la_ = dtc * a  # (B,C,T,nh) negative
    cum = jnp.cumsum(la_, axis=2)  # inclusive

    xc, bc, cc, dtc, la_, cum = (jnp.moveaxis(t, 1, 0)
                                 for t in (xc, bc, cc, dtc, la_, cum))

    def step(h, inp):
        x_c, b_c, c_c, dt_c, cum_c = inp
        # (T,T) decay matrix per head: exp(cum_t - cum_u) for u <= t.
        diff = cum_c[:, :, None, :] - cum_c[:, None, :, :]   # (B,T,T,nh)
        tri = jnp.tril(jnp.ones((T, T), bool))[None, :, :, None]
        decay = jnp.where(tri, jnp.exp(diff), 0.0)
        # Intra: scores[t,u] = decay * (C_t . B_u) * dt_u
        cb = jnp.einsum("btgs,bugs->btug", c_c, b_c)         # (B,T,T,ng)
        cb = jnp.repeat(cb, g, axis=-1)                      # (B,T,T,nh)
        scores = decay * cb * dt_c[:, None, :, :]
        y = jnp.einsum("btuh,buhd->bthd", scores, x_c)
        # Inter: prefix state read out at each position, decayed by exp(cum_t).
        cg = jnp.repeat(c_c, g, axis=-2)                     # (B,T,nh,ds)
        y += jnp.einsum("bths,bhds->bthd",
                        cg * jnp.exp(cum_c)[..., None], h)
        # State update: h' = exp(cum_T) h + sum_u exp(cum_T - cum_u) dt_u x B^T
        w = jnp.exp(cum_c[:, -1:, :] - cum_c) * dt_c          # (B,T,nh)
        bg = jnp.repeat(b_c, g, axis=-2)                      # (B,T,nh,ds)
        dh_ = jnp.einsum("bthd,bths->bhds", x_c * w[..., None], bg)
        h = jnp.exp(cum_c[:, -1, :])[..., None, None] * h + dh_
        return h, y

    h0 = (jnp.zeros((B, nh, hd, ds), jnp.float32) if init_h is None
          else init_h.astype(jnp.float32))
    h_fin, ys = jax.lax.scan(step, h0, (xc, bc, cc, dtc, cum))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, L, nh, hd)
    if return_state:
        return y, h_fin
    return y


def ssd_init_state(lead_shape, d_model: int, d_state: int, expand: int = 2,
                   head_dim: int = 64, ngroups: int = 1,
                   conv_width: int = 4) -> SsmState:
    d_inner = expand * d_model
    nheads = d_inner // head_dim
    conv_dim = d_inner + 2 * ngroups * d_state
    return SsmState(
        h=jnp.zeros((*lead_shape, nheads, head_dim, d_state), jnp.float32),
        conv=jnp.zeros((*lead_shape, conv_width - 1, conv_dim), jnp.float32))


def ssd_decode_step(params: dict, x: jnp.ndarray, state: SsmState, *,
                    d_state: int, expand: int = 2, head_dim: int = 64,
                    ngroups: int = 1, conv_width: int = 4):
    """One token. x (B, d_model) -> (B, d_model), O(nh*hd*ds) state update."""
    d_model = x.shape[-1]
    z, xs, b, c, dt, d_inner, nheads = _split_proj(
        params, x, d_model, d_state, expand, head_dim, ngroups)
    u = jnp.concatenate([xs, b, c], -1)                       # (B, conv_dim)
    hist = jnp.concatenate([state.conv, u[..., None, :].astype(jnp.float32)],
                           axis=-2)                           # (B, W, C)
    conv_out = jnp.einsum("bwc,wc->bc", hist,
                          params["conv_w"].astype(jnp.float32))
    xbc = jax.nn.silu(conv_out + params["conv_b"]).astype(x.dtype)
    xs, b, c = jnp.split(xbc, [d_inner, d_inner + ngroups * d_state], -1)
    B = x.shape[0]
    xh = xs.reshape(B, nheads, head_dim).astype(jnp.float32)
    bh = jnp.repeat(b.reshape(B, ngroups, d_state), nheads // ngroups,
                    axis=-2).astype(jnp.float32)
    chd = jnp.repeat(c.reshape(B, ngroups, d_state), nheads // ngroups,
                     axis=-2).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B, nh)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt * a)                                    # (B, nh)
    h = (decay[..., None, None] * state.h
         + jnp.einsum("bh,bhd,bhs->bhds", dt, xh, bh))
    y = jnp.einsum("bhds,bhs->bhd", h, chd)
    y = y + params["d_skip"].astype(jnp.float32)[:, None] * xh
    y = y.reshape(B, d_inner).astype(x.dtype) * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), -1, keepdims=True)
    y = (yf * jax.lax.rsqrt(var + 1e-6)
         * (1.0 + params["norm"].astype(jnp.float32))).astype(x.dtype)
    return y @ params["out_proj"], SsmState(h, hist[..., 1:, :])
