"""Shared neural-net layers: norms, RoPE, MLPs, MoE, embeddings.

Pure functional: every layer is ``f(params_subtree, x, ...) -> y``. Parameter
construction goes through :class:`ParamSpec` templates so that the same
structural code yields (a) initialized arrays and (b) logical sharding axes
(consumed by ``repro.distributed.sharding``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declarative parameter: shape + logical axes + initializer."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]   # logical axis names, len == len(shape)
    init: str = "normal"           # normal | zeros | ones
    scale: float | None = None     # stddev; default fan-in

    def initialize(self, key: jax.Array, dtype) -> jnp.ndarray:
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        scale = self.scale
        if scale is None:
            fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
            scale = 1.0 / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, self.shape, jnp.float32)
                * scale).astype(dtype)


def realize(template, key: jax.Array, dtype) -> dict:
    """Initialize a nested-dict template of ParamSpecs into arrays."""
    leaves, treedef = jax.tree.flatten(
        template, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [spec.initialize(k, dtype) for spec, k in zip(leaves, keys)])


def axes_of(template) -> dict:
    """Extract the logical-axes tree from a ParamSpec template."""
    return jax.tree.map(lambda s: s.axes, template,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def stack_specs(template, n: int, axis_name: str = "layers"):
    """Prefix every spec with a stacked leading dim (for scan-over-layers)."""
    def _stack(s: ParamSpec) -> ParamSpec:
        return ParamSpec((n, *s.shape), (axis_name, *s.axes), s.init, s.scale)
    return jax.tree.map(_stack, template,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(scale: jnp.ndarray, x: jnp.ndarray, eps: float = 1e-6):
    """RMSNorm with fp32 statistics (TPU mixed-precision practice)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
            ).astype(x.dtype)


def rmsnorm_spec(dim: int) -> ParamSpec:
    return ParamSpec((dim,), ("embed",), init="zeros")


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0):
    """Apply RoPE. x (..., L, H, Dh), positions (..., L) int32."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., L, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., L, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------


def mlp_specs(d_model: int, d_ff: int, gated: bool = True) -> dict:
    specs = {
        "up": ParamSpec((d_model, d_ff), ("embed", "mlp")),
        "down": ParamSpec((d_ff, d_model), ("mlp", "embed")),
    }
    if gated:
        specs["gate"] = ParamSpec((d_model, d_ff), ("embed", "mlp"))
    return specs


def mlp(params: dict, x: jnp.ndarray, gated: bool = True,
        activation: Callable = jax.nn.silu) -> jnp.ndarray:
    from repro.distributed.sharding import constrain
    hidden_axes = (("act_batch", "act_seq", "act_mlp") if x.ndim == 3
                   else ("act_batch", "act_mlp"))
    up = constrain(x @ params["up"], hidden_axes)
    if gated:
        up = activation(constrain(x @ params["gate"], hidden_axes)) * up
    else:
        up = activation(up)
    return up @ params["down"]


# ---------------------------------------------------------------------------
# Mixture of Experts (top-k, GShard-style one-hot dispatch)
# ---------------------------------------------------------------------------


def moe_specs(d_model: int, d_ff: int, num_experts: int) -> dict:
    return {
        "router": ParamSpec((d_model, num_experts), ("embed", None)),
        "gate": ParamSpec((num_experts, d_model, d_ff),
                          ("experts", "embed", "mlp")),
        "up": ParamSpec((num_experts, d_model, d_ff),
                        ("experts", "embed", "mlp")),
        "down": ParamSpec((num_experts, d_ff, d_model),
                          ("experts", "mlp", "embed")),
    }


def moe(params: dict, x: jnp.ndarray, num_experts: int, top_k: int = 2,
        capacity_factor: float = 1.25,
        seq_chunk: int = 4096) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k MoE with capacity-bounded one-hot dispatch (GShard/Switch),
    applied over sequence chunks.

    x (..., S, d). Returns (y, aux_loss). The dispatch/combine tensors are
    einsum-expressed so GSPMD partitions them cleanly (scatter/gather
    routing was measured 1.5-7x WORSE on collectives under GSPMD — see
    EXPERIMENTS.md §Perf grok iteration 2). Chunking the sequence bounds
    the (G, S_c, E, C_c) dispatch tensors: at 32k tokens unchunked they
    are tens of GiB; with 4k chunks they match the train-shape cost.
    Capacity is enforced per chunk (stricter, never looser, than global).
    """
    *lead, s, d = x.shape
    if s > seq_chunk and s % seq_chunk == 0:
        n = s // seq_chunk
        xc = x.reshape(*lead, n, seq_chunk, d)
        xc = jnp.moveaxis(xc, len(lead), 0)        # (n, ..., S_c, d)

        def one(xi):
            return moe(params, xi, num_experts, top_k, capacity_factor,
                       seq_chunk)

        yc, aux = jax.lax.map(one, xc)
        y = jnp.moveaxis(yc, 0, len(lead)).reshape(*lead, s, d)
        return y, jnp.mean(aux)

    xf = x.reshape(-1, s, d)                       # (G, S, d)
    g = xf.shape[0]
    e, k = num_experts, top_k
    cap = max(int(capacity_factor * s * k / e), 1)

    logits = jnp.einsum("gsd,de->gse", xf,
                        params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    # Load-balance auxiliary loss (Switch eq. 4).
    density = jnp.mean(probs, axis=1)                              # (G, E)
    top1 = jax.nn.one_hot(jnp.argmax(probs, -1), e, dtype=jnp.float32)
    frac = jnp.mean(top1, axis=1)
    aux = jnp.mean(jnp.sum(density * frac, axis=-1)) * e

    gate_vals, gate_idx = jax.lax.top_k(probs, k)                  # (G, S, k)
    gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)

    # Position within each expert queue, capacity-masked.
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)        # (G,S,k,E)
    flat = onehot.reshape(g, s * k, e)
    pos = jnp.cumsum(flat, axis=1) - flat                          # (G,S*k,E)
    pos = pos.reshape(g, s, k, e)
    keep = (pos < cap).astype(jnp.float32) * onehot
    posc = jax.nn.one_hot(jnp.sum(pos * onehot, -1).astype(jnp.int32), cap,
                          dtype=jnp.float32)                       # (G,S,k,C)
    dispatch = jnp.einsum("gske,gskc->gsec", keep, posc)           # (G,S,E,C)
    combine = jnp.einsum("gsec,gsk,gske->gsec", dispatch, gate_vals, onehot)

    from repro.distributed.sharding import constrain
    _exp = ("act_batch", "experts", None, None)
    xe = constrain(jnp.einsum("gsec,gsd->gecd", dispatch.astype(x.dtype), xf),
                   _exp)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, params["gate"]))
    h = h * jnp.einsum("gecd,edf->gecf", xe, params["up"])
    ye = constrain(jnp.einsum("gecf,efd->gecd", h, params["down"]), _exp)
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), ye)
    return y.reshape(*lead, s, d), aux


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_spec(vocab: int, d_model: int) -> ParamSpec:
    return ParamSpec((vocab, d_model), ("vocab", "embed"), scale=1.0)


def embed(table: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table, tokens, axis=0)


def unembed(table: jnp.ndarray, x: jnp.ndarray,
            softcap: float = 0.0) -> jnp.ndarray:
    logits = jnp.einsum("...d,vd->...v", x, table)
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits
