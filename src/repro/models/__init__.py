"""Model zoo: unified decoder LM (dense/MoE/SSM/hybrid), Whisper enc-dec,
attention dispatch, and the shared layer library.

Use :mod:`repro.models.api` as the entry point — it dispatches on
``ArchConfig.family``.
"""
from repro.models import api  # noqa: F401
