"""Family-dispatching model API: one call surface for all 10+ architectures.

    params    = api.init_params(cfg, key)
    axes      = api.param_axes(cfg)          # logical sharding axes pytree
    loss, mx  = api.loss_fn(params, cfg, batch)
    logits,c  = api.prefill(params, cfg, **batch)
    logits,c  = api.decode_step(params, cfg, cache, tokens)
"""
from __future__ import annotations

import dataclasses

import jax

from repro.configs.base import ArchConfig
from repro.models import transformer, whisper


def _mod(cfg: ArchConfig):
    return whisper if cfg.family == "encdec" else transformer


def init_params(cfg: ArchConfig, key: jax.Array) -> dict:
    return _mod(cfg).init_params(cfg, key)


def abstract_params(cfg: ArchConfig) -> dict:
    """ShapeDtypeStruct pytree of the parameters — no allocation (dry-run)."""
    return jax.eval_shape(
        lambda k: init_params(cfg, k), jax.ShapeDtypeStruct((2,), "uint32"))


def param_axes(cfg: ArchConfig) -> dict:
    return _mod(cfg).param_axes(cfg)


def loss_fn(params, cfg: ArchConfig, batch: dict, *, remat: bool = False):
    return _mod(cfg).loss_fn(params, cfg, batch, remat=remat)


def forward(params, cfg: ArchConfig, batch: dict):
    if cfg.family == "encdec":
        return whisper.forward(params, cfg, batch["tokens"],
                               batch["frame_embeds"])
    return transformer.forward(params, cfg, batch["tokens"],
                               patch_embeds=batch.get("patch_embeds"))


def init_cache(cfg: ArchConfig, batch: int, max_len: int, *,
               page_size: int = 0, num_pages: int = 0, shards: int = 1):
    """``page_size > 0`` requests a *paged* pool cache (KV rings become
    shared physical pages with a PageState table — DESIGN.md §11) for
    configs that :func:`supports_paging`; ignored otherwise (constant-
    state kinds have nothing to page)."""
    if page_size and cfg.family != "encdec":
        return transformer.init_cache(cfg, batch, max_len,
                                      page_size=page_size,
                                      num_pages=num_pages, shards=shards)
    return _mod(cfg).init_cache(cfg, batch, max_len)


def abstract_cache(cfg: ArchConfig, batch: int, max_len: int, *,
                   page_size: int = 0, num_pages: int = 0,
                   shards: int = 1):
    """Cache shapes without allocation (decode dry-run cells)."""
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len,
                                             page_size=page_size,
                                             num_pages=num_pages,
                                             shards=shards))


# -- Slot-pooled cache surface (continuous-batching serving) ---------------
#
# A pool cache is an ordinary init_cache(cfg, num_slots, max_len); slots are
# batch rows. Admission/eviction are single-slot overwrites — O(slot bytes),
# no paging — because every regime's per-sequence decode state lives in
# contiguous batch-indexed leaves (constant-state (S, z), KV rings, SSM
# carries) with per-slot positions. Under a slot-sharded pool (DESIGN.md
# §8) the slot dim is partitioned over the `data` mesh axis in contiguous
# static blocks; both ops below are dynamic-updates along that dim, so
# jitted with the pool's sharding as in- AND out-sharding (cache donated)
# they lower to shard-local writes — only the owning shard's block mutates.


def reset_slot(cfg: ArchConfig, cache, slot: int, pages=None):
    """Zero one slot (eviction). Slot-stable: other rows untouched — and
    under a sharded pool, shard-local: only ``slot``'s static owner shard
    writes; every other shard's bytes alias through the donated input.
    Paged pool: ``pages`` installs the host allocator's post-free
    ``PageState`` (the slot's pages return to the free list)."""
    if pages is not None:
        return transformer.reset_slot(cfg, cache, slot, pages)
    return _mod(cfg).reset_slot(cfg, cache, slot)


def write_slot(cfg: ArchConfig, cache, src, slot: int, pages=None):
    """Install a batch=1 request cache into a pool slot (admission).

    ``src`` (a freshly prefilled request cache) is replicated by the
    engine's jit signature, so the prefill output lands directly on the
    owning shard as part of the donated pool update — admission never
    moves another shard's slot bytes or reshards the pool. Paged pool:
    ``pages`` carries the post-allocation ``PageState``."""
    if pages is not None:
        return transformer.write_slot(cfg, cache, src, slot, pages)
    return _mod(cfg).write_slot(cfg, cache, src, slot)


def supports_paging(cfg: ArchConfig) -> bool:
    """Whether the pooled KV rings can be page-indexed (DESIGN.md §11).

    True only for non-windowed exact quadratic rings — the one decode
    state that scales with context. Constant-state kinds (linear SLAY,
    SSM/hybrid carries) bypass paging: their per-slot state is O(1), the
    paper's serving asymmetry."""
    return cfg.family != "encdec" and transformer.supports_paging(cfg)


def context_capacity(cfg: ArchConfig, max_len: int) -> int | None:
    """Rows of context (prefix + prompt + decode budget) one slot admits;
    ``None`` = unbounded (constant-state decode or an exactly-wrapping
    windowed ring)."""
    return _mod(cfg).context_capacity(cfg, max_len)


def slot_state_finite(cfg: ArchConfig, cache) -> jax.Array:
    """(B,) bool per-slot finiteness probe over the pooled decode state.

    The quarantine guard's detection surface (DESIGN.md §10): True where
    every float state leaf of that slot (KV rings, constant-state (S, z),
    SSM carries, cross-attention summaries) is finite. Reductions are
    per-slot only — shard-local under a slot-sharded pool, so the §8
    zero-collective decode contract is preserved when this runs inside
    the jitted macro-step."""
    return _mod(cfg).slot_state_finite(cfg, cache)


def corrupt_slot(cfg: ArchConfig, cache, slot: int):
    """Overwrite one slot's float state with NaN (fault injection).

    Chaos-harness primitive (``serving.faults``) — the write mirrors
    ``reset_slot``'s slot-stable shard-local shape, so injecting a fault
    never perturbs neighbouring slots' bytes or the pool sharding."""
    return _mod(cfg).corrupt_slot(cfg, cache, slot)


def supports_chunked_prefill(cfg: ArchConfig) -> bool:
    """Whether prefill can be fed chunk-by-chunk with state continuation.

    True for every decoder-only config — all attention kinds (linear,
    softmax, exact yat), the ssm/hybrid scan-carry families, and vision
    frontends (the patch prefix feeds through ``prefill_chunk(embeds=)``
    chunk-by-chunk — DESIGN.md §9/§11). False only for encdec."""
    return _mod(cfg).supports_chunked_prefill(cfg)


def prefill_chunk(cfg: ArchConfig, params, cache, tokens, embeds=None):
    """Absorb one prompt chunk into an existing cache; last-token logits.

    Exact continuation for any chunk schedule: linear (S, z) and SSM
    (scan + conv-tail) carries are fp32; quadratic kinds re-attend the
    ring prefix. ``embeds`` (B, Lc, d) feeds a pre-embedded chunk (vision
    patch prefix) instead of token ids."""
    if embeds is not None:
        return transformer.prefill_chunk(params, cfg, cache, tokens,
                                         embeds=embeds)
    return _mod(cfg).prefill_chunk(params, cfg, cache, tokens)


def supports_masked_prefill(cfg: ArchConfig) -> bool:
    """Whether prefill accepts ``true_len`` (right-padded prompts) — the
    enabler for length-bucketed compilation of the non-chunkable serving
    prefill fallback."""
    return _mod(cfg).supports_masked_prefill(cfg)


def prefill(params, cfg: ArchConfig, batch: dict, *,
            max_len: int | None = None, true_len=None):
    """Absorb a prompt batch. ``true_len`` (B,) int32 marks real lengths of
    right-padded prompts (see transformer.prefill)."""
    if cfg.family == "encdec":
        return whisper.prefill(params, cfg, batch["tokens"],
                               batch["frame_embeds"], max_len=max_len,
                               true_len=true_len)
    return transformer.prefill(params, cfg, batch["tokens"],
                               patch_embeds=batch.get("patch_embeds"),
                               max_len=max_len, true_len=true_len)


def decode_step(params, cfg: ArchConfig, cache, tokens, active=None):
    """One decode tick. ``active`` (B,) masks continuous-batching pool
    slots: drained rows are an exact state passthrough with zero attention
    output (their logits are meaningless — callers sample active rows
    only), so the pool dispatch stays one fixed-shape jitted call."""
    return _mod(cfg).decode_step(params, cfg, cache, tokens, active)


# -- Speculative decoding surface (DESIGN.md §13) ---------------------------


def supports_speculative(cfg: ArchConfig) -> bool:
    """Whether ``cfg`` can verify draft-verify speculative decoding:
    a non-windowed exact quadratic ring (rollback = ``pos`` rewind) whose
    params differ from the linear SLAY draft's only by the tiny ``slay``
    projection entry (one pytree serves both regimes)."""
    return cfg.family != "encdec" and transformer.supports_speculative(cfg)


def draft_config(cfg: ArchConfig) -> ArchConfig:
    """The linear-SLAY draft twin of a verifier config: same architecture,
    ``attn_kind="slay"`` — the paper's linearization of the verifier's own
    kernel, which is what makes its proposals land (high acceptance)."""
    return dataclasses.replace(cfg, attn_kind="slay")


def ensure_draft_params(draft_cfg: ArchConfig, params: dict) -> dict:
    """Add the draft's ``slay`` projection entry to a verifier params tree.

    The draft shares every transformer weight with the verifier; only the
    SLAY anchor/omega random projections are extra. They are derived from
    a fixed key so the draft — and therefore sampled spec streams — is
    deterministic per checkpoint, never per process. (Draft quality only
    affects acceptance rate, not output distribution.)"""
    if "slay" in params:
        return params
    from repro.core.slay import slay_init
    params = dict(params)
    params["slay"] = slay_init(jax.random.PRNGKey(0),
                               draft_cfg.slay_config())
    return params


def verify_chunk(cfg: ArchConfig, params, cache, tokens, active=None):
    """Score ``Lc`` candidate tokens per slot in one exact dispatch:
    tokens (B, Lc) -> (logits (B, Lc, V), advanced cache). Row j is the
    verifier's distribution after absorbing tokens[:, :j+1]; ``active``
    masks drained slots exactly like ``decode_step``."""
    return transformer.verify_chunk(params, cfg, cache, tokens, active)


def slot_positions(cfg: ArchConfig, cache) -> jax.Array:
    """(B,) int32 per-slot absorbed-context horizons."""
    return cache.pos


def rollback_slots(cfg: ArchConfig, cache, new_pos):
    """Rewind per-slot horizons to ``new_pos`` (B,) — the rejected-suffix
    rollback: a pure ``pos`` rewind, no ring bytes move, page table
    untouched (see transformer.rollback_slots)."""
    return transformer.rollback_slots(cfg, cache, new_pos)
