"""Attention dispatch: one entry point, many mechanisms.

The paper's technique (SLAY) is a first-class backend here, selected via
:class:`repro.core.slay.AttentionSpec`. All mechanisms share the model-side
convention q (..., L, H, Dh), k/v (..., L, Hkv, Dh) -> (..., L, H, Dh) and a
uniform decode interface over :class:`AttnCache`.

Backends:
    softmax      — exact quadratic (optionally logit-softcapped / windowed)
    yat          — exact quadratic Yat-kernel attention (paper Eq. 1)
    yat_spherical— exact quadratic spherical Yat (paper Eq. 5)
    slay         — the paper's linear-time mechanism (features + reordering)
    favor | cosformer | elu1 — linear baselines (paper Table 5)

Decode caches:
    softmax/yat* — ring-buffer KV cache (windowed when spec.window > 0)
    linear kinds — constant-size (S, z) running state (the 30x memory win)
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import baselines as bl
from repro.core import kernels as exact
from repro.core import linear_attention as la
from repro.core import slay as slay_mod
from repro.core.slay import AttentionSpec


class AttnCache(NamedTuple):
    """Uniform decode cache. Exactly one of (kv, state) is meaningful.

    kv:    k,v ring buffers (..., S, Hkv, Dh) + write position(s).
    state: linear-attention running state (S = sum psi(k)^T v, z = sum psi(k)).

    ``pos`` counts tokens seen so far. It is *per slot* — shape equal to the
    lead (batch) shape — so a serving slot pool can hold sequences of
    different lengths and a slot overwrite never perturbs its neighbours.
    A scalar pos (rank 0) is still accepted on the decode path for lockstep
    callers where every row shares one position.
    """

    k: jnp.ndarray | None
    v: jnp.ndarray | None
    pos: jnp.ndarray | None          # int32, lead-shaped (or scalar)
    s: jnp.ndarray | None            # (..., Hkv, m, dv) fp32
    z: jnp.ndarray | None            # (..., Hkv, m)     fp32


def init_cache(spec: AttentionSpec, lead_shape, num_kv: int, head_dim: int,
               dv: int, max_len: int, dtype) -> AttnCache:
    if spec.is_linear:
        m = spec.slay.feature_dim if spec.kind == "slay" else _baseline_dim(
            spec, head_dim)
        st = la.init_state(lead_shape, num_kv, m, dv)
        return AttnCache(None, None, jnp.zeros(lead_shape, jnp.int32),
                         st.s, st.z)
    size = min(max_len, spec.window) if spec.window else max_len
    shape = (*lead_shape, size, num_kv, head_dim)
    return AttnCache(jnp.zeros(shape, dtype),
                     jnp.zeros((*lead_shape, size, num_kv, dv), dtype),
                     jnp.zeros(lead_shape, jnp.int32), None, None)


def _baseline_dim(spec: AttentionSpec, head_dim: int) -> int:
    if spec.kind == "favor":
        return 64
    if spec.kind == "cosformer":
        return 2 * head_dim
    return head_dim  # elu1


def full_attention(spec: AttentionSpec, params: dict | None, q, k, v, *,
                   causal: bool = True) -> jnp.ndarray:
    """Full-sequence attention (training / prefill)."""
    if not spec.is_linear and k.shape[-2] != q.shape[-2]:
        # Exact quadratic paths operate head-aligned: broadcast kv over the
        # GQA group (XLA fuses the broadcast into the batched matmul).
        g = q.shape[-2] // k.shape[-2]
        k = jnp.repeat(k, g, axis=-2)
        v = jnp.repeat(v, g, axis=-2)
    if spec.kind == "softmax":
        return exact.softmax_attention(
            q, k, v, causal=causal, logit_softcap=spec.logit_softcap,
            window=spec.window)
    if spec.kind in ("yat", "yat_spherical"):
        return exact.yat_attention(q, k, v, causal=causal,
                                   spherical=spec.kind == "yat_spherical")
    if spec.kind == "slay":
        return slay_mod.slay_attention(
            params, q, k, v, spec.slay, causal=causal,
            chunk_size=spec.chunk_size, use_kernel=spec.use_pallas,
            fuse_features=spec.fuse_features)
    return bl.linear_baseline_attention(
        spec.kind, params, q, k, v, causal=causal, chunk_size=spec.chunk_size)


def cross_attention(spec: AttentionSpec, params: dict | None, q, k, v):
    """Non-causal cross-attention (encoder-decoder)."""
    return full_attention(spec, params, q, k, v, causal=False)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def prefill_cache(spec: AttentionSpec, params: dict | None, k, v,
                  cache: AttnCache, valid=None) -> AttnCache:
    """Absorb a full prompt's keys/values into a fresh decode cache.

    k/v: (..., L, Hkv, *). Linear kinds reduce to the constant-size state;
    KV kinds write the (window-truncated) suffix into the ring buffer.

    ``valid`` (..., L) bool masks a right-padded prompt (length-bucketed
    prefill): invalid positions contribute nothing to the state — linear
    kinds zero their key *features* (exact: the fp32 sums gain literal
    zeros), KV kinds write zeroed k/v rows that ``pos`` (set to the true
    length) keeps outside every later validity horizon.
    """
    L = k.shape[-3]
    lead = k.shape[:-3]
    if valid is None:
        pos = jnp.full(lead, L, jnp.int32)
    else:
        pos = jnp.sum(valid.astype(jnp.int32), axis=-1)
        pos = jnp.broadcast_to(pos, lead)
    if spec.is_linear:
        kf = _features(spec, params, k)
        if valid is not None:
            kf = jnp.where(valid[..., None, None], kf, 0.0)
        st = la.prefill_state(kf, v)
        return AttnCache(None, None, pos, st.s, st.z)
    if valid is not None:
        k = jnp.where(valid[..., None, None], k, 0)
        v = jnp.where(valid[..., None, None], v, 0)
    size = cache.k.shape[-3]
    # Keep the most recent `size` tokens, written at ring positions.
    take = min(L, size)
    ks, vs = k[..., L - take:, :, :], v[..., L - take:, :, :]
    idx = (jnp.arange(take) + (L - take)) % size
    kbuf = cache.k.at[..., idx, :, :].set(ks.astype(cache.k.dtype))
    vbuf = cache.v.at[..., idx, :, :].set(vs.astype(cache.v.dtype))
    return AttnCache(kbuf, vbuf, pos, None, None)


def prefill_chunk(spec: AttentionSpec, params: dict | None, q, k, v,
                  cache: AttnCache) -> tuple[jnp.ndarray, AttnCache]:
    """Absorb one *prompt chunk* into an existing decode cache.

    q (B, Lc, H, Dh), k/v (B, Lc, Hkv, *); ``cache.pos`` is the per-slot
    (B,) count of tokens already absorbed. This is the chunked-prefill
    primitive: feeding a prompt chunk-by-chunk reproduces the whole-prompt
    prefill (linear kinds: exact same fp32 state recurrence; softmax and
    the exact quadratic yat kinds: exact attention against the ring prefix
    + causal intra-chunk scores).

    Supported kinds: every linear kind, softmax (windowed or not), and the
    exact yat kinds (``yat`` / ``yat_spherical`` — same ring-prefix
    continuation, with scores used as nonnegative kernel weights under
    kernel normalization instead of a softmax, DESIGN.md §9).
    """
    B, Lc = q.shape[0], q.shape[1]
    start = cache.pos                                     # (B,)
    if spec.is_linear:
        qf = _features(spec, params, q)
        kf = _features(spec, params, k)
        out, st = la.causal_chunked(
            qf, kf, v, chunk_size=max(min(spec.chunk_size, Lc), 1),
            init_state=la.LinearState(cache.s, cache.z), return_state=True)
        return out, AttnCache(None, None, start + Lc, st.s, st.z)
    if spec.kind not in ("softmax", "yat", "yat_spherical"):
        raise NotImplementedError(
            f"chunked prefill not supported for kind={spec.kind!r}")

    size = cache.k.shape[-3]
    dh = q.shape[-1]
    hkv = k.shape[-2]
    g = q.shape[-2] // hkv
    qg = q.reshape(B, Lc, hkv, g, dh)
    p = start[:, None] + jnp.arange(Lc)[None, :]          # (B, Lc) abs pos
    # Absolute position held by ring slot j *before* this chunk's writes:
    # the newest written position congruent to j (mod size); negative when
    # the slot has never been written.
    j = jnp.arange(size)[None, :]
    a0 = j + ((start[:, None] - 1 - j) // size) * size    # (B, S)
    pre_ok = a0 >= 0
    if spec.window:
        pre_ok = pre_ok & (p[:, :, None] - a0[:, None, :] < spec.window)
    else:
        pre_ok = jnp.broadcast_to(pre_ok[:, None, :], (B, Lc, size))
    rel = jnp.arange(Lc)[:, None] - jnp.arange(Lc)[None, :]
    in_ok = rel >= 0
    if spec.window:
        in_ok = in_ok & (rel < spec.window)
    mask = jnp.concatenate([
        jnp.broadcast_to(pre_ok[:, :, None, None, :], (B, Lc, 1, 1, size)),
        jnp.broadcast_to(in_ok[None, :, None, None, :], (B, Lc, 1, 1, Lc)),
    ], axis=-1)                                           # (B,Lc,1,1,S+Lc)
    k_all = jnp.concatenate([cache.k.astype(q.dtype),
                             k.astype(q.dtype)], axis=1)  # (B,S+Lc,Hkv,Dh)
    v_all = jnp.concatenate([cache.v.astype(q.dtype),
                             v.astype(q.dtype)], axis=1)
    if spec.kind in ("yat", "yat_spherical"):
        # Exact yat continuation: masked positions get zero kernel weight
        # (not -inf — yat normalizes by the weight sum, not a softmax).
        # k_all broadcasts over the Lc query axis via a size-1 dim.
        scores = jnp.where(mask, _yat_scores(spec.kind, qg,
                                             k_all[:, None]), 0.0)
        num = jnp.einsum("blkgs,bskd->blkgd", scores, v_all)
        den = jnp.sum(scores, axis=-1)[..., None] + 1e-6
        y = (num / den).reshape(B, Lc, hkv * g, v.shape[-1])
    else:
        scores = jnp.einsum("blkgd,bskd->blkgs", qg, k_all) / jnp.sqrt(
            jnp.asarray(dh, q.dtype))
        if spec.logit_softcap:
            scores = spec.logit_softcap * jnp.tanh(
                scores / spec.logit_softcap)
        scores = jnp.where(mask, scores, jnp.finfo(scores.dtype).min)
        probs = jax.nn.softmax(scores.astype(jnp.float32), -1).astype(
            q.dtype)
        y = jnp.einsum("blkgs,bskd->blkgd", probs, v_all)
        y = y.reshape(B, Lc, hkv * g, v.shape[-1])
    # Commit the chunk's keys/values to the ring — only the trailing `size`
    # tokens when the chunk is longer than the ring (duplicate scatter
    # indices would otherwise race).
    take = min(Lc, size)
    b = jnp.arange(B)[:, None]
    idx = (start[:, None] + (Lc - take)
           + jnp.arange(take)[None, :]) % size
    kbuf = cache.k.at[b, idx].set(k[:, Lc - take:].astype(cache.k.dtype))
    vbuf = cache.v.at[b, idx].set(v[:, Lc - take:].astype(cache.v.dtype))
    return y, AttnCache(kbuf, vbuf, start + Lc, None, None)


def _yat_scores(kind: str, qg, kb):
    """Exact yat kernel weights (paper Eq. 1 / Eq. 5 with the reference
    eps constants) for grouped queries qg (..., Hkv, G, Dh) against keys
    kb (..., S, Hkv, Dh) -> (..., Hkv, G, S). One source of truth for the
    decode step and the chunked-prefill continuation — callers mask and
    kernel-normalize (weights, not logits: masked-out positions get 0)."""
    if kind == "yat_spherical":
        from repro.core.features import normalize
        x = jnp.einsum("...kgd,...skd->...kgs", normalize(qg),
                       normalize(kb))
        return jnp.square(x) / (2.0 + 1e-3 - 2.0 * x)
    x = jnp.einsum("...kgd,...skd->...kgs", qg, kb)
    q2 = jnp.sum(jnp.square(qg), -1)[..., None]          # (..., Hkv, G, 1)
    k2 = jnp.moveaxis(jnp.sum(jnp.square(kb), -1), -2, -1)[
        ..., :, None, :]                                 # (..., Hkv, 1, S)
    return jnp.square(x) / (jnp.maximum(q2 + k2 - 2 * x, 0.0) + 1e-3)


def decode_step(spec: AttentionSpec, params: dict | None, q, k, v,
                cache: AttnCache, *,
                active=None) -> tuple[jnp.ndarray, AttnCache]:
    """One token. q (..., H, Dh), k/v (..., Hkv, *) -> (..., H, dv).

    ``active`` (B,) bool/int masks continuous-batching pool rows: drained
    slots are an exact state passthrough (linear (S, z) and KV ring bytes
    bit-identical, ``pos`` frozen) with a zero output row — the same
    contract as the Pallas decode kernel's active-row mask, so the
    reference path and the kernel path are interchangeable mid-stream.
    Requires per-slot (vector) ``pos`` when given.
    """
    act = None
    if active is not None:
        if cache.pos is None or cache.pos.ndim == 0:
            raise ValueError("active mask requires per-slot cache.pos")
        act = active.astype(bool)
    if spec.is_linear:
        qf = _features(spec, params, q)
        kf = _features(spec, params, k)
        step = 1 if act is None else act.astype(jnp.int32)
        if spec.use_pallas and qf.ndim == 3:
            # Serving hot path: single fused Pallas dispatch for the pool
            # (jnp oracle off-TPU — identical masked semantics).
            from repro.kernels import ops
            y, s2, z2 = ops.decode_linear_step(qf, kf, v, cache.s, cache.z,
                                               active)
            return y, AttnCache(None, None, cache.pos + step, s2, z2)
        y, st = la.decode_step(qf, kf, v, la.LinearState(cache.s, cache.z))
        if act is None:
            return y, AttnCache(None, None, cache.pos + 1, st.s, st.z)
        s2 = jnp.where(act[:, None, None, None], st.s, cache.s)
        z2 = jnp.where(act[:, None, None], st.z, cache.z)
        y = jnp.where(act[:, None, None], y, 0).astype(y.dtype)
        return y, AttnCache(None, None, cache.pos + step, s2, z2)

    size = cache.k.shape[-3]
    ring = cache.pos % size
    n_seen = cache.pos + (1 if act is None else act.astype(jnp.int32))
    if cache.pos.ndim:
        # Per-slot positions (continuous batching): each batch row writes
        # its own ring slot and carries its own validity horizon. The
        # write is a one-hot row select rather than a batch-indexed
        # scatter: elementwise along the slot dim, it partitions cleanly
        # when the pool is slot-sharded (a scatter with explicit batch
        # indices forces GSPMD into all-gather/all-reduce — DESIGN.md §8),
        # and the ring is already fully read by attention each tick, so
        # bandwidth stays O(ring). Drained slots simply don't write.
        kw = k.astype(cache.k.dtype)
        vw = v.astype(cache.v.dtype)
        write = jnp.arange(size)[None, :] == ring[:, None]       # (B, S)
        if act is not None:
            write = write & act[:, None]
        wmask = write[:, :, None, None]               # vs (B, S, Hkv, dh)
        kbuf = jnp.where(wmask, kw[:, None], cache.k)
        vbuf = jnp.where(wmask, vw[:, None], cache.v)
        valid = (jnp.arange(size)[None, :]
                 < jnp.minimum(n_seen, size)[:, None])    # (B, S)
        valid = valid[:, None, None, :]                   # vs (B,Hkv,G,S)
    else:
        kbuf = jax.lax.dynamic_update_index_in_dim(
            cache.k, k.astype(cache.k.dtype), ring, axis=-3)
        vbuf = jax.lax.dynamic_update_index_in_dim(
            cache.v, v.astype(cache.v.dtype), ring, axis=-3)
        # Validity mask: ring slots written so far (inside the window).
        valid = jnp.arange(size) < jnp.minimum(n_seen, size)
    h, dh = q.shape[-2], q.shape[-1]
    hkv, dv = kbuf.shape[-2], vbuf.shape[-1]
    g = h // hkv
    qg = q.reshape(*q.shape[:-2], hkv, g, dh)   # (..., Hkv, G, Dh)
    kb = kbuf.astype(q.dtype)
    vb = vbuf.astype(q.dtype)

    if spec.kind in ("yat", "yat_spherical"):
        scores = jnp.where(valid, _yat_scores(spec.kind, qg, kb), 0.0)
        num = jnp.einsum("...kgs,...skd->...kgd", scores, vb)
        den = jnp.sum(scores, axis=-1)[..., None] + 1e-6
        y = (num / den).reshape(*q.shape[:-1], dv)
        if act is not None:
            y = jnp.where(act[:, None, None], y, 0).astype(y.dtype)
        return y, AttnCache(kbuf, vbuf, n_seen, None, None)

    logits = jnp.einsum("...kgd,...skd->...kgs", qg, kb) / jnp.sqrt(
        jnp.asarray(dh, q.dtype))
    if spec.logit_softcap:
        logits = spec.logit_softcap * jnp.tanh(logits / spec.logit_softcap)
    logits = jnp.where(valid, logits, jnp.finfo(logits.dtype).min)
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1).astype(q.dtype)
    y = jnp.einsum("...kgs,...skd->...kgd", probs, vb)
    y = y.reshape(*q.shape[:-1], dv)
    if act is not None:
        y = jnp.where(act[:, None, None], y, 0).astype(y.dtype)
    return y, AttnCache(kbuf, vbuf, n_seen, None, None)


def _features(spec: AttentionSpec, params: dict | None, u):
    if spec.kind == "slay":
        from repro.core.features import slay_features
        return slay_features(u, params, spec.slay)
    if spec.kind == "favor":
        return bl.favor_features(u, params)
    if spec.kind == "elu1":
        return bl.elu1_features(u)
    if spec.kind == "cosformer":
        # Decode: position-dependent reweighting needs absolute positions;
        # we use the large-M limit (cos ~ 1) for the single-token path.
        return jnp.concatenate([jax.nn.relu(u), jnp.zeros_like(u)], axis=-1)
    raise ValueError(spec.kind)
