"""Attention dispatch: one entry point, many mechanisms.

The paper's technique (SLAY) is a first-class backend here, selected via
:class:`repro.core.slay.AttentionSpec`. All mechanisms share the model-side
convention q (..., L, H, Dh), k/v (..., L, Hkv, Dh) -> (..., L, H, Dh) and a
uniform decode interface over :class:`AttnCache`.

Backends:
    softmax      — exact quadratic (optionally logit-softcapped / windowed)
    yat          — exact quadratic Yat-kernel attention (paper Eq. 1)
    yat_spherical— exact quadratic spherical Yat (paper Eq. 5)
    slay         — the paper's linear-time mechanism (features + reordering)
    favor | cosformer | elu1 — linear baselines (paper Table 5)

Decode caches:
    softmax/yat* — ring-buffer KV cache (windowed when spec.window > 0)
    linear kinds — constant-size (S, z) running state (the 30x memory win)
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import baselines as bl
from repro.core import kernels as exact
from repro.core import linear_attention as la
from repro.core import slay as slay_mod
from repro.core.slay import AttentionSpec


class AttnCache(NamedTuple):
    """Uniform decode cache. Exactly one of (kv, state) is meaningful.

    kv:    k,v ring buffers (..., S, Hkv, Dh) + scalar write position.
    state: linear-attention running state (S = sum psi(k)^T v, z = sum psi(k)).
    """

    k: jnp.ndarray | None
    v: jnp.ndarray | None
    pos: jnp.ndarray | None          # int32 scalar (tokens seen so far)
    s: jnp.ndarray | None            # (..., Hkv, m, dv) fp32
    z: jnp.ndarray | None            # (..., Hkv, m)     fp32


def init_cache(spec: AttentionSpec, lead_shape, num_kv: int, head_dim: int,
               dv: int, max_len: int, dtype) -> AttnCache:
    if spec.is_linear:
        m = spec.slay.feature_dim if spec.kind == "slay" else _baseline_dim(
            spec, head_dim)
        st = la.init_state(lead_shape, num_kv, m, dv)
        return AttnCache(None, None, jnp.zeros((), jnp.int32), st.s, st.z)
    size = min(max_len, spec.window) if spec.window else max_len
    shape = (*lead_shape, size, num_kv, head_dim)
    return AttnCache(jnp.zeros(shape, dtype),
                     jnp.zeros((*lead_shape, size, num_kv, dv), dtype),
                     jnp.zeros((), jnp.int32), None, None)


def _baseline_dim(spec: AttentionSpec, head_dim: int) -> int:
    if spec.kind == "favor":
        return 64
    if spec.kind == "cosformer":
        return 2 * head_dim
    return head_dim  # elu1


def full_attention(spec: AttentionSpec, params: dict | None, q, k, v, *,
                   causal: bool = True) -> jnp.ndarray:
    """Full-sequence attention (training / prefill)."""
    if not spec.is_linear and k.shape[-2] != q.shape[-2]:
        # Exact quadratic paths operate head-aligned: broadcast kv over the
        # GQA group (XLA fuses the broadcast into the batched matmul).
        g = q.shape[-2] // k.shape[-2]
        k = jnp.repeat(k, g, axis=-2)
        v = jnp.repeat(v, g, axis=-2)
    if spec.kind == "softmax":
        return exact.softmax_attention(
            q, k, v, causal=causal, logit_softcap=spec.logit_softcap,
            window=spec.window)
    if spec.kind in ("yat", "yat_spherical"):
        return exact.yat_attention(q, k, v, causal=causal,
                                   spherical=spec.kind == "yat_spherical")
    if spec.kind == "slay":
        return slay_mod.slay_attention(
            params, q, k, v, spec.slay, causal=causal,
            chunk_size=spec.chunk_size, use_kernel=spec.use_pallas,
            fuse_features=spec.fuse_features)
    return bl.linear_baseline_attention(
        spec.kind, params, q, k, v, causal=causal, chunk_size=spec.chunk_size)


def cross_attention(spec: AttentionSpec, params: dict | None, q, k, v):
    """Non-causal cross-attention (encoder-decoder)."""
    return full_attention(spec, params, q, k, v, causal=False)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def prefill_cache(spec: AttentionSpec, params: dict | None, k, v,
                  cache: AttnCache) -> AttnCache:
    """Absorb a full prompt's keys/values into a fresh decode cache.

    k/v: (..., L, Hkv, *). Linear kinds reduce to the constant-size state;
    KV kinds write the (window-truncated) suffix into the ring buffer.
    """
    L = k.shape[-3]
    if spec.is_linear:
        kf = _features(spec, params, k)
        st = la.prefill_state(kf, v)
        return AttnCache(None, None, jnp.asarray(L, jnp.int32), st.s, st.z)
    size = cache.k.shape[-3]
    # Keep the most recent `size` tokens, written at ring positions.
    take = min(L, size)
    ks, vs = k[..., L - take:, :, :], v[..., L - take:, :, :]
    idx = (jnp.arange(take) + (L - take)) % size
    kbuf = cache.k.at[..., idx, :, :].set(ks.astype(cache.k.dtype))
    vbuf = cache.v.at[..., idx, :, :].set(vs.astype(cache.v.dtype))
    return AttnCache(kbuf, vbuf, jnp.asarray(L, jnp.int32), None, None)


def decode_step(spec: AttentionSpec, params: dict | None, q, k, v,
                cache: AttnCache) -> tuple[jnp.ndarray, AttnCache]:
    """One token. q (..., H, Dh), k/v (..., Hkv, *) -> (..., H, dv)."""
    if spec.is_linear:
        qf = _features(spec, params, q)
        kf = _features(spec, params, k)
        y, st = la.decode_step(qf, kf, v, la.LinearState(cache.s, cache.z))
        return y, AttnCache(None, None, cache.pos + 1, st.s, st.z)

    size = cache.k.shape[-3]
    slot = cache.pos % size
    kbuf = jax.lax.dynamic_update_index_in_dim(
        cache.k, k.astype(cache.k.dtype), slot, axis=-3)
    vbuf = jax.lax.dynamic_update_index_in_dim(
        cache.v, v.astype(cache.v.dtype), slot, axis=-3)
    # Validity mask: ring slots written so far (and inside the window).
    n_seen = cache.pos + 1
    valid = jnp.arange(size) < jnp.minimum(n_seen, size)
    h, dh = q.shape[-2], q.shape[-1]
    hkv, dv = kbuf.shape[-2], vbuf.shape[-1]
    g = h // hkv
    qg = q.reshape(*q.shape[:-2], hkv, g, dh)   # (..., Hkv, G, Dh)
    kb = kbuf.astype(q.dtype)
    vb = vbuf.astype(q.dtype)

    if spec.kind in ("yat", "yat_spherical"):
        if spec.kind == "yat_spherical":
            from repro.core.features import normalize
            qs, ks = normalize(qg), normalize(kb)
            x = jnp.einsum("...kgd,...skd->...kgs", qs, ks)
            scores = jnp.square(x) / (2.0 + 1e-3 - 2.0 * x)
        else:
            x = jnp.einsum("...kgd,...skd->...kgs", qg, kb)
            q2 = jnp.sum(jnp.square(qg), -1)[..., None]        # (...,Hkv,G,1)
            k2 = jnp.moveaxis(jnp.sum(jnp.square(kb), -1), -2, -1)[
                ..., :, None, :]                               # (...,Hkv,1,S)
            scores = jnp.square(x) / (jnp.maximum(q2 + k2 - 2 * x, 0.) + 1e-3)
        scores = jnp.where(valid, scores, 0.0)
        num = jnp.einsum("...kgs,...skd->...kgd", scores, vb)
        den = jnp.sum(scores, axis=-1)[..., None] + 1e-6
        y = (num / den).reshape(*q.shape[:-1], dv)
        return y, AttnCache(kbuf, vbuf, n_seen, None, None)

    logits = jnp.einsum("...kgd,...skd->...kgs", qg, kb) / jnp.sqrt(
        jnp.asarray(dh, q.dtype))
    if spec.logit_softcap:
        logits = spec.logit_softcap * jnp.tanh(logits / spec.logit_softcap)
    logits = jnp.where(valid, logits, jnp.finfo(logits.dtype).min)
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1).astype(q.dtype)
    y = jnp.einsum("...kgs,...skd->...kgd", probs, vb)
    return y.reshape(*q.shape[:-1], dv), AttnCache(kbuf, vbuf, n_seen,
                                                   None, None)


def _features(spec: AttentionSpec, params: dict | None, u):
    if spec.kind == "slay":
        from repro.core.features import slay_features
        return slay_features(u, params, spec.slay)
    if spec.kind == "favor":
        return bl.favor_features(u, params)
    if spec.kind == "elu1":
        return bl.elu1_features(u)
    if spec.kind == "cosformer":
        # Decode: position-dependent reweighting needs absolute positions;
        # we use the large-M limit (cos ~ 1) for the single-token path.
        return jnp.concatenate([jax.nn.relu(u), jnp.zeros_like(u)], axis=-1)
    raise ValueError(spec.kind)
