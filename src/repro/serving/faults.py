"""Deterministic chaos injection for the serving engine (DESIGN.md §10).

Test/bench-only: pass a :class:`FaultInjector` to
``ContinuousServingEngine(..., fault_injector=...)`` and the engine
consults it at fixed points — arrival delay at ``submit()``, injected
cancellations and slot NaN-corruption at the top of each tick. Production
engines pass None and none of this code runs.

Every draw is keyed on ``(seed, kind, tick-or-submission-index)`` via
``np.random.SeedSequence`` — no global RNG state, no draw-order
dependence — so a chaos run is a pure function of (trace, seed): replay
the same request trace with the same injector seed and the same faults
land on the same ticks. That determinism is what makes the chaos bench's
degraded-mode rows (shed rate, deadline-miss rate, fault-detect latency,
retry success) trendable in CI rather than flaky.

The injector keeps a ``log`` of every event it fired. The chaos bench
joins the ``nan`` entries against the engine's ``fault_events`` records
(same slot, detect tick >= inject tick) to measure fault-detection
latency in ticks — bounded by K, since detection rides the (K, S) fault
plane of the next decode dispatch.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# SeedSequence stream tags — one disjoint stream per fault kind.
_ARRIVAL, _CANCEL, _NAN, _CRASH = 1, 2, 3, 4


class EngineCrash(RuntimeError):
    """Injected stand-in for process death (kill -9, preemption).

    Raised out of ``engine.step()`` at the crash tick. The harness must
    *abandon* the engine object — no cleanup runs, unflushed journal
    records are lost, exactly as a real crash would lose them — and
    recover via ``ContinuousServingEngine.restore`` (DESIGN.md §12).
    """

    def __init__(self, tick: int):
        super().__init__(f"injected crash at tick {tick}")
        self.tick = int(tick)


@dataclasses.dataclass
class FaultInjector:
    """Seeded fault source. All cadences are in engine ticks; 0 disables
    that fault kind. ``delay_prob`` applies per submission.

    nan_every     corrupt one live slot's device state every N ticks
    cancel_every  cancel one live request every N ticks
    delay_prob    chance a submission's arrival_time is pushed back by
                  Uniform{1..max_delay_ticks} ticks
    crash_window  (lo, hi) tick window: the engine dies (EngineCrash) at
                  one seeded uniform tick in [lo, hi]; () disables. Fires
                  at most once per injector instance.
    """

    seed: int = 0
    nan_every: int = 0
    cancel_every: int = 0
    delay_prob: float = 0.0
    max_delay_ticks: int = 8
    crash_window: tuple = ()
    log: list = dataclasses.field(default_factory=list)
    _submissions: int = 0
    _crashed: bool = False

    def _rng(self, kind: int, n: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, kind, n]))

    def arrival_delay_for(self) -> float:
        """Delay (ticks, possibly 0) for the next submission. Keyed on the
        submission index, so the delay pattern is independent of when in
        wall time requests are submitted."""
        n = self._submissions
        self._submissions += 1
        if not self.delay_prob:
            return 0.0
        rng = self._rng(_ARRIVAL, n)
        if rng.random() >= self.delay_prob:
            return 0.0
        d = int(rng.integers(1, self.max_delay_ticks + 1))
        self.log.append({"kind": "delay", "submission": n, "ticks": d})
        return float(d)

    def cancel_rids(self, tick: int, live_rids) -> list[int]:
        """Request ids to cancel at this tick (at most one). ``live_rids``
        is the engine's view of cancellable requests (slot-resident +
        ready-queued); the choice is uniform over them, keyed on the
        tick so engine-state history cannot perturb later draws."""
        if not self.cancel_every or tick == 0 or tick % self.cancel_every:
            return []
        rids = sorted(live_rids)
        if not rids:
            return []
        rid = rids[int(self._rng(_CANCEL, tick).integers(len(rids)))]
        self.log.append({"kind": "cancel", "tick": tick, "rid": rid})
        return [rid]

    def crash_tick(self) -> int | None:
        """The seeded tick this injector will crash at, or None."""
        if not self.crash_window:
            return None
        lo, hi = int(self.crash_window[0]), int(self.crash_window[1])
        if hi <= lo:
            return lo
        return lo + int(self._rng(_CRASH, 0).integers(hi - lo + 1))

    def crash_now(self, tick: int) -> bool:
        """True exactly once, at the first tick >= the seeded crash tick.
        The engine raises :class:`EngineCrash` out of ``step()`` — no
        flush, no cleanup — simulating process death mid-run."""
        t = self.crash_tick()
        if t is None or self._crashed or tick < t:
            return False
        self._crashed = True
        self.log.append({"kind": "crash", "tick": int(tick)})
        return True

    def corrupt_slots(self, tick: int, live_slots) -> list[int]:
        """Pool slots to NaN-corrupt at this tick (at most one), chosen
        uniformly over the live slots. The engine applies the corruption
        with its jitted ``corrupt_slot`` (slot-stable, shard-local) and
        then *detects* it through the ordinary macro-step fault lane —
        injection exercises the same path an organic NaN would take."""
        if not self.nan_every or tick == 0 or tick % self.nan_every:
            return []
        slots = sorted(live_slots)
        if not slots:
            return []
        slot = slots[int(self._rng(_NAN, tick).integers(len(slots)))]
        self.log.append({"kind": "nan", "tick": tick, "slot": slot})
        return [slot]


def detection_latencies(log: list, fault_events: list) -> list[int]:
    """Join injector ``nan`` events against engine ``fault_events``:
    ticks from injection to quarantine per detected fault (first unmatched
    detection on the same slot at tick >= injection). Undetected
    injections (e.g. the slot finished naturally first — impossible once
    the corruption lands, but possible if it raced an eviction) are
    simply absent."""
    used: set[int] = set()
    out: list[int] = []
    for ev in log:
        if ev.get("kind") != "nan":
            continue
        for i, f in enumerate(fault_events):
            if (i not in used and f["slot"] == ev["slot"]
                    and f["tick"] >= ev["tick"]):
                used.add(i)
                out.append(int(f["tick"] - ev["tick"]))
                break
    return out
