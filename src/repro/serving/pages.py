"""Paged slot memory: a page-table layer under the serving slot pool.

Unpaged, every slot of the pool reserves ``kv_len`` ring rows up front —
a short chat request pays the same HBM as a long-context one. This module
splits the KV/ring leaves of the pooled decode cache into fixed-size
*pages* drawn from one shared physical pool:

    unpaged kv leaf   (nl, S, kv_len, Hkv, dh)
    paged kv leaf     (nl, P, page,   Hkv, dh)     P * page <= S * kv_len

``PagePool`` (host side) owns the free list and the per-slot page tables;
``PageState`` (device side) is the jit-traced mirror — a registered pytree
riding inside ``DecodeCache`` so the decode hot loop stays one fixed-shape
dispatch. Admission allocates ``ceil(need / page)`` pages where ``need``
is the request's true context horizon (prefix + prompt + max_new), so
short requests leave pages free for long ones — the memory-sharing win.

Two hard contracts (DESIGN.md §11):

* **Byte identity.** Inside the decode step the paged ring is gathered to
  the same dense ``(S, kv_len, ...)`` layout the unpaged path uses, the
  unchanged attention decode runs on it, and the result scatters back
  into owned pages. Unmapped table entries materialize as zeros — exactly
  what the unpaged reset-zeroed rows hold — so streams are byte-identical
  paged-vs-unpaged by construction.
* **Zero collectives.** The page dim shards over the ``data`` mesh axis
  in the same static contiguous blocks as the slot dim, and the allocator
  only ever hands a slot pages from its own shard's block. The
  gather/scatter below are written shard-explicitly (reshape to a leading
  shard dim, index within it), so GSPMD partitions them without any
  cross-shard data movement and ``decode_hlo()`` stays collective-free
  (DESIGN.md §8).

Constant-state kinds (linear SLAY ``(S, z)``, SSM carries) bypass paging
entirely: their per-slot state is O(1) in context length, so there is
nothing to page (the paper's point — PAPER.md §3).

This module imports only jax/numpy (no repro.* — models code lazily
imports it, keeping the models<->serving layering acyclic).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
class PageState:
    """Device-side page tables: the traced half of the allocator.

    table      (S, Lp) int32   global page id of slot s's logical page j,
                               -1 where unmapped
    owner_slot (P,)    int32   slot owning physical page p, -1 if free
    owner_lp   (P,)    int32   logical index of page p within its owner

    ``shards`` (static aux data) is the slot/page shard count D — needed
    inside jit because it is not derivable from leaf shapes.
    """

    def __init__(self, table, owner_slot, owner_lp, *, shards: int = 1):
        self.table = table
        self.owner_slot = owner_slot
        self.owner_lp = owner_lp
        self.shards = int(shards)

    def tree_flatten(self):
        return (self.table, self.owner_slot, self.owner_lp), self.shards

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, shards=aux)

    @property
    def num_pages(self) -> int:
        return int(self.owner_slot.shape[0])


def init_state(num_slots: int, num_pages: int, pages_per_slot: int, *,
               shards: int = 1) -> PageState:
    """All-free PageState (fresh pool: every table entry unmapped)."""
    return PageState(
        jnp.full((num_slots, pages_per_slot), -1, jnp.int32),
        jnp.full((num_pages,), -1, jnp.int32),
        jnp.full((num_pages,), -1, jnp.int32), shards=shards)


# ---------------------------------------------------------------------------
# Device helpers — all shard-explicit (leading reshape to D blocks) so the
# compiled decode loop stays free of cross-shard collectives.
# ---------------------------------------------------------------------------


def _split(n: int, d: int, what: str) -> int:
    if n % d:
        raise ValueError(f"{what}={n} not divisible by shards={d}")
    return n // d


def gather_ring(leaf: jax.Array, state: PageState) -> jax.Array:
    """Materialize one paged ring leaf as its dense unpaged layout.

    leaf (P, page, *tail)  ->  (S, Lp*page, *tail); unmapped logical pages
    read as zeros (byte-identical to the unpaged pool's reset rows).
    """
    D = state.shards
    P, page = int(leaf.shape[0]), int(leaf.shape[1])
    S, Lp = int(state.table.shape[0]), int(state.table.shape[1])
    tail = leaf.shape[2:]
    Pn = _split(P, D, "num_pages")
    Sd = _split(S, D, "num_slots")
    kp = leaf.reshape((D, Pn * page) + tail)
    tbl = state.table.reshape(D, Sd, Lp)
    # Shard-local page index; rows of each selected page.
    loc = tbl - (jnp.arange(D, dtype=jnp.int32) * Pn)[:, None, None]
    rows = (jnp.clip(loc, 0, Pn - 1)[..., None] * page
            + jnp.arange(page, dtype=jnp.int32))          # (D, Sd, Lp, page)
    idx = rows.reshape(D, Sd * Lp * page)
    idxb = idx.reshape(idx.shape + (1,) * len(tail))
    out = jnp.take_along_axis(kp, idxb, axis=1)           # (D, Sd*Lp*page, *)
    ok = (tbl >= 0)[..., None] & jnp.ones((page,), bool)  # (D, Sd, Lp, page)
    okb = ok.reshape((D, Sd * Lp * page) + (1,) * len(tail))
    out = jnp.where(okb, out, jnp.zeros((), leaf.dtype))
    return out.reshape((S, Lp * page) + tail)


def scatter_ring(leaf: jax.Array, dense: jax.Array,
                 state: PageState) -> jax.Array:
    """Write a dense ring leaf back into its pages (inverse of gather).

    dense (S, Lp*page, *tail) -> updated leaf (P, page, *tail). Rows not
    covered by an owned page are dropped (they are zeros by the gather
    contract); free pages keep their old bytes.
    """
    D = state.shards
    P, page = int(leaf.shape[0]), int(leaf.shape[1])
    S = int(dense.shape[0])
    size = int(dense.shape[1])
    Lp = size // page
    tail = leaf.shape[2:]
    Pn = _split(P, D, "num_pages")
    Sd = _split(S, D, "num_slots")
    dn = dense.reshape((D, Sd * size) + tail)
    own = state.owner_slot.reshape(D, Pn)
    lp = state.owner_lp.reshape(D, Pn)
    sloc = own - (jnp.arange(D, dtype=jnp.int32) * Sd)[:, None]
    rows = (jnp.clip(sloc, 0, Sd - 1) * size
            + jnp.clip(lp, 0, Lp - 1) * page)[..., None] \
        + jnp.arange(page, dtype=jnp.int32)               # (D, Pn, page)
    idx = rows.reshape(D, Pn * page)
    idxb = idx.reshape(idx.shape + (1,) * len(tail))
    vals = jnp.take_along_axis(dn, idxb, axis=1)          # (D, Pn*page, *)
    owned = (own >= 0)[..., None] & jnp.ones((page,), bool)
    ownb = owned.reshape((D, Pn * page) + (1,) * len(tail))
    kp = leaf.reshape((D, Pn * page) + tail)
    out = jnp.where(ownb, vals, kp)
    return out.reshape((P, page) + tail)


def write_slot_pages(leaf: jax.Array, src: jax.Array, slot: jax.Array,
                     state: PageState) -> jax.Array:
    """Install a batch=1 dense ring into the pages owned by ``slot``.

    leaf (nl, P, page, *tail); src (nl, 1, Lp*page, *tail) — a freshly
    prefilled (replicated) request cache. Every page owned by ``slot`` is
    overwritten in full, so stale bytes from a prior owner never leak.
    Shard-local: src is replicated and the owner vectors are sharded, so
    the select writes only the owning shard's block.
    """
    nl, P, page = int(leaf.shape[0]), int(leaf.shape[1]), int(leaf.shape[2])
    tail = leaf.shape[3:]
    Lp = int(src.shape[2]) // page
    vals = src[:, 0].reshape((nl, Lp, page) + tail)
    sel = jnp.take(vals, jnp.clip(state.owner_lp, 0, Lp - 1),
                   axis=1)                                # (nl, P, page, *)
    mine = (state.owner_slot == slot).reshape(
        (1, P) + (1,) * (leaf.ndim - 2))
    return jnp.where(mine, sel, leaf)


def corrupt_slot_pages(leaf: jax.Array, slot: jax.Array,
                       state: PageState) -> jax.Array:
    """NaN every page owned by ``slot`` (chaos-harness fault injection)."""
    mine = (state.owner_slot == slot).reshape(
        (1, int(leaf.shape[1])) + (1,) * (leaf.ndim - 2))
    return jnp.where(mine, jnp.full((), jnp.nan, leaf.dtype), leaf)


def write_zero_pages(leaf: jax.Array, slot: jax.Array,
                     state: PageState) -> jax.Array:
    """Zero every page owned by ``slot`` (eviction/quarantine reset) —
    freed pages hand their next owner zeros, never a prior slot's bytes
    (in particular never an injected NaN)."""
    mine = (state.owner_slot == slot).reshape(
        (1, int(leaf.shape[1])) + (1,) * (leaf.ndim - 2))
    return jnp.where(mine, jnp.zeros((), leaf.dtype), leaf)


def pages_finite(leaves, state: PageState, num_slots: int) -> jax.Array:
    """(S,) bool: True where every page owned by that slot is finite.

    Per-page reduce then shard-explicit owner attribution — free pages
    (possibly holding stale NaN from a quarantined owner) never count
    against any live slot.
    """
    D = state.shards
    P = state.num_pages
    Pn = _split(P, D, "num_pages")
    Sd = _split(num_slots, D, "num_slots")
    okp = jnp.ones((P,), bool)
    for leaf in leaves:
        red = tuple(i for i in range(leaf.ndim) if i != 1)
        okp = okp & jnp.all(jnp.isfinite(leaf), axis=red)
    own = state.owner_slot.reshape(D, Pn)
    bad = own[:, None, :] == (
        (jnp.arange(D, dtype=jnp.int32) * Sd)[:, None]
        + jnp.arange(Sd, dtype=jnp.int32))[..., None]     # (D, Sd, Pn)
    bad = jnp.any(bad & ~okp.reshape(D, 1, Pn), axis=-1)
    return ~bad.reshape(num_slots)


# ---------------------------------------------------------------------------
# Host-side allocator
# ---------------------------------------------------------------------------


class PagePool:
    """Free-list page allocator with numpy mirrors of the device tables.

    Static geometry for the engine's lifetime: ``num_pages`` physical
    pages of ``page_size`` rows, split into D contiguous shard blocks
    aligned with the slot pool's shard blocks (DESIGN.md §8). A slot is
    only ever given pages from its own shard's block — the invariant the
    shard-explicit device indexing above relies on.

    All mutation is host-side and O(pages touched); the engine pushes the
    updated mirrors to the jitted slot ops as traced args (static shapes,
    so no recompiles).
    """

    def __init__(self, num_slots: int, num_pages: int, page_size: int,
                 pages_per_slot: int, *, shards: int = 1):
        if page_size <= 0:
            raise ValueError("page_size must be positive")
        if num_pages % max(shards, 1):
            raise ValueError(
                f"num_pages={num_pages} not divisible by shards={shards}")
        if num_slots % max(shards, 1):
            raise ValueError(
                f"num_slots={num_slots} not divisible by shards={shards}")
        self.num_slots = num_slots
        self.num_pages = num_pages
        self.page_size = page_size
        self.pages_per_slot = pages_per_slot
        self.shards = max(int(shards), 1)
        self._pn = num_pages // self.shards
        self._sd = num_slots // self.shards
        self.table = np.full((num_slots, pages_per_slot), -1, np.int32)
        self.owner_slot = np.full((num_pages,), -1, np.int32)
        self.owner_lp = np.full((num_pages,), -1, np.int32)
        # Per-shard sorted free lists (lowest page id first: deterministic).
        self.free: list[list[int]] = [
            list(range(d * self._pn, (d + 1) * self._pn))
            for d in range(self.shards)]
        self.pages_peak = 0

    # -- queries ---------------------------------------------------------

    def shard_of(self, slot: int) -> int:
        return slot // self._sd

    def pages_for(self, need_rows: int) -> int:
        """Pages required to hold ``need_rows`` ring rows (capped at the
        per-slot table width)."""
        n = -(-max(int(need_rows), 1) // self.page_size)
        return min(n, self.pages_per_slot)

    def pages_in_use(self) -> int:
        return self.num_pages - sum(len(f) for f in self.free)

    def free_in_shard(self, shard: int) -> int:
        return len(self.free[shard])

    def can_alloc(self, slot: int, need_rows: int) -> bool:
        return self.pages_for(need_rows) <= len(self.free[
            self.shard_of(slot)])

    def slot_pages(self, slot: int) -> list[int]:
        return [int(p) for p in self.table[slot] if p >= 0]

    # -- mutation --------------------------------------------------------

    def alloc(self, slot: int, need_rows: int) -> list[int]:
        """Assign pages_for(need_rows) pages to ``slot`` from its shard's
        free list. The slot must hold no pages (admission is whole-slot)."""
        if self.table[slot].max(initial=-1) >= 0:
            raise RuntimeError(f"slot {slot} already holds pages")
        n = self.pages_for(need_rows)
        shard = self.shard_of(slot)
        if n > len(self.free[shard]):
            raise RuntimeError(
                f"shard {shard} has {len(self.free[shard])} free pages, "
                f"need {n}")
        got = self.free[shard][:n]
        del self.free[shard][:n]
        for j, p in enumerate(got):
            if self.owner_slot[p] >= 0:      # pragma: no cover — invariant
                raise RuntimeError(f"page {p} double-assigned")
            self.table[slot, j] = p
            self.owner_slot[p] = slot
            self.owner_lp[p] = j
        self.pages_peak = max(self.pages_peak, self.pages_in_use())
        return got

    def free_slot(self, slot: int) -> int:
        """Return all of ``slot``'s pages to its shard's free list."""
        shard = self.shard_of(slot)
        n = 0
        for j in range(self.pages_per_slot):
            p = int(self.table[slot, j])
            if p < 0:
                continue
            self.table[slot, j] = -1
            self.owner_slot[p] = -1
            self.owner_lp[p] = -1
            self.free[shard].append(p)
            n += 1
        self.free[shard].sort()
        return n

    def device_vectors(self) -> PageState:
        """Snapshot the mirrors as a device PageState (traced jit args)."""
        return PageState(jnp.asarray(self.table),
                         jnp.asarray(self.owner_slot),
                         jnp.asarray(self.owner_lp), shards=self.shards)

    # -- durability (DESIGN.md §12) --------------------------------------

    def snapshot(self) -> dict:
        """Plain-host copy of allocator state for engine checkpoints."""
        return {
            "num_slots": self.num_slots,
            "num_pages": self.num_pages,
            "page_size": self.page_size,
            "pages_per_slot": self.pages_per_slot,
            "shards": self.shards,
            "table": self.table.copy(),
            "owner_slot": self.owner_slot.copy(),
            "owner_lp": self.owner_lp.copy(),
            "free": [list(f) for f in self.free],
            "pages_peak": int(self.pages_peak),
        }

    def load_snapshot(self, snap: dict) -> None:
        """Restore allocator state from :meth:`snapshot`; geometry must
        match this pool's (checkpoints are rejected upstream otherwise)."""
        for k in ("num_slots", "num_pages", "page_size", "pages_per_slot",
                  "shards"):
            if int(snap[k]) != getattr(self, k):
                raise ValueError(
                    f"PagePool snapshot {k}={snap[k]} != {getattr(self, k)}")
        self.table = np.asarray(snap["table"], np.int32).copy()
        self.owner_slot = np.asarray(snap["owner_slot"], np.int32).copy()
        self.owner_lp = np.asarray(snap["owner_lp"], np.int32).copy()
        self.free = [sorted(int(p) for p in f) for f in snap["free"]]
        self.pages_peak = int(snap["pages_peak"])
        self.check()

    def check(self) -> None:
        """Invariant audit (tests/chaos): free + owned partitions pages,
        table and owner vectors agree, shard blocks respected."""
        seen: set[int] = set()
        for d, fl in enumerate(self.free):
            for p in fl:
                assert d * self._pn <= p < (d + 1) * self._pn, (d, p)
                assert self.owner_slot[p] == -1, p
                assert p not in seen, p
                seen.add(p)
        for s in range(self.num_slots):
            for j in range(self.pages_per_slot):
                p = int(self.table[s, j])
                if p < 0:
                    continue
                d = self.shard_of(s)
                assert d * self._pn <= p < (d + 1) * self._pn, (s, p)
                assert self.owner_slot[p] == s, (s, j, p)
                assert self.owner_lp[p] == j, (s, j, p)
                assert p not in seen, p
                seen.add(p)
        assert len(seen) == self.num_pages, (len(seen), self.num_pages)
