"""On-device token sampling for the serving decode hot loop.

The continuous-batching engine samples *inside* the jitted decode tick so
only an ``(num_slots,)`` int32 token vector — never an
``(num_slots, vocab)`` logits matrix — crosses to host.

Determinism contract: the Gumbel noise for request ``rid``'s ``idx``-th
generated token is keyed on ``(seed, rid, idx)`` via threefry ``fold_in``
— independent of slot placement, batch composition, and macro-step size K.
A request therefore samples the same token stream whether it decodes alone,
in a full pool, tick-by-tick (K=1), or K ticks per dispatch, and
:func:`host_sample_token` reproduces the fused sampler exactly on the same
backend (the parity oracle for tests).

The same property makes sampling *slot-shard-placement-invariant*
(DESIGN.md §8): under a data-axis-sharded slot pool each shard evaluates
the identical ``fold_in``-keyed Gumbel row for its own slots' (rid, idx)
pairs, so token streams are byte-identical between mesh=(1,) and
mesh=(data=N,) — nothing here reads the mesh, the slot index, or the
shard.

Greedy (``temperature <= 0``) is a plain fp32 argmax: ``jnp.argmax`` and
``np.argmax`` both take the first maximum, so device and host agree
bit-for-bit on identical logits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# Finish-reason taxonomy (DESIGN.md §10). Every request terminates with
# exactly one of these, stamped on its RequestStats, passed to its
# ``on_finish`` callback, and counted in ``ServingMetrics.summary()``:
#
#   eos        sampled the request's eos_id (natural stop)
#   length     hit the max_new_tokens budget
#   deadline   missed its ttft/total deadline (ticks or wall-clock)
#   cancelled  explicitly cancelled via ContinuousServingEngine.cancel
#   shed       dropped by the overload policy (queue full / queue-wait)
#   fault      non-finite slot state detected and retries exhausted
#
# eos/length are the *successful* reasons (requests_completed counts
# them); the other four are degraded-mode exits.
FINISH_REASONS = ("eos", "length", "deadline", "cancelled", "shed", "fault")

# Version of the (seed, rid, token-index) stream-keying scheme below.
# The write-ahead journal stamps this into its meta record and restore
# refuses to resume a journal written under a different version: crash
# recovery regenerates in-flight tokens by *re-sampling*, so its
# byte-identity-after-restore contract (DESIGN.md §12) is only as strong
# as the keying being unchanged. Bump on any change to the fold_in
# scheme, Gumbel construction, or argmax tie-breaking.
STREAM_KEY_VERSION = 1


def stop_hit(tok, gen, eos_id, max_new):
    """Natural-stop predicate: did the just-emitted token end the request?

    One logic, two call sites: elementwise on the (S,) device lanes inside
    the jitted macro-step, and on python/numpy scalars in the host replay
    — so device masking and host eviction can never disagree. ``gen``
    counts tokens emitted *including* ``tok``.
    """
    return (tok == eos_id) | (gen >= max_new)


def finish_reason_of(tok: int, eos_id: int) -> str:
    """Reason for a natural stop: ``eos`` wins over ``length`` when the
    budget-exhausting token is also the eos id."""
    return "eos" if tok == eos_id else "length"


def _gumbel_row(seed: int, rid, idx, vocab: int) -> jnp.ndarray:
    """Gumbel(0,1) row keyed on (seed, rid, idx); fp32, (vocab,)."""
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(seed), rid), idx)
    return jax.random.gumbel(key, (vocab,), jnp.float32)


# Speculative-decoding substreams (DESIGN.md §13). Each token index needs
# up to three *independent* draws — the draft proposal, the accept coin,
# and the rejection resample — so each gets its own stream derived from
# the same (seed, rid, idx) base key by one extra ``fold_in`` tag. The
# *bonus* token (emitted when every draft in a round is accepted) uses the
# untagged base stream — i.e. exactly the draw plain decode would make —
# which is part of what keeps greedy spec streams byte-identical to plain
# greedy decode. Covered by STREAM_KEY_VERSION: any change here changes
# sampled spec streams and must bump it.
SPEC_TAG_DRAFT = 1
SPEC_TAG_ACCEPT = 2
SPEC_TAG_RESAMPLE = 3


def spec_key(seed: int, rid, idx, tag: int):
    """Threefry key for a speculative substream of (seed, rid, idx).

    Placement-invariant for the same reason the base stream is: derived
    only from the request id and token index, never from slot, shard, or
    round boundary.
    """
    return jax.random.fold_in(
        jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(seed), rid), idx), tag)


def spec_gumbel_row(seed: int, rid, idx, tag: int, vocab: int) -> jnp.ndarray:
    """Gumbel(0,1) row on a speculative substream; fp32, (vocab,)."""
    return jax.random.gumbel(spec_key(seed, rid, idx, tag), (vocab,),
                             jnp.float32)


def spec_uniform(seed: int, rid, idx) -> jnp.ndarray:
    """The accept coin u ~ U[0,1) for token index ``idx``; fp32 scalar."""
    return jax.random.uniform(spec_key(seed, rid, idx, SPEC_TAG_ACCEPT),
                              (), jnp.float32)


def sample_tokens(logits: jnp.ndarray, rids: jnp.ndarray,
                  idxs: jnp.ndarray, *, temperature: float,
                  seed: int) -> jnp.ndarray:
    """Fused per-slot sampling: logits (S, V) -> tokens (S,) int32.

    ``rids``/``idxs`` are (S,) int32 — the request id and token index each
    slot is sampling (values for drained slots are ignored by the caller).
    ``temperature``/``seed`` are static (baked into the jitted tick).
    Greedy argmax when ``temperature <= 0``; Gumbel-max otherwise.
    """
    logits = logits.astype(jnp.float32)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    vocab = logits.shape[-1]
    g = jax.vmap(lambda r, i: _gumbel_row(seed, r, i, vocab))(rids, idxs)
    return jnp.argmax(logits / temperature + g, axis=-1).astype(jnp.int32)


def host_sample_token(row: np.ndarray, rid: int, idx: int, *,
                      temperature: float, seed: int) -> int:
    """Host-side reference sampler — same math as :func:`sample_tokens`
    on one logits row; the parity oracle for the fused on-device path."""
    row = np.asarray(row, np.float32)
    if temperature <= 0.0:
        return int(np.argmax(row))
    g = np.asarray(_gumbel_row(seed, jnp.int32(rid), jnp.int32(idx),
                               row.shape[-1]))
    return int(np.argmax(row / temperature + g))
