"""On-device token sampling for the serving decode hot loop.

The continuous-batching engine samples *inside* the jitted decode tick so
only an ``(num_slots,)`` int32 token vector — never an
``(num_slots, vocab)`` logits matrix — crosses to host.

Determinism contract: the Gumbel noise for request ``rid``'s ``idx``-th
generated token is keyed on ``(seed, rid, idx)`` via threefry ``fold_in``
— independent of slot placement, batch composition, and macro-step size K.
A request therefore samples the same token stream whether it decodes alone,
in a full pool, tick-by-tick (K=1), or K ticks per dispatch, and
:func:`host_sample_token` reproduces the fused sampler exactly on the same
backend (the parity oracle for tests).

The same property makes sampling *slot-shard-placement-invariant*
(DESIGN.md §8): under a data-axis-sharded slot pool each shard evaluates
the identical ``fold_in``-keyed Gumbel row for its own slots' (rid, idx)
pairs, so token streams are byte-identical between mesh=(1,) and
mesh=(data=N,) — nothing here reads the mesh, the slot index, or the
shard.

Greedy (``temperature <= 0``) is a plain fp32 argmax: ``jnp.argmax`` and
``np.argmax`` both take the first maximum, so device and host agree
bit-for-bit on identical logits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _gumbel_row(seed: int, rid, idx, vocab: int) -> jnp.ndarray:
    """Gumbel(0,1) row keyed on (seed, rid, idx); fp32, (vocab,)."""
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(seed), rid), idx)
    return jax.random.gumbel(key, (vocab,), jnp.float32)


def sample_tokens(logits: jnp.ndarray, rids: jnp.ndarray,
                  idxs: jnp.ndarray, *, temperature: float,
                  seed: int) -> jnp.ndarray:
    """Fused per-slot sampling: logits (S, V) -> tokens (S,) int32.

    ``rids``/``idxs`` are (S,) int32 — the request id and token index each
    slot is sampling (values for drained slots are ignored by the caller).
    ``temperature``/``seed`` are static (baked into the jitted tick).
    Greedy argmax when ``temperature <= 0``; Gumbel-max otherwise.
    """
    logits = logits.astype(jnp.float32)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    vocab = logits.shape[-1]
    g = jax.vmap(lambda r, i: _gumbel_row(seed, r, i, vocab))(rids, idxs)
    return jnp.argmax(logits / temperature + g, axis=-1).astype(jnp.int32)


def host_sample_token(row: np.ndarray, rid: int, idx: int, *,
                      temperature: float, seed: int) -> int:
    """Host-side reference sampler — same math as :func:`sample_tokens`
    on one logits row; the parity oracle for the fused on-device path."""
    row = np.asarray(row, np.float32)
    if temperature <= 0.0:
        return int(np.argmax(row))
    g = np.asarray(_gumbel_row(seed, jnp.int32(rid), jnp.int32(idx),
                               row.shape[-1]))
    return int(np.argmax(row / temperature + g))
