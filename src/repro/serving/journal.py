"""Append-only write-ahead journal for crash-safe serving (DESIGN.md §12).

The journal is the source of truth for *request-level* state: which
requests were admitted (rid, prompt tokens + digest, deadlines), every
token emitted to a stream, fault retries, and terminations with their
typed finish_reason.  Together with a periodic engine checkpoint
(``serving/checkpoint.py``) it makes ``ContinuousServingEngine.restore``
deterministic: sampling is keyed on (seed, rid, token-index)
(``serving/sampling.py``), so any request replayed from its journaled
admission regenerates the *byte-identical* stream, and already-journaled
tokens are deduplicated against the regenerated ones instead of being
delivered twice.

Record framing — one record per line::

    <crc32 hex8> <json>\n

The CRC covers the JSON payload bytes.  A torn write at crash time can
only corrupt the tail of the file, so the reader (``replay``) validates
records in order and drops everything from the first bad/partial record
onwards; ``Journal(path, truncate_to=...)`` truncates the file back to
the last valid byte offset before resuming appends, so a corrupt tail
can never shadow post-restore records.

Durability contract: ``append`` only buffers in memory; ``flush`` writes
and fsyncs the batch.  The engine flushes once per macro-step (K device
ticks) and at admission/termination boundaries, so the decode hot loop's
host_syncs_per_token ≤ 1/K cadence is untouched.

Record types (the ``t`` field):

- ``meta``   — journal version, engine seed/temperature, sampling stream
  key version, geometry hints.  Written once when a fresh journal is
  created.
- ``admit``  — rid, prompt token list + sha256 digest, arrival time,
  max_new_tokens, eos_id, deadline fields, wall timestamp.
- ``tok``    — rid, one emitted token (in emission order).
- ``retry``  — rid was quarantined and restarted from scratch; all
  previously journaled tokens for that rid are void.
- ``fin``    — rid, typed finish_reason, tick.
"""

from __future__ import annotations

import dataclasses
import json
import os
import zlib

JOURNAL_VERSION = 1
JOURNAL_NAME = "journal.wal"


def _frame(payload: dict) -> bytes:
    data = json.dumps(payload, separators=(",", ":"), sort_keys=True).encode()
    return b"%08x " % (zlib.crc32(data) & 0xFFFFFFFF) + data + b"\n"


def _parse_line(line: bytes) -> dict | None:
    """Return the decoded record, or None if the line is torn/corrupt."""
    if not line.endswith(b"\n") or len(line) < 10 or line[8:9] != b" ":
        return None
    body = line[9:-1]
    try:
        if int(line[:8], 16) != (zlib.crc32(body) & 0xFFFFFFFF):
            return None
        rec = json.loads(body)
    except (ValueError, json.JSONDecodeError):
        return None
    return rec if isinstance(rec, dict) and "t" in rec else None


@dataclasses.dataclass
class JournalState:
    """Result of a tolerant journal replay."""

    meta: dict | None = None
    admits: dict[int, dict] = dataclasses.field(default_factory=dict)
    tokens: dict[int, list[int]] = dataclasses.field(default_factory=dict)
    retries: dict[int, int] = dataclasses.field(default_factory=dict)
    fins: dict[int, str] = dataclasses.field(default_factory=dict)
    records: int = 0
    valid_bytes: int = 0
    dropped_tail: bool = False


def replay(path: str) -> JournalState:
    """Fold a journal file into per-rid state, tolerating a torn tail.

    Records are validated in order; the first bad record (truncated
    write, flipped bits, partial final line) ends the replay and marks
    ``dropped_tail`` — everything before it is intact because appends
    are strictly sequential.
    """
    st = JournalState()
    if not os.path.exists(path):
        return st
    with open(path, "rb") as f:
        for line in f:
            rec = _parse_line(line)
            if rec is None:
                st.dropped_tail = True
                break
            kind = rec["t"]
            if kind == "meta":
                st.meta = rec
            elif kind == "admit":
                st.admits[int(rec["rid"])] = rec
            elif kind == "tok":
                st.tokens.setdefault(int(rec["rid"]), []).append(int(rec["tok"]))
            elif kind == "retry":
                rid = int(rec["rid"])
                st.tokens[rid] = []
                st.retries[rid] = st.retries.get(rid, 0) + 1
            elif kind == "fin":
                st.fins[int(rec["rid"])] = str(rec["reason"])
            # Unknown record types are forward-compatible no-ops.
            st.records += 1
            st.valid_bytes += len(line)
    return st


class Journal:
    """Buffered, fsync-batched appender over the WAL file.

    ``append`` is O(1) host work (dict → frame bytes into a list);
    ``flush`` concatenates the buffer, writes once, and fsyncs once.
    """

    def __init__(self, path: str, *, truncate_to: int | None = None):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        # Truncate a torn tail *before* opening for append so resumed
        # records land immediately after the last valid one.
        if truncate_to is not None and os.path.exists(path):
            with open(path, "r+b") as f:
                f.truncate(truncate_to)
        self._f = open(path, "ab")
        self._buf: list[bytes] = []
        self.nbytes = self._f.tell()
        self.flushes = 0

    @property
    def dirty(self) -> bool:
        return bool(self._buf)

    def append(self, record: dict) -> None:
        self._buf.append(_frame(record))

    def flush(self) -> None:
        if not self._buf:
            return
        blob = b"".join(self._buf)
        self._buf.clear()
        self._f.write(blob)
        self._f.flush()
        os.fsync(self._f.fileno())
        self.nbytes += len(blob)
        self.flushes += 1

    def close(self) -> None:
        try:
            self.flush()
        finally:
            self._f.close()

    def __enter__(self) -> "Journal":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
