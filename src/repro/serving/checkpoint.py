"""Atomic engine checkpoints for crash-safe serving (DESIGN.md §12).

A checkpoint captures everything the journal does *not*: the device
pool cache (including the ``PageState`` pytree when paging is on),
host slot mirrors, scheduler residency (which rid owns which slot),
``PagePool`` free lists, and the prefix-cache index.  Restore loads the
latest valid checkpoint, then replays the journal suffix to rebuild
queued requests and deduplicate already-emitted tokens.

File format::

    magic (8B) | version u32 | payload_len u64 | sha256(payload) 32B | payload

The payload is a pickled dict of plain host objects (numpy arrays,
lists, dicts) — device arrays are pulled via ``jax.device_get`` and the
pool pytree is stored as a leaves list; restore rebuilds the structure
from a freshly constructed engine's treedef, so no code objects are
serialized.  Writes are atomic: tmp file + fsync + ``os.replace`` +
directory fsync.  ``latest_valid`` scans ``ckpt-*.ckpt`` newest-first
and skips files whose checksum/header fails, so a crash mid-checkpoint
falls back to the previous checkpoint (or journal-only recovery).
"""

from __future__ import annotations

import hashlib
import os
import pickle
import re
import struct

import jax
import numpy as np

MAGIC = b"SLAYCKPT"
CKPT_VERSION = 1
_HEADER = struct.Struct("<8sIQ")
_CKPT_RE = re.compile(r"^ckpt-(\d+)\.ckpt$")


class CheckpointError(RuntimeError):
    """Raised when a checkpoint file fails header/checksum validation."""


def checkpoint_path(directory: str, tick: int) -> str:
    return os.path.join(directory, f"ckpt-{tick:012d}.ckpt")


def save(path: str, state: dict) -> None:
    """Atomically write ``state`` to ``path`` (tmp + rename + fsync)."""
    payload = pickle.dumps(state, protocol=4)
    blob = (
        _HEADER.pack(MAGIC, CKPT_VERSION, len(payload))
        + hashlib.sha256(payload).digest()
        + payload
    )
    d = os.path.dirname(path) or "."
    os.makedirs(d, exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        f.write(blob)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    dirfd = os.open(d, os.O_RDONLY)
    try:
        os.fsync(dirfd)
    finally:
        os.close(dirfd)


def load(path: str) -> dict:
    """Load and validate one checkpoint file; raises CheckpointError."""
    with open(path, "rb") as f:
        blob = f.read()
    if len(blob) < _HEADER.size + 32:
        raise CheckpointError(f"{path}: truncated header")
    magic, version, plen = _HEADER.unpack_from(blob)
    if magic != MAGIC:
        raise CheckpointError(f"{path}: bad magic {magic!r}")
    if version != CKPT_VERSION:
        raise CheckpointError(f"{path}: unsupported version {version}")
    digest = blob[_HEADER.size : _HEADER.size + 32]
    payload = blob[_HEADER.size + 32 :]
    if len(payload) != plen:
        raise CheckpointError(f"{path}: payload length {len(payload)} != {plen}")
    if hashlib.sha256(payload).digest() != digest:
        raise CheckpointError(f"{path}: checksum mismatch")
    state = pickle.loads(payload)
    if not isinstance(state, dict):
        raise CheckpointError(f"{path}: payload is not a state dict")
    return state


def list_checkpoints(directory: str) -> list[tuple[int, str]]:
    """All checkpoint files in ``directory`` as (tick, path), newest first."""
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = _CKPT_RE.match(name)
        if m:
            out.append((int(m.group(1)), os.path.join(directory, name)))
    out.sort(reverse=True)
    return out


def latest_valid(directory: str) -> dict | None:
    """Newest checkpoint that passes validation, or None."""
    for _tick, path in list_checkpoints(directory):
        try:
            return load(path)
        except (CheckpointError, OSError, pickle.UnpicklingError, EOFError):
            continue  # corrupt/torn checkpoint: fall back to an older one
    return None


def snapshot_engine(eng) -> dict:
    """Build the checkpoint state dict from a live engine.

    Mid-prefill state is deliberately *not* captured: if a chunked
    prefill is in flight, its slot's pages are freed in a cloned
    ``PagePool`` snapshot and the request simply re-admits from its
    journaled admission at restore (same chunk schedule, same stream).
    """
    pool_leaves = [np.asarray(x) for x in jax.device_get(jax.tree.leaves(eng.pool))]
    # Speculative mode (§13): the draft pool is live decode state too — a
    # resident slot resumed without its draft twin would draft from zeros
    # (still correct output, but a silent acceptance-rate cliff), so it is
    # captured and restored alongside the verifier pool.
    draft_leaves = None
    if getattr(eng, "draft_pool", None) is not None:
        draft_leaves = [
            np.asarray(x)
            for x in jax.device_get(jax.tree.leaves(eng.draft_pool))
        ]
    mirrors = {
        "last_tok": np.asarray(eng._last_tok).copy(),
        "active": np.asarray(eng._active).copy(),
        "rids": np.asarray(eng._rids).copy(),
        "gen": np.asarray(eng._gen).copy(),
        "eos": np.asarray(eng._eos).copy(),
        "maxn": np.asarray(eng._maxn).copy(),
    }
    inflight_slot = eng._prefill.slot if eng._prefill is not None else None
    page_snap = None
    if eng.page_pool is not None:
        pp = eng.page_pool
        if inflight_slot is not None and pp.slot_pages(inflight_slot):
            from repro.serving import pages as pages_lib

            clone = pages_lib.PagePool(
                pp.num_slots, pp.num_pages, pp.page_size,
                pp.pages_per_slot, shards=pp.shards,
            )
            clone.load_snapshot(pp.snapshot())
            clone.free_slot(inflight_slot)
            page_snap = clone.snapshot()
        else:
            page_snap = pp.snapshot()
    slots = {}
    for slot, rec in eng.sched.active.items():
        if slot == inflight_slot:
            continue
        slots[int(slot)] = int(rec.rid)
    prefix_entries = None
    if eng.prefix_cache is not None:
        prefix_entries = []
        for ent in eng.prefix_cache.entries():
            prefix_entries.append(
                {
                    "tokens": np.asarray(ent.tokens, np.int32),
                    "length": int(ent.length),
                    "cache": [np.asarray(x) for x in jax.device_get(
                        jax.tree.leaves(ent.cache))],
                    "logits": (
                        np.asarray(jax.device_get(ent.logits))
                        if ent.logits is not None
                        else None
                    ),
                }
            )
    return {
        "version": CKPT_VERSION,
        "tick": int(eng.tick),
        "next_rid": int(eng._next_rid),
        "num_slots": int(eng.serving.num_slots),
        "max_len": int(eng.serving.max_len),
        "page_size": int(eng.serving.page_size) if eng.page_pool is not None else 0,
        "seed": int(eng.serving.seed),
        "speculative": bool(getattr(eng, "_spec", False)),
        "spec_gamma": (int(eng.serving.spec_gamma)
                       if getattr(eng, "_spec", False) else 0),
        "pool": pool_leaves,
        "draft_pool": draft_leaves,
        "mirrors": mirrors,
        "slots": slots,
        "page_pool": page_snap,
        "prefix": prefix_entries,
    }
