"""Draft-verify speculative decoding on the two-regime engine (DESIGN.md §13).

The repo holds both of the paper's regimes behind one slot API: linear
SLAY decode carries O(1) constant state per slot (the cheap *draft*), and
the exact quadratic yat kinds score a whole token chunk in one dispatch
via the §9 chunked-prefill continuation (the *verifier*). A speculative
round drafts ``gamma`` tokens per slot with the linear model, scores all
``gamma + 1`` positions with the exact model in a single ``verify_chunk``
dispatch, and applies the standard accept/resample correction — so the
emitted distribution equals the verifier's exactly, while each round can
emit up to ``gamma + 1`` tokens for one verifier evaluation.

Determinism contract (the serving.sampling one, extended): every draw is
keyed on (seed, rid, token-index) plus a substream tag — the draft
proposal, the accept coin, and the rejection resample are independent
streams of the same base key, and the *bonus* token (all drafts accepted)
uses the untagged base stream. Nothing keys on slot, shard, macro-step
size, or round boundary, so accepted streams are placement-, K-, and
shard-invariant for a fixed ``gamma``. Greedy (temperature <= 0) collapses
to "accept iff the draft equals the verifier argmax, emit the verifier
argmax either way": every emitted token is the verifier's argmax given the
emitted prefix, i.e. greedy spec streams are byte-identical to greedy
exact decode for *any* draft and any ``gamma`` — provided the verifier's
fp32 argmax is unique at every emitted position (an *exact* top-2 logit
tie may be broken differently by the differently-shaped decode-step and
verify-chunk XLA programs; measure-zero for trained weights, see
DESIGN.md §13).

Rollback composes with the rest of the serving stack because KV-ring
validity is derived from per-slot ``pos`` alone: rejecting a suffix is a
``pos`` rewind (``api.rollback_slots``), stale rows past the accept
horizon are invisible and get overwritten in place, and a paged pool's
page table is untouched (admission already sized the slot's pages for the
full horizon plus ``gamma`` overshoot rows — zero pages to free, zero to
leak). The draft pool re-absorbs exactly the emitted tokens from its
round-start snapshot, so both regimes agree on the context after every
round.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import api
from repro.serving import sampling


# ---------------------------------------------------------------------------
# Pure acceptance math (vectorized over slots; also the test harness's
# statistical-contract surface — see tests/test_speculative.py)
# ---------------------------------------------------------------------------


def draft_sample(logits: jnp.ndarray, rids: jnp.ndarray, idxs: jnp.ndarray,
                 *, temperature: float, seed: int) -> jnp.ndarray:
    """Draft proposal: logits (S, V) -> tokens (S,) drawn from
    softmax(logits / T) on the DRAFT substream (Gumbel-max), or the plain
    fp32 argmax when greedy. Mirrors :func:`sampling.sample_tokens` but on
    an independent stream: the proposal must never consume the verifier's
    (seed, rid, idx) base draw."""
    logits = logits.astype(jnp.float32)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    vocab = logits.shape[-1]
    g = jax.vmap(lambda r, i: sampling.spec_gumbel_row(
        seed, r, i, sampling.SPEC_TAG_DRAFT, vocab))(rids, idxs)
    return jnp.argmax(logits / temperature + g, axis=-1).astype(jnp.int32)


def accept_and_correct(p_logits: jnp.ndarray, q_logits: jnp.ndarray,
                       drafts: jnp.ndarray, rids: jnp.ndarray,
                       idxs: jnp.ndarray, *, temperature: float,
                       seed: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One draft position's accept/resample correction, per slot.

    p_logits/q_logits (S, V) are the verifier's and draft's logits for the
    same token index; ``drafts`` (S,) the proposed tokens. Returns
    ``(accept (S,) bool, corrected (S,) int32)`` where ``corrected`` is
    the token to emit *instead* on rejection.

    Sampled (T > 0): accept with probability min(1, p(d)/q(d)) on the
    ACCEPT coin; on rejection emit a draw from normalize(max(p - q, 0))
    on the RESAMPLE substream. Marginalizing over the draft proposal, the
    emitted token is distributed exactly softmax(p_logits / T) — the
    standard speculative-sampling identity the chi-square harness checks
    empirically.

    Greedy (T <= 0): accept iff the draft *is* the verifier argmax;
    ``corrected`` is that argmax — so the emitted token is the verifier
    argmax in both branches.
    """
    p_logits = p_logits.astype(jnp.float32)
    if temperature <= 0.0:
        top = jnp.argmax(p_logits, axis=-1).astype(jnp.int32)
        return drafts == top, top
    q_logits = q_logits.astype(jnp.float32)
    p = jax.nn.softmax(p_logits / temperature, axis=-1)
    q = jax.nn.softmax(q_logits / temperature, axis=-1)
    pd = jnp.take_along_axis(p, drafts[:, None], axis=-1)[:, 0]
    qd = jnp.take_along_axis(q, drafts[:, None], axis=-1)[:, 0]
    u = jax.vmap(lambda r, i: sampling.spec_uniform(seed, r, i))(rids, idxs)
    # u < min(1, pd/qd), division-free: u in [0, 1) so u*qd < pd is the
    # same event and stays exact when p == q (always accept).
    accept = u * qd < pd
    r = jnp.maximum(p - q, 0.0)
    # Gumbel-max over log-residuals; zero-residual entries are -inf and
    # can never win. All-zero residual (p == q elementwise) is unreachable
    # — acceptance is then certain — so the argmax fallback row there is
    # irrelevant; it just must not be NaN.
    logr = jnp.where(r > 0, jnp.log(jnp.maximum(r, 1e-38)), -jnp.inf)
    vocab = p.shape[-1]
    g = jax.vmap(lambda rr, i: sampling.spec_gumbel_row(
        seed, rr, i, sampling.SPEC_TAG_RESAMPLE, vocab))(rids, idxs)
    corrected = jnp.argmax(logr + g, axis=-1).astype(jnp.int32)
    return accept, corrected


# ---------------------------------------------------------------------------
# The jitted speculative macro-step (the engine's decode hot loop in
# speculative mode — one dispatch = K rounds, each up to gamma+1 tokens)
# ---------------------------------------------------------------------------


def spec_macro(params, draft_pool, pool, last_tok, active, rids, gen,
               eos_ids, max_new, *, draft_cfg: ArchConfig, cfg: ArchConfig,
               num_rounds: int, gamma: int, temperature: float, seed: int,
               fault_guard: bool = True):
    """K speculative rounds as one jitted ``lax.scan`` over the slot pool.

    Per round and per active slot: (1) the linear draft pool runs
    ``gamma`` masked decode steps proposing d_1..d_gamma; (2) the exact
    pool scores the ``gamma + 1`` inputs [last_tok, d_1..d_gamma] in one
    ``verify_chunk`` — the §9-exact chunked continuation — yielding the
    verifier distribution for every proposed index plus the bonus
    position; (3) accept/resample correction picks the emitted tokens
    e_1..e_m (m <= gamma+1: the accepted prefix, then one corrected or
    bonus token), truncated at EOS/budget exactly like the plain
    macro-step; (4) the verifier rewinds to the accept horizon (``pos``
    rewind — stale ring rows become invisible) and the draft re-absorbs
    the emitted tokens from its round-start snapshot, so both caches
    agree on the context entering the next round.

    The fault lane mirrors ``_macro_decode``: per-slot finiteness of both
    pools and of the verifier logits, checked on device, zero extra host
    syncs. A faulted slot emits nothing for the round (its verifier
    rewinds to the round start, its draft keeps the snapshot) and is
    flagged in the fault plane for host quarantine.

    Returns ``(draft_pool, pool, toks, em, flt, acc)`` with token/emitted/
    fault buffers shaped (K, gamma+1, S) — the host replays them row-major
    exactly like the (K, S) macro buffers — and ``acc`` (K, S) int32: the
    per-round accepted-draft count, or -1 where the slot ran no round
    (drained or faulted), for the draft_acceptance_rate accounting.
    """
    G = gamma
    S = last_tok.shape[0]

    def round_(carry, _):
        dpool, vpool, last_tok, act, gen = carry

        # (1) draft phase: G masked decode steps on the linear pool.
        def draft_step(c, j):
            dp, tok = c
            logits, dp = api.decode_step(params, draft_cfg, dp,
                                         tok[:, None], act)
            row = logits[:, -1, :]
            nxt = draft_sample(row, rids, gen + j, temperature=temperature,
                               seed=seed)
            nxt = jnp.where(act, nxt, tok)
            return (dp, nxt), (nxt, row)

        (dp_end, _), (drafts, q_rows) = jax.lax.scan(
            draft_step, (dpool, last_tok), jnp.arange(G))

        # (2) verify phase: one exact chunk over [last_tok, d_1..d_G].
        vt = jnp.concatenate([last_tok[None, :], drafts], axis=0).T
        p_logits, vp_adv = api.verify_chunk(cfg, params, vpool, vt,
                                            active=act)

        # (3) acceptance + correction, position by position (vmapped —
        # each position has its own token index, hence its own keys).
        def acc_one(p_row, q_row, d, j):
            return accept_and_correct(p_row, q_row, d, rids, gen + j,
                                      temperature=temperature, seed=seed)

        accs, corr = jax.vmap(acc_one, in_axes=(1, 0, 0, 0))(
            p_logits[:, :G], q_rows, drafts, jnp.arange(G))
        # Bonus token: the untagged base stream — the draw plain decode
        # would make at this index (greedy: the verifier argmax).
        bonus = sampling.sample_tokens(p_logits[:, G, :], rids, gen + G,
                                       temperature=temperature, seed=seed)
        jj = jnp.arange(G + 1)[:, None]                         # (G+1, 1)
        a = jnp.sum(jnp.cumprod(accs.astype(jnp.int32), 0), 0)  # (S,)
        cand = jnp.concatenate([corr, bonus[None, :]], axis=0)  # (G+1, S)
        dpad = jnp.concatenate(
            [drafts, jnp.zeros((1, S), jnp.int32)], axis=0)
        e = jnp.where(jj < a[None, :], dpad, cand)              # (G+1, S)
        emit = (jj <= a[None, :]) & act[None, :]
        gen_j = gen[None, :] + jj + 1
        hitj = emit & sampling.stop_hit(e, gen_j, eos_ids[None, :],
                                        max_new[None, :])
        cs = jnp.cumsum(hitj.astype(jnp.int32), axis=0)
        emit = emit & ((cs - hitj.astype(jnp.int32)) == 0)

        # Fault lane: both pools' fresh state + the verifier logits.
        if fault_guard:
            ok = (api.slot_state_finite(cfg, vp_adv)
                  & api.slot_state_finite(draft_cfg, dp_end)
                  & jnp.all(jnp.isfinite(p_logits.astype(jnp.float32)),
                            axis=(1, 2)))
            faulted = act & jnp.logical_not(ok)
        else:
            ok = jnp.ones_like(act)
            faulted = jnp.zeros_like(act)
        emit = emit & ok[None, :]
        m = jnp.sum(emit.astype(jnp.int32), axis=0)             # (S,)
        stopped = jnp.any(hitj & emit, axis=0)

        # (4a) verifier rollback to the accept horizon: keep exactly the
        # absorbed context [last_tok, e_1..e_{m-1}] — by construction the
        # kept ring rows hold the right inputs (e_j = d_j on the accepted
        # prefix), so only ``pos`` moves.
        pos0 = api.slot_positions(cfg, vpool)
        vp_new = api.rollback_slots(cfg, vp_adv, pos0 + m)

        # (4b) draft resync from the round-start snapshot: absorb the
        # same m inputs, masked per slot per step — covers every case up
        # to full-accept-plus-bonus (m = G+1 inputs: last_tok, e_1..e_G).
        sync_in = jnp.concatenate([last_tok[None, :], e[:-1]], axis=0)
        step_act = jj < m[None, :]

        def sync_step(dp, xs):
            inp, sa = xs
            _, dp = api.decode_step(params, draft_cfg, dp, inp[:, None], sa)
            return dp, None

        dp_new, _ = jax.lax.scan(sync_step, dpool, (sync_in, step_act))

        e_out = jnp.where(emit, e, last_tok[None, :])
        last2 = jnp.take_along_axis(
            e_out, jnp.maximum(m - 1, 0)[None, :], axis=0)[0]
        last_new = jnp.where(m > 0, last2, last_tok)
        gen_new = gen + m
        act_new = act & ok & jnp.logical_not(stopped)
        acc_out = jnp.where(act & ok, a, -1)
        flt = jnp.zeros_like(emit).at[0].set(faulted)
        return ((dp_new, vp_new, last_new, act_new, gen_new),
                (e_out, emit, flt, acc_out))

    (dpool, vpool, _, _, _), (toks, em, flt, acc) = jax.lax.scan(
        round_, (draft_pool, pool, last_tok, active, gen), None,
        length=num_rounds)
    return dpool, vpool, toks, em, flt, acc
