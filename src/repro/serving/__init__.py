"""Serving: lockstep + continuous-batching engines over KV-cache or
constant-state decode paths, with a typed fault-tolerant request
lifecycle (deadlines, cancellation, load-shedding, NaN quarantine —
DESIGN.md §10), paged slot memory + a content-addressed prefix cache
(DESIGN.md §11), crash-safe durability (write-ahead journal + atomic
checkpoints + byte-identical restore — DESIGN.md §12), and a
deterministic chaos harness."""
from repro.serving.checkpoint import CheckpointError  # noqa: F401
from repro.serving.engine import (AdmissionError,  # noqa: F401
                                  ContinuousServingEngine, EngineMetrics,
                                  QueueFullError, Request,
                                  RequestTooLargeError, Scheduler,
                                  ServingEngine, ServingMetrics,
                                  jit_serve_fns)
from repro.serving.faults import EngineCrash, FaultInjector  # noqa: F401
from repro.serving.journal import Journal, JournalState  # noqa: F401
from repro.serving.pages import PagePool, PageState  # noqa: F401
from repro.serving.prefix_cache import (PrefixCache,  # noqa: F401
                                        PrefixEntry)
from repro.serving.sampling import (FINISH_REASONS,  # noqa: F401
                                    STREAM_KEY_VERSION)
