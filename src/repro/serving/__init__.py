"""Serving: lockstep + continuous-batching engines over KV-cache or
constant-state decode paths."""
from repro.serving.engine import (ContinuousServingEngine,  # noqa: F401
                                  EngineMetrics, Request, Scheduler,
                                  ServingEngine, ServingMetrics,
                                  jit_serve_fns)
