"""Serving: batched prefill + decode over KV-cache or constant-state paths."""
from repro.serving.engine import ServingEngine, jit_serve_fns  # noqa
