"""Serving engines: continuous batching over a slot-pooled decode cache.

Two engines share one model surface (``repro.models.api``):

* :class:`ServingEngine` — the lockstep reference: one prefill per batch,
  then decode steps in lockstep until every request finishes. Simple,
  exact, and the parity oracle for the continuous engine.
* :class:`ContinuousServingEngine` — the production shape: a
  :class:`Scheduler` owns a fixed pool of ``num_slots`` decode slots;
  requests queue, are admitted into free slots via *chunked prefill*
  (interleaved with decode ticks so long prompts never stall the pool),
  stream tokens per request, and on EOS/max-tokens are evicted by a single
  slot overwrite.

Why continuous batching is dramatically simpler for SLAY than for KV-cache
models: the constant-state path's per-slot decode state is O(m·dv) per
layer-head *regardless of context length*, so admitting a new request is a
single ``write_slot`` overwrite of a fixed-size block and evicting is a
``reset_slot`` zero — no paged KV allocator needed, no fragmentation, no
copy-out. The KV path rides the same surface with ring-buffer slot resets;
with ``ServingConfig.page_size`` set, its rings additionally draw physical
pages from a shared :class:`repro.serving.pages.PagePool` (DESIGN.md §11)
so short and long requests share HBM — constant-state kinds bypass paging
(their state is O(1), the paper's serving asymmetry). A
``prefix_cache_bytes`` budget enables the content-addressed prefix cache
(``repro.serving.prefix_cache``): admissions whose prompt shares a cached
prefix seed their slot from a stored state snapshot and chunk-prefill only
the suffix.

Cache shardings come from ``sharding.serving_cache_sharding`` and depend
only on pool shape — never on which slots are live — so admission/eviction
never reshard (slot-stable contract).

Fault model (DESIGN.md §10): every request terminates with exactly one
``finish_reason`` from ``sampling.FINISH_REASONS``. Admission failures are
typed (:class:`AdmissionError` and subclasses) and overload degrades per
``ServingConfig.overload_policy`` instead of throwing; requests carry
tick- and wall-clock deadlines and can be cancelled anywhere in their
lifecycle (queued, mid-prefill, slot-resident, even mid-macro-step); a
per-slot NaN/Inf lane inside the jitted macro-step detects numeric faults
and the host replay quarantines + retries them. ``serving.faults`` holds
the deterministic chaos injector that exercises all of it.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import os
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ServingConfig
from repro.distributed import sharding as shd
from repro.models import api
from repro.serving import checkpoint as checkpoint_lib
from repro.serving import faults as faults_lib
from repro.serving import journal as journal_lib
from repro.serving import pages as pages_lib
from repro.serving import prefix_cache as prefix_lib
from repro.serving import sampling
from repro.serving import speculative


def jit_serve_fns(cfg: ArchConfig, mesh, max_len: int,
                  rules: shd.ShardingRules = shd.DEFAULT_RULES,
                  batch: int | None = None):
    """jit'd (prefill, decode_step) with rule-derived shardings — the
    lockstep engine's entry points.

    decode_step donates the cache (in-place ring-buffer update on device).
    When ``batch`` is given the cache sharding comes from
    ``sharding.serving_cache_sharding``, which shards the batch (slot) dim
    over the ``data`` mesh axis under the same slot-stable contract as the
    continuous engine's pool (DESIGN.md §8): shardings derive from shapes
    only, so in- and out-shardings agree and decode never reshards.
    """
    axes = api.param_axes(cfg)
    p_abs = api.abstract_params(cfg)
    p_sh = shd.logical_to_sharding(mesh, rules, p_abs, axes)
    b_sh = shd.batch_sharding(mesh, rules)

    def _prefill(params, batch_):
        with shd.activation_sharding(mesh, rules):
            return api.prefill(params, cfg, batch_, max_len=max_len)

    pf = jax.jit(_prefill, in_shardings=(p_sh, b_sh), out_shardings=None)
    if batch is not None:
        c_abs = api.abstract_cache(cfg, batch, max_len)
        c_sh = shd.serving_cache_sharding(mesh, rules, c_abs)
    else:
        c_sh = None
    dec = jax.jit(
        lambda params, cache, tok: api.decode_step(params, cfg, cache, tok),
        in_shardings=(p_sh, c_sh, b_sh) if c_sh is not None else None,
        out_shardings=(b_sh, c_sh) if c_sh is not None else None,
        donate_argnums=(1,))
    return pf, dec


class AdmissionError(RuntimeError):
    """Typed admission failure. ``queue_depth``/``max_queue`` let callers
    report or back off instead of parsing a message (DESIGN.md §10)."""

    def __init__(self, msg: str, *, queue_depth: int = 0,
                 max_queue: int = 0):
        super().__init__(msg)
        self.queue_depth = queue_depth
        self.max_queue = max_queue


class QueueFullError(AdmissionError):
    """Admission queue at ``max_queue`` under the ``reject_new`` overload
    policy. The request was NOT enqueued — the caller keeps it."""


class RequestTooLargeError(AdmissionError, ValueError):
    """prefix + prompt + max_new_tokens exceeds the slot's ``max_len``
    context ring. Also a ValueError (the pre-§10 type, kept for callers)."""


@dataclasses.dataclass
class Request:
    """One generation request.

    Deadlines (all optional, checked every tick — DESIGN.md §10): the
    ``*_ticks`` forms are measured from ``arrival_time`` on the engine's
    logical clock (backend-independent, what tests/benches use); the
    ``*_s`` forms are wall-clock from submission. ``ttft_*`` bounds time
    to the first emitted token only; ``deadline_*`` bounds the whole
    request. A deadline expiring on the same tick as a natural stop loses
    — the emission is processed first, so EOS wins. ``on_finish`` fires
    exactly once per request with its ``finish_reason``
    (``sampling.FINISH_REASONS``); on a fault retry ``on_token`` replays
    the stream from index 0 (deterministic sampling regenerates the same
    prefix when the fault was transient).
    """

    prompt: np.ndarray               # (Lp,) int32
    max_new_tokens: int = 32
    eos_id: int = -1                 # -1: never stop early
    arrival_time: float = 0.0        # engine ticks (continuous engine only)
    on_token: Callable[[int, int], None] | None = None  # (rid, token)
    ttft_deadline_ticks: float | None = None   # first token by arrival + T
    deadline_ticks: float | None = None        # finished by arrival + T
    ttft_deadline_s: float | None = None       # wall-clock equivalents,
    deadline_s: float | None = None            # measured from submit()
    on_finish: Callable[[int, str], None] | None = None  # (rid, reason)

    def __post_init__(self):
        # Fail at construction with an actionable message, not mid-decode
        # with a shape error or a silent never-terminating slot.
        if np.asarray(self.prompt).size == 0:
            raise ValueError("empty prompt: a request must carry at least "
                             "one prompt token")
        if self.max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got "
                             f"{self.max_new_tokens}")
        if not np.isfinite(self.arrival_time) or self.arrival_time < 0:
            raise ValueError(f"arrival_time must be finite and >= 0, got "
                             f"{self.arrival_time!r}")
        for name in ("ttft_deadline_ticks", "deadline_ticks",
                     "ttft_deadline_s", "deadline_s"):
            v = getattr(self, name)
            if v is not None and (not np.isfinite(v) or v <= 0):
                raise ValueError(f"{name} must be finite and > 0 when "
                                 f"set, got {v!r}")


def _model_batch(cfg: ArchConfig, tokens: jnp.ndarray) -> dict:
    """Token batch plus zero frontend stand-ins (vision/audio stubs)."""
    batch = {"tokens": tokens}
    B = tokens.shape[0]
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.zeros(
            (B, cfg.num_patches, cfg.d_model), cfg.activation_dtype)
    if cfg.frontend == "audio":
        batch["frame_embeds"] = jnp.zeros(
            (B, cfg.enc_seq, cfg.d_model), cfg.activation_dtype)
    return batch


class ServingEngine:
    """Lockstep reference engine (parity oracle for the continuous path).

    NOTE: batched generate left-pads prompts to a common length, so with
    mixed prompt lengths the pad tokens are visible to the model (seed
    behavior, kept for the oracle). For exact per-request results, call
    with a single request — the continuous engine's parity tests do.
    """

    def __init__(self, cfg: ArchConfig, params, mesh, *, max_len: int = 4096,
                 rules: shd.ShardingRules = shd.DEFAULT_RULES):
        self.cfg, self.params, self.mesh = cfg, params, mesh
        self.max_len = max_len
        self.prefill_fn, self.decode_fn = jit_serve_fns(cfg, mesh, max_len,
                                                        rules)

    def generate(self, requests: list[Request], *,
                 temperature: float = 0.0, seed: int = 0) -> list[np.ndarray]:
        """Run a batch of requests to completion.

        Returns one int32 array per request, of the *actual* generated
        length: up to and including the EOS token when ``eos_id`` fires,
        ``max_new_tokens`` otherwise (no trailing zero padding).
        """
        cfg = self.cfg
        B = len(requests)
        lp = max(len(r.prompt) for r in requests)
        over = max(lp + r.max_new_tokens for r in requests)
        if over > self.max_len:
            # Non-windowed KV rings would silently truncate the context.
            raise ValueError(f"prompt+max_new ({over}) exceeds "
                             f"max_len {self.max_len}")
        # Left-pad prompts to a common length (pad id 0).
        prompts = np.zeros((B, lp), np.int32)
        for i, r in enumerate(requests):
            prompts[i, lp - len(r.prompt):] = r.prompt
        batch = _model_batch(cfg, jnp.asarray(prompts))
        with self.mesh:
            logits, cache = self.prefill_fn(self.params, batch)
            key = jax.random.PRNGKey(seed)
            max_new = max(r.max_new_tokens for r in requests)
            out = np.zeros((B, max_new), np.int32)
            lengths = np.zeros(B, np.int64)
            done = np.zeros(B, bool)
            tok = self._sample(logits, temperature, key)
            for t in range(max_new):
                tok_np = np.asarray(tok[:, 0])
                for i, r in enumerate(requests):
                    if done[i]:
                        continue
                    out[i, t] = tok_np[i]
                    lengths[i] += 1
                    if (t + 1 >= r.max_new_tokens
                            or int(tok_np[i]) == r.eos_id):
                        done[i] = True
                if done.all():
                    break
                key, sub = jax.random.split(key)
                logits, cache = self.decode_fn(self.params, cache, tok)
                tok = self._sample(logits, temperature, sub)
        return [out[i, :lengths[i]] for i in range(B)]

    @staticmethod
    def _sample(logits, temperature: float, key) -> jnp.ndarray:
        logits = logits[:, -1, :]
        if temperature <= 0.0:
            return jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        g = jax.random.categorical(key, logits / temperature)
        return g.astype(jnp.int32)[:, None]


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------


def _macro_decode(params, cache, last_tok, active, rids, gen, eos_ids,
                  max_new, *, cfg: ArchConfig, num_ticks: int,
                  temperature: float, seed: int, fault_guard: bool = True):
    """K decode ticks as one jitted ``lax.scan`` over the slot pool.

    The serving decode hot loop, fully device-resident: per tick the pool
    runs one masked ``api.decode_step`` (drained slots are an exact state
    passthrough), sampling happens on device keyed per (seed, rid,
    token-index), and a slot that hits EOS or its ``max_new`` budget
    mid-macro-step is masked for the remaining ticks. The host receives
    only the (K, S) int32 token buffer plus (K, S) emitted and fault
    flags — one sync per K ticks instead of an (S, vocab) logits pull
    per token.

    Fault lane (DESIGN.md §10, ``fault_guard``): after each tick's
    ``decode_step`` the per-slot finiteness of the freshly written decode
    state AND the logits row is checked on device. A non-finite slot does
    not emit (its sampled token is garbage), is masked from the remaining
    ticks exactly like an EOS hit, and is flagged in the (K, S) fault
    plane — which rides the token-buffer pull the host already does, so
    detection costs zero extra host syncs and ``host_syncs_per_token``
    stays <= 1/K. Both checks reduce per slot only (shard-local under a
    slot-sharded pool): no collectives enter the §8 decode contract.

    last_tok/active/rids/gen/eos_ids/max_new are (S,) vectors; ``gen``
    counts tokens already emitted per slot (the prefill-sampled first
    token included), which is exactly the sampling ``idx`` of the *next*
    token — so the stream is byte-identical for every K.
    """
    def tick(carry, _):
        cache, last_tok, active, gen = carry
        logits, cache = api.decode_step(params, cfg, cache,
                                        last_tok[:, None], active)
        row = logits[:, -1, :]
        tok = sampling.sample_tokens(row, rids, gen,
                                     temperature=temperature, seed=seed)
        if fault_guard:
            ok = api.slot_state_finite(cfg, cache) & jnp.all(
                jnp.isfinite(row.astype(jnp.float32)), axis=-1)
            faulted = active & jnp.logical_not(ok)
        else:
            ok = jnp.ones_like(active)
            faulted = jnp.zeros_like(active)
        emitted = active & ok
        tok = jnp.where(emitted, tok, last_tok)
        gen = gen + emitted.astype(jnp.int32)
        hit = emitted & sampling.stop_hit(tok, gen, eos_ids, max_new)
        active = emitted & jnp.logical_not(hit)
        return (cache, tok, active, gen), (tok, emitted, faulted)

    (cache, _, _, _), (toks, em, flt) = jax.lax.scan(
        tick, (cache, last_tok, active, gen), None, length=num_ticks)
    return cache, toks, em, flt


def _bucket_len(n: int, lo: int, cap: int) -> int:
    """Smallest pow-2 >= max(n, lo), capped at ``cap`` (>= n always)."""
    b = lo
    while b < n:
        b *= 2
    return min(b, cap) if cap >= n else n


@dataclasses.dataclass
class RequestStats:
    rid: int
    arrival: float                   # ticks
    prompt_len: int = 0
    slot: int | None = None          # pool slot served in (last, if retried)
    admitted: float | None = None    # prefill started
    first_token: float | None = None
    finished: float | None = None
    first_token_wall: float | None = None
    arrival_wall: float | None = None
    finish_reason: str | None = None  # sampling.FINISH_REASONS; None = live
    retries: int = 0                 # fault-quarantine re-admissions so far
    prefix_cached: bool = False      # seeded from the prefix cache (§11)
    prefix_tokens: int = 0           # prompt tokens reused from a snapshot

    @property
    def ttft_ticks(self) -> float | None:
        """Ticks to first token — None until one is emitted (a request
        cancelled/shed/expired pre-emission has no TTFT, by design: it
        must drop out of the percentiles rather than read as 0)."""
        if self.first_token is None:
            return None
        return self.first_token - self.arrival

    @property
    def ttft_s(self) -> float | None:
        if self.first_token_wall is None or self.arrival_wall is None:
            return None
        return self.first_token_wall - self.arrival_wall


@dataclasses.dataclass
class ServingMetrics:
    """Counters the engine updates every tick; ``summary()`` aggregates.

    Units: *ticks* are the engine's logical clock (one scheduling decision
    = one tick; backend-independent, what CI trends on); *wall* is host
    ``time.perf_counter()`` seconds (meaningful on TPU only). Counters are
    per engine lifetime unless noted.
    """

    num_slots: int = 0          # pool size the engine was built with (slots)
    macro_ticks: int = 1        # K: decode ticks per jitted dispatch (ticks)
    slot_shards: int = 1        # data-axis pool shards in effect (count)
    ticks: int = 0              # engine clock: scheduling decisions (ticks)
    decode_ticks: int = 0       # ticks that ran a pool decode step (ticks)
    prefill_ticks: int = 0      # ticks that ran a prefill chunk (ticks)
    tokens_generated: int = 0   # decode tokens emitted to requests (tokens)
    prompt_tokens: int = 0      # prompt tokens absorbed by prefill (tokens)
    requests_completed: int = 0  # requests finished (EOS or budget) (count)
    queue_depth_sum: int = 0    # sum of ready-queue depth per tick (req*ticks)
    queue_depth_max: int = 0    # peak ready-queue depth (requests)
    occupancy_sum: int = 0      # sum of live slots per tick (slots*ticks)
    # Hot-loop sync cadence. decode_dispatches counts jitted macro-step
    # calls (one per K decode ticks, whole pool — never per slot or per
    # shard); host_syncs counts blocking device->host pulls in the decode
    # loop (the (K, S) token buffer, one per dispatch). Prefill first-token
    # pulls are tracked separately (prefill_token_syncs): they are one
    # int32 scalar per admitted request, off the per-token hot loop.
    decode_dispatches: int = 0  # jitted K-tick macro-step calls (count)
    host_syncs: int = 0         # blocking device->host pulls, decode (count)
    prefill_token_syncs: int = 0  # first-token scalar pulls at admit (count)
    bucket_hits: int = 0        # fallback prefill reusing a bucket (count)
    bucket_misses: int = 0      # first compile of a bucket length (count)
    # Fault-tolerance counters (DESIGN.md §10). requests_terminated counts
    # EVERY terminal request (any finish_reason); requests_completed stays
    # the successful subset (eos | length). finish_reasons is the per-
    # reason breakdown; fault_events records each quarantine as
    # {"rid", "slot", "tick"} (the chaos harness joins these against its
    # injection log to measure detection latency).
    requests_terminated: int = 0   # requests reaching any terminal state
    finish_reasons: dict = dataclasses.field(  # reason -> count
        default_factory=dict)
    faults_detected: int = 0    # non-finite slots quarantined (count)
    fault_retries: int = 0      # re-admissions after a quarantine (count)
    fault_retries_succeeded: int = 0  # retried requests ending eos|length
    # Prefix-cache + paged-pool instrumentation (DESIGN.md §11). The page
    # gauges mirror the host allocator; 0 everywhere when unpaged.
    prefix_hits: int = 0        # admissions seeded from the prefix cache
    prefix_tokens_reused: int = 0  # prompt tokens skipped via snapshots
    num_pages: int = 0          # paged-pool size in pages (0 = unpaged)
    pages_in_use: int = 0       # gauge: pages currently allocated
    pages_peak: int = 0         # high-water mark of pages_in_use
    fault_events: list = dataclasses.field(  # per-quarantine records
        default_factory=list)
    # Durability counters (DESIGN.md §12). tokens_replayed counts post-
    # restore tokens that were regenerated on device but deduplicated
    # against the journal (verified byte-equal, not re-delivered);
    # checkpoints_written counts atomic engine checkpoints.
    tokens_replayed: int = 0    # journal-deduped regenerated tokens (count)
    checkpoints_written: int = 0  # atomic checkpoints written (count)
    # Speculative decoding counters (DESIGN.md §13). Proposed counts every
    # draft token offered to the verifier in a counted (non-faulted, slot-
    # active) round; accepted counts those that survived the accept test.
    # Emitted tokens exceed accepted ones — each round also emits a
    # corrected-or-bonus token — which is why tokens_per_dispatch can beat
    # macro_ticks even at acceptance < 1.
    speculative: bool = False   # engine is in draft-verify mode
    spec_gamma: int = 0         # draft tokens per round (0 = non-spec)
    draft_tokens_proposed: int = 0  # draft tokens offered to the verifier
    draft_tokens_accepted: int = 0  # draft tokens accepted
    # Injectable time source (satellite of DESIGN.md §12): every wall-
    # clock read in the engine goes through this, so deadline tests use a
    # fake clock and journal timestamps are replayable.
    clock: Callable[[], float] = time.perf_counter
    wall_start: float | None = None  # engine construction time (wall)
    per_request: dict = dataclasses.field(  # rid -> RequestStats
        default_factory=dict)

    def __post_init__(self):
        if self.wall_start is None:
            self.wall_start = self.clock()

    def sample(self, queue_depth: int, occupancy: int):
        self.queue_depth_sum += queue_depth
        self.queue_depth_max = max(self.queue_depth_max, queue_depth)
        self.occupancy_sum += occupancy

    def summary(self) -> dict:
        wall = max(self.clock() - self.wall_start, 1e-9)
        ttfts = sorted(s.ttft_ticks for s in self.per_request.values()
                       if s.ttft_ticks is not None)
        ttfts_s = sorted(s.ttft_s for s in self.per_request.values()
                         if s.ttft_s is not None)
        # Split TTFT by prefix-cache seeding — the §11 win the bench
        # contract asserts on (cached admissions skip prefill work).
        ttfts_c = sorted(s.ttft_ticks for s in self.per_request.values()
                         if s.ttft_ticks is not None and s.prefix_cached)
        ttfts_w = sorted(s.ttft_ticks for s in self.per_request.values()
                         if s.ttft_ticks is not None and not s.prefix_cached)

        def pct(xs, q):
            return xs[min(int(q * len(xs)), len(xs) - 1)] if xs else None

        t = max(self.ticks, 1)
        return {
            "ticks": self.ticks,
            "decode_ticks": self.decode_ticks,
            "prefill_ticks": self.prefill_ticks,
            "requests_completed": self.requests_completed,
            "tokens_generated": self.tokens_generated,
            "prompt_tokens": self.prompt_tokens,
            "macro_ticks": self.macro_ticks,
            "slot_shards": self.slot_shards,
            "decode_dispatches": self.decode_dispatches,
            "host_syncs": self.host_syncs,
            "prefill_token_syncs": self.prefill_token_syncs,
            "host_syncs_per_token":
                self.host_syncs / max(self.tokens_generated, 1),
            "tokens_per_dispatch":
                self.tokens_generated / max(self.decode_dispatches, 1),
            "dispatches_per_decode_tick":
                self.decode_dispatches / max(self.decode_ticks, 1),
            "bucket_hits": self.bucket_hits,
            "bucket_misses": self.bucket_misses,
            "requests_terminated": self.requests_terminated,
            "finish_reasons": dict(self.finish_reasons),
            # Degraded-mode rates are over terminated requests (0.0 when
            # nothing terminated yet — never a division by zero, even for
            # a run whose every request was cancelled before emitting).
            "shed_rate": self.finish_reasons.get("shed", 0)
            / max(self.requests_terminated, 1),
            "deadline_miss_rate": self.finish_reasons.get("deadline", 0)
            / max(self.requests_terminated, 1),
            "faults_detected": self.faults_detected,
            "fault_retries": self.fault_retries,
            "fault_retries_succeeded": self.fault_retries_succeeded,
            "tokens_replayed": self.tokens_replayed,
            "checkpoints_written": self.checkpoints_written,
            "wall_s": wall,
            "decode_tokens_per_s": self.tokens_generated / wall,
            "total_tokens_per_s":
                (self.tokens_generated + self.prompt_tokens) / wall,
            "mean_queue_depth": self.queue_depth_sum / t,
            "max_queue_depth": self.queue_depth_max,
            "mean_slot_occupancy":
                self.occupancy_sum / (t * max(self.num_slots, 1)),
            "ttft_ticks_p50": pct(ttfts, 0.50),
            "ttft_ticks_p95": pct(ttfts, 0.95),
            "ttft_s_p50": pct(ttfts_s, 0.50),
            "ttft_s_p95": pct(ttfts_s, 0.95),
            "prefix_hits": self.prefix_hits,
            "prefix_tokens_reused": self.prefix_tokens_reused,
            "ttft_cached_ticks_p50": pct(ttfts_c, 0.50),
            "ttft_cached_ticks_p95": pct(ttfts_c, 0.95),
            "ttft_cold_ticks_p50": pct(ttfts_w, 0.50),
            "ttft_cold_ticks_p95": pct(ttfts_w, 0.95),
            "num_pages": self.num_pages,
            "pages_in_use": self.pages_in_use,
            "pages_peak": self.pages_peak,
            "speculative": self.speculative,
            "spec_gamma": self.spec_gamma,
            "draft_tokens_proposed": self.draft_tokens_proposed,
            "draft_tokens_accepted": self.draft_tokens_accepted,
            "draft_acceptance_rate": self.draft_tokens_accepted
            / max(self.draft_tokens_proposed, 1),
        }


EngineMetrics = ServingMetrics   # pre-§8 name, kept for callers


@dataclasses.dataclass
class _Slot:
    """One live sequence in the decode pool."""

    rid: int
    req: Request
    last_tok: int
    tokens: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _Prefill:
    """An admission in flight: prompt being absorbed chunk-by-chunk."""

    rid: int
    req: Request
    slot: int
    cache: object                    # per-request (batch=1) decode cache
    offset: int = 0                  # prompt tokens absorbed so far
    prefix_offset: int = 0           # pre-embedded frontend rows absorbed
    logits: object | None = None     # (1, 1, V) — full prefix-cache hit
    draft: object | None = None      # batch=1 draft cache (speculative mode)


class Scheduler:
    """Owns the slot pool and the admission queue.

    Policy: FIFO admission order; the *slot* a request lands in is chosen
    shard-aware — the free slot whose data shard currently serves the
    fewest live requests (ties break to the lowest slot id, which with a
    single shard reduces to the pre-§8 lowest-free-slot policy). At most
    one prefill is in flight (chunked, so a long prompt yields to decode
    ticks between chunks); decode and prefill strictly interleave per
    ``decode_ticks_per_prefill`` when both have work.

    Shard awareness: slot->shard ownership is *static* — with S slots over
    N shards, shard k owns the contiguous block [k*S/N, (k+1)*S/N), the
    same split GSPMD applies to the slot-sharded pool cache — so admission
    and eviction never migrate state across shards, only overwrite
    shard-local slot blocks. Balancing admissions across shards keeps
    every data shard's masked decode work even under partial load. Token
    streams never depend on the slot (or shard) chosen: sampling is keyed
    on (seed, rid, token-index) only.
    """

    def __init__(self, serving: ServingConfig, slot_shards: int = 1):
        self.serving = serving
        self.slot_shards = max(slot_shards, 1)
        self.slots_per_shard = serving.num_slots // self.slot_shards
        self.free: list[int] = list(range(serving.num_slots))
        self.active: dict[int, _Slot] = {}
        self.waiting: collections.deque = collections.deque()  # (rid, req)
        self.ready: collections.deque = collections.deque()
        self._decode_since_prefill = serving.decode_ticks_per_prefill

    def shard_of(self, slot: int) -> int:
        """Static owner shard of ``slot`` (GSPMD contiguous-block split)."""
        return slot // self.slots_per_shard

    def submit(self, rid: int, req: Request) -> list[tuple[int, "Request"]]:
        """Enqueue a request; returns the (rid, req) pairs shed to make
        room (``shed_oldest`` policy — the engine terminates them with
        ``finish_reason="shed"``).

        Overload behavior when the queue sits at ``max_queue``
        (DESIGN.md §10): ``reject_new`` raises :class:`QueueFullError`
        with the depth spelled out (nothing is mutated — the caller keeps
        the request); ``shed_oldest`` drops the longest-waiting queued
        request; ``queue_wait`` admits unconditionally and relies on the
        engine's queue-age sweep to shed stale requests instead."""
        shed: list[tuple[int, Request]] = []
        depth = len(self.waiting) + len(self.ready)
        if self.serving.max_queue and depth >= self.serving.max_queue:
            policy = self.serving.overload_policy
            if policy == "reject_new":
                raise QueueFullError(
                    f"admission queue full: {depth} queued >= max_queue "
                    f"{self.serving.max_queue} (overload_policy="
                    f"'reject_new'; retry later, or configure "
                    f"'shed_oldest' / 'queue_wait' to degrade instead)",
                    queue_depth=depth, max_queue=self.serving.max_queue)
            if policy == "shed_oldest":
                victim = self.pop_oldest()
                if victim is not None:
                    shed.append(victim)
            # queue_wait: admit; the age sweep sheds laggards by deadline.
        self.waiting.append((rid, req))
        # Keep ordered by (arrival, rid) so a late submission with an
        # earlier arrival_time cannot be head-of-line blocked.
        self.waiting = collections.deque(
            sorted(self.waiting, key=lambda t: (t[1].arrival_time, t[0])))
        return shed

    def pop_oldest(self) -> tuple[int, Request] | None:
        """Remove and return the longest-waiting queued request — ready
        queue first (already arrived, FIFO head is oldest), else the
        earliest-arriving waiting entry. None if nothing is queued."""
        if self.ready:
            return self.ready.popleft()
        if self.waiting:
            return self.waiting.popleft()
        return None

    def cancel(self, rid: int) -> Request | None:
        """Remove a still-queued request (ready or waiting); returns its
        Request, or None if ``rid`` is not queued here (it may be in a
        slot, mid-prefill, or already terminal — the engine checks)."""
        for q in (self.ready, self.waiting):
            for item in q:
                if item[0] == rid:
                    q.remove(item)
                    return item[1]
        return None

    def poll_arrivals(self, now: float):
        while self.waiting and self.waiting[0][1].arrival_time <= now:
            self.ready.append(self.waiting.popleft())

    def next_admission(self, slot_ok=None):
        """Pop the request to admit next, reserving a slot — or None.

        The slot comes from the least-loaded shard (see class docstring);
        request order itself stays strictly FIFO. ``slot_ok(slot, req)``
        further filters candidate slots (the paged pool gates on its
        shard's free pages — DESIGN.md §11); when no slot qualifies the
        head request stays queued (head-of-line waits for pages to free,
        preserving FIFO admission order)."""
        if not self.ready or not self.free:
            return None
        rid, req = self.ready[0]
        cands = (self.free if slot_ok is None
                 else [s for s in self.free if slot_ok(s, req)])
        if not cands:
            return None
        self.ready.popleft()
        load = [0] * self.slot_shards
        for slot in self.active:
            load[self.shard_of(slot)] += 1
        slot = min(cands, key=lambda s: (load[self.shard_of(s)], s))
        self.free.remove(slot)
        return rid, req, slot

    def evict(self, slot: int):
        del self.active[slot]
        self.free.append(slot)
        self.free.sort()

    @property
    def queue_depth(self) -> int:
        return len(self.ready)

    @property
    def occupancy(self) -> int:
        return len(self.active)

    def want_prefill(self, prefill_inflight: bool) -> bool:
        """Interleave policy: prefill only after enough decode ticks, unless
        there is no decode work at all."""
        has_work = prefill_inflight or (bool(self.ready) and bool(self.free))
        if not has_work:
            return False
        if not self.active:
            return True
        return (self._decode_since_prefill
                >= self.serving.decode_ticks_per_prefill)

    def note_decode(self):
        self._decode_since_prefill += 1

    def note_prefill(self):
        self._decode_since_prefill = 0


class ContinuousServingEngine:
    """Continuous-batching engine over a fixed decode-slot pool.

    Usage::

        eng = ContinuousServingEngine(cfg, params, mesh,
                                      serving=ServingConfig(num_slots=4))
        rids = [eng.submit(r) for r in requests]
        outs, metrics = eng.run()          # rid -> np.ndarray of tokens

    or drive it tick-by-tick with :meth:`step` for external event loops.
    Time is a logical tick counter; request ``arrival_time`` is in ticks,
    letting benchmarks replay arrival traces deterministically on any
    backend. With ``macro_ticks`` K > 1 a decode dispatch covers K ticks:
    the host replays the returned (K, S) token buffer tick-by-tick so
    streaming callbacks, TTFT-in-ticks, queue-depth samples, and eviction
    all happen at exact per-tick granularity — only admission waits for a
    macro-step boundary (the K tradeoff; token streams are K-invariant).

    Compile-cache note: the decode hot loop is exactly one jitted
    macro-step entry. The chunked prefill path — every decoder-only
    config: all attention kinds *and* the ssm/hybrid scan-carry families
    (DESIGN.md §9) — compiles once per distinct chunk length (bounded by
    ``prefill_chunk``); the non-chunkable fallback (modality frontends)
    compiles once per pow-2 length *bucket* (right-padded, masked exactly
    via ``true_len``), except encdec which has no masked form and stays
    per-length. :meth:`jit_cache_entries` exposes the live counts (CI
    budgets them).

    Sharding (DESIGN.md §8): the slot pool — cache, control vectors, and
    the (K, S) token buffers — shards over the mesh ``data`` axis per
    ``serving.slot_shards``; slot->shard ownership is static and the
    decode macro-step contains no cross-shard collectives
    (:meth:`decode_hlo` exposes the compiled HLO the contract test greps).
    Params replicate over the slot axes (``sharding.serving_param_rules``).
    Token streams for a fixed trace are byte-identical across mesh shapes:
    sampling is keyed on (seed, rid, token-index), never on placement.
    """

    def __init__(self, cfg: ArchConfig, params, mesh, *,
                 serving: ServingConfig = ServingConfig(),
                 rules: shd.ShardingRules = shd.DEFAULT_RULES,
                 fault_injector=None, prefix_cache=None,
                 journal: journal_lib.Journal | None = None,
                 clock: Callable[[], float] = time.perf_counter):
        self.cfg, self.params, self.mesh = cfg, params, mesh
        self.serving = serving
        self.rules = rules
        # Injectable wall-clock source — every perf_counter read in the
        # engine and its metrics goes through this (fake clocks make the
        # wall-deadline tests deterministic; DESIGN.md §12 satellite).
        self._clock = clock
        # Chaos harness hook (serving.faults.FaultInjector) — test/bench
        # only; None in production. The engine consults it for slot
        # corruption, injected cancellations, and arrival delays.
        self._injector = fault_injector
        S, L = serving.num_slots, serving.max_len
        # Resolve the slot-pool sharding once (static for the engine's
        # lifetime): shard the pool over the `data` mesh axis per
        # serving.slot_shards, falling back to a replicated pool when
        # num_slots is not divisible (recorded like the rule-engine
        # divisibility fallback; surfaced in metrics/bench rows).
        self.slot_shard_fallbacks: list = []
        _, self.slot_shards = shd.pool_slot_axes(
            mesh, rules, S, serving.slot_shards,
            self.slot_shard_fallbacks)
        self.sched = Scheduler(serving, self.slot_shards)
        # Paged slot memory (DESIGN.md §11): only KV-ring kinds page —
        # constant-state (linear SLAY / SSM carry) decode state is O(1)
        # per slot, so a page_size request is a silent no-op for them.
        self._paged = bool(serving.page_size) and api.supports_paging(cfg)
        self.page_pool: pages_lib.PagePool | None = None
        if self._paged:
            lp = L // serving.page_size      # config validates divisibility
            num_pages = serving.num_pages or S * lp
            if num_pages % self.slot_shards:
                raise ValueError(
                    f"num_pages={num_pages} must divide evenly over "
                    f"{self.slot_shards} slot shards (the page dim shards "
                    f"in the same static blocks as the slot dim — §8)")
            self.page_pool = pages_lib.PagePool(
                S, num_pages, serving.page_size, lp,
                shards=self.slot_shards)
        # Content-addressed prefix cache (DESIGN.md §11): seeding relies
        # on chunked-prefill state continuation, so encdec (the one
        # non-chunkable family) never caches. A shared instance can be
        # passed in (warm-up engine populates, measured engine hits).
        self.prefix_cache = prefix_cache
        if self.prefix_cache is None and serving.prefix_cache_bytes:
            self.prefix_cache = prefix_lib.PrefixCache(
                serving.prefix_cache_bytes)
        if not api.supports_chunked_prefill(cfg):
            self.prefix_cache = None
        self._pfx_refs: dict[int, prefix_lib.PrefixEntry] = {}
        self.metrics = ServingMetrics(
            num_slots=serving.num_slots, macro_ticks=serving.macro_ticks,
            slot_shards=self.slot_shards,
            num_pages=self.page_pool.num_pages if self._paged else 0,
            clock=clock)
        self.tick = 0
        self._next_rid = 0
        self._outputs: dict[int, list] = {}
        self._prefill: _Prefill | None = None
        # Durability layer (DESIGN.md §12). With a journal attached, every
        # admission/token/termination is journaled (fsync once per engine
        # step — macro-step granularity, hot-loop cadence untouched) and
        # checkpoint_every_ticks > 0 adds periodic atomic checkpoints in
        # the journal's directory. ``_replay_until[rid]`` marks how many
        # tokens of a restored request are already journaled: regenerated
        # tokens below that index are verified byte-equal and deduped
        # instead of re-delivered.
        self.journal = journal
        self._ckpt_dir = (os.path.dirname(os.path.abspath(journal.path))
                          if journal is not None else None)
        self._last_ckpt_tick = 0
        self._replay_until: dict[int, int] = {}
        self.recovery: dict | None = None
        self._audit = serving.debug_audit or (
            os.environ.get("REPRO_DEBUG_AUDIT", "") not in ("", "0"))
        self._chunkable = api.supports_chunked_prefill(cfg)
        self._bucketable = (serving.prefill_buckets
                            and api.supports_masked_prefill(cfg))
        self._seen_buckets: set[int] = set()

        # Speculative decoding (DESIGN.md §13): the engine holds TWO slot
        # pools over one params pytree — the linear SLAY draft pool
        # (constant-state, never paged) and the exact verifier pool (the
        # ordinary `self.pool`, paged or not). Draft and verifier slots
        # move in lockstep: admission prefills and installs both, decode
        # runs spec rounds, eviction resets both.
        self._spec = bool(serving.speculative)
        self.draft_cfg: ArchConfig | None = None
        self.draft_pool = None
        if self._spec:
            if not api.supports_speculative(cfg):
                raise ValueError(
                    f"speculative decoding needs a verifier config with "
                    f"api.supports_speculative (a non-windowed exact "
                    f"quadratic attention kind); got attn_kind="
                    f"{cfg.attn_kind!r}, family={cfg.family!r}")
            self.draft_cfg = api.draft_config(cfg)
            params = api.ensure_draft_params(self.draft_cfg, params)
            self.params = params
            self.metrics.speculative = True
            self.metrics.spec_gamma = serving.spec_gamma
            # Mutually exclusive with the prefix cache (config validates
            # the byte-budget knob; a shared instance is dropped too): a
            # prefix snapshot seeds only the verifier ring — the draft
            # pool would have no matching state to seed from.
            self.prefix_cache = None

        # Param shapes/axes: in speculative mode the draft config's tree
        # is the superset (same transformer weights + the tiny `slay`
        # projection entry the verifier ignores), so it drives placement.
        axes_cfg = self.draft_cfg if self._spec else cfg
        axes = api.param_axes(axes_cfg)
        p_abs = api.abstract_params(axes_cfg)
        # Params replicate over the slot (data) axes at serving time —
        # FSDP-sharded weights would all-gather inside every decode tick
        # (DESIGN.md §8 zero-collective contract).
        p_sh = shd.logical_to_sharding(mesh, shd.serving_param_rules(rules),
                                       p_abs, axes)
        page_kw = dict(page_size=serving.page_size if self._paged else 0,
                       num_pages=(self.page_pool.num_pages
                                  if self._paged else 0),
                       shards=self.slot_shards)
        c_abs = api.abstract_cache(cfg, S, L, **page_kw)
        c_sh = shd.serving_cache_sharding(
            mesh, rules, c_abs, num_slots=S,
            slot_shards=serving.slot_shards,
            num_pages=self.page_pool.num_pages if self._paged else None)
        # Per-slot control vectors and the (K, S) token/emitted buffers
        # carry the same slot sharding as the pool cache.
        v_sh = shd.serving_vector_sharding(mesh, rules, num_slots=S,
                                           slot_shards=serving.slot_shards)
        buf_sh = shd.serving_vector_sharding(
            mesh, rules, num_slots=S, slot_shards=serving.slot_shards,
            leading=1)
        rep_sh = jax.sharding.NamedSharding(mesh,
                                            jax.sharding.PartitionSpec())
        self._abstract = (p_abs, c_abs)
        self._cache_sharding = c_sh   # restore() re-places checkpointed pools
        with mesh:
            self.pool = jax.device_put(api.init_cache(cfg, S, L, **page_kw),
                                       c_sh)
            self.params = jax.device_put(params, p_sh)
        # Host mirrors of the per-slot decode vectors fed to the jitted
        # macro-step. The replay loop applies the *same* emit/EOS/budget
        # logic as the device scan, so mirrors and device state never
        # diverge and nothing needs to be read back besides the token
        # buffer itself.
        self._last_tok = np.zeros(S, np.int32)
        self._active = np.zeros(S, bool)
        self._rids = np.zeros(S, np.int32)
        self._gen = np.zeros(S, np.int32)
        self._eos = np.full(S, -1, np.int32)
        self._maxn = np.zeros(S, np.int32)
        # The decode hot loop: one jitted K-tick macro-step for the whole
        # pool (donated cache, fused sampling, masked drained slots). Every
        # input/output carries the slot sharding, so the scan partitions
        # into independent per-shard slot blocks — no collectives (§8).
        self._macro_fn = jax.jit(
            functools.partial(_macro_decode, cfg=cfg,
                              num_ticks=serving.macro_ticks,
                              temperature=serving.temperature,
                              seed=serving.seed,
                              fault_guard=serving.fault_guard),
            in_shardings=(p_sh, c_sh) + (v_sh,) * 6,
            out_shardings=(c_sh, buf_sh, buf_sh, buf_sh),
            donate_argnums=(1,))
        # Speculative decode hot loop (§13): K draft-verify rounds per
        # dispatch over both pools, (K, gamma+1, S) token/emitted/fault
        # buffers plus a (K, S) accepted-count plane — still one host
        # pull per dispatch, same zero-collective slot partitioning.
        self._draft_sharding = None
        self._spec_fn = None
        if self._spec:
            d_abs = api.abstract_cache(self.draft_cfg, S, L)
            d_sh = shd.serving_cache_sharding(
                mesh, rules, d_abs, num_slots=S,
                slot_shards=serving.slot_shards)
            self._draft_sharding = d_sh
            self._draft_abstract = d_abs
            buf2_sh = shd.serving_vector_sharding(
                mesh, rules, num_slots=S, slot_shards=serving.slot_shards,
                leading=2)
            with mesh:
                self.draft_pool = jax.device_put(
                    api.init_cache(self.draft_cfg, S, L), d_sh)
            self._spec_fn = jax.jit(
                functools.partial(speculative.spec_macro,
                                  draft_cfg=self.draft_cfg, cfg=cfg,
                                  num_rounds=serving.macro_ticks,
                                  gamma=serving.spec_gamma,
                                  temperature=serving.temperature,
                                  seed=serving.seed,
                                  fault_guard=serving.fault_guard),
                in_shardings=(p_sh, d_sh, c_sh) + (v_sh,) * 6,
                out_shardings=(d_sh, c_sh, buf2_sh, buf2_sh, buf2_sh,
                               buf_sh),
                donate_argnums=(1, 2))
        self._sample_fn = jax.jit(
            functools.partial(sampling.sample_tokens,
                              temperature=serving.temperature,
                              seed=serving.seed))
        # Slot ops: slot index is a traced scalar -> one compile each, and
        # out-shardings pinned to the pool's (slot-stable, never reshards).
        # The batch=1 source cache is pinned replicated, so a write_slot is
        # a shard-local donated dynamic-update: only the owning shard's
        # block changes, the others alias their input bytes.
        if self._paged:
            # Paged variants additionally take the host allocator's
            # PageState snapshot (write: post-alloc mapping to install;
            # reset: post-free mapping — the op zeroes the slot's pages
            # via the *old* device mapping first, so a freed page always
            # hands zeros to its next owner).
            pg_sh = c_sh.pages
            self._write_fn = jax.jit(
                lambda pool, src, i, st: api.write_slot(cfg, pool, src, i,
                                                        st),
                in_shardings=(c_sh, rep_sh, None, pg_sh),
                out_shardings=c_sh, donate_argnums=(0,))
            self._reset_fn = jax.jit(
                lambda pool, i, st: api.reset_slot(cfg, pool, i, st),
                in_shardings=(c_sh, None, pg_sh), out_shardings=c_sh,
                donate_argnums=(0,))
        else:
            self._write_fn = jax.jit(
                lambda pool, src, i: api.write_slot(cfg, pool, src, i),
                in_shardings=(c_sh, rep_sh, None), out_shardings=c_sh,
                donate_argnums=(0,))
            self._reset_fn = jax.jit(
                lambda pool, i: api.reset_slot(cfg, pool, i),
                in_shardings=(c_sh, None), out_shardings=c_sh,
                donate_argnums=(0,))
        # Fault injection (chaos harness only): NaN one slot's float
        # state. Same slot-stable donated-update shape as reset_slot;
        # never compiled unless an injector actually fires.
        self._corrupt_fn = jax.jit(
            lambda pool, i: api.corrupt_slot(cfg, pool, i),
            in_shardings=(c_sh, None), out_shardings=c_sh,
            donate_argnums=(0,))
        self._chunk_fn = jax.jit(
            lambda p, c, t: api.prefill_chunk(cfg, p, c, t),
            donate_argnums=(1,))
        # Pre-embedded prefill chunks (vision patch prefix): same donated
        # continuation, fed (1, Lc, d) rows instead of token ids — this is
        # what lets an oversized vision prompt absorb its patch prefix
        # chunk-by-chunk instead of being rejected at admission (§11).
        self._chunk_embeds_fn = jax.jit(
            lambda p, c, e: api.prefill_chunk(cfg, p, c, None, embeds=e),
            donate_argnums=(1,))
        self._prefill_fn = jax.jit(
            lambda p, b: api.prefill(p, cfg, b, max_len=L))
        self._prefill_masked_fn = jax.jit(
            lambda p, b, n: api.prefill(p, cfg, b, max_len=L, true_len=n))
        if self._spec:
            # Draft-pool twins of the slot/prefill ops. The draft pool is
            # never paged (constant-state — nothing to page), so these are
            # always the unpaged shapes.
            dcfg = self.draft_cfg
            d_sh = self._draft_sharding
            self._dwrite_fn = jax.jit(
                lambda pool, src, i: api.write_slot(dcfg, pool, src, i),
                in_shardings=(d_sh, rep_sh, None), out_shardings=d_sh,
                donate_argnums=(0,))
            self._dreset_fn = jax.jit(
                lambda pool, i: api.reset_slot(dcfg, pool, i),
                in_shardings=(d_sh, None), out_shardings=d_sh,
                donate_argnums=(0,))
            self._dchunk_fn = jax.jit(
                lambda p, c, t: api.prefill_chunk(dcfg, p, c, t),
                donate_argnums=(1,))
            self._dprefill_fn = jax.jit(
                lambda p, b: api.prefill(p, dcfg, b, max_len=L))
            self._dprefill_masked_fn = jax.jit(
                lambda p, b, n: api.prefill(p, dcfg, b, max_len=L,
                                            true_len=n))
        if journal is not None and journal.nbytes == 0:
            # Fresh journal: stamp the sampling/geometry contract once.
            # restore() refuses a journal whose stream keying or sampling
            # params differ — regenerated tokens would not be byte-equal.
            journal.append({
                "t": "meta", "v": journal_lib.JOURNAL_VERSION,
                "stream_key_v": sampling.STREAM_KEY_VERSION,
                "seed": serving.seed, "temperature": serving.temperature,
                "num_slots": S, "max_len": L,
                # §13: sampled spec streams consume different substreams
                # than plain decode, so restore must not cross modes (and
                # gamma changes which indices take the bonus base draw).
                "speculative": self._spec,
                "spec_gamma": serving.spec_gamma if self._spec else 0})
            journal.flush()

    # -- submission ---------------------------------------------------------

    def submit(self, req: Request) -> int:
        """Queue a request; returns its request id.

        Raises typed :class:`AdmissionError` subclasses
        (DESIGN.md §10): :class:`RequestTooLargeError` when prefix +
        prompt + max_new overflows the slot ring (the KV ring would
        silently overwrite live context otherwise), and
        :class:`QueueFullError` when the queue is at ``max_queue`` under
        the ``reject_new`` overload policy. Under ``shed_oldest`` the
        longest-waiting queued request is terminated with
        ``finish_reason="shed"`` instead; under ``queue_wait`` admission
        always succeeds and staleness is bounded by the queue-age sweep.
        A rejected request is never enqueued and consumes no rid."""
        if self._injector is not None:
            delay = self._injector.arrival_delay_for()
            if delay:
                req = dataclasses.replace(
                    req, arrival_time=req.arrival_time + delay)
        prefix = (self.cfg.num_patches
                  if self.cfg.frontend == "vision" else 0)
        need = prefix + len(req.prompt) + req.max_new_tokens
        if self._spec:
            # Verify overshoot (§13): a round writes up to spec_gamma ring
            # rows past the accept horizon before rolling back, so the
            # slot needs that much extra headroom to never wrap onto live
            # context.
            need += self.serving.spec_gamma
        # Capacity is per config kind (api.context_capacity): None means
        # unbounded — constant-state decode (linear SLAY, SSM carries) or
        # an exactly-wrapping windowed ring — so an oversized prompt (e.g.
        # a linear-attention vision request whose patch prefix + prompt
        # exceeds max_len) is admitted and absorbed chunk-by-chunk (§11).
        # Unbounded admission still requires chunked prefill: the
        # non-chunkable fallback runs one full-length prefill that cannot
        # exceed the ring.
        cap = api.context_capacity(self.cfg, self.serving.max_len)
        if cap is None and not (self._chunkable
                                and self.serving.prefill_chunk):
            cap = self.serving.max_len
        if cap is not None and need > cap:
            raise RequestTooLargeError(
                f"request does not fit its decode slot: "
                + (f"{prefix} vision-prefix patches + " if prefix else "")
                + f"{len(req.prompt)} prompt + {req.max_new_tokens} "
                f"max_new "
                + (f"+ {self.serving.spec_gamma} spec verify headroom "
                   if self._spec else "")
                + f"= {need} > context capacity {cap} "
                f"(the cache ring would overwrite live context; shorten "
                f"the prompt/max_new_tokens or raise ServingConfig."
                f"max_len)",
                queue_depth=self.sched.queue_depth,
                max_queue=self.serving.max_queue)
        rid = self._next_rid
        shed = self.sched.submit(rid, req)   # may raise QueueFullError
        self._next_rid += 1
        st = RequestStats(rid=rid, arrival=req.arrival_time,
                          prompt_len=len(req.prompt))
        st.arrival_wall = self._clock()
        self.metrics.per_request[rid] = st
        self._outputs[rid] = []
        if self.journal is not None:
            prompt = np.asarray(req.prompt, np.int32).reshape(-1)
            self.journal.append({
                "t": "admit", "rid": rid,
                "prompt": [int(x) for x in prompt],
                "digest": prefix_lib.token_digest(prompt).hex(),
                "arrival": float(req.arrival_time),
                "max_new": int(req.max_new_tokens),
                "eos": int(req.eos_id),
                "ttft_deadline_ticks": req.ttft_deadline_ticks,
                "deadline_ticks": req.deadline_ticks,
                "ttft_deadline_s": req.ttft_deadline_s,
                "deadline_s": req.deadline_s,
                "ts": self._clock()})
        for srid, sreq in shed:
            self._terminate(srid, sreq, "shed")
        if self.journal is not None:
            # Admission durability: fsync before the caller learns the
            # rid. Off the decode hot loop, so the §7 cadence is intact.
            self.journal.flush()
        return rid

    # -- engine ticks -------------------------------------------------------

    def step(self) -> bool:
        """One scheduling decision: a prefill chunk (one tick) or a decode
        macro-step (K ticks, replayed per tick). Returns False when fully
        idle.

        Tick anatomy (DESIGN.md §10): arrivals poll, then the lifecycle
        sweep (deadline expiry + queue-age shedding), then chaos
        injections if an injector is attached, then the scheduling
        decision proper. The sweep also runs after every replayed decode
        tick, so deadlines are enforced at per-tick granularity even
        under K-tick macro-stepping."""
        sched = self.sched
        sched.poll_arrivals(self.tick)
        did = False
        with self.mesh:
            self._lifecycle_sweep()
            if self._injector is not None:
                self._apply_injections()
            if sched.want_prefill(self._prefill is not None):
                self.metrics.sample(sched.queue_depth, sched.occupancy)
                self._prefill_tick()
                sched.note_prefill()
                self.metrics.prefill_ticks += 1
                self.tick += 1
                did = True
            elif sched.active:
                if self._spec:
                    self._decode_spec()
                else:
                    self._decode_macro()
                did = True
            else:
                self.metrics.sample(sched.queue_depth, sched.occupancy)
                self.tick += 1
        self.metrics.ticks = self.tick
        if self.journal is not None:
            # One fsync per engine step = macro-step granularity: the
            # K-tick decode dispatch batch-journals its emissions here.
            self.journal.flush()
            every = self.serving.checkpoint_every_ticks
            if every and self.tick - self._last_ckpt_tick >= every:
                self.checkpoint()
        return did or bool(sched.waiting)

    def run(self, requests: list[Request] | None = None, *,
            max_ticks: int | None = None):
        """Drive to completion. Returns (outputs, metrics summary) where
        outputs maps rid -> int32 array of that request's generated tokens
        (actual length: through EOS inclusive, or max_new_tokens)."""
        for r in requests or ():
            self.submit(r)
        limit = max_ticks if max_ticks is not None else 10_000_000
        while self.tick < limit:
            if not (self.sched.active or self.sched.ready
                    or self.sched.waiting or self._prefill):
                break
            self.step()
        if self.journal is not None:
            self.journal.flush()
        if self._audit:
            self._debug_audit()
        outs = {rid: np.asarray(toks, np.int32)
                for rid, toks in self._outputs.items()}
        summary = self.metrics.summary()
        summary["journal_bytes"] = (self.journal.nbytes
                                    if self.journal is not None else 0)
        # Leak contract (CI asserts these on every bench row): a drained
        # engine holds zero live slots and an empty queue — every
        # admission path, including quarantine retries, cancels, and
        # deadline evictions, returned its slot to the pool.
        summary["final_occupancy"] = self.sched.occupancy
        summary["final_queue_depth"] = self.sched.queue_depth
        # Paged pool: every exit path returned its pages to the free list
        # ("pages leaked = 0" — the CI bench contract asserts this).
        summary["final_pages_in_use"] = (
            self.page_pool.pages_in_use() if self.page_pool else 0)
        return outs, summary

    # -- durability: checkpoint / restore (DESIGN.md §12) -------------------

    def checkpoint(self) -> str:
        """Write an atomic engine checkpoint next to the journal.

        Journal first, checkpoint second: the flush guarantees every
        token the checkpointed mirrors count as emitted is on disk, so a
        restored resident slot's ``gen`` can never run ahead of its
        journaled stream. Called automatically every
        ``serving.checkpoint_every_ticks`` ticks (macro-step boundaries),
        or explicitly."""
        if self.journal is None:
            raise RuntimeError(
                "checkpointing requires the engine to have a journal "
                "(ContinuousServingEngine(..., journal=Journal(path)))")
        self.journal.flush()
        state = checkpoint_lib.snapshot_engine(self)
        path = checkpoint_lib.checkpoint_path(self._ckpt_dir, self.tick)
        checkpoint_lib.save(path, state)
        self._last_ckpt_tick = self.tick
        self.metrics.checkpoints_written += 1
        return path

    @classmethod
    def restore(cls, path: str, cfg: ArchConfig, params, mesh, *,
                serving: ServingConfig = ServingConfig(),
                rules: shd.ShardingRules = shd.DEFAULT_RULES,
                fault_injector=None, prefix_cache=None,
                clock: Callable[[], float] = time.perf_counter,
                on_token: Callable[[int, int], None] | None = None,
                on_finish: Callable[[int, str], None] | None = None,
                redeliver: bool = False) -> "ContinuousServingEngine":
        """Rebuild an engine from a durability directory (journal +
        checkpoints) after a crash, with byte-identical streams.

        Recovery sequence (DESIGN.md §12): tolerant journal replay (torn
        tail dropped and truncated), latest *valid* checkpoint load
        (corrupt files skipped), fresh engine construction, then
        ``_apply_restore``: device pool + mirrors + allocator + prefix
        cache come from the checkpoint when its geometry matches this
        config; every live rid is rebuilt from its journaled admission —
        checkpoint-resident ones resume mid-stream in their slots,
        everything else re-queues in arrival order and re-prefills from
        scratch. Because sampling is keyed on (seed, rid, token-index),
        both paths regenerate the pre-crash tokens bit-for-bit; the
        journal horizon dedupes them (verified in ``_emit``) so streaming
        callbacks see each token exactly once. A checkpoint with a
        *different* slot count (restore onto another machine shape) is
        rejected wholesale and recovery is journal-only — streams are
        still byte-identical, only more tokens replay.

        ``on_token``/``on_finish`` attach to every restored live request;
        ``redeliver=True`` additionally re-fires them for the journaled
        prefix (and journaled terminal requests) at restore time —
        exactly-once delivery for a consumer that lost its own state with
        the process."""
        t0 = clock()
        jpath = os.path.join(path, journal_lib.JOURNAL_NAME)
        jst = journal_lib.replay(jpath)
        meta = jst.meta
        if meta is not None:
            if meta.get("stream_key_v") != sampling.STREAM_KEY_VERSION:
                raise ValueError(
                    f"journal stream keying v{meta.get('stream_key_v')} != "
                    f"engine v{sampling.STREAM_KEY_VERSION}: regenerated "
                    f"tokens would not be byte-identical; cannot resume")
            if (int(meta.get("seed", serving.seed)) != serving.seed
                    or float(meta.get("temperature", serving.temperature))
                    != serving.temperature):
                raise ValueError(
                    "journal was written under a different sampling config "
                    f"(seed={meta.get('seed')}, temperature="
                    f"{meta.get('temperature')}); restore with the same "
                    "seed/temperature or streams diverge")
            if "speculative" in meta and (
                    bool(meta["speculative"]) != bool(serving.speculative)
                    or int(meta.get("spec_gamma", 0))
                    != (serving.spec_gamma if serving.speculative else 0)):
                # §13: sampled spec streams consume tagged substreams and
                # the bonus-index pattern depends on gamma, so crossing
                # modes (or gammas) would regenerate different tokens.
                raise ValueError(
                    "journal was written under a different speculative "
                    f"config (speculative={meta['speculative']}, "
                    f"spec_gamma={meta.get('spec_gamma')}); restore with "
                    "the same speculative/spec_gamma or streams diverge")
        ck = checkpoint_lib.latest_valid(path)
        jr = journal_lib.Journal(jpath, truncate_to=jst.valid_bytes)
        eng = cls(cfg, params, mesh, serving=serving, rules=rules,
                  fault_injector=fault_injector, prefix_cache=prefix_cache,
                  journal=jr, clock=clock)
        eng._apply_restore(jst, ck, on_token=on_token, on_finish=on_finish,
                           redeliver=redeliver)
        eng.recovery["wall_s"] = clock() - t0
        return eng

    def _apply_restore(self, jst: journal_lib.JournalState,
                       ck: dict | None, *, on_token, on_finish,
                       redeliver: bool):
        S = self.serving.num_slots
        usable = (
            ck is not None
            and int(ck.get("num_slots", -1)) == S
            and int(ck.get("max_len", -1)) == self.serving.max_len
            and int(ck.get("page_size", -1))
            == (self.serving.page_size if self._paged else 0)
            and bool(ck.get("speculative", False)) == self._spec
            and int(ck.get("spec_gamma", 0))
            == (self.serving.spec_gamma if self._spec else 0))
        if usable:
            cur = jax.tree.leaves(self.pool)
            saved = ck["pool"]
            usable = (len(cur) == len(saved) and all(
                tuple(c.shape) == tuple(s.shape)
                and np.dtype(c.dtype) == np.dtype(s.dtype)
                for c, s in zip(cur, saved)))
        if usable and self._spec:
            dcur = jax.tree.leaves(self.draft_pool)
            dsaved = ck.get("draft_pool") or []
            usable = (len(dcur) == len(dsaved) and all(
                tuple(c.shape) == tuple(s.shape)
                and np.dtype(c.dtype) == np.dtype(s.dtype)
                for c, s in zip(dcur, dsaved)))
        resident: dict[int, int] = {}       # rid -> slot
        if usable:
            treedef = jax.tree.structure(self.pool)
            with self.mesh:
                self.pool = jax.device_put(
                    jax.tree.unflatten(
                        treedef, [jnp.asarray(x) for x in ck["pool"]]),
                    self._cache_sharding)
            if self._spec:
                dtree = jax.tree.structure(self.draft_pool)
                with self.mesh:
                    self.draft_pool = jax.device_put(
                        jax.tree.unflatten(
                            dtree,
                            [jnp.asarray(x) for x in ck["draft_pool"]]),
                        self._draft_sharding)
            mir = ck["mirrors"]
            self._last_tok = np.asarray(mir["last_tok"], np.int32).copy()
            self._active = np.asarray(mir["active"], bool).copy()
            self._rids = np.asarray(mir["rids"], np.int32).copy()
            self._gen = np.asarray(mir["gen"], np.int32).copy()
            self._eos = np.asarray(mir["eos"], np.int32).copy()
            self._maxn = np.asarray(mir["maxn"], np.int32).copy()
            if self.page_pool is not None and ck.get("page_pool"):
                self.page_pool.load_snapshot(ck["page_pool"])
            self.tick = int(ck["tick"])
            self.metrics.ticks = self.tick
            self._last_ckpt_tick = self.tick
            resident = {int(r): int(s) for s, r in ck["slots"].items()}
            if self.prefix_cache is not None and ck.get("prefix"):
                # Rebuild the prefix-cache index. Entries are batch=1
                # unpaged snapshots; refcounts restart at zero (live pins
                # are re-acquired when restored requests re-admit).
                pstruct = jax.tree.structure(
                    api.init_cache(self.cfg, 1, self.serving.max_len))
                for ent in ck["prefix"]:
                    try:
                        cache = jax.tree.unflatten(
                            pstruct,
                            [jnp.asarray(x) for x in ent["cache"]])
                        lg = (jnp.asarray(ent["logits"])
                              if ent["logits"] is not None else None)
                        self.prefix_cache.insert(ent["tokens"], cache,
                                                 logits=lg, copy=False)
                    except Exception:
                        continue  # shape-incompatible entry: skip, a miss
        nr = int(ck["next_rid"]) if usable else 0
        if jst.admits:
            nr = max(nr, max(jst.admits) + 1)
        self._next_rid = nr
        # Validate checkpoint residency against the journal: a resident
        # slot needs a journaled admission, no terminal record, agreeing
        # mirrors, and a journaled stream at least as long as its ``gen``
        # (guaranteed by the flush-before-checkpoint order; anything else
        # falls back to re-admission from scratch).
        for rid, slot in list(resident.items()):
            toks = jst.tokens.get(rid, [])
            ok = (rid in jst.admits and rid not in jst.fins
                  and 0 <= slot < S and bool(self._active[slot])
                  and int(self._rids[slot]) == rid
                  and 0 < int(self._gen[slot]) <= len(toks))
            if not ok:
                resident.pop(rid)
        now_wall = self._clock()
        for rid in sorted(jst.admits):
            a = jst.admits[rid]
            toks = [int(t) for t in jst.tokens.get(rid, [])]
            st = RequestStats(rid=rid, arrival=float(a["arrival"]),
                              prompt_len=len(a["prompt"]))
            st.arrival_wall = now_wall   # wall deadlines re-anchor here
            st.retries = int(jst.retries.get(rid, 0))
            self.metrics.per_request[rid] = st
            self._outputs[rid] = list(toks)
            fin = jst.fins.get(rid)
            if fin is not None:
                # Terminal before the crash: the stream is fixed from the
                # journal; not re-admitted, not re-counted in lifetime
                # counters (they describe this engine's work).
                st.finish_reason = fin
                st.finished = self.tick
                continue
            req = Request(
                np.asarray(a["prompt"], np.int32),
                max_new_tokens=int(a["max_new"]),
                eos_id=int(a["eos"]),
                arrival_time=float(a["arrival"]),
                on_token=on_token, on_finish=on_finish,
                ttft_deadline_ticks=a.get("ttft_deadline_ticks"),
                deadline_ticks=a.get("deadline_ticks"),
                ttft_deadline_s=a.get("ttft_deadline_s"),
                deadline_s=a.get("deadline_s"))
            if toks:
                self._replay_until[rid] = len(toks)
            slot = resident.get(rid)
            if slot is not None:
                gen = int(self._gen[slot])
                rec = _Slot(rid, req, int(self._last_tok[slot]),
                            tokens=list(toks[:gen]))
                self.sched.active[slot] = rec
                self.sched.free.remove(slot)
                st.slot = slot
                st.admitted = self.tick
                st.first_token = self.tick
                st.first_token_wall = now_wall
            else:
                self.sched.waiting.append((rid, req))
        self.sched.waiting = collections.deque(
            sorted(self.sched.waiting,
                   key=lambda t: (t[1].arrival_time, t[0])))
        # Clear mirror/allocator state for slots the journal suffix shows
        # were evicted (or whose residency failed validation) after the
        # checkpoint. No device op needed: inactive slots are masked
        # passthrough in the decode scan, write_slot fully overwrites on
        # reuse, and unmapped pages gather as zeros.
        for slot in range(S):
            if self._active[slot] and slot not in self.sched.active:
                self._active[slot] = False
                if (self.page_pool is not None
                        and self.page_pool.slot_pages(slot)):
                    self.page_pool.free_slot(slot)
        if self.page_pool is not None:
            self._note_pages()
        if redeliver:
            for rid in sorted(self._outputs):
                if on_token is not None:
                    for tok in self._outputs[rid]:
                        on_token(rid, int(tok))
                fin = jst.fins.get(rid)
                if fin is not None and on_finish is not None:
                    on_finish(rid, fin)
        self.recovery = {
            "checkpoint_used": bool(usable),
            "checkpoint_tick": int(ck["tick"]) if usable else None,
            "journal_records": jst.records,
            "journal_dropped_tail": jst.dropped_tail,
            "resident_resumed": len(self.sched.active),
            "requeued": len(self.sched.waiting),
            "terminal_from_journal": len(jst.fins),
        }

    def _debug_audit(self):
        """Invariant audit (``ServingConfig.debug_audit`` or the
        ``REPRO_DEBUG_AUDIT`` env var), run at the end of every
        :meth:`run`: the page allocator's free/owned partition must be
        consistent and every prefix-cache refcount must correspond to a
        live engine pin — a leaked pin would block eviction forever."""
        if self.page_pool is not None:
            self.page_pool.check()
        if self.prefix_cache is not None:
            refs = self.prefix_cache.live_refs()
            pins = len(self._pfx_refs)
            assert refs == pins, (
                f"prefix-cache refcount leak: {refs} live refs vs {pins} "
                f"engine pins")

    # -- internals ----------------------------------------------------------

    def _need_rows(self, req: Request) -> int:
        """Context rows a request occupies: frontend prefix + prompt +
        decode budget (what the page allocator sizes a slot's pages by) —
        plus, in speculative mode, ``spec_gamma`` verify-overshoot rows
        (§13: a round's ring writes reach past the accept horizon before
        rolling back; the pages for those rows are allocated up front so
        rollback never touches the page table and nothing can leak)."""
        prefix = (self.cfg.num_patches
                  if self.cfg.frontend == "vision" else 0)
        need = prefix + len(req.prompt) + req.max_new_tokens
        if self._spec:
            need += self.serving.spec_gamma
        return need

    def _note_pages(self):
        self.metrics.pages_in_use = self.page_pool.pages_in_use()
        self.metrics.pages_peak = self.page_pool.pages_peak

    def _seed_from_prefix(self, pf: _Prefill, C: int):
        """Seed an admission from the longest cached prompt prefix (§11).

        A full-prompt hit skips prefill entirely (the stored last-token
        logits sample token 0 — sampling is keyed (seed, rid, idx), never
        on how the state was produced). A proper-prefix hit deep-copies
        the snapshot (the donating chunk jit would invalidate the cached
        buffers) and chunk-prefills only the suffix — hits land on chunk
        multiples only, so the suffix chunk schedule is identical to a
        cold prefill's and the stream stays byte-identical."""
        entry = self.prefix_cache.lookup(pf.req.prompt, chunk=C)
        if entry is None:
            return
        self.prefix_cache.acquire(entry)
        self._pfx_refs[pf.rid] = entry
        st = self.metrics.per_request[pf.rid]
        st.prefix_cached = True
        st.prefix_tokens = entry.length
        self.metrics.prefix_hits += 1
        self.metrics.prefix_tokens_reused += entry.length
        if entry.length == len(pf.req.prompt):
            pf.cache = entry.cache   # write_slot does not donate its src
            pf.logits = entry.logits
        else:
            pf.cache = prefix_lib.tree_copy(entry.cache)
        pf.offset = entry.length
        pf.prefix_offset = (self.cfg.num_patches
                            if self.cfg.frontend == "vision" else 0)

    def _prefill_tick(self):
        pf = self._prefill
        C = self.serving.prefill_chunk
        if pf is None:
            slot_ok = None
            if self.page_pool is not None:
                slot_ok = (lambda s, r:
                           self.page_pool.can_alloc(s, self._need_rows(r)))
            admission = self.sched.next_admission(slot_ok)
            if admission is None:
                return
            rid, req, slot = admission
            pf = _Prefill(rid, req, slot,
                          api.init_cache(self.cfg, 1, self.serving.max_len))
            if self._spec:
                # Dual-cache residency (§13): the draft twin absorbs the
                # same prompt so both regimes enter decode in agreement.
                pf.draft = api.init_cache(self.draft_cfg, 1,
                                          self.serving.max_len)
            if self.page_pool is not None:
                # Host-side reservation only: the device PageState learns
                # the mapping at install (write_slot) time, so an
                # admission cancelled mid-prefill frees host-side with no
                # device op — and freshly freed pages are zeros (reset
                # zeroes them via the old mapping), never stale bytes.
                self.page_pool.alloc(slot, self._need_rows(req))
                self._note_pages()
            if self.prefix_cache is not None and self._chunkable and C:
                self._seed_from_prefix(pf, C)
            self._prefill = pf
            self.metrics.per_request[rid].admitted = self.tick
            self.metrics.per_request[rid].slot = slot
        req, prompt = pf.req, np.asarray(pf.req.prompt, np.int32)
        logits = pf.logits
        if logits is not None:
            pass                     # full prefix-cache hit: nothing to run
        elif self._chunkable and C:
            patches = (self.cfg.num_patches
                       if self.cfg.frontend == "vision" else 0)
            if pf.prefix_offset < patches:
                # Vision patch prefix, absorbed as pre-embedded rows chunk
                # by chunk — this is why an oversized vision prompt no
                # longer needs (and is no longer bounded by) a full-length
                # prefill (§11 bugfix).
                n = min(C, patches - pf.prefix_offset)
                emb = jnp.zeros((1, n, self.cfg.d_model),
                                self.cfg.activation_dtype)
                _, pf.cache = self._chunk_embeds_fn(self.params, pf.cache,
                                                    emb)
                pf.prefix_offset += n
                return
            chunk = prompt[pf.offset:pf.offset + C]
            toks = jnp.asarray(chunk[None, :])
            logits, pf.cache = self._chunk_fn(self.params, pf.cache, toks)
            if self._spec:
                _, pf.draft = self._dchunk_fn(self.params, pf.draft, toks)
            pf.offset += len(chunk)
            if (self.prefix_cache is not None and pf.offset % C == 0
                    and pf.offset < len(prompt)):
                # Chunk-boundary snapshot: a future prompt sharing this
                # prefix seeds from it and prefills only its suffix.
                self.prefix_cache.insert(prompt[:pf.offset], pf.cache)
        elif self._bucketable:
            # Non-chunkable fallback, bucketed: right-pad to the pow-2
            # bucket and mask exactly via true_len — one compile per
            # bucket instead of one per distinct prompt length. The cap
            # leaves room for the vision patch prefix: prefix + bucket
            # must fit the KV ring or the ring write would drop real
            # prefix rows still inside the validity horizon (submit()
            # rejects any request whose prefix + prompt + max_new exceeds
            # max_len, so the cap can never undershoot the prompt here).
            prefix = (self.cfg.num_patches
                      if self.cfg.frontend == "vision" else 0)
            Lb = _bucket_len(len(prompt), self.serving.prefill_bucket_min,
                             self.serving.max_len - prefix)
            if Lb in self._seen_buckets:
                self.metrics.bucket_hits += 1
            else:
                self._seen_buckets.add(Lb)
                self.metrics.bucket_misses += 1
            padded = np.zeros(Lb, np.int32)
            padded[:len(prompt)] = prompt
            batch = _model_batch(self.cfg, jnp.asarray(padded[None, :]))
            tl = jnp.full((1,), prefix + len(prompt), jnp.int32)
            logits, pf.cache = self._prefill_masked_fn(self.params, batch,
                                                       tl)
            if self._spec:
                _, pf.draft = self._dprefill_masked_fn(self.params, batch,
                                                       tl)
            pf.offset = len(prompt)
        else:
            batch = _model_batch(self.cfg, jnp.asarray(prompt[None, :]))
            logits, pf.cache = self._prefill_fn(self.params, batch)
            if self._spec:
                _, pf.draft = self._dprefill_fn(self.params, batch)
            pf.offset = len(prompt)
        if pf.offset < len(prompt):
            return                       # more chunks; decode may interleave
        # Prompt fully absorbed: sample the first token on device (same
        # fused sampler as the decode loop, idx 0) and install the request
        # into its pool slot. One int32 scalar crosses to host.
        tok0 = int(self._sample_fn(
            logits[:, -1, :], jnp.full((1,), pf.rid, jnp.int32),
            jnp.zeros((1,), jnp.int32))[0])
        self.metrics.prefill_token_syncs += 1
        if (self.prefix_cache is not None and self._chunkable and C
                and pf.logits is None):
            # Full-prompt entry with last-token logits: a repeat of this
            # exact prompt becomes a zero-prefill admission.
            self.prefix_cache.insert(prompt, pf.cache,
                                     logits=logits[:, -1:, :])
        if self.page_pool is not None:
            self.pool = self._write_fn(self.pool, pf.cache,
                                       jnp.int32(pf.slot),
                                       self.page_pool.device_vectors())
        else:
            self.pool = self._write_fn(self.pool, pf.cache,
                                       jnp.int32(pf.slot))
        if self._spec:
            self.draft_pool = self._dwrite_fn(self.draft_pool, pf.draft,
                                              jnp.int32(pf.slot))
        self._prefill = None
        self.metrics.prompt_tokens += (
            len(prompt) - self.metrics.per_request[pf.rid].prefix_tokens)
        slot_rec = _Slot(pf.rid, req, tok0)
        self.sched.active[pf.slot] = slot_rec
        self._last_tok[pf.slot] = tok0
        self._active[pf.slot] = True
        self._rids[pf.slot] = pf.rid
        self._gen[pf.slot] = 1
        self._eos[pf.slot] = req.eos_id
        self._maxn[pf.slot] = req.max_new_tokens
        self._emit(slot_rec, tok0, 0)
        if tok0 == req.eos_id or req.max_new_tokens <= 1:
            self._finish(pf.slot,
                         sampling.finish_reason_of(tok0, req.eos_id))

    def _decode_macro(self):
        """One decode dispatch = K device ticks for the whole pool; replay
        the token buffer on host at per-tick granularity so streaming
        callbacks, TTFT/queue-depth samples, and eviction stay exact."""
        self.pool, toks, em, flt = self._macro_fn(
            self.params, self.pool, jnp.asarray(self._last_tok),
            jnp.asarray(self._active), jnp.asarray(self._rids),
            jnp.asarray(self._gen), jnp.asarray(self._eos),
            jnp.asarray(self._maxn))
        self.metrics.decode_dispatches += 1
        toks, em, flt = (np.asarray(toks), np.asarray(em),
                         np.asarray(flt))  # ONE host sync per K ticks
        self.metrics.host_syncs += 1
        for t in range(toks.shape[0]):
            if not (em[t].any() or flt[t].any()):
                break   # every slot drained mid-macro-step; suffix unused
            self.sched.poll_arrivals(self.tick)
            self.metrics.sample(self.sched.queue_depth,
                                self.sched.occupancy)
            # Quarantine before emission: a faulted slot never emitted at
            # this tick (its sampled token is garbage by definition).
            for slot in np.nonzero(flt[t])[0]:
                if int(slot) in self.sched.active:
                    self._quarantine(int(slot))
            for slot in list(self.sched.active):
                if not em[t, slot]:
                    continue
                rec = self.sched.active[slot]
                tk = int(toks[t, slot])
                rec.last_tok = tk
                self._last_tok[slot] = tk
                self._gen[slot] += 1
                self._emit(rec, tk, int(self._gen[slot]) - 1)
                if (tk == rec.req.eos_id
                        or len(rec.tokens) >= rec.req.max_new_tokens):
                    self._finish(slot, sampling.finish_reason_of(
                        tk, rec.req.eos_id))
            self.sched.note_decode()
            self.metrics.decode_ticks += 1
            self.tick += 1
            self.metrics.ticks = self.tick
            # Sweep *after* the tick's emissions: EOS beats a deadline
            # expiring on the same tick; an on_token cancel has already
            # removed its slot from residency by the time we get here.
            self._lifecycle_sweep()

    def _decode_spec(self):
        """One speculative dispatch = K draft-verify rounds (§13); replay
        the (K, gamma+1, S) token buffer on host one round per tick.

        A round is one engine tick (one scheduling quantum) emitting up to
        gamma+1 tokens per slot, so the per-tick contracts — streaming
        callbacks in emission order, quarantine before emission, the
        lifecycle sweep after — run exactly like the plain macro-step's
        replay; only the tokens-per-tick arithmetic changes. Still one
        host sync per dispatch."""
        G = self.serving.spec_gamma
        self.draft_pool, self.pool, toks, em, flt, acc = self._spec_fn(
            self.params, self.draft_pool, self.pool,
            jnp.asarray(self._last_tok), jnp.asarray(self._active),
            jnp.asarray(self._rids), jnp.asarray(self._gen),
            jnp.asarray(self._eos), jnp.asarray(self._maxn))
        self.metrics.decode_dispatches += 1
        toks, em, flt, acc = (np.asarray(toks), np.asarray(em),
                              np.asarray(flt), np.asarray(acc))
        self.metrics.host_syncs += 1      # ONE host sync per K rounds
        for r in range(toks.shape[0]):
            if not (em[r].any() or flt[r].any()):
                break   # every slot drained mid-dispatch; suffix unused
            self.sched.poll_arrivals(self.tick)
            self.metrics.sample(self.sched.queue_depth,
                                self.sched.occupancy)
            # Quarantine before emission — a faulted round emitted nothing
            # (device side: its verifier rewound to the round start, its
            # draft kept the snapshot; the flag rides row 0 only).
            for slot in np.nonzero(flt[r, 0])[0]:
                if int(slot) in self.sched.active:
                    self._quarantine(int(slot))
            # Acceptance accounting: acc[r, s] >= 0 is a counted round
            # (slot active, not faulted) that offered G drafts.
            for slot in range(acc.shape[1]):
                v = int(acc[r, slot])
                if v >= 0:
                    self.metrics.draft_tokens_proposed += G
                    self.metrics.draft_tokens_accepted += v
            for j in range(toks.shape[1]):
                if not em[r, j].any():
                    break   # per-slot emissions are a j-prefix: done
                for slot in list(self.sched.active):
                    if not em[r, j, slot]:
                        continue
                    rec = self.sched.active.get(slot)
                    if rec is None:   # cancelled by an earlier callback
                        continue
                    tk = int(toks[r, j, slot])
                    rec.last_tok = tk
                    self._last_tok[slot] = tk
                    self._gen[slot] += 1
                    self._emit(rec, tk, int(self._gen[slot]) - 1)
                    if (tk == rec.req.eos_id
                            or len(rec.tokens) >= rec.req.max_new_tokens):
                        self._finish(slot, sampling.finish_reason_of(
                            tk, rec.req.eos_id))
            self.sched.note_decode()
            self.metrics.decode_ticks += 1
            self.tick += 1
            self.metrics.ticks = self.tick
            self._lifecycle_sweep()

    def jit_cache_entries(self) -> dict:
        """Live jit-cache entry counts per engine entry point — the
        recompile budget CI asserts on (the decode hot loop must stay at
        exactly one entry; prefill entries are bounded by the chunk/bucket
        counts, never by the number of distinct prompt lengths).

        Counting relies on jax's ``_cache_size`` introspection; entry
        points it cannot measure are omitted (callers treat a missing key
        as "unmeasurable", not as a budget violation)."""
        fns = {"macro_decode": self._macro_fn, "sample": self._sample_fn,
               "write": self._write_fn, "reset": self._reset_fn,
               "corrupt": self._corrupt_fn, "chunk": self._chunk_fn,
               "chunk_embeds": self._chunk_embeds_fn,
               "prefill": self._prefill_fn,
               "prefill_masked": self._prefill_masked_fn}
        if self._spec:
            fns.update({"spec_macro": self._spec_fn,
                        "draft_write": self._dwrite_fn,
                        "draft_reset": self._dreset_fn,
                        "draft_chunk": self._dchunk_fn})
        out = {}
        for name, fn in fns.items():
            try:
                out[name] = int(fn._cache_size())
            except Exception:         # pragma: no cover — jax internals
                continue
        return out

    def decode_hlo(self) -> str:
        """Compiled HLO of the decode macro-step at the engine's shapes and
        shardings — the §8 zero-collective contract surface: on a slot-
        sharded mesh the op table must contain no collective opcodes
        (``repro.analysis.hlo.parse_hlo`` + ``check_no_collectives`` is
        how the sharded-parity tests assert it — parsed opcodes, not
        substring greps). Compiles (cached) but never executes."""
        p_abs, c_abs = self._abstract
        S = self.serving.num_slots
        i32 = jax.ShapeDtypeStruct((S,), jnp.int32)
        b1 = jax.ShapeDtypeStruct((S,), jnp.bool_)
        with self.mesh:
            if self._spec:
                lowered = self._spec_fn.lower(
                    p_abs, self._draft_abstract, c_abs, i32, b1, i32, i32,
                    i32, i32)
            else:
                lowered = self._macro_fn.lower(p_abs, c_abs, i32, b1, i32,
                                               i32, i32, i32)
        return lowered.compile().as_text()

    def contract_lowerings(self) -> dict:
        """Compiled HLO text + expected donated-leaf count for every
        ``donate_argnums`` engine entry point — the DESIGN.md §14 contract
        surface the HLO analyzer checks (zero collectives, no host
        callbacks, and every donated leaf actually aliased in
        ``input_output_alias``; XLA drops unusable donations *silently*,
        which would double the pool's HBM footprint with no error).

        Returns ``{name: (compiled_hlo_text, expected_donated_leaves)}``.
        ``write_slot``/``reset_slot`` are only lowered for unpaged pools —
        the paged variants take a live host ``PageState`` snapshot that
        has no static abstract here. Compiles (cached) but never
        executes."""
        p_abs, c_abs = self._abstract
        S, L = self.serving.num_slots, self.serving.max_len
        i32 = jax.ShapeDtypeStruct((S,), jnp.int32)
        b1 = jax.ShapeDtypeStruct((S,), jnp.bool_)
        scalar = jax.ShapeDtypeStruct((), jnp.int32)
        cache_leaves = len(jax.tree.leaves(c_abs))
        out = {}
        with self.mesh:
            if self._spec:
                lowered = self._spec_fn.lower(
                    p_abs, self._draft_abstract, c_abs, i32, b1, i32, i32,
                    i32, i32)
                donated = cache_leaves + len(
                    jax.tree.leaves(self._draft_abstract))
            else:
                lowered = self._macro_fn.lower(p_abs, c_abs, i32, b1, i32,
                                               i32, i32, i32)
                donated = cache_leaves
            out["macro_decode"] = (lowered.compile().as_text(), donated)
            if not self._paged:
                src_abs = api.abstract_cache(self.cfg, 1, L)
                lowered = self._write_fn.lower(c_abs, src_abs, scalar)
                out["write_slot"] = (lowered.compile().as_text(),
                                     cache_leaves)
                lowered = self._reset_fn.lower(c_abs, scalar)
                out["reset_slot"] = (lowered.compile().as_text(),
                                     cache_leaves)
        return out

    def _emit(self, rec: _Slot, tok: int, idx: int):
        """Deliver one emitted token. ``idx`` is the request's token index
        (the sampling key index — 0 for the prefill-sampled first token).

        Post-restore dedup (DESIGN.md §12): tokens with ``idx`` below the
        request's journaled horizon were already delivered before the
        crash. Deterministic (seed, rid, idx) sampling regenerates them
        bit-for-bit — verified here, which *is* the byte-identity
        assertion — and they are counted as replayed, not re-journaled or
        re-delivered to callbacks."""
        st = self.metrics.per_request[rec.rid]
        out = self._outputs[rec.rid]
        if idx < self._replay_until.get(rec.rid, 0):
            if idx >= len(out) or tok != out[idx]:
                raise RuntimeError(
                    f"restore byte-identity violated: rid {rec.rid} token "
                    f"{idx} regenerated {tok} != journaled "
                    f"{out[idx] if idx < len(out) else '<missing>'}")
            rec.tokens.append(tok)
            self.metrics.tokens_generated += 1
            self.metrics.tokens_replayed += 1
            if st.first_token is None:
                st.first_token = self.tick
                st.first_token_wall = self._clock()
            return
        rec.tokens.append(tok)
        out.append(tok)
        self.metrics.tokens_generated += 1
        if st.first_token is None:
            st.first_token = self.tick
            st.first_token_wall = self._clock()
        if self.journal is not None:
            self.journal.append({"t": "tok", "rid": rec.rid,
                                 "tok": int(tok)})
        if rec.req.on_token is not None:
            rec.req.on_token(rec.rid, tok)

    def _evict_slot_state(self, slot: int):
        """Zero a slot's device state; paged pools first return its pages
        to the free list (the reset op zeroes them via the old device
        mapping, so the next owner always reads zeros — never a prior
        slot's bytes, in particular never an injected NaN)."""
        if self.page_pool is not None:
            self.page_pool.free_slot(slot)
            self._note_pages()
            self.pool = self._reset_fn(self.pool, jnp.int32(slot),
                                       self.page_pool.device_vectors())
        else:
            self.pool = self._reset_fn(self.pool, jnp.int32(slot))
        if self._spec:
            self.draft_pool = self._dreset_fn(self.draft_pool,
                                              jnp.int32(slot))

    def _finish(self, slot: int, reason: str):
        """Evict a slot-resident request into terminal state ``reason``."""
        rec = self.sched.active[slot]
        self._active[slot] = False
        # Eviction = one slot overwrite (constant-state asymmetry: O(m·dv)
        # zeros for SLAY vs an O(max_len) ring zero for KV backends).
        self._evict_slot_state(slot)
        self.sched.evict(slot)
        self._terminate(rec.rid, rec.req, reason)

    def _terminate(self, rid: int, req: Request, reason: str):
        """Stamp the single terminal state of a request — every exit path
        (natural stop, deadline, cancel, shed, fault) funnels here, so
        ``on_finish`` fires exactly once and the finish-reason breakdown
        always sums to ``requests_terminated``."""
        st = self.metrics.per_request[rid]
        st.finished = self.tick
        st.finish_reason = reason
        self._replay_until.pop(rid, None)
        if self.journal is not None:
            self.journal.append({"t": "fin", "rid": rid, "reason": reason,
                                 "tick": self.tick})
        entry = self._pfx_refs.pop(rid, None)
        if entry is not None:       # release the seeding snapshot's pin
            self.prefix_cache.release(entry)
        m = self.metrics
        m.requests_terminated += 1
        m.finish_reasons[reason] = m.finish_reasons.get(reason, 0) + 1
        if reason in ("eos", "length"):
            m.requests_completed += 1
            if st.retries:
                m.fault_retries_succeeded += 1
        if req.on_finish is not None:
            req.on_finish(rid, reason)

    def _quarantine(self, slot: int):
        """Non-finite decode state detected in ``slot`` (DESIGN.md §10):
        reset the slot and either re-admit the request *from scratch* at
        the head of the ready queue — deterministic (seed, rid, idx)
        sampling regenerates the identical stream prefix when the fault
        was transient, so a successful retry is indistinguishable from a
        fault-free run — or, with ``serving.fault_retries`` exhausted,
        terminate it with ``finish_reason="fault"``. The possibly-tainted
        emitted prefix is dropped either way."""
        rec = self.sched.active[slot]
        st = self.metrics.per_request[rec.rid]
        m = self.metrics
        m.faults_detected += 1
        m.fault_events.append({"rid": rec.rid, "slot": slot,
                               "tick": self.tick})
        self._active[slot] = False
        self._evict_slot_state(slot)
        self.sched.evict(slot)
        ent = self._pfx_refs.pop(rec.rid, None)
        if ent is not None:
            self.prefix_cache.release(ent)
        if st.retries < self.serving.fault_retries:
            st.retries += 1
            m.fault_retries += 1
            self._outputs[rec.rid] = []
            # A retry restarts the stream from index 0: void the journaled
            # prefix (replay folds a retry record into an empty token
            # list) and drop any restore-dedup horizon with it.
            self._replay_until.pop(rec.rid, None)
            if self.journal is not None:
                self.journal.append({"t": "retry", "rid": rec.rid})
            st.first_token = None
            st.first_token_wall = None
            st.prefix_cached = False
            st.prefix_tokens = 0
            # Head of the ready queue: the request already waited its
            # turn once; retry latency is one admission, not a requeue.
            self.sched.ready.appendleft((rec.rid, rec.req))
        else:
            self._terminate(rec.rid, rec.req, "fault")

    def _release_prefill_slot(self, slot: int):
        """Return a mid-prefill slot to the pool (cancel/deadline before
        install). Pages were only ever reserved host-side — the device
        PageState never learned the mapping — so freeing is host-only."""
        self.sched.free.append(slot)
        self.sched.free.sort()
        if self.page_pool is not None:
            self.page_pool.free_slot(slot)
            self._note_pages()

    # -- lifecycle: cancellation, deadlines, queue-age shedding -------------

    def cancel(self, rid: int) -> bool:
        """Cancel a request anywhere in its lifecycle: still queued,
        mid-chunked-prefill, or slot-resident (including mid-macro-step —
        the replay loop re-checks slot residency per buffered tick, so a
        cancelled slot's remaining device ticks are dropped on the floor).
        Returns True if the request was live and is now terminated with
        ``finish_reason="cancelled"``; False if ``rid`` is unknown or
        already terminal (idempotent — ``on_finish`` never fires twice)."""
        st = self.metrics.per_request.get(rid)
        if st is None or st.finish_reason is not None:
            return False
        req = self.sched.cancel(rid)
        if req is not None:                  # still queued
            self._terminate(rid, req, "cancelled")
            return True
        pf = self._prefill
        if pf is not None and pf.rid == rid:  # admission in flight
            self._prefill = None
            self._release_prefill_slot(pf.slot)
            self._terminate(rid, pf.req, "cancelled")
            return True
        for slot, rec in self.sched.active.items():
            if rec.rid == rid:               # slot-resident
                with self.mesh:
                    self._finish(slot, "cancelled")
                return True
        return False                         # pragma: no cover — unreachable

    def _lifecycle_sweep(self):
        """Deadline expiry plus ``queue_wait`` age shedding, applied to
        every live request (queued, mid-prefill, slot-resident).

        Runs at the top of each engine tick and again after every
        *replayed* tick of a decode macro-step, so deadlines hold at
        per-tick granularity even with K > 1. Expiry is strict
        (``now - arrival > deadline``) and the decode replay processes a
        tick's emissions before sweeping it, so a natural stop landing on
        the deadline tick finishes ``eos``/``length`` — EOS wins.
        TTFT deadlines only bind while no token has been emitted yet."""
        now = self.tick
        wall = self._clock()

        def expired(req: Request, st: RequestStats) -> bool:
            age = now - req.arrival_time
            wage = (wall - st.arrival_wall
                    if st.arrival_wall is not None else 0.0)
            if st.first_token is None:
                if (req.ttft_deadline_ticks is not None
                        and age > req.ttft_deadline_ticks):
                    return True
                if (req.ttft_deadline_s is not None
                        and wage > req.ttft_deadline_s):
                    return True
            if req.deadline_ticks is not None and age > req.deadline_ticks:
                return True
            if req.deadline_s is not None and wage > req.deadline_s:
                return True
            return False

        sched = self.sched
        per = self.metrics.per_request
        for q in (sched.ready, sched.waiting):
            for item in list(q):
                rid, req = item
                if expired(req, per[rid]):
                    q.remove(item)
                    self._terminate(rid, req, "deadline")
        if (self.serving.overload_policy == "queue_wait"
                and self.serving.queue_wait_ticks):
            # queue_wait admits unconditionally at submit; staleness is
            # bounded here instead — queued longer than the budget = shed.
            W = self.serving.queue_wait_ticks
            for q in (sched.ready, sched.waiting):
                for item in list(q):
                    rid, req = item
                    if now - req.arrival_time > W:
                        q.remove(item)
                        self._terminate(rid, req, "shed")
        pf = self._prefill
        if pf is not None and expired(pf.req, per[pf.rid]):
            self._prefill = None
            self._release_prefill_slot(pf.slot)
            self._terminate(pf.rid, pf.req, "deadline")
        for slot, rec in list(sched.active.items()):
            if expired(rec.req, per[rec.rid]):
                self._finish(slot, "deadline")

    def _apply_injections(self):
        """Consult the chaos injector (test/bench only): injected
        cancellations hit the public :meth:`cancel` path; slot corruption
        NaNs a live slot's float state on device — detection is then the
        macro-step fault lane's job, exactly as for an organic fault."""
        inj = self._injector
        if inj.crash_now(self.tick):
            # Simulated process death (DESIGN.md §12): propagate out of
            # step() with no flush and no cleanup — buffered journal
            # records are lost exactly as a real kill -9 would lose them.
            raise faults_lib.EngineCrash(self.tick)
        live_rids = ([rec.rid for rec in self.sched.active.values()]
                     + [rid for rid, _ in self.sched.ready])
        for rid in inj.cancel_rids(self.tick, live_rids):
            self.cancel(rid)
        for slot in inj.corrupt_slots(self.tick,
                                      list(self.sched.active)):
            if slot in self.sched.active:
                self.pool = self._corrupt_fn(self.pool, jnp.int32(slot))
