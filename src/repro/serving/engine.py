"""Serving engines: continuous batching over a slot-pooled decode cache.

Two engines share one model surface (``repro.models.api``):

* :class:`ServingEngine` — the lockstep reference: one prefill per batch,
  then decode steps in lockstep until every request finishes. Simple,
  exact, and the parity oracle for the continuous engine.
* :class:`ContinuousServingEngine` — the production shape: a
  :class:`Scheduler` owns a fixed pool of ``num_slots`` decode slots;
  requests queue, are admitted into free slots via *chunked prefill*
  (interleaved with decode ticks so long prompts never stall the pool),
  stream tokens per request, and on EOS/max-tokens are evicted by a single
  slot overwrite — no paging.

Why continuous batching is dramatically simpler for SLAY than for KV-cache
models: the constant-state path's per-slot decode state is O(m·dv) per
layer-head *regardless of context length*, so admitting a new request is a
single ``write_slot`` overwrite of a fixed-size block and evicting is a
``reset_slot`` zero — there is no paged KV allocator, no fragmentation, no
copy-out. The KV path rides the same surface with ring-buffer slot resets.

Cache shardings come from ``sharding.serving_cache_sharding`` and depend
only on pool shape — never on which slots are live — so admission/eviction
never reshard (slot-stable contract).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ServingConfig
from repro.distributed import sharding as shd
from repro.models import api


def jit_serve_fns(cfg: ArchConfig, mesh, max_len: int,
                  rules: shd.ShardingRules = shd.DEFAULT_RULES,
                  batch: int | None = None):
    """jit'd (prefill, decode_step) with rule-derived shardings.

    decode_step donates the cache (in-place ring-buffer update on device).
    """
    axes = api.param_axes(cfg)
    p_abs = api.abstract_params(cfg)
    p_sh = shd.logical_to_sharding(mesh, rules, p_abs, axes)
    b_sh = shd.batch_sharding(mesh, rules)

    def _prefill(params, batch_):
        with shd.activation_sharding(mesh, rules):
            return api.prefill(params, cfg, batch_, max_len=max_len)

    pf = jax.jit(_prefill, in_shardings=(p_sh, b_sh), out_shardings=None)
    if batch is not None:
        c_abs = api.abstract_cache(cfg, batch, max_len)
        c_sh = shd.serving_cache_sharding(mesh, rules, c_abs)
    else:
        c_sh = None
    dec = jax.jit(
        lambda params, cache, tok: api.decode_step(params, cfg, cache, tok),
        in_shardings=(p_sh, c_sh, b_sh) if c_sh is not None else None,
        out_shardings=(b_sh, c_sh) if c_sh is not None else None,
        donate_argnums=(1,))
    return pf, dec


@dataclasses.dataclass
class Request:
    prompt: np.ndarray               # (Lp,) int32
    max_new_tokens: int = 32
    eos_id: int = -1                 # -1: never stop early
    arrival_time: float = 0.0        # engine ticks (continuous engine only)
    on_token: Callable[[int, int], None] | None = None  # (rid, token)


def _model_batch(cfg: ArchConfig, tokens: jnp.ndarray) -> dict:
    """Token batch plus zero frontend stand-ins (vision/audio stubs)."""
    batch = {"tokens": tokens}
    B = tokens.shape[0]
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.zeros(
            (B, cfg.num_patches, cfg.d_model), cfg.activation_dtype)
    if cfg.frontend == "audio":
        batch["frame_embeds"] = jnp.zeros(
            (B, cfg.enc_seq, cfg.d_model), cfg.activation_dtype)
    return batch


class ServingEngine:
    """Lockstep reference engine (parity oracle for the continuous path).

    NOTE: batched generate left-pads prompts to a common length, so with
    mixed prompt lengths the pad tokens are visible to the model (seed
    behavior, kept for the oracle). For exact per-request results, call
    with a single request — the continuous engine's parity tests do.
    """

    def __init__(self, cfg: ArchConfig, params, mesh, *, max_len: int = 4096,
                 rules: shd.ShardingRules = shd.DEFAULT_RULES):
        self.cfg, self.params, self.mesh = cfg, params, mesh
        self.max_len = max_len
        self.prefill_fn, self.decode_fn = jit_serve_fns(cfg, mesh, max_len,
                                                        rules)

    def generate(self, requests: list[Request], *,
                 temperature: float = 0.0, seed: int = 0) -> list[np.ndarray]:
        """Run a batch of requests to completion.

        Returns one int32 array per request, of the *actual* generated
        length: up to and including the EOS token when ``eos_id`` fires,
        ``max_new_tokens`` otherwise (no trailing zero padding).
        """
        cfg = self.cfg
        B = len(requests)
        lp = max(len(r.prompt) for r in requests)
        over = max(lp + r.max_new_tokens for r in requests)
        if over > self.max_len:
            # Non-windowed KV rings would silently truncate the context.
            raise ValueError(f"prompt+max_new ({over}) exceeds "
                             f"max_len {self.max_len}")
        # Left-pad prompts to a common length (pad id 0).
        prompts = np.zeros((B, lp), np.int32)
        for i, r in enumerate(requests):
            prompts[i, lp - len(r.prompt):] = r.prompt
        batch = _model_batch(cfg, jnp.asarray(prompts))
        with self.mesh:
            logits, cache = self.prefill_fn(self.params, batch)
            key = jax.random.PRNGKey(seed)
            max_new = max(r.max_new_tokens for r in requests)
            out = np.zeros((B, max_new), np.int32)
            lengths = np.zeros(B, np.int64)
            done = np.zeros(B, bool)
            tok = self._sample(logits, temperature, key)
            for t in range(max_new):
                tok_np = np.asarray(tok[:, 0])
                for i, r in enumerate(requests):
                    if done[i]:
                        continue
                    out[i, t] = tok_np[i]
                    lengths[i] += 1
                    if (t + 1 >= r.max_new_tokens
                            or int(tok_np[i]) == r.eos_id):
                        done[i] = True
                if done.all():
                    break
                key, sub = jax.random.split(key)
                logits, cache = self.decode_fn(self.params, cache, tok)
                tok = self._sample(logits, temperature, sub)
        return [out[i, :lengths[i]] for i in range(B)]

    @staticmethod
    def _sample(logits, temperature: float, key) -> jnp.ndarray:
        logits = logits[:, -1, :]
        if temperature <= 0.0:
            return jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        g = jax.random.categorical(key, logits / temperature)
        return g.astype(jnp.int32)[:, None]


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RequestStats:
    rid: int
    arrival: float                   # ticks
    prompt_len: int = 0
    slot: int | None = None          # pool slot served in
    admitted: float | None = None    # prefill started
    first_token: float | None = None
    finished: float | None = None
    first_token_wall: float | None = None
    arrival_wall: float | None = None

    @property
    def ttft_ticks(self) -> float | None:
        if self.first_token is None:
            return None
        return self.first_token - self.arrival

    @property
    def ttft_s(self) -> float | None:
        if self.first_token_wall is None or self.arrival_wall is None:
            return None
        return self.first_token_wall - self.arrival_wall


@dataclasses.dataclass
class EngineMetrics:
    """Counters the engine updates every tick; ``summary()`` aggregates."""

    num_slots: int = 0
    ticks: int = 0
    decode_ticks: int = 0
    prefill_ticks: int = 0
    tokens_generated: int = 0
    prompt_tokens: int = 0
    requests_completed: int = 0
    queue_depth_sum: int = 0
    queue_depth_max: int = 0
    occupancy_sum: int = 0
    wall_start: float = dataclasses.field(default_factory=time.perf_counter)
    per_request: dict = dataclasses.field(default_factory=dict)

    def sample(self, queue_depth: int, occupancy: int):
        self.queue_depth_sum += queue_depth
        self.queue_depth_max = max(self.queue_depth_max, queue_depth)
        self.occupancy_sum += occupancy

    def summary(self) -> dict:
        wall = max(time.perf_counter() - self.wall_start, 1e-9)
        ttfts = sorted(s.ttft_ticks for s in self.per_request.values()
                       if s.ttft_ticks is not None)
        ttfts_s = sorted(s.ttft_s for s in self.per_request.values()
                         if s.ttft_s is not None)

        def pct(xs, q):
            return xs[min(int(q * len(xs)), len(xs) - 1)] if xs else None

        t = max(self.ticks, 1)
        return {
            "ticks": self.ticks,
            "decode_ticks": self.decode_ticks,
            "prefill_ticks": self.prefill_ticks,
            "requests_completed": self.requests_completed,
            "tokens_generated": self.tokens_generated,
            "prompt_tokens": self.prompt_tokens,
            "wall_s": wall,
            "decode_tokens_per_s": self.tokens_generated / wall,
            "total_tokens_per_s":
                (self.tokens_generated + self.prompt_tokens) / wall,
            "mean_queue_depth": self.queue_depth_sum / t,
            "max_queue_depth": self.queue_depth_max,
            "mean_slot_occupancy":
                self.occupancy_sum / (t * max(self.num_slots, 1)),
            "ttft_ticks_p50": pct(ttfts, 0.50),
            "ttft_ticks_p95": pct(ttfts, 0.95),
            "ttft_s_p50": pct(ttfts_s, 0.50),
            "ttft_s_p95": pct(ttfts_s, 0.95),
        }


@dataclasses.dataclass
class _Slot:
    """One live sequence in the decode pool."""

    rid: int
    req: Request
    last_tok: int
    tokens: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _Prefill:
    """An admission in flight: prompt being absorbed chunk-by-chunk."""

    rid: int
    req: Request
    slot: int
    cache: object                    # per-request (batch=1) decode cache
    offset: int = 0                  # prompt tokens absorbed so far


class Scheduler:
    """Owns the slot pool and the admission queue.

    Policy: FIFO admission into the lowest free slot; at most one prefill
    in flight (chunked, so a long prompt yields to decode ticks between
    chunks); decode and prefill strictly interleave per
    ``decode_ticks_per_prefill`` when both have work.
    """

    def __init__(self, serving: ServingConfig):
        self.serving = serving
        self.free: list[int] = list(range(serving.num_slots))
        self.active: dict[int, _Slot] = {}
        self.waiting: collections.deque = collections.deque()  # (rid, req)
        self.ready: collections.deque = collections.deque()
        self._decode_since_prefill = serving.decode_ticks_per_prefill

    def submit(self, rid: int, req: Request):
        if (self.serving.max_queue
                and len(self.waiting) + len(self.ready)
                >= self.serving.max_queue):
            raise RuntimeError("admission queue full")
        self.waiting.append((rid, req))
        # Keep ordered by (arrival, rid) so a late submission with an
        # earlier arrival_time cannot be head-of-line blocked.
        self.waiting = collections.deque(
            sorted(self.waiting, key=lambda t: (t[1].arrival_time, t[0])))

    def poll_arrivals(self, now: float):
        while self.waiting and self.waiting[0][1].arrival_time <= now:
            self.ready.append(self.waiting.popleft())

    def next_admission(self):
        """Pop the request to admit next, reserving a slot — or None."""
        if not self.ready or not self.free:
            return None
        rid, req = self.ready.popleft()
        return rid, req, self.free.pop(0)

    def evict(self, slot: int):
        del self.active[slot]
        self.free.append(slot)
        self.free.sort()

    @property
    def queue_depth(self) -> int:
        return len(self.ready)

    @property
    def occupancy(self) -> int:
        return len(self.active)

    def want_prefill(self, prefill_inflight: bool) -> bool:
        """Interleave policy: prefill only after enough decode ticks, unless
        there is no decode work at all."""
        has_work = prefill_inflight or (bool(self.ready) and bool(self.free))
        if not has_work:
            return False
        if not self.active:
            return True
        return (self._decode_since_prefill
                >= self.serving.decode_ticks_per_prefill)

    def note_decode(self):
        self._decode_since_prefill += 1

    def note_prefill(self):
        self._decode_since_prefill = 0


class ContinuousServingEngine:
    """Continuous-batching engine over a fixed decode-slot pool.

    Usage::

        eng = ContinuousServingEngine(cfg, params, mesh,
                                      serving=ServingConfig(num_slots=4))
        rids = [eng.submit(r) for r in requests]
        outs, metrics = eng.run()          # rid -> np.ndarray of tokens

    or drive it tick-by-tick with :meth:`step` for external event loops.
    Time is a logical tick counter (one device dispatch per tick); request
    ``arrival_time`` is in ticks, letting benchmarks replay arrival traces
    deterministically on any backend.

    Compile-cache note: the chunked prefill path compiles once per distinct
    chunk length (at most the full-chunk shape plus the ragged final-chunk
    remainders, bounded by ``prefill_chunk``); the non-chunkable fallback
    (yat kinds, SSM/hybrid, frontends) compiles per distinct prompt length.
    Length-bucketed padding for those paths is a tracked ROADMAP item.
    """

    def __init__(self, cfg: ArchConfig, params, mesh, *,
                 serving: ServingConfig = ServingConfig(),
                 rules: shd.ShardingRules = shd.DEFAULT_RULES):
        self.cfg, self.params, self.mesh = cfg, params, mesh
        self.serving = serving
        self.rules = rules
        self.sched = Scheduler(serving)
        self.metrics = EngineMetrics(num_slots=serving.num_slots)
        self.tick = 0
        self._next_rid = 0
        self._outputs: dict[int, list] = {}
        self._prefill: _Prefill | None = None
        self._chunkable = api.supports_chunked_prefill(cfg)

        S, L = serving.num_slots, serving.max_len
        axes = api.param_axes(cfg)
        p_abs = api.abstract_params(cfg)
        p_sh = shd.logical_to_sharding(mesh, rules, p_abs, axes)
        c_abs = api.abstract_cache(cfg, S, L)
        c_sh = shd.serving_cache_sharding(mesh, rules, c_abs)
        b_sh = shd.batch_sharding(mesh, rules)
        with mesh:
            self.pool = jax.device_put(api.init_cache(cfg, S, L), c_sh)
        self._decode_fn = jax.jit(
            lambda p, c, t: api.decode_step(p, cfg, c, t),
            in_shardings=(p_sh, c_sh, b_sh),
            out_shardings=(b_sh, c_sh), donate_argnums=(1,))
        # Slot ops: slot index is a traced scalar -> one compile each, and
        # out-shardings pinned to the pool's (slot-stable, never reshards).
        self._write_fn = jax.jit(
            lambda pool, src, i: api.write_slot(cfg, pool, src, i),
            in_shardings=(c_sh, None, None), out_shardings=c_sh,
            donate_argnums=(0,))
        self._reset_fn = jax.jit(
            lambda pool, i: api.reset_slot(cfg, pool, i),
            in_shardings=(c_sh, None), out_shardings=c_sh,
            donate_argnums=(0,))
        self._chunk_fn = jax.jit(
            lambda p, c, t: api.prefill_chunk(cfg, p, c, t),
            donate_argnums=(1,))
        self._prefill_fn = jax.jit(
            lambda p, b: api.prefill(p, cfg, b, max_len=L))

    # -- submission ---------------------------------------------------------

    def submit(self, req: Request) -> int:
        """Queue a request; returns its request id."""
        if len(req.prompt) + req.max_new_tokens > self.serving.max_len:
            raise ValueError(
                f"prompt+max_new ({len(req.prompt)}+{req.max_new_tokens}) "
                f"exceeds max_len {self.serving.max_len}")
        rid = self._next_rid
        self._next_rid += 1
        self.sched.submit(rid, req)
        st = RequestStats(rid=rid, arrival=req.arrival_time,
                          prompt_len=len(req.prompt))
        st.arrival_wall = time.perf_counter()
        self.metrics.per_request[rid] = st
        self._outputs[rid] = []
        return rid

    # -- engine ticks -------------------------------------------------------

    def step(self) -> bool:
        """One engine tick: a prefill chunk or a decode step (whichever the
        interleave policy picks). Returns False when fully idle."""
        sched = self.sched
        sched.poll_arrivals(self.tick)
        self.metrics.sample(sched.queue_depth, sched.occupancy)
        did = False
        with self.mesh:
            if sched.want_prefill(self._prefill is not None):
                self._prefill_tick()
                sched.note_prefill()
                self.metrics.prefill_ticks += 1
                did = True
            elif sched.active:
                self._decode_tick()
                sched.note_decode()
                self.metrics.decode_ticks += 1
                did = True
        self.tick += 1
        self.metrics.ticks = self.tick
        return did or bool(sched.waiting)

    def run(self, requests: list[Request] | None = None, *,
            max_ticks: int | None = None):
        """Drive to completion. Returns (outputs, metrics summary) where
        outputs maps rid -> int32 array of that request's generated tokens
        (actual length: through EOS inclusive, or max_new_tokens)."""
        for r in requests or ():
            self.submit(r)
        limit = max_ticks if max_ticks is not None else 10_000_000
        while self.tick < limit:
            if not (self.sched.active or self.sched.ready
                    or self.sched.waiting or self._prefill):
                break
            self.step()
        outs = {rid: np.asarray(toks, np.int32)
                for rid, toks in self._outputs.items()}
        return outs, self.metrics.summary()

    # -- internals ----------------------------------------------------------

    def _prefill_tick(self):
        pf = self._prefill
        if pf is None:
            admission = self.sched.next_admission()
            if admission is None:
                return
            rid, req, slot = admission
            pf = _Prefill(rid, req, slot,
                          api.init_cache(self.cfg, 1, self.serving.max_len))
            self._prefill = pf
            self.metrics.per_request[rid].admitted = self.tick
            self.metrics.per_request[rid].slot = slot
        req, prompt = pf.req, np.asarray(pf.req.prompt, np.int32)
        C = self.serving.prefill_chunk
        if self._chunkable and C:
            chunk = prompt[pf.offset:pf.offset + C]
            toks = jnp.asarray(chunk[None, :])
            logits, pf.cache = self._chunk_fn(self.params, pf.cache, toks)
            pf.offset += len(chunk)
        else:
            batch = _model_batch(self.cfg, jnp.asarray(prompt[None, :]))
            logits, pf.cache = self._prefill_fn(self.params, batch)
            pf.offset = len(prompt)
        if pf.offset < len(prompt):
            return                       # more chunks; decode may interleave
        # Prompt fully absorbed: first token, install into the pool slot.
        tok0 = self._sample_token(
            np.asarray(logits[0, -1], np.float32), pf.rid, 0)
        self.pool = self._write_fn(self.pool, pf.cache, jnp.int32(pf.slot))
        self._prefill = None
        self.metrics.prompt_tokens += len(prompt)
        slot_rec = _Slot(pf.rid, req, tok0)
        self.sched.active[pf.slot] = slot_rec
        self._emit(slot_rec, tok0)
        if tok0 == req.eos_id or req.max_new_tokens <= 1:
            self._finish(pf.slot)

    def _decode_tick(self):
        S = self.serving.num_slots
        tok = np.zeros((S, 1), np.int32)
        for slot, rec in self.sched.active.items():
            tok[slot, 0] = rec.last_tok
        logits, self.pool = self._decode_fn(self.params, self.pool,
                                            jnp.asarray(tok))
        rows = np.asarray(logits[:, -1], np.float32)
        for slot in list(self.sched.active):
            rec = self.sched.active[slot]
            t = self._sample_token(rows[slot], rec.rid, len(rec.tokens))
            rec.last_tok = t
            self._emit(rec, t)
            if (t == rec.req.eos_id
                    or len(rec.tokens) >= rec.req.max_new_tokens):
                self._finish(slot)

    def _sample_token(self, row: np.ndarray, rid: int, idx: int) -> int:
        """Greedy, or per-request deterministic Gumbel sampling keyed on
        (engine seed, rid, token index) — independent of slot placement and
        batch composition, so replays are reproducible."""
        T = self.serving.temperature
        if T <= 0.0:
            return int(np.argmax(row))
        rng = np.random.default_rng((self.serving.seed, rid, idx))
        return int(np.argmax(row / T + rng.gumbel(size=row.shape)))

    def _emit(self, rec: _Slot, tok: int):
        rec.tokens.append(tok)
        self._outputs[rec.rid].append(tok)
        self.metrics.tokens_generated += 1
        st = self.metrics.per_request[rec.rid]
        if st.first_token is None:
            st.first_token = self.tick
            st.first_token_wall = time.perf_counter()
        if rec.req.on_token is not None:
            rec.req.on_token(rec.rid, tok)

    def _finish(self, slot: int):
        rec = self.sched.active[slot]
        st = self.metrics.per_request[rec.rid]
        st.finished = self.tick
        self.metrics.requests_completed += 1
        # Eviction = one slot overwrite (constant-state asymmetry: O(m·dv)
        # zeros for SLAY vs an O(max_len) ring zero for KV backends).
        self.pool = self._reset_fn(self.pool, jnp.int32(slot))
        self.sched.evict(slot)
