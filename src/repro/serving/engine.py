"""Batched serving engine.

Two cache regimes, selected by the architecture's attention backend:

* **KV-cache path** (softmax/yat baselines): ring-buffer caches, O(S) memory
  per sequence (window-bounded for local layers).
* **Constant-state path** (SLAY / linear baselines / SSM): O(m·dv) running
  state per layer-head, independent of context length — the paper's
  long-context win. A 500k-token context costs the same decode-state memory
  as a 1k one (DESIGN.md §6 quantifies ~30x vs a 32k KV cache).

The engine drives batched requests: one prefill per batch, then lockstep
decode steps with greedy/temperature sampling; finished sequences are masked
(continuation-batching-lite — at production scale slot reuse would attach
here).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.distributed import sharding as shd
from repro.models import api


def jit_serve_fns(cfg: ArchConfig, mesh, max_len: int,
                  rules: shd.ShardingRules = shd.DEFAULT_RULES,
                  batch: int | None = None):
    """jit'd (prefill, decode_step) with rule-derived shardings.

    decode_step donates the cache (in-place ring-buffer update on device).
    """
    axes = api.param_axes(cfg)
    p_abs = api.abstract_params(cfg)
    p_sh = shd.logical_to_sharding(mesh, rules, p_abs, axes)
    b_sh = shd.batch_sharding(mesh, rules)

    def _prefill(params, batch_):
        with shd.activation_sharding(mesh, rules):
            return api.prefill(params, cfg, batch_, max_len=max_len)

    pf = jax.jit(_prefill, in_shardings=(p_sh, b_sh), out_shardings=None)
    if batch is not None:
        c_abs = api.abstract_cache(cfg, batch, max_len)
        c_sh = shd.cache_sharding(mesh, rules, c_abs)
    else:
        c_sh = None
    dec = jax.jit(
        lambda params, cache, tok: api.decode_step(params, cfg, cache, tok),
        in_shardings=(p_sh, c_sh, b_sh) if c_sh is not None else None,
        out_shardings=(b_sh, c_sh) if c_sh is not None else None,
        donate_argnums=(1,))
    return pf, dec


@dataclasses.dataclass
class Request:
    prompt: np.ndarray               # (Lp,) int32
    max_new_tokens: int = 32
    eos_id: int = -1                 # -1: never stop early


class ServingEngine:
    def __init__(self, cfg: ArchConfig, params, mesh, *, max_len: int = 4096,
                 rules: shd.ShardingRules = shd.DEFAULT_RULES):
        self.cfg, self.params, self.mesh = cfg, params, mesh
        self.max_len = max_len
        self.prefill_fn, self.decode_fn = jit_serve_fns(cfg, mesh, max_len,
                                                        rules)

    def generate(self, requests: list[Request], *,
                 temperature: float = 0.0, seed: int = 0) -> list[np.ndarray]:
        """Run a batch of requests to completion; returns generated ids."""
        cfg = self.cfg
        B = len(requests)
        lp = max(len(r.prompt) for r in requests)
        # Left-pad prompts to a common length (pad id 0).
        prompts = np.zeros((B, lp), np.int32)
        for i, r in enumerate(requests):
            prompts[i, lp - len(r.prompt):] = r.prompt
        batch = {"tokens": jnp.asarray(prompts)}
        if cfg.frontend == "vision":
            batch["patch_embeds"] = jnp.zeros(
                (B, cfg.num_patches, cfg.d_model), cfg.activation_dtype)
        if cfg.frontend == "audio":
            batch["frame_embeds"] = jnp.zeros(
                (B, cfg.enc_seq, cfg.d_model), cfg.activation_dtype)
        with self.mesh:
            logits, cache = self.prefill_fn(self.params, batch)
            key = jax.random.PRNGKey(seed)
            max_new = max(r.max_new_tokens for r in requests)
            out = np.zeros((B, max_new), np.int32)
            done = np.zeros(B, bool)
            tok = self._sample(logits, temperature, key)
            for t in range(max_new):
                out[:, t] = np.where(done, 0, np.asarray(tok[:, 0]))
                for i, r in enumerate(requests):
                    if (t + 1 >= r.max_new_tokens
                            or int(out[i, t]) == r.eos_id):
                        done[i] = True
                if done.all():
                    break
                key, sub = jax.random.split(key)
                logits, cache = self.decode_fn(self.params, cache, tok)
                tok = self._sample(logits, temperature, sub)
        return [out[i, :requests[i].max_new_tokens] for i in range(B)]

    @staticmethod
    def _sample(logits, temperature: float, key) -> jnp.ndarray:
        logits = logits[:, -1, :]
        if temperature <= 0.0:
            return jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        g = jax.random.categorical(key, logits / temperature)
        return g.astype(jnp.int32)[:, None]
