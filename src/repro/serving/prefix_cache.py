"""Content-addressed prefix cache: admit shared prompts by state copy.

Production traffic is dominated by shared system prompts. In SLAY's
linear-time regime a prompt prefix is a *single constant-size (S, z)
state snapshot* (PAPER.md §3) — and the chunked-prefill continuation
machinery (DESIGN.md §9: fp32 linear/SSM carries, exact yat ring-prefix
continuation) means *any* decoder-only config can resume from a stored
chunk-boundary snapshot. So the cache stores batch=1 ``DecodeCache``
snapshots keyed by the sha256 of the prompt-token prefix:

* **Keying.** ``(length, sha256(int32 prefix bytes))``. The raw tokens
  are stored alongside and compared on lookup, so a digest collision can
  never false-hit (the digest function is injectable for exactly that
  test). Proper-prefix entries are only stored/served at chunk-size
  multiples — that keeps the suffix's chunk schedule identical to a cold
  prefill of the same prompt, which is what makes cached-vs-cold streams
  *byte*-identical (same fp op order), not just statistically equal.
* **Full-prompt entries** also carry the last-token logits, so a full
  hit skips prefill entirely: the engine seeds the slot from the
  snapshot and samples token 0 from the stored logits (sampling is keyed
  on (seed, rid, index) — never on how the state was produced).
* **Eviction.** LRU under ``capacity_bytes``; entries referenced by a
  live request (``refs > 0``) are never evicted.

The cache is a plain host-side object and can be shared across engines
(e.g. a warm-up pass populating it for a measured run — how the bench's
``prefix_cached`` rows get a 1.0 hit rate).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


def token_digest(tokens: np.ndarray) -> bytes:
    """sha256 over the canonical int32 little-endian token bytes."""
    a = np.ascontiguousarray(np.asarray(tokens, dtype="<i4"))
    return hashlib.sha256(a.tobytes()).digest()


def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_copy(tree):
    """Deep device copy — snapshots must not alias buffers the engine's
    donating jits (``_chunk_fn``) are about to invalidate."""
    return jax.tree.map(jnp.copy, tree)


@dataclasses.dataclass
class PrefixEntry:
    length: int                       # tokens covered by this snapshot
    tokens: np.ndarray                # (length,) int32 — collision check
    cache: object                     # batch=1 DecodeCache snapshot
    logits: object | None             # (1, 1, V) last-token logits
    nbytes: int
    refs: int = 0                     # live requests seeded from this
    stamp: int = 0                    # LRU clock


class PrefixCache:
    """LRU-bounded, refcounted map: prompt-prefix hash -> state snapshot."""

    def __init__(self, capacity_bytes: int,
                 digest_fn: Callable[[np.ndarray], bytes] = token_digest):
        self.capacity_bytes = int(capacity_bytes)
        self._digest = digest_fn
        self._entries: dict[tuple[int, bytes], PrefixEntry] = {}
        self._clock = 0
        self.hits = 0
        self.misses = 0
        self.tokens_reused = 0
        self.evictions = 0

    # -- bookkeeping -----------------------------------------------------

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def nbytes(self) -> int:
        return sum(e.nbytes for e in self._entries.values())

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    # -- lookup ----------------------------------------------------------

    def lookup(self, tokens, *, chunk: int) -> PrefixEntry | None:
        """Longest cached prefix of ``tokens``, or None (counts a miss).

        Candidates: the full prompt, then chunk-size multiples descending
        (proper prefixes at other lengths are never served — the suffix
        chunk schedule must match a cold prefill's). Tokens are compared
        outright on digest match, so a collision cannot false-hit.
        """
        toks = np.asarray(tokens, np.int32).reshape(-1)
        n = len(toks)
        cands = [n]
        if chunk > 0:
            top = ((n - 1) // chunk) * chunk
            cands += list(range(top, 0, -chunk))
        for ln in cands:
            e = self._entries.get((ln, self._digest(toks[:ln])))
            if e is None or not np.array_equal(e.tokens, toks[:ln]):
                continue
            if ln == n and e.logits is None:
                # A full-length entry without stored logits cannot serve a
                # full hit (no way to sample token 0); fall through to the
                # proper-prefix candidates instead.
                continue
            e.stamp = self._tick()
            self.hits += 1
            self.tokens_reused += ln
            return e
        self.misses += 1
        return None

    def acquire(self, entry: PrefixEntry) -> None:
        entry.refs += 1

    def release(self, entry: PrefixEntry) -> None:
        entry.refs = max(entry.refs - 1, 0)

    # -- insert / evict --------------------------------------------------

    def insert(self, tokens, cache, *, logits=None, copy: bool = True
               ) -> PrefixEntry | None:
        """Store a snapshot of the state after absorbing ``tokens``.

        ``copy=True`` deep-copies the cache/logits (callers inside the
        engine hold buffers that the next donating dispatch invalidates).
        Returns the entry, or None if it cannot fit the budget even after
        evicting every unreferenced entry. An existing identical key just
        refreshes its LRU stamp (first snapshot wins — entries for the
        same (length, digest) are byte-identical by construction).
        """
        toks = np.asarray(tokens, np.int32).reshape(-1)
        key = (len(toks), self._digest(toks))
        if key in self._entries:
            e = self._entries[key]
            e.stamp = self._tick()
            if e.logits is None and logits is not None:
                # Upgrade a proper-prefix entry (stored without logits by
                # a longer prompt) into a full-hit-capable one.
                lg = jnp.copy(logits) if copy else logits
                e.logits = lg
                e.nbytes += tree_bytes(lg)
            return e
        if copy:
            cache = tree_copy(cache)
            logits = None if logits is None else jnp.copy(logits)
        nbytes = tree_bytes(cache) + (0 if logits is None
                                      else tree_bytes(logits))
        if not self._make_room(nbytes):
            return None
        e = PrefixEntry(len(toks), toks.copy(), cache, logits, nbytes,
                        stamp=self._tick())
        self._entries[key] = e
        return e

    def _make_room(self, nbytes: int) -> bool:
        if self.capacity_bytes <= 0:
            return False
        while self.nbytes + nbytes > self.capacity_bytes:
            victims = [e for e in self._entries.values() if e.refs == 0]
            if not victims:
                return False
            victim = min(victims, key=lambda e: e.stamp)
            for k, v in list(self._entries.items()):
                if v is victim:
                    del self._entries[k]
                    self.evictions += 1
                    break
        return True

    # -- durability / audit (DESIGN.md §12) ------------------------------

    def entries(self) -> list[PrefixEntry]:
        """Stable (LRU-stamp) ordered view of live entries — used by the
        engine checkpoint to persist the cache index."""
        return sorted(self._entries.values(), key=lambda e: e.stamp)

    def live_refs(self) -> int:
        """Total refcount across entries. The engine's ``debug_audit``
        asserts this equals its count of live per-slot pins at the end of
        every ``run()`` — a leaked pin would wedge eviction forever."""
        return sum(e.refs for e in self._entries.values())

    # -- metrics ---------------------------------------------------------

    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        return {"entries": len(self), "bytes": self.nbytes,
                "hits": self.hits, "misses": self.misses,
                "hit_rate": self.hit_rate(),
                "tokens_reused": self.tokens_reused,
                "evictions": self.evictions}
