"""AST jit-safety / determinism linter (DESIGN.md §14).

Rule engine over the repo's python source. The load-bearing rule is
SYNC001 — host-synchronizing calls inside *jit regions*: the serving hot
loop's ≤1/K host-syncs-per-token contract (§7) dies silently if someone
adds an ``.item()`` three calls deep inside the jitted macro-step. The
linter builds a cross-module call graph, seeds it with every jit root it
can see (``jax.jit`` / ``lax.scan``-family bodies / ``pallas_call``
kernels / ``custom_vjp`` functions), propagates reachability, and flags
host syncs only inside reachable code.

Rules:

SYNC001  host sync reachable from a jit region: ``.item()``,
         ``.tolist()``, ``.block_until_ready()``, ``np.asarray``/
         ``np.array``/``np.copy``, ``jax.device_get``, and
         ``float()``/``int()``/``bool()`` applied directly to a function
         parameter (the static approximation of "cast on a tracer").
RNG001   unseeded randomness anywhere: ``np.random.default_rng()`` with
         no seed, legacy global-state ``np.random.<draw>()``, stdlib
         ``random.<draw>()``. Generalizes the old tests-only conftest
         guard to src/ and benchmarks/.
CLK001   wall-clock read (``time.time``/``perf_counter``/``monotonic``,
         ``datetime.now``) inside the serving package anywhere but the
         injectable-clock surface — a *default parameter value* is the
         surface (``clock=time.perf_counter``); a call in a body bypasses
         the injection and breaks the §12 FakeClock restore drills.
TAG001   two fold_in substream-tag constants (``*TAG*`` int assignments,
         e.g. ``SPEC_TAG_DRAFT``) in the same package share a value —
         the §13 substreams would collide and stop being independent.

Static analysis is approximate by design: name resolution follows
module-level ``import``/``from import`` bindings across the scanned
set; method calls and dynamic dispatch are not followed. False positives
go in the committed suppressions baseline with a reason.
"""
from __future__ import annotations

import ast
import dataclasses
import os

from repro.analysis.findings import Finding, relpath

# Call targets that make a traced function a *root* whose callee runs
# under jit/scan/pallas: (dotted-suffix, positional index of the fn arg).
_ROOT_CALLS = {
    "jax.jit": 0, "jit": 0,
    "jax.lax.scan": 0, "lax.scan": 0,
    "jax.lax.while_loop": 0, "lax.while_loop": 0,   # cond fn
    "jax.lax.fori_loop": 2, "lax.fori_loop": 2,
    "jax.lax.map": 0, "lax.map": 0,
    "pl.pallas_call": 0, "pallas_call": 0,
    "jax.custom_vjp": 0, "custom_vjp": 0,
    "jax.custom_jvp": 0, "custom_jvp": 0,
    "jax.eval_shape": 0,
}
# while_loop's body is arg 1, cond arg 0; switch/cond take several fns.
_MULTI_FN_ROOTS = {
    "jax.lax.while_loop": (0, 1), "lax.while_loop": (0, 1),
    "jax.lax.cond": (1, 2), "lax.cond": (1, 2),
    "jax.lax.switch": None, "lax.switch": None,   # all args from 1 on
}

_HOST_SYNC_ATTRS = {"item", "tolist", "block_until_ready"}
_HOST_SYNC_CALLS = {
    "np.asarray", "np.array", "np.copy", "numpy.asarray", "numpy.array",
    "jax.device_get", "jax.block_until_ready",
}
_CAST_NAMES = {"float", "int", "bool"}

_NP_RANDOM_GLOBAL_DRAWS = {
    "rand", "randn", "randint", "random", "random_sample", "sample",
    "choice", "shuffle", "permutation", "uniform", "normal",
    "standard_normal", "beta", "binomial", "poisson", "seed",
}
_STDLIB_RANDOM_DRAWS = {
    "random", "randint", "randrange", "uniform", "choice", "choices",
    "shuffle", "sample", "gauss", "normalvariate", "seed", "betavariate",
}
_CLOCK_CALLS = {
    "time.time", "time.perf_counter", "time.monotonic",
    "time.process_time", "time.time_ns", "time.perf_counter_ns",
    "time.monotonic_ns", "datetime.now", "datetime.utcnow",
    "datetime.datetime.now", "datetime.datetime.utcnow",
}


@dataclasses.dataclass
class Options:
    """Scan configuration (defaults match the repo contract)."""

    # CLK001 applies under these repo-relative path prefixes only: the
    # serving stack must route every wall read through the injectable
    # clock; benchmarks/launch legitimately measure wall time.
    clock_paths: tuple = ("src/repro/serving/",)
    # Paths skipped entirely (known-bad lint fixtures, caches).
    exclude_parts: tuple = ("__pycache__", "tests/fixtures/")


def _dotted(node: ast.AST) -> str | None:
    """'a.b.c' for Name/Attribute chains, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _match_suffix(dotted: str | None, table) -> str | None:
    """Return the table key that equals ``dotted`` exactly."""
    if dotted is None:
        return None
    return dotted if dotted in table else None


@dataclasses.dataclass
class _FnInfo:
    node: ast.AST                 # FunctionDef / AsyncFunctionDef / Lambda
    module: "_ModuleInfo"
    name: str                     # "" for lambdas
    reachable: bool = False


@dataclasses.dataclass
class _ModuleInfo:
    path: str                     # repo-relative posix
    dotted: str                   # e.g. "repro.serving.engine"
    tree: ast.Module = None
    # module-level binding -> dotted module it refers to
    import_mods: dict = dataclasses.field(default_factory=dict)
    # local name -> (module dotted, original name)
    import_names: dict = dataclasses.field(default_factory=dict)
    # top-level function name -> _FnInfo
    functions: dict = dataclasses.field(default_factory=dict)


def _module_dotted(rel: str) -> str:
    """Map a repo-relative path to the dotted module name used in
    imports (``src/repro/x.py`` -> ``repro.x``; ``tests/x.py`` ->
    ``tests.x``)."""
    p = rel[:-3] if rel.endswith(".py") else rel
    if p.startswith("src/"):
        p = p[len("src/"):]
    if p.endswith("/__init__"):
        p = p[: -len("/__init__")]
    return p.replace("/", ".")


class _Graph:
    """Cross-module call graph with jit-reachability propagation."""

    def __init__(self, modules: list[_ModuleInfo]):
        self.modules = {m.dotted: m for m in modules}
        self.fn_of_node: dict[int, _FnInfo] = {}
        for m in modules:
            for fn in m.functions.values():
                self.fn_of_node[id(fn.node)] = fn

    def resolve(self, mod: _ModuleInfo, dotted: str) -> _FnInfo | None:
        """Resolve a call target 'f' or 'alias.f' to a scanned function."""
        parts = dotted.split(".")
        if len(parts) == 1:
            name = parts[0]
            if name in mod.functions:
                return mod.functions[name]
            if name in mod.import_names:
                src_mod, orig = mod.import_names[name]
                target = self.modules.get(src_mod)
                if target:
                    return target.functions.get(orig)
            return None
        if len(parts) == 2 and parts[0] in mod.import_mods:
            target = self.modules.get(mod.import_mods[parts[0]])
            if target:
                return target.functions.get(parts[1])
        return None


def _collect_module(path: str, rel: str, src: str | None = None):
    if src is None:
        with open(path, encoding="utf-8") as fh:
            src = fh.read()
    tree = ast.parse(src, filename=rel)
    info = _ModuleInfo(path=rel, dotted=_module_dotted(rel), tree=tree)
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                info.import_mods[a.asname or a.name.split(".")[0]] = a.name
        elif isinstance(node, ast.ImportFrom) and node.module:
            if node.level:
                continue                     # no relative imports in repo
            for a in node.names:
                bound = a.asname or a.name
                # `from pkg import mod` may bind a module; record both
                # interpretations — resolution tries functions first.
                info.import_mods.setdefault(bound,
                                            f"{node.module}.{a.name}")
                info.import_names[bound] = (node.module, a.name)
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            info.functions[node.name] = _FnInfo(node=node, module=info,
                                                name=node.name)
    return info


_SCALAR_ANNOTATIONS = {"bool", "int", "float", "str", "bytes", "None"}


def _is_scalar_annotation(ann: ast.AST | None) -> bool:
    """True when an annotation names only static python scalars (e.g.
    ``bool``, ``int | None``) — such a parameter is never a tracer, so
    casting it is not a host sync."""
    if ann is None:
        return False
    if isinstance(ann, ast.Name):
        return ann.id in _SCALAR_ANNOTATIONS
    if isinstance(ann, ast.Constant):
        return ann.value is None or isinstance(ann.value, str)
    if isinstance(ann, ast.BinOp) and isinstance(ann.op, ast.BitOr):
        return (_is_scalar_annotation(ann.left)
                and _is_scalar_annotation(ann.right))
    if (isinstance(ann, ast.Subscript)
            and _dotted(ann.value) in ("Optional", "typing.Optional")):
        return _is_scalar_annotation(ann.slice)
    return False


def _fn_args(node) -> set[str]:
    """Parameter names that could be tracers (scalar-annotated params
    are excluded — see :func:`_is_scalar_annotation`)."""
    if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                         ast.Lambda)):
        a = node.args
        args = a.posonlyargs + a.args + a.kwonlyargs
        names = [x.arg for x in args
                 if not _is_scalar_annotation(getattr(x, "annotation",
                                                      None))]
        if a.vararg:
            names.append(a.vararg.arg)
        if a.kwarg:
            names.append(a.kwarg.arg)
        return set(names)
    return set()


def _fn_targets(call: ast.Call) -> list[ast.AST]:
    """Function-valued arguments of a jit-root call."""
    dotted = _dotted(call.func)
    keys = []
    if dotted in _MULTI_FN_ROOTS:
        idxs = _MULTI_FN_ROOTS[dotted]
        if idxs is None:
            keys = list(range(1, len(call.args)))
        else:
            keys = [i for i in idxs if i < len(call.args)]
    elif dotted in _ROOT_CALLS:
        i = _ROOT_CALLS[dotted]
        if i < len(call.args):
            keys = [i]
    out = []
    for i in keys:
        arg = call.args[i]
        # functools.partial(f, ...) / partial(f, ...): unwrap to f.
        if (isinstance(arg, ast.Call)
                and _dotted(arg.func) in ("functools.partial", "partial")
                and arg.args):
            arg = arg.args[0]
        out.append(arg)
    return out


def _decorated_as_root(node) -> bool:
    for dec in getattr(node, "decorator_list", []):
        target = dec.func if isinstance(dec, ast.Call) else dec
        dotted = _dotted(target)
        if dotted in ("jax.jit", "jit", "jax.custom_vjp", "custom_vjp",
                      "jax.custom_jvp", "custom_jvp"):
            return True
        # @functools.partial(jax.jit, ...) and friends.
        if (isinstance(dec, ast.Call)
                and _dotted(dec.func) in ("functools.partial", "partial")
                and dec.args and _dotted(dec.args[0]) in (
                    "jax.jit", "jit", "jax.custom_vjp", "custom_vjp",
                    "jax.custom_jvp", "custom_jvp")):
            return True
    return False


def _propagate_reachability(graph: _Graph):
    """Seed jit roots, then close over same/cross-module calls."""
    work: list[_FnInfo] = []

    def seed(fninfo):
        if fninfo and not fninfo.reachable:
            fninfo.reachable = True
            work.append(fninfo)

    for mod in graph.modules.values():
        # Decorator roots.
        for fn in mod.functions.values():
            if _decorated_as_root(fn.node):
                seed(fn)
        # Call-site roots (jax.jit(f), lax.scan(f,...), f.defvjp(a, b)).
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            dotted = _dotted(node.func)
            if dotted and dotted.endswith(".defvjp"):
                for arg in node.args:
                    t = _dotted(arg)
                    if t:
                        seed(graph.resolve(mod, t))
                continue
            for target in _fn_targets(node):
                if isinstance(target, ast.Lambda):
                    # Anonymous jit region: treat the lambda body as its
                    # own reachable function.
                    fn = _FnInfo(node=target, module=mod, name="<lambda>")
                    graph.fn_of_node[id(target)] = fn
                    seed(fn)
                else:
                    t = _dotted(target)
                    if t:
                        seed(graph.resolve(mod, t))

    while work:
        fn = work.pop()
        for node in ast.walk(fn.node):
            if isinstance(node, ast.Call):
                t = _dotted(node.func)
                if t:
                    callee = graph.resolve(fn.module, t)
                    seed(callee)


def _scan_host_syncs(graph: _Graph) -> list[Finding]:
    out = []
    for fn in list(graph.fn_of_node.values()):
        if not fn.reachable:
            continue
        params = _fn_args(fn.node)
        body = (fn.node.body if not isinstance(fn.node, ast.Lambda)
                else [fn.node.body])
        for stmt in body:
            for node in ast.walk(stmt):
                if not isinstance(node, ast.Call):
                    continue
                dotted = _dotted(node.func)
                where = fn.name or "<lambda>"
                if (isinstance(node.func, ast.Attribute)
                        and node.func.attr in _HOST_SYNC_ATTRS
                        and not node.args):
                    out.append(Finding(
                        rule="SYNC001", path=fn.module.path,
                        line=node.lineno, symbol=where,
                        message=(f".{node.func.attr}() host sync inside "
                                 f"jit-reachable {where}()")))
                elif dotted in _HOST_SYNC_CALLS:
                    out.append(Finding(
                        rule="SYNC001", path=fn.module.path,
                        line=node.lineno, symbol=where,
                        message=(f"{dotted}() host materialization "
                                 f"inside jit-reachable {where}()")))
                elif (dotted in _CAST_NAMES and len(node.args) == 1
                      and isinstance(node.args[0], ast.Name)
                      and node.args[0].id in params):
                    out.append(Finding(
                        rule="SYNC001", path=fn.module.path,
                        line=node.lineno, symbol=where,
                        message=(f"{dotted}({node.args[0].id}) casts a "
                                 f"parameter of jit-reachable {where}() "
                                 f"— host sync on a tracer")))
    return out


def _scan_rng(mod: _ModuleInfo) -> list[Finding]:
    out = []
    # Which local names are the stdlib `random` module?
    stdlib_random = {alias for alias, m in mod.import_mods.items()
                     if m == "random"}
    np_aliases = {alias for alias, m in mod.import_mods.items()
                  if m in ("numpy", "numpy.random")}
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        dotted = _dotted(node.func)
        if not dotted:
            continue
        parts = dotted.split(".")
        # np.random.default_rng() with no seed (or an explicit None).
        if parts[-1] == "default_rng" and (
                parts[0] in np_aliases or "random" in parts[:-1]):
            seed_kw = next((k for k in node.keywords
                            if k.arg in ("seed", None)), None)
            unseeded = not node.args and seed_kw is None
            explicit_none = (node.args
                             and isinstance(node.args[0], ast.Constant)
                             and node.args[0].value is None)
            if unseeded or explicit_none:
                out.append(Finding(
                    rule="RNG001", path=mod.path, line=node.lineno,
                    symbol="default_rng",
                    message="np.random.default_rng() without an explicit "
                            "seed — unpinned randomness"))
        # Legacy global-state numpy draws: np.random.<draw>(...).
        elif (len(parts) >= 3 and parts[0] in np_aliases
              and parts[-2] == "random"
              and parts[-1] in _NP_RANDOM_GLOBAL_DRAWS):
            out.append(Finding(
                rule="RNG001", path=mod.path, line=node.lineno,
                symbol=f"np.random.{parts[-1]}",
                message=f"global-state np.random.{parts[-1]}() — use a "
                        f"seeded np.random.default_rng(seed)"))
        # Bare stdlib random.<draw>(...).
        elif (len(parts) == 2 and parts[0] in stdlib_random
              and parts[1] in _STDLIB_RANDOM_DRAWS):
            out.append(Finding(
                rule="RNG001", path=mod.path, line=node.lineno,
                symbol=f"random.{parts[1]}",
                message=f"stdlib random.{parts[1]}() draws from hidden "
                        f"global state — use a seeded generator"))
    return out


def _scan_clock(mod: _ModuleInfo, opts: Options) -> list[Finding]:
    if not any(mod.path.startswith(p) for p in opts.clock_paths):
        return []
    out = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        dotted = _dotted(node.func)
        if dotted in _CLOCK_CALLS:
            out.append(Finding(
                rule="CLK001", path=mod.path, line=node.lineno,
                symbol=dotted,
                message=(f"{dotted}() wall-clock read in the serving "
                         f"stack — route through the injectable clock "
                         f"(a default parameter value is the only "
                         f"allowed reference)")))
    return out


def _scan_tags(modules: list[_ModuleInfo]) -> list[Finding]:
    out = []
    by_dir: dict[str, dict[int, tuple[str, str, int]]] = {}
    for mod in modules:
        d = os.path.dirname(mod.path)
        seen = by_dir.setdefault(d, {})
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Assign) or len(node.targets) != 1:
                continue
            t = node.targets[0]
            if not (isinstance(t, ast.Name) and "TAG" in t.id
                    and t.id.isupper()):
                continue
            if not (isinstance(node.value, ast.Constant)
                    and isinstance(node.value.value, int)):
                continue
            val = node.value.value
            if val in seen and seen[val][0] != t.id:
                prev_name, prev_path, prev_line = seen[val]
                out.append(Finding(
                    rule="TAG001", path=mod.path, line=node.lineno,
                    symbol=t.id,
                    message=(f"substream tag {t.id}={val} collides with "
                             f"{prev_name} ({prev_path}:{prev_line}) — "
                             f"fold_in substreams would coincide")))
            else:
                seen.setdefault(val, (t.id, mod.path, node.lineno))
    return out


def iter_python_files(root: str, subdirs, opts: Options):
    for sub in subdirs:
        base = os.path.join(root, sub)
        for dirpath, _dirnames, filenames in os.walk(base):
            for fname in sorted(filenames):
                if not fname.endswith(".py"):
                    continue
                full = os.path.join(dirpath, fname)
                rel = relpath(full, root)
                if any(part in rel for part in opts.exclude_parts):
                    continue
                yield full, rel


def scan(root: str, subdirs=("src", "benchmarks", "tests", "tools"),
         opts: Options | None = None,
         extra_sources: list[tuple[str, str]] | None = None
         ) -> list[Finding]:
    """Run every jitlint rule; returns findings sorted by location.

    ``extra_sources`` is a list of (repo-relative-path, source-text)
    pairs scanned *in addition* to the on-disk tree (fixture tests use
    it to inject known-bad snippets without touching the real scan).
    """
    opts = opts or Options()
    modules: list[_ModuleInfo] = []
    for full, rel in iter_python_files(root, subdirs, opts):
        modules.append(_collect_module(full, rel))
    for rel, src in (extra_sources or []):
        modules.append(_collect_module(rel, rel, src=src))
    graph = _Graph(modules)
    _propagate_reachability(graph)
    findings = _scan_host_syncs(graph)
    for mod in modules:
        findings += _scan_rng(mod)
        findings += _scan_clock(mod, opts)
    findings += _scan_tags(modules)
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))
