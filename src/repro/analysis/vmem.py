"""Pallas VMEM budget checker (DESIGN.md §14, budget from §3).

Every Pallas kernel in ``repro.kernels`` pipelines HBM blocks through
VMEM; the per-core budget is ~16 MB (DESIGN.md §3). A BlockSpec edit that
silently blows past it compiles fine in ``interpret=True`` CI and then
dies (or silently spills) on real hardware — exactly the class of
regression a static check should catch before merge.

Mechanism: the kernel modules all share the ``jax.experimental.pallas``
module object (``from jax.experimental import pallas as pl``), so the
checker temporarily swaps ``pallas_call`` for a recorder, runs each
module's *private impl* (``_fwd_impl``/``_bwd_impl``/…, plain functions —
the public entry points are jitted and would cache-skip the recorder)
under :func:`jax.eval_shape` at pinned serving-representative shapes, and
computes per-grid-step VMEM from the recorded BlockSpecs:

    footprint = 2 × (Σ in-block + Σ out-block bytes)   # double-buffered
              + Σ scratch bytes                        # persistent

Checks:

VMEM001  footprint over the §3 per-core budget.
VMEM002  footprint drifted from the committed per-kernel baseline
         (``vmem_baseline.json``) — intentional BlockSpec changes must
         regenerate it (``tools/lint_contracts.py --update-vmem-baseline``)
         so the diff is reviewed.
VMEM003  baseline/probe set out of sync: kernel missing from the
         baseline, or a stale baseline entry for a kernel that no longer
         exists.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import json
import os

import numpy as np

from repro.analysis.findings import Finding

# DESIGN.md §3: ~16 MB usable VMEM per TensorCore.
VMEM_BUDGET_BYTES = 16 * 1024 * 1024

DEFAULT_BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "vmem_baseline.json")

# Serving-representative probe shapes (match DESIGN.md §3's sizing table):
# d = head_dim, dv = value dim, m = R·P·D feature dim, T = chunk,
# bh/bk = q/kv head rows (GQA group 2), L = tokens, n = flat token count.
_D, _DV, _M, _T, _L = 128, 128, 384, 256, 512
_BH, _BK = 4, 2
_DEC_BK, _DEC_G = 8, 2
_N, _BLOCK = 512, 256


@dataclasses.dataclass(frozen=True)
class KernelFootprint:
    """Per-grid-step VMEM bytes for one recorded ``pallas_call``."""

    name: str            # "<module>.<kernel body fn>", e.g. "slay_scan._kernel"
    in_bytes: int        # Σ input block bytes (single copy)
    out_bytes: int       # Σ output block bytes (single copy)
    scratch_bytes: int   # Σ scratch_shapes bytes
    grid: tuple

    @property
    def total_bytes(self) -> int:
        # In/out blocks are double-buffered by the Pallas pipeline;
        # scratch is a single persistent allocation.
        return 2 * (self.in_bytes + self.out_bytes) + self.scratch_bytes


def _nbytes(shape, dtype) -> int:
    n = 1
    for dim in shape:
        n *= int(dim)
    return n * int(np.dtype(dtype).itemsize)


def _aslist(x):
    if x is None:
        return []
    return list(x) if isinstance(x, (list, tuple)) else [x]


def _body_name(kernel) -> str:
    while isinstance(kernel, functools.partial):
        kernel = kernel.func
    return getattr(kernel, "__name__", repr(kernel))


@contextlib.contextmanager
def record_pallas_calls(records: list, module_label: str):
    """Swap ``jax.experimental.pallas.pallas_call`` for a recorder.

    The stub skips kernel tracing entirely and returns zeros of
    ``out_shape`` — enough for :func:`jax.eval_shape` to keep flowing
    through the surrounding impl code.
    """
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    real = pl.pallas_call

    def recorder(kernel, *, grid=None, in_specs=None, out_specs=None,
                 out_shape=None, scratch_shapes=None, **_kwargs):
        def run(*args):
            outs = _aslist(out_shape)
            in_bytes = 0
            for spec, arg in zip(_aslist(in_specs), args):
                in_bytes += _nbytes(spec.block_shape, arg.dtype)
            out_bytes = 0
            for spec, sds in zip(_aslist(out_specs), outs):
                out_bytes += _nbytes(spec.block_shape, sds.dtype)
            scratch_bytes = 0
            for ref in _aslist(scratch_shapes):
                scratch_bytes += _nbytes(ref.shape, ref.dtype)
            records.append(KernelFootprint(
                name=f"{module_label}.{_body_name(kernel)}",
                in_bytes=in_bytes, out_bytes=out_bytes,
                scratch_bytes=scratch_bytes,
                grid=tuple(grid) if grid is not None else ()))
            zeros = [jnp.zeros(s.shape, s.dtype) for s in outs]
            if isinstance(out_shape, (list, tuple)):
                return tuple(zeros)
            return zeros[0]
        return run

    pl.pallas_call = recorder
    try:
        yield
    finally:
        pl.pallas_call = real


def _probe_all() -> list[KernelFootprint]:
    """Run every kernel module's impls under eval_shape; return records."""
    import jax
    import jax.numpy as jnp

    from repro.core.features import SlayFeatureConfig
    from repro.kernels import decode_step, feature_map, slay_fused, slay_scan

    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct
    cfg = SlayFeatureConfig(head_dim=_D)
    records: list[KernelFootprint] = []

    def run(label, impl, *args):
        with record_pallas_calls(records, label):
            jax.eval_shape(impl, *args)

    # slay_scan: feature-level chunked scan (fwd + two bwd kernels).
    st = slay_scan.ScanStatics(chunk_size=_T, delta=1e-6, interpret=True)
    qf, kf = sds((_BH, _L, _M), f32), sds((_BK, _L, _M), f32)
    v = sds((_BK, _L, _DV), f32)
    y, den = sds((_BH, _L, _DV), f32), sds((_BH, _L), f32)
    run("slay_scan", functools.partial(slay_scan._fwd_impl, st), qf, kf, v)
    run("slay_scan", functools.partial(slay_scan._bwd_impl, st),
        qf, kf, v, y, den, y)

    # feature_map: fused Ψ(u) (fwd + bwd).
    mst = feature_map._MapStatics(
        feat=slay_fused.statics_for(cfg, chunk_size=_T, delta=1e-6,
                                    interpret=True).feat,
        block_tokens=_BLOCK, interpret=True)
    u = sds((_N, _D), f32)
    anchors = sds((mst.feat.num_anchors, _D), f32)
    omegas = sds((mst.feat.num_prf, _D), f32)
    dpsi = sds((_N, _M), f32)
    run("feature_map", functools.partial(feature_map._fwd_impl, mst),
        u, anchors, omegas)
    run("feature_map", functools.partial(feature_map._bwd_impl, mst),
        u, anchors, omegas, dpsi)

    # slay_fused: megakernel (fwd + two bwd kernels) on raw q/k.
    fst = slay_fused.statics_for(cfg, chunk_size=_T, delta=1e-6,
                                 interpret=True)
    q, k = sds((_BH, _L, _D), f32), sds((_BK, _L, _D), f32)
    run("slay_fused", functools.partial(slay_fused._fwd_impl, fst),
        q, k, v, anchors, omegas)
    run("slay_fused", functools.partial(slay_fused._bwd_impl, fst),
        q, k, v, anchors, omegas, y, den, y)

    # decode_step: one-token serving step (plain + active-masked).
    dst = decode_step.DecodeStatics(delta=1e-6, interpret=True)
    dqf = sds((_DEC_BK * _DEC_G, _M), f32)
    dkf, dvv = sds((_DEC_BK, _M), f32), sds((_DEC_BK, _DV), f32)
    s = sds((_DEC_BK, _M, _DV), f32)
    z = sds((_DEC_BK, _M), f32)
    active = sds((_DEC_BK,), jnp.int32)
    run("decode_step", functools.partial(decode_step._decode_impl, dst),
        dqf, dkf, dvv, s, z)
    run("decode_step", functools.partial(decode_step._decode_masked, dst),
        dqf, dkf, dvv, s, z, active)

    return records


def probe_footprints() -> dict[str, KernelFootprint]:
    """Footprints keyed by kernel name; duplicates keep the max (a body
    reused at several sites is budgeted by its worst site)."""
    out: dict[str, KernelFootprint] = {}
    for rec in _probe_all():
        prev = out.get(rec.name)
        if prev is None or rec.total_bytes > prev.total_bytes:
            out[rec.name] = rec
    return out


def load_vmem_baseline(path: str = DEFAULT_BASELINE) -> dict[str, int]:
    with open(path) as fh:
        raw = json.load(fh)
    return {k: int(v) for k, v in raw.get("kernels", {}).items()}


def write_vmem_baseline(footprints: dict[str, KernelFootprint],
                        path: str = DEFAULT_BASELINE) -> None:
    payload = {
        "comment": "per-grid-step VMEM bytes (2x in/out blocks + scratch) "
                   "at the pinned probe shapes in analysis/vmem.py; "
                   "regenerate with tools/lint_contracts.py "
                   "--update-vmem-baseline",
        "budget_bytes": VMEM_BUDGET_BYTES,
        "kernels": {k: footprints[k].total_bytes
                    for k in sorted(footprints)},
    }
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=False)
        fh.write("\n")


def check(footprints: dict[str, KernelFootprint] | None = None,
          baseline: dict[str, int] | None = None,
          budget: int = VMEM_BUDGET_BYTES) -> list[Finding]:
    """Run VMEM001/002/003 over probed footprints vs the baseline."""
    if footprints is None:
        footprints = probe_footprints()
    if baseline is None:
        baseline = (load_vmem_baseline()
                    if os.path.exists(DEFAULT_BASELINE) else {})
    findings = []
    label = "analysis/vmem"
    for name in sorted(footprints):
        fp = footprints[name]
        if fp.total_bytes > budget:
            findings.append(Finding(
                rule="VMEM001", path=label, line=0, symbol=name,
                message=(f"{fp.total_bytes/2**20:.2f} MiB per grid step "
                         f"exceeds the {budget/2**20:.0f} MiB §3 budget "
                         f"(in={fp.in_bytes}, out={fp.out_bytes}, "
                         f"scratch={fp.scratch_bytes})")))
        if name not in baseline:
            findings.append(Finding(
                rule="VMEM003", path=label, line=0, symbol=name,
                message=f"kernel missing from vmem_baseline.json "
                        f"(measured {fp.total_bytes} B) — regenerate "
                        f"the baseline"))
        elif baseline[name] != fp.total_bytes:
            findings.append(Finding(
                rule="VMEM002", path=label, line=0, symbol=name,
                message=(f"footprint {fp.total_bytes} B != baseline "
                         f"{baseline[name]} B — BlockSpec change; review "
                         f"and regenerate the baseline")))
    for name in sorted(set(baseline) - set(footprints)):
        findings.append(Finding(
            rule="VMEM003", path=label, line=0, symbol=name,
            message="stale vmem_baseline.json entry: kernel no longer "
                    "probed — regenerate the baseline"))
    return findings
