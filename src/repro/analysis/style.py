"""Minimal style pass — the in-container ruff fallback (DESIGN.md §14).

ruff is the configured linter (``ruff.toml``: line-length 88, E/F/W) but
is not installable inside the CI container (CHANGES.md PR 2). This pass
re-implements the three rules that actually catch regressions here, so
``tools/lint_contracts.py --all`` can gate style even where ruff cannot
run. It is deliberately a subset — when ruff *is* available it remains
authoritative.

STY001  line longer than 88 characters (≈ E501).
STY002  trailing whitespace (≈ W291/W293).
STY003  module-level import never referenced again in the file (≈ F401),
        conservative: skipped for ``__init__.py`` re-exports, ``# noqa``
        lines, and ``__future__``/side-effect imports.
"""
from __future__ import annotations

import ast
import tokenize

from repro.analysis.findings import Finding

MAX_LINE = 88


def _unused_imports(src: str, path: str) -> list[Finding]:
    if path.endswith("__init__.py"):
        return []
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError:
        return []
    lines = src.splitlines()
    # name -> (lineno, display) for module-level imports only.
    imported: dict[str, tuple[int, str]] = {}
    for node in tree.body:
        if isinstance(node, ast.Import):
            for a in node.names:
                bound = a.asname or a.name.split(".")[0]
                imported[bound] = (node.lineno, a.name)
        elif isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            for a in node.names:
                if a.name == "*":
                    continue
                bound = a.asname or a.name
                imported[bound] = (node.lineno,
                                   f"{node.module or '.'}.{a.name}")
    if not imported:
        return []
    used: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            used.add(node.id)
        elif isinstance(node, ast.Attribute):
            base = node
            while isinstance(base, ast.Attribute):
                base = base.value
            if isinstance(base, ast.Name):
                used.add(base.id)
    # Names referenced in __all__ strings count as used.
    for node in ast.walk(tree):
        if (isinstance(node, ast.Constant)
                and isinstance(node.value, str)):
            used.add(node.value)
    out = []
    for bound, (lineno, display) in sorted(imported.items(),
                                           key=lambda kv: kv[1][0]):
        if bound in used:
            continue
        line = lines[lineno - 1] if lineno <= len(lines) else ""
        if "noqa" in line:
            continue
        out.append(Finding(
            rule="STY003", path=path, line=lineno, symbol=bound,
            message=f"import {display!r} is never used"))
    return out


def scan_source(src: str, path: str) -> list[Finding]:
    findings = []
    for lineno, line in enumerate(src.splitlines(), start=1):
        if len(line) > MAX_LINE and "noqa" not in line:
            findings.append(Finding(
                rule="STY001", path=path, line=lineno,
                message=f"line is {len(line)} chars (> {MAX_LINE})"))
        if line != line.rstrip():
            findings.append(Finding(
                rule="STY002", path=path, line=lineno,
                message="trailing whitespace"))
    findings += _unused_imports(src, path)
    return findings


def scan_files(files) -> list[Finding]:
    """``files`` is an iterable of (abs-path, repo-relative-path)."""
    findings = []
    for full, rel in files:
        try:
            with tokenize.open(full) as fh:
                src = fh.read()
        except (OSError, SyntaxError):
            continue
        findings += scan_source(src, rel)
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))
