"""Static contract analysis for the serving stack (DESIGN.md §14).

Three passes, one finding type, one committed suppressions baseline:

* :mod:`repro.analysis.hlo` — parse compiled HLO text into an op-level
  table and check the §8 zero-collective decode contract, the no-host-
  callback contract, and donation aliasing (``input_output_alias``).
* :mod:`repro.analysis.jitlint` — AST rule engine over the repo source:
  host syncs reachable from jit regions, unseeded RNG, wall-clock reads
  outside the injectable-clock surface, fold_in substream-tag collisions.
* :mod:`repro.analysis.vmem` — per-kernel VMEM footprint from the Pallas
  BlockSpecs, gated against the §3 per-core budget and a committed
  per-kernel baseline.

Everything flows through :class:`repro.analysis.findings.Finding`;
``tools/lint_contracts.py`` is the CLI the static-analysis CI job runs.
"""
from repro.analysis.findings import Finding  # noqa: F401
