"""Op-level analyzer for compiled HLO module text (DESIGN.md §14).

Parses the text that ``jax.stages.Compiled.as_text()`` returns into a
table of instructions — (opcode, shape, sharding annotation, custom-call
target) — plus the module-level ``input_output_alias`` map, and checks
declarative contracts against it:

* :func:`check_no_collectives` — the §8 zero-collective decode contract.
  Asserted on parsed *opcodes* (with async ``-start``/``-done``/``-update``
  suffixes normalized away), not substrings: a substring grep
  false-negatives on renamed ops and false-positives on fusion names that
  merely mention a collective.
* :func:`check_no_host_ops` — no infeed/outfeed/send/recv and no
  host-callback ``custom-call`` inside a jitted serving path.
* :func:`check_donation` — every ``donate_argnums`` leaf actually aliases
  an output. XLA silently *drops* unusable donations; a dropped pool
  donation doubles the slot pool's HBM footprint without any error.

The parser is deliberately tolerant: lines that are not instructions
(computation headers, braces, comments, metadata continuation) are
skipped, so it works across XLA text-format drift.

Sibling: :mod:`repro.launch.hlo_cost` parses the same text for a
*quantitative* cost model (FLOPs / HBM bytes / collective wire bytes);
this module is the *qualitative* contract surface — which opcodes exist
at all, and what aliases what. They stay separate because the cost model
needs loop-trip/shape arithmetic the contract checks never touch.
"""
from __future__ import annotations

import dataclasses
import re

from repro.analysis.findings import Finding

# Cross-device collective opcodes (base names; async forms are the base
# plus -start/-done/-update, normalized by `base_opcode`).
COLLECTIVE_OPCODES = frozenset({
    "all-reduce", "all-gather", "all-to-all", "ragged-all-to-all",
    "reduce-scatter", "collective-permute", "collective-broadcast",
})

# Ops that move data to/from the host inside the compiled program.
HOST_TRANSFER_OPCODES = frozenset({
    "infeed", "outfeed", "send", "recv", "send-done", "recv-done",
})

# custom-call targets that re-enter python / the host runtime.
_HOST_CALLBACK_TARGET = re.compile(r"callback|host_", re.IGNORECASE)

_ASYNC_SUFFIXES = ("-start", "-done", "-update")


def base_opcode(opcode: str) -> str:
    """Normalize async variants: ``all-gather-start`` -> ``all-gather``."""
    for suf in _ASYNC_SUFFIXES:
        if opcode.endswith(suf):
            return opcode[: -len(suf)]
    return opcode


def is_collective(opcode: str) -> bool:
    return base_opcode(opcode) in COLLECTIVE_OPCODES


@dataclasses.dataclass(frozen=True)
class HloInstruction:
    name: str                      # %name (leading % stripped)
    opcode: str                    # e.g. "dynamic-update-slice"
    shape: str                     # raw result-shape text
    line: int                      # 1-based line in the module text
    sharding: str | None           # raw sharding={...} annotation, if any
    custom_call_target: str | None  # for custom-call ops
    text: str                      # the full instruction line


@dataclasses.dataclass
class HloModule:
    """Instruction table + entry-module attributes of one HLO module."""

    name: str
    instructions: list[HloInstruction]
    # output shape-index -> (param number, param shape-index, kind)
    input_output_alias: dict[tuple[int, ...],
                             tuple[int, tuple[int, ...], str]]
    text: str

    def opcodes(self) -> set[str]:
        return {i.opcode for i in self.instructions}

    def find(self, opcode: str) -> list[HloInstruction]:
        return [i for i in self.instructions if i.opcode == opcode]

    def collectives(self) -> list[HloInstruction]:
        return [i for i in self.instructions if is_collective(i.opcode)]

    def donated_params(self) -> set[tuple[int, tuple[int, ...]]]:
        """Distinct (param number, param shape-index) pairs that alias an
        output — the donations XLA actually honoured."""
        return {(p, pidx)
                for p, pidx, _kind in self.input_output_alias.values()}


_MODULE_RE = re.compile(r"^HloModule\s+([^\s,]+)")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"\s*(?P<op>[A-Za-z][\w\-]*)\(")
_SHARDING_RE = re.compile(r"sharding=(\{[^}]*\})")
_TARGET_RE = re.compile(r'custom_call_target="([^"]*)"')
_ALIAS_ENTRY_RE = re.compile(
    r"\{([\d,\s]*)\}:\s*\((\d+),\s*\{([\d,\s]*)\}(?:,\s*([\w\-]+))?\)")


def _index_tuple(raw: str) -> tuple[int, ...]:
    return tuple(int(x) for x in raw.replace(",", " ").split())


def _balanced(text: str, start: int) -> int:
    """Index one past the paren that closes ``text[start]`` ('(')."""
    depth = 0
    for i in range(start, len(text)):
        if text[i] == "(":
            depth += 1
        elif text[i] == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(text)


def _parse_alias_map(text: str) -> dict:
    """Parse ``input_output_alias={ {0}: (1, {}, may-alias), ... }``."""
    key = "input_output_alias={"
    at = text.find(key)
    if at < 0:
        return {}
    depth, i = 1, at + len(key)
    start = i
    while i < len(text) and depth:
        if text[i] == "{":
            depth += 1
        elif text[i] == "}":
            depth -= 1
        i += 1
    body = text[start:i - 1]
    out = {}
    for m in _ALIAS_ENTRY_RE.finditer(body):
        out_idx = _index_tuple(m.group(1))
        param = int(m.group(2))
        param_idx = _index_tuple(m.group(3))
        kind = m.group(4) or "may-alias"
        out[out_idx] = (param, param_idx, kind)
    return out


def parse_hlo(text: str) -> HloModule:
    """Parse HLO module text into an :class:`HloModule` op table."""
    name = ""
    instructions: list[HloInstruction] = []
    alias = _parse_alias_map(text)
    for lineno, line in enumerate(text.splitlines(), start=1):
        mod = _MODULE_RE.match(line)
        if mod:
            name = name or mod.group(1)
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        rest = line[m.end():]
        # Result shape: balanced parens for tuple shapes, else one token.
        if rest.startswith("("):
            end = _balanced(rest, 0)
        else:
            end = len(rest) - len(rest.lstrip())
            while end < len(rest) and not rest[end].isspace():
                end += 1
        shape, tail = rest[:end], rest[end:]
        op = _OPCODE_RE.match(tail)
        if not op:
            continue                     # not an instruction line
        sharding = _SHARDING_RE.search(line)
        target = _TARGET_RE.search(line)
        instructions.append(HloInstruction(
            name=m.group("name"), opcode=op.group("op"), shape=shape,
            line=lineno, sharding=sharding.group(1) if sharding else None,
            custom_call_target=target.group(1) if target else None,
            text=line.strip()))
    return HloModule(name=name, instructions=instructions,
                     input_output_alias=alias, text=text)


# ---------------------------------------------------------------------------
# Contract checks (DESIGN.md §14 rule catalog)
# ---------------------------------------------------------------------------


def check_no_collectives(module: HloModule, label: str) -> list[Finding]:
    """HLO001: zero cross-device collectives in the compiled program —
    the §8 sharded-decode contract."""
    return [Finding(rule="HLO001", path=label, line=i.line,
                    symbol=base_opcode(i.opcode),
                    message=f"collective {i.opcode} in {i.shape}")
            for i in module.collectives()]


def check_no_host_ops(module: HloModule, label: str) -> list[Finding]:
    """HLO002: no host transfers (infeed/outfeed/send/recv) and no
    host-callback custom-calls inside a jitted serving path."""
    out = []
    for i in module.instructions:
        if base_opcode(i.opcode) in HOST_TRANSFER_OPCODES:
            out.append(Finding(
                rule="HLO002", path=label, line=i.line,
                symbol=base_opcode(i.opcode),
                message=f"host transfer op {i.opcode}"))
        elif (i.opcode == "custom-call" and i.custom_call_target
              and _HOST_CALLBACK_TARGET.search(i.custom_call_target)):
            out.append(Finding(
                rule="HLO002", path=label, line=i.line,
                symbol=i.custom_call_target,
                message=f"host callback custom-call "
                        f"({i.custom_call_target})"))
    return out


def check_donation(module: HloModule, expected_leaves: int,
                   label: str) -> list[Finding]:
    """DON001: the compiled program honours fewer donations than the
    ``donate_argnums`` contract promised — XLA silently dropped some
    (shape/dtype mismatch with every output, or an unused input), which
    doubles that buffer's HBM footprint."""
    got = len(module.donated_params())
    if got < expected_leaves:
        return [Finding(
            rule="DON001", path=label, line=0, symbol=module.name,
            message=(f"only {got}/{expected_leaves} donated leaves alias "
                     f"an output (input_output_alias) — donation silently "
                     f"dropped"))]
    return []
