"""The one finding type every analysis pass emits, plus the committed
suppressions baseline (DESIGN.md §14).

A :class:`Finding` is a rule violation at a location. The repo starts
clean: ``tools/lint_contracts.py --all`` must exit 0, so any finding that
cannot be fixed immediately needs a *justified* entry in the committed
baseline (``src/repro/analysis/baseline.json``). New violations therefore
fail CI by default — the baseline only ever shrinks (stale entries are
reported so they get deleted when the underlying code is fixed).

Baseline schema::

    {"suppressions": [
        {"rule": "SYNC001", "path": "src/repro/foo.py",
         "symbol": "bar", "reason": "why this is acceptable"}
    ]}

``path`` is repo-relative (posix). ``symbol`` is optional; when present
the finding's symbol must match exactly. ``reason`` is mandatory — an
unexplained suppression is itself rejected.
"""
from __future__ import annotations

import dataclasses
import json
import os


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation.

    rule     stable id (catalog in DESIGN.md §14), e.g. ``SYNC001``.
    path     repo-relative posix path — or a virtual label like
             ``decode_hlo[slay]`` for compiled-artifact passes.
    line     1-based line (0 when the pass has no line notion).
    message  human-readable description of the violation.
    symbol   the offending function/kernel/op name (suppression key).
    """

    rule: str
    path: str
    line: int
    message: str
    symbol: str = ""

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        sym = f" [{self.symbol}]" if self.symbol else ""
        return f"{self.rule} {loc}{sym}: {self.message}"


@dataclasses.dataclass(frozen=True)
class Suppression:
    rule: str
    path: str
    reason: str
    symbol: str = ""

    def matches(self, f: Finding) -> bool:
        if self.rule != f.rule or self.path != f.path:
            return False
        return (not self.symbol) or self.symbol == f.symbol


def load_baseline(path: str) -> list[Suppression]:
    """Load and validate the committed suppressions baseline."""
    with open(path) as fh:
        raw = json.load(fh)
    out = []
    for i, e in enumerate(raw.get("suppressions", [])):
        for k in ("rule", "path", "reason"):
            if not e.get(k):
                raise ValueError(f"baseline entry {i} missing {k!r}: {e}")
        out.append(Suppression(rule=e["rule"], path=e["path"],
                               reason=e["reason"],
                               symbol=e.get("symbol", "")))
    return out


def apply_baseline(findings: list[Finding], sups: list[Suppression]):
    """Split findings into (unsuppressed, suppressed); also return the
    stale suppressions that matched nothing (candidates for deletion)."""
    unsuppressed, suppressed = [], []
    used: set[int] = set()
    for f in findings:
        hit = None
        for i, s in enumerate(sups):
            if s.matches(f):
                hit = i
                break
        if hit is None:
            unsuppressed.append(f)
        else:
            used.add(hit)
            suppressed.append(f)
    stale = [s for i, s in enumerate(sups) if i not in used]
    return unsuppressed, suppressed, stale


def format_table(findings: list[Finding], title: str = "Findings") -> str:
    """GitHub-flavoured markdown table (for GITHUB_STEP_SUMMARY)."""
    lines = [f"### {title}", ""]
    if not findings:
        lines.append("No findings.")
        return "\n".join(lines) + "\n"
    lines += ["| rule | location | symbol | message |",
              "| --- | --- | --- | --- |"]
    for f in findings:
        loc = f"{f.path}:{f.line}" if f.line else f.path
        msg = f.message.replace("|", "\\|")
        lines.append(f"| {f.rule} | `{loc}` | `{f.symbol or '-'}` "
                     f"| {msg} |")
    return "\n".join(lines) + "\n"


def repo_root() -> str:
    """Repo root (three levels above this package)."""
    return os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))


def relpath(path: str, root: str | None = None) -> str:
    """Repo-relative posix path for stable finding/suppression keys."""
    root = root or repo_root()
    return os.path.relpath(os.path.abspath(path), root).replace(os.sep, "/")


DEFAULT_BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "baseline.json")
