"""Data pipeline: deterministic, shard-aware synthetic streams + the
paper's 22 synthetic benchmark tasks."""
from repro.data.pipeline import DataConfig, make_batch, batch_iterator  # noqa
from repro.data import tasks  # noqa: F401
