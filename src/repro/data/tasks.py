"""The paper's synthetic task suite (Table 7/8): 22 tasks in 8 categories.

Each generator returns {"tokens": (B, L) int32, "labels": (B, L) int32,
"mask": (B, L) bool} — loss/accuracy are evaluated at masked positions only.
Layout convention: [input segment] SEP [answer segment]; the model is
queried autoregressively over the answer segment.

Vocabulary: 0 = PAD, 1 = SEP, 2 = QUERY, 3.. = payload symbols.
"""
from __future__ import annotations

import numpy as np

PAD, SEP, QUERY = 0, 1, 2
BASE = 3

CATEGORIES = {
    "basic": ["copy", "sort", "reverse"],
    "arithmetic": ["counting", "parity", "addition", "modular"],
    "long_range": ["long_copy", "distant_match", "multihop"],
    "memory": ["retrieval", "kv_recall", "first_token", "selective_copy"],
    "patterns": ["bigram", "majority"],
    "reasoning": ["stack", "induction", "pattern"],
    "robustness": ["noisy_copy", "compression"],
    "aggregation": ["histogram"],
}
ALL_TASKS = [t for ts in CATEGORIES.values() for t in ts]


def _pack(inp: np.ndarray, ans: np.ndarray, L: int):
    """[inp SEP ans PAD...]; labels shifted; mask over answer positions."""
    B = inp.shape[0]
    tokens = np.full((B, L), PAD, np.int32)
    labels = np.full((B, L), PAD, np.int32)
    mask = np.zeros((B, L), bool)
    n_in, n_ans = inp.shape[1], ans.shape[1]
    assert n_in + 1 + n_ans <= L, (n_in, n_ans, L)
    tokens[:, :n_in] = inp
    tokens[:, n_in] = SEP
    # Teacher forcing: answer tokens appear as inputs shifted by one.
    tokens[:, n_in + 1:n_in + 1 + n_ans - 1] = ans[:, :-1] if n_ans > 1 \
        else tokens[:, n_in + 1:n_in]
    labels[:, n_in:n_in + n_ans] = ans
    mask[:, n_in:n_in + n_ans] = True
    return {"tokens": tokens, "labels": labels, "mask": mask}


def generate(task: str, rng: np.random.Generator, batch: int, seq_len: int,
             vocab: int) -> dict:
    V = vocab - BASE  # payload symbols
    B, L = batch, seq_len
    n = max(2, min((L - 2) // 2, 16))

    if task in ("copy", "noisy_copy", "long_copy"):
        m = max(2, (L - 2) // 2) if task == "long_copy" else n
        x = rng.integers(BASE, BASE + V, (B, m))
        inp = x.copy()
        if task == "noisy_copy":
            noise = rng.random((B, m)) < 0.2
            inp = np.where(noise, rng.integers(BASE, BASE + V, (B, m)), x)
            ans = inp.copy()       # copy the (noisy) input as seen
        else:
            ans = x
        return _pack(inp, ans, L)
    if task == "reverse":
        x = rng.integers(BASE, BASE + V, (B, n))
        return _pack(x, x[:, ::-1], L)
    if task == "sort":
        x = rng.integers(BASE, BASE + V, (B, n))
        return _pack(x, np.sort(x, -1), L)
    if task == "counting":
        x = rng.integers(BASE, BASE + min(V, 8), (B, n))
        target = x[:, :1]
        cnt = (x == target).sum(-1) % min(V, 10)
        return _pack(x, BASE + cnt[:, None], L)
    if task == "parity":
        x = rng.integers(BASE, BASE + 2, (B, n))
        par = ((x - BASE).sum(-1) % 2)
        return _pack(x, BASE + par[:, None], L)
    if task == "addition":
        d = min(V, 10)
        a = rng.integers(0, d, (B, n // 2))
        b = rng.integers(0, d, (B, n // 2))
        s = (a + b) % d
        inp = np.concatenate([BASE + a, BASE + b], 1)
        return _pack(inp, BASE + s, L)
    if task == "modular":
        d = min(V, 10)
        x = rng.integers(0, d, (B, n))
        m = (x.sum(-1) % d)
        return _pack(BASE + x, BASE + m[:, None], L)
    if task == "distant_match":
        x = rng.integers(BASE, BASE + V, (B, L - 4))
        first = x[:, 0]
        return _pack(x, first[:, None], L)
    if task == "multihop":
        # Chain a->b, b->c pairs; query: follow 2 hops from start symbol.
        d = min(V, 12)
        perm = np.stack([rng.permutation(d) for _ in range(B)])
        pairs = np.zeros((B, 2 * d), np.int64)
        pairs[:, 0::2] = BASE + np.arange(d)
        pairs[:, 1::2] = BASE + perm
        start = rng.integers(0, d, (B,))
        hop1 = np.take_along_axis(perm, start[:, None], 1)[:, 0]
        hop2 = np.take_along_axis(perm, hop1[:, None], 1)[:, 0]
        inp = np.concatenate([pairs, np.full((B, 1), QUERY),
                              BASE + start[:, None]], 1)
        return _pack(inp, BASE + hop2[:, None], L)
    if task in ("retrieval", "kv_recall"):
        d = min(V // 2, 12)
        keys = np.stack([rng.permutation(d) for _ in range(B)])
        vals = rng.integers(0, d, (B, d))
        kv = np.zeros((B, 2 * d), np.int64)
        kv[:, 0::2] = BASE + keys
        kv[:, 1::2] = BASE + d + vals
        qi = rng.integers(0, d, (B,))
        qkey = np.take_along_axis(keys, qi[:, None], 1)[:, 0]
        qval = np.take_along_axis(vals, qi[:, None], 1)[:, 0]
        inp = np.concatenate([kv, np.full((B, 1), QUERY),
                              BASE + qkey[:, None]], 1)
        return _pack(inp, BASE + d + qval[:, None], L)
    if task == "first_token":
        x = rng.integers(BASE, BASE + V, (B, n))
        return _pack(x, x[:, :1], L)
    if task == "selective_copy":
        # Copy only the marked (QUERY-preceded) tokens, in order.
        k = 4
        x = rng.integers(BASE, BASE + V, (B, n))
        marks = np.zeros((B, n), bool)
        for i in range(B):
            marks[i, rng.choice(n, k, replace=False)] = True
        inp = np.full((B, 2 * n), PAD, np.int64)
        inp[:, 0::2] = np.where(marks, QUERY, PAD)
        inp[:, 1::2] = x
        ans = np.stack([x[i][marks[i]] for i in range(B)])
        return _pack(inp, ans, L)
    if task == "bigram":
        # Predict the symbol that always follows a trigger symbol.
        trig = BASE
        follow = rng.integers(BASE + 1, BASE + V, (B, 1))
        x = rng.integers(BASE + 1, BASE + V, (B, n))
        x[:, n // 3] = trig
        x[:, n // 3 + 1] = follow[:, 0]
        x[:, -1] = trig
        return _pack(x, follow, L)
    if task == "majority":
        d = min(V, 6)
        x = BASE + rng.integers(0, d, (B, n))
        maj = np.array([np.bincount(r - BASE, minlength=d).argmax()
                        for r in x])
        return _pack(x, BASE + maj[:, None], L)
    if task == "histogram":
        d = min(V // 2, 6)
        x = rng.integers(0, d, (B, n))
        counts = np.stack([np.bincount(r, minlength=d) for r in x])
        return _pack(BASE + x, BASE + d + np.clip(counts, 0, d), L)
    if task == "stack":
        # Balanced push(sym)/pop sequence; answer: top of stack at the end.
        d = min(V, 8)
        x = np.zeros((B, n), np.int64)
        ans = np.zeros((B, 1), np.int64)
        for i in range(B):
            stack = [rng.integers(0, d)]
            seq = [BASE + stack[0]]
            for _ in range(n - 1):
                if len(stack) > 1 and rng.random() < 0.4:
                    stack.pop()
                    seq.append(QUERY)      # pop marker
                else:
                    s = int(rng.integers(0, d))
                    stack.append(s)
                    seq.append(BASE + s)
            x[i] = seq
            ans[i, 0] = BASE + stack[-1]
        return _pack(x, ans, L)
    if task == "induction":
        # Induction head: ...A B ... A -> B
        x = rng.integers(BASE, BASE + V, (B, n))
        a = rng.integers(BASE, BASE + V, (B,))
        b = rng.integers(BASE, BASE + V, (B,))
        x[:, n // 4] = a
        x[:, n // 4 + 1] = b
        x[:, -1] = a
        return _pack(x, b[:, None], L)
    if task == "pattern":
        # Periodic pattern continuation (period 3).
        p = rng.integers(BASE, BASE + V, (B, 3))
        reps = n // 3 + 1
        x = np.tile(p, (1, reps))[:, :n]
        nxt = x[:, n % 3 if n % 3 < 3 else 0][:, None]
        nxt = p[:, n % 3][:, None]
        return _pack(x, nxt, L)
    if task == "compression":
        # Run-length: answer is the de-duplicated symbol sequence.
        d = min(V, 8)
        k = 4
        syms = BASE + np.stack([rng.permutation(d)[:k] for _ in range(B)])
        runs = rng.integers(1, max(2, n // k), (B, k))
        x = np.full((B, n), PAD, np.int64)
        for i in range(B):
            seq = np.repeat(syms[i], runs[i])[:n]
            x[i, :len(seq)] = seq
            x[i, len(seq):] = syms[i, -1]
        return _pack(x, syms, L)
    raise ValueError(f"unknown task {task}")


def accuracy(logits: np.ndarray, labels: np.ndarray,
             mask: np.ndarray) -> float:
    pred = logits.argmax(-1)
    hit = (pred == labels) & mask
    return float(hit.sum() / max(mask.sum(), 1))
