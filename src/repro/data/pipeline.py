"""Deterministic, step-indexed synthetic LM data pipeline.

Design constraints at 1000+ nodes:

* **Step-indexed determinism** — the batch for step t is a pure function of
  (seed, step), so a restart from a checkpoint at step t reproduces the
  exact token stream with no data-loader state to persist.
* **Shard-awareness** — each data-parallel shard derives its slice from its
  position in the global batch; no host reads another host's slice.
* **Zipf-ish marginals** — tokens follow an approximate power law so the
  loss curve behaves like natural text rather than uniform noise.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_alpha: float = 1.1


def _zipf_cdf(cfg: DataConfig) -> np.ndarray:
    ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
    p = ranks ** (-cfg.zipf_alpha)
    p /= p.sum()
    return np.cumsum(p)


def make_batch(cfg: DataConfig, step: int,
               cdf: np.ndarray | None = None) -> dict:
    """Global batch for `step`: tokens/labels (B, L) int32.

    Labels are next-token targets with a final filler token (the repeated
    markov-ish stream makes next-token prediction learnable).
    """
    if cdf is None:
        cdf = _zipf_cdf(cfg)
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    u = jax.random.uniform(key, (cfg.global_batch, cfg.seq_len + 1))
    toks = jnp.searchsorted(jnp.asarray(cdf), u).astype(jnp.int32)
    toks = jnp.clip(toks, 0, cfg.vocab_size - 1)
    # Inject short-range structure: every even position repeats a shifted
    # copy of the previous token half the time (learnable bigram signal).
    prev = jnp.roll(toks, 1, axis=-1)
    gate = (jnp.arange(cfg.seq_len + 1) % 2 == 0) & (u < 0.5)
    toks = jnp.where(gate, (prev + 1) % cfg.vocab_size, toks)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def batch_iterator(cfg: DataConfig, start_step: int = 0):
    """Infinite deterministic iterator (resume-exact from any step)."""
    cdf = _zipf_cdf(cfg)
    step = start_step
    while True:
        yield step, make_batch(cfg, step, cdf)
        step += 1
