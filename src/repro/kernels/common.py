"""Shared helpers for the Pallas kernels: tiling/compile utilities and the
in-VMEM SLAY feature map Ψ with its closed-form VJP.

The feature math is traced *inside* kernel bodies on fp32 VMEM blocks — it
is shared by the standalone feature kernel (`feature_map.py`) and the fused
attention megakernel (`slay_fused.py`) so forward, backward, and the two
call sites can never drift apart (DESIGN.md §3).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

NORM_EPS = 1e-6  # matches repro.core.features.normalize


class FeatureStatics(NamedTuple):
    """Hashable static description of the Ψ pipeline (per head)."""

    s_nodes: tuple      # quadrature nodes s_r
    sqrt_w: tuple       # √w_r
    num_anchors: int    # P
    num_prf: int        # D


def causal_mask(scores):
    """Zero the strict upper triangle of a (T, T) score block."""
    t = scores.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    return jnp.where(rows >= cols, scores, 0.0)


def vmem_scratch(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)


def tpu_params():
    """Compiler params for (parallel head, sequential chunk) grids.

    The chunk axis must stay sequential ("arbitrary") so VMEM scratch
    carries state across grid steps; the head axis is embarrassingly
    parallel. Handles the CompilerParams/TPUCompilerParams rename across
    jax versions in one place.
    """
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(dimension_semantics=("parallel", "arbitrary"))


def features_fwd(u, a, w, st: FeatureStatics):
    """u (T, d) fp32 -> (Ψ(u) (T, m), intermediates for the VJP).

    normalize → anchor poly φ_p = (ûᵀa)²/√P → PRF
    φ_e = exp(√(2s_r) ωᵀû − s_r)/√D → √w_r (φ_p ⊗ φ_e), concat over r.
    """
    n2 = jnp.sum(u * u, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(n2 + NORM_EPS)                       # (T, 1)
    uh = u * inv                                             # (T, d) unit
    pa = jax.lax.dot_general(uh, a, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (T, P)
    phi_p = (pa * pa) * (1.0 / np.sqrt(st.num_anchors))      # (T, P)
    pw = jax.lax.dot_general(uh, w, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (T, D)
    t = u.shape[0]
    phi_es = []
    chunks = []
    for s, swr in zip(st.s_nodes, st.sqrt_w):
        phi_e = jnp.exp(np.sqrt(2.0 * s) * pw - s) * (1.0 / np.sqrt(st.num_prf))
        phi_es.append(phi_e)                                 # (T, D)
        kron = (phi_p[:, :, None] * phi_e[:, None, :]) * swr
        chunks.append(kron.reshape(t, st.num_anchors * st.num_prf))
    psi = jnp.concatenate(chunks, axis=-1)                   # (T, m)
    return psi, (uh, inv, pa, phi_p, phi_es)


def features_bwd(dpsi, res, a, w, st: FeatureStatics):
    """dΨ (T, m) -> (du (T, d), dA (P, d), dΩ (D, d))."""
    uh, inv, pa, phi_p, phi_es = res
    t = dpsi.shape[0]
    P, D = st.num_anchors, st.num_prf
    dphi_p = jnp.zeros_like(phi_p)                           # (T, P)
    dpw = jnp.zeros((t, D), jnp.float32)
    for r, (s, swr) in enumerate(zip(st.s_nodes, st.sqrt_w)):
        m_r = dpsi[:, r * P * D:(r + 1) * P * D].reshape(t, P, D) * swr
        phi_e = phi_es[r]
        # kron = phi_p ⊗ phi_e: split the cotangent.
        dphi_p = dphi_p + jnp.einsum("tpd,td->tp", m_r, phi_e)
        dphi_e = jnp.einsum("tpd,tp->td", m_r, phi_p)
        # phi_e = exp(√(2s) pw − s)/√D → d pw = √(2s)·phi_e∘dphi_e.
        dpw = dpw + np.sqrt(2.0 * s) * phi_e * dphi_e
    dpa = 2.0 * pa * dphi_p * (1.0 / np.sqrt(P))             # (T, P)
    duh = (jax.lax.dot(dpa, a, preferred_element_type=jnp.float32)
           + jax.lax.dot(dpw, w, preferred_element_type=jnp.float32))
    da = jax.lax.dot_general(dpa, uh, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (P, d)
    dw = jax.lax.dot_general(dpw, uh, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (D, d)
    # û = u·rsqrt(‖u‖²+ε):  du = inv·(dû − û (ûᵀdû)).
    du = inv * (duh - uh * jnp.sum(uh * duh, axis=-1, keepdims=True))
    return du, da, dw
