"""Pallas TPU megakernel: end-to-end SLAY causal attention with custom VJP.

Fuses the whole SLAY pipeline — normalize → anchor poly → PRF → Kronecker
fusion (Ψ) → chunked causal prefix contraction — into one kernel per pass
(DESIGN.md §3 "Fused megakernel"). The two-dispatch path
(`kernels/feature_map.py` then `kernels/slay_scan.py`) writes Ψ(Q)/Ψ(K) at
m = R·P·D floats per token to HBM and immediately re-reads them; here the
features are (re)computed inside VMEM per chunk and **never touch HBM** —
per-head HBM traffic drops from O(L·m) feature reads+writes to O(L·d) raw
q/k reads. Anchors (P·d) and omegas (D·d) are a few KB and stay
VMEM-resident across the sequential chunk axis.

Forward (grid (BH, C), chunk axis sequential):

    Q_c = Ψ(q_c), K_c = Ψ(k_c)                      (VMEM only)
    Y_c = Q_c S_{<c} + tril(Q_c K_cᵀ) V_c           (numerator)
    e_c = Q_c z_{<c} + rowsum(tril(Q_c K_cᵀ)) + δ   (denominator)
    S_c = S_{<c} + K_cᵀ V_c,   z_c = z_{<c} + Σ K_c (VMEM scratch carry)

The denominator (one float per token, like flash attention's LSE) is saved
as a residual so the backward pass never re-solves the division.

Backward = recompute-everything, two scans (DESIGN.md §3 "Backward"):

* `_bwd_q` runs chunks **forward**, re-carrying (S, z) exactly like the
  forward pass, and emits dq (+ the q-path dA/dΩ partials): dQ feat-grad
  needs only the *prefix* state.
* `_bwd_kv` runs chunks in **reverse**, carrying the state cotangents
  (dS, dz) in VMEM scratch, and emits dk, dv (+ the k-path dA/dΩ
  partials): dK/dV feat-grads need only the *suffix* cotangent state.

Both recompute Ψ and the intra-chunk scores tril(Q_c K_cᵀ) from raw q/k in
VMEM — the classic flash-attention trade: O(T·m) extra FLOPs per chunk
instead of O(L·m) residual HBM traffic. dA/dΩ are accumulated per head in a
revisited output block and reduced across heads (and the q/k paths) by the
wrapper, so `jax.grad` works end to end — including through GQA groups and
the shared random projections.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import quadrature
from repro.core.features import SlayFeatureConfig
from repro.kernels.common import (FeatureStatics, causal_mask as _causal_mask,
                                  features_bwd, features_fwd,
                                  tpu_params as _tpu_params,
                                  vmem_scratch as _scratch)


class FusedStatics(NamedTuple):
    """Hashable static bundle for the custom-VJP boundary."""

    feat: FeatureStatics
    chunk_size: int
    delta: float
    interpret: bool

    @property
    def feature_dim(self) -> int:
        f = self.feat
        return len(f.s_nodes) * f.num_anchors * f.num_prf


def statics_for(cfg: SlayFeatureConfig, *, chunk_size: int, delta: float,
                interpret: bool) -> FusedStatics:
    if cfg.poly_kind != "anchor" or cfg.fusion != "tensor":
        raise ValueError("fused kernel supports anchor+tensor only")
    s_np, w_np = quadrature.yat_quadrature(cfg.num_quad_nodes, cfg.eps)
    feat = FeatureStatics(
        s_nodes=tuple(float(x) for x in s_np),
        sqrt_w=tuple(float(x) for x in np.sqrt(w_np)),
        num_anchors=cfg.num_anchors, num_prf=cfg.num_prf)
    return FusedStatics(feat=feat, chunk_size=chunk_size, delta=delta,
                        interpret=interpret)


# ---------------------------------------------------------------------------
# Forward kernel
# ---------------------------------------------------------------------------


def _fwd_kernel(q_ref, k_ref, v_ref, a_ref, w_ref, o_ref, den_ref,
                s_ref, z_ref, *, st: FusedStatics):
    """Blocks: q (1,T,d), k (1,T,d), v (1,T,dv), a (P,d), w (D,d);
    outs o (1,T,dv), den (1,T); scratch s (m,dv) fp32, z (1,m) fp32."""
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)
        z_ref[...] = jnp.zeros_like(z_ref)

    a = a_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    qf, _ = features_fwd(q_ref[0].astype(jnp.float32), a, w, st.feat)   # (T, m)
    kf, _ = features_fwd(k_ref[0].astype(jnp.float32), a, w, st.feat)   # (T, m)
    v = v_ref[0].astype(jnp.float32)                                # (T, dv)
    s = s_ref[...]
    z = z_ref[0]

    num = jax.lax.dot(qf, s, preferred_element_type=jnp.float32)    # (T, dv)
    den = qf @ z[:, None]                                           # (T, 1)
    scores = _causal_mask(jax.lax.dot_general(
        qf, kf, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32))                        # (T, T)
    num = num + jax.lax.dot(scores, v, preferred_element_type=jnp.float32)
    den = den + jnp.sum(scores, axis=1, keepdims=True)

    o_ref[0] = (num / (den + st.delta)).astype(o_ref.dtype)
    den_ref[0] = den[:, 0]

    s_ref[...] = s + jax.lax.dot_general(kf, v, (((0,), (0,)), ((), ())),
                                         preferred_element_type=jnp.float32)
    z_ref[0] = z + jnp.sum(kf, axis=0)


def _fwd_impl(st: FusedStatics, q, k, v, anchors, omegas):
    bh, L, d = q.shape
    bk, _, dv = v.shape
    g = bh // bk
    t = st.chunk_size
    m = st.feature_dim
    P, D = st.feat.num_anchors, st.feat.num_prf
    grid = (bh, L // t)
    return pl.pallas_call(
        functools.partial(_fwd_kernel, st=st),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, t, d), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, t, d), lambda h, c: (h // g, c, 0)),
            pl.BlockSpec((1, t, dv), lambda h, c: (h // g, c, 0)),
            pl.BlockSpec((P, d), lambda h, c: (0, 0)),
            pl.BlockSpec((D, d), lambda h, c: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, t, dv), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, t), lambda h, c: (h, c)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, L, dv), v.dtype),
            jax.ShapeDtypeStruct((bh, L), jnp.float32),
        ],
        scratch_shapes=[_scratch((m, dv)), _scratch((1, m))],
        compiler_params=_tpu_params(),
        interpret=st.interpret,
    )(q, k, v, anchors, omegas)


# ---------------------------------------------------------------------------
# Backward kernel 1: forward chunk scan → dq (+ q-path dA/dΩ)
# ---------------------------------------------------------------------------


def _bwd_q_kernel(q_ref, k_ref, v_ref, a_ref, w_ref, dy_ref, y_ref, den_ref,
                  dq_ref, da_ref, dw_ref, s_ref, z_ref, *, st: FusedStatics):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)
        z_ref[...] = jnp.zeros_like(z_ref)
        da_ref[...] = jnp.zeros_like(da_ref)
        dw_ref[...] = jnp.zeros_like(dw_ref)

    a = a_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    qf, qres = features_fwd(q_ref[0].astype(jnp.float32), a, w, st.feat)
    kf, _ = features_fwd(k_ref[0].astype(jnp.float32), a, w, st.feat)
    v = v_ref[0].astype(jnp.float32)
    dy = dy_ref[0].astype(jnp.float32)                        # (T, dv)
    y = y_ref[0].astype(jnp.float32)                          # (T, dv)
    e = den_ref[0][:, None] + st.delta                        # (T, 1)
    s = s_ref[...]
    z = z_ref[0]

    gg = dy / e                                               # dnum (T, dv)
    hh = -jnp.sum(dy * y, axis=-1, keepdims=True) / e         # dden (T, 1)
    # dP = tril(G Vᵀ + h 1ᵀ);  dQfeat = G Sᵀ + h zᵀ + dP K.
    dp = _causal_mask(
        jax.lax.dot_general(gg, v, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) + hh)
    dqf = (jax.lax.dot_general(gg, s, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
           + hh * z[None, :]
           + jax.lax.dot(dp, kf, preferred_element_type=jnp.float32))
    dq, da, dw = features_bwd(dqf, qres, a, w, st.feat)
    dq_ref[0] = dq.astype(dq_ref.dtype)
    da_ref[0] += da
    dw_ref[0] += dw

    s_ref[...] = s + jax.lax.dot_general(kf, v, (((0,), (0,)), ((), ())),
                                         preferred_element_type=jnp.float32)
    z_ref[0] = z + jnp.sum(kf, axis=0)


# ---------------------------------------------------------------------------
# Backward kernel 2: reverse chunk scan → dk, dv (+ k-path dA/dΩ)
# ---------------------------------------------------------------------------


def _bwd_kv_kernel(q_ref, k_ref, v_ref, a_ref, w_ref, dy_ref, y_ref, den_ref,
                   dk_ref, dv_ref, da_ref, dw_ref, ds_ref, dz_ref, *,
                   st: FusedStatics):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        ds_ref[...] = jnp.zeros_like(ds_ref)
        dz_ref[...] = jnp.zeros_like(dz_ref)
        da_ref[...] = jnp.zeros_like(da_ref)
        dw_ref[...] = jnp.zeros_like(dw_ref)

    a = a_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    qf, _ = features_fwd(q_ref[0].astype(jnp.float32), a, w, st.feat)
    kf, kres = features_fwd(k_ref[0].astype(jnp.float32), a, w, st.feat)
    v = v_ref[0].astype(jnp.float32)
    dy = dy_ref[0].astype(jnp.float32)
    y = y_ref[0].astype(jnp.float32)
    e = den_ref[0][:, None] + st.delta
    ds = ds_ref[...]                                          # (m, dv)
    dz = dz_ref[0]                                            # (m,)

    gg = dy / e
    hh = -jnp.sum(dy * y, axis=-1, keepdims=True) / e
    scores = _causal_mask(jax.lax.dot_general(
        qf, kf, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32))                  # (T, T)
    dp = _causal_mask(
        jax.lax.dot_general(gg, v, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) + hh)
    # dKfeat = dPᵀ Q + V dSᵀ + 1 dzᵀ;  dV = Pᵀ G + K dS.
    dkf = (jax.lax.dot_general(dp, qf, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
           + jax.lax.dot_general(v, ds, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
           + dz[None, :])
    dvv = (jax.lax.dot_general(scores, gg, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
           + jax.lax.dot(kf, ds, preferred_element_type=jnp.float32))
    dk, da, dw = features_bwd(dkf, kres, a, w, st.feat)
    dk_ref[0] = dk.astype(dk_ref.dtype)
    dv_ref[0] = dvv.astype(dv_ref.dtype)
    da_ref[0] += da
    dw_ref[0] += dw

    # Carry state cotangents to the *previous* chunk.
    ds_ref[...] = ds + jax.lax.dot_general(
        qf, gg, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    dz_ref[0] = dz + jnp.sum(qf * hh, axis=0)


def _bwd_impl(st: FusedStatics, q, k, v, anchors, omegas, y, den, dy):
    bh, L, d = q.shape
    bk, _, dv = v.shape
    g = bh // bk
    t = st.chunk_size
    nc = L // t
    m = st.feature_dim
    P, D = st.feat.num_anchors, st.feat.num_prf

    common_in = [
        pl.BlockSpec((1, t, d), lambda h, c: (h, c, 0)),
        pl.BlockSpec((1, t, d), lambda h, c: (h // g, c, 0)),
        pl.BlockSpec((1, t, dv), lambda h, c: (h // g, c, 0)),
        pl.BlockSpec((P, d), lambda h, c: (0, 0)),
        pl.BlockSpec((D, d), lambda h, c: (0, 0)),
        pl.BlockSpec((1, t, dv), lambda h, c: (h, c, 0)),   # dy
        pl.BlockSpec((1, t, dv), lambda h, c: (h, c, 0)),   # y
        pl.BlockSpec((1, t), lambda h, c: (h, c)),          # den
    ]
    dq, da_q, dw_q = pl.pallas_call(
        functools.partial(_bwd_q_kernel, st=st),
        grid=(bh, nc),
        in_specs=common_in,
        out_specs=[
            pl.BlockSpec((1, t, d), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, P, d), lambda h, c: (h, 0, 0)),
            pl.BlockSpec((1, D, d), lambda h, c: (h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, L, d), q.dtype),
            jax.ShapeDtypeStruct((bh, P, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, D, d), jnp.float32),
        ],
        scratch_shapes=[_scratch((m, dv)), _scratch((1, m))],
        compiler_params=_tpu_params(),
        interpret=st.interpret,
    )(q, k, v, anchors, omegas, dy, y, den)

    # Reverse scan: grid step c processes chunk nc-1-c.
    rev_in = [
        pl.BlockSpec((1, t, d), lambda h, c: (h, nc - 1 - c, 0)),
        pl.BlockSpec((1, t, d), lambda h, c: (h // g, nc - 1 - c, 0)),
        pl.BlockSpec((1, t, dv), lambda h, c: (h // g, nc - 1 - c, 0)),
        pl.BlockSpec((P, d), lambda h, c: (0, 0)),
        pl.BlockSpec((D, d), lambda h, c: (0, 0)),
        pl.BlockSpec((1, t, dv), lambda h, c: (h, nc - 1 - c, 0)),
        pl.BlockSpec((1, t, dv), lambda h, c: (h, nc - 1 - c, 0)),
        pl.BlockSpec((1, t), lambda h, c: (h, nc - 1 - c)),
    ]
    dk_p, dv_p, da_k, dw_k = pl.pallas_call(
        functools.partial(_bwd_kv_kernel, st=st),
        grid=(bh, nc),
        in_specs=rev_in,
        out_specs=[
            pl.BlockSpec((1, t, d), lambda h, c: (h, nc - 1 - c, 0)),
            pl.BlockSpec((1, t, dv), lambda h, c: (h, nc - 1 - c, 0)),
            pl.BlockSpec((1, P, d), lambda h, c: (h, 0, 0)),
            pl.BlockSpec((1, D, d), lambda h, c: (h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, L, d), k.dtype),
            jax.ShapeDtypeStruct((bh, L, dv), v.dtype),
            jax.ShapeDtypeStruct((bh, P, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, D, d), jnp.float32),
        ],
        scratch_shapes=[_scratch((m, dv)), _scratch((1, m))],
        compiler_params=_tpu_params(),
        interpret=st.interpret,
    )(q, k, v, anchors, omegas, dy, y, den)

    # GQA: dk/dv partials are per q-head; reduce over each group of g.
    dk = jnp.sum(dk_p.reshape(bk, g, L, d), axis=1).astype(k.dtype)
    dvv = jnp.sum(dv_p.reshape(bk, g, L, dv), axis=1).astype(v.dtype)
    da = jnp.sum(da_q + da_k, axis=0).astype(anchors.dtype)
    dw = jnp.sum(dw_q + dw_k, axis=0).astype(omegas.dtype)
    return dq, dk, dvv, da, dw


# ---------------------------------------------------------------------------
# custom_vjp wrapper
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _fused(st: FusedStatics, q, k, v, anchors, omegas):
    y, _den = _fwd_impl(st, q, k, v, anchors, omegas)
    return y


def _fused_fwd(st: FusedStatics, q, k, v, anchors, omegas):
    y, den = _fwd_impl(st, q, k, v, anchors, omegas)
    return y, (q, k, v, anchors, omegas, y, den)


def _fused_bwd(st: FusedStatics, res, dy):
    q, k, v, anchors, omegas, y, den = res
    return _bwd_impl(st, q, k, v, anchors, omegas, y, den, dy)


_fused.defvjp(_fused_fwd, _fused_bwd)


@functools.partial(jax.jit, static_argnames=("cfg", "chunk_size", "delta",
                                             "interpret"))
def fused_causal_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           anchors: jnp.ndarray, omegas: jnp.ndarray,
                           cfg: SlayFeatureConfig, *, chunk_size: int = 256,
                           delta: float = 1e-6,
                           interpret: bool = False) -> jnp.ndarray:
    """q (BH, L, d), k (BK, L, d), v (BK, L, dv) → y (BH, L, dv).

    Raw (pre-feature) q/k; Ψ is computed inside the kernel. Differentiable
    w.r.t. every array input via the custom VJP. BH must be a multiple of
    BK (GQA group G = BH // BK); L must be a multiple of ``chunk_size`` —
    the `ops` wrapper zero-pads arbitrary L.
    """
    bh, L, d = q.shape
    bk = v.shape[0]
    if bh % bk:
        raise ValueError(f"q rows {bh} not divisible by kv rows {bk}")
    if L % chunk_size:
        raise ValueError(f"L={L} not divisible by chunk={chunk_size}")
    st = statics_for(cfg, chunk_size=chunk_size, delta=delta,
                     interpret=interpret)
    return _fused(st, q, k, v, anchors, omegas)
