"""Pallas TPU kernels for SLAY's compute hot-spots.

* ``slay_scan``    — chunked causal linear attention, VMEM running state.
* ``feature_map``  — fused normalize→poly→PRF→Kronecker feature pipeline.
* ``ops``          — jit'd layout-adapting wrappers (public entry points).
* ``ref``          — pure-jnp oracles (match ``repro.core``).
"""
from repro.kernels import ops, ref  # noqa: F401
