"""Pallas TPU kernels for SLAY's compute hot-spots.

* ``slay_fused``   — end-to-end megakernel: Ψ + chunked causal attention in
                     one pass, custom VJP (features never touch HBM).
* ``slay_scan``    — chunked causal linear attention on precomputed
                     features, VMEM running state, custom VJP.
* ``feature_map``  — fused normalize→poly→PRF→Kronecker feature pipeline.
* ``decode_step``  — one-token serving step, in-place state, custom VJP.
* ``ops``          — jit'd layout-adapting wrappers (public entry points).
* ``ref``          — pure-jnp oracles (match ``repro.core``).
"""
from repro.kernels import ops, ref  # noqa: F401
