"""Pallas TPU kernel: chunked causal linear attention with VMEM-resident state.

TPU-native adaptation of SLAY's causal prefix computation (DESIGN.md §3).
GPU implementations use a per-token recurrence; on TPU we use the
chunk-parallel decomposition

    Y_c = Q_c S_{<c} + tril(Q_c K_cᵀ) V_c          (numerator)
    d_c = Q_c z_{<c} + rowsum(tril(Q_c K_cᵀ))      (denominator)
    S_c = S_{<c} + K_cᵀ V_c,   z_c = z_{<c} + Σ K_c

so every contraction is an MXU-shaped [T×m]·[m×dv] / [T×m]·[m×T] matmul and
the running state (S ∈ m×dv fp32, z ∈ m fp32) lives in VMEM scratch across
the sequential chunk axis of the grid — one HBM round-trip per token block.

Grid: (BH, L // T) with dimension_semantics ("parallel", "arbitrary") — the
chunk axis iterates innermost and sequentially, so scratch carries state.
GQA is expressed in the BlockSpec index maps: q-head row h reads kv row
h // group — the kv features are never materialized per-q-head.

Block shapes: T (chunk) and m (features) should be multiples of 128 for
MXU/VREG lane alignment; dv is typically 128 (head_dim). VMEM footprint per
step ≈ T·m (q,k) + T·dv (v,o) + m·dv + m (state) floats — e.g. T=256, m=384,
dv=128: ~0.9 MB « 16 MB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, k_ref, v_ref, o_ref, s_ref, z_ref, *, delta: float):
    """One (head, chunk) grid step. Refs hold VMEM blocks:

    q_ref (1, T, m), k_ref (1, T, m), v_ref (1, T, dv), o_ref (1, T, dv);
    scratch s_ref (m, dv) fp32, z_ref (1, m) fp32.
    """
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)
        z_ref[...] = jnp.zeros_like(z_ref)

    q = q_ref[0].astype(jnp.float32)          # (T, m)
    k = k_ref[0].astype(jnp.float32)          # (T, m)
    v = v_ref[0].astype(jnp.float32)          # (T, dv)
    s = s_ref[...]                            # (m, dv)
    z = z_ref[0]                              # (m,)

    # Inter-chunk: prefix state contribution.
    num = jax.lax.dot(q, s, preferred_element_type=jnp.float32)      # (T, dv)
    den = q @ z[:, None]                                             # (T, 1)

    # Intra-chunk: causal quadratic on features (T×T stays in VMEM).
    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (T, T)
    t = scores.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    scores = jnp.where(rows >= cols, scores, 0.0)
    num = num + jax.lax.dot(scores, v, preferred_element_type=jnp.float32)
    den = den + jnp.sum(scores, axis=1, keepdims=True)

    o_ref[0] = (num / (den + delta)).astype(o_ref.dtype)

    # Carry the running state to the next chunk.
    s_ref[...] = s + jax.lax.dot_general(k, v, (((0,), (0,)), ((), ())),
                                         preferred_element_type=jnp.float32)
    z_ref[0] = z + jnp.sum(k, axis=0)


@functools.partial(jax.jit, static_argnames=("chunk_size", "delta",
                                             "interpret"))
def causal_linear_attention(qf: jnp.ndarray, kf: jnp.ndarray, v: jnp.ndarray,
                            *, chunk_size: int = 256, delta: float = 1e-6,
                            interpret: bool = False) -> jnp.ndarray:
    """qf (BH, L, m), kf (BK, L, m), v (BK, L, dv) -> (BH, L, dv).

    BH must be a multiple of BK (GQA group size G = BH // BK); L must be a
    multiple of ``chunk_size``.
    """
    bh, L, m = qf.shape
    bk, _, dv = v.shape
    if bh % bk:
        raise ValueError(f"q rows {bh} not divisible by kv rows {bk}")
    if L % chunk_size:
        raise ValueError(f"L={L} not divisible by chunk={chunk_size}")
    g = bh // bk
    t = chunk_size
    grid = (bh, L // t)

    return pl.pallas_call(
        functools.partial(_kernel, delta=delta),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, t, m), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, t, m), lambda h, c: (h // g, c, 0)),
            pl.BlockSpec((1, t, dv), lambda h, c: (h // g, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, t, dv), lambda h, c: (h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, L, dv), v.dtype),
        scratch_shapes=[
            _scratch((m, dv)),   # S: running ΣKᵀV
            _scratch((1, m)),    # z: running ΣK
        ],
        compiler_params=_tpu_params(),
        interpret=interpret,
    )(qf, kf, v)


def _scratch(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)


def _tpu_params():
    from jax.experimental.pallas import tpu as pltpu
    # Chunk axis must stay sequential ("arbitrary") so VMEM scratch carries
    # the running state; head axis is embarrassingly parallel.
    return pltpu.CompilerParams(
        dimension_semantics=("parallel", "arbitrary"))
