"""Pallas TPU kernel: chunked causal linear attention with VMEM-resident state.

TPU-native adaptation of SLAY's causal prefix computation (DESIGN.md §3).
GPU implementations use a per-token recurrence; on TPU we use the
chunk-parallel decomposition

    Y_c = Q_c S_{<c} + tril(Q_c K_cᵀ) V_c          (numerator)
    d_c = Q_c z_{<c} + rowsum(tril(Q_c K_cᵀ))      (denominator)
    S_c = S_{<c} + K_cᵀ V_c,   z_c = z_{<c} + Σ K_c

so every contraction is an MXU-shaped [T×m]·[m×dv] / [T×m]·[m×T] matmul and
the running state (S ∈ m×dv fp32, z ∈ m fp32) lives in VMEM scratch across
the sequential chunk axis of the grid — one HBM round-trip per token block.

Grid: (BH, L // T) with dimension_semantics ("parallel", "arbitrary") — the
chunk axis iterates innermost and sequentially, so scratch carries state.
GQA is expressed in the BlockSpec index maps: q-head row h reads kv row
h // group — the kv features are never materialized per-q-head.

Block shapes: T (chunk) and m (features) should be multiples of 128 for
MXU/VREG lane alignment; dv is typically 128 (head_dim). VMEM footprint per
step ≈ T·m (q,k) + T·dv (v,o) + m·dv + m (state) floats — e.g. T=256, m=384,
dv=128: ~0.9 MB « 16 MB VMEM.

Differentiable: the public entry point carries a custom VJP (DESIGN.md §3
"Backward") so `use_pallas=True` works under `jax.grad`. The forward saves
only the per-token denominator (L floats/head, like flash attention's LSE);
the backward recomputes the intra-chunk scores from the saved features and
runs two scans — a forward scan re-carrying (S, z) for dQ and a reverse scan
carrying the state cotangents (dS, dz) for dK/dV.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (causal_mask as _causal_mask,
                                  tpu_params as _tpu_params,
                                  vmem_scratch as _scratch)


class ScanStatics(NamedTuple):
    chunk_size: int
    delta: float
    interpret: bool


def _kernel(q_ref, k_ref, v_ref, o_ref, den_ref, s_ref, z_ref, *,
            delta: float):
    """One (head, chunk) grid step. Refs hold VMEM blocks:

    q_ref (1, T, m), k_ref (1, T, m), v_ref (1, T, dv); outs o (1, T, dv),
    den (1, T); scratch s_ref (m, dv) fp32, z_ref (1, m) fp32.
    """
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)
        z_ref[...] = jnp.zeros_like(z_ref)

    q = q_ref[0].astype(jnp.float32)          # (T, m)
    k = k_ref[0].astype(jnp.float32)          # (T, m)
    v = v_ref[0].astype(jnp.float32)          # (T, dv)
    s = s_ref[...]                            # (m, dv)
    z = z_ref[0]                              # (m,)

    # Inter-chunk: prefix state contribution.
    num = jax.lax.dot(q, s, preferred_element_type=jnp.float32)      # (T, dv)
    den = q @ z[:, None]                                             # (T, 1)

    # Intra-chunk: causal quadratic on features (T×T stays in VMEM).
    scores = _causal_mask(jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32))                          # (T, T)
    num = num + jax.lax.dot(scores, v, preferred_element_type=jnp.float32)
    den = den + jnp.sum(scores, axis=1, keepdims=True)

    o_ref[0] = (num / (den + delta)).astype(o_ref.dtype)
    den_ref[0] = den[:, 0]

    # Carry the running state to the next chunk.
    s_ref[...] = s + jax.lax.dot_general(k, v, (((0,), (0,)), ((), ())),
                                         preferred_element_type=jnp.float32)
    z_ref[0] = z + jnp.sum(k, axis=0)


def _fwd_impl(st: ScanStatics, qf, kf, v):
    bh, L, m = qf.shape
    bk, _, dv = v.shape
    g = bh // bk
    t = st.chunk_size
    grid = (bh, L // t)
    return pl.pallas_call(
        functools.partial(_kernel, delta=st.delta),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, t, m), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, t, m), lambda h, c: (h // g, c, 0)),
            pl.BlockSpec((1, t, dv), lambda h, c: (h // g, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, t, dv), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, t), lambda h, c: (h, c)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, L, dv), v.dtype),
            jax.ShapeDtypeStruct((bh, L), jnp.float32),
        ],
        scratch_shapes=[
            _scratch((m, dv)),   # S: running ΣKᵀV
            _scratch((1, m)),    # z: running ΣK
        ],
        compiler_params=_tpu_params(),
        interpret=st.interpret,
    )(qf, kf, v)


# ---------------------------------------------------------------------------
# Backward kernels (feature-level; see slay_fused.py for the raw-q/k fused
# variant that also backprops through Ψ).
# ---------------------------------------------------------------------------


def _bwd_q_kernel(q_ref, k_ref, v_ref, dy_ref, y_ref, den_ref, dq_ref,
                  s_ref, z_ref, *, delta: float):
    """Forward chunk scan: dQ = G S_{<c}ᵀ + h z_{<c}ᵀ + tril(G Vᵀ + h 1ᵀ) K."""
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)
        z_ref[...] = jnp.zeros_like(z_ref)

    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    dy = dy_ref[0].astype(jnp.float32)
    y = y_ref[0].astype(jnp.float32)
    e = den_ref[0][:, None] + delta
    s = s_ref[...]
    z = z_ref[0]

    gg = dy / e
    hh = -jnp.sum(dy * y, axis=-1, keepdims=True) / e
    dp = _causal_mask(
        jax.lax.dot_general(gg, v, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) + hh)
    dq = (jax.lax.dot_general(gg, s, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
          + hh * z[None, :]
          + jax.lax.dot(dp, k, preferred_element_type=jnp.float32))
    dq_ref[0] = dq.astype(dq_ref.dtype)

    s_ref[...] = s + jax.lax.dot_general(k, v, (((0,), (0,)), ((), ())),
                                         preferred_element_type=jnp.float32)
    z_ref[0] = z + jnp.sum(k, axis=0)


def _bwd_kv_kernel(q_ref, k_ref, v_ref, dy_ref, y_ref, den_ref, dk_ref,
                   dv_ref, ds_ref, dz_ref, *, delta: float):
    """Reverse chunk scan carrying (dS, dz):
    dK = dPᵀ Q + V dSᵀ + 1 dzᵀ;  dV = Pᵀ G + K dS."""
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        ds_ref[...] = jnp.zeros_like(ds_ref)
        dz_ref[...] = jnp.zeros_like(dz_ref)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    dy = dy_ref[0].astype(jnp.float32)
    y = y_ref[0].astype(jnp.float32)
    e = den_ref[0][:, None] + delta
    ds = ds_ref[...]
    dz = dz_ref[0]

    gg = dy / e
    hh = -jnp.sum(dy * y, axis=-1, keepdims=True) / e
    scores = _causal_mask(jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32))
    dp = _causal_mask(
        jax.lax.dot_general(gg, v, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) + hh)
    dk = (jax.lax.dot_general(dp, q, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
          + jax.lax.dot_general(v, ds, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
          + dz[None, :])
    dvv = (jax.lax.dot_general(scores, gg, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
           + jax.lax.dot(k, ds, preferred_element_type=jnp.float32))
    dk_ref[0] = dk.astype(dk_ref.dtype)
    dv_ref[0] = dvv.astype(dv_ref.dtype)

    ds_ref[...] = ds + jax.lax.dot_general(
        q, gg, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    dz_ref[0] = dz + jnp.sum(q * hh, axis=0)


def _bwd_impl(st: ScanStatics, qf, kf, v, y, den, dy):
    bh, L, m = qf.shape
    bk, _, dv = v.shape
    g = bh // bk
    t = st.chunk_size
    nc = L // t

    dq = pl.pallas_call(
        functools.partial(_bwd_q_kernel, delta=st.delta),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, t, m), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, t, m), lambda h, c: (h // g, c, 0)),
            pl.BlockSpec((1, t, dv), lambda h, c: (h // g, c, 0)),
            pl.BlockSpec((1, t, dv), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, t, dv), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, t), lambda h, c: (h, c)),
        ],
        out_specs=pl.BlockSpec((1, t, m), lambda h, c: (h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, L, m), qf.dtype),
        scratch_shapes=[_scratch((m, dv)), _scratch((1, m))],
        compiler_params=_tpu_params(),
        interpret=st.interpret,
    )(qf, kf, v, dy, y, den)

    dk_p, dv_p = pl.pallas_call(
        functools.partial(_bwd_kv_kernel, delta=st.delta),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, t, m), lambda h, c: (h, nc - 1 - c, 0)),
            pl.BlockSpec((1, t, m), lambda h, c: (h // g, nc - 1 - c, 0)),
            pl.BlockSpec((1, t, dv), lambda h, c: (h // g, nc - 1 - c, 0)),
            pl.BlockSpec((1, t, dv), lambda h, c: (h, nc - 1 - c, 0)),
            pl.BlockSpec((1, t, dv), lambda h, c: (h, nc - 1 - c, 0)),
            pl.BlockSpec((1, t), lambda h, c: (h, nc - 1 - c)),
        ],
        out_specs=[
            pl.BlockSpec((1, t, m), lambda h, c: (h, nc - 1 - c, 0)),
            pl.BlockSpec((1, t, dv), lambda h, c: (h, nc - 1 - c, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, L, m), kf.dtype),
            jax.ShapeDtypeStruct((bh, L, dv), v.dtype),
        ],
        scratch_shapes=[_scratch((m, dv)), _scratch((1, m))],
        compiler_params=_tpu_params(),
        interpret=st.interpret,
    )(qf, kf, v, dy, y, den)

    # GQA: reduce the per-q-head dk/dv partials over each group.
    dk = jnp.sum(dk_p.reshape(bk, g, L, m), axis=1).astype(kf.dtype)
    dvv = jnp.sum(dv_p.reshape(bk, g, L, dv), axis=1).astype(v.dtype)
    return dq, dk, dvv


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _scan(st: ScanStatics, qf, kf, v):
    y, _den = _fwd_impl(st, qf, kf, v)
    return y


def _scan_fwd(st: ScanStatics, qf, kf, v):
    y, den = _fwd_impl(st, qf, kf, v)
    return y, (qf, kf, v, y, den)


def _scan_bwd(st: ScanStatics, res, dy):
    qf, kf, v, y, den = res
    return _bwd_impl(st, qf, kf, v, y, den, dy)


_scan.defvjp(_scan_fwd, _scan_bwd)


@functools.partial(jax.jit, static_argnames=("chunk_size", "delta",
                                             "interpret"))
def causal_linear_attention(qf: jnp.ndarray, kf: jnp.ndarray, v: jnp.ndarray,
                            *, chunk_size: int = 256, delta: float = 1e-6,
                            interpret: bool = False) -> jnp.ndarray:
    """qf (BH, L, m), kf (BK, L, m), v (BK, L, dv) -> (BH, L, dv).

    BH must be a multiple of BK (GQA group size G = BH // BK); L must be a
    multiple of ``chunk_size``. Differentiable (custom VJP).
    """
    bh, L, m = qf.shape
    bk, _, dv = v.shape
    if bh % bk:
        raise ValueError(f"q rows {bh} not divisible by kv rows {bk}")
    if L % chunk_size:
        raise ValueError(f"L={L} not divisible by chunk={chunk_size}")
    st = ScanStatics(chunk_size=chunk_size, delta=delta, interpret=interpret)
    return _scan(st, qf, kf, v)
