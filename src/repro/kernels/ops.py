"""Jit'd public wrappers around the Pallas kernels.

Model code calls these; they translate between the model's
(..., L, H, feat) layout and the kernels' head-major (BH, L, feat) layout,
and fall back to the jnp reference on non-TPU backends (interpret mode is
used for correctness tests, not production CPU execution).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.features import SlayFeatureConfig
from repro.kernels import feature_map as _fm
from repro.kernels import ref as _ref
from repro.kernels import slay_scan as _scan


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def slay_causal_attention(qf: jnp.ndarray, kf: jnp.ndarray, v: jnp.ndarray,
                          *, chunk_size: int = 256, delta: float = 1e-6,
                          interpret: bool | None = None) -> jnp.ndarray:
    """Causal linear attention on fused features.

    qf (..., L, H, m), kf (..., L, Hkv, m), v (..., L, Hkv, dv)
    -> (..., L, H, dv).
    """
    *lead, L, H, m = qf.shape
    hkv, dv = kf.shape[-2], v.shape[-1]
    g = H // hkv
    b = 1
    for x in lead:
        b *= x
    # (..., L, H, m) -> (B*Hkv*G, L, m): group-major so q row i reads kv
    # row i // g, matching the kernel's index map.
    qh = (qf.reshape(b, L, hkv, g, m).transpose(0, 2, 3, 1, 4)
          .reshape(b * hkv * g, L, m))
    kh = kf.reshape(b, L, hkv, m).transpose(0, 2, 1, 3).reshape(b * hkv, L, m)
    vh = v.reshape(b, L, hkv, dv).transpose(0, 2, 1, 3).reshape(b * hkv, L, dv)

    use_kernel = _on_tpu() if interpret is None else True
    if use_kernel:
        yh = _scan.causal_linear_attention(
            qh, kh, vh, chunk_size=chunk_size, delta=delta,
            interpret=bool(interpret))
    else:
        yh = _ref.causal_linear_attention_ref(
            qh, kh, vh, chunk_size=chunk_size, delta=delta)
    y = (yh.reshape(b, hkv, g, L, dv).transpose(0, 3, 1, 2, 4)
         .reshape(*lead, L, H, dv))
    return y


def slay_features(u: jnp.ndarray, params: dict, cfg: SlayFeatureConfig, *,
                  block_tokens: int = 256,
                  interpret: bool | None = None) -> jnp.ndarray:
    """Fused Ψ(u) over the trailing dim; u (..., d) -> (..., m)."""
    use_kernel = (_on_tpu() if interpret is None else True)
    kernelizable = (cfg.poly_kind == "anchor" and cfg.fusion == "tensor")
    *lead, d = u.shape
    n = 1
    for x in lead:
        n *= x
    if use_kernel and kernelizable and n % block_tokens == 0:
        out = _fm.slay_feature_map(
            u.reshape(n, d), params["anchors"], params["omegas"], cfg,
            block_tokens=block_tokens, interpret=bool(interpret))
        return out.reshape(*lead, cfg.feature_dim)
    return _ref.slay_features_ref(u, params, cfg)
