"""Jit'd public wrappers around the Pallas kernels.

Model code calls these; they translate between the model's
(..., L, H, feat) layout and the kernels' head-major (BH, L, feat) layout,
zero-pad ragged lengths to block multiples (zero features contribute
nothing to the running state, matching ``core.linear_attention``), and fall
back to the jnp reference on non-TPU backends.

``interpret`` semantics (uniform across wrappers):
    None  — compiled kernel on TPU, jnp reference elsewhere (production).
    False — same as None: "compiled kernel if available"; an explicit False
            never forces an interpret-mode kernel onto CPU.
    True  — interpret-mode kernel on any backend (correctness tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.features import SlayFeatureConfig
from repro.kernels import decode_step as _dk
from repro.kernels import feature_map as _fm
from repro.kernels import ref as _ref
from repro.kernels import slay_fused as _fused
from repro.kernels import slay_scan as _scan


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _use_kernel(interpret: bool | None) -> bool:
    """Kernel-vs-reference dispatch for the ``interpret`` tri-state."""
    if interpret is True:
        return True
    return _on_tpu()


def _pad_len(L: int, block: int) -> int:
    return (block - L % block) % block


def _headmajor_call(kernel_fn, q, k, v, *, chunk_size: int):
    """Run a head-major (BH, L, feat) kernel from the model layout.

    q (..., L, H, dq), k (..., L, Hkv, dk), v (..., L, Hkv, dv)
    -> (..., L, H, dv). Zero-pads ragged L to a chunk multiple (zero
    features contribute nothing to the running state) and maps q heads
    group-major so q row i reads kv row i // g, matching the kernels'
    index maps.
    """
    *lead, L, H, dq = q.shape
    hkv, dk, dv = k.shape[-2], k.shape[-1], v.shape[-1]
    g = H // hkv
    b = 1
    for x in lead:
        b *= x
    pad = _pad_len(L, chunk_size)
    if pad:
        padding = [(0, 0)] * len(lead) + [(0, pad), (0, 0), (0, 0)]
        q = jnp.pad(q, padding)
        k = jnp.pad(k, padding)
        v = jnp.pad(v, padding)
    Lp = L + pad
    qh = (q.reshape(b, Lp, hkv, g, dq).transpose(0, 2, 3, 1, 4)
          .reshape(b * hkv * g, Lp, dq))
    kh = k.reshape(b, Lp, hkv, dk).transpose(0, 2, 1, 3).reshape(
        b * hkv, Lp, dk)
    vh = v.reshape(b, Lp, hkv, dv).transpose(0, 2, 1, 3).reshape(
        b * hkv, Lp, dv)
    yh = kernel_fn(qh, kh, vh)
    y = (yh.reshape(b, hkv, g, Lp, dv).transpose(0, 3, 1, 2, 4)
         .reshape(*lead, Lp, H, dv))
    return y[..., :L, :, :] if pad else y


def slay_causal_attention(qf: jnp.ndarray, kf: jnp.ndarray, v: jnp.ndarray,
                          *, chunk_size: int = 256, delta: float = 1e-6,
                          interpret: bool | None = None) -> jnp.ndarray:
    """Causal linear attention on fused features.

    qf (..., L, H, m), kf (..., L, Hkv, m), v (..., L, Hkv, dv)
    -> (..., L, H, dv). L may be ragged — zero-padded to a chunk multiple
    (zero features contribute nothing to the running state).
    """
    if not _use_kernel(interpret):
        from repro.core import linear_attention as la
        return la.causal_chunked(qf, kf, v, chunk_size=chunk_size,
                                 delta=delta)
    return _headmajor_call(
        lambda qh, kh, vh: _scan.causal_linear_attention(
            qh, kh, vh, chunk_size=chunk_size, delta=delta,
            interpret=bool(interpret)),
        qf, kf, v, chunk_size=chunk_size)


def slay_fused_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         params: dict, cfg: SlayFeatureConfig, *,
                         chunk_size: int = 256, delta: float = 1e-6,
                         interpret: bool | None = None) -> jnp.ndarray:
    """End-to-end SLAY causal attention on **raw** q/k (no HBM features).

    q (..., L, H, d), k (..., L, Hkv, d), v (..., L, Hkv, dv)
    -> (..., L, H, dv). Ψ is computed inside the megakernel; the only
    per-token HBM traffic is the raw O(L·d) q/k/v reads and the O(L·dv)
    output write. Differentiable (custom VJP); ragged L is zero-padded.

    Falls back to the jnp reference (features + chunked scan) off-TPU or
    for non-kernelizable feature configs.
    """
    kernelizable = (cfg.poly_kind == "anchor" and cfg.fusion == "tensor")
    if not (_use_kernel(interpret) and kernelizable):
        from repro.core import linear_attention as la
        from repro.core.features import slay_features
        qf = slay_features(q, params, cfg)
        kf = slay_features(k, params, cfg)
        return la.causal_chunked(qf, kf, v, chunk_size=chunk_size,
                                 delta=delta)
    return _headmajor_call(
        lambda qh, kh, vh: _fused.fused_causal_attention(
            qh, kh, vh, params["anchors"], params["omegas"], cfg,
            chunk_size=chunk_size, delta=delta, interpret=bool(interpret)),
        q, k, v, chunk_size=chunk_size)


def decode_linear_step(qf: jnp.ndarray, kf: jnp.ndarray, v: jnp.ndarray,
                       s: jnp.ndarray, z: jnp.ndarray,
                       active: jnp.ndarray | None = None, *,
                       delta: float = 1e-6,
                       interpret: bool | None = None):
    """One-token linear-attention decode step from the *model* layout.

    qf (B, H, m), kf (B, Hkv, m), v (B, Hkv, dv), s (B, Hkv, m, dv) fp32,
    z (B, Hkv, m) fp32 -> (y (B, H, dv), s', z').

    This is the serving decode hot path: the whole slot pool is one fused
    VMEM-resident Pallas dispatch (grid = B·Hkv kv rows, in-place state
    RMW). ``active`` (B,) masks continuous-batching pool rows — drained
    slots skip the state update and MXU readout (y rows zero, (s, z) pass
    through bit-identical), so an idle slot costs only block pipelining.
    Falls back to the jnp oracle off-TPU with identical masked semantics.
    """
    B, H, m = qf.shape
    hkv, dv = kf.shape[-2], v.shape[-1]
    g = H // hkv
    qh = qf.reshape(B * hkv * g, m)          # model heads are kv-major
    kh = kf.reshape(B * hkv, m)
    vh = v.reshape(B * hkv, dv)
    sh = s.reshape(B * hkv, m, dv)
    zh = z.reshape(B * hkv, m)
    ah = None
    if active is not None:
        ah = jnp.broadcast_to(active.astype(jnp.int32)[:, None],
                              (B, hkv)).reshape(B * hkv)
    if not _use_kernel(interpret):
        y, s2, z2 = _ref.decode_linear_attention_ref(qh, kh, vh, sh, zh, ah,
                                                     delta=delta)
    else:
        y, s2, z2 = _dk.decode_linear_attention(qh, kh, vh, sh, zh, ah,
                                                delta=delta,
                                                interpret=bool(interpret))
    return (y.reshape(B, H, dv), s2.reshape(B, hkv, m, dv),
            z2.reshape(B, hkv, m))


def slay_features(u: jnp.ndarray, params: dict, cfg: SlayFeatureConfig, *,
                  block_tokens: int = 256,
                  interpret: bool | None = None) -> jnp.ndarray:
    """Fused Ψ(u) over the trailing dim; u (..., d) -> (..., m).

    Ragged token counts are zero-padded to a block multiple and sliced
    (Ψ(0) = 0 for the anchor map, so padding is inert downstream).
    """
    kernelizable = (cfg.poly_kind == "anchor" and cfg.fusion == "tensor")
    *lead, d = u.shape
    n = 1
    for x in lead:
        n *= x
    if not (_use_kernel(interpret) and kernelizable and n > 0):
        return _ref.slay_features_ref(u, params, cfg)
    pad = _pad_len(n, block_tokens)
    uf = u.reshape(n, d)
    if pad:
        uf = jnp.pad(uf, ((0, pad), (0, 0)))
    out = _fm.slay_feature_map(
        uf, params["anchors"], params["omegas"], cfg,
        block_tokens=block_tokens, interpret=bool(interpret))
    if pad:
        out = out[:n]
    return out.reshape(*lead, cfg.feature_dim)
