"""Pallas TPU kernel: one-token linear-attention decode step.

The serving hot loop: update the running state with the new key/value and
read out the attention for the G query heads of each kv head —

    S' = S + Ψ(k)ᵀ v        (m x dv, fp32, in-place)
    z' = z + Ψ(k)           (m,     fp32, in-place)
    y_g = (q_g S') / (q_g z' + δ)      for g = 1..G

All operands for one kv head fit comfortably in VMEM (m·dv fp32 ≈ 192 KB at
m=384, dv=128), so the step is a single fused VMEM-resident kernel: one HBM
read-modify-write of the state per token instead of separate outer-product /
matvec / reduction kernels. The state buffers are donated
(input_output_aliased) — the update is truly in place in HBM.

Grid: (BK,) — one program per kv head; the G query heads of that kv head
are processed together as a (G, m) x (m, dv) MXU matmul.

Differentiable: the public entry point carries a custom VJP so the decode
step composes with `jax.grad` (e.g. RL-style losses over generated tokens).
The backward is O(m·dv) closed-form math on one token — far below Pallas
dispatch granularity — so it is plain jnp (DESIGN.md §3).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _step_body(qf_ref, kf_ref, v_ref, s_ref, z_ref, y_ref, s_out, z_out,
               delta: float):
    """Shared per-kv-head step: state RMW + grouped-query readout."""
    kf = kf_ref[0].astype(jnp.float32)                       # (m,)
    v = v_ref[0].astype(jnp.float32)                         # (dv,)
    s = s_ref[0] + kf[:, None] * v[None, :]                  # (m, dv)
    z = z_ref[0] + kf                                        # (m,)
    q = qf_ref[0].astype(jnp.float32)                        # (G, m)
    num = jax.lax.dot(q, s, preferred_element_type=jnp.float32)   # (G, dv)
    den = q @ z[:, None]                                          # (G, 1)
    y_ref[0] = (num / (den + delta)).astype(y_ref.dtype)
    s_out[0] = s
    z_out[0] = z


def _kernel(qf_ref, kf_ref, v_ref, s_ref, z_ref, y_ref, s_out, z_out, *,
            delta: float):
    """Refs (per kv head): qf (1, G, m), kf (1, m), v (1, dv),
    s (1, m, dv) fp32, z (1, m) fp32; outs y (1, G, dv), s', z'."""
    _step_body(qf_ref, kf_ref, v_ref, s_ref, z_ref, y_ref, s_out, z_out,
               delta)


def _kernel_masked(a_ref, qf_ref, kf_ref, v_ref, s_ref, z_ref, y_ref,
                   s_out, z_out, *, delta: float):
    """Active-slot-masked step for the continuous-batching pool.

    a (1, 1) int32 per kv row: nonzero = slot is serving a live request.
    Drained slots skip the feature/MXU work and the state RMW entirely —
    the state block passes through unchanged and the output row is zero —
    so an idle slot costs only the block pipeline, no compute.
    """
    active = a_ref[0, 0] != 0

    @pl.when(active)
    def _():
        _step_body(qf_ref, kf_ref, v_ref, s_ref, z_ref, y_ref, s_out,
                   z_out, delta)

    @pl.when(jnp.logical_not(active))
    def _():
        y_ref[0] = jnp.zeros_like(y_ref[0])
        s_out[0] = s_ref[0]
        z_out[0] = z_ref[0]


class DecodeStatics(NamedTuple):
    delta: float
    interpret: bool


@functools.partial(jax.jit, static_argnames=("delta", "interpret"))
def decode_linear_attention(qf: jnp.ndarray, kf: jnp.ndarray, v: jnp.ndarray,
                            s: jnp.ndarray, z: jnp.ndarray,
                            active: jnp.ndarray | None = None, *,
                            delta: float = 1e-6,
                            interpret: bool = False):
    """qf (BH, m), kf (BK, m), v (BK, dv), s (BK, m, dv) f32, z (BK, m) f32
    -> (y (BH, dv), s', z'). BH must be a multiple of BK (GQA).
    Differentiable (custom VJP) when ``active`` is None.

    ``active`` (BK,) int/bool masks continuous-batching pool rows: inactive
    (drained) kv rows skip the state update and MXU readout — y rows are 0
    and (s, z) pass through unchanged — so an idle serving slot costs no
    compute. The masked path is forward-only: it is the serving decode
    tick, dispatched from the engine's jitted macro-step via
    ``attention.decode_step`` → ``ops.decode_linear_step`` whenever
    ``spec.use_pallas`` is set (jnp reference off-TPU, same semantics).
    """
    bh, m = qf.shape
    bk = v.shape[0]
    if bh % bk:
        raise ValueError(f"q rows {bh} not divisible by kv rows {bk}")
    st = DecodeStatics(delta=delta, interpret=interpret)
    if active is None:
        return _decode(st, qf, kf, v, s, z)
    if active.shape != (bk,):
        raise ValueError(f"active shape {active.shape} != ({bk},)")
    return _decode_masked(st, qf, kf, v, s, z,
                          active.astype(jnp.int32))


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _decode(st: DecodeStatics, qf, kf, v, s, z):
    return _decode_impl(st, qf, kf, v, s, z)


def _specs(bk, g, m, dv, y_dtype):
    in_specs = [
        pl.BlockSpec((1, g, m), lambda i: (i, 0, 0)),
        pl.BlockSpec((1, m), lambda i: (i, 0)),
        pl.BlockSpec((1, dv), lambda i: (i, 0)),
        pl.BlockSpec((1, m, dv), lambda i: (i, 0, 0)),
        pl.BlockSpec((1, m), lambda i: (i, 0)),
    ]
    out_specs = [
        pl.BlockSpec((1, g, dv), lambda i: (i, 0, 0)),
        pl.BlockSpec((1, m, dv), lambda i: (i, 0, 0)),
        pl.BlockSpec((1, m), lambda i: (i, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((bk, g, dv), y_dtype),
        jax.ShapeDtypeStruct((bk, m, dv), jnp.float32),
        jax.ShapeDtypeStruct((bk, m), jnp.float32),
    ]
    return in_specs, out_specs, out_shape


def _decode_impl(st: DecodeStatics, qf, kf, v, s, z):
    bh, m = qf.shape
    bk, dv = v.shape
    g = bh // bk
    qg = qf.reshape(bk, g, m)
    in_specs, out_specs, out_shape = _specs(bk, g, m, dv, v.dtype)

    y, s2, z2 = pl.pallas_call(
        functools.partial(_kernel, delta=st.delta),
        grid=(bk,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        input_output_aliases={3: 1, 4: 2},   # s, z updated in place
        interpret=st.interpret,
    )(qg, kf, v, s, z)
    return y.reshape(bh, dv), s2, z2


def _decode_masked(st: DecodeStatics, qf, kf, v, s, z, active):
    bh, m = qf.shape
    bk, dv = v.shape
    g = bh // bk
    qg = qf.reshape(bk, g, m)
    in_specs, out_specs, out_shape = _specs(bk, g, m, dv, v.dtype)
    in_specs = [pl.BlockSpec((1, 1), lambda i: (i, 0))] + in_specs

    y, s2, z2 = pl.pallas_call(
        functools.partial(_kernel_masked, delta=st.delta),
        grid=(bk,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        input_output_aliases={4: 1, 5: 2},   # s, z updated in place
        interpret=st.interpret,
    )(active.reshape(bk, 1), qg, kf, v, s, z)
    return y.reshape(bh, dv), s2, z2


def _decode_fwd(st: DecodeStatics, qf, kf, v, s, z):
    y, s2, z2 = _decode_impl(st, qf, kf, v, s, z)
    # NOTE: s/z are donated to s2/z2 by the kernel; save the *updated* state
    # (s2 = s + kfᵀv, z2 = z + kf) and the inputs needed to reconstruct.
    return (y, s2, z2), (qf, kf, v, s2, z2, y)


def _decode_bwd(st: DecodeStatics, res, cts):
    """Closed-form one-token backward (jnp; below kernel granularity).

    y_g = (q_g S') / (q_g z' + δ) with S' = S + kᵀv, z' = z + k.
    Cotangents arrive for all three outputs (y, S', z').
    """
    qf, kf, v, s2, z2, y = res
    dy, ds2_in, dz2_in = cts
    bh, m = qf.shape
    bk, dv = v.shape
    g = bh // bk
    f32 = jnp.float32
    qg = qf.reshape(bk, g, m).astype(f32)
    dyg = dy.reshape(bk, g, dv).astype(f32)
    yg = y.reshape(bk, g, dv).astype(f32)
    den = jnp.einsum("kgm,km->kg", qg, z2) + st.delta          # (bk, g)
    gg = dyg / den[..., None]                                  # dnum
    hh = -jnp.sum(dyg * yg, axis=-1) / den                     # dden (bk, g)
    dqg = (jnp.einsum("kgd,kmd->kgm", gg, s2)
           + hh[..., None] * z2[:, None, :])
    ds2 = ds2_in.astype(f32) + jnp.einsum("kgm,kgd->kmd", qg, gg)
    dz2 = dz2_in.astype(f32) + jnp.einsum("kgm,kg->km", qg, hh)
    # S' = S + kfᵀ v, z' = z + kf.
    vf = v.astype(f32)
    kff = kf.astype(f32)
    dkf = jnp.einsum("kmd,kd->km", ds2, vf) + dz2
    dvv = jnp.einsum("km,kmd->kd", kff, ds2)
    return (dqg.reshape(bh, m).astype(qf.dtype), dkf.astype(kf.dtype),
            dvv.astype(v.dtype), ds2, dz2)


_decode.defvjp(_decode_fwd, _decode_bwd)
