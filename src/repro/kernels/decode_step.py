"""Pallas TPU kernel: one-token linear-attention decode step.

The serving hot loop: update the running state with the new key/value and
read out the attention for the G query heads of each kv head —

    S' = S + Ψ(k)ᵀ v        (m x dv, fp32, in-place)
    z' = z + Ψ(k)           (m,     fp32, in-place)
    y_g = (q_g S') / (q_g z' + δ)      for g = 1..G

All operands for one kv head fit comfortably in VMEM (m·dv fp32 ≈ 192 KB at
m=384, dv=128), so the step is a single fused VMEM-resident kernel: one HBM
read-modify-write of the state per token instead of separate outer-product /
matvec / reduction kernels. The state buffers are donated
(input_output_aliased) — the update is truly in place in HBM.

Grid: (BK,) — one program per kv head; the G query heads of that kv head
are processed together as a (G, m) x (m, dv) MXU matmul.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(qf_ref, kf_ref, v_ref, s_ref, z_ref, y_ref, s_out, z_out, *,
            delta: float):
    """Refs (per kv head): qf (1, G, m), kf (1, m), v (1, dv),
    s (1, m, dv) fp32, z (1, m) fp32; outs y (1, G, dv), s', z'."""
    kf = kf_ref[0].astype(jnp.float32)                       # (m,)
    v = v_ref[0].astype(jnp.float32)                         # (dv,)
    s = s_ref[0] + kf[:, None] * v[None, :]                  # (m, dv)
    z = z_ref[0] + kf                                        # (m,)
    q = qf_ref[0].astype(jnp.float32)                        # (G, m)
    num = jax.lax.dot(q, s, preferred_element_type=jnp.float32)   # (G, dv)
    den = q @ z[:, None]                                          # (G, 1)
    y_ref[0] = (num / (den + delta)).astype(y_ref.dtype)
    s_out[0] = s
    z_out[0] = z


@functools.partial(jax.jit, static_argnames=("delta", "interpret"))
def decode_linear_attention(qf: jnp.ndarray, kf: jnp.ndarray, v: jnp.ndarray,
                            s: jnp.ndarray, z: jnp.ndarray, *,
                            delta: float = 1e-6,
                            interpret: bool = False):
    """qf (BH, m), kf (BK, m), v (BK, dv), s (BK, m, dv) f32, z (BK, m) f32
    -> (y (BH, dv), s', z'). BH must be a multiple of BK (GQA)."""
    bh, m = qf.shape
    bk, dv = v.shape
    if bh % bk:
        raise ValueError(f"q rows {bh} not divisible by kv rows {bk}")
    g = bh // bk
    qg = qf.reshape(bk, g, m)

    y, s2, z2 = pl.pallas_call(
        functools.partial(_kernel, delta=delta),
        grid=(bk,),
        in_specs=[
            pl.BlockSpec((1, g, m), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
            pl.BlockSpec((1, dv), lambda i: (i, 0)),
            pl.BlockSpec((1, m, dv), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, g, dv), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, m, dv), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bk, g, dv), v.dtype),
            jax.ShapeDtypeStruct((bk, m, dv), jnp.float32),
            jax.ShapeDtypeStruct((bk, m), jnp.float32),
        ],
        input_output_aliases={3: 1, 4: 2},   # s, z updated in place
        interpret=interpret,
    )(qg, kf, v, s, z)
    return y.reshape(bh, dv), s2, z2
