"""Pure-jnp oracles for the Pallas kernels in this package.

These intentionally re-route through ``repro.core`` — the core implementations
are the mathematically-audited references (tested against the closed-form
kernel in tests/), and the Pallas kernels must match them bit-for-bit up to
fp32 accumulation order.

Layouts used by the kernels (head-major, TPU-friendly):
    qf: (BH, L, m)     fused SLAY features of queries, one row per q-head
    kf: (BK, L, m)     fused features of keys, one row per kv-head
    v:  (BK, L, dv)
where BH = batch * num_q_heads, BK = batch * num_kv_heads and the GQA group
size G = BH // BK maps q-head row i to kv row i // G.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import linear_attention as la
from repro.core.features import SlayFeatureConfig, slay_features


def causal_linear_attention_ref(qf: jnp.ndarray, kf: jnp.ndarray,
                                v: jnp.ndarray, *, chunk_size: int = 256,
                                delta: float = 1e-6) -> jnp.ndarray:
    """Oracle for kernels.slay_scan: head-major chunked causal linear attn.

    qf (BH, L, m), kf (BK, L, m), v (BK, L, dv) -> (BH, L, dv).
    """
    bh, L, m = qf.shape
    bk, _, dv = v.shape
    g = bh // bk
    # Reshape into core's (batch, L, heads, feat) convention: treat BK as
    # batch and G as heads-per-kv so grouping matches i -> i // G.
    q = qf.reshape(bk, g, L, m).transpose(0, 2, 1, 3)       # (bk, L, g, m)
    k = kf[:, :, None, :]                                    # (bk, L, 1, m)
    vv = v[:, :, None, :]                                    # (bk, L, 1, dv)
    y = la.causal_chunked(q, k, vv, chunk_size=chunk_size, delta=delta)
    return y.transpose(0, 2, 1, 3).reshape(bh, L, dv)


def slay_features_ref(u: jnp.ndarray, params: dict,
                      cfg: SlayFeatureConfig) -> jnp.ndarray:
    """Oracle for kernels.feature_map: Ψ(u) over the trailing dim."""
    return slay_features(u, params, cfg)


def decode_linear_attention_ref(qf, kf, v, s, z, active=None, *,
                                delta: float = 1e-6):
    """Oracle for kernels.decode_step: one-token state update + readout.

    qf (BH, m), kf (BK, m), v (BK, dv), s (BK, m, dv), z (BK, m).
    BK is treated as the batch; each kv row serves its G = BH // BK query
    heads (q row i -> kv row i // G), expressed to core.decode_step as an
    explicit singleton kv-head axis. ``active`` (BK,) masks drained pool
    rows: y rows zero, state passes through (continuous-batching slots).
    """
    bh, m = qf.shape
    bk, dv = v.shape
    g = bh // bk
    state = la.LinearState(s[:, None], z[:, None])      # (bk, 1, m, dv)
    y, new = la.decode_step(qf.reshape(bk, g, m), kf[:, None], v[:, None],
                            state, delta=delta)
    y, s2, z2 = y.reshape(bh, dv), new.s[:, 0], new.z[:, 0]
    if active is not None:
        am = active.astype(bool)
        y = jnp.where(jnp.repeat(am, g)[:, None], y, 0.0)
        s2 = jnp.where(am[:, None, None], s2, s)
        z2 = jnp.where(am[:, None], z2, z)
    return y, s2, z2
