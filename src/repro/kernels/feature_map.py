"""Pallas TPU kernel: fused SLAY feature map Ψ(u).

Fuses the whole per-token feature pipeline into one VMEM-resident pass
(DESIGN.md §3 "Feature-map fusion"):

    normalize → anchor poly φ_p = (uᵀa)²/√P → PRF φ_e = exp(√(2s)ωᵀu − s)/√D
              → per-node Kronecker √w_r (φ_p ⊗ φ_e) → concat over r.

On GPU these are 4-5 separate elementwise/matmul kernels with HBM traffic of
~(2R+3)·L·max(P·D, d) floats; fused, each token block makes exactly one HBM
read (T·d) and one write (T·R·P·D). Both matmuls (u·Aᵀ, u·Ωᵀ) are MXU ops.

Grid: (num_token_blocks,) over a flattened token axis. Anchors/omegas are
small (P·d, D·d) and are loaded whole into VMEM for every block (they fit in
a few KB). Quadrature constants (s_r, √w_r) are compile-time Python floats —
R is small (default 3) so the node loop is unrolled.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import quadrature
from repro.core.features import SlayFeatureConfig


def _kernel(u_ref, a_ref, w_ref, o_ref, *, s_nodes, sqrt_w, num_anchors,
            num_prf, norm_eps):
    """u_ref (T, d), a_ref (P, d), w_ref (D, d), o_ref (T, R*P*D)."""
    u = u_ref[...].astype(jnp.float32)                     # (T, d)
    # Spherical constraint (paper Eq. 2), fp32 rsqrt.
    inv = jax.lax.rsqrt(jnp.sum(u * u, axis=-1, keepdims=True) + norm_eps)
    u = u * inv

    a = a_ref[...].astype(jnp.float32)                     # (P, d)
    w = w_ref[...].astype(jnp.float32)                     # (D, d)
    # Anchor poly features: (uᵀa_i)²/√P  (paper §2.4.2) — MXU matmul.
    pa = jax.lax.dot_general(u, a, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    phi_p = (pa * pa) * (1.0 / np.sqrt(num_anchors))       # (T, P)
    pw = jax.lax.dot_general(u, w, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (T, D)

    t = u.shape[0]
    chunks = []
    for s, sw in zip(s_nodes, sqrt_w):
        # PRF for node r (paper Eq. 9): exp(√(2s) ωᵀu − s)/√D.
        phi_e = jnp.exp(np.sqrt(2.0 * s) * pw - s) * (1.0 / np.sqrt(num_prf))
        # Kronecker fusion √w_r (φ_p ⊗ φ_e)  (paper Eq. 10).
        kron = (phi_p[:, :, None] * phi_e[:, None, :]) * sw
        chunks.append(kron.reshape(t, num_anchors * num_prf))
    o_ref[...] = jnp.concatenate(chunks, axis=-1).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("cfg", "block_tokens",
                                             "interpret"))
def slay_feature_map(u: jnp.ndarray, anchors: jnp.ndarray,
                     omegas: jnp.ndarray, cfg: SlayFeatureConfig, *,
                     block_tokens: int = 256,
                     interpret: bool = False) -> jnp.ndarray:
    """u (N, d) -> Ψ(u) (N, m) with m = R·P·D. N must divide block_tokens.

    Only the default configuration (anchor poly, explicit-tensor fusion) is
    kernelized — it is the hot path; other variants fall back to the jnp
    reference in ``repro.core.features``.
    """
    if cfg.poly_kind != "anchor" or cfg.fusion != "tensor":
        raise ValueError("kernelized path supports anchor+tensor only")
    n, d = u.shape
    if n % block_tokens:
        raise ValueError(f"N={n} not divisible by block={block_tokens}")
    s_np, w_np = quadrature.yat_quadrature(cfg.num_quad_nodes, cfg.eps)
    m = cfg.feature_dim

    return pl.pallas_call(
        functools.partial(
            _kernel,
            s_nodes=tuple(float(x) for x in s_np),
            sqrt_w=tuple(float(x) for x in np.sqrt(w_np)),
            num_anchors=cfg.num_anchors, num_prf=cfg.num_prf,
            norm_eps=1e-6),
        grid=(n // block_tokens,),
        in_specs=[
            pl.BlockSpec((block_tokens, d), lambda i: (i, 0)),
            pl.BlockSpec((cfg.num_anchors, d), lambda i: (0, 0)),
            pl.BlockSpec((cfg.num_prf, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_tokens, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, m), u.dtype),
        interpret=interpret,
    )(u, anchors, omegas)
