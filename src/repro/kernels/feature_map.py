"""Pallas TPU kernel: fused SLAY feature map Ψ(u).

Fuses the whole per-token feature pipeline into one VMEM-resident pass
(DESIGN.md §3 "Feature-map fusion"):

    normalize → anchor poly φ_p = (uᵀa)²/√P → PRF φ_e = exp(√(2s)ωᵀu − s)/√D
              → per-node Kronecker √w_r (φ_p ⊗ φ_e) → concat over r.

On GPU these are 4-5 separate elementwise/matmul kernels with HBM traffic of
~(2R+3)·L·max(P·D, d) floats; fused, each token block makes exactly one HBM
read (T·d) and one write (T·R·P·D). Both matmuls (u·Aᵀ, u·Ωᵀ) are MXU ops.

Grid: (num_token_blocks,) over a flattened token axis. Anchors/omegas are
small (P·d, D·d) and are loaded whole into VMEM for every block (they fit in
a few KB). Quadrature constants (s_r, √w_r) are compile-time Python floats —
R is small (default 3) so the node loop is unrolled.

Differentiable: the public entry point carries a custom VJP whose backward
is itself one Pallas kernel (recompute Ψ intermediates per block, emit du
plus per-block dA/dΩ partials reduced outside), so the two-dispatch
feature→scan pipeline trains end to end (DESIGN.md §3).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import quadrature
from repro.core.features import SlayFeatureConfig
from repro.kernels.common import FeatureStatics, features_bwd, features_fwd


class _MapStatics(NamedTuple):
    """Hashable static bundle for the feature kernel's custom-VJP boundary."""

    feat: FeatureStatics
    block_tokens: int
    interpret: bool


def _kernel(u_ref, a_ref, w_ref, o_ref, *, feat: FeatureStatics):
    """u_ref (T, d), a_ref (P, d), w_ref (D, d), o_ref (T, R*P*D).

    normalize → anchor poly (paper §2.4.2) → PRF (Eq. 9) → Kronecker
    fusion (Eq. 10), all via ``common.features_fwd`` — the same code the
    backward kernel differentiates, so fwd/bwd can never drift."""
    psi, _ = features_fwd(u_ref[...].astype(jnp.float32),
                          a_ref[...].astype(jnp.float32),
                          w_ref[...].astype(jnp.float32), feat)
    o_ref[...] = psi.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("cfg", "block_tokens",
                                             "interpret"))
def slay_feature_map(u: jnp.ndarray, anchors: jnp.ndarray,
                     omegas: jnp.ndarray, cfg: SlayFeatureConfig, *,
                     block_tokens: int = 256,
                     interpret: bool = False) -> jnp.ndarray:
    """u (N, d) -> Ψ(u) (N, m) with m = R·P·D. N must divide block_tokens.

    Only the default configuration (anchor poly, explicit-tensor fusion) is
    kernelized — it is the hot path; other variants fall back to the jnp
    reference in ``repro.core.features``. Differentiable (custom VJP).
    """
    if cfg.poly_kind != "anchor" or cfg.fusion != "tensor":
        raise ValueError("kernelized path supports anchor+tensor only")
    n, d = u.shape
    if n % block_tokens:
        raise ValueError(f"N={n} not divisible by block={block_tokens}")
    s_np, w_np = quadrature.yat_quadrature(cfg.num_quad_nodes, cfg.eps)
    feat = FeatureStatics(
        s_nodes=tuple(float(x) for x in s_np),
        sqrt_w=tuple(float(x) for x in np.sqrt(w_np)),
        num_anchors=cfg.num_anchors, num_prf=cfg.num_prf)
    st = _MapStatics(feat=feat, block_tokens=block_tokens,
                     interpret=interpret)
    return _fmap(st, u, anchors, omegas)


def _fwd_impl(st: _MapStatics, u, anchors, omegas):
    n, d = u.shape
    f = st.feat
    m = len(f.s_nodes) * f.num_anchors * f.num_prf
    block = st.block_tokens
    return pl.pallas_call(
        functools.partial(_kernel, feat=f),
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            pl.BlockSpec((f.num_anchors, d), lambda i: (0, 0)),
            pl.BlockSpec((f.num_prf, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, m), u.dtype),
        interpret=st.interpret,
    )(u, anchors, omegas)


def _bwd_kernel(u_ref, a_ref, w_ref, dpsi_ref, du_ref, da_ref, dw_ref, *,
                feat: FeatureStatics):
    """Recompute the Ψ intermediates for this block and backprop dΨ.

    Emits du (T, d) plus per-block dA (P, d) / dΩ (D, d) partials (reduced
    over blocks by the wrapper — keeps every grid step independent)."""
    a = a_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    _, res = features_fwd(u_ref[...].astype(jnp.float32), a, w, feat)
    dpsi = dpsi_ref[...].astype(jnp.float32)
    du, da, dw = features_bwd(dpsi, res, a, w, feat)
    du_ref[...] = du.astype(du_ref.dtype)
    da_ref[0] = da
    dw_ref[0] = dw


def _bwd_impl(st: _MapStatics, u, anchors, omegas, dpsi):
    n, d = u.shape
    f = st.feat
    m = len(f.s_nodes) * f.num_anchors * f.num_prf
    block = st.block_tokens
    P, D = f.num_anchors, f.num_prf
    nb = n // block
    du, da_p, dw_p = pl.pallas_call(
        functools.partial(_bwd_kernel, feat=f),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            pl.BlockSpec((P, d), lambda i: (0, 0)),
            pl.BlockSpec((D, d), lambda i: (0, 0)),
            pl.BlockSpec((block, m), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            pl.BlockSpec((1, P, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, D, d), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), u.dtype),
            jax.ShapeDtypeStruct((nb, P, d), jnp.float32),
            jax.ShapeDtypeStruct((nb, D, d), jnp.float32),
        ],
        interpret=st.interpret,
    )(u, anchors, omegas, dpsi)
    da = jnp.sum(da_p, axis=0).astype(anchors.dtype)
    dw = jnp.sum(dw_p, axis=0).astype(omegas.dtype)
    return du, da, dw


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _fmap(st: _MapStatics, u, anchors, omegas):
    return _fwd_impl(st, u, anchors, omegas)


def _fmap_fwd(st: _MapStatics, u, anchors, omegas):
    psi = _fwd_impl(st, u, anchors, omegas)
    return psi, (u, anchors, omegas)


def _fmap_bwd(st: _MapStatics, res, dpsi):
    u, anchors, omegas = res
    return _bwd_impl(st, u, anchors, omegas, dpsi)


_fmap.defvjp(_fmap_fwd, _fmap_bwd)
