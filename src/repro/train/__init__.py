"""Training runtime: jit'd step builder + fault-tolerant loop."""
from repro.train.loop import TrainConfig, Trainer, make_train_step  # noqa
