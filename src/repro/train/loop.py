"""Training step + fault-tolerant loop.

The step is a single pjit'd program: microbatched grad accumulation
(lax.scan), optional remat (nothing_saveable over the layer scan), optional
error-feedback int8 gradient compression, global-norm clip, AdamW. Sharding
comes from the logical-axes rules (repro.distributed) — the same step runs
on 1 CPU device or a 512-chip multi-pod mesh unchanged.

Fault tolerance in the loop:
* checkpoint cadence (atomic; resume-latest on start),
* a step-time watchdog for straggler/step-time anomalies — at real scale a
  consistently slow step indicates a degraded host; the loop flags it and
  tightens checkpoint cadence (preemption-safe posture),
* elastic restart: checkpoints are mesh-agnostic (see repro.checkpoint).
"""
from __future__ import annotations

import dataclasses
import logging
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import restore_latest, save_checkpoint
from repro.configs.base import ArchConfig
from repro.distributed import sharding as shd
from repro.models import api
from repro.optim import compress as gcomp
from repro.optim.adamw import AdamWConfig, AdamWState, adamw_init, adamw_update

log = logging.getLogger("repro.train")


def resolve_attention_path(cfg: ArchConfig,
                           train_cfg: "TrainConfig") -> ArchConfig:
    """Apply the TrainConfig attention-kernel overrides to the arch config."""
    updates = {}
    if train_cfg.use_pallas is not None:
        updates["use_pallas"] = train_cfg.use_pallas
    if train_cfg.fuse_attention_features is not None:
        updates["fuse_attention_features"] = train_cfg.fuse_attention_features
    return dataclasses.replace(cfg, **updates) if updates else cfg


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1            # grad-accumulation steps
    remat: bool = True
    # Attention-kernel override for the training step. None = respect
    # cfg.use_pallas; True/False force the Pallas / jnp attention path.
    # The Pallas kernels carry custom VJPs (DESIGN.md §3), so use_pallas
    # training steps differentiate end to end — no inference-only fallback.
    use_pallas: bool | None = None
    fuse_attention_features: bool | None = None
    # "nothing" = nothing_saveable; "save_collectives" saves the named
    # post-all-reduce tensors (attn_out/mlp_out) so the backward recompute
    # skips re-running the forward TP collectives (§Perf).
    remat_policy: str = "nothing"
    compress_grads: bool = False     # error-feedback int8 (DP payload /4)
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 200
    watchdog_factor: float = 2.0     # step slower than factor x median -> flag
    keep_ckpts: int = 3


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig,
                    train_cfg: TrainConfig):
    """Returns train_step(params, opt_state, ef_state, batch) -> (...)"""

    cfg = resolve_attention_path(cfg, train_cfg)
    remat_arg = (train_cfg.remat_policy
                 if (train_cfg.remat and train_cfg.remat_policy != "nothing")
                 else train_cfg.remat)

    def compute_grads(params, batch):
        if train_cfg.microbatches <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                api.loss_fn, has_aux=True)(params, cfg, batch,
                                           remat=remat_arg)
            return loss, metrics, grads
        n = train_cfg.microbatches
        mb = jax.tree.map(
            lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]), batch)

        def acc_step(carry, micro):
            loss_a, grads_a = carry
            (loss, _), grads = jax.value_and_grad(
                api.loss_fn, has_aux=True)(params, cfg, micro,
                                           remat=remat_arg)
            grads_a = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / n, grads_a, grads)
            return (loss_a + loss / n, grads_a), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(acc_step, (jnp.zeros(()), zeros), mb)
        return loss, {"nll": loss, "moe_aux": jnp.zeros(())}, grads

    def train_step(params, opt_state: AdamWState, ef_state, batch):
        loss, metrics, grads = compute_grads(params, batch)
        if train_cfg.compress_grads:
            grads, ef_state = gcomp.compress_decompress(grads, ef_state)
        params, opt_state, opt_metrics = adamw_update(
            grads, opt_state, params, opt_cfg)
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return params, opt_state, ef_state, metrics

    return train_step


def jit_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig,
                   train_cfg: TrainConfig, mesh,
                   rules: shd.ShardingRules = shd.DEFAULT_RULES):
    """pjit the step with rule-derived in/out shardings + donation."""
    base_step = make_train_step(cfg, opt_cfg, train_cfg)

    def step(*args):
        # Install the activation-constraint context during tracing so
        # with_sharding_constraint picks up (mesh, rules).
        with shd.activation_sharding(mesh, rules):
            return base_step(*args)

    axes = api.param_axes(cfg)
    p_abs = api.abstract_params(cfg)
    p_sh = shd.logical_to_sharding(mesh, rules, p_abs, axes)
    opt_abs = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), p_abs)
    m_sh = shd.logical_to_sharding(mesh, rules, opt_abs.m, axes)
    v_sh = shd.logical_to_sharding(mesh, rules, opt_abs.v, axes)
    from jax.sharding import NamedSharding, PartitionSpec
    scalar_sh = NamedSharding(mesh, PartitionSpec())
    opt_sh = AdamWState(scalar_sh, m_sh, v_sh)
    ef_sh = (shd.logical_to_sharding(mesh, rules, p_abs, axes)
             if train_cfg.compress_grads else scalar_sh)
    b_sh = shd.batch_sharding(mesh, rules)
    metric_sh = {k: scalar_sh for k in
                 ("nll", "moe_aux", "grad_norm", "lr", "loss")}
    return jax.jit(
        step,
        in_shardings=(p_sh, opt_sh, ef_sh, b_sh),
        out_shardings=(p_sh, opt_sh, ef_sh, metric_sh),
        donate_argnums=(0, 1, 2),
    )


class Trainer:
    """Fault-tolerant loop around the jit'd step."""

    def __init__(self, cfg: ArchConfig, opt_cfg: AdamWConfig,
                 train_cfg: TrainConfig, mesh,
                 rules: shd.ShardingRules = shd.DEFAULT_RULES, *, seed=0):
        self.cfg, self.opt_cfg, self.train_cfg = cfg, opt_cfg, train_cfg
        self.mesh, self.rules = mesh, rules
        self.step_fn = jit_train_step(cfg, opt_cfg, train_cfg, mesh, rules)
        key = jax.random.PRNGKey(seed)
        axes = api.param_axes(cfg)
        with mesh:
            self.params = shd.shard_params(
                mesh, rules, api.init_params(cfg, key), axes)
            self.opt_state = adamw_init(self.params, opt_cfg)
            self.ef_state = (gcomp.init(self.params)
                             if train_cfg.compress_grads
                             else jnp.zeros(()))
        self.step = 0
        self._times: list[float] = []
        self._resume()

    def _resume(self):
        state = {"params": self.params, "opt": self.opt_state}
        restored, step = restore_latest(self.train_cfg.ckpt_dir, state)
        if restored is not None:
            self.params = restored["params"]
            self.opt_state = restored["opt"]
            self.step = step
            log.info("resumed from step %d", step)

    def save(self):
        save_checkpoint(self.train_cfg.ckpt_dir, self.step,
                        {"params": self.params, "opt": self.opt_state},
                        keep=self.train_cfg.keep_ckpts)

    def run(self, batches, num_steps: int, *, log_every: int = 10):
        """batches: iterator of (step, batch). Returns metric history."""
        history = []
        ckpt_every = self.train_cfg.ckpt_every
        with self.mesh:
            for step, batch in batches:
                if step >= num_steps:
                    break
                t0 = time.monotonic()
                (self.params, self.opt_state, self.ef_state,
                 metrics) = self.step_fn(self.params, self.opt_state,
                                         self.ef_state, batch)
                metrics = jax.device_get(metrics)
                dt = time.monotonic() - t0
                self._times.append(dt)
                self.step = step + 1
                # Straggler / anomaly watchdog: tighten checkpoint cadence.
                med = sorted(self._times)[len(self._times) // 2]
                if (len(self._times) > 5
                        and dt > self.train_cfg.watchdog_factor * med):
                    log.warning("step %d took %.2fs (median %.2fs) — "
                                "tightening checkpoint cadence", step, dt, med)
                    ckpt_every = max(ckpt_every // 2, 10)
                if self.step % ckpt_every == 0:
                    self.save()
                history.append({"step": self.step, **metrics,
                                "step_time_s": dt})
                if step % log_every == 0:
                    log.info("step %d loss %.4f (%.2fs)", step,
                             float(metrics["loss"]), dt)
        self.save()
        return history
