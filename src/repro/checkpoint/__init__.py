"""Fault-tolerant checkpointing."""
from repro.checkpoint.store import (save_checkpoint, restore_checkpoint,
                                    latest_step, restore_latest)  # noqa
