"""Atomic, mesh-agnostic checkpoints: msgpack + zstd.

Fault-tolerance contract (DESIGN.md §5):

* **Atomic** — write to ``<dir>/tmp.<step>`` then ``os.replace`` to
  ``step_<n>.ckpt``; a preemption mid-write never corrupts the latest
  checkpoint (restore scans for complete files only).
* **Mesh-agnostic / elastic** — arrays are stored as (dtype, shape, bytes)
  logical tensors with no sharding metadata; on restore the caller
  device_puts onto whatever mesh/sharding the *new* job uses, so a 512-chip
  run can resume on 256 chips (elastic rescale) or vice versa.
* **Resume-exact** — the data pipeline is step-indexed (repro.data), so
  (params, opt_state, step) is the complete job state.
"""
from __future__ import annotations

import os
import re

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

import zlib

try:  # zstd preferred; fall back to stdlib zlib where the wheel is absent.
    import zstandard
except ImportError:  # pragma: no cover - environment dependent
    zstandard = None

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def _compress(payload: bytes) -> bytes:
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=3).compress(payload)
    return zlib.compress(payload, 3)


def _decompress(blob: bytes) -> bytes:
    # Sniff the frame magic so checkpoints stay readable across
    # environments that differ in zstandard availability.
    if blob[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise RuntimeError(
                "checkpoint is zstd-compressed but the zstandard module "
                "is not installed in this environment")
        return zstandard.ZstdDecompressor().decompress(blob)
    return zlib.decompress(blob)


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(jax.device_get(leaf))
        flat[key] = {"dtype": str(arr.dtype), "shape": list(arr.shape),
                     "data": arr.tobytes()}
    return flat


def save_checkpoint(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    payload = msgpack.packb({"step": step, "arrays": _flatten(tree)})
    blob = _compress(payload)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}.ckpt")
    with open(tmp, "wb") as f:
        f.write(blob)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)           # atomic on POSIX
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    ckpts = sorted(f for f in os.listdir(ckpt_dir) if f.endswith(".ckpt"))
    for f in ckpts[:-keep]:
        os.remove(os.path.join(ckpt_dir, f))


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.match(r"step_(\d+)\.ckpt$", f))]
    return max(steps) if steps else None


def restore_checkpoint(path: str, tree_like, *, shardings=None):
    """Restore into the structure of `tree_like`; optional target shardings
    (pytree of NamedSharding) for elastic resume onto a new mesh."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(_decompress(f.read()))
    arrays = payload["arrays"]
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    shard_leaves = (jax.tree.leaves(shardings)
                    if shardings is not None else [None] * len(leaves_p))
    out = []
    for (path_k, leaf), sh in zip(leaves_p, shard_leaves):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_k)
        rec = arrays[key]
        arr = np.frombuffer(rec["data"], dtype=rec["dtype"]).reshape(
            rec["shape"])
        if list(arr.shape) != list(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        out.append(jax.device_put(jnp.asarray(arr), sh) if sh is not None
                   else jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), payload["step"]


def restore_latest(ckpt_dir: str, tree_like, *, shardings=None):
    step = latest_step(ckpt_dir)
    if step is None:
        return None, None
    path = os.path.join(ckpt_dir, f"step_{step:08d}.ckpt")
    return restore_checkpoint(path, tree_like, shardings=shardings)
