import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (arch x shape x mesh) cell lowers,
compiles, and fits — and extract the roofline terms from the compiled
artifact. No arrays are ever allocated: parameters, optimizer state, decode
caches and batches are all ShapeDtypeStruct stand-ins.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b \
        --shape train_4k [--multi-pod] [--attn-kind softmax] \
        [--out results.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all --out grid.json

Exit code != 0 on any failed cell (sharding mismatch, OOM at compile,
unsupported collective) — those are bugs in the system, per the assignment.
"""  # noqa: E402
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import ArchConfig, ShapeCell
from repro.distributed import sharding as shd
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.models import api
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train.loop import TrainConfig, make_train_step


def _struct_batch(cfg: ArchConfig, cell: ShapeCell) -> dict:
    return configs.input_specs(cfg, cell)


def _cell_is_skipped(cfg: ArchConfig, cell: ShapeCell) -> str | None:
    """Assignment skip rules. With SLAY as the default backend no cell is
    skipped (long_500k is exactly what SLAY enables); pure full-attention
    variants skip long_500k."""
    if cell.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        spec_linear = cfg.attn_kind in ("slay", "favor", "cosformer", "elu1")
        if not spec_linear:
            return ("long_500k needs sub-quadratic attention; "
                    f"attn_kind={cfg.attn_kind} is full-attention "
                    "(run with the SLAY backend instead)")
    return None


def default_microbatches(cfg: ArchConfig, cell: ShapeCell, mesh) -> int:
    """Grad-accumulation factor so one microbatch is ~4k tokens per
    data-parallel shard — keeps activation residency << HBM without
    starving the MXU. Must divide the per-shard batch."""
    data_par = 1
    for ax in ("pod", "data"):
        data_par *= mesh.shape.get(ax, 1)
    per_shard_seqs = max(cell.global_batch // data_par, 1)
    tokens_per_shard = per_shard_seqs * cell.seq_len
    want = max(1, tokens_per_shard // 4096)
    mb = min(want, per_shard_seqs)
    while per_shard_seqs % mb:
        mb -= 1
    return max(mb, 1)


def lower_cell(cfg: ArchConfig, cell: ShapeCell, mesh,
               rules: shd.ShardingRules = shd.DEFAULT_RULES, *,
               train_cfg: TrainConfig | None = None,
               opt_cfg: AdamWConfig | None = None):
    """Build + lower the cell's step function. Returns `lowered`."""
    axes = api.param_axes(cfg)
    p_abs = api.abstract_params(cfg)
    fallback_log: list = []
    p_sh = shd.logical_to_sharding(mesh, rules, p_abs, axes, fallback_log)
    b_specs = _struct_batch(cfg, cell)
    b_sh = shd.batch_sharding(mesh, rules, batch_size=cell.global_batch)
    b_shard = {k: b_sh for k in b_specs}

    if cell.mode == "train":
        train_cfg = train_cfg or TrainConfig(
            microbatches=default_microbatches(cfg, cell, mesh), remat=True,
            compress_grads=False)
        opt_cfg = opt_cfg or AdamWConfig(
            moment_dtype="bfloat16"
            if cfg.param_count_dense > 1e11 else "float32")
        step = make_train_step(cfg, opt_cfg, train_cfg)
        opt_abs = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), p_abs)
        m_sh = shd.logical_to_sharding(mesh, rules, opt_abs.m, axes)
        v_sh = shd.logical_to_sharding(mesh, rules, opt_abs.v, axes)
        from jax.sharding import NamedSharding, PartitionSpec
        sc = NamedSharding(mesh, PartitionSpec())
        opt_sh = type(opt_abs)(sc, m_sh, v_sh)
        if train_cfg.compress_grads:
            from repro.optim import compress as gcomp
            ef_abs = jax.eval_shape(gcomp.init, p_abs)
            ef_sh = shd.logical_to_sharding(mesh, rules, ef_abs, axes)
        else:
            ef_abs = jax.ShapeDtypeStruct((), jnp.float32)
            ef_sh = sc
        fn = jax.jit(step, in_shardings=(p_sh, opt_sh, ef_sh, b_shard),
                     out_shardings=(p_sh, opt_sh, ef_sh, None),
                     donate_argnums=(0, 1))
        with mesh, shd.activation_sharding(mesh, rules):
            lowered = fn.lower(p_abs, opt_abs, ef_abs, b_specs)
    elif cell.mode == "prefill":
        fn = jax.jit(lambda p, b: api.prefill(p, cfg, b),
                     in_shardings=(p_sh, b_shard))
        with mesh, shd.activation_sharding(mesh, rules):
            lowered = fn.lower(p_abs, b_specs)
    else:  # decode
        c_abs = api.abstract_cache(cfg, cell.global_batch, cell.seq_len)
        c_sh = shd.cache_sharding(mesh, rules, c_abs)
        fn = jax.jit(lambda p, c, t: api.decode_step(p, cfg, c, t),
                     in_shardings=(p_sh, c_sh, b_shard["tokens"]),
                     out_shardings=(b_shard["tokens"], c_sh),
                     donate_argnums=(1,))
        with mesh, shd.activation_sharding(mesh, rules):
            lowered = fn.lower(p_abs, c_abs, b_specs["tokens"])
    return lowered, fallback_log


def run_cell(arch: str, shape: str, *, multi_pod: bool = False,
             attn_kind: str | None = None,
             rules: shd.ShardingRules = shd.DEFAULT_RULES,
             train_cfg: TrainConfig | None = None,
             opt_cfg: AdamWConfig | None = None,
             mesh_shape: tuple[int, ...] | None = None,
             verbose: bool = True, **cfg_overrides) -> dict:
    cell = configs.get_cell(shape)
    overrides = dict(cfg_overrides)
    if attn_kind:
        overrides["attn_kind"] = attn_kind
    cfg = configs.get_config(arch, **overrides) if overrides \
        else configs.get_config(arch)
    record: dict = {"arch": arch, "shape": shape,
                    "mesh": ("x".join(map(str, mesh_shape)) if mesh_shape
                             else ("2x16x16" if multi_pod else "16x16")),
                    "attn_kind": cfg.attn_kind, "mode": cell.mode}
    skip = _cell_is_skipped(cfg, cell)
    if skip:
        record["status"] = "skipped"
        record["reason"] = skip
        return record
    if mesh_shape is not None:
        # Same 256-chip pod (or 512-chip 2-pod), different logical split —
        # e.g. (32, 8) so a 24-head/8-kv arch shards instead of replicating.
        axes = ("pod", "data", "model")[-len(mesh_shape):]
        mesh = jax.make_mesh(mesh_shape, axes)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.monotonic()
    try:
        lowered, fallbacks = lower_cell(cfg, cell, mesh, rules,
                                        train_cfg=train_cfg, opt_cfg=opt_cfg)
        compiled = lowered.compile()
    except Exception as e:  # noqa: BLE001 — report per-cell failures
        record["status"] = "FAILED"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc(limit=8)
        return record
    t_compile = time.monotonic() - t0

    mem = compiled.memory_analysis()
    totals = rl.hlo_cost.analyze(compiled.as_text())
    roof = rl.Roofline(
        flops=totals.flops, hbm_bytes=totals.hbm_bytes,
        coll_bytes=totals.coll_wire_bytes, chips=chips,
        model_flops=rl.model_flops_for(cfg, cell),
        coll_by_kind=totals.coll_by_kind)
    top_dots = sorted(totals.dot_flops_by_meta.items(),
                      key=lambda kv: -kv[1])[:10]
    record.update({
        "status": "ok",
        "compile_s": round(t_compile, 1),
        "bytes_per_device": {
            "argument": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp": int(getattr(mem, "temp_size_in_bytes", 0)),
            "peak": int(getattr(mem, "temp_size_in_bytes", 0))
            + int(getattr(mem, "argument_size_in_bytes", 0)),
        },
        "collectives": roof.coll_by_kind,
        "sharding_fallbacks": [f"{log}:{dim}!%{ax}" for log, dim, ax
                               in (fallbacks or [])][:20],
        "roofline": roof.report(),
        "top_dot_flops": [{"op": k, "flops": v} for k, v in top_dots],
    })
    if verbose:
        bpd = record["bytes_per_device"]
        print(f"[{record['mesh']}] {arch} x {shape}: OK "
              f"compile={t_compile:.0f}s "
              f"args={bpd['argument'] / 2**30:.2f}GiB "
              f"temp={bpd['temp'] / 2**30:.2f}GiB "
              f"dom={roof.dominant} "
              f"t=({roof.t_compute:.2e},{roof.t_memory:.2e},"
              f"{roof.t_collective:.2e})s "
              f"roofline_frac={roof.roofline_fraction:.2f}")
    return record


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    choices=list(configs.ALL_ARCHS) + [None])
    ap.add_argument("--shape", default=None,
                    choices=[c.name for c in configs.SHAPE_CELLS] + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="full assigned grid (10 archs x 4 shapes)")
    ap.add_argument("--attn-kind", default=None)
    ap.add_argument("--out", default=None, help="write JSON records here")
    args = ap.parse_args(argv)

    cells: list[tuple[str, str, bool]] = []
    archs = [args.arch] if args.arch else list(configs.ASSIGNED_ARCHS)
    shapes = [args.shape] if args.shape else \
        [c.name for c in configs.SHAPE_CELLS]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    records = []
    failed = 0
    for a, s, mp in cells:
        rec = run_cell(a, s, multi_pod=mp, attn_kind=args.attn_kind)
        records.append(rec)
        if rec["status"] == "FAILED":
            failed += 1
            print(f"[{'2x16x16' if mp else '16x16'}] {a} x {s}: FAILED — "
                  f"{rec['error']}", file=sys.stderr)
        elif rec["status"] == "skipped":
            print(f"[{'2x16x16' if mp else '16x16'}] {a} x {s}: skipped — "
                  f"{rec['reason']}")
        if args.out:
            with open(args.out, "w") as f:
                json.dump(records, f, indent=1)
    print(f"\n{len(records) - failed}/{len(records)} cells passed")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
