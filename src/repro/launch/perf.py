"""§Perf hillclimb driver: re-lower a cell under a named experiment
configuration and report the roofline delta vs baseline.

    PYTHONPATH=src python -m repro.launch.perf --cell qwen3-32b:train_4k \
        --exp mb4,seqpar,grad_bf16

Each experiment is a (rules, train_cfg, cfg_overrides) transform; the
driver prints the three terms + dominant + roofline fraction so the
hypothesis → change → measure loop in EXPERIMENTS.md §Perf is mechanical
and reproducible.
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402

from repro import configs                          # noqa: E402
from repro.distributed import sharding as shd      # noqa: E402
from repro.launch import dryrun                    # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.train.loop import TrainConfig           # noqa: E402
from repro.optim.adamw import AdamWConfig          # noqa: E402,F401


def _mb(n):
    def t(rules, tc, ov):
        return rules, dataclasses.replace(tc, microbatches=n), ov
    return t


def _seqpar(rules, tc, ov):
    """Sequence parallelism: residual stream seq dim sharded over model
    between layers (Megatron-SP: the per-layer AR becomes RS+AG and the
    norm/elementwise work is 1/model-size per device)."""
    return dataclasses.replace(rules, act_seq="model"), tc, ov


def _grad_compress(rules, tc, ov):
    return rules, dataclasses.replace(tc, compress_grads=True), ov


def _no_remat(rules, tc, ov):
    return rules, dataclasses.replace(tc, remat=False), ov


def _save_coll(rules, tc, ov):
    return rules, dataclasses.replace(tc, remat_policy="save_collectives"), ov


def _serving_rules(rules, tc, ov):
    """Decode-optimized: weights sharded over model only (no per-step FSDP
    all-gather over data); batch over (pod,data)."""
    return dataclasses.replace(rules, embed=None, act_embed=None), tc, ov


def _fsdp_pod(rules, tc, ov):
    """Multi-pod ZeRO: shard params/opt over the pod axis as well (512-way
    total) — halves per-device param+optimizer bytes at the cost of
    inter-pod weight all-gathers."""
    return dataclasses.replace(rules, embed=("pod", "data")), tc, ov


def _fsdp_model_too(rules, tc, ov):
    """FSDP over BOTH axes: embed -> (data, model) — params 256-way sharded;
    weight all-gathers grow but optimizer/memory shrink."""
    return dataclasses.replace(rules, embed=("data", "model"), heads=None,
                               mlp=None, vocab=None,
                               act_heads=None, act_mlp=None), tc, ov


def _chunk(n):
    def t(rules, tc, ov):
        ov = dict(ov)
        ov["chunk_size"] = n
        return rules, tc, ov
    return t


def _anchors(p, d):
    def t(rules, tc, ov):
        ov = dict(ov)
        ov.update(slay_anchors=p, slay_prf=d)
        return rules, tc, ov
    return t


def _mesh(*shape):
    def t(rules, tc, ov):
        ov = dict(ov)
        ov["__mesh_shape__"] = shape
        return rules, tc, ov
    return t


EXPERIMENTS = {
    "baseline": lambda rules, tc, ov: (rules, tc, ov),
    "mb1": _mb(1), "mb2": _mb(2), "mb4": _mb(4), "mb8": _mb(8),
    "seqpar": _seqpar,
    "gradcomp": _grad_compress,
    "no_remat": _no_remat,
    "save_coll": _save_coll,
    "serving_rules": _serving_rules,
    "fsdp2d": _fsdp_model_too,
    "fsdp_pod": _fsdp_pod,
    "chunk128": _chunk(128), "chunk512": _chunk(512),
    "slay_p4d8": _anchors(4, 8), "slay_p16d32": _anchors(16, 32),
    # Logical mesh re-splits of the same 256-chip pod (heads-divisibility).
    "mesh32x8": _mesh(32, 8), "mesh64x4": _mesh(64, 4),
    "mesh8x32": _mesh(8, 32), "mesh128x2": _mesh(128, 2),
    "mesh256x1": _mesh(256, 1),
}


def run_experiment(arch: str, shape: str, names: list[str], *,
                   multi_pod: bool = False) -> dict:
    rules = shd.DEFAULT_RULES
    cell = configs.get_cell(shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    tc = TrainConfig(
        microbatches=dryrun.default_microbatches(
            configs.get_config(arch), cell, mesh),
        remat=True, compress_grads=False)
    ov: dict = {}
    for n in names:
        rules, tc, ov = EXPERIMENTS[n](rules, tc, ov)
    mesh_shape = ov.pop("__mesh_shape__", None)
    rec = dryrun.run_cell(arch, shape, multi_pod=multi_pod, rules=rules,
                          train_cfg=tc, mesh_shape=mesh_shape, verbose=True,
                          **ov)
    rec["experiments"] = names
    rec["microbatches"] = tc.microbatches
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--exp", default="baseline",
                    help="comma-separated experiment names, applied in "
                         f"order; known: {sorted(EXPERIMENTS)}")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    arch, shape = args.cell.split(":")
    rec = run_experiment(arch, shape, args.exp.split(","),
                         multi_pod=args.multi_pod)
    if args.out:
        existing = []
        if os.path.exists(args.out):
            with open(args.out) as f:
                existing = json.load(f)
        existing.append(rec)
        with open(args.out, "w") as f:
            json.dump(existing, f, indent=1)
    if rec["status"] != "ok":
        print(rec.get("error"))
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
