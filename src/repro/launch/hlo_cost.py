"""HLO-text cost model: FLOPs / HBM bytes / collective wire-bytes.

``compiled.cost_analysis()`` is unusable for scanned programs: XLA counts a
while-loop body ONCE, so a 64-layer ``lax.scan`` transformer under-reports
FLOPs by ~64x. This module parses ``compiled.as_text()`` (the per-device
SPMD program) and computes:

* **flops** — 2*M*N*K per ``dot`` (batch dims included via the output
  product), convolutions likewise, each scaled by the product of enclosing
  while-loop trip counts. Elementwise FLOPs are excluded (they are
  bandwidth-, not compute-, bound and are captured by the bytes term).
* **hbm_bytes** — traffic of the *heavy* ops only: dot/convolution
  (operands + output), collectives (in + out), reduce, gather /
  dynamic-slice (output side), scatter / dynamic-update-slice (update
  slice, read+write). Pure elementwise chains, copies, transposes and
  converts are EXCLUDED: on TPU XLA fuses them into the neighboring
  matmuls, so counting them at the CPU backend's (much finer) fusion
  granularity would overestimate HBM traffic by ~10x. The resulting number
  approximates the weight/activation streaming a real TPU program does and
  errs slightly low (an unfused elementwise epilogue would add traffic).
* **collective wire bytes** — per-chip bytes actually moved on the ICI for
  each collective, using the standard ring-algorithm factors:

      all-gather        (G-1)/G * out_bytes
      reduce-scatter    (G-1)/G * in_bytes
      all-reduce        2*(G-1)/G * in_bytes   (RS + AG)
      all-to-all        (G-1)/G * in_bytes
      collective-permute       in_bytes

  with G the replica-group size parsed from ``replica_groups``.

While-loop trip counts come from the loop condition computation (the
``compare(iv, constant(N), LT)`` pattern jax emits for ``lax.scan`` /
``fori_loop``). ``conditional`` ops (from ``lax.cond``) take the *max* over
branches (conservative). All quantities are per device: the HLO module is
the partitioned per-device program.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(
    r"(pred|f8e4m3fn|f8e5m2|f8e4m3b11fnuz|bf16|f16|f32|f64|u4|u8|u16|u32|u64"
    r"|s4|s8|s16|s32|s64|c64|c128|token)\[([0-9,]*)\]")

# "  %name = TYPE opcode(args), attrs" (ROOT optional). opcode is the token
# immediately before the first '('.
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s*([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_EXPLICIT_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TF_RE = re.compile(r"(?:true|false)_computation=%?([\w.\-]+)")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute", "ragged-all-to-all")

# Ops that do not materialize / move data at the fusion boundary.
_FREE_OPS = frozenset({
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "add-dependency", "partition-id", "replica-id", "domain",
    "opt-barrier", "optimization-barrier",
})


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[int]:
    """Dims of the FIRST array shape in the type string."""
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    args: str            # raw text inside the outer parens (up to ')')
    attrs: str           # raw text after the closing paren


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    shapes: dict         # op name -> type string


def parse_module(hlo_text: str) -> dict:
    """Parse HLO text into {computation name: Computation}."""
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line)
        if m and "=" not in line.split("(")[0]:
            cur = Computation(m.group(1), [], {})
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        om = _OP_RE.match(line)
        if not om:
            continue
        name, type_str, opcode, rest = om.groups()
        # Split args from attrs at the matching close paren (dims/attrs
        # contain no parens except nested calls like constant(3) — those
        # only appear in attrs, so the first unbalanced ')' is the end).
        depth = 1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        args, attrs = rest[:i], rest[i + 1:]
        cur.ops.append(Op(name, type_str, opcode, args, attrs))
        cur.shapes[name] = type_str
    return comps


def _trip_count(cond: Computation) -> int:
    """Max integer constant in the loop condition — jax emits
    compare(iv, constant(N), LT) for scan/fori."""
    best = 1
    for op in cond.ops:
        if op.opcode == "constant" and op.args.strip().isdigit():
            best = max(best, int(op.args.strip()))
        for m in _CONST_RE.finditer(op.args):
            best = max(best, int(m.group(1)))
    return best


def _group_size(attrs: str, default: int = 1) -> int:
    m = _IOTA_GROUPS_RE.search(attrs)
    if m:
        return int(m.group(2))
    m = _EXPLICIT_GROUPS_RE.search(attrs)
    if m:
        return len([x for x in m.group(1).split(",") if x])
    return default


def _operand_bytes(op: Op, shapes: dict) -> int:
    total = 0
    for m in _OPERAND_RE.finditer(op.args):
        t = shapes.get(m.group(1))
        if t is not None:
            total += _shape_bytes(t)
    return total


def _dot_flops(op: Op, shapes: dict) -> float:
    """2 * (output elements) * (contraction size)."""
    out_dims = _shape_dims(op.type_str)
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    lhs_name_m = _OPERAND_RE.search(op.args)
    k = 1
    if lhs_name_m:
        lhs_t = shapes.get(lhs_name_m.group(1), "")
        lhs_dims = _shape_dims(lhs_t)
        cm = _LHS_CDIMS_RE.search(op.attrs)
        if cm and lhs_dims:
            for ci in cm.group(1).split(","):
                if ci:
                    ci = int(ci)
                    if ci < len(lhs_dims):
                        k *= lhs_dims[ci]
    return 2.0 * out_elems * k


def _conv_flops(op: Op, shapes: dict) -> float:
    out_dims = _shape_dims(op.type_str)
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    ops = _OPERAND_RE.findall(op.args)
    k = 1
    if len(ops) >= 2:
        rhs_dims = _shape_dims(shapes.get(ops[1], ""))
        for d in rhs_dims[:-1]:   # kernel spatial+input-feature dims
            k *= d
    return 2.0 * out_elems * k


@dataclasses.dataclass
class CostTotals:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_wire_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(default_factory=dict)
    dot_flops_by_meta: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "CostTotals", scale: float = 1.0):
        self.flops += other.flops * scale
        self.hbm_bytes += other.hbm_bytes * scale
        self.coll_wire_bytes += other.coll_wire_bytes * scale
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v * scale
        for k, v in other.dot_flops_by_meta.items():
            self.dot_flops_by_meta[k] = (
                self.dot_flops_by_meta.get(k, 0.0) + v * scale)


_META_RE = re.compile(r'op_name="([^"]*)"')


def _meta_tag(attrs: str) -> str:
    m = _META_RE.search(attrs)
    if not m:
        return "?"
    # Strip jit wrapper + trailing indices for a stable grouping key.
    tag = m.group(1)
    tag = re.sub(r"\[[^\]]*\]", "", tag)
    return tag[:120]


class HloCostModel:
    """Whole-module cost with while-loop trip-count scaling."""

    def __init__(self, hlo_text: str):
        self.comps = parse_module(hlo_text)
        self._memo: dict[str, CostTotals] = {}
        entry = None
        # The ENTRY computation: jax names it main.NN / main_spmd etc.
        for name in self.comps:
            if name.startswith("main"):
                entry = name
        if entry is None and self.comps:
            entry = list(self.comps)[-1]
        self.entry = entry

    def totals(self) -> CostTotals:
        if self.entry is None:
            return CostTotals()
        return self._comp_cost(self.entry)

    def _comp_cost(self, name: str) -> CostTotals:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        total = CostTotals()
        self._memo[name] = total      # breaks cycles defensively
        if comp is None:
            return total
        shapes = comp.shapes
        for op in comp.ops:
            oc = op.opcode
            if oc in _FREE_OPS:
                continue
            if oc == "while":
                cond = _COND_RE.search(op.attrs)
                body = _BODY_RE.search(op.attrs)
                trips = 1
                if cond and cond.group(1) in self.comps:
                    trips = _trip_count(self.comps[cond.group(1)])
                if body:
                    total.add(self._comp_cost(body.group(1)), float(trips))
                continue
            if oc == "conditional":
                branches = []
                bm = _BRANCHES_RE.search(op.attrs)
                if bm:
                    branches = _OPERAND_RE.findall(bm.group(1)) or [
                        b.strip().lstrip("%")
                        for b in bm.group(1).split(",") if b.strip()]
                else:
                    branches = _TF_RE.findall(op.attrs)
                if branches:
                    costs = [self._comp_cost(b) for b in branches]
                    best = max(costs, key=lambda c: (c.flops, c.hbm_bytes))
                    total.add(best)
                continue
            if oc in ("call", "async-start"):
                cm = _CALLS_RE.search(op.attrs)
                if cm:
                    total.add(self._comp_cost(cm.group(1)))
                continue

            out_b = _shape_bytes(op.type_str)
            in_b = _operand_bytes(op, shapes)

            base = oc[:-6] if oc.endswith("-start") else oc
            if base.endswith("-done"):
                continue  # async pair counted at -start
            if base in COLLECTIVE_OPS:
                g = _group_size(op.attrs, default=1)
                frac = (g - 1) / g if g > 1 else 0.0
                if base == "all-gather":
                    wire = frac * out_b
                elif base == "all-reduce":
                    wire = 2.0 * frac * in_b
                elif base == "reduce-scatter":
                    wire = frac * in_b
                elif base in ("all-to-all", "ragged-all-to-all"):
                    wire = frac * in_b
                else:  # collective-permute
                    wire = float(in_b)
                total.coll_wire_bytes += wire
                total.coll_by_kind[base] = (
                    total.coll_by_kind.get(base, 0.0) + wire)
                total.hbm_bytes += in_b + out_b
                continue

            if base == "fusion":
                cm = _CALLS_RE.search(op.attrs)
                if cm:
                    # Dots/heavy ops nested inside the fusion still count
                    # (flops AND their bytes); elementwise-only fusions are
                    # treated as free (fused into neighbors on TPU).
                    inner = self._comp_cost(cm.group(1))
                    total.flops += inner.flops
                    total.hbm_bytes += inner.hbm_bytes
                    for k, v in inner.dot_flops_by_meta.items():
                        total.dot_flops_by_meta[k] = (
                            total.dot_flops_by_meta.get(k, 0.0) + v)
                continue

            if base == "dot":
                f = _dot_flops(op, shapes)
                total.flops += f
                tag = _meta_tag(op.attrs)
                total.dot_flops_by_meta[tag] = (
                    total.dot_flops_by_meta.get(tag, 0.0) + f)
                total.hbm_bytes += in_b + out_b
                continue
            if base == "convolution":
                total.flops += _conv_flops(op, shapes)
                total.hbm_bytes += in_b + out_b
                continue
            if base in ("reduce", "reduce-window", "sort"):
                total.hbm_bytes += in_b + out_b
                continue
            if base in ("gather", "dynamic-slice", "slice"):
                # Reads only the gathered/sliced rows, writes the output.
                total.hbm_bytes += 2 * out_b
                continue
            if base in ("scatter", "dynamic-update-slice"):
                # Read-modify-write of the update slice (second operand).
                ops_ = _OPERAND_RE.findall(op.args)
                upd = (_shape_bytes(shapes.get(ops_[1], ""))
                       if len(ops_) > 1 else out_b)
                total.hbm_bytes += 2 * upd
                continue
            # Everything else (elementwise, transpose, copy, convert,
            # broadcast, ...): fused into neighbors on TPU — free here.
        return total


def analyze(hlo_text: str) -> CostTotals:
    return HloCostModel(hlo_text).totals()
