"""Roofline-term extraction from compiled dry-run artifacts.

Per (arch x shape x mesh) cell we derive three times (seconds), all from the
PER-DEVICE partitioned HLO program (see ``repro.launch.hlo_cost`` — XLA's
``cost_analysis()`` undercounts scanned programs by the loop trip count, so
we parse the HLO text ourselves):

    compute    = device_FLOPs      / 197e12 bf16 FLOP/s
    memory     = device_HBM_bytes  / 819e9  B/s
    collective = device_wire_bytes / 50e9   B/s (one ICI link direction)

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) gives the "useful" FLOP
floor; the ratio MODEL_FLOPS/HLO_FLOPs exposes remat/redundancy waste, and

    roofline_fraction = (MODEL_FLOPS / chips / peak) / max(three terms)

is the achievable-MFU bound this cell can reach — the number §Perf iterates
on. Collective wire bytes use ring-algorithm factors ((G-1)/G etc.) and
assume the collective serializes on one link direction — a conservative
bound; 2D torus algorithms can use more links, so real machines may beat
the collective term.
"""
from __future__ import annotations

import dataclasses

from repro.launch import hlo_cost

# TPU v5e hardware constants (per chip).
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link direction
HBM_PER_CHIP = 16 * 2**30    # 16 GiB


@dataclasses.dataclass
class Roofline:
    """All byte/FLOP quantities are PER DEVICE; model_flops is global."""

    flops: float
    hbm_bytes: float
    coll_bytes: float
    chips: int
    model_flops: float
    coll_by_kind: dict = dataclasses.field(default_factory=dict)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flop_ratio(self) -> float:
        """MODEL_FLOPS / (total compiled FLOPs across chips)."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def t_useful(self) -> float:
        return self.model_flops / (self.chips * PEAK_FLOPS)

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU bound: useful-FLOP time / bound step time."""
        bound = max(self.t_compute, self.t_memory, self.t_collective)
        return self.t_useful / bound if bound else 0.0

    def report(self) -> dict:
        return {
            "device_flops": self.flops,
            "device_hbm_bytes": self.hbm_bytes,
            "device_coll_wire_bytes": self.coll_bytes,
            "coll_by_kind": {k: float(v)
                             for k, v in self.coll_by_kind.items()},
            "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_flop_ratio": self.useful_flop_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def from_compiled(compiled, chips: int, model_flops: float) -> Roofline:
    """Parse the compiled per-device program into roofline terms."""
    totals = hlo_cost.analyze(compiled.as_text())
    return Roofline(
        flops=totals.flops, hbm_bytes=totals.hbm_bytes,
        coll_bytes=totals.coll_wire_bytes, chips=chips,
        model_flops=model_flops, coll_by_kind=totals.coll_by_kind)


def model_flops_for(cfg, cell) -> float:
    """6*N*D with N = active params, D = tokens processed this step.

    Train counts fwd+bwd (6ND); prefill counts forward only (2ND); decode
    counts one token per sequence (2ND, D = batch).
    """
    n = cfg.active_param_count
    if cell.mode == "train":
        return 6.0 * n * cell.global_batch * cell.seq_len
    if cell.mode == "prefill":
        return 2.0 * n * cell.global_batch * cell.seq_len
    return 2.0 * n * cell.global_batch


def extract_cost(compiled) -> tuple[float, float]:
    """(flops, bytes accessed) from compiled.cost_analysis().

    Kept for reference only — XLA counts while-loop bodies once, so these
    numbers undercount scanned programs. Roofline uses ``from_compiled``.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    by = float(cost.get("bytes accessed", 0.0))
    return flops, by
