"""Production mesh factory.

Kept as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod mesh: 16x16 (256 chips) or 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (elastic rescale, tests)."""
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke runs (axes present, size 1)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_serving_mesh(data: int = 1, model: int = 1):
    """Serving mesh: ``data`` carries slot-pool sharding (DESIGN.md §8),
    ``model`` carries TP. Uses the first data*model devices, so the
    sharded-parity tests can build mesh=(1,) and mesh=(data=4,) side by
    side in one forced-multi-device CPU process."""
    if data * model > jax.device_count():
        raise ValueError(
            f"mesh ({data}x{model}) needs {data * model} devices, have "
            f"{jax.device_count()} (set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N on CPU)")
    return jax.make_mesh((data, model), ("data", "model"))
