"""Gauss-Laguerre quadrature for the Bernstein/Laplace linearization.

The spherical Yat-kernel admits the integral representation (paper Eq. 8):

    E_sph(x) = x^2 / (C - 2x) = \\int_0^inf e^{-sC} [x^2 e^{2sx}] ds,
    x = q^T k in [-1, 1],  C = 2 + eps.

With the change of variables t = C s this becomes a standard Gauss-Laguerre
integral; the R-node rule uses nodes/weights

    s_r = t_r / C,   w_r = alpha_r / C,

where (t_r, alpha_r) are the classical Laguerre nodes/weights for
\\int_0^inf e^{-t} f(t) dt (paper §2.4.1, App. J).
"""
from __future__ import annotations

import functools

import numpy as np


@functools.lru_cache(maxsize=64)
def laguerre_nodes(num_nodes: int) -> tuple[np.ndarray, np.ndarray]:
    """Classical Gauss-Laguerre nodes/weights for ∫ e^{-t} f(t) dt."""
    t, a = np.polynomial.laguerre.laggauss(num_nodes)
    return np.asarray(t, dtype=np.float64), np.asarray(a, dtype=np.float64)


def yat_quadrature(num_nodes: int, eps: float) -> tuple[np.ndarray, np.ndarray]:
    """Scaled nodes/weights (s_r, w_r) for the spherical Yat integral.

    Returns float64 numpy arrays; callers cast to the compute dtype. The
    weights already absorb the 1/C Jacobian of t = C s.
    """
    if num_nodes < 1:
        raise ValueError("num_nodes must be >= 1")
    if eps <= 0:
        raise ValueError("eps must be > 0 (Bernstein applicability, Lemma 1)")
    c = 2.0 + eps
    t, a = laguerre_nodes(num_nodes)
    return t / c, a / c


def quadrature_kernel(x: np.ndarray, num_nodes: int, eps: float) -> np.ndarray:
    """Quadrature approximation of E_sph(x) = x^2/(C-2x) (no random features).

    Pure-numpy helper used by tests and the convergence benchmark (Fig. 9).
    """
    s, w = yat_quadrature(num_nodes, eps)
    x = np.asarray(x, dtype=np.float64)[..., None]
    return np.sum(w * (x**2) * np.exp(2.0 * s * x), axis=-1)


def exact_spherical_yat(x: np.ndarray, eps: float) -> np.ndarray:
    """Closed-form E_sph(x) = x^2 / (2 + eps - 2x)."""
    x = np.asarray(x, dtype=np.float64)
    return x**2 / (2.0 + eps - 2.0 * x)
