"""Linear-attention baselines the paper compares against (Table 5):

* FAVOR+ (Performer, ReLU random features),
* ELU+1 linear attention (Katharopoulos et al.),
* cosformer (Qin et al., 2022) position-reweighted ReLU features.

Each produces feature maps compatible with `repro.core.linear_attention`,
so the same causal/non-causal/decode machinery serves all mechanisms.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import linear_attention as la


def favor_init(key: jax.Array, head_dim: int, num_features: int = 64) -> dict:
    """Orthogonal-ish Gaussian projection matrix for FAVOR+ ReLU features."""
    blocks = []
    n = num_features
    while n > 0:
        k, key = jax.random.split(key)
        g = jax.random.normal(k, (head_dim, head_dim), jnp.float32)
        qmat, _ = jnp.linalg.qr(g)
        norms = jnp.linalg.norm(
            jax.random.normal(key, (head_dim, head_dim), jnp.float32), axis=-1)
        blocks.append(qmat * norms[:, None])
        n -= head_dim
    proj = jnp.concatenate(blocks, axis=0)[:num_features]
    return {"proj": proj}


def favor_features(u: jnp.ndarray, params: dict) -> jnp.ndarray:
    """ReLU random features (Performer, paper Table 9: M=64 ReLU)."""
    m = params["proj"].shape[0]
    proj = jnp.einsum("...d,Dd->...D", u, params["proj"].astype(u.dtype))
    return jax.nn.relu(proj) / np.sqrt(m)


def elu1_features(u: jnp.ndarray) -> jnp.ndarray:
    """φ(x) = elu(x) + 1 (strictly positive)."""
    return jax.nn.elu(u) + 1.0


def cosformer_features(u: jnp.ndarray, seq_axis: int = -3,
                       max_len: int | None = None) -> jnp.ndarray:
    """cosformer: ReLU(u) reweighted by cos/sin(π i / 2M) along the sequence.

    Doubles the feature dim: [φ cos, φ sin]. The cos/sin pair reconstructs
    the cos(π(i−j)/2M) locality weighting after the linear-attention product.
    """
    L = u.shape[seq_axis]
    M = max_len or L
    pos = jnp.arange(L, dtype=u.dtype) * (np.pi / (2 * M))
    shape = [1] * u.ndim
    shape[seq_axis] = L
    pos = pos.reshape(shape)
    phi = jax.nn.relu(u)
    return jnp.concatenate([phi * jnp.cos(pos), phi * jnp.sin(pos)], axis=-1)


def linear_baseline_attention(kind: str, params: dict | None, q, k, v, *,
                              causal: bool = True, chunk_size: int = 256,
                              delta: float = 1e-6):
    """Dispatch for favor|cosformer|elu1 over shared linear machinery."""
    if kind == "favor":
        qf, kf = favor_features(q, params), favor_features(k, params)
    elif kind == "elu1":
        qf, kf = elu1_features(q), elu1_features(k)
    elif kind == "cosformer":
        m = max(q.shape[-3], k.shape[-3])
        qf = cosformer_features(q, max_len=m)
        kf = cosformer_features(k, max_len=m)
    else:
        raise ValueError(f"unknown linear baseline {kind}")
    if causal:
        return la.causal_chunked(qf, kf, v, chunk_size=chunk_size, delta=delta)
    return la.noncausal(qf, kf, v, delta=delta)
