"""Linear-time attention contractions (paper Eq. 11 and Algorithm 1).

Given feature maps Ψ(Q) ∈ (..., L, H, m), Ψ(K) ∈ (..., L, Hkv, m) and values
V ∈ (..., L, Hkv, dv) (GQA: H = Hkv·G, the kv features/values are shared
across each group of G query heads *without* materializing the repeat):

    Y = Ψ(Q) (Ψ(K)ᵀ V) / (Ψ(Q) (Ψ(K)ᵀ 1) + δ)

* non-causal: two einsums, O(L·m·dv).
* causal: chunk-parallel form — intra-chunk quadratic on features (MXU
  friendly T×T tiles) + inter-chunk running state via `lax.scan`
  (O(L·T·m + L·m·dv) time, O(m·dv) carry). This is the TPU-native
  adaptation of the GPU per-token recurrence (DESIGN.md §3).
* decode: O(m·dv) per token with persistent (S, z) state.

All accumulation is fp32 regardless of input dtype.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class LinearState(NamedTuple):
    """Running linear-attention state: S = ΣΨ(k)ᵀv, z = ΣΨ(k)."""

    s: jnp.ndarray  # (..., Hkv, m, dv)
    z: jnp.ndarray  # (..., Hkv, m)


def _group(qf: jnp.ndarray, num_kv: int) -> jnp.ndarray:
    """(..., L, H, m) -> (..., L, Hkv, G, m)."""
    *lead, L, H, m = qf.shape
    if H % num_kv:
        raise ValueError(f"q heads {H} not divisible by kv heads {num_kv}")
    return qf.reshape(*lead, L, num_kv, H // num_kv, m)


def noncausal(qf, kf, v, delta: float = 1e-6):
    """Non-causal (or cross-) linear attention; kf/v may have length != L."""
    num_kv = kf.shape[-2]
    qg = _group(qf, num_kv)
    acc = jnp.float32
    s = jnp.einsum("...lkm,...lkd->...kmd", kf, v, preferred_element_type=acc)
    z = jnp.sum(kf.astype(acc), axis=-3)  # (..., Hkv, m)
    num = jnp.einsum("...lkgm,...kmd->...lkgd", qg, s, preferred_element_type=acc)
    den = jnp.einsum("...lkgm,...km->...lkg", qg, z, preferred_element_type=acc)
    out = num / (den[..., None] + delta)
    return out.reshape(*qf.shape[:-1], v.shape[-1]).astype(v.dtype)


def causal_chunked(qf, kf, v, chunk_size: int = 256, delta: float = 1e-6,
                   init_state: LinearState | None = None,
                   return_state: bool = False):
    """Causal linear attention via chunked prefix state (pure-jnp oracle for
    the Pallas kernel; also the general-rank training path).

    qf: (..., L, H, m), kf: (..., L, Hkv, m), v: (..., L, Hkv, dv).
    L is zero-padded to a chunk multiple (zero features contribute nothing
    to the running state, and padded query rows are sliced away).

    ``init_state`` seeds the running (S, z) carry — chunked *prefill
    continuation*: feeding a prompt chunk-by-chunk with the previous chunks'
    state reproduces the whole-prompt result exactly (same fp32 carry math).
    ``return_state`` additionally returns the post-sequence LinearState.
    """
    *lead, L, H, m = qf.shape
    num_kv, dv = kf.shape[-2], v.shape[-1]
    if L % chunk_size:
        pad = chunk_size - L % chunk_size
        padding = [(0, 0)] * (len(lead)) + [(0, pad), (0, 0), (0, 0)]
        out = causal_chunked(jnp.pad(qf, padding), jnp.pad(kf, padding),
                             jnp.pad(v, padding), chunk_size, delta,
                             init_state, return_state)
        if return_state:
            return out[0][..., :L, :, :], out[1]
        return out[..., :L, :, :]
    C, T = L // chunk_size, chunk_size
    acc = jnp.float32

    qg = _group(qf, num_kv).reshape(*lead, C, T, num_kv, H // num_kv, m)
    kc = kf.reshape(*lead, C, T, num_kv, m)
    vc = v.reshape(*lead, C, T, num_kv, dv)

    # Move chunk axis to front for scan.
    nlead = len(lead)
    qg = jnp.moveaxis(qg, nlead, 0)
    kc = jnp.moveaxis(kc, nlead, 0)
    vc = jnp.moveaxis(vc, nlead, 0)

    tril = jnp.tril(jnp.ones((T, T), bool))

    def step(carry, inp):
        s, z = carry  # (..., Hkv, m, dv), (..., Hkv, m)
        q_c, k_c, v_c = inp
        # Inter-chunk contribution from the prefix state.
        num = jnp.einsum("...tkgm,...kmd->...tkgd", q_c, s,
                         preferred_element_type=acc)
        den = jnp.einsum("...tkgm,...km->...tkg", q_c, z,
                         preferred_element_type=acc)
        # Intra-chunk causal quadratic on features.
        scores = jnp.einsum("...tkgm,...ukm->...kgtu", q_c, k_c,
                            preferred_element_type=acc)
        scores = jnp.where(tril, scores, 0.0)
        num += jnp.einsum("...kgtu,...ukd->...tkgd", scores,
                          v_c.astype(acc), preferred_element_type=acc)
        den += jnp.sum(scores, axis=-1).swapaxes(-1, -3).swapaxes(-1, -2)
        # Update running state.
        s = s + jnp.einsum("...tkm,...tkd->...kmd", k_c, v_c,
                           preferred_element_type=acc)
        z = z + jnp.sum(k_c.astype(acc), axis=-3)
        out = (num / (den[..., None] + delta)).astype(v.dtype)
        return (s, z), out

    if init_state is not None:
        s0 = jnp.broadcast_to(init_state.s.astype(acc),
                              (*lead, num_kv, m, dv))
        z0 = jnp.broadcast_to(init_state.z.astype(acc), (*lead, num_kv, m))
    else:
        s0 = jnp.zeros((*lead, num_kv, m, dv), acc)
        z0 = jnp.zeros((*lead, num_kv, m), acc)
    (s_fin, z_fin), ys = jax.lax.scan(step, (s0, z0), (qg, kc, vc))
    ys = jnp.moveaxis(ys, 0, nlead)  # back to (..., C, T, Hkv, G, dv)
    out = ys.reshape(*lead, L, H, dv)
    if return_state:
        return out, LinearState(s_fin, z_fin)
    return out


def init_state(lead_shape, num_kv: int, m: int, dv: int) -> LinearState:
    return LinearState(
        s=jnp.zeros((*lead_shape, num_kv, m, dv), jnp.float32),
        z=jnp.zeros((*lead_shape, num_kv, m), jnp.float32),
    )


def prefill_state(kf, v) -> LinearState:
    """Absorb a whole prompt into the decode state (causal prefix total)."""
    s = jnp.einsum("...lkm,...lkd->...kmd", kf, v,
                   preferred_element_type=jnp.float32)
    z = jnp.sum(kf.astype(jnp.float32), axis=-3)
    return LinearState(s, z)


def decode_step(qf, kf, v, state: LinearState, delta: float = 1e-6):
    """One autoregressive token: qf (..., H, m), kf (..., Hkv, m),
    v (..., Hkv, dv). Returns (y (..., H, dv), new_state). O(m·dv)."""
    num_kv = kf.shape[-2]
    s = state.s + jnp.einsum("...km,...kd->...kmd", kf, v,
                             preferred_element_type=jnp.float32)
    z = state.z + kf.astype(jnp.float32)
    *lead, H, m = qf.shape
    qg = qf.reshape(*lead, num_kv, H // num_kv, m)
    num = jnp.einsum("...kgm,...kmd->...kgd", qg, s,
                     preferred_element_type=jnp.float32)
    den = jnp.einsum("...kgm,...km->...kg", qg, z,
                     preferred_element_type=jnp.float32)
    y = (num / (den[..., None] + delta)).reshape(*lead, H, v.shape[-1])
    return y.astype(v.dtype), LinearState(s, z)
