"""SLAY attention — the paper's contribution as a composable JAX module.

Ties together: spherical normalization → anchor/poly features → PRFs →
Gauss-Laguerre-weighted tensor fusion (Ψ) → linear attention reordering.

Usage (functional):

    cfg   = SlayConfig(head_dim=64)
    prm   = slay_init(key, cfg)
    y     = slay_attention(prm, q, k, v, cfg, causal=True)

q: (..., L, H, Dh), k/v: (..., L, Hkv, Dh/dv). Decode via `slay_decode_step`.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.core import linear_attention as la
from repro.core.features import (SlayFeatureConfig, init_feature_params,
                                 slay_features)

# Re-export the feature config under the public name.
SlayConfig = SlayFeatureConfig


def slay_init(key: jax.Array, cfg: SlayConfig) -> dict:
    """Random projections (anchors, omegas). Shared across layers/heads by
    default (paper App. H); pass distinct keys to untie."""
    return init_feature_params(key, cfg)


def slay_attention(params: dict, q, k, v, cfg: SlayConfig, *,
                   causal: bool = True, chunk_size: int = 256,
                   delta: float = 1e-6, use_kernel: bool = False,
                   fuse_features: bool = True,
                   interpret: bool | None = None):
    """Full-sequence SLAY attention (training / prefill).

    ``use_kernel`` selects the Pallas path (differentiable — the kernels
    carry custom VJPs, so this works under ``jax.grad``). With
    ``fuse_features`` (default) the causal path runs the end-to-end
    megakernel on raw q/k: Ψ(Q)/Ψ(K) are computed in VMEM and never hit
    HBM. ``fuse_features=False`` keeps the two-dispatch path (feature
    kernel → HBM → scan kernel) for A/B benchmarking.
    """
    if causal and use_kernel:
        from repro.kernels import ops  # lazy: pallas import
        if fuse_features:
            return ops.slay_fused_attention(
                q, k, v, params, cfg, chunk_size=chunk_size, delta=delta,
                interpret=interpret)
        qf = ops.slay_features(q, params, cfg, interpret=interpret)
        kf = ops.slay_features(k, params, cfg, interpret=interpret)
        return ops.slay_causal_attention(qf, kf, v, chunk_size=chunk_size,
                                         delta=delta, interpret=interpret)
    qf = slay_features(q, params, cfg)
    kf = slay_features(k, params, cfg)
    if causal:
        return la.causal_chunked(qf, kf, v, chunk_size=chunk_size, delta=delta)
    return la.noncausal(qf, kf, v, delta=delta)


def slay_cross_attention(params: dict, q, k, v, cfg: SlayConfig,
                         delta: float = 1e-6):
    """Non-causal cross-attention (e.g. Whisper decoder->encoder)."""
    qf = slay_features(q, params, cfg)
    kf = slay_features(k, params, cfg)
    return la.noncausal(qf, kf, v, delta=delta)


def slay_prefill_state(params: dict, k, v, cfg: SlayConfig) -> la.LinearState:
    kf = slay_features(k, params, cfg)
    return la.prefill_state(kf, v)


def slay_decode_step(params: dict, q, k, v, state: la.LinearState,
                     cfg: SlayConfig, delta: float = 1e-6):
    """One token: q (..., H, Dh), k/v (..., Hkv, Dh/dv)."""
    qf = slay_features(q, params, cfg)
    kf = slay_features(k, params, cfg)
    return la.decode_step(qf, kf, v, state, delta=delta)


@dataclasses.dataclass(frozen=True)
class AttentionSpec:
    """Which attention mechanism a model layer uses (first-class feature)."""

    kind: str = "softmax"  # softmax|slay|yat|yat_spherical|favor|cosformer|elu1
    slay: SlayConfig | None = None
    window: int = 0            # sliding window for local softmax layers
    logit_softcap: float = 0.0
    chunk_size: int = 256
    use_pallas: bool = False
    # With use_pallas: run the end-to-end megakernel (Ψ fused into the
    # attention scan, zero feature HBM traffic) instead of the two-dispatch
    # feature-kernel → scan-kernel pipeline.
    fuse_features: bool = True

    @property
    def is_linear(self) -> bool:
        return self.kind in ("slay", "favor", "cosformer", "elu1")
