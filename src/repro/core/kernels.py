"""Exact (quadratic) kernel attention references: Yat, spherical Yat, softmax.

These are the brute-force O(L^2) mechanisms the paper compares against
(Table 5 "Quadratic Attention" block) and the oracles for SLAY's
approximation-quality benchmarks (Table 2 / Table 6).

Shapes follow the multi-head convention (..., L, H, Dh) for q/k/v.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.features import normalize


def yat_scores(q: jnp.ndarray, k: jnp.ndarray, eps: float = 1e-3) -> jnp.ndarray:
    """Exact E-product scores (paper Eq. 1): (qᵀk)² / (||q−k||² + eps)."""
    dot = jnp.einsum("...qhd,...khd->...hqk", q, k)
    q2 = jnp.sum(jnp.square(q), axis=-1)  # (..., L, H)
    k2 = jnp.sum(jnp.square(k), axis=-1)
    dist2 = (q2.swapaxes(-1, -2)[..., :, None]
             + k2.swapaxes(-1, -2)[..., None, :] - 2.0 * dot)
    return jnp.square(dot) / (jnp.maximum(dist2, 0.0) + eps)


def spherical_yat_scores(q: jnp.ndarray, k: jnp.ndarray,
                         eps: float = 1e-3) -> jnp.ndarray:
    """Spherical E-product scores (paper Eq. 5): x²/(C−2x), x = q̂ᵀk̂."""
    x = jnp.einsum("...qhd,...khd->...hqk", normalize(q), normalize(k))
    return jnp.square(x) / (2.0 + eps - 2.0 * x)


def kernel_normalized_attention(
    scores: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool,
    delta: float = 1e-6,
) -> jnp.ndarray:
    """Kernel normalization: Y_i = Σ_j K_ij v_j / (Σ_j K_ij + δ).

    Not a softmax — scores are used as nonnegative kernel weights
    (paper Eq. 11 applied to the exact kernel matrix).
    """
    if causal:
        L = scores.shape[-1]
        mask = jnp.tril(jnp.ones((L, L), bool))
        scores = jnp.where(mask, scores, 0.0)
    num = jnp.einsum("...hqk,...khd->...qhd", scores, v)
    den = jnp.sum(scores, axis=-1).swapaxes(-1, -2)[..., None]
    return num / (den + delta)


def windowed_softmax_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    window: int,
    logit_softcap: float = 0.0,
) -> jnp.ndarray:
    """Banded causal sliding-window attention in O(L·2w) memory.

    Queries are processed in blocks of `window`; block i attends to key
    blocks [i-1, i] only (a causal query at offset t in block i reaches at
    most w-1 positions back, which never crosses below block i-1). This
    avoids the O(L²) logits tensor the masked path materializes —
    at 32k tokens and w=4096 that is a 64x peak-memory reduction
    (the gemma2 prefill cell drops from 523 GiB to the banded footprint).
    Requires L % window == 0 (callers fall back to the masked path
    otherwise)."""
    *lead, L, H, dh = q.shape
    w = window
    nb = L // w
    qb = q.reshape(*lead, nb, w, H, dh)
    kb = k.reshape(*lead, nb, w, H, dh)
    vb = v.reshape(*lead, nb, w, H, dh)
    # Keys/values of the previous block (block 0 sees zeros, masked out).
    pad = [(0, 0)] * len(lead) + [(1, 0), (0, 0), (0, 0), (0, 0)]
    kprev = jnp.pad(kb, pad)[..., :-1, :, :, :]
    vprev = jnp.pad(vb, pad)[..., :-1, :, :, :]
    k2 = jnp.concatenate([kprev, kb], axis=-3)       # (..., nb, 2w, H, dh)
    v2 = jnp.concatenate([vprev, vb], axis=-3)
    logits = jnp.einsum("...qhd,...khd->...hqk", qb, k2) / jnp.sqrt(
        jnp.asarray(dh, q.dtype))
    if logit_softcap:
        logits = logit_softcap * jnp.tanh(logits / logit_softcap)
    qpos = jnp.arange(w)[:, None] + w                # absolute within band
    kpos = jnp.arange(2 * w)[None, :]
    mask = (qpos >= kpos) & (qpos - kpos < w)
    first = jnp.arange(nb) == 0                      # block 0: mask prev half
    mask_first = mask & (kpos >= w)
    mask_b = jnp.where(first[:, None, None], mask_first[None], mask[None])
    shape = [1] * len(lead) + [nb, 1, w, 2 * w]
    logits = jnp.where(mask_b.reshape(shape), logits,
                       jnp.finfo(logits.dtype).min)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(
        q.dtype)
    out = jnp.einsum("...hqk,...khd->...qhd", probs, v2)
    return out.reshape(*lead, L, H, dh)


def softmax_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    logit_softcap: float = 0.0,
    window: int = 0,
) -> jnp.ndarray:
    """Standard scaled dot-product attention; optional Gemma-2 logit softcap
    and sliding-window (local) masking. Windowed causal self-attention with
    L % window == 0 routes to the banded O(L·2w) implementation."""
    if (window and causal and q.shape[-3] == k.shape[-3]
            and q.shape[-3] % window == 0 and q.shape[-3] > window):
        return windowed_softmax_attention(q, k, v, window, logit_softcap)
    dh = q.shape[-1]
    logits = jnp.einsum("...qhd,...khd->...hqk", q, k) / jnp.sqrt(
        jnp.asarray(dh, q.dtype))
    if logit_softcap:
        logits = logit_softcap * jnp.tanh(logits / logit_softcap)
    L, Lk = logits.shape[-2], logits.shape[-1]
    qpos = jnp.arange(L)[:, None] + (Lk - L)  # align when Lk > L (KV cache)
    kpos = jnp.arange(Lk)[None, :]
    mask = jnp.ones((L, Lk), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= qpos - kpos < window
    logits = jnp.where(mask, logits, jnp.finfo(logits.dtype).min)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("...hqk,...khd->...qhd", probs, v)


def yat_attention(q, k, v, causal=True, eps=1e-3, spherical=False):
    """Quadratic Yat attention (exact or spherical) with kernel normalization."""
    fn = spherical_yat_scores if spherical else yat_scores
    return kernel_normalized_attention(fn(q, k, eps), v, causal)
