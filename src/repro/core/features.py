"""Random / deterministic feature maps for the SLAY kernel factorization.

Two ingredients (paper §2.4):

* polynomial features for x^2 = (q̂ᵀk̂)^2 — five variants. Anchor features
  (nonnegative, default) carry the positivity guarantee; exact vec(uuᵀ) is
  exact; TensorSketch / Random Maclaurin / Nystrom are signed baselines.
* positive random features (PRFs) for e^{2s x} (Choromanski et al., 2021).

All maps operate on the trailing dimension: u has shape (..., d) and the
feature output has shape (..., F).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quadrature

PolyKind = Literal["anchor", "exact", "rm", "tensorsketch", "nystrom",
                   "laplace"]   # "laplace" = no polynomial factor (App. F)
FusionKind = Literal["tensor", "subsample", "hadamard"]


def normalize(u: jnp.ndarray, axis: int = -1, eps: float = 1e-6) -> jnp.ndarray:
    """L2-normalize onto the unit sphere (paper Eq. 2). Stable at ~0."""
    # rsqrt in fp32 for stability under bf16 activations.
    sq = jnp.sum(jnp.square(u.astype(jnp.float32)), axis=axis, keepdims=True)
    inv = jax.lax.rsqrt(sq + eps)
    return (u.astype(jnp.float32) * inv).astype(u.dtype)


# ---------------------------------------------------------------------------
# Polynomial factor  (q̂ᵀk̂)^2
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SlayFeatureConfig:
    """Static configuration of the SLAY feature map (per attention head)."""

    head_dim: int
    num_anchors: int = 8          # P
    num_prf: int = 16             # D
    num_quad_nodes: int = 3       # R
    eps: float = 1e-3             # kernel stabilizer (C = 2 + eps)
    poly_kind: PolyKind = "anchor"
    fusion: FusionKind = "tensor"
    sketch_dim: int = 0           # D_t for fusion="subsample" (0 -> P*D)
    prf_antithetic: bool = True   # pair omega with -omega (variance reduction)

    @property
    def poly_dim(self) -> int:
        if self.poly_kind == "exact":
            return self.head_dim * self.head_dim
        if self.poly_kind == "laplace":
            return 1
        return self.num_anchors

    @property
    def node_dim(self) -> int:
        if self.fusion == "hadamard":
            return max(self.poly_dim, self.num_prf)
        if self.fusion == "subsample" and self.sketch_dim:
            return self.sketch_dim
        return self.poly_dim * self.num_prf

    @property
    def feature_dim(self) -> int:
        """m — final concatenated feature dimension."""
        return self.num_quad_nodes * self.node_dim


def init_feature_params(key: jax.Array, cfg: SlayFeatureConfig) -> dict:
    """Draw the random projections used by the feature map.

    anchors: (P, d) unit rows; omegas: (D, d) iid N(0, I) (antithetic pairs
    when enabled); subsample indices for the sketched Kronecker fusion.
    """
    k_anchor, k_omega, k_idx, k_rm = jax.random.split(key, 4)
    d = cfg.head_dim
    anchors = jax.random.normal(k_anchor, (cfg.num_anchors, d), jnp.float32)
    anchors = anchors / jnp.linalg.norm(anchors, axis=-1, keepdims=True)
    if cfg.prf_antithetic and cfg.num_prf % 2 == 0:
        half = jax.random.normal(k_omega, (cfg.num_prf // 2, d), jnp.float32)
        omegas = jnp.concatenate([half, -half], axis=0)
    else:
        omegas = jax.random.normal(k_omega, (cfg.num_prf, d), jnp.float32)
    params = {"anchors": anchors, "omegas": omegas}
    if cfg.poly_kind == "rm":
        r = jax.random.rademacher(k_rm, (2, cfg.num_anchors, d), jnp.float32)
        params["rm_signs"] = r
    if cfg.fusion == "subsample" and cfg.sketch_dim:
        total = cfg.poly_dim * cfg.num_prf
        idx = jax.random.choice(k_idx, total, (cfg.sketch_dim,), replace=False)
        params["subsample_idx"] = idx
    return params


def poly_features(u: jnp.ndarray, params: dict, cfg: SlayFeatureConfig) -> jnp.ndarray:
    """φ_poly(u): feature map for the degree-2 polynomial kernel (uᵀv)²."""
    if cfg.poly_kind == "anchor":
        # φ_anc(u) = [(uᵀa_i)²]_i / sqrt(P)  — nonnegative (paper §2.4.2).
        proj = jnp.einsum("...d,pd->...p", u, params["anchors"].astype(u.dtype))
        return jnp.square(proj) / np.sqrt(cfg.num_anchors)
    if cfg.poly_kind == "exact":
        # vec(u uᵀ): exact, d² features.
        outer = u[..., :, None] * u[..., None, :]
        return outer.reshape(*u.shape[:-1], cfg.head_dim * cfg.head_dim)
    if cfg.poly_kind == "rm":
        # Random Maclaurin: (rᵀu)(sᵀu), unbiased but signed.
        r, s = params["rm_signs"][0], params["rm_signs"][1]
        pr = jnp.einsum("...d,pd->...p", u, r.astype(u.dtype))
        ps = jnp.einsum("...d,pd->...p", u, s.astype(u.dtype))
        return (pr * ps) / np.sqrt(cfg.num_anchors)
    if cfg.poly_kind == "nystrom":
        # K_xA (K_AA + λI)^{-1/2}; signed via the whitening inverse.
        a = params["anchors"].astype(jnp.float32)
        kaa = jnp.square(a @ a.T)
        lam = 1e-4
        evals, evecs = jnp.linalg.eigh(kaa + lam * jnp.eye(cfg.num_anchors))
        whiten = evecs @ jnp.diag(jax.lax.rsqrt(jnp.maximum(evals, 1e-12))) @ evecs.T
        kxa = jnp.square(jnp.einsum("...d,pd->...p", u.astype(jnp.float32), a))
        return (kxa @ whiten).astype(u.dtype)
    if cfg.poly_kind == "tensorsketch":
        # Count-sketch of u composed twice via FFT (Pham & Pagh 2013).
        return _tensorsketch(u, params, cfg)
    if cfg.poly_kind == "laplace":
        # "Laplace-only" baseline (paper §3.1 / App. F): drop the x² factor;
        # the estimator targets Σ w_r e^{2s_r x} instead of the Yat kernel.
        return jnp.ones((*u.shape[:-1], 1), u.dtype)
    raise ValueError(f"unknown poly_kind {cfg.poly_kind}")


def _tensorsketch(u: jnp.ndarray, params: dict, cfg: SlayFeatureConfig) -> jnp.ndarray:
    d, dp = cfg.head_dim, cfg.num_anchors
    # Derive deterministic hash/sign tables from the anchor RNG (folded in
    # params to stay functional): reuse anchors bits for reproducibility.
    key = jax.random.PRNGKey(17)
    kh1, kh2, ks1, ks2 = jax.random.split(key, 4)
    h1 = jax.random.randint(kh1, (d,), 0, dp)
    h2 = jax.random.randint(kh2, (d,), 0, dp)
    s1 = jax.random.rademacher(ks1, (d,), jnp.float32)
    s2 = jax.random.rademacher(ks2, (d,), jnp.float32)
    uf = u.astype(jnp.float32)
    c1 = jnp.zeros((*u.shape[:-1], dp), jnp.float32).at[..., h1].add(uf * s1)
    c2 = jnp.zeros((*u.shape[:-1], dp), jnp.float32).at[..., h2].add(uf * s2)
    out = jnp.fft.irfft(jnp.fft.rfft(c1, axis=-1) * jnp.fft.rfft(c2, axis=-1),
                        n=dp, axis=-1)
    return out.astype(u.dtype)


# ---------------------------------------------------------------------------
# Exponential factor  e^{2 s x}  — positive random features
# ---------------------------------------------------------------------------


def prf_features(u: jnp.ndarray, omegas: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    """φ_PRF(u; s) = exp(√(2s) ωᵢᵀu − s)/√D (paper Eq. 9). u must be unit-norm.

    s may be scalar or (R,) — with (R,) the output gains a leading-node axis
    appended as (..., R, D).
    """
    d_feat = omegas.shape[0]
    proj = jnp.einsum("...d,Dd->...D", u, omegas.astype(u.dtype))
    s = jnp.asarray(s, dtype=u.dtype)
    if s.ndim == 0:
        logits = jnp.sqrt(2.0 * s) * proj - s
        return jnp.exp(logits) / np.sqrt(d_feat)
    # (..., R, D)
    logits = jnp.sqrt(2.0 * s)[..., :, None] * proj[..., None, :] - s[..., :, None]
    return jnp.exp(logits) / np.sqrt(d_feat)


# ---------------------------------------------------------------------------
# Fused SLAY feature map  Ψ(u)
# ---------------------------------------------------------------------------


def slay_features(u: jnp.ndarray, params: dict, cfg: SlayFeatureConfig) -> jnp.ndarray:
    """Ψ(u) ∈ (..., m): concatenation over quadrature nodes of the fused
    (polynomial ⊗ PRF) features, scaled by √w_r (paper Eq. 10).

    Inputs are normalized internally; callers may pass raw q/k head vectors.
    """
    u = normalize(u)
    s_np, w_np = quadrature.yat_quadrature(cfg.num_quad_nodes, cfg.eps)
    s = jnp.asarray(s_np, dtype=u.dtype)
    w = jnp.asarray(w_np, dtype=u.dtype)

    phi_p = poly_features(u, params, cfg)                 # (..., Dp)
    phi_e = prf_features(u, params["omegas"], s)          # (..., R, D)

    sqrt_w = jnp.sqrt(w)                                  # (R,)
    if cfg.fusion == "hadamard":
        # Elementwise fusion (biased baseline, paper App. F).
        dim = cfg.node_dim
        pp = jnp.pad(phi_p, [(0, 0)] * (phi_p.ndim - 1) + [(0, dim - phi_p.shape[-1])],
                     constant_values=1.0) if phi_p.shape[-1] < dim else phi_p[..., :dim]
        pe = jnp.pad(phi_e, [(0, 0)] * (phi_e.ndim - 1) + [(0, dim - phi_e.shape[-1])],
                     constant_values=1.0) if phi_e.shape[-1] < dim else phi_e[..., :dim]
        fused = sqrt_w[:, None] * pp[..., None, :] * pe   # (..., R, dim)
    else:
        # Explicit Kronecker per node: (..., R, Dp*D). Positivity preserved
        # when φ_poly >= 0 (anchor/exact).
        kron = phi_p[..., None, :, None] * phi_e[..., :, None, :]  # (...,R,Dp,D)
        fused = sqrt_w[:, None, None] * kron
        fused = fused.reshape(*fused.shape[:-2], cfg.poly_dim * cfg.num_prf)
        if cfg.fusion == "subsample" and cfg.sketch_dim:
            scale = np.sqrt(cfg.poly_dim * cfg.num_prf / cfg.sketch_dim)
            fused = fused[..., params["subsample_idx"]] * scale
    return fused.reshape(*u.shape[:-1], cfg.feature_dim)
