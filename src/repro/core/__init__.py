"""SLAY core: the paper's primary contribution in composable JAX.

Public API:
    SlayConfig, slay_init, slay_attention, slay_decode_step,
    AttentionSpec, quadrature, features, kernels (exact references),
    linear_attention (shared O(L) machinery), baselines.
"""
from repro.core.slay import (AttentionSpec, SlayConfig, slay_attention,
                             slay_cross_attention, slay_decode_step,
                             slay_init, slay_prefill_state)

__all__ = [
    "AttentionSpec", "SlayConfig", "slay_attention", "slay_cross_attention",
    "slay_decode_step", "slay_init", "slay_prefill_state",
]
