"""Optimizers and distributed-optimization tricks."""
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update  # noqa
from repro.optim import compress  # noqa: F401
