"""AdamW with warmup+cosine schedule and global-norm clipping.

Functional, pytree-shaped like the params. Moment dtype is configurable:
fp32 default; bf16 moments halve optimizer HBM for the largest configs
(grok-1 314B on 256 x 16 GB v5e needs it — DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-4                 # paper App. H
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01       # paper App. H
    warmup_steps: int = 500
    total_steps: int = 10_000
    clip_norm: float = 1.0
    moment_dtype: str = "float32"    # float32 | bfloat16


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


def _mdtype(cfg: AdamWConfig):
    return jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32


def adamw_init(params, cfg: AdamWConfig) -> AdamWState:
    dt = _mdtype(cfg)
    zeros = lambda p: jnp.zeros(p.shape, dt)  # noqa: E731
    return AdamWState(jnp.zeros((), jnp.int32),
                      jax.tree.map(zeros, params),
                      jax.tree.map(zeros, params))


def schedule(step, cfg: AdamWConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, state: AdamWState, params,
                 cfg: AdamWConfig) -> tuple[dict, AdamWState, dict]:
    """One step; returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(step, cfg)
    dt = _mdtype(cfg)
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mh, vh = m32 / c1, v32 / c2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m32.astype(dt), v32.astype(dt))

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step, new_m, new_v), metrics
