"""Error-feedback int8 gradient compression (1-bit-Adam-family trick).

At 1000+ node scale the data-parallel gradient all-reduce dominates the
step for small per-device batches. This module compresses gradients to int8
with a per-tensor scale before the cross-replica reduction and carries the
quantization residual in an error-feedback buffer so the bias vanishes over
steps (Karimireddy et al., 2019).

Usage (in the train loop, between grad computation and the optimizer):

    cstate = compress.init(grads)
    grads_q, cstate = compress.compress_decompress(grads, cstate)

Under GSPMD the all-reduce itself is inserted by XLA; compressing the
tensors that feed it shrinks the collective payload 4x (bf16) / 2x (int8 vs
bf16). The dry-run's collective-bytes report (§Roofline) quantifies this.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init(grads):
    """Error-feedback residual buffers (fp32, zero)."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _quantize(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_decompress(grads, ef_state):
    """Quantize (grad + residual) to int8, dequantize, update residual."""
    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, scale = _quantize(x)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), x - deq

    out = jax.tree.map(one, grads, ef_state)
    new_g = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_e = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_g, new_e
