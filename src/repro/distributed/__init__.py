"""Distribution: logical-axis sharding rules, gradient compression, and
collective helpers for the (pod, data, model) production mesh."""
from repro.distributed.sharding import (ShardingRules, DEFAULT_RULES,
                                        logical_to_sharding, shard_params,
                                        batch_sharding)  # noqa: F401
