"""Logical-axis sharding: GSPMD rules with divisibility-aware fallback.

Model code annotates parameters with *logical* axis names (see
``repro.models.layers.ParamSpec``); this module maps them to mesh axes:

    batch    -> (pod, data)      activations' batch dim (DP across pods too)
    embed    -> data             FSDP: params/opt-state sharded over data
    heads    -> model            TP over attention heads
    kv_heads -> model            TP over kv heads (falls back when Hkv < mesh)
    mlp      -> model            TP over FFN hidden
    vocab    -> model            TP over embedding/unembedding rows
    experts  -> model            EP over MoE experts
    layers   -> None             scan axis, never sharded
    seq      -> model            SP for long-context activations
    slots    -> data             serving slot-pool dim (DESIGN.md §8)

The fallback rule: if a tensor dim is not divisible by the mesh-axis size
(e.g. granite's single KV head over 16-way model parallelism), the rule
engine *drops the mesh axis* (replicates) rather than failing — recorded so
the dry-run report can show which dims replicated.

Rules are data (a dataclass), so perf iterations can swap whole schemes
(§Perf beyond-paper experiments) without touching model code.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical axis name -> mesh axis (or tuple of mesh axes, or None).

    The ``act_*`` entries govern *activation* constraints
    (``with_sharding_constraint`` inside the model forward):

        act_batch   batch dim of every activation          -> DP
        act_embed   residual-stream d_model dim            -> None (replicated)
        act_heads   per-head dims of q/k/v/attn-out        -> TP
        act_mlp     FFN hidden dim                         -> TP
        act_seq     sequence dim (sequence parallelism)    -> None at 4k

    Megatron-style defaults: residual replicated over `model`, heads/FFN
    sharded over `model` — XLA then inserts exactly one all-reduce after
    the attention-out / FFN-down contractions instead of the d-sharded
    residual + per-op resharding it otherwise invents.
    """

    batch: tuple[str, ...] | str | None = ("pod", "data")
    embed: tuple[str, ...] | str | None = "data"
    heads: tuple[str, ...] | str | None = "model"
    kv_heads: tuple[str, ...] | str | None = "model"
    mlp: tuple[str, ...] | str | None = "model"
    vocab: tuple[str, ...] | str | None = "model"
    experts: tuple[str, ...] | str | None = "model"
    seq: tuple[str, ...] | str | None = None
    layers: tuple[str, ...] | str | None = None
    # Serving slot pool: the slot dim of the pooled decode cache and of the
    # engine's per-slot control vectors shards over `data` (DESIGN.md §8).
    slots: tuple[str, ...] | str | None = "data"
    act_batch: tuple[str, ...] | str | None = ("pod", "data")
    act_embed: tuple[str, ...] | str | None = None
    act_heads: tuple[str, ...] | str | None = "model"
    act_mlp: tuple[str, ...] | str | None = "model"
    act_seq: tuple[str, ...] | str | None = None
    act_vocab: tuple[str, ...] | str | None = "model"

    def lookup(self, logical: str | None):
        if logical is None:
            return None
        return getattr(self, logical)


DEFAULT_RULES = ShardingRules()


# ---------------------------------------------------------------------------
# Activation-sharding context (MaxText-style logical constraints)
# ---------------------------------------------------------------------------

_ACTIVATION_CTX: list = []   # stack of (mesh, rules)


class activation_sharding:
    """Context manager installing (mesh, rules) for ``constrain`` calls
    inside model code. No-op when not entered (CPU unit tests)."""

    def __init__(self, mesh: Mesh, rules: ShardingRules = DEFAULT_RULES):
        self.pair = (mesh, rules)

    def __enter__(self):
        _ACTIVATION_CTX.append(self.pair)
        return self

    def __exit__(self, *exc):
        _ACTIVATION_CTX.pop()
        return False


def constrain(x, logical_axes: tuple):
    """with_sharding_constraint by logical activation axes; identity when no
    activation_sharding context is installed. Divisibility-checked the same
    way as parameters (drop-axis fallback)."""
    if not _ACTIVATION_CTX:
        return x
    mesh, rules = _ACTIVATION_CTX[-1]
    spec = partition_spec(mesh, rules, tuple(x.shape), tuple(logical_axes))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _mesh_axes_present(mesh: Mesh, axes) -> tuple[str, ...]:
    if axes is None:
        return ()
    if isinstance(axes, str):
        axes = (axes,)
    return tuple(a for a in axes if a in mesh.shape)


def partition_spec(mesh: Mesh, rules: ShardingRules, shape: tuple[int, ...],
                   logical_axes: tuple[str | None, ...],
                   fallback_log: list | None = None) -> P:
    """Build a PartitionSpec honoring divisibility (drop-axis fallback)."""
    if len(shape) != len(logical_axes):
        raise ValueError(f"rank mismatch: {shape} vs {logical_axes}")
    spec = []
    used: set[str] = set()
    for dim, logical in zip(shape, logical_axes):
        axes = _mesh_axes_present(mesh, rules.lookup(logical))
        # Drop mesh axes already used by an earlier dim of this tensor.
        axes = tuple(a for a in axes if a not in used)
        total = int(np.prod([mesh.shape[a] for a in axes], dtype=np.int64)) \
            if axes else 1
        while axes and dim % total:
            dropped = axes[-1]
            axes = axes[:-1]
            total = int(np.prod([mesh.shape[a] for a in axes],
                                dtype=np.int64)) if axes else 1
            if fallback_log is not None:
                fallback_log.append((logical, dim, dropped))
        used.update(axes)
        if not axes:
            spec.append(None)
        elif len(axes) == 1:
            spec.append(axes[0])
        else:
            spec.append(tuple(axes))
    while spec and spec[-1] is None:
        spec.pop()
    return P(*spec)


def logical_to_sharding(mesh: Mesh, rules: ShardingRules, abstract, axes,
                        fallback_log: list | None = None):
    """Map a pytree of (ShapeDtypeStruct|Array) + logical-axes pytree to
    NamedShardings."""
    def one(x, ax):
        return NamedSharding(mesh, partition_spec(
            mesh, rules, tuple(x.shape), tuple(ax), fallback_log))
    return jax.tree.map(one, abstract, axes,
                        is_leaf=lambda x: isinstance(x, tuple)
                        and all(isinstance(a, (str, type(None))) for a in x))


def shard_params(mesh: Mesh, rules: ShardingRules, params, axes):
    """Device_put a realized param tree onto the mesh per the rules."""
    sh = logical_to_sharding(mesh, rules, params, axes)
    return jax.tree.map(jax.device_put, params, sh)


def batch_sharding(mesh: Mesh, rules: ShardingRules = DEFAULT_RULES,
                   *, extra_rank: int = 1,
                   batch_size: int | None = None) -> NamedSharding:
    """Sharding for (B, ...) input batches: batch dim over (pod, data).

    When ``batch_size`` is given, axes that do not divide it are dropped
    (innermost first) — e.g. the long_500k cell's global_batch=1 replicates
    rather than failing to lower."""
    axes = _mesh_axes_present(mesh, rules.batch)
    if batch_size is not None:
        total = int(np.prod([mesh.shape[a] for a in axes], dtype=np.int64)) \
            if axes else 1
        while axes and batch_size % total:
            axes = axes[:-1]
            total = int(np.prod([mesh.shape[a] for a in axes],
                                dtype=np.int64)) if axes else 1
    ax = axes[0] if len(axes) == 1 else (tuple(axes) if axes else None)
    return NamedSharding(mesh, P(ax))


def serving_param_rules(rules: ShardingRules = DEFAULT_RULES
                        ) -> ShardingRules:
    """Serving-time parameter rules: replicate over the slot axes.

    Training shards params over ``data`` (FSDP, ``embed -> data``); at
    decode the ``data`` axis carries slot parallelism instead, and an
    FSDP-sharded param tree would force a weight all-gather inside every
    decode tick. Serving therefore replicates params over the slot axes
    (keeping TP axes intact) — the enabler for the §8 zero-collective
    decode hot loop contract.
    """
    slot_axes = rules.slots if isinstance(rules.slots, tuple) else \
        (rules.slots,) if rules.slots else ()

    def strip(entry):
        if entry is None:
            return None
        axes = entry if isinstance(entry, tuple) else (entry,)
        kept = tuple(a for a in axes if a not in slot_axes)
        return kept if len(kept) > 1 else (kept[0] if kept else None)

    # Strip the slot axes from *every* rule (custom rule sets may map any
    # logical axis to `data`), except `slots` itself — that one IS the
    # slot-pool sharding the engine resolves separately.
    return dataclasses.replace(rules, **{
        f.name: strip(getattr(rules, f.name))
        for f in dataclasses.fields(rules) if f.name != "slots"})


def pool_slot_axes(mesh: Mesh, rules: ShardingRules, num_slots: int,
                   requested: int = 0,
                   fallback_log: list | None = None
                   ) -> tuple[tuple[str, ...], int]:
    """Resolve the mesh axes the serving slot pool shards over.

    ``requested`` is ``ServingConfig.slot_shards``: 0 = auto (the whole
    slot mesh axis, normally ``data``), 1 = force a single shard
    (replicate), N > 1 = demand exactly N-way sharding (raises if the mesh
    slot axes don't multiply to N — a config/mesh mismatch, not a
    fallback). Slot->shard ownership is static: GSPMD splits the slot dim
    into contiguous blocks, so shard k owns slots
    [k*S/N, (k+1)*S/N) for the engine's lifetime.

    Divisibility fallback: when ``num_slots`` is not divisible by the
    slot-axis size the axis is dropped (pool replicates) and the drop is
    recorded in ``fallback_log`` as ``("slots", num_slots, axis)`` — the
    same contract as :func:`partition_spec`'s rule engine.

    Returns ``(axes, shard_count)``; ``axes`` is ``()`` when replicated.
    """
    axes = _mesh_axes_present(mesh, rules.slots)
    size = int(np.prod([mesh.shape[a] for a in axes], dtype=np.int64)) \
        if axes else 1
    if requested > 1 and requested != size:
        raise ValueError(
            f"slot_shards={requested} but mesh slot axes {axes} have size "
            f"{size}; build the mesh to match (e.g. make_serving_mesh)")
    if requested == 1 or not axes or size == 1:
        return (), 1
    while axes and num_slots % size:
        dropped = axes[-1]
        axes = axes[:-1]
        size = int(np.prod([mesh.shape[a] for a in axes], dtype=np.int64)) \
            if axes else 1
        if fallback_log is not None:
            fallback_log.append(("slots", num_slots, dropped))
    return axes, size


def _axis_entry(axes: tuple[str, ...]):
    """Collapse an axis tuple to a PartitionSpec entry."""
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else tuple(axes)


def serving_cache_sharding(mesh: Mesh, rules: ShardingRules, abstract, *,
                           num_slots: int | None = None,
                           num_pages: int | None = None,
                           slot_shards: int = 0,
                           fallback_log: list | None = None):
    """Slot-stable, slot-sharded decode-cache shardings for the pool.

    Derived from leaf *shapes* only (never from which slots are live), with
    the pool's slot dim fixed for the engine's lifetime — so admission and
    eviction (single-slot overwrites via ``api.reset_slot``/``write_slot``)
    keep every leaf's sharding bit-identical and never trigger a reshard or
    a host round-trip. The engine jits its decode/slot ops with these as
    both in- and out-shardings (cache donated), making that contract
    explicit to XLA.

    The slot dim — dim 1 of every stacked ``(nl, S, ...)`` leaf and dim 0
    of the ``(S,)`` per-slot ``pos`` vector — shards over ``rules.slots``
    (the ``data`` mesh axis; DESIGN.md §8), so each data shard owns a
    contiguous static block of slots end-to-end through the decode scan.
    Head-like dims keep the TP heuristic of :func:`cache_sharding`.
    ``num_slots``/``slot_shards``/``fallback_log`` follow
    :func:`pool_slot_axes`; ``num_slots`` is inferred from the leaves when
    omitted.

    Paged pool (DESIGN.md §11): pass ``num_pages`` so the page dim —
    dim 1 of the ``(nl, P, page, Hkv, dh)`` ring leaves — shards over the
    same slot axes (pages are allocated shard-block-aligned with their
    owning slots, so this keeps every page on its owner's shard). A
    ``DecodeCache.pages`` PageState is sharded explicitly: table (S, Lp)
    by slot dim 0, owner vectors (P,) by page dim 0.
    """
    pstate = getattr(abstract, "pages", None)
    base = abstract._replace(pages=None) if pstate is not None else abstract
    if num_slots is None:
        for x in jax.tree.leaves(base):
            if len(x.shape) >= 2:
                num_slots = int(x.shape[1])
                break
        else:                         # pragma: no cover — degenerate tree
            num_slots = 1
    saxes, _ = pool_slot_axes(mesh, rules, num_slots, slot_shards,
                              fallback_log)
    sax = _axis_entry(saxes)
    maxes = tuple(a for a in _mesh_axes_present(mesh, rules.heads)
                  if a not in saxes)
    msize = int(np.prod([mesh.shape[a] for a in maxes], dtype=np.int64)) \
        if maxes else 1
    mx = _axis_entry(maxes)

    def one(x):
        shape = tuple(x.shape)
        if len(shape) == 0:
            return NamedSharding(mesh, P())
        if len(shape) == 1:           # per-slot pos vector
            return NamedSharding(
                mesh, P(sax) if shape[0] == num_slots else P())
        spec: list = [None] * len(shape)
        if shape[1] == num_slots or (num_pages is not None
                                     and shape[1] == num_pages):
            spec[1] = sax
        # Shard the head-like axis (dim 2 for state/ssm, dim 3 for kv ring).
        for cand in (3, 2):
            if len(shape) > cand and shape[cand] % max(msize, 1) == 0 \
                    and msize > 1 and shape[cand] >= msize:
                spec[cand] = mx
                break
        while spec and spec[-1] is None:
            spec.pop()
        return NamedSharding(mesh, P(*spec))

    tree = jax.tree.map(one, base)
    if pstate is not None:
        tree = tree._replace(pages=page_state_sharding(mesh, sax, pstate))
    return tree


def page_state_sharding(mesh: Mesh, sax, pstate):
    """Shardings for a PageState pytree: every child shards its leading
    dim over the slot axes (table rows are slots; owner vectors are
    pages, block-aligned with their owning shard)."""
    cls = type(pstate)
    return cls(NamedSharding(mesh, P(sax)), NamedSharding(mesh, P(sax)),
               NamedSharding(mesh, P(sax)), shards=pstate.shards)


def serving_vector_sharding(mesh: Mesh,
                            rules: ShardingRules = DEFAULT_RULES, *,
                            num_slots: int,
                            slot_shards: int = 0, leading: int = 0,
                            fallback_log: list | None = None
                            ) -> NamedSharding:
    """Slot sharding for the engine's per-slot control vectors.

    The macro-step decode signature carries ``(num_slots,)``-shaped
    int32/bool vectors — last token, active mask, request ids, per-slot
    generation counts / EOS ids / budgets — plus the
    ``(K, num_slots)``-shaped token/emitted buffers it returns
    (``leading=1``). Every one of them carries the *same* slot sharding as
    the pool cache: each data shard reads exactly its own slots' control
    state and writes exactly its own slots' tokens, which is what keeps the
    K-tick decode scan free of cross-shard collectives (DESIGN.md §8).
    When the pool replicates (divisibility fallback, or a mesh without
    slot axes) these replicate too — shardings always move in lockstep
    with the cache, which is why ``num_slots`` is required: the
    divisibility decision must be made from the same inputs here and in
    :func:`serving_cache_sharding`.
    """
    saxes, _ = pool_slot_axes(mesh, rules, num_slots, slot_shards,
                              fallback_log)
    return NamedSharding(mesh, P(*([None] * leading), _axis_entry(saxes)))


def cache_sharding(mesh: Mesh, rules: ShardingRules, abstract):
    """Decode caches: shard the batch dim (first non-layer dim) over
    (pod, data) and head-like dims heuristically over model.

    Cache layouts (stacked layers first, then batch):
        kv ring:      (nl, B, S, Hkv, dh)
        linear state: (nl, B, Hkv, m, dv) / (nl, B, Hkv, m)
        ssm state:    (nl, B, nh, hd, ds); conv (nl, B, W-1, C)
    """
    baxes = _mesh_axes_present(mesh, rules.batch)
    bax = baxes[0] if len(baxes) == 1 else (tuple(baxes) if baxes else None)
    maxes = _mesh_axes_present(mesh, rules.heads)
    msize = int(np.prod([mesh.shape[a] for a in maxes], dtype=np.int64)) \
        if maxes else 1
    mx = maxes[0] if len(maxes) == 1 else (tuple(maxes) if maxes else None)

    def one(x):
        shape = tuple(x.shape)
        if len(shape) == 0:
            return NamedSharding(mesh, P())
        if len(shape) == 1:  # per-layer scalars (pos)
            return NamedSharding(mesh, P())
        bsize = int(np.prod([mesh.shape[a] for a in baxes],
                            dtype=np.int64)) if baxes else 1
        spec: list = [None] * len(shape)
        if shape[1] % max(bsize, 1) == 0 and bsize > 1:
            spec[1] = bax
        # Shard the head-like axis (dim 2 for state/ssm, dim 3 for kv ring).
        for cand in (3, 2):
            if len(shape) > cand and shape[cand] % max(msize, 1) == 0 \
                    and msize > 1 and shape[cand] >= msize:
                spec[cand] = mx
                break
        while spec and spec[-1] is None:
            spec.pop()
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, abstract)
