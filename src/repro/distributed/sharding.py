"""Logical-axis sharding: GSPMD rules with divisibility-aware fallback.

Model code annotates parameters with *logical* axis names (see
``repro.models.layers.ParamSpec``); this module maps them to mesh axes:

    batch    -> (pod, data)      activations' batch dim (DP across pods too)
    embed    -> data             FSDP: params/opt-state sharded over data
    heads    -> model            TP over attention heads
    kv_heads -> model            TP over kv heads (falls back when Hkv < mesh)
    mlp      -> model            TP over FFN hidden
    vocab    -> model            TP over embedding/unembedding rows
    experts  -> model            EP over MoE experts
    layers   -> None             scan axis, never sharded
    seq      -> model            SP for long-context activations

The fallback rule: if a tensor dim is not divisible by the mesh-axis size
(e.g. granite's single KV head over 16-way model parallelism), the rule
engine *drops the mesh axis* (replicates) rather than failing — recorded so
the dry-run report can show which dims replicated.

Rules are data (a dataclass), so perf iterations can swap whole schemes
(§Perf beyond-paper experiments) without touching model code.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical axis name -> mesh axis (or tuple of mesh axes, or None).

    The ``act_*`` entries govern *activation* constraints
    (``with_sharding_constraint`` inside the model forward):

        act_batch   batch dim of every activation          -> DP
        act_embed   residual-stream d_model dim            -> None (replicated)
        act_heads   per-head dims of q/k/v/attn-out        -> TP
        act_mlp     FFN hidden dim                         -> TP
        act_seq     sequence dim (sequence parallelism)    -> None at 4k

    Megatron-style defaults: residual replicated over `model`, heads/FFN
    sharded over `model` — XLA then inserts exactly one all-reduce after
    the attention-out / FFN-down contractions instead of the d-sharded
    residual + per-op resharding it otherwise invents.
    """

    batch: tuple[str, ...] | str | None = ("pod", "data")
    embed: tuple[str, ...] | str | None = "data"
    heads: tuple[str, ...] | str | None = "model"
    kv_heads: tuple[str, ...] | str | None = "model"
    mlp: tuple[str, ...] | str | None = "model"
    vocab: tuple[str, ...] | str | None = "model"
    experts: tuple[str, ...] | str | None = "model"
    seq: tuple[str, ...] | str | None = None
    layers: tuple[str, ...] | str | None = None
    act_batch: tuple[str, ...] | str | None = ("pod", "data")
    act_embed: tuple[str, ...] | str | None = None
    act_heads: tuple[str, ...] | str | None = "model"
    act_mlp: tuple[str, ...] | str | None = "model"
    act_seq: tuple[str, ...] | str | None = None
    act_vocab: tuple[str, ...] | str | None = "model"

    def lookup(self, logical: str | None):
        if logical is None:
            return None
        return getattr(self, logical)


DEFAULT_RULES = ShardingRules()


# ---------------------------------------------------------------------------
# Activation-sharding context (MaxText-style logical constraints)
# ---------------------------------------------------------------------------

_ACTIVATION_CTX: list = []   # stack of (mesh, rules)


class activation_sharding:
    """Context manager installing (mesh, rules) for ``constrain`` calls
    inside model code. No-op when not entered (CPU unit tests)."""

    def __init__(self, mesh: Mesh, rules: ShardingRules = DEFAULT_RULES):
        self.pair = (mesh, rules)

    def __enter__(self):
        _ACTIVATION_CTX.append(self.pair)
        return self

    def __exit__(self, *exc):
        _ACTIVATION_CTX.pop()
        return False


def constrain(x, logical_axes: tuple):
    """with_sharding_constraint by logical activation axes; identity when no
    activation_sharding context is installed. Divisibility-checked the same
    way as parameters (drop-axis fallback)."""
    if not _ACTIVATION_CTX:
        return x
    mesh, rules = _ACTIVATION_CTX[-1]
    spec = partition_spec(mesh, rules, tuple(x.shape), tuple(logical_axes))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _mesh_axes_present(mesh: Mesh, axes) -> tuple[str, ...]:
    if axes is None:
        return ()
    if isinstance(axes, str):
        axes = (axes,)
    return tuple(a for a in axes if a in mesh.shape)


def partition_spec(mesh: Mesh, rules: ShardingRules, shape: tuple[int, ...],
                   logical_axes: tuple[str | None, ...],
                   fallback_log: list | None = None) -> P:
    """Build a PartitionSpec honoring divisibility (drop-axis fallback)."""
    if len(shape) != len(logical_axes):
        raise ValueError(f"rank mismatch: {shape} vs {logical_axes}")
    spec = []
    used: set[str] = set()
    for dim, logical in zip(shape, logical_axes):
        axes = _mesh_axes_present(mesh, rules.lookup(logical))
        # Drop mesh axes already used by an earlier dim of this tensor.
        axes = tuple(a for a in axes if a not in used)
        total = int(np.prod([mesh.shape[a] for a in axes], dtype=np.int64)) \
            if axes else 1
        while axes and dim % total:
            dropped = axes[-1]
            axes = axes[:-1]
            total = int(np.prod([mesh.shape[a] for a in axes],
                                dtype=np.int64)) if axes else 1
            if fallback_log is not None:
                fallback_log.append((logical, dim, dropped))
        used.update(axes)
        if not axes:
            spec.append(None)
        elif len(axes) == 1:
            spec.append(axes[0])
        else:
            spec.append(tuple(axes))
    while spec and spec[-1] is None:
        spec.pop()
    return P(*spec)


def logical_to_sharding(mesh: Mesh, rules: ShardingRules, abstract, axes,
                        fallback_log: list | None = None):
    """Map a pytree of (ShapeDtypeStruct|Array) + logical-axes pytree to
    NamedShardings."""
    def one(x, ax):
        return NamedSharding(mesh, partition_spec(
            mesh, rules, tuple(x.shape), tuple(ax), fallback_log))
    return jax.tree.map(one, abstract, axes,
                        is_leaf=lambda x: isinstance(x, tuple)
                        and all(isinstance(a, (str, type(None))) for a in x))


def shard_params(mesh: Mesh, rules: ShardingRules, params, axes):
    """Device_put a realized param tree onto the mesh per the rules."""
    sh = logical_to_sharding(mesh, rules, params, axes)
    return jax.tree.map(jax.device_put, params, sh)


def batch_sharding(mesh: Mesh, rules: ShardingRules = DEFAULT_RULES,
                   *, extra_rank: int = 1,
                   batch_size: int | None = None) -> NamedSharding:
    """Sharding for (B, ...) input batches: batch dim over (pod, data).

    When ``batch_size`` is given, axes that do not divide it are dropped
    (innermost first) — e.g. the long_500k cell's global_batch=1 replicates
    rather than failing to lower."""
    axes = _mesh_axes_present(mesh, rules.batch)
    if batch_size is not None:
        total = int(np.prod([mesh.shape[a] for a in axes], dtype=np.int64)) \
            if axes else 1
        while axes and batch_size % total:
            axes = axes[:-1]
            total = int(np.prod([mesh.shape[a] for a in axes],
                                dtype=np.int64)) if axes else 1
    ax = axes[0] if len(axes) == 1 else (tuple(axes) if axes else None)
    return NamedSharding(mesh, P(ax))


def serving_cache_sharding(mesh: Mesh, rules: ShardingRules, abstract):
    """Slot-stable decode-cache shardings for the continuous-batching pool.

    Derived from leaf *shapes* only (never from which slots are live), with
    the pool's slot dim fixed for the engine's lifetime — so admission and
    eviction (single-slot overwrites via ``api.reset_slot``/``write_slot``)
    keep every leaf's sharding bit-identical and never trigger a reshard or
    a host round-trip. The engine jits its decode/slot ops with these as
    both in- and out-shardings (cache donated), making that contract
    explicit to XLA.
    """
    return cache_sharding(mesh, rules, abstract)


def serving_vector_sharding(mesh: Mesh) -> NamedSharding:
    """Replicated sharding for the engine's per-slot control vectors.

    The macro-step decode signature carries (num_slots,)-shaped int32/bool
    vectors — last token, active mask, request ids, per-slot generation
    counts / EOS ids / budgets — plus the (K, num_slots) emitted-token
    buffer it returns. These are a few hundred bytes; every device needs
    the full active mask and token vector to run its shard of the pool
    dispatch, so they replicate (sharding them would force an all-gather
    inside the scan per tick). Pinning P() explicitly keeps the jitted
    macro-step's in/out shardings fully specified alongside the donated
    slot-stable cache.
    """
    return NamedSharding(mesh, P())


def cache_sharding(mesh: Mesh, rules: ShardingRules, abstract):
    """Decode caches: shard the batch dim (first non-layer dim) over
    (pod, data) and head-like dims heuristically over model.

    Cache layouts (stacked layers first, then batch):
        kv ring:      (nl, B, S, Hkv, dh)
        linear state: (nl, B, Hkv, m, dv) / (nl, B, Hkv, m)
        ssm state:    (nl, B, nh, hd, ds); conv (nl, B, W-1, C)
    """
    baxes = _mesh_axes_present(mesh, rules.batch)
    bax = baxes[0] if len(baxes) == 1 else (tuple(baxes) if baxes else None)
    maxes = _mesh_axes_present(mesh, rules.heads)
    msize = int(np.prod([mesh.shape[a] for a in maxes], dtype=np.int64)) \
        if maxes else 1
    mx = maxes[0] if len(maxes) == 1 else (tuple(maxes) if maxes else None)

    def one(x):
        shape = tuple(x.shape)
        if len(shape) == 0:
            return NamedSharding(mesh, P())
        if len(shape) == 1:  # per-layer scalars (pos)
            return NamedSharding(mesh, P())
        bsize = int(np.prod([mesh.shape[a] for a in baxes],
                            dtype=np.int64)) if baxes else 1
        spec: list = [None] * len(shape)
        if shape[1] % max(bsize, 1) == 0 and bsize > 1:
            spec[1] = bax
        # Shard the head-like axis (dim 2 for state/ssm, dim 3 for kv ring).
        for cand in (3, 2):
            if len(shape) > cand and shape[cand] % max(msize, 1) == 0 \
                    and msize > 1 and shape[cand] >= msize:
                spec[cand] = mx
                break
        while spec and spec[-1] is None:
            spec.pop()
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, abstract)
