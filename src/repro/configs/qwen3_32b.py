"""qwen3-32b — dense decoder with qk-norm, GQA [hf:Qwen/Qwen3-8B; hf]."""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-32b", family="decoder",
    num_layers=64, d_model=5120, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=25600, vocab_size=151936, qk_norm=True, tie_embeddings=False,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-8B; hf",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=256, chunk_size=16)
