"""gemma2-27b — local+global alternating attention, logit softcaps
[arXiv:2408.00118; hf].

Under the SLAY backend the *global* layers linearize; local layers keep the
O(L·w) sliding-window softmax (already sub-quadratic). The attention-logit
softcap is a softmax-logit device and does not apply to kernel scores
(DESIGN.md §Arch-applicability); the final-logit softcap is kept.
"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b", family="decoder",
    num_layers=46, d_model=4608, num_heads=32, num_kv_heads=16, head_dim=128,
    d_ff=36864, vocab_size=256000, tie_embeddings=True,
    local_window=4096, local_global_period=2,
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    source="arXiv:2408.00118; hf",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=256, local_window=32,
        chunk_size=16)
