"""granite-20b — llama-arch code model with MQA (kv=1) [arXiv:2405.04324]."""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b", family="decoder",
    num_layers=52, d_model=6144, num_heads=48, num_kv_heads=1, head_dim=128,
    d_ff=24576, vocab_size=49152, tie_embeddings=True,
    source="arXiv:2405.04324; hf",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=1,
        head_dim=16, d_ff=128, vocab_size=256, chunk_size=16)
