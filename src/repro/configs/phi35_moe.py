"""phi3.5-moe-42b-a6.6b — 16-expert top-2 MoE decoder
[hf:microsoft/Phi-3.5-MoE-instruct; hf]."""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b", family="decoder",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=6400, vocab_size=32064, tie_embeddings=True,
    moe_experts=16, moe_top_k=2,
    source="hf:microsoft/Phi-3.5-MoE-instruct; hf",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=96, vocab_size=256, moe_experts=4, chunk_size=16)
