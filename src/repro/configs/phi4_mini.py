"""phi4-mini-3.8b — dense decoder, RoPE SwiGLU GQA [arXiv:2412.08905; hf]."""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi4-mini-3.8b", family="decoder",
    num_layers=32, d_model=3072, num_heads=24, num_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=200064, tie_embeddings=True,
    source="arXiv:2412.08905; hf",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=256, chunk_size=16)
