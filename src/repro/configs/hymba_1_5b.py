"""hymba-1.5b — hybrid: parallel attention + mamba heads per layer
[arXiv:2411.13676; hf]."""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5, head_dim=64,
    d_ff=5504, vocab_size=32001, tie_embeddings=True,
    ssm_state=16, ssm_expand=2, ssm_head_dim=64,
    source="arXiv:2411.13676; hf",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=256, ssm_state=8, ssm_head_dim=16,
        chunk_size=16)
