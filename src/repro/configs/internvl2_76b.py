"""internvl2-76b — VLM: InternLM2-style LM backbone; InternViT frontend is a
STUB (``input_specs`` provides precomputed patch embeddings)
[arXiv:2404.16821; unverified]."""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b", family="decoder",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=128256, tie_embeddings=False,
    frontend="vision", num_patches=256,
    source="arXiv:2404.16821; unverified",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=256, num_patches=8, chunk_size=16)
