"""Config registry: the 10 assigned architectures + the paper's SLAYformer.

    cfg = configs.get_config("qwen3-32b")          # full (dry-run only)
    cfg = configs.get_smoke_config("qwen3-32b")    # reduced (CPU smoke test)

Every arch runs with the paper's SLAY attention by default
(``attn_kind="slay"``); pass ``attn_kind="softmax"`` via
``dataclasses.replace`` for the quadratic baseline variant.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import (ArchConfig, ShapeCell, SHAPE_CELLS, get_cell,
                                input_specs)

_MODULES = {
    "phi4-mini-3.8b": "phi4_mini",
    "qwen3-32b": "qwen3_32b",
    "granite-20b": "granite_20b",
    "gemma2-27b": "gemma2_27b",
    "hymba-1.5b": "hymba_1_5b",
    "whisper-small": "whisper_small",
    "internvl2-76b": "internvl2_76b",
    "mamba2-780m": "mamba2_780m",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "grok-1-314b": "grok1_314b",
    "slayformer-124m": "slayformer_124m",
}

ASSIGNED_ARCHS = tuple(k for k in _MODULES if k != "slayformer-124m")
ALL_ARCHS = tuple(_MODULES)


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str, **overrides) -> ArchConfig:
    cfg = _module(name).CONFIG
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def get_smoke_config(name: str, **overrides) -> ArchConfig:
    cfg = _module(name).smoke_config()
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


__all__ = [
    "ArchConfig", "ShapeCell", "SHAPE_CELLS", "ASSIGNED_ARCHS", "ALL_ARCHS",
    "get_cell", "get_config", "get_smoke_config", "input_specs",
]
