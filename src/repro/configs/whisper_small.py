"""whisper-small — enc-dec audio backbone; conv frontend is a STUB
(``input_specs`` provides precomputed frame embeddings)
[arXiv:2212.04356; unverified]."""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", family="encdec",
    num_layers=12, enc_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    head_dim=64, d_ff=3072, vocab_size=51865, gated_mlp=False,
    enc_seq=1500, frontend="audio", tie_embeddings=True,
    source="arXiv:2212.04356; unverified",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, enc_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256, enc_seq=32,
        chunk_size=16)
