"""slayformer-124m — the paper's own model (§3.5): GPT-2 Small scale with
SLAY attention, 12L x 768d x 12H, vocab 50257 [paper App. H]."""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="slayformer-124m", family="decoder",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size=50257, gated_mlp=False, tie_embeddings=True,
    attn_kind="slay",
    source="paper App. H (GPT-2 Small + SLAY)",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=256, chunk_size=16)
