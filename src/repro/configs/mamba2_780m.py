"""mamba2-780m — attention-free SSD (state-space duality)
[arXiv:2405.21060; unverified].

SLAY is inapplicable (no Q/K/V attention anywhere) — implemented without the
technique per DESIGN.md §Arch-applicability. The SSD block itself is already
linear-time with constant decode state.
"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-780m", family="ssm",
    num_layers=48, d_model=1536, vocab_size=50280, tie_embeddings=True,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64,
    attn_kind="none",
    source="arXiv:2405.21060; unverified",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, vocab_size=256, ssm_state=16,
        ssm_head_dim=16, chunk_size=16)
