"""grok-1-314b — 8-expert top-2 MoE decoder [hf:xai-org/grok-1; unverified]."""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b", family="decoder",
    num_layers=64, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=32768, vocab_size=131072, tie_embeddings=False,
    moe_experts=8, moe_top_k=2,
    source="hf:xai-org/grok-1; unverified",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=96, vocab_size=256, moe_experts=4, chunk_size=16)
