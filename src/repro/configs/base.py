"""Architecture + shape-cell configuration system.

Every assigned architecture is an :class:`ArchConfig` (one module per arch in
this package, exposing ``CONFIG`` and ``smoke_config()``). Shape cells follow
the assignment:

    train_4k     seq 4096,   batch 256   -> train_step
    prefill_32k  seq 32768,  batch 32    -> prefill (full forward, no loss)
    decode_32k   seq 32768,  batch 128   -> serve_step (1 token, 32k cache)
    long_500k    seq 524288, batch 1     -> serve_step (sub-quadratic only)

``input_specs`` returns jax.ShapeDtypeStruct stand-ins — no allocation — for
the dry-run's .lower().
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core.features import SlayFeatureConfig
from repro.core.slay import AttentionSpec


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # decoder | moe | ssm | hybrid | encdec
    num_layers: int
    d_model: int
    vocab_size: int
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    # Feature flags
    qk_norm: bool = False
    attn_logit_softcap: float = 0.0   # gemma2 attention softcap
    final_logit_softcap: float = 0.0  # gemma2 output softcap
    local_window: int = 0             # sliding window for local layers
    local_global_period: int = 0      # every Nth layer global (0 = all global)
    gated_mlp: bool = True
    tie_embeddings: bool = True
    rope_theta: float = 10000.0
    # MoE
    moe_experts: int = 0
    moe_top_k: int = 2
    # SSM / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_ngroups: int = 1
    ssm_conv_width: int = 4
    # Encoder-decoder (whisper)
    enc_layers: int = 0
    enc_seq: int = 0                  # precomputed frame embeddings length
    # Modality frontend stub: "" | "audio" | "vision"
    frontend: str = ""
    num_patches: int = 0              # VLM: patch-embedding prefix length
    # Attention backend ("slay" = the paper's technique; "softmax" baseline)
    attn_kind: str = "slay"
    slay_anchors: int = 8
    slay_prf: int = 16
    slay_quad_nodes: int = 3
    chunk_size: int = 256
    # Pallas attention kernels (trainable — the kernels carry custom VJPs).
    # use_pallas dispatches the compiled kernels on TPU (jnp reference
    # elsewhere); fuse_attention_features selects the end-to-end megakernel
    # over the two-dispatch feature→scan pipeline.
    use_pallas: bool = False
    fuse_attention_features: bool = True
    # Numerics
    dtype: str = "bfloat16"
    # Source provenance (public-literature citation)
    source: str = ""

    @property
    def activation_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    def slay_config(self) -> SlayFeatureConfig:
        return SlayFeatureConfig(
            head_dim=self.resolved_head_dim, num_anchors=self.slay_anchors,
            num_prf=self.slay_prf, num_quad_nodes=self.slay_quad_nodes)

    def attention_spec(self, *, local: bool = False) -> AttentionSpec:
        """The AttentionSpec for a (global|local) layer under this config."""
        if local and self.local_window:
            return AttentionSpec(kind="softmax", window=self.local_window,
                                 logit_softcap=self.attn_logit_softcap,
                                 chunk_size=self.chunk_size)
        if self.attn_kind == "slay":
            return AttentionSpec(kind="slay", slay=self.slay_config(),
                                 chunk_size=self.chunk_size,
                                 use_pallas=self.use_pallas,
                                 fuse_features=self.fuse_attention_features)
        return AttentionSpec(kind=self.attn_kind,
                             logit_softcap=self.attn_logit_softcap,
                             chunk_size=self.chunk_size,
                             slay=self.slay_config()
                             if self.attn_kind == "slay" else None)

    @property
    def param_count_dense(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6 N D)."""
        d, L = self.d_model, self.num_layers
        dh = self.resolved_head_dim
        n = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            di = self.ssm_expand * d
            per = d * (2 * di + 2 * self.ssm_ngroups * self.ssm_state
                       + di // self.ssm_head_dim) + di * d
            return n + L * per
        attn = d * dh * (self.num_heads + 2 * self.num_kv_heads) \
            + self.num_heads * dh * d
        mlp = d * self.d_ff * (3 if self.gated_mlp else 2)
        if self.moe_experts:
            mlp = mlp * self.moe_experts + d * self.moe_experts
        per = attn + mlp
        if self.family == "hybrid":
            di = self.ssm_expand * d
            per += d * (2 * di + 2 * self.ssm_ngroups * self.ssm_state
                        + di // self.ssm_head_dim) + di * d
        total = n + L * per
        if self.family == "encdec":
            total += self.enc_layers * (attn + mlp) + L * attn  # cross-attn
        return total

    @property
    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if not self.moe_experts:
            return self.param_count_dense
        d, L = self.d_model, self.num_layers
        dh = self.resolved_head_dim
        attn = d * dh * (self.num_heads + 2 * self.num_kv_heads) \
            + self.num_heads * dh * d
        mlp_active = d * self.d_ff * 3 * self.moe_top_k + d * self.moe_experts
        return self.vocab_size * d + L * (attn + mlp_active)


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Continuous-batching engine knobs (see repro.serving.engine).

    The pool has ``num_slots`` decode slots, each with ``max_len`` context
    capacity (KV ring size; the constant-state path is length-independent).
    Prompts are absorbed ``prefill_chunk`` tokens per engine tick so long
    prompts cannot stall the decode pool; ``decode_ticks_per_prefill``
    decode ticks run between consecutive prefill chunks when both kinds of
    work are pending (1 = strict alternation).

    ``macro_ticks`` (K) is the decode macro-step: the engine wraps K decode
    ticks in one jitted ``lax.scan`` dispatch with fused on-device sampling
    and pulls a (K, num_slots) token buffer to host once per dispatch
    instead of a logits matrix per tick. Token streams are byte-identical
    for any K (sampling is keyed per (seed, rid, token-index)); larger K
    trades admission/streaming granularity (up to K ticks) for ~K× fewer
    host syncs and dispatches. K=1 recovers per-tick behavior.

    ``prefill_buckets`` pads the non-chunkable prefill fallback to pow-2
    length buckets (>= ``prefill_bucket_min``, capped at ``max_len``) so
    it compiles once per bucket instead of once per distinct prompt
    length; masked out exactly via ``true_len``. Only modality frontends
    still take this fallback — every decoder-only config (ssm/hybrid and
    the exact yat kinds included) prefills chunk-by-chunk since
    DESIGN.md §9.

    ``slot_shards`` partitions the slot pool over the mesh ``data`` axis
    (DESIGN.md §8): 0 = auto (shard over the whole data axis when
    ``num_slots`` is divisible by it, else replicate — recorded like the
    rule-engine divisibility fallback), 1 = force a single shard
    (replicated pool), N > 1 = demand exactly N-way sharding (the engine
    raises on a mesh whose data axis is not N). Token streams are
    byte-identical across any value — sampling is keyed on
    (seed, rid, token-index), never on slot or shard placement.

    Fault model (DESIGN.md §10): ``overload_policy`` picks what a full
    admission queue (``max_queue`` > 0) does with new work —

    * ``"reject_new"``: ``Scheduler.submit`` raises
      :class:`repro.serving.engine.QueueFullError` (typed, carries
      ``queue_depth``/``max_queue``) and the caller keeps the request;
    * ``"shed_oldest"``: the longest-waiting queued request is shed
      (``finish_reason="shed"``) to make room — freshest work wins;
    * ``"queue_wait"``: admission never rejects, but any request still
      queued ``queue_wait_ticks`` ticks after its arrival is shed — a
      queue-wait deadline that bounds staleness instead of depth.

    ``fault_guard`` enables the per-slot NaN/Inf finiteness lane inside
    the jitted decode macro-step (one extra (K, num_slots) bool plane in
    the token buffer the host already pulls — no new host syncs); on a
    detected fault the engine quarantines the slot (``reset_slot``) and
    re-admits the request up to ``fault_retries`` times before failing it
    with ``finish_reason="fault"``.

    Paged slot memory (DESIGN.md §11): ``page_size > 0`` splits the KV
    ring leaves of configs that support paging (non-windowed quadratic
    rings) into shared physical pages; admission allocates
    ``ceil((prompt + max_new) / page_size)`` pages, so short requests
    stop paying ``max_len``. ``num_pages`` sizes the physical pool
    (0 = ``num_slots * max_len / page_size``, i.e. no memory saving but
    full paging mechanics — set it lower to overcommit). Constant-state
    configs ignore both. ``prefix_cache_bytes > 0`` enables the
    content-addressed prefix cache: admission seeds a slot from the
    longest cached prompt-prefix snapshot and chunk-prefills only the
    suffix (LRU-evicted under this byte budget; streams stay
    byte-identical cached-vs-cold).

    Speculative decoding (DESIGN.md §13): ``speculative=True`` turns each
    of the K macro-ticks into a draft-verify *round* — the linear SLAY
    draft proposes ``spec_gamma`` tokens per slot, the exact verifier
    scores all of them in one ``verify_chunk`` dispatch, and standard
    accept/resample correction keeps the output distribution exactly the
    verifier's (greedy streams byte-identical to plain greedy decode).
    Requires a verifier config with ``api.supports_speculative`` (a
    non-windowed exact quadratic kind); the prefix cache is mutually
    exclusive with it for now (a seeded verifier slot has no draft-side
    snapshot).

    Durability (DESIGN.md §12): ``checkpoint_every_ticks > 0`` makes an
    engine constructed with a write-ahead ``journal=`` also write an
    atomic checkpoint every N engine ticks (at macro-step boundaries);
    ``ContinuousServingEngine.restore`` resumes from the latest valid
    one with byte-identical streams. ``debug_audit`` runs the invariant
    audit (``PagePool.check()`` + prefix-cache refcounts == live pins) at
    the end of every ``run()`` — also forced on by the
    ``REPRO_DEBUG_AUDIT`` env var (set for the test suite and chaos CI).
    """

    num_slots: int = 4
    max_len: int = 4096
    prefill_chunk: int = 128          # 0 = absorb whole prompts in one tick
    decode_ticks_per_prefill: int = 1
    max_queue: int = 0                # 0 = unbounded admission queue
    temperature: float = 0.0          # 0 = greedy
    seed: int = 0
    macro_ticks: int = 8              # K decode ticks per device dispatch
    prefill_buckets: bool = True      # pow-2 bucketing of fallback prefill
    prefill_bucket_min: int = 16      # smallest bucket
    slot_shards: int = 0              # data-axis pool shards (0 = auto)
    overload_policy: str = "reject_new"  # reject_new | shed_oldest | queue_wait
    queue_wait_ticks: int = 0         # queue_wait policy: max queue age (ticks)
    fault_guard: bool = True          # NaN/Inf lane in the decode macro-step
    fault_retries: int = 1            # re-admissions after a slot quarantine
    page_size: int = 0                # 0 = unpaged; else ring rows per page
    num_pages: int = 0                # 0 = auto (num_slots * max_len / page)
    prefix_cache_bytes: int = 0       # 0 = prefix cache off; else LRU budget
    checkpoint_every_ticks: int = 0   # 0 = no periodic engine checkpoints
    speculative: bool = False         # draft-verify decode (DESIGN.md §13)
    spec_gamma: int = 2               # draft tokens per speculative round
    debug_audit: bool = False         # invariant audit at end of run()

    def __post_init__(self):
        if self.num_slots < 1:
            raise ValueError("num_slots must be >= 1")
        if self.prefill_chunk < 0 or self.max_len < 1:
            raise ValueError("bad prefill_chunk/max_len")
        if self.macro_ticks < 1:
            raise ValueError("macro_ticks must be >= 1")
        if self.prefill_bucket_min < 1:
            raise ValueError("prefill_bucket_min must be >= 1")
        if self.slot_shards < 0:
            raise ValueError("slot_shards must be >= 0 (0 = auto)")
        if self.slot_shards > 1 and self.num_slots % self.slot_shards:
            raise ValueError(
                f"num_slots ({self.num_slots}) must be divisible by "
                f"slot_shards ({self.slot_shards})")
        if not math.isfinite(self.temperature) or self.temperature < 0:
            raise ValueError(
                f"temperature must be finite and >= 0 (0 = greedy), got "
                f"{self.temperature!r}")
        if self.overload_policy not in ("reject_new", "shed_oldest",
                                        "queue_wait"):
            raise ValueError(
                f"overload_policy must be one of reject_new | shed_oldest "
                f"| queue_wait, got {self.overload_policy!r}")
        if self.queue_wait_ticks < 0:
            raise ValueError("queue_wait_ticks must be >= 0 (0 = no cap)")
        if self.fault_retries < 0:
            raise ValueError("fault_retries must be >= 0")
        if self.page_size < 0 or self.num_pages < 0:
            raise ValueError("page_size/num_pages must be >= 0")
        if self.page_size and self.max_len % self.page_size:
            raise ValueError(
                f"page_size ({self.page_size}) must divide max_len "
                f"({self.max_len})")
        if self.num_pages and not self.page_size:
            raise ValueError("num_pages requires page_size > 0")
        if self.prefix_cache_bytes < 0:
            raise ValueError("prefix_cache_bytes must be >= 0")
        if self.checkpoint_every_ticks < 0:
            raise ValueError("checkpoint_every_ticks must be >= 0 (0 = off)")
        if self.spec_gamma < 1:
            raise ValueError("spec_gamma must be >= 1")
        if self.speculative and self.prefix_cache_bytes:
            raise ValueError(
                "speculative decoding and the prefix cache are mutually "
                "exclusive (a prefix-seeded verifier slot has no draft-side "
                "snapshot to seed from)")


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    mode: str                       # train | prefill | decode


SHAPE_CELLS = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)


def get_cell(name: str) -> ShapeCell:
    for c in SHAPE_CELLS:
        if c.name == name:
            return c
    raise KeyError(name)


def input_specs(cfg: ArchConfig, cell: ShapeCell) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, L = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    act = cfg.activation_dtype

    def tok(shape):
        return jax.ShapeDtypeStruct(shape, i32)

    if cell.mode == "train":
        specs = {}
        lt = L
        if cfg.frontend == "vision":
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.num_patches, cfg.d_model), act)
            lt = L - cfg.num_patches
        if cfg.frontend == "audio":
            specs["frame_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_seq, cfg.d_model), act)
        specs["tokens"] = tok((B, lt))
        specs["labels"] = tok((B, lt))
        return specs
    if cell.mode == "prefill":
        specs = {}
        lt = L
        if cfg.frontend == "vision":
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.num_patches, cfg.d_model), act)
            lt = L - cfg.num_patches
        if cfg.frontend == "audio":
            specs["frame_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_seq, cfg.d_model), act)
        specs["tokens"] = tok((B, lt))
        return specs
    # decode: one new token; the cache (sized for seq_len) is a separate
    # donated argument produced by serving.init_cache_specs.
    return {"tokens": tok((B, 1))}
