"""Data-axis sharded serving slot pool (DESIGN.md §8).

Two layers of coverage:

* In-process unit tests of the sharding resolution (pool_slot_axes,
  serving_vector_sharding specs, serving_param_rules, ServingConfig
  validation) and the shard-aware Scheduler — these need no devices.
* Multi-device contract checks (byte-identical streams mesh=(1,) vs
  mesh=(data=4,) at K=1/K=8, shard-local eviction/reuse, divisibility
  fallback, zero-collective decode HLO) — these need a forced 8-device CPU,
  and jax pins its device count at first init, so each check runs
  ``tests/sharded_driver.py`` in a subprocess with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``. CI additionally
  invokes the driver directly under that flag.
"""
import os
import subprocess
import sys

import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ServingConfig
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.serving.engine import Scheduler

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_DRIVER = os.path.join(_REPO, "tests", "sharded_driver.py")


def _run_driver(check: str):
    env = dict(os.environ)
    # Append (not overwrite) so the child shares the parent's XLA config —
    # anything numerics-affecting must hit both sides of the parity check.
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8"
                        ).strip()
    env["PYTHONPATH"] = os.path.join(_REPO, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, _DRIVER, "--check", check],
        capture_output=True, text=True, timeout=1800, env=env, cwd=_REPO)
    assert proc.returncode == 0, (
        f"sharded_driver --check {check} failed\n"
        f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}")
    assert f"sharded_driver OK: {check}" in proc.stdout, proc.stdout


# ---------------------------------------------------------------------------
# In-process: sharding resolution + config + scheduler
# ---------------------------------------------------------------------------


def test_slot_shards_config_validation():
    with pytest.raises(ValueError, match="slot_shards"):
        ServingConfig(slot_shards=-1)
    with pytest.raises(ValueError, match="divisible"):
        ServingConfig(num_slots=6, slot_shards=4)
    assert ServingConfig(num_slots=8, slot_shards=4).slot_shards == 4
    assert ServingConfig().slot_shards == 0          # auto


def test_pool_slot_axes_host_mesh():
    """Size-1 data axis: always a single shard, never a fallback entry."""
    mesh = make_host_mesh()
    log = []
    axes, n = shd.pool_slot_axes(mesh, shd.DEFAULT_RULES, 4, 0, log)
    assert (axes, n) == ((), 1) and log == []
    axes, n = shd.pool_slot_axes(mesh, shd.DEFAULT_RULES, 4, 1, log)
    assert (axes, n) == ((), 1)


def test_serving_vector_sharding_specs_host_mesh():
    """On a size-1 data axis the control vectors replicate — the vector
    shardings always move in lockstep with the (replicated) pool. Sharded
    specs (P('data') on the slot dim) are asserted on a real 4-device mesh
    by the driver's ``collectives`` check."""
    mesh = make_host_mesh()
    v = shd.serving_vector_sharding(mesh, num_slots=4)
    assert v.spec == P(None)
    buf = shd.serving_vector_sharding(mesh, num_slots=4, leading=1)
    assert buf.spec == P(None, None)
    rep = shd.serving_vector_sharding(mesh, num_slots=4, slot_shards=1)
    assert rep.spec == P(None)


def test_serving_cache_sharding_host_mesh():
    """Host mesh (size-1 data axis): pool leaves replicate, shapes-only
    derivation still holds (no exceptions, full leaf coverage)."""
    import jax
    import jax.numpy as jnp
    mesh = make_host_mesh()
    abstract = {
        "kv": jax.ShapeDtypeStruct((2, 4, 8, 2, 16), jnp.bfloat16),
        "state": jax.ShapeDtypeStruct((2, 4, 2, 24, 16), jnp.float32),
        "lpos": jax.ShapeDtypeStruct((2, 4), jnp.int32),
        "pos": jax.ShapeDtypeStruct((4,), jnp.int32),
        "scalar": jax.ShapeDtypeStruct((), jnp.int32),
    }
    sh = shd.serving_cache_sharding(mesh, shd.DEFAULT_RULES, abstract,
                                    num_slots=4)
    assert sh["kv"].spec == P()
    assert sh["pos"].spec in (P(), P(None))   # replicated either way
    assert sh["scalar"].spec == P()


def test_serving_param_rules_strip_slot_axes():
    """Serving params replicate over the slot (data) axes, keep TP."""
    rules = shd.serving_param_rules(shd.DEFAULT_RULES)
    assert rules.embed is None
    assert rules.batch == "pod"
    assert rules.heads == "model" and rules.vocab == "model"


def test_scheduler_shard_balanced_admission():
    """Admission picks a free slot from the least-loaded shard (static
    contiguous ownership); with one shard it degrades to lowest-free-slot."""
    sched = Scheduler(ServingConfig(num_slots=4, max_len=32), slot_shards=2)
    assert [sched.shard_of(s) for s in range(4)] == [0, 0, 1, 1]
    from repro.serving.engine import Request
    import numpy as np
    req = Request(np.zeros(2, np.int32))
    for rid in range(3):
        sched.submit(rid, req)
    sched.poll_arrivals(0.0)
    rid, _, slot = sched.next_admission()
    assert (rid, slot) == (0, 0)
    sched.active[slot] = object()         # occupy shard 0
    rid, _, slot = sched.next_admission()
    assert (rid, slot) == (1, 2)          # balances onto shard 1
    sched.active[slot] = object()
    rid, _, slot = sched.next_admission()
    assert (rid, slot) == (2, 1)          # both loaded: lowest slot id
    single = Scheduler(ServingConfig(num_slots=4, max_len=32))
    single.submit(9, req)
    single.poll_arrivals(0.0)
    assert single.next_admission()[2] == 0


# ---------------------------------------------------------------------------
# Multi-device (subprocess under forced 8-device CPU)
# ---------------------------------------------------------------------------


@pytest.mark.serving
def test_sharded_stream_parity():
    """mesh=(1,) and mesh=(data=4,) emit byte-identical token streams for a
    fixed mixed-length Poisson trace, at K=8 and K=1, greedy and sampled,
    both cache regimes."""
    _run_driver("parity")


@pytest.mark.serving
def test_sharded_eviction_and_reuse():
    """Shard-local eviction/reuse on a 1-slot-per-shard pool, balanced
    admission across all shards, streams matching the single-shard run."""
    _run_driver("evict_reuse")


@pytest.mark.serving
def test_sharded_divisibility_fallback():
    """num_slots not divisible by the data axis replicates the pool and
    records the drop like the rule-engine fallback; streams stay exact."""
    _run_driver("fallback")


@pytest.mark.serving
def test_sharded_decode_has_no_collectives():
    """The compiled decode macro-step on mesh=(data=4,) contains no
    cross-shard collectives (the §8 hot-loop contract), both regimes."""
    _run_driver("collectives")
