"""Distributed integration: run a REAL sharded train step on 8 placeholder
CPU devices in a subprocess (device count is locked at first jax init, so
this must not run in the main pytest process)."""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data.pipeline import DataConfig, make_batch
from repro.distributed import sharding as shd
from repro.launch.mesh import make_mesh
from repro.models import api
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train.loop import TrainConfig, jit_train_step

assert jax.device_count() == 8, jax.device_count()
mesh = make_mesh((4, 2), ("data", "model"))
cfg = configs.get_smoke_config("slayformer-124m")
opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
tcfg = TrainConfig(microbatches=2, remat=True)
step = jit_train_step(cfg, opt_cfg, tcfg, mesh)

axes = api.param_axes(cfg)
with mesh:
    params = shd.shard_params(mesh, shd.DEFAULT_RULES,
                              api.init_params(cfg, jax.random.PRNGKey(0)),
                              axes)
    opt = adamw_init(params, opt_cfg)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
    losses = []
    ef = jnp.zeros(())
    for s in range(3):
        batch = make_batch(dcfg, s)
        params, opt, ef, m = step(params, opt, ef, batch)
        losses.append(float(m["loss"]))

# Single-device reference: same math, no sharding.
cfg2 = cfg
params2 = api.init_params(cfg2, jax.random.PRNGKey(0))
from repro.train.loop import make_train_step
step2 = jax.jit(make_train_step(cfg2, opt_cfg, TrainConfig(microbatches=2,
                                                           remat=True)))
opt2 = adamw_init(params2, opt_cfg)
ef2 = jnp.zeros(())
losses2 = []
for s in range(3):
    batch = make_batch(dcfg, s)
    params2, opt2, ef2, m2 = step2(params2, opt2, ef2, batch)
    losses2.append(float(m2["loss"]))

print(json.dumps({"sharded": losses, "single": losses2}))
"""


@pytest.mark.slow
def test_sharded_train_step_matches_single_device(tmp_path):
    script = tmp_path / "dist_check.py"
    script.write_text(_SCRIPT)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    data = json.loads(out.stdout.strip().splitlines()[-1])
    a, b = data["sharded"], data["single"]
    assert all(abs(x - y) / max(abs(y), 1e-6) < 0.05
               for x, y in zip(a, b)), (a, b)
    assert a[-1] < a[0]     # learning


@pytest.mark.slow
def test_elastic_checkpoint_resharding(tmp_path):
    """Save on a (4,2) mesh, restore onto (2,4) — checkpoints are
    mesh-agnostic logical tensors (DESIGN.md §5)."""
    script = tmp_path / "elastic.py"
    script.write_text(r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.checkpoint import save_checkpoint, restore_latest
from repro.distributed import sharding as shd
from repro.launch.mesh import make_mesh
from repro.models import api

cfg = configs.get_smoke_config("slayformer-124m")
axes = api.param_axes(cfg)
mesh_a = make_mesh((4, 2), ("data", "model"))
params = shd.shard_params(mesh_a, shd.DEFAULT_RULES,
                          api.init_params(cfg, jax.random.PRNGKey(0)), axes)
ckdir = os.environ["CKDIR"]
save_checkpoint(ckdir, 7, {"params": params})

mesh_b = make_mesh((2, 4), ("data", "model"))
abstract = {"params": jax.eval_shape(
    lambda: api.init_params(cfg, jax.random.PRNGKey(0)))}
sh = {"params": shd.logical_to_sharding(mesh_b, shd.DEFAULT_RULES,
                                        abstract["params"], axes)}
restored, step = restore_latest(ckdir, abstract, shardings=sh)
assert step == 7
a = jax.device_get(jax.tree.leaves(params)[0])
b = jax.device_get(jax.tree.leaves(restored["params"])[0])
np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("ELASTIC_OK")
""")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["CKDIR"] = str(tmp_path / "ck")
    out = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "ELASTIC_OK" in out.stdout
