"""Known-bad jitlint fixture: a host sync hidden one call deep inside a
``lax.scan`` body. The linter must follow the call graph from the scan
root through ``_body`` into ``_leaf`` and flag the ``.item()`` there —
exactly one SYNC001. (Excluded from real scans: tests/fixtures/ is in
``jitlint.Options.exclude_parts``.)"""
import jax
import jax.numpy as jnp


def _leaf(x):
    return x.item()            # SYNC001: host sync in a jit region


def _body(carry, x):
    return carry + _leaf(x), None


def run(xs):
    return jax.lax.scan(_body, jnp.float32(0), xs)
