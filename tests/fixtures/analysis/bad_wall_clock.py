"""Known-bad jitlint fixture: a wall-clock *call* in a body (CLK001 when
the test points ``Options.clock_paths`` at this directory). The default
parameter value is the allowed injectable-clock surface — it is a
reference, not a call, and must NOT be flagged."""
import time


def allowed(clock=time.perf_counter):  # reference: the injectable surface
    return clock()


def stamp():
    return time.time()                 # CLK001: bypasses the injection
