"""Known-bad jitlint fixture: two fold_in substream-tag constants with
the same value in one package — the (seed, rid, idx) substreams would
coincide (DESIGN.md §13). Exactly one TAG001 on the second constant."""

SPEC_TAG_ALPHA = 7
SPEC_TAG_BETA = 7                      # TAG001: collides with ALPHA
