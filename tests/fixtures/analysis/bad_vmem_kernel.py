"""Known-bad VMEM fixture: one pallas_call whose BlockSpecs pull the
whole (4096, 4096) fp32 operand into VMEM per grid step — 64 MiB in +
64 MiB out (× 2 for double buffering), far over the ~16 MiB §3 budget.
Probed by the analyzer test through ``vmem.record_pallas_calls``; never
executed."""
import jax
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def oversized_copy(x):
    n, d = x.shape
    return pl.pallas_call(
        _kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((n, d), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((n, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=True,
    )(x)
