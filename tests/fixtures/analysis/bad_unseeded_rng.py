"""Known-bad jitlint fixture: a fresh unseeded generator — exactly one
RNG001 (the follow-up draw on the generator object is not itself a
violation)."""
import numpy as np


def draw():
    rng = np.random.default_rng()      # RNG001: unseeded
    return rng.normal()
