"""Component-level model tests: MoE dispatch vs dense reference, SSD
(Mamba2) decode==forward consistency, Whisper enc-dec decode, RoPE/rmsnorm
numerics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import api
from repro.models.layers import (moe, moe_specs, realize, rmsnorm,
                                 mlp, mlp_specs)


def test_moe_matches_dense_reference(key):
    """With generous capacity, top-k MoE output must equal the explicit
    per-token expert mixture."""
    d, ff, E, k = 16, 32, 4, 2
    specs = moe_specs(d, ff, E)
    params = realize(specs, key, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, d)) * 0.5
    y, aux = moe(params, x, E, k, capacity_factor=8.0)  # no drops

    # Dense reference.
    logits = jnp.einsum("bsd,de->bse", x, params["router"])
    probs = jax.nn.softmax(logits, -1)
    vals, idx = jax.lax.top_k(probs, k)
    vals = vals / vals.sum(-1, keepdims=True)
    ref = jnp.zeros_like(x)
    for e in range(E):
        h = jax.nn.silu(x @ params["gate"][e]) * (x @ params["up"][e])
        oe = h @ params["down"][e]
        w = jnp.where(idx == e, vals, 0.0).sum(-1)   # (B,S)
        ref += w[..., None] * oe
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=2e-4,
                               rtol=1e-3)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens(key):
    """With capacity_factor << 1 most tokens drop to zero output — the
    capacity mechanism must bound per-expert work."""
    d, ff, E = 8, 16, 4
    params = realize(moe_specs(d, ff, E), key, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, d))
    y_full, _ = moe(params, x, E, 2, capacity_factor=8.0)
    y_tight, _ = moe(params, x, E, 2, capacity_factor=0.1)
    assert float(jnp.sum(jnp.abs(y_tight))) < float(jnp.sum(jnp.abs(y_full)))


def test_ssd_decode_matches_forward(key):
    """Mamba2 SSD: step-by-step decode must reproduce the chunked forward
    (the SSD duality — same recurrence, different schedule)."""
    from repro.models import ssm
    d_model, d_state, expand, hd, ng, cw = 16, 8, 2, 8, 1, 4
    specs = ssm.ssd_specs(d_model, d_state, expand, hd, ng, cw)
    params = realize(specs, key, jnp.float32)
    B, L = 1, 12
    x = jax.random.normal(jax.random.PRNGKey(1), (B, L, d_model)) * 0.3
    y_full = ssm.ssd_forward(params, x, d_state=d_state, expand=expand,
                             head_dim=hd, ngroups=ng, conv_width=cw,
                             chunk_size=4)
    state = ssm.ssd_init_state((B,), d_model, d_state, expand, hd, ng, cw)
    outs = []
    for t in range(L):
        y_t, state = ssm.ssd_decode_step(params, x[:, t], state,
                                         d_state=d_state, expand=expand,
                                         head_dim=hd, ngroups=ng,
                                         conv_width=cw)
        outs.append(y_t)
    y_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full),
                               atol=2e-4, rtol=1e-3)


def test_whisper_prefill_decode(key):
    cfg = configs.get_smoke_config("whisper-small")
    params = api.init_params(cfg, key)
    B, L = 2, 8
    frames = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model),
                               cfg.activation_dtype)
    toks = jax.random.randint(key, (B, L), 0, cfg.vocab_size)
    batch = {"tokens": toks, "frame_embeds": frames}
    logits, cache = api.prefill(params, cfg, batch)
    assert logits.shape == (B, 1, cfg.vocab_size)
    nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    for _ in range(2):
        logits, cache = api.decode_step(params, cfg, cache, nxt)
        assert np.all(np.isfinite(np.asarray(logits, np.float32)))
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    assert np.all(np.asarray(cache.pos) == L + 2)   # per-slot positions


def test_whisper_decode_matches_forward(key):
    """Teacher-forced whisper decode == full forward logits."""
    cfg = configs.get_smoke_config("whisper-small")
    params = api.init_params(cfg, key)
    B, L = 1, 10
    frames = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model),
                               cfg.activation_dtype)
    toks = jax.random.randint(key, (B, L), 0, cfg.vocab_size)
    full, _ = api.forward(params, cfg, {"tokens": toks,
                                        "frame_embeds": frames})
    _, cache = api.prefill(params, cfg, {"tokens": toks[:, :5],
                                         "frame_embeds": frames})
    errs = []
    for t in range(5, L):
        lg, cache = api.decode_step(params, cfg, cache, toks[:, t:t + 1])
        errs.append(np.max(np.abs(np.asarray(lg[:, 0], np.float32)
                                  - np.asarray(full[:, t], np.float32))))
    assert max(errs) < 0.2


def test_rmsnorm_scale_init_is_identityish(key):
    x = jax.random.normal(key, (4, 16))
    y = rmsnorm(jnp.zeros((16,)), x)   # scale param 0 -> gain 1
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1) / np.sqrt(16), 1.0, atol=0.01)


def test_gated_mlp_matches_manual(key):
    d, ff = 8, 16
    params = realize(mlp_specs(d, ff, True), key, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, d))
    y = mlp(params, x, gated=True)
    want = (jax.nn.silu(x @ params["gate"]) * (x @ params["up"])) \
        @ params["down"]
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-6)


def test_vision_prefix_changes_text_logits(key):
    """internvl2 stub: patch embeddings must influence the text tail (the
    prefix participates in attention)."""
    cfg = configs.get_smoke_config("internvl2-76b")
    params = api.init_params(cfg, key)
    toks = jax.random.randint(key, (1, 8), 0, cfg.vocab_size)
    pe1 = jnp.zeros((1, cfg.num_patches, cfg.d_model), cfg.activation_dtype)
    pe2 = jax.random.normal(key, pe1.shape, cfg.activation_dtype)
    l1, _ = api.forward(params, cfg, {"tokens": toks, "patch_embeds": pe1})
    l2, _ = api.forward(params, cfg, {"tokens": toks, "patch_embeds": pe2})
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-3
