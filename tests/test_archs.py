"""Per-architecture smoke tests: REDUCED config of each assigned family,
one forward + one train step on CPU, asserting shapes and no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import api
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train.loop import TrainConfig, make_train_step


def _batch(cfg, key, B=2, L=32):
    ks = jax.random.split(key, 4)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, L), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (B, L), 0, cfg.vocab_size),
    }
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jax.random.normal(
            ks[2], (B, cfg.num_patches, cfg.d_model), cfg.activation_dtype)
    if cfg.frontend == "audio":
        batch["frame_embeds"] = jax.random.normal(
            ks[3], (B, cfg.enc_seq, cfg.d_model), cfg.activation_dtype)
    return batch


@pytest.mark.parametrize("arch", configs.ALL_ARCHS)
def test_smoke_forward(arch, key):
    cfg = configs.get_smoke_config(arch)
    params = api.init_params(cfg, key)
    batch = _batch(cfg, key)
    logits, aux = api.forward(params, cfg, batch)
    L_exp = batch["tokens"].shape[1] + (cfg.num_patches
                                        if cfg.frontend == "vision" else 0)
    assert logits.shape == (2, L_exp, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", configs.ALL_ARCHS)
def test_smoke_train_step(arch, key):
    cfg = configs.get_smoke_config(arch)
    params = api.init_params(cfg, key)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    step = make_train_step(cfg, opt_cfg,
                           TrainConfig(microbatches=1, remat=False))
    opt = adamw_init(params, opt_cfg)
    batch = _batch(cfg, key)
    p2, opt2, _, metrics = step(params, opt, jnp.zeros(()), batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # Params actually moved.
    moved = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.sum(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))), params, p2))
    assert moved > 0


@pytest.mark.parametrize("arch", ["phi4-mini-3.8b", "gemma2-27b",
                                  "hymba-1.5b", "mamba2-780m",
                                  "phi3.5-moe-42b-a6.6b"])
def test_smoke_prefill_decode(arch, key):
    """Prefill a prompt then take 3 decode steps; logits finite, cache pos
    advances."""
    cfg = configs.get_smoke_config(arch)
    params = api.init_params(cfg, key)
    B, L = 2, 16
    batch = _batch(cfg, key, B=B, L=L)
    batch.pop("labels")
    logits, cache = api.prefill(params, cfg, batch)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    for _ in range(3):
        logits, cache = api.decode_step(params, cfg, cache, tok)
        assert np.all(np.isfinite(np.asarray(logits, np.float32)))
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    assert np.all(np.asarray(cache.pos) == L + 3)   # per-slot positions


def test_full_configs_match_assignment():
    """The FULL configs must carry the exact published hyperparameters."""
    expect = {
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "gemma2-27b": (46, 4608, 32, 16, 36864, 256000),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
    }
    for arch, (nl, d, h, kv, ff, v) in expect.items():
        cfg = configs.get_config(arch)
        assert cfg.num_layers == nl, arch
        assert cfg.d_model == d, arch
        assert cfg.num_heads == h, arch
        assert cfg.num_kv_heads == kv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab_size == v, arch
    w = configs.get_config("whisper-small")
    assert (w.num_layers, w.d_model, w.num_heads, w.d_ff,
            w.vocab_size) == (12, 768, 12, 3072, 51865)
    m = configs.get_config("mamba2-780m")
    assert (m.num_layers, m.d_model, m.vocab_size,
            m.ssm_state) == (48, 1536, 50280, 128)
    moe = configs.get_config("phi3.5-moe-42b-a6.6b")
    assert (moe.moe_experts, moe.moe_top_k) == (16, 2)
    g = configs.get_config("grok-1-314b")
    assert (g.moe_experts, g.moe_top_k) == (8, 2)


def test_moe_param_counts_plausible():
    """Sanity: grok-1 total ~314B, phi3.5-moe ~42B total / ~6.6B active."""
    g = configs.get_config("grok-1-314b")
    assert 2.4e11 < g.param_count_dense < 4.2e11
    p = configs.get_config("phi3.5-moe-42b-a6.6b")
    assert 3.2e10 < p.param_count_dense < 5.5e10
    assert 4e9 < p.active_param_count < 9e9


def test_softmax_variant_selectable(key):
    """Every arch accepts attn_kind overrides (paper baselines)."""
    cfg = configs.get_smoke_config("phi4-mini-3.8b", attn_kind="softmax")
    params = api.init_params(cfg, key)
    batch = _batch(cfg, key)
    logits, _ = api.forward(params, cfg, batch)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("kind", ["yat", "yat_spherical", "favor",
                                  "cosformer", "elu1"])
def test_attention_backends_swap(kind, key):
    cfg = configs.get_smoke_config("slayformer-124m", attn_kind=kind)
    params = api.init_params(cfg, key)
    batch = _batch(cfg, key)
    logits, _ = api.forward(params, cfg, batch)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


def test_remat_matches_no_remat(key):
    cfg = configs.get_smoke_config("slayformer-124m")
    params = api.init_params(cfg, key)
    batch = _batch(cfg, key)
    l1, _ = api.loss_fn(params, cfg, batch, remat=False)
    l2, _ = api.loss_fn(params, cfg, batch, remat=True)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_gemma2_local_global_alternation(key):
    cfg = configs.get_smoke_config("gemma2-27b")
    assert cfg.local_global_period and cfg.local_window
    from repro.models.transformer import _layer_kinds
    kinds = _layer_kinds(cfg)
    assert kinds.sum() > 0            # some local layers
    assert (kinds == 0).sum() > 0     # some global layers
