"""Paged slot memory (DESIGN.md §11): PagePool free-list invariants under
randomized admit/evict churn, shard-block confinement, the shard-explicit
device gather/scatter vs a dense numpy reference, whole-page install /
zero / NaN-attribution ops, and engine-level paged-vs-unpaged stream
byte-identity (including under chaos fault injection)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ServingConfig
from repro.launch.mesh import make_host_mesh
from repro.models import api
from repro.serving import pages
from repro.serving.engine import ContinuousServingEngine, Request
from repro.serving.faults import FaultInjector


# ---------------------------------------------------------------------------
# PagePool (host allocator)
# ---------------------------------------------------------------------------


def test_pool_alloc_free_roundtrip():
    pool = pages.PagePool(num_slots=4, num_pages=16, page_size=8,
                          pages_per_slot=4)
    assert pool.pages_for(1) == 1 and pool.pages_for(8) == 1
    assert pool.pages_for(9) == 2 and pool.pages_for(64) == 4  # capped
    got = pool.alloc(2, need_rows=17)            # ceil(17/8) = 3 pages
    assert len(got) == 3 and pool.pages_in_use() == 3
    assert pool.slot_pages(2) == got
    assert pool.pages_peak == 3
    pool.check()
    assert pool.free_slot(2) == 3
    assert pool.pages_in_use() == 0 and pool.slot_pages(2) == []
    assert pool.pages_peak == 3                  # high-water survives free
    pool.check()


def test_pool_alloc_errors():
    pool = pages.PagePool(num_slots=2, num_pages=4, page_size=8,
                          pages_per_slot=4)
    pool.alloc(0, need_rows=24)                  # 3 of 4 pages
    with pytest.raises(RuntimeError, match="already holds pages"):
        pool.alloc(0, need_rows=8)
    assert not pool.can_alloc(1, need_rows=16)   # 2 needed, 1 free
    with pytest.raises(RuntimeError, match="free pages"):
        pool.alloc(1, need_rows=16)
    assert pool.can_alloc(1, need_rows=8)
    pool.alloc(1, need_rows=8)
    pool.check()


def test_pool_geometry_validation():
    with pytest.raises(ValueError, match="page_size"):
        pages.PagePool(2, 4, 0, 2)
    with pytest.raises(ValueError, match="num_pages"):
        pages.PagePool(4, 6, 8, 2, shards=4)
    with pytest.raises(ValueError, match="num_slots"):
        pages.PagePool(6, 8, 8, 2, shards=4)


def test_pool_shard_block_confinement():
    """A slot only ever receives pages from its own shard's contiguous
    block — the invariant the collective-free device indexing relies on."""
    pool = pages.PagePool(num_slots=4, num_pages=8, page_size=4,
                          pages_per_slot=2, shards=2)
    for slot in range(4):
        got = pool.alloc(slot, need_rows=8)
        d = pool.shard_of(slot)
        lo, hi = d * 4, (d + 1) * 4
        assert all(lo <= p < hi for p in got), (slot, got)
    pool.check()
    # Shard 0 exhausted: its slots can't borrow from shard 1's free block.
    pool.free_slot(2)
    assert pool.free_in_shard(1) == 2 and pool.free_in_shard(0) == 0
    assert not pool.can_alloc(1, need_rows=4)    # slot 1 lives in shard 0
    assert pool.can_alloc(2, need_rows=4)        # shard 1 has room
    pool.check()


@pytest.mark.parametrize("shards", [1, 2])
def test_pool_churn_property(rng, shards):
    """Randomized admit/evict churn: the allocator never double-assigns,
    never leaks, and its mirrors stay consistent (check() audits after
    every op); draining every slot returns the pool to all-free."""
    pool = pages.PagePool(num_slots=8, num_pages=32, page_size=4,
                          pages_per_slot=4, shards=shards)
    held: set[int] = set()
    peak = 0
    for _ in range(300):
        slot = int(rng.integers(8))
        if slot in held:
            pool.free_slot(slot)
            held.discard(slot)
        else:
            need = int(rng.integers(1, 17))
            if pool.can_alloc(slot, need):
                got = pool.alloc(slot, need)
                assert len(got) == pool.pages_for(need)
                held.add(slot)
        peak = max(peak, pool.pages_in_use())
        assert pool.pages_in_use() == sum(
            len(pool.slot_pages(s)) for s in held)
        pool.check()
    for slot in sorted(held):
        pool.free_slot(slot)
    assert pool.pages_in_use() == 0
    assert pool.pages_peak == peak
    pool.check()


# ---------------------------------------------------------------------------
# Device ops vs dense reference
# ---------------------------------------------------------------------------


def _dense_ref(leaf, table):
    """Numpy oracle for gather_ring: table walk, unmapped pages zero."""
    P, page = leaf.shape[:2]
    S, Lp = table.shape
    out = np.zeros((S, Lp * page) + leaf.shape[2:], leaf.dtype)
    for s in range(S):
        for j in range(Lp):
            p = int(table[s, j])
            if p >= 0:
                out[s, j * page:(j + 1) * page] = leaf[p]
    return out


def _churned_pool(rng, shards):
    pool = pages.PagePool(num_slots=4, num_pages=8, page_size=4,
                          pages_per_slot=2, shards=shards)
    for slot in (0, 1, 3):                       # slot 2 left unmapped
        pool.alloc(slot, need_rows=int(rng.integers(1, 9)))
    pool.free_slot(1)                            # churn: a freed slot too
    pool.check()
    return pool


@pytest.mark.parametrize("shards", [1, 2])
def test_gather_matches_dense_reference(rng, shards):
    pool = _churned_pool(rng, shards)
    leaf = rng.standard_normal((8, 4, 3)).astype(np.float32)
    state = pool.device_vectors()
    got = np.asarray(pages.gather_ring(jnp.asarray(leaf), state))
    np.testing.assert_array_equal(got, _dense_ref(leaf, pool.table))


@pytest.mark.parametrize("shards", [1, 2])
def test_scatter_gather_roundtrip(rng, shards):
    """scatter then gather reproduces the dense rows of owned pages; rows
    of unmapped logical pages read zero; free pages keep their old bytes
    (the gather mask, not the scatter, is what hides them)."""
    pool = _churned_pool(rng, shards)
    state = pool.device_vectors()
    leaf0 = rng.standard_normal((8, 4, 3)).astype(np.float32)
    dense = rng.standard_normal((4, 8, 3)).astype(np.float32)
    leaf1 = pages.scatter_ring(jnp.asarray(leaf0), jnp.asarray(dense),
                               state)
    back = np.asarray(pages.gather_ring(leaf1, state))
    want = dense.copy()
    for s in range(4):
        for j in range(2):
            if pool.table[s, j] < 0:
                want[s, j * 4:(j + 1) * 4] = 0.0
    np.testing.assert_array_equal(back, want)
    leaf1 = np.asarray(leaf1)
    for p in range(8):
        if pool.owner_slot[p] < 0:               # free page: untouched
            np.testing.assert_array_equal(leaf1[p], leaf0[p])


def test_write_slot_pages_overwrites_owner_only(rng):
    pool = _churned_pool(rng, 1)
    state = pool.device_vectors()
    leaf = jnp.asarray(rng.standard_normal((2, 8, 4, 3)).astype(np.float32))
    src = rng.standard_normal((2, 1, 8, 3)).astype(np.float32)
    out = np.asarray(pages.write_slot_pages(leaf, jnp.asarray(src),
                                            jnp.int32(0), state))
    for p in range(8):
        if pool.owner_slot[p] == 0:
            j = int(pool.owner_lp[p])
            np.testing.assert_array_equal(
                out[:, p], src[:, 0, j * 4:(j + 1) * 4])
        else:                                    # other owners + free pages
            np.testing.assert_array_equal(out[:, p],
                                          np.asarray(leaf)[:, p])


def test_pages_finite_attributes_nan_to_owner_only(rng):
    """A NaN page counts against its owning slot alone; a stale NaN in a
    *freed* page (quarantined owner) counts against nobody; zeroing the
    owned pages clears the flag."""
    pool = _churned_pool(rng, 1)                 # slots 0,3 own; 1,2 don't
    state = pool.device_vectors()
    leaf = jnp.zeros((2, 8, 4, 3), jnp.float32)  # (layers, P, page, tail)
    bad = pages.corrupt_slot_pages(leaf, jnp.int32(3), state)
    ok = np.asarray(pages.pages_finite([bad], state, num_slots=4))
    assert ok.tolist() == [True, True, True, False]
    # Free slot 3 host-side: the NaN bytes persist in the (now free) pages
    # but no live slot is blamed for them.
    pool.free_slot(3)
    st2 = pool.device_vectors()
    ok2 = np.asarray(pages.pages_finite([bad], st2, num_slots=4))
    assert ok2.tolist() == [True, True, True, True]
    # The §11 reset contract: zeroing via the OLD mapping scrubs the NaNs
    # before the pages can be re-issued.
    clean = pages.write_zero_pages(bad, jnp.int32(3), state)
    assert bool(jnp.all(jnp.isfinite(clean)))


# ---------------------------------------------------------------------------
# Engine level: byte identity + leak-freedom under churn
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def paged_setup():
    cfg = configs.get_smoke_config("slayformer-124m", attn_kind="softmax")
    assert api.supports_paging(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params, make_host_mesh()


def _trace(cfg, n, seed, max_new=8):
    rng = np.random.default_rng(seed)
    return [Request(rng.integers(3, cfg.vocab_size,
                                 size=int(rng.integers(3, 12)))
                    .astype(np.int32),
                    max_new_tokens=max_new, arrival_time=float(i))
            for i in range(n)]


def _run(cfg, params, mesh, reqs, *, page_size=0, injector=None, **kw):
    eng = ContinuousServingEngine(
        cfg, params, mesh, fault_injector=injector,
        serving=ServingConfig(num_slots=2, max_len=64, prefill_chunk=4,
                              macro_ticks=4, page_size=page_size, **kw))
    outs, summary = eng.run([Request(r.prompt, max_new_tokens=r.max_new_tokens,
                                     arrival_time=r.arrival_time)
                             for r in reqs])
    return eng, outs, summary


@pytest.mark.serving
def test_engine_paged_streams_byte_identical(paged_setup):
    """Paged KV ring == unpaged: token streams byte-identical, page math
    visible in the summary, zero pages leaked after drain."""
    cfg, params, mesh = paged_setup
    reqs = _trace(cfg, 5, seed=3)
    _, o1, s1 = _run(cfg, params, mesh, reqs)
    e2, o2, s2 = _run(cfg, params, mesh, reqs, page_size=8)
    assert s2["requests_completed"] == len(reqs) == s1["requests_completed"]
    for rid in o1:
        np.testing.assert_array_equal(o1[rid], o2[rid])
    assert s1.get("num_pages", 0) == 0           # unpaged run: no pool
    assert s2["num_pages"] == 2 * (64 // 8)
    assert s2["pages_peak"] >= 1
    assert s2["final_pages_in_use"] == 0
    e2.page_pool.check()


@pytest.mark.serving
def test_engine_short_requests_reserve_fewer_pages(paged_setup):
    """The memory-sharing win: a short request pins ceil(need/page) pages,
    not the whole slot ring."""
    cfg, params, mesh = paged_setup
    reqs = [Request(np.int32([5, 6, 7]), max_new_tokens=4,
                    arrival_time=0.0)]
    e, _, s = _run(cfg, params, mesh, reqs, page_size=8)
    assert s["pages_peak"] == 1                  # 3 + 4 rows -> 1 of 8 pages
    assert s["final_pages_in_use"] == 0
    e.page_pool.check()


@pytest.mark.serving
@pytest.mark.chaos
def test_engine_paged_no_leaks_under_chaos(paged_setup):
    """Fault-injection churn (NaN quarantine + cancels) over the paged
    pool: every exit path returns its pages, the allocator audit passes,
    and retried streams still match the fault-free paged run."""
    cfg, params, mesh = paged_setup
    reqs = _trace(cfg, 6, seed=5, max_new=6)
    _, clean, _ = _run(cfg, params, mesh, reqs, page_size=8)
    inj = FaultInjector(seed=2, nan_every=5, cancel_every=9)
    e, outs, s = _run(cfg, params, mesh, reqs, page_size=8, injector=inj,
                      fault_retries=3)
    assert s["requests_terminated"] == len(reqs)
    assert s["faults_detected"] >= 1             # the injector actually fired
    assert s["final_pages_in_use"] == 0
    assert s["final_occupancy"] == 0
    e.page_pool.check()
    for rid, toks in outs.items():
        reason = e.metrics.per_request[rid].finish_reason
        if reason in ("eos", "length"):          # survivors: exact replay
            np.testing.assert_array_equal(toks, clean[rid])
