"""Smoke tests for the examples/serve.py CLI: the demo must keep working
end-to-end as engine features land, since it's the documented entry point
for the DESIGN.md walkthroughs (§8 sharding, §11 paging/prefix cache,
§13 speculative decoding). Each case runs the script in a fresh process
and asserts exit 0 plus the feature's summary lines."""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.serving

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SERVE = os.path.join(_ROOT, "examples", "serve.py")


def _run(*args, env_extra=None, check=True):
    env = dict(os.environ, PYTHONPATH=os.path.join(_ROOT, "src"))
    env.update(env_extra or {})
    proc = subprocess.run(
        [sys.executable, _SERVE, "--max-new", "6", "--batch", "3", *args],
        capture_output=True, text=True, timeout=600, env=env, cwd=_ROOT)
    if check:
        assert proc.returncode == 0, proc.stdout + proc.stderr
    return proc


def test_cli_sharded_paged_prefix_cache():
    """--slot-shards/--page-size/--prefix-cache together (fresh process so
    the forced 4-device CPU runtime doesn't leak into other tests)."""
    proc = _run("--attn-kind", "softmax", "--slot-shards", "4",
                "--slots", "4", "--page-size", "16", "--prefix-cache", "8",
                env_extra={
                    "XLA_FLAGS": "--xla_force_host_platform_device_count=4"})
    assert "finish reasons:" in proc.stdout
    assert "4 shard(s)" in proc.stdout
    assert "pages:" in proc.stdout and "prefix cache:" in proc.stdout


def test_cli_speculative():
    proc = _run("--speculative", "--spec-gamma", "2")
    assert "finish reasons:" in proc.stdout
    assert "speculative: gamma=2" in proc.stdout
    assert "tok/dispatch" in proc.stdout


def test_cli_speculative_rejects_prefix_cache():
    proc = _run("--speculative", "--prefix-cache", "8", check=False)
    assert proc.returncode != 0
    assert "mutually exclusive" in proc.stderr
