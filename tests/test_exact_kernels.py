"""Exact quadratic kernels (Yat / spherical Yat / softmax) — paper Eq. 1/5,
Props. 1/3, softcap + sliding window."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kernels
from repro.core.features import normalize


def _qkv(key, B=1, L=8, H=2, d=16):
    ks = jax.random.split(key, 3)
    return (jax.random.normal(ks[0], (B, L, H, d)),
            jax.random.normal(ks[1], (B, L, H, d)),
            jax.random.normal(ks[2], (B, L, H, d)))


def test_yat_equals_spherical_on_unit_inputs(key):
    """On the sphere, ||q-k||^2 = 2-2x, so E == E_sph with the same eps."""
    q, k, v = _qkv(key)
    qn, kn = normalize(q), normalize(k)
    s1 = kernels.yat_scores(qn, kn, eps=1e-2)
    s2 = kernels.spherical_yat_scores(qn, kn, eps=1e-2)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4,
                               rtol=1e-3)


def test_spherical_scores_bounded(key):
    q, k, _ = _qkv(key, L=32)
    s = np.asarray(kernels.spherical_yat_scores(q, k, eps=1e-2))
    assert np.all(s >= 0)
    assert np.all(s <= 1.0 / 1e-2 + 1e-6)


def test_kernel_normalized_attention_is_convex_combo(key):
    q, k, v = _qkv(key, L=12)
    scores = kernels.spherical_yat_scores(q, k)
    y = np.asarray(kernels.kernel_normalized_attention(scores, v, causal=True))
    vmin, vmax = np.asarray(v).min(), np.asarray(v).max()
    assert np.all(y >= vmin - 1e-3) and np.all(y <= vmax + 1e-3)


def test_softmax_attention_rows_sum_to_one(key):
    q, k, v = _qkv(key, L=6)
    ones = jnp.ones_like(v)
    y = kernels.softmax_attention(q, k, ones, causal=True)
    np.testing.assert_allclose(np.asarray(y), 1.0, atol=1e-5)


def test_softmax_causality(key):
    """Changing a future key/value must not affect earlier outputs."""
    q, k, v = _qkv(key, L=8)
    y1 = kernels.softmax_attention(q, k, v, causal=True)
    k2 = k.at[:, -1].set(jax.random.normal(jax.random.PRNGKey(9), k[:, -1].shape))
    v2 = v.at[:, -1].set(0.0)
    y2 = kernels.softmax_attention(q, k2, v2, causal=True)
    np.testing.assert_allclose(np.asarray(y1[:, :-1]), np.asarray(y2[:, :-1]),
                               atol=1e-6)


def test_yat_attention_causality(key):
    q, k, v = _qkv(key, L=8)
    y1 = kernels.yat_attention(q, k, v, causal=True, spherical=True)
    v2 = v.at[:, -1].set(123.0)
    y2 = kernels.yat_attention(q, k, v2, causal=True, spherical=True)
    np.testing.assert_allclose(np.asarray(y1[:, :-1]), np.asarray(y2[:, :-1]),
                               atol=1e-6)


def test_sliding_window_masks_distant_tokens(key):
    """A window-w attention must ignore keys further than w-1 back."""
    q, k, v = _qkv(key, L=10)
    w = 3
    y = kernels.softmax_attention(q, k, v, causal=True, window=w)
    # Recompute with the distant past zeroed out: same result.
    L = 10
    vmod = v
    for t in range(L):
        for s in range(0, max(0, t - w + 1)):
            pass  # masked inside the op; compare against explicit mask below
    logits = jnp.einsum("...qhd,...khd->...hqk", q, k) / jnp.sqrt(16.0)
    qpos = jnp.arange(L)[:, None]
    kpos = jnp.arange(L)[None, :]
    mask = (qpos >= kpos) & (qpos - kpos < w)
    logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    want = jnp.einsum("...hqk,...khd->...qhd", probs, vmod)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-5)


def test_logit_softcap_bounds_logits(key):
    """Softcap keeps |logit| <= cap — outputs must differ from uncapped when
    logits are large, and equal a direct tanh-capped computation."""
    q, k, v = _qkv(key, L=6)
    q = q * 10
    cap = 5.0
    y = kernels.softmax_attention(q, k, v, causal=False, logit_softcap=cap)
    logits = jnp.einsum("...qhd,...khd->...hqk", q, k) / jnp.sqrt(16.0)
    logits = cap * jnp.tanh(logits / cap)
    probs = jax.nn.softmax(logits, axis=-1)
    want = jnp.einsum("...hqk,...khd->...qhd", probs, v)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-5)


def test_banded_window_matches_masked_reference(key):
    """Banded O(L·2w) sliding-window == full masked softmax attention."""
    q, k, v = _qkv(key, L=24)
    for w in (4, 8, 12):
        got = kernels.windowed_softmax_attention(q, k, v, window=w)
        logits = jnp.einsum("...qhd,...khd->...hqk", q, k) / jnp.sqrt(16.0)
        qpos = jnp.arange(24)[:, None]
        kpos = jnp.arange(24)[None, :]
        mask = (qpos >= kpos) & (qpos - kpos < w)
        logits = jnp.where(mask, logits, -jnp.inf)
        probs = jax.nn.softmax(logits, axis=-1)
        want = jnp.einsum("...hqk,...khd->...qhd", probs, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=1e-4)


def test_banded_window_with_softcap(key):
    q, k, v = _qkv(key, L=16)
    got = kernels.softmax_attention(q, k, v, causal=True, window=4,
                                    logit_softcap=5.0)
    assert np.all(np.isfinite(np.asarray(got)))
