"""HLO cost-model parser: trip counts, dot flops, collective wire factors.
Pure text-level tests (no devices) + one end-to-end jit cross-check."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo_cost

SYNTH = """
HloModule test

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %y = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%y), replica_groups=[2,4]<=[8], to_apply=%add
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]{1,0}) tuple(%i2, %ar)
}

%cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,16]{1,0}) tuple(%z, %a)
  %w = (s32[], f32[8,16]{1,0}) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%w), index=1
}
"""


def test_while_trip_count_scales_flops():
    t = hlo_cost.analyze(SYNTH)
    # dot: 2*8*16*16 = 4096 flops, x10 trips.
    assert t.flops == 10 * 2 * 8 * 16 * 16


def test_all_reduce_wire_factor():
    t = hlo_cost.analyze(SYNTH)
    # group size 4 -> 2*(3/4)*8*16*4B = 3072 bytes, x10.
    np.testing.assert_allclose(t.coll_wire_bytes, 10 * 2 * 0.75 * 8 * 16 * 4)
    assert set(t.coll_by_kind) == {"all-reduce"}


def test_group_size_parsing():
    assert hlo_cost._group_size("replica_groups=[2,4]<=[8]") == 4
    assert hlo_cost._group_size("replica_groups=[16,32]<=[2,16,16]T(1,0,2)") \
        == 32
    assert hlo_cost._group_size("replica_groups={{0,1,2,3},{4,5,6,7}}") == 4
    assert hlo_cost._group_size("no groups here", default=7) == 7


def test_shape_bytes():
    assert hlo_cost._shape_bytes("f32[8,16]{1,0}") == 8 * 16 * 4
    assert hlo_cost._shape_bytes("bf16[3]") == 6
    assert hlo_cost._shape_bytes("(f32[2], bf16[4]{0})") == 8 + 8
    assert hlo_cost._shape_bytes("pred[]") == 1


def test_end_to_end_scan_flop_count():
    """Cross-check the parser against a jit'd scan with known FLOPs on the
    real (single-device) backend."""
    M, K, N, T = 8, 32, 64, 7
    w = jnp.zeros((K, N))
    x = jnp.zeros((M, K))

    def f(w, x):
        def body(c, _):
            return jnp.tanh(c @ w @ w.T), ()
        y, _ = jax.lax.scan(body, x, None, length=T)
        return y

    comp = jax.jit(f).lower(w, x).compile()
    t = hlo_cost.analyze(comp.as_text())
    want = T * (2 * M * N * K + 2 * M * K * N)
    np.testing.assert_allclose(t.flops, want, rtol=0.01)


def test_memory_counts_dot_traffic():
    t = hlo_cost.analyze(SYNTH)
    # per iter: dot reads x(512B)+w(1024B), writes 512B; all-reduce in+out.
    per_iter = (512 + 1024 + 512) + (512 + 512)
    assert t.hbm_bytes == 10 * per_iter
