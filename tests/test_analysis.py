"""Static contract analyzer (DESIGN.md §14): the analyzer must catch
every known-bad fixture with its stable rule id, stay silent on the live
repo, and the engine's ``donate_argnums`` contracts must hold end to end
through the HLO parser (the donation tier-1 test — a silently dropped
donation doubles pool HBM with no error)."""
import importlib.util
import os

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.analysis import findings as flib
from repro.analysis import hlo as hlo_lib
from repro.analysis import jitlint, style, vmem
from repro.configs.base import ServingConfig
from repro.launch.mesh import make_host_mesh
from repro.models import api
from repro.serving.engine import ContinuousServingEngine

ROOT = flib.repo_root()
FIXTURES = os.path.join(ROOT, "tests", "fixtures", "analysis")


def _fixture_src(name: str) -> tuple[str, str]:
    path = os.path.join(FIXTURES, name)
    with open(path) as fh:
        return f"tests/fixtures/analysis/{name}", fh.read()


def _scan_fixture(name: str, opts=None) -> list:
    """jitlint over ONE fixture injected as an extra source (fixtures are
    excluded from disk scans so the repo itself stays clean)."""
    rel, src = _fixture_src(name)
    return jitlint.scan(ROOT, subdirs=(), opts=opts,
                        extra_sources=[(rel, src)])


def _load_fixture_module(name: str):
    path = os.path.join(FIXTURES, name)
    spec = importlib.util.spec_from_file_location(name[:-3], path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ---------------------------------------------------------------------------
# Known-bad fixtures: exactly one finding each, with the right rule id.
# ---------------------------------------------------------------------------


def test_fixture_hidden_host_sync():
    got = _scan_fixture("bad_host_sync.py")
    assert len(got) == 1, [f.render() for f in got]
    assert got[0].rule == "SYNC001"
    assert got[0].symbol == "_leaf"          # one call deep from the scan
    assert ".item()" in got[0].message


def test_fixture_unseeded_rng():
    got = _scan_fixture("bad_unseeded_rng.py")
    assert len(got) == 1, [f.render() for f in got]
    assert got[0].rule == "RNG001"
    assert "default_rng" in got[0].message


def test_fixture_tag_collision():
    got = _scan_fixture("bad_tag_collision.py")
    assert len(got) == 1, [f.render() for f in got]
    assert got[0].rule == "TAG001"
    assert got[0].symbol == "SPEC_TAG_BETA"
    assert "SPEC_TAG_ALPHA" in got[0].message


def test_fixture_wall_clock():
    opts = jitlint.Options(clock_paths=("tests/fixtures/analysis/",),
                           exclude_parts=("__pycache__",))
    got = _scan_fixture("bad_wall_clock.py", opts=opts)
    # Exactly one: the time.time() *call*. The clock=time.perf_counter
    # default is a reference — the injectable surface — and must pass.
    assert len(got) == 1, [f.render() for f in got]
    assert got[0].rule == "CLK001"
    assert got[0].symbol == "time.time"


def test_fixture_vmem_over_budget():
    mod = _load_fixture_module("bad_vmem_kernel.py")
    records = []
    with vmem.record_pallas_calls(records, "bad_vmem_kernel"):
        jax.eval_shape(mod.oversized_copy,
                       jax.ShapeDtypeStruct((4096, 4096), jnp.float32))
    assert len(records) == 1
    fp = records[0]
    assert fp.name == "bad_vmem_kernel._kernel"
    # 2 × (64 MiB in + 64 MiB out): way over the 16 MiB budget.
    assert fp.total_bytes == 4 * 4096 * 4096 * 4
    got = vmem.check({fp.name: fp}, baseline={fp.name: fp.total_bytes})
    assert len(got) == 1, [f.render() for f in got]
    assert got[0].rule == "VMEM001"


def test_fixture_hlo_collective():
    with open(os.path.join(FIXTURES, "bad_collective.hlo")) as fh:
        module = hlo_lib.parse_hlo(fh.read())
    got = hlo_lib.check_no_collectives(module, "bad_collective")
    # Exactly one: the async all-gather-start (a substring grep keyed on
    # "all-gather" alone used to miss renamed/async forms; one keyed on
    # "all-reduce" would false-positive on the decoy *fusion name* here).
    assert len(got) == 1, [f.render() for f in got]
    assert got[0].rule == "HLO001"
    assert got[0].symbol == "all-gather"
    assert "all-reduce" not in {i.opcode for i in module.instructions}


def test_fixture_hlo_host_callback():
    with open(os.path.join(FIXTURES, "bad_callback.hlo")) as fh:
        module = hlo_lib.parse_hlo(fh.read())
    got = hlo_lib.check_no_host_ops(module, "bad_callback")
    assert len(got) == 1, [f.render() for f in got]
    assert got[0].rule == "HLO002"
    assert got[0].symbol == "xla_python_cpu_callback"


def test_dropped_donation_is_caught():
    """DON001 end to end on real compiled output: donate an input that
    cannot alias any output (f32 in, i32 out) — XLA silently drops the
    donation, and the analyzer must say so."""
    def bad(x):
        return (x > 0).astype(jnp.int32)

    lowered = jax.jit(bad, donate_argnums=(0,)).lower(
        jax.ShapeDtypeStruct((8,), jnp.float32))
    module = hlo_lib.parse_hlo(lowered.compile().as_text())
    assert module.donated_params() == set()
    got = hlo_lib.check_donation(module, 1, "bad_donation")
    assert len(got) == 1, [f.render() for f in got]
    assert got[0].rule == "DON001"

    # Positive control: a donatable same-shape/dtype update aliases.
    lowered = jax.jit(lambda x: x + 1, donate_argnums=(0,)).lower(
        jax.ShapeDtypeStruct((8,), jnp.float32))
    module = hlo_lib.parse_hlo(lowered.compile().as_text())
    assert module.donated_params() == {(0, ())}
    assert hlo_lib.check_donation(module, 1, "good_donation") == []


# ---------------------------------------------------------------------------
# HLO parser unit coverage.
# ---------------------------------------------------------------------------


def test_parse_hlo_table_and_alias_map():
    text = "\n".join([
        "HloModule jit_step, input_output_alias={ {0}: (1, {}, "
        "must-alias), {1,0}: (2, {0}, may-alias) }",
        "",
        "ENTRY %main (p0: s32[4], p1: f32[8], p2: (f32[2], f32[2]))"
        " -> (f32[8], (f32[2], f32[2])) {",
        "  %p0 = s32[4]{0} parameter(0)",
        "  %p1 = f32[8]{0} parameter(1), sharding={devices=[4]0,1,2,3}",
        "  %p2 = (f32[2]{0}, f32[2]{0}) parameter(2)",
        "  %add.1 = f32[8]{0} add(f32[8]{0} %p1, f32[8]{0} %p1)",
        "  ROOT %tup = (f32[8]{0}, (f32[2]{0}, f32[2]{0})) "
        "tuple(f32[8]{0} %add.1, (f32[2]{0}, f32[2]{0}) %p2)",
        "}",
    ])
    module = hlo_lib.parse_hlo(text)
    assert module.name == "jit_step"
    assert {"parameter", "add", "tuple"} <= module.opcodes()
    assert module.input_output_alias == {
        (0,): (1, (), "must-alias"),
        (1, 0): (2, (0,), "may-alias"),
    }
    assert module.donated_params() == {(1, ()), (2, (0,))}
    (p1,) = [i for i in module.instructions if i.name == "p1"]
    assert p1.sharding == "{devices=[4]0,1,2,3}"
    (tup,) = [i for i in module.instructions if i.name == "tup"]
    assert tup.shape.startswith("(f32[8]")      # tuple shape survives


def test_base_opcode_normalization():
    assert hlo_lib.base_opcode("all-gather-start") == "all-gather"
    assert hlo_lib.base_opcode("all-reduce-done") == "all-reduce"
    assert hlo_lib.base_opcode("collective-permute-start") == \
        "collective-permute"
    assert hlo_lib.base_opcode("dynamic-update-slice") == \
        "dynamic-update-slice"
    assert hlo_lib.is_collective("reduce-scatter-start")
    assert not hlo_lib.is_collective("reduce")
    assert not hlo_lib.is_collective("fusion")


def test_baseline_roundtrip_and_staleness():
    sups = [flib.Suppression(rule="SYNC001", path="a.py", reason="r"),
            flib.Suppression(rule="RNG001", path="b.py", reason="r",
                             symbol="f")]
    f1 = flib.Finding(rule="SYNC001", path="a.py", line=3, message="m")
    f2 = flib.Finding(rule="RNG001", path="b.py", line=9, message="m",
                      symbol="g")          # symbol mismatch -> unsuppressed
    un, sup, stale = flib.apply_baseline([f1, f2], sups)
    assert un == [f2]
    assert sup == [f1]
    assert stale == [sups[1]]


# ---------------------------------------------------------------------------
# Clean-repo gates: the live tree has zero unsuppressed findings.
# ---------------------------------------------------------------------------


def test_repo_jitlint_clean():
    findings = jitlint.scan(ROOT)
    sups = flib.load_baseline(flib.DEFAULT_BASELINE)
    unsuppressed, _sup, _stale = flib.apply_baseline(findings, sups)
    assert not unsuppressed, "\n".join(f.render() for f in unsuppressed)


def test_repo_style_clean():
    files = jitlint.iter_python_files(
        ROOT, ("src", "benchmarks", "tests", "tools"), jitlint.Options())
    findings = style.scan_files(files)
    assert not findings, "\n".join(f.render() for f in findings)


def test_repo_vmem_within_budget_and_baseline():
    findings = vmem.check()
    assert not findings, "\n".join(f.render() for f in findings)


# ---------------------------------------------------------------------------
# Donation tier-1 contract: every donate_argnums leaf actually aliases.
# ---------------------------------------------------------------------------


@pytest.mark.serving
@pytest.mark.parametrize("kind", ["slay", "softmax"])
def test_engine_donation_contract(kind):
    """Compile macro_decode / write_slot / reset_slot at engine shapes and
    assert via ``input_output_alias`` that *every* donated pool leaf is
    honoured — plus the no-host-op contract on the same modules."""
    cfg = configs.get_smoke_config("slayformer-124m", attn_kind=kind)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = ContinuousServingEngine(
        cfg, params, make_host_mesh(),
        serving=ServingConfig(num_slots=2, max_len=32, prefill_chunk=4,
                              macro_ticks=2))
    lowerings = eng.contract_lowerings()
    assert set(lowerings) == {"macro_decode", "write_slot", "reset_slot"}
    for name, (text, expected) in lowerings.items():
        module = hlo_lib.parse_hlo(text)
        assert expected > 0
        bad = (hlo_lib.check_donation(module, expected, name)
               + hlo_lib.check_no_host_ops(module, name))
        assert not bad, "\n".join(f.render() for f in bad)
        assert len(module.donated_params()) == expected, name
