"""Pallas TPU kernels vs pure-jnp oracles, interpret=True on CPU.

Per the assignment: sweep shapes/dtypes and assert_allclose against the
ref.py oracle for every kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.features import SlayFeatureConfig, init_feature_params
from repro.kernels import feature_map, ops, ref, slay_scan

pytestmark = pytest.mark.kernels


@pytest.mark.parametrize("bh,bk,L,m,dv,chunk", [
    (4, 2, 64, 48, 32, 16),     # GQA g=2
    (2, 2, 32, 16, 16, 8),      # MHA
    (6, 1, 48, 24, 8, 16),      # MQA g=6
    (1, 1, 16, 8, 4, 16),       # single head, chunk == L
    (8, 4, 128, 96, 64, 32),    # bigger
])
def test_scan_kernel_matches_ref(bh, bk, L, m, dv, chunk):
    qf = jax.random.uniform(jax.random.PRNGKey(0), (bh, L, m))
    kf = jax.random.uniform(jax.random.PRNGKey(1), (bk, L, m))
    v = jax.random.normal(jax.random.PRNGKey(2), (bk, L, dv))
    got = slay_scan.causal_linear_attention(qf, kf, v, chunk_size=chunk,
                                            interpret=True)
    want = ref.causal_linear_attention_ref(qf, kf, v, chunk_size=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5,
                               rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_scan_kernel_dtypes(dtype):
    qf = jax.random.uniform(jax.random.PRNGKey(0), (2, 32, 16)).astype(dtype)
    kf = jax.random.uniform(jax.random.PRNGKey(1), (2, 32, 16)).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 32, 8)).astype(dtype)
    got = slay_scan.causal_linear_attention(qf, kf, v, chunk_size=8,
                                            interpret=True)
    want = ref.causal_linear_attention_ref(qf, kf, v, chunk_size=8)
    assert got.dtype == dtype
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)


def test_scan_kernel_rejects_bad_shapes():
    qf = jnp.zeros((3, 32, 16))
    kf = jnp.zeros((2, 32, 16))
    v = jnp.zeros((2, 32, 8))
    with pytest.raises(ValueError):
        slay_scan.causal_linear_attention(qf, kf, v, chunk_size=8,
                                          interpret=True)
    with pytest.raises(ValueError):
        slay_scan.causal_linear_attention(
            jnp.zeros((2, 30, 16)), kf[:, :30], v[:, :30], chunk_size=8,
            interpret=True)


@pytest.mark.parametrize("d,P,D,R,block", [
    (32, 8, 16, 3, 64),
    (16, 4, 8, 2, 32),
    (64, 8, 16, 1, 128),
    (128, 16, 32, 4, 64),
])
def test_feature_map_kernel_matches_ref(d, P, D, R, block):
    cfg = SlayFeatureConfig(head_dim=d, num_anchors=P, num_prf=D,
                            num_quad_nodes=R)
    params = init_feature_params(jax.random.PRNGKey(0), cfg)
    n = block * 2
    u = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    got = feature_map.slay_feature_map(u, params["anchors"],
                                       params["omegas"], cfg,
                                       block_tokens=block, interpret=True)
    want = ref.slay_features_ref(u, params, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_feature_map_kernel_dtypes(dtype):
    cfg = SlayFeatureConfig(head_dim=32)
    params = init_feature_params(jax.random.PRNGKey(0), cfg)
    u = jax.random.normal(jax.random.PRNGKey(1), (64, 32)).astype(dtype)
    got = feature_map.slay_feature_map(u, params["anchors"],
                                       params["omegas"], cfg,
                                       block_tokens=64, interpret=True)
    want = ref.slay_features_ref(u, params, cfg)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)


def test_feature_map_kernel_rejects_nonkernelizable():
    cfg = SlayFeatureConfig(head_dim=16, poly_kind="exact")
    params = init_feature_params(jax.random.PRNGKey(0), cfg)
    u = jnp.zeros((32, 16))
    with pytest.raises(ValueError):
        feature_map.slay_feature_map(u, params["anchors"], params["omegas"],
                                     cfg, block_tokens=32, interpret=True)


def test_ops_wrapper_layout_roundtrip():
    """ops.slay_causal_attention must agree with the model-layout oracle
    (GQA layout transposes are the risky part)."""
    B, L, H, hkv, m, dv = 2, 32, 4, 2, 24, 16
    qf = jax.random.uniform(jax.random.PRNGKey(0), (B, L, H, m))
    kf = jax.random.uniform(jax.random.PRNGKey(1), (B, L, hkv, m))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, L, hkv, dv))
    got = ops.slay_causal_attention(qf, kf, v, chunk_size=8, interpret=True)
    from repro.core import linear_attention as la
    want = la.causal_chunked(qf, kf, v, chunk_size=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5,
                               rtol=1e-4)


def test_ops_feature_wrapper_fallback_matches():
    """ops.slay_features: kernel path (interpret) == jnp fallback path."""
    cfg = SlayFeatureConfig(head_dim=16)
    params = init_feature_params(jax.random.PRNGKey(0), cfg)
    u = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 16))  # 256 tokens
    got = ops.slay_features(u, params, cfg, block_tokens=256, interpret=True)
    want = ref.slay_features_ref(u, params, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=1e-4)


@pytest.mark.parametrize("bh,bk,m,dv", [
    (4, 2, 24, 16),
    (2, 2, 16, 8),
    (6, 1, 48, 32),
    (8, 4, 384, 128),   # production SLAY shape
])
def test_decode_kernel_matches_ref(bh, bk, m, dv):
    from repro.kernels import decode_step as dk
    qf = jax.random.uniform(jax.random.PRNGKey(0), (bh, m))
    kf = jax.random.uniform(jax.random.PRNGKey(1), (bk, m))
    v = jax.random.normal(jax.random.PRNGKey(2), (bk, dv))
    s = jax.random.uniform(jax.random.PRNGKey(3), (bk, m, dv))
    z = jax.random.uniform(jax.random.PRNGKey(4), (bk, m)) + 1.0
    y_k, s_k, z_k = dk.decode_linear_attention(qf, kf, v, s.copy(), z.copy(),
                                               interpret=True)
    y_r, s_r, z_r = ref.decode_linear_attention_ref(qf, kf, v, s, z)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), atol=3e-5,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), atol=3e-5)
    np.testing.assert_allclose(np.asarray(z_k), np.asarray(z_r), atol=3e-5)


@pytest.mark.parametrize("bh,bk,m,dv", [(8, 4, 24, 16), (4, 4, 16, 8)])
def test_decode_kernel_active_mask(bh, bk, m, dv):
    """Continuous-batching pool rows: inactive (drained) slots produce zero
    output and pass their state through bit-identically."""
    from repro.kernels import decode_step as dk
    qf = jax.random.uniform(jax.random.PRNGKey(0), (bh, m))
    kf = jax.random.uniform(jax.random.PRNGKey(1), (bk, m))
    v = jax.random.normal(jax.random.PRNGKey(2), (bk, dv))
    s = jax.random.uniform(jax.random.PRNGKey(3), (bk, m, dv))
    z = jax.random.uniform(jax.random.PRNGKey(4), (bk, m)) + 1.0
    active = jnp.asarray(np.arange(bk) % 2 == 0, jnp.int32)   # evens live
    y_k, s_k, z_k = dk.decode_linear_attention(
        qf, kf, v, s.copy(), z.copy(), active, interpret=True)
    y_r, s_r, z_r = ref.decode_linear_attention_ref(qf, kf, v, s, z, active)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), atol=3e-5,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), atol=3e-5)
    np.testing.assert_allclose(np.asarray(z_k), np.asarray(z_r), atol=3e-5)
    g = bh // bk
    for row in range(bk):
        if row % 2:                    # drained
            np.testing.assert_array_equal(np.asarray(s_k)[row],
                                          np.asarray(s)[row])
            np.testing.assert_array_equal(np.asarray(z_k)[row],
                                          np.asarray(z)[row])
            assert np.all(np.asarray(y_k)[row * g:(row + 1) * g] == 0)


def test_decode_kernel_sequence_consistency():
    """Repeated kernel decode steps == the chunked causal oracle rows."""
    from repro.kernels import decode_step as dk
    bh = bk = 2
    m, dv, L = 12, 8, 6
    qf = jax.random.uniform(jax.random.PRNGKey(0), (L, bh, m))
    kf = jax.random.uniform(jax.random.PRNGKey(1), (L, bk, m))
    v = jax.random.normal(jax.random.PRNGKey(2), (L, bk, dv))
    full = ref.causal_linear_attention_ref(
        jnp.moveaxis(qf, 0, 1), jnp.moveaxis(kf, 0, 1),
        jnp.moveaxis(v, 0, 1), chunk_size=3)
    s = jnp.zeros((bk, m, dv))
    z = jnp.zeros((bk, m))
    for t in range(L):
        y, s, z = dk.decode_linear_attention(qf[t], kf[t], v[t], s, z,
                                             interpret=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(full[:, t]),
                                   atol=3e-5, rtol=1e-4)
