"""Docs integrity under tier-1: the same contract CI's docs step runs
(`python tools/check_docs.py`) — README section anchors, DESIGN.md §
anchors (docstrings across src/repro cite them), and resolvable
intra-repo relative links."""
import os
import subprocess
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_REPO, "tools"))

import check_docs  # noqa: E402


def test_docs_integrity_clean():
    assert check_docs.check() == []


def test_docs_check_cli_passes():
    proc = subprocess.run(
        [sys.executable, os.path.join(_REPO, "tools", "check_docs.py")],
        capture_output=True, text=True, timeout=60)
    assert proc.returncode == 0, proc.stderr
    assert "docs OK" in proc.stdout


def test_docs_check_catches_breakage(tmp_path):
    """The checker actually fails on a repo with a broken link and a
    missing anchor (guards against a vacuous green CI step)."""
    (tmp_path / "README.md").write_text(
        "# x\n## Install\nsee [gone](no/such/file.md)\n")
    (tmp_path / "DESIGN.md").write_text("# d\n## §1\n")
    errors = check_docs.check(str(tmp_path))
    assert any("broken relative link" in e for e in errors)
    assert any("missing anchor" in e and "README" in e for e in errors)
    assert any("§8" in e for e in errors)
