"""Linear-attention contractions (paper Eq. 11 / Algorithm 1):
chunk invariance, causal==quadratic oracle, decode==prefix, GQA grouping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import linear_attention as la


def _rand_features(key, B, L, H, m, positive=True):
    x = jax.random.uniform(key, (B, L, H, m)) if positive else \
        jax.random.normal(key, (B, L, H, m))
    return x


def _naive_causal(qf, kf, v, delta=1e-6):
    """O(L^2) reference: scores = qf kf^T, causal-masked, kernel-normalized.
    qf (B,L,H,m), kf (B,L,Hkv,m), v (B,L,Hkv,dv)."""
    B, L, H, m = qf.shape
    hkv = kf.shape[-2]
    g = H // hkv
    kfr = jnp.repeat(kf, g, axis=-2)
    vr = jnp.repeat(v, g, axis=-2)
    scores = jnp.einsum("blhm,bshm->bhls", qf, kfr)
    mask = jnp.tril(jnp.ones((L, L), bool))
    scores = jnp.where(mask, scores, 0.0)
    num = jnp.einsum("bhls,bshd->blhd", scores, vr)
    den = jnp.sum(scores, axis=-1).swapaxes(-1, -2)[..., None]
    return num / (den + delta)


@pytest.mark.parametrize("chunk", [2, 4, 8, 32])
def test_causal_chunked_matches_naive(chunk, key):
    B, L, H, hkv, m, dv = 2, 32, 4, 2, 12, 8
    qf = _rand_features(jax.random.PRNGKey(1), B, L, H, m)
    kf = _rand_features(jax.random.PRNGKey(2), B, L, hkv, m)
    v = jax.random.normal(jax.random.PRNGKey(3), (B, L, hkv, dv))
    got = la.causal_chunked(qf, kf, v, chunk_size=chunk)
    want = _naive_causal(qf, kf, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-4)


def test_chunk_size_invariance(key):
    B, L, H, m, dv = 1, 24, 2, 8, 4
    qf = _rand_features(jax.random.PRNGKey(1), B, L, H, m)
    kf = _rand_features(jax.random.PRNGKey(2), B, L, H, m)
    v = jax.random.normal(jax.random.PRNGKey(3), (B, L, H, dv))
    outs = [la.causal_chunked(qf, kf, v, chunk_size=c) for c in (3, 8, 24)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=2e-5)


def test_padding_path(key):
    """L not divisible by chunk: zero-padding must not change the output."""
    B, L, H, m, dv = 1, 19, 2, 8, 4
    qf = _rand_features(jax.random.PRNGKey(1), B, L, H, m)
    kf = _rand_features(jax.random.PRNGKey(2), B, L, H, m)
    v = jax.random.normal(jax.random.PRNGKey(3), (B, L, H, dv))
    got = la.causal_chunked(qf, kf, v, chunk_size=8)
    want = _naive_causal(qf, kf, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_noncausal_matches_quadratic(key):
    B, L, Lk, H, m, dv = 2, 8, 12, 4, 6, 5
    qf = _rand_features(jax.random.PRNGKey(1), B, L, H, m)
    kf = _rand_features(jax.random.PRNGKey(2), B, Lk, H, m)
    v = jax.random.normal(jax.random.PRNGKey(3), (B, Lk, H, dv))
    got = la.noncausal(qf, kf, v)
    scores = jnp.einsum("blhm,bshm->bhls", qf, kf)
    num = jnp.einsum("bhls,bshd->blhd", scores, v)
    den = jnp.sum(scores, -1).swapaxes(-1, -2)[..., None]
    want = num / (den + 1e-6)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=1e-4)


def test_decode_steps_match_full_causal(key):
    """Token-by-token decode must reproduce each causal row."""
    B, L, H, hkv, m, dv = 1, 10, 4, 2, 6, 4
    qf = _rand_features(jax.random.PRNGKey(1), B, L, H, m)
    kf = _rand_features(jax.random.PRNGKey(2), B, L, hkv, m)
    v = jax.random.normal(jax.random.PRNGKey(3), (B, L, hkv, dv))
    full = la.causal_chunked(qf, kf, v, chunk_size=5)
    state = la.init_state((B,), hkv, m, dv)
    for t in range(L):
        y, state = la.decode_step(qf[:, t], kf[:, t], v[:, t], state)
        np.testing.assert_allclose(np.asarray(y), np.asarray(full[:, t]),
                                   atol=3e-5, rtol=1e-4)


def test_prefill_state_then_decode(key):
    """prefill_state(prompt) + decode(next) == causal at position L."""
    B, L, hkv, m, dv = 2, 12, 2, 6, 4
    kf = _rand_features(jax.random.PRNGKey(2), B, L + 1, hkv, m)
    v = jax.random.normal(jax.random.PRNGKey(3), (B, L + 1, hkv, dv))
    qf = _rand_features(jax.random.PRNGKey(1), B, L + 1, hkv, m)
    st = la.prefill_state(kf[:, :L], v[:, :L])
    y, _ = la.decode_step(qf[:, L], kf[:, L], v[:, L], st)
    full = la.causal_chunked(qf, kf, v, chunk_size=13)
    np.testing.assert_allclose(np.asarray(y), np.asarray(full[:, L]),
                               atol=3e-5, rtol=1e-4)


def test_gqa_grouping_equivalence(key):
    """GQA (Hkv < H) must equal explicitly repeating kv to all heads."""
    B, L, H, hkv, m, dv = 1, 16, 6, 3, 5, 4
    qf = _rand_features(jax.random.PRNGKey(1), B, L, H, m)
    kf = _rand_features(jax.random.PRNGKey(2), B, L, hkv, m)
    v = jax.random.normal(jax.random.PRNGKey(3), (B, L, hkv, dv))
    got = la.causal_chunked(qf, kf, v, chunk_size=8)
    kfr = jnp.repeat(kf, H // hkv, axis=-2)
    vr = jnp.repeat(v, H // hkv, axis=-2)
    want = la.causal_chunked(qf, kfr, vr, chunk_size=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_output_in_value_hull_for_nonneg_features(key):
    """With nonnegative features the attention output is a convex
    combination of values (up to the +delta shrinkage): coordinates lie in
    [min v, max v] componentwise."""
    B, L, H, m, dv = 1, 20, 2, 8, 3
    qf = _rand_features(jax.random.PRNGKey(1), B, L, H, m) + 0.1
    kf = _rand_features(jax.random.PRNGKey(2), B, L, H, m) + 0.1
    v = jax.random.normal(jax.random.PRNGKey(3), (B, L, H, dv))
    out = np.asarray(la.causal_chunked(qf, kf, v, chunk_size=4))
    vmin = np.asarray(v).min(axis=(0, 1, 2))
    vmax = np.asarray(v).max(axis=(0, 1, 2))
    assert np.all(out >= vmin - 1e-3)
    assert np.all(out <= vmax + 1e-3)
