"""Training substrate: trainer loop, checkpoint/restart, elastic resume,
gradient compression, data pipeline determinism, straggler watchdog."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import (latest_step, restore_checkpoint,
                              restore_latest, save_checkpoint)
from repro.data.pipeline import DataConfig, batch_iterator, make_batch
from repro.launch.mesh import make_host_mesh
from repro.models import api
from repro.optim import compress as gcomp
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train.loop import TrainConfig, Trainer, make_train_step


def _tiny_cfg():
    return configs.get_smoke_config("slayformer-124m")


def test_data_pipeline_deterministic():
    cfg = DataConfig(vocab_size=97, seq_len=16, global_batch=4, seed=3)
    b1 = make_batch(cfg, 7)
    b2 = make_batch(cfg, 7)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = make_batch(cfg, 8)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_data_iterator_resumes_exactly():
    cfg = DataConfig(vocab_size=31, seq_len=8, global_batch=2)
    it = batch_iterator(cfg)
    ref = [next(it) for _ in range(5)]
    it2 = batch_iterator(cfg, start_step=3)
    s, b = next(it2)
    assert s == 3
    np.testing.assert_array_equal(np.asarray(ref[3][1]["tokens"]),
                                  np.asarray(b["tokens"]))


def test_labels_are_next_tokens():
    cfg = DataConfig(vocab_size=11, seq_len=9, global_batch=2)
    b = make_batch(cfg, 0)
    assert b["tokens"].shape == (2, 9) and b["labels"].shape == (2, 9)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    p = save_checkpoint(str(tmp_path), 42, tree)
    assert os.path.exists(p)
    restored, step = restore_checkpoint(p, tree)
    assert step == 42
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_gc_keeps_latest(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(str(tmp_path), s, tree, keep=2)
    names = sorted(os.listdir(tmp_path))
    assert names == ["step_00000004.ckpt", "step_00000005.ckpt"]
    assert latest_step(str(tmp_path)) == 5


def test_checkpoint_shape_mismatch_raises(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    p = save_checkpoint(str(tmp_path), 1, tree)
    with pytest.raises(ValueError):
        restore_checkpoint(p, {"x": jnp.zeros((3,))})


def test_restore_latest_empty_dir(tmp_path):
    out, step = restore_latest(str(tmp_path / "nope"), {"x": jnp.zeros(2)})
    assert out is None and step is None


def test_trainer_runs_and_resumes(tmp_path):
    """5 steps, checkpoint, new Trainer resumes at the saved step and the
    loss stream continues identically (step-indexed data)."""
    cfg = _tiny_cfg()
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50)
    tcfg = TrainConfig(microbatches=1, remat=False,
                       ckpt_dir=str(tmp_path), ckpt_every=100)
    mesh = make_host_mesh()
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4)
    tr = Trainer(cfg, opt_cfg, tcfg, mesh, seed=0)
    hist = tr.run(batch_iterator(dcfg), num_steps=5, log_every=100)
    assert len(hist) == 5
    assert all(np.isfinite(h["loss"]) for h in hist)
    assert tr.step == 5
    tr.save()

    tr2 = Trainer(cfg, opt_cfg, tcfg, mesh, seed=0)
    assert tr2.step == 5          # resumed
    p1 = jax.tree.leaves(tr.params)[0]
    p2 = jax.tree.leaves(tr2.params)[0]
    np.testing.assert_allclose(np.asarray(p1, np.float32),
                               np.asarray(p2, np.float32))


def test_microbatched_step_matches_single(key):
    """Gradient accumulation must not change the update (same global
    batch)."""
    cfg = _tiny_cfg()
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    params = api.init_params(cfg, key)
    batch = {
        "tokens": jax.random.randint(key, (4, 16), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.fold_in(key, 1), (4, 16), 0,
                                     cfg.vocab_size),
    }
    s1 = make_train_step(cfg, opt_cfg, TrainConfig(microbatches=1,
                                                   remat=False))
    s2 = make_train_step(cfg, opt_cfg, TrainConfig(microbatches=4,
                                                   remat=False))
    opt = adamw_init(params, opt_cfg)
    p1, *_ = s1(params, opt, jnp.zeros(()), batch)
    p2, *_ = s2(params, opt, jnp.zeros(()), batch)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), p1, p2)
    assert max(jax.tree.leaves(d)) < 2e-2   # bf16 params, fp32 accumulation


def test_grad_compression_error_feedback():
    """int8 EF compression: the residual carries what quantization lost,
    so the *running sum* of decompressed grads tracks the true sum."""
    rng = np.random.default_rng(0)
    g_true = [jnp.asarray(rng.normal(size=(64,)) * 0.01, jnp.float32)
              for _ in range(20)]
    ef = gcomp.init({"w": g_true[0]})
    acc_q = np.zeros(64)
    acc_t = np.zeros(64)
    for g in g_true:
        gq, ef = gcomp.compress_decompress({"w": g}, ef)
        acc_q += np.asarray(gq["w"])
        acc_t += np.asarray(g)
    # Without EF, int8 bias would accumulate; with EF the error stays O(1
    # quantum), not O(steps).
    err = np.abs(acc_q - acc_t).max()
    single_quantum = 0.01 * 4 / 127
    assert err < 10 * single_quantum


def test_compressed_grads_int8_payload():
    g = {"w": jnp.ones((32,), jnp.float32)}
    ef = gcomp.init(g)
    gq, _ = gcomp.compress_decompress(g, ef)
    # Dequantized values match within one quantum.
    np.testing.assert_allclose(np.asarray(gq["w"]), 1.0, atol=1.0 / 127)


def test_watchdog_tightens_ckpt_cadence(tmp_path, monkeypatch):
    """A straggling step (simulated) must halve the checkpoint cadence."""
    cfg = _tiny_cfg()
    opt_cfg = AdamWConfig()
    tcfg = TrainConfig(microbatches=1, remat=False, ckpt_dir=str(tmp_path),
                       ckpt_every=64, watchdog_factor=1.5)
    mesh = make_host_mesh()
    tr = Trainer(cfg, opt_cfg, tcfg, mesh)
    times = iter([0.1] * 12 + [10.0] + [0.1] * 10)

    real_monotonic = [0.0]

    def fake_monotonic():
        real_monotonic[0] += next(times, 0.1)
        return real_monotonic[0]

    import repro.train.loop as loop_mod
    monkeypatch.setattr(loop_mod.time, "monotonic", fake_monotonic)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=8, global_batch=2)
    tr.run(batch_iterator(dcfg), num_steps=10, log_every=100)
    # ckpt_every is local to run(); observable effect: a checkpoint exists
    # well before step 64.
    assert latest_step(str(tmp_path)) is not None
