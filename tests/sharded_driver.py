"""Multi-device driver for the sharded serving slot-pool tests.

Run in a subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(jax device count is fixed at first init, so the forced-device flag cannot
be set from inside the already-initialized tier-1 process —
``tests/test_serving_sharded.py`` spawns this file per check). CI also
invokes it directly under the same flag.

Each check exercises the DESIGN.md §8 contract on real multi-device
shardings: byte-identical token streams between mesh=(1,) and
mesh=(data=4,), shard-local eviction/reuse, the num_slots divisibility
fallback, and the zero-collective decode hot loop.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python tests/sharded_driver.py --check parity
"""
from __future__ import annotations

import argparse
import os
import sys

import jax
import numpy as np

# The bench package lives at the repo root (not on PYTHONPATH=src);
# reuse its seeded trace generator rather than keeping a hand-synced copy.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))))

from benchmarks.serving_bench import _poisson_trace as _bench_trace  # noqa: E402,E501
from repro import configs  # noqa: E402
from repro.analysis import hlo as hlo_lib  # noqa: E402
from repro.configs.base import ServingConfig  # noqa: E402
from repro.launch.mesh import make_serving_mesh  # noqa: E402
from repro.models import api  # noqa: E402
from repro.serving.engine import ContinuousServingEngine  # noqa: E402


def _assert_collective_free(hlo_text: str, label: str) -> int:
    """§8 contract via the op-level analyzer (not a substring grep —
    parsed opcodes catch async forms like ``all-gather-start`` and don't
    trip on fusion *names* that merely mention a collective). Also holds
    the no-host-callback line (§14 HLO002). Returns the op count."""
    module = hlo_lib.parse_hlo(hlo_text)
    findings = (hlo_lib.check_no_collectives(module, label)
                + hlo_lib.check_no_host_ops(module, label))
    assert not findings, "\n".join(f.render() for f in findings)
    return len(module.instructions)


def _setup(attn_kind="slay"):
    cfg = configs.get_smoke_config("slayformer-124m", attn_kind=attn_kind)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _poisson_trace(cfg, n=6, rate=0.5, prompt_range=(3, 12), max_new=6,
                   seed=1234):
    """Mixed-length Poisson arrivals — serving_bench's generator."""
    return _bench_trace(np.random.default_rng(seed), n, rate, prompt_range,
                        cfg.vocab_size, max_new)


def _run(cfg, params, *, data, num_slots, macro_ticks, temperature=0.0,
         reqs=None, slot_shards=0, page_size=0):
    mesh = make_serving_mesh(data)
    eng = ContinuousServingEngine(
        cfg, params, mesh,
        serving=ServingConfig(num_slots=num_slots, max_len=64,
                              prefill_chunk=4, macro_ticks=macro_ticks,
                              temperature=temperature, seed=3,
                              slot_shards=slot_shards,
                              page_size=page_size))
    outs, summary = eng.run(list(reqs))
    return eng, outs, summary


def check_parity():
    """Byte-identical streams mesh=(1,) vs mesh=(data=4,) at K=8 and K=1,
    greedy and sampled, both cache regimes; jit budget holds sharded."""
    assert jax.device_count() >= 4, jax.device_count()
    for kind, temps, ks in (("slay", (0.0, 0.8), (8, 1)),
                            ("softmax", (0.0,), (8,))):
        cfg, params = _setup(kind)
        reqs = _poisson_trace(cfg)
        for temperature in temps:
            for k in ks:
                _, o1, s1 = _run(cfg, params, data=1, num_slots=4,
                                 macro_ticks=k, temperature=temperature,
                                 reqs=reqs)
                e4, o4, s4 = _run(cfg, params, data=4, num_slots=4,
                                  macro_ticks=k, temperature=temperature,
                                  reqs=reqs)
                assert s1["slot_shards"] == 1 and s4["slot_shards"] == 4
                assert s4["requests_completed"] == len(reqs)
                for rid in o1:
                    np.testing.assert_array_equal(o1[rid], o4[rid])
                # Scheduling trajectory is mesh-shape-independent too.
                assert s1["ticks"] == s4["ticks"]
                assert s1["decode_dispatches"] == s4["decode_dispatches"]
                # PR-3 recompile budget survives sharding.
                assert e4.jit_cache_entries().get("macro_decode", 1) == 1
                print(f"parity OK kind={kind} T={temperature} K={k}")


def check_evict_reuse():
    """Shard-local eviction/reuse: 2 slots per shard, burst arrivals so the
    pool fills — admissions spread across shards before doubling up on
    any, every reuse honours the finished-before-admitted invariant, and
    streams match the single-shard run."""
    cfg, params = _setup()
    # Burst: everything arrives at once, short prompts (one prefill chunk
    # per admission), K=1 so admissions aren't quantized to macro-step
    # boundaries — the pool actually fills before anything finishes.
    reqs = _poisson_trace(cfg, n=10, rate=100.0, prompt_range=(3, 4),
                          max_new=16, seed=7)
    _, o1, _ = _run(cfg, params, data=1, num_slots=8, macro_ticks=1,
                    reqs=reqs)
    e4, o4, s4 = _run(cfg, params, data=4, num_slots=8, macro_ticks=1,
                      reqs=reqs)
    assert s4["requests_completed"] == 10
    for rid in o1:
        np.testing.assert_array_equal(o1[rid], o4[rid])
    stats = sorted(e4.metrics.per_request.values(),
                   key=lambda st: (st.admitted, st.rid))
    # Burst fill: the first four admissions land on four distinct shards
    # (load balancing), not on shard 0's two slots back-to-back.
    first4 = [e4.sched.shard_of(st.slot) for st in stats[:4]]
    assert sorted(first4) == [0, 1, 2, 3], first4
    by_slot = {}
    for st in stats:
        by_slot.setdefault(st.slot, []).append(st)
    for tenants in by_slot.values():
        for prev, nxt in zip(tenants, tenants[1:]):
            assert nxt.admitted >= prev.finished   # shard-local slot reuse
    assert any(len(v) >= 2 for v in by_slot.values())   # reuse happened
    print("evict/reuse OK: slots", {s: len(v) for s, v in by_slot.items()})


def check_fallback():
    """num_slots=6 over data=4 does not divide: the pool replicates, the
    drop is recorded like the rule-engine fallback, streams stay exact."""
    cfg, params = _setup()
    reqs = _poisson_trace(cfg, n=5, seed=11)
    _, o1, _ = _run(cfg, params, data=1, num_slots=6, macro_ticks=8,
                    reqs=reqs)
    e6, o6, s6 = _run(cfg, params, data=4, num_slots=6, macro_ticks=8,
                      reqs=reqs)
    assert s6["slot_shards"] == 1
    assert e6.slot_shard_fallbacks == [("slots", 6, "data")]
    for rid in o1:
        np.testing.assert_array_equal(o1[rid], o6[rid])
    # Demanding an impossible shard count is a hard error, not a fallback.
    try:
        _run(cfg, params, data=4, num_slots=6, macro_ticks=8, reqs=[],
             slot_shards=2)
    except ValueError as e:
        assert "slot_shards" in str(e)
    else:
        raise AssertionError("slot_shards=2 on a data=4 mesh must raise")
    print("fallback OK:", e6.slot_shard_fallbacks)


def check_collectives():
    """The compiled K-tick decode macro-step has zero cross-shard
    collectives on mesh=(data=4,), for both cache regimes — and the
    sharding specs actually place the slot dim on `data`."""
    from jax.sharding import PartitionSpec as P

    from repro.distributed import sharding as shd
    from repro.models import api as mapi

    mesh = make_serving_mesh(4)
    v = shd.serving_vector_sharding(mesh, num_slots=4)
    assert v.spec == P("data"), v.spec
    buf = shd.serving_vector_sharding(mesh, num_slots=4, leading=1)
    assert buf.spec == P(None, "data"), buf.spec

    for kind in ("slay", "softmax"):
        cfg, params = _setup(kind)
        c_abs = mapi.abstract_cache(cfg, 4, 64)
        c_sh = shd.serving_cache_sharding(mesh, shd.DEFAULT_RULES, c_abs,
                                          num_slots=4)
        for leaf, sh in zip(jax.tree.leaves(c_abs), jax.tree.leaves(c_sh)):
            dim = 1 if len(leaf.shape) >= 2 else 0
            assert len(sh.spec) > dim and sh.spec[dim] == "data", \
                (leaf.shape, sh.spec)
        eng = ContinuousServingEngine(
            cfg, params, mesh,
            serving=ServingConfig(num_slots=4, max_len=64, prefill_chunk=4,
                                  macro_ticks=8))
        assert eng.slot_shards == 4
        nops = _assert_collective_free(eng.decode_hlo(),
                                       f"decode_hlo[{kind}]")
        print(f"collectives OK kind={kind} (none in {nops} ops)")


def check_paged():
    """Paged slot memory on a sharded pool (DESIGN.md §11): streams are
    byte-identical to the unpaged single-shard run, the shard-aligned
    page allocator never crosses a shard block, no pages leak, and the
    compiled decode macro-step stays collective-free with the page
    gather/scatter inside it."""
    cfg, params = _setup("softmax")        # KV ring: the paged regime
    assert api.supports_paging(cfg)
    reqs = _poisson_trace(cfg, n=8, seed=23, max_new=8)
    _, o1, _ = _run(cfg, params, data=1, num_slots=4, macro_ticks=8,
                    reqs=reqs)
    e4, o4, s4 = _run(cfg, params, data=4, num_slots=4, macro_ticks=8,
                      reqs=reqs, page_size=16)
    assert s4["requests_completed"] == len(reqs)
    for rid in o1:
        np.testing.assert_array_equal(o1[rid], o4[rid])
    assert s4["num_pages"] == 16 and s4["pages_peak"] >= 1, s4
    assert s4["final_pages_in_use"] == 0, s4
    e4.page_pool.check()                    # allocator invariant audit
    nops = _assert_collective_free(e4.decode_hlo(), "decode_hlo[paged]")
    print(f"paged OK: sharded paged streams byte-identical, "
          f"pages_peak={s4['pages_peak']}, no collectives "
          f"({nops} ops)")


CHECKS = {"parity": check_parity, "evict_reuse": check_evict_reuse,
          "fallback": check_fallback, "collectives": check_collectives,
          "paged": check_paged}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", choices=sorted(CHECKS) + ["all"],
                    default="all")
    args = ap.parse_args()
    names = sorted(CHECKS) if args.check == "all" else [args.check]
    for name in names:
        CHECKS[name]()
    print(f"sharded_driver OK: {', '.join(names)}")


if __name__ == "__main__":
    main()
