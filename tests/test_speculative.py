"""Speculative decoding (DESIGN.md §13): the statistical sampling-contract
harness plus the engine-level byte-identity / invariance / rollback suite.

The contract under test is the one the module docstring of
``repro.serving.speculative`` states:

* the accept/resample correction makes the emitted-token distribution
  equal the *verifier's* softmax exactly, for any draft distribution —
  checked empirically with a chi-square bound over randomized
  (logits, temperature) pairs at fixed seeds;
* greedy speculative streams are byte-identical to greedy exact decode;
* accepted streams are placement-, K-, and (greedy) gamma-invariant;
* rejected-suffix rollback composes with paged KV (zero leaked pages),
  the write-ahead journal (only accepted tokens are journaled, so
  crash/restore reproduces streams byte-identically) and NaN quarantine.

Every random draw in this module is seeded; the conftest guard enforces
that repo-wide.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ServingConfig
from repro.launch.mesh import make_host_mesh
from repro.models import api
from repro.serving import faults
from repro.serving import journal as journal_lib
from repro.serving import sampling, speculative
from repro.serving.engine import ContinuousServingEngine, Request

pytestmark = pytest.mark.serving


# ---------------------------------------------------------------------------
# Statistical sampling contract (pure math, no engine)
# ---------------------------------------------------------------------------

# chi-square critical values at p = 0.001 for df = vocab - 1; a correct
# sampler fails a single test with probability 1e-3, and the seeds below
# are fixed, so CI is deterministic: these cases are known-passing draws.
_CHI2_CRIT = {7: 24.32, 15: 37.70}


def _emitted(p_logits, q_logits, *, temperature, seed, n, idx):
    """Simulate n independent speculative draws of one token position:
    draft from q, accept/resample against p. Trials are vectorized over
    the rid axis — by the determinism contract each (seed, rid, idx) is
    an independent stream, which is exactly what the harness needs."""
    vocab = p_logits.shape[-1]
    rids = jnp.arange(n, dtype=jnp.int32)
    idxs = jnp.full((n,), idx, jnp.int32)
    p = jnp.broadcast_to(p_logits, (n, vocab))
    q = jnp.broadcast_to(q_logits, (n, vocab))
    drafts = speculative.draft_sample(q, rids, idxs,
                                      temperature=temperature, seed=seed)
    acc, corr = speculative.accept_and_correct(
        p, q, drafts, rids, idxs, temperature=temperature, seed=seed)
    return np.asarray(jnp.where(acc, drafts, corr)), np.asarray(acc)


def _chi2(counts, probs, n):
    exp = probs * n
    return float(np.sum((counts - exp) ** 2 / np.maximum(exp, 1e-12)))


@pytest.mark.parametrize("case", range(4))
def test_accepted_distribution_matches_verifier(case):
    """Empirical emitted-token histogram ~ softmax(p / T) regardless of
    how far the draft q is from the verifier p (chi-square, p = 0.001)."""
    rng = np.random.default_rng(100 + case)
    vocab = int(rng.choice([8, 16]))
    temperature = float(rng.uniform(0.4, 1.6))
    scale = float(rng.uniform(0.5, 3.0))          # draft/verifier mismatch
    p_logits = jnp.asarray(rng.normal(size=vocab), jnp.float32)
    q_logits = jnp.asarray(rng.normal(size=vocab) * scale, jnp.float32)
    n = 8000
    toks, _ = _emitted(p_logits, q_logits, temperature=temperature,
                       seed=case, n=n, idx=3 + case)
    counts = np.bincount(toks, minlength=vocab)
    probs = np.asarray(jax.nn.softmax(p_logits / temperature))
    assert _chi2(counts, probs, n) < _CHI2_CRIT[vocab - 1]


def test_identical_distributions_always_accept():
    """p == q makes acceptance certain (u * q(d) < p(d) with u in [0, 1))
    — the division-free accept test must not lose this exactness."""
    rng = np.random.default_rng(11)
    logits = jnp.asarray(rng.normal(size=16), jnp.float32)
    toks, acc = _emitted(logits, logits, temperature=0.9, seed=7,
                         n=2000, idx=5)
    assert acc.all()
    counts = np.bincount(toks, minlength=16)
    probs = np.asarray(jax.nn.softmax(logits / 0.9))
    assert _chi2(counts, probs, 2000) < _CHI2_CRIT[15]


def test_greedy_accept_is_verifier_argmax():
    """T = 0: accept iff draft == argmax(p); corrected token is that
    argmax, so the emitted token is the verifier argmax either way."""
    rng = np.random.default_rng(2)
    p = jnp.asarray(rng.normal(size=(6, 32)), jnp.float32)
    top = jnp.argmax(p, axis=-1).astype(jnp.int32)
    drafts = top.at[0].set((top[0] + 1) % 32)     # one wrong proposal
    rids = jnp.arange(6, dtype=jnp.int32)
    idxs = jnp.zeros((6,), jnp.int32)
    acc, corr = speculative.accept_and_correct(
        p, p, drafts, rids, idxs, temperature=0.0, seed=0)
    assert not bool(acc[0]) and bool(jnp.all(acc[1:]))
    assert np.array_equal(np.asarray(corr), np.asarray(top))


def test_substreams_are_independent():
    """DRAFT / ACCEPT / RESAMPLE substreams of one (seed, rid, idx) must
    not collide with each other or with the untagged bonus stream."""
    u = float(sampling.spec_uniform(0, jnp.int32(1), jnp.int32(2)))
    assert 0.0 <= u < 1.0
    rows = [np.asarray(sampling.spec_gumbel_row(0, jnp.int32(1),
                                                jnp.int32(2), tag, 64))
            for tag in (sampling.SPEC_TAG_DRAFT, sampling.SPEC_TAG_RESAMPLE)]
    assert not np.array_equal(rows[0], rows[1])


# ---------------------------------------------------------------------------
# Engine-level contract (byte identity, invariances, rollback)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def setup():
    # The bench pairing: exact yat_spherical verifier, linear SLAY draft
    # (draft_config swaps attn_kind only; anchors/features shrink the
    # shared trunk so the smoke suite stays fast).
    cfg = configs.get_smoke_config("slayformer-124m",
                                   attn_kind="yat_spherical",
                                   slay_anchors=16, slay_prf=32)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    mesh = make_host_mesh()
    return cfg, params, mesh


def _trace(cfg):
    rng = np.random.default_rng(7)
    return [Request(rng.integers(1, cfg.vocab_size, size=n).astype(np.int32),
                    max_new_tokens=m, eos_id=1)
            for n, m in [(12, 20), (5, 16), (30, 24), (9, 12)]]


def _sv(**kw):
    return ServingConfig(**{"num_slots": 2, "max_len": 128,
                            "prefill_chunk": 16, "macro_ticks": 4,
                            "debug_audit": True, **kw})


def _run(setup, **kw):
    cfg, params, mesh = setup
    eng = ContinuousServingEngine(cfg, params, mesh, serving=_sv(**kw))
    return eng.run(_trace(cfg))


@pytest.fixture(scope="module")
def greedy_runs(setup):
    ref, s_ref = _run(setup)                                  # plain exact
    spec, s_spec = _run(setup, speculative=True, spec_gamma=2)
    return ref, s_ref, spec, s_spec


def test_greedy_spec_byte_identical_to_exact(greedy_runs):
    ref, _, spec, s_spec = greedy_runs
    assert set(ref) == set(spec)
    for rid in ref:
        assert np.array_equal(ref[rid], spec[rid]), rid
    assert s_spec["requests_completed"] == len(ref)


def test_spec_amortizes_dispatches(greedy_runs):
    """One speculative dispatch covers K rounds x up to gamma+1 tokens:
    tokens/dispatch must beat both the plain macro engine and the K
    floor, and the acceptance accounting must be populated."""
    _, s_ref, _, s_spec = greedy_runs
    assert s_spec["tokens_per_dispatch"] > s_ref["tokens_per_dispatch"]
    assert s_spec["tokens_per_dispatch"] > 4            # macro_ticks
    assert s_spec["draft_tokens_proposed"] > 0
    assert 0.0 < s_spec["draft_acceptance_rate"] <= 1.0
    assert s_spec["speculative"] and s_spec["spec_gamma"] == 2


@pytest.mark.parametrize("kw", [
    {"macro_ticks": 1},                 # K-invariance
    {"spec_gamma": 3},                  # greedy gamma-invariance
    {"num_slots": 4},                   # placement invariance
])
def test_greedy_invariance(greedy_runs, setup, kw):
    spec = greedy_runs[2]
    outs, _ = _run(setup, speculative=True,
                   **{"spec_gamma": 2, **kw})
    for rid in spec:
        assert np.array_equal(spec[rid], outs[rid]), (kw, rid)


def test_paged_rollback_leaks_no_pages(greedy_runs, setup):
    """Rejected-suffix rollback on a paged pool: streams unchanged and —
    under the debug audit — every page is back in the free list."""
    spec = greedy_runs[2]
    outs, summ = _run(setup, speculative=True, spec_gamma=2, page_size=16)
    for rid in spec:
        assert np.array_equal(spec[rid], outs[rid]), rid
    assert summ["final_pages_in_use"] == 0


def test_sampled_invariance(setup):
    """T > 0: accepted streams keyed on (seed, rid, token-index) only —
    macro-step size and slot placement must not change them."""
    base, s_base = _run(setup, speculative=True, spec_gamma=2,
                        temperature=0.8, seed=3)
    for kw in ({"macro_ticks": 2}, {"num_slots": 4}):
        outs, _ = _run(setup, speculative=True, spec_gamma=2,
                       temperature=0.8, seed=3, **kw)
        for rid in base:
            assert np.array_equal(base[rid], outs[rid]), (kw, rid)
    assert 0.0 < s_base["draft_acceptance_rate"] < 1.0


# ---------------------------------------------------------------------------
# Composition with the fault-tolerance stack
# ---------------------------------------------------------------------------


@pytest.mark.chaos
def test_crash_restore_byte_identity(greedy_runs, setup, tmp_path):
    """Journal replay + checkpoint restore under speculative decoding:
    only *accepted* tokens hit the journal, so a mid-flight crash
    restores to byte-identical streams (including the draft pool)."""
    cfg, params, mesh = setup
    ref = greedy_runs[0]
    d = str(tmp_path)
    sv = _sv(speculative=True, spec_gamma=2, checkpoint_every_ticks=6)
    jr = journal_lib.Journal(os.path.join(d, journal_lib.JOURNAL_NAME))
    inj = faults.FaultInjector(crash_window=(9, 9))
    eng = ContinuousServingEngine(cfg, params, mesh, serving=sv,
                                  fault_injector=inj, journal=jr)
    with pytest.raises(faults.EngineCrash):
        eng.run(_trace(cfg))

    eng2 = ContinuousServingEngine.restore(d, cfg, params, mesh, serving=sv)
    assert eng2.recovery["checkpoint_used"]
    outs, _ = eng2.run()
    for rid in ref:
        assert np.array_equal(ref[rid], outs[rid]), rid


@pytest.mark.chaos
def test_quarantine_retry_byte_identity(greedy_runs, setup):
    """NaN-corrupted verifier slots are quarantined at the round fault
    lane; the retried requests still reproduce the reference streams."""
    cfg, params, mesh = setup
    ref = greedy_runs[0]
    inj = faults.FaultInjector(nan_every=8, seed=5)
    eng = ContinuousServingEngine(
        cfg, params, mesh, serving=_sv(speculative=True, spec_gamma=2),
        fault_injector=inj)
    outs, summ = eng.run(_trace(cfg))
    assert summ["faults_detected"] >= 1
    assert summ["fault_retries_succeeded"] == summ["faults_detected"]
    for rid in ref:
        assert np.array_equal(ref[rid], outs[rid]), rid


def test_restore_rejects_spec_mismatch(greedy_runs, setup, tmp_path):
    """A journal written in speculative mode cannot be restored into a
    non-speculative engine (or a different gamma): the tagged substreams
    and gamma-dependent bonus indices would change sampled streams."""
    cfg, params, mesh = setup
    d = str(tmp_path)
    sv = _sv(speculative=True, spec_gamma=2, checkpoint_every_ticks=6)
    jr = journal_lib.Journal(os.path.join(d, journal_lib.JOURNAL_NAME))
    inj = faults.FaultInjector(crash_window=(9, 9))
    eng = ContinuousServingEngine(cfg, params, mesh, serving=sv,
                                  fault_injector=inj, journal=jr)
    with pytest.raises(faults.EngineCrash):
        eng.run(_trace(cfg))
    for bad in (dataclasses.replace(sv, speculative=False),
                dataclasses.replace(sv, spec_gamma=3)):
        with pytest.raises(ValueError, match="speculative"):
            ContinuousServingEngine.restore(d, cfg, params, mesh,
                                            serving=bad)


def test_config_validation(setup):
    cfg, params, mesh = setup
    with pytest.raises(ValueError, match="spec_gamma"):
        _sv(speculative=True, spec_gamma=0)
    with pytest.raises(ValueError, match="mutually"):
        _sv(speculative=True, prefix_cache_bytes=1 << 20)
    lin = configs.get_smoke_config("slayformer-124m", attn_kind="slay")
    assert not api.supports_speculative(lin)
    with pytest.raises(ValueError, match="speculative"):
        ContinuousServingEngine(
            lin, api.init_params(lin, jax.random.PRNGKey(0)), mesh,
            serving=_sv(speculative=True))
