"""On-device serving hot loop (decode path).

Covers the PR-3 vertical slice: fused on-device sampling (seeded parity vs
the host oracle, slot-placement invariance), the active-slot mask threaded
through the model decode path (reference-path state passthrough vs the
Pallas active-row oracle, both cache regimes), K-tick macro-stepping
(K=1 vs K>1 token-stream and eviction parity), the length-bucketed masked
prefill fallback, and the host-sync cadence metrics.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ServingConfig
from repro.kernels import ops
from repro.launch.mesh import make_host_mesh
from repro.models import api
from repro.models import attention as attn
from repro.serving import sampling
from repro.serving.engine import (ContinuousServingEngine, Request,
                                  ServingEngine)


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get_smoke_config("slayformer-124m")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    mesh = make_host_mesh()
    return cfg, params, mesh


def _prompts(cfg, lengths, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(3, cfg.vocab_size, size=n).astype(np.int32)
            for n in lengths]


# ---------------------------------------------------------------------------
# Fused on-device sampling
# ---------------------------------------------------------------------------


@pytest.mark.serving
@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_device_sampler_matches_host(temperature):
    """The fused sampler and the host oracle pick identical tokens for the
    same (seed, rid, idx) keys — greedy and Gumbel."""
    logits = np.asarray(jax.random.normal(jax.random.PRNGKey(3), (5, 91)),
                        np.float32)
    rids = np.array([7, 0, 3, 3, 12], np.int32)
    idxs = np.array([0, 5, 1, 2, 9], np.int32)
    toks = sampling.sample_tokens(jnp.asarray(logits), jnp.asarray(rids),
                                  jnp.asarray(idxs),
                                  temperature=temperature, seed=11)
    for i in range(5):
        want = sampling.host_sample_token(
            logits[i], int(rids[i]), int(idxs[i]),
            temperature=temperature, seed=11)
        assert int(toks[i]) == want


@pytest.mark.serving
def test_sampler_independent_of_slot_placement():
    """Sampling is keyed on (seed, rid, idx) — the same request samples the
    same token regardless of which pool row it occupies or who shares the
    batch (the property that makes K=1 and K>1 streams identical)."""
    row = np.asarray(jax.random.normal(jax.random.PRNGKey(4), (64,)),
                     np.float32)
    batch = np.stack([row, row + 1.0, row])      # rid 5 in slots 0 and 2
    rids = jnp.asarray([5, 1, 5], jnp.int32)
    idxs = jnp.asarray([2, 2, 2], jnp.int32)
    toks = sampling.sample_tokens(jnp.asarray(batch), rids, idxs,
                                  temperature=0.9, seed=0)
    alone = sampling.sample_tokens(jnp.asarray(row[None]),
                                   jnp.asarray([5], jnp.int32),
                                   jnp.asarray([2], jnp.int32),
                                   temperature=0.9, seed=0)
    assert int(toks[0]) == int(toks[2]) == int(alone[0])


# ---------------------------------------------------------------------------
# Masked decode through the model path
# ---------------------------------------------------------------------------


def _leaves_at_slot(cache, slot, batch):
    out = []
    for x in jax.tree.leaves(cache):
        a = np.asarray(x)
        if a.ndim >= 2 and a.shape[1] == batch:   # (nl, B, ...) leaves
            out.append(a[:, slot].copy())
    return out


@pytest.mark.serving
@pytest.mark.parametrize("kind", ["slay", "softmax"])
def test_masked_decode_state_passthrough(kind):
    """Model-path masked decode honours the Pallas kernel contract on both
    cache regimes: drained slots keep every cache byte (incl. pos)
    bit-identical, active slots match the unmasked decode exactly."""
    cfg = configs.get_smoke_config("slayformer-124m", attn_kind=kind)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 7), 0,
                              cfg.vocab_size)
    pool = api.init_cache(cfg, 3, 32)
    _, req = api.prefill(params, cfg, {"tokens": toks}, max_len=32)
    pool = api.write_slot(cfg, pool, req, 0)
    pool = api.write_slot(cfg, pool, req, 2)
    step_tok = jnp.full((3, 1), 5, jnp.int32)
    active = jnp.asarray([True, False, True])

    before_slot1 = _leaves_at_slot(pool, 1, 3)
    lg_m, cache_m = api.decode_step(params, cfg, pool, step_tok, active)
    lg_u, cache_u = api.decode_step(params, cfg, pool, step_tok)

    # Drained slot: every stacked leaf bit-identical, pos frozen.
    after_slot1 = _leaves_at_slot(cache_m, 1, 3)
    for b, a in zip(before_slot1, after_slot1):
        np.testing.assert_array_equal(b, a)
    assert np.asarray(cache_m.pos).tolist() == [8, 0, 8]

    # Active slots: logits and cache match the unmasked decode exactly.
    np.testing.assert_array_equal(np.asarray(lg_m[0]), np.asarray(lg_u[0]))
    np.testing.assert_array_equal(np.asarray(lg_m[2]), np.asarray(lg_u[2]))
    for xm, xu in zip(_leaves_at_slot(cache_m, 0, 3),
                      _leaves_at_slot(cache_u, 0, 3)):
        np.testing.assert_array_equal(xm, xu)


@pytest.mark.serving
@pytest.mark.kernels
def test_masked_reference_matches_pallas_active_row_oracle():
    """attention.decode_step's reference-path masking and the decode
    kernel's active-row semantics (via ops.decode_linear_step, interpret
    kernel + jnp oracle) agree on the constant-state regime."""
    rng = np.random.default_rng(0)
    B, hkv, g, m, dv = 4, 2, 2, 16, 8
    qf = jnp.asarray(rng.standard_normal((B, hkv * g, m)), jnp.float32)
    kf = jnp.asarray(np.abs(rng.standard_normal((B, hkv, m))), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, hkv, dv)), jnp.float32)
    s = jnp.asarray(rng.standard_normal((B, hkv, m, dv)), jnp.float32)
    z = jnp.asarray(np.abs(rng.standard_normal((B, hkv, m))), jnp.float32)
    active = jnp.asarray([1, 0, 1, 0], jnp.int32)

    # Oracle path (jnp reference, active-row masked).
    y_r, s_r, z_r = ops.decode_linear_step(qf, kf, v, s, z, active)
    # Interpret-mode Pallas kernel, same masked semantics.
    y_k, s_k, z_k = ops.decode_linear_step(qf, kf, v, s, z, active,
                                           interpret=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(z_k), np.asarray(z_r), atol=1e-5)
    # Drained rows: exact passthrough and zero output on both paths.
    for s2, z2, y2 in ((s_r, z_r, y_r), (s_k, z_k, y_k)):
        np.testing.assert_array_equal(np.asarray(s2[1]), np.asarray(s[1]))
        np.testing.assert_array_equal(np.asarray(z2[3]), np.asarray(z[3]))
        assert np.all(np.asarray(y2[1]) == 0)
        assert np.all(np.asarray(y2[3]) == 0)


@pytest.mark.serving
def test_masked_decode_requires_vector_pos():
    spec = configs.get_smoke_config("slayformer-124m").attention_spec()
    cache = attn.init_cache(spec, (), 1, 4, 4, 8, jnp.float32)
    q = jnp.zeros((2, 4))
    with pytest.raises(ValueError, match="per-slot"):
        attn.decode_step(spec, None, q, q[:1], q[:1], cache,
                         active=jnp.asarray([True]))


# ---------------------------------------------------------------------------
# K-tick macro-stepping
# ---------------------------------------------------------------------------


@pytest.mark.serving
@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_macro_step_vs_per_tick_parity(setup, temperature):
    """K=8 and K=1 engines emit byte-identical per-request token streams
    (greedy and sampled), complete the same requests, and preserve the
    slot-reuse/eviction invariant."""
    cfg, params, mesh = setup
    prompts = _prompts(cfg, (5, 9, 3, 7), seed=2)

    def run(K):
        reqs = [Request(p, max_new_tokens=6, arrival_time=float(2 * i))
                for i, p in enumerate(prompts)]
        eng = ContinuousServingEngine(
            cfg, params, mesh,
            serving=ServingConfig(num_slots=2, max_len=64, prefill_chunk=4,
                                  macro_ticks=K, temperature=temperature,
                                  seed=13))
        outs, summary = eng.run(reqs)
        return eng, outs, summary

    eng8, outs8, sum8 = run(8)
    eng1, outs1, sum1 = run(1)
    assert sum8["requests_completed"] == sum1["requests_completed"] == 4
    for rid in outs1:
        np.testing.assert_array_equal(outs8[rid], outs1[rid])
    # Dispatch amortization actually happened under K=8.
    assert sum8["decode_dispatches"] < sum1["decode_dispatches"]
    assert sum8["dispatches_per_decode_tick"] <= 1.0
    # Eviction invariant holds under macro-stepping: a slot's next tenant
    # is admitted no earlier than the previous tenant finished.
    for eng in (eng8, eng1):
        by_slot = {}
        for st in eng.metrics.per_request.values():
            by_slot.setdefault(st.slot, []).append(st)
        for tenants in by_slot.values():
            tenants.sort(key=lambda s: s.admitted)
            for prev, nxt in zip(tenants, tenants[1:]):
                assert nxt.admitted >= prev.finished


@pytest.mark.serving
def test_macro_step_eos_mid_buffer(setup):
    """A slot hitting EOS mid-macro-step is masked on device for the
    remaining ticks: nothing is emitted past EOS and the slot is reused."""
    cfg, params, mesh = setup
    p0, p1 = _prompts(cfg, (4, 6), seed=3)
    ref = ServingEngine(cfg, params, mesh, max_len=64)
    first = ref.generate([Request(p0, max_new_tokens=8)])[0]
    # EOS = a greedy token whose *first* occurrence is past the prefill
    # token, so the stop happens inside the macro-step buffer.
    eos, cut = int(first[0]), 0
    for i in range(1, len(first)):
        if first[i] not in first[:i]:
            eos, cut = int(first[i]), i
            break
    reqs = [Request(p0, max_new_tokens=8, eos_id=eos),
            Request(p1, max_new_tokens=4, arrival_time=1.0)]
    eng = ContinuousServingEngine(
        cfg, params, mesh,
        serving=ServingConfig(num_slots=1, max_len=64, prefill_chunk=4,
                              macro_ticks=8))
    outs, summary = eng.run(reqs)
    assert summary["requests_completed"] == 2
    np.testing.assert_array_equal(outs[0], first[:cut + 1])  # eos inclusive
    want1 = ref.generate([Request(p1, max_new_tokens=4)])[0]
    np.testing.assert_array_equal(outs[1], want1)
    st = eng.metrics.per_request
    assert st[0].slot == st[1].slot == 0
    assert st[1].admitted >= st[0].finished


@pytest.mark.serving
def test_macro_streaming_and_ttft_per_tick(setup):
    """Streaming callbacks fire per replayed tick with exact tick-granular
    TTFT — not once per host sync."""
    cfg, params, mesh = setup
    prompts = _prompts(cfg, (6, 4), seed=5)
    seen = {}

    def on_token(rid, tok):
        seen.setdefault(rid, []).append(tok)

    reqs = [Request(p, max_new_tokens=5, on_token=on_token)
            for p in prompts]
    eng = ContinuousServingEngine(
        cfg, params, mesh,
        serving=ServingConfig(num_slots=2, max_len=64, prefill_chunk=4,
                              macro_ticks=8))
    outs, summary = eng.run(reqs)
    for rid, p in enumerate(prompts):
        np.testing.assert_array_equal(np.asarray(seen[rid], np.int32),
                                      outs[rid])
    # TTFT is recorded at the (prefill) tick the first token was emitted,
    # so it is well-defined and tick-exact under macro-stepping.
    for st in eng.metrics.per_request.values():
        assert st.ttft_ticks is not None and st.ttft_ticks >= 0
    # Per-tick accounting: replayed decode ticks count individually (more
    # ticks than dispatches), and the tick clock covers every decode tick
    # — metrics were sampled per replayed tick, not per host sync.
    assert summary["decode_ticks"] > summary["decode_dispatches"]
    assert summary["ticks"] >= (summary["prefill_ticks"]
                                + summary["decode_ticks"])


# ---------------------------------------------------------------------------
# Length-bucketed masked prefill fallback
# ---------------------------------------------------------------------------


@pytest.mark.serving
def test_masked_prefill_matches_unpadded():
    """Right-padded prefill with true_len reproduces the unpadded prefill:
    same last-token logits, same decode continuation, same pos."""
    cfg = configs.get_smoke_config("slayformer-124m",
                                   attn_kind="yat_spherical")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 7), 3,
                              cfg.vocab_size)
    lg_u, cache_u = api.prefill(params, cfg, {"tokens": toks}, max_len=32)
    padded = jnp.pad(toks, ((0, 0), (0, 9)))             # 7 -> 16 bucket
    lg_m, cache_m = api.prefill(params, cfg, {"tokens": padded},
                                max_len=32,
                                true_len=jnp.asarray([7], jnp.int32))
    np.testing.assert_allclose(np.asarray(lg_m, np.float32),
                               np.asarray(lg_u, np.float32), atol=1e-4)
    assert np.asarray(cache_m.pos).tolist() == [7]
    tok = jnp.argmax(lg_u[:, -1], -1).astype(jnp.int32)[:, None]
    for _ in range(3):
        l_u, cache_u = api.decode_step(params, cfg, cache_u, tok)
        l_m, cache_m = api.decode_step(params, cfg, cache_m, tok)
        np.testing.assert_allclose(np.asarray(l_m, np.float32),
                                   np.asarray(l_u, np.float32), atol=1e-4)
        tok = jnp.argmax(l_u[:, -1], -1).astype(jnp.int32)[:, None]


@pytest.mark.serving
def test_bucketed_fallback_parity_and_metrics(setup):
    """The bucketed masked-prefill fallback still serves exactly via pow-2
    buckets: token parity with the lockstep oracle, one compile per
    bucket, and hit/miss counts exposed in the engine metrics. Exact-yat
    kinds chunk by default now (DESIGN.md §9), so the fallback is routed
    explicitly with prefill_chunk=0."""
    cfg = configs.get_smoke_config("slayformer-124m",
                                   attn_kind="yat_spherical")
    assert api.supports_chunked_prefill(cfg)     # fallback retired for yat
    assert api.supports_masked_prefill(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    mesh = setup[2]
    prompts = _prompts(cfg, (5, 9, 3, 12), seed=4)   # buckets 16,16,16,16
    reqs = [Request(p, max_new_tokens=4, arrival_time=float(i))
            for i, p in enumerate(prompts)]
    eng = ContinuousServingEngine(
        cfg, params, mesh,
        serving=ServingConfig(num_slots=2, max_len=64, prefill_chunk=0,
                              macro_ticks=4))
    outs, summary = eng.run(reqs)
    assert summary["requests_completed"] == 4
    assert summary["bucket_misses"] == 1        # single pow-2 bucket: 16
    assert summary["bucket_hits"] == 3
    assert eng.jit_cache_entries()["prefill_masked"] == 1
    ref = ServingEngine(cfg, params, mesh, max_len=64)
    for i, p in enumerate(prompts):
        want = ref.generate([Request(p, max_new_tokens=4)])[0]
        np.testing.assert_array_equal(outs[i], want)


@pytest.mark.serving
def test_masked_prefill_unsupported_families_raise():
    cfg = configs.get_smoke_config("mamba2-780m")
    assert not api.supports_masked_prefill(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.zeros((1, 8), jnp.int32)
    with pytest.raises(NotImplementedError):
        api.prefill(params, cfg, {"tokens": toks}, max_len=32,
                    true_len=jnp.asarray([4], jnp.int32))


# ---------------------------------------------------------------------------
# Host-sync cadence metrics
# ---------------------------------------------------------------------------


@pytest.mark.serving
def test_host_sync_cadence_contract(setup):
    """With K=8 and enough decode work, the decode loop syncs to host at
    most once per 8 generated tokens, dispatches once per pool (never per
    slot), and the macro-step stays a single jit cache entry."""
    cfg, params, mesh = setup
    prompts = _prompts(cfg, (5, 7, 4, 6), seed=6)
    reqs = [Request(p, max_new_tokens=16, arrival_time=float(i))
            for i, p in enumerate(prompts)]
    eng = ContinuousServingEngine(
        cfg, params, mesh,
        serving=ServingConfig(num_slots=2, max_len=64, prefill_chunk=4,
                              macro_ticks=8))
    _, summary = eng.run(reqs)
    assert summary["requests_completed"] == 4
    assert summary["host_syncs_per_token"] <= 1.0 / 8 + 1e-9
    assert summary["tokens_per_dispatch"] >= 8.0
    assert summary["dispatches_per_decode_tick"] <= 1.0
    entries = eng.jit_cache_entries()
    assert entries["macro_decode"] == 1
    assert entries["sample"] == 1
