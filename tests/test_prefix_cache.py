"""Content-addressed prefix cache (DESIGN.md §11): keying/collision
safety, chunk-multiple candidate discipline, LRU eviction under the byte
budget with refcount pinning, full-hit logits requirements — and
engine-level cached-vs-cold stream *byte*-identity for both cache regimes
(constant-state and KV ring, paged and unpaged)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ServingConfig
from repro.launch.mesh import make_host_mesh
from repro.models import api
from repro.serving.engine import ContinuousServingEngine, Request
from repro.serving.prefix_cache import PrefixCache, token_digest


# ---------------------------------------------------------------------------
# Unit level
# ---------------------------------------------------------------------------


def _cache(n=100):
    return {"state": jnp.zeros((n,), jnp.float32)}    # 4n bytes


def test_digest_collision_cannot_false_hit():
    """Even a pathological digest function (everything collides) never
    returns a wrong entry: the stored tokens are compared outright."""
    pc = PrefixCache(1 << 20, digest_fn=lambda toks: b"collide")
    a, b = np.int32([1, 2, 3, 4]), np.int32([1, 2, 9, 9])
    pc.insert(a, _cache(), logits=jnp.zeros((1, 1, 8)))
    got = pc.lookup(b, chunk=4)
    assert got is None and pc.misses == 1
    got = pc.lookup(a, chunk=4)
    assert got is not None and got.length == 4 and pc.hits == 1


def test_token_digest_is_length_and_content_addressed():
    assert token_digest(np.int32([1, 2])) != token_digest(np.int32([2, 1]))
    assert token_digest(np.int32([1, 2])) == token_digest(
        np.asarray([1, 2], np.int64))             # canonical int32 bytes


def test_lookup_serves_only_chunk_multiples():
    """Proper prefixes at non-chunk-multiple lengths are never served —
    the suffix chunk schedule must match a cold prefill's."""
    pc = PrefixCache(1 << 20)
    toks = np.int32(range(10))
    pc.insert(toks[:5], _cache())                 # not a multiple of 4
    pc.insert(toks[:4], _cache())
    got = pc.lookup(toks, chunk=4)
    assert got is not None and got.length == 4    # 8 absent, 5 skipped
    pc.insert(toks[:8], _cache())
    got = pc.lookup(toks, chunk=4)
    assert got.length == 8                        # longest multiple wins


def test_full_hit_requires_stored_logits():
    """A full-length entry without logits cannot seed token 0, so lookup
    falls through to a proper-prefix candidate; insert() upgrades the
    entry in place once logits become available."""
    pc = PrefixCache(1 << 20)
    toks = np.int32(range(8))
    pc.insert(toks, _cache())                     # full length, no logits
    pc.insert(toks[:4], _cache())
    got = pc.lookup(toks, chunk=4)
    assert got.length == 4                        # full entry skipped
    e = pc.insert(toks, _cache(), logits=jnp.ones((1, 1, 8)))
    assert e.logits is not None                   # upgraded, not duplicated
    got = pc.lookup(toks, chunk=4)
    assert got.length == 8 and got is e
    assert len(pc) == 2


def test_lru_eviction_under_byte_budget():
    pc = PrefixCache(900)
    lg = jnp.zeros((1, 1, 4), jnp.float32)        # 16 bytes
    t = np.int32(range(12))
    e1 = pc.insert(t[:4], _cache(100), logits=lg)     # 416 bytes
    pc.insert(t[:8], _cache(100), logits=lg)          # 416 bytes
    assert pc.lookup(t[:4], chunk=4) is e1        # refresh e1's stamp
    pc.insert(t[:12], _cache(100), logits=lg)     # needs room -> evict LRU
    assert pc.evictions == 1 and len(pc) == 2
    assert pc.lookup(t[:4], chunk=4) is e1        # refreshed entry survives
    got = pc.lookup(t[:8], chunk=4)
    assert got is e1                              # LRU victim gone: falls
    assert got.length == 4                        # back to the short prefix
    assert pc.nbytes <= 900


def test_referenced_entries_are_never_evicted():
    pc = PrefixCache(1000)
    t = np.int32(range(8))
    e1 = pc.insert(t[:4], _cache(100))
    e2 = pc.insert(t[:8], _cache(100),
                   logits=jnp.zeros((1, 1, 4), jnp.float32))
    pc.acquire(e1)
    pc.acquire(e2)
    assert pc.insert(t[:6], _cache(100)) is None  # both pinned: no room
    assert len(pc) == 2 and pc.evictions == 0
    pc.release(e1)
    assert pc.insert(t[:6], _cache(100)) is not None
    assert pc.lookup(t[:8], chunk=4) is e2        # pinned entry survived


def test_insert_copy_snapshots_buffers():
    """copy=True must deep-copy: mutating (donating) the caller's buffer
    after insert cannot corrupt the stored snapshot."""
    pc = PrefixCache(1 << 20)
    src = {"state": jnp.ones((4,), jnp.float32)}
    e = pc.insert(np.int32([1, 2, 3, 4]), src)
    src["state"] = src["state"] * 0               # caller moves on
    np.testing.assert_array_equal(np.asarray(e.cache["state"]),
                                  np.ones(4, np.float32))


def test_stats_shape():
    pc = PrefixCache(1 << 20)
    pc.insert(np.int32([1, 2]), _cache(), logits=jnp.zeros((1, 1, 4)))
    pc.lookup(np.int32([1, 2]), chunk=2)
    pc.lookup(np.int32([7, 7]), chunk=2)
    s = pc.stats()
    assert s["hits"] == 1 and s["misses"] == 1 and s["hit_rate"] == 0.5
    assert s["entries"] == 1 and s["tokens_reused"] == 2
    assert s["bytes"] == pc.nbytes > 0


# ---------------------------------------------------------------------------
# Engine level: cached-vs-cold byte identity
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh()


def _shared_prefix_reqs(cfg, n=4, prefix_len=8, seed=17):
    """n prompts sharing a prefix_len system prefix + 1 unique token."""
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(3, cfg.vocab_size, size=prefix_len)
    return [Request(np.concatenate([sys_prompt, [3 + i]]).astype(np.int32),
                    max_new_tokens=8, arrival_time=float(i))
            for i in range(n)]


def _serve(cfg, params, mesh, reqs, *, pc=None, page_size=0):
    eng = ContinuousServingEngine(
        cfg, params, mesh, prefix_cache=pc,
        serving=ServingConfig(num_slots=2, max_len=32, prefill_chunk=4,
                              macro_ticks=4, page_size=page_size))
    outs, summary = eng.run(
        [Request(r.prompt, max_new_tokens=r.max_new_tokens,
                 arrival_time=r.arrival_time) for r in reqs])
    return eng, outs, summary


@pytest.mark.serving
@pytest.mark.parametrize("arch_kind,page_size", [
    (("slayformer-124m", "slay"), 0),         # constant-state (no paging)
    (("slayformer-124m", "softmax"), 0),      # KV ring, unpaged
    (("slayformer-124m", "softmax"), 8),      # KV ring, paged
], ids=["constant_state", "kv_ring", "kv_ring_paged"])
def test_cached_streams_byte_identical_to_cold(arch_kind, page_size, mesh):
    """A warmed shared cache full-hits every request of a replayed trace
    and the streams are byte-identical to the cold run, in every cache
    regime; with paging on, no pages leak."""
    arch, kind = arch_kind
    cfg = configs.get_smoke_config(arch, attn_kind=kind)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    reqs = _shared_prefix_reqs(cfg)
    _, cold, s_cold = _serve(cfg, params, mesh, reqs,
                             page_size=page_size)  # no cache: truly cold
    assert s_cold["prefix_hits"] == 0
    pc = PrefixCache(64 * 1024 * 1024)
    _serve(cfg, params, mesh, reqs, pc=pc, page_size=page_size)  # warm-up
    _, warm, s_warm = _serve(cfg, params, mesh, reqs, pc=pc,
                             page_size=page_size)
    assert s_warm["prefix_hits"] == len(reqs)     # replay: all full hits
    assert s_warm["prefix_tokens_reused"] == sum(len(r.prompt)
                                                 for r in reqs)
    for rid in cold:
        np.testing.assert_array_equal(cold[rid], warm[rid])
    if page_size:
        assert s_cold["final_pages_in_use"] == 0
        assert s_warm["final_pages_in_use"] == 0


@pytest.mark.serving
def test_partial_prefix_hit_within_one_engine(mesh):
    """Within a single engine, later arrivals partial-hit the shared
    chunk-boundary snapshot stored by the first request; their streams
    match a no-cache run byte-for-byte and only suffix tokens prefill."""
    cfg = configs.get_smoke_config("slayformer-124m", attn_kind="softmax")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    # Arrivals spaced out so request 0 finishes prefill (and inserts its
    # chunk-boundary snapshots) before the others are admitted.
    reqs = _shared_prefix_reqs(cfg)
    reqs = [Request(r.prompt, max_new_tokens=8, arrival_time=i * 30.0)
            for i, r in enumerate(reqs)]
    _, plain, _ = _serve(cfg, params, mesh, reqs)
    e, outs, s = _serve(cfg, params, mesh, reqs, pc=PrefixCache(1 << 26))
    assert s["prefix_hits"] >= len(reqs) - 1      # all but the first
    assert s["prefix_tokens_reused"] >= (len(reqs) - 1) * 8
    for rid in plain:
        np.testing.assert_array_equal(plain[rid], outs[rid])
    # The hit requests absorbed only their suffix at prefill time.
    assert s["prompt_tokens"] < sum(len(r.prompt) for r in reqs)
    for rid, st in e.metrics.per_request.items():
        if st.prefix_cached:
            assert st.prefix_tokens == 8          # the 2-chunk system prefix


@pytest.mark.serving
def test_identical_prompt_full_hit_skips_prefill(mesh):
    """The second submission of an identical prompt seeds from the stored
    snapshot + logits: zero prompt tokens absorbed, identical stream."""
    cfg = configs.get_smoke_config("slayformer-124m", attn_kind="slay")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    prompt = np.int32([4, 5, 6, 7, 8, 9, 10, 11])
    reqs = [Request(prompt, max_new_tokens=8, arrival_time=0.0),
            Request(prompt, max_new_tokens=8, arrival_time=40.0)]
    e, outs, s = _serve(cfg, params, mesh, reqs, pc=PrefixCache(1 << 26))
    assert s["prefix_hits"] == 1
    np.testing.assert_array_equal(outs[0], outs[1])
    st = e.metrics.per_request[1]
    assert st.prefix_cached and st.prefix_tokens == len(prompt)
    assert s["prompt_tokens"] == len(prompt)      # absorbed exactly once
    # TTFT split metrics surface the win.
    assert s["ttft_cached_ticks_p50"] is not None
    assert s["ttft_cold_ticks_p50"] is not None
