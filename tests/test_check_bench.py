"""Bench-regression gate unit behavior (tools/check_bench.py): a row new
in the current run but absent from the baseline is noted and skipped —
never a crash or a failure — while a baseline row gone missing still
fails, and rows without a ``load`` key (e.g. crash-recovery rows before
their regime prefix skip) cannot KeyError the gate."""
import json
import os
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_REPO, "tools"))

import check_bench  # noqa: E402


def _payload(*rows):
    return {"results": list(rows)}


def _row(regime, load=1.0, **metrics):
    base = {"regime": regime, "load": load, "tokens_per_dispatch": 3.0,
            "host_syncs_per_token": 0.25, "mean_slot_occupancy": 0.9}
    base.update(metrics)
    return base


def test_identical_runs_pass():
    lines, bad = check_bench.compare(_payload(_row("steady")),
                                     _payload(_row("steady")))
    assert not bad
    assert not any("REGRESSION" in ln for ln in lines)


def test_new_row_is_noted_not_failed():
    """A regime added by the current change (no baseline entry yet) must
    not fail the gate — it gets a visible note and is skipped."""
    baseline = _payload(_row("steady"))
    current = _payload(_row("steady"), _row("brand_new_regime"))
    lines, bad = check_bench.compare(baseline, current)
    assert not bad
    note = [ln for ln in lines if "brand_new_regime" in ln]
    assert note and "new row (not in baseline)" in note[0]


def test_missing_baseline_row_fails():
    baseline = _payload(_row("steady"), _row("burst"))
    current = _payload(_row("steady"))
    lines, bad = check_bench.compare(baseline, current)
    assert bad
    assert any("MISSING ROW" in ln and "burst" in ln for ln in lines)


def test_regression_detected_and_improvement_tolerated():
    baseline = _payload(_row("steady"))
    worse = _payload(_row("steady", host_syncs_per_token=0.5))
    _, bad = check_bench.compare(baseline, worse)
    assert bad
    better = _payload(_row("steady", host_syncs_per_token=0.1))
    _, bad = check_bench.compare(baseline, better)
    assert not bad


def test_chaos_and_crash_rows_excluded_and_load_optional():
    """Chaos/crash-recovery rows never enter the trend gate, and a row
    without a ``load`` key parses (defaults to 0.0) instead of raising."""
    cur = _payload(_row("steady"),
                   {"regime": "chaos_nan", "streams_ok": True},
                   {"regime": "crash_recovery_paged",
                    "streams_byte_identical": True},
                   _row("no_load_regime").copy())
    del cur["results"][-1]["load"]
    rows = check_bench._rows(cur)
    assert ("steady", 1.0) in rows
    assert ("no_load_regime", 0.0) in rows
    assert not any(r.startswith(("chaos", "crash")) for r, _ in rows)
    lines, bad = check_bench.compare(_payload(_row("steady")), cur)
    assert not bad


def test_cli_new_row_path_exits_zero(tmp_path):
    """End-to-end: the CLI exits 0 when the fresh run adds a row the
    committed baseline has never seen."""
    bp = tmp_path / "baseline.json"
    cp = tmp_path / "current.json"
    bp.write_text(json.dumps(_payload(_row("steady"))))
    cp.write_text(json.dumps(_payload(_row("steady"),
                                      _row("crash_recovery_kv_ring"),
                                      _row("fresh_regime"))))
    rc = check_bench.main(["--baseline", str(bp), "--current", str(cp)])
    assert rc == 0
