"""Fused SLAY megakernel + custom VJPs vs jax.grad through the jnp oracles.

All Pallas calls run interpret=True on CPU. Forward parity covers GQA group
sizes and ragged (non-chunk-multiple) lengths through the padding wrappers;
gradient parity checks every differentiable input of every kernel against
autodiff through the mathematically-audited ``repro.core`` references.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import linear_attention as la
from repro.core.features import (SlayFeatureConfig, init_feature_params,
                                 slay_features)
from repro.core.slay import slay_attention
from repro.kernels import decode_step as dk
from repro.kernels import ops, ref, slay_fused, slay_scan

pytestmark = pytest.mark.kernels

ATOL, RTOL = 2e-4, 2e-4


def _cfg(d=16, P=4, D=8, R=2):
    return SlayFeatureConfig(head_dim=d, num_anchors=P, num_prf=D,
                             num_quad_nodes=R)


def _inputs(key, bh, bk, L, d, dv):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (bh, L, d))
    k = jax.random.normal(kk, (bk, L, d))
    v = jax.random.normal(kv, (bk, L, dv))
    return q, k, v


def _oracle_headmajor(q, k, v, params, cfg, chunk):
    """Fused-attention oracle in the kernel's head-major layout."""
    bh, L, _ = q.shape
    bk, _, dv = v.shape
    g = bh // bk
    qf = slay_features(q, params, cfg)
    kf = slay_features(k, params, cfg)
    qq = qf.reshape(bk, g, L, -1).transpose(0, 2, 1, 3)
    y = la.causal_chunked(qq, kf[:, :, None, :], v[:, :, None, :],
                          chunk_size=chunk)
    return y.transpose(0, 2, 1, 3).reshape(bh, L, dv)


# ---------------------------------------------------------------------------
# Fused megakernel: forward parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bh,bk,L,d,dv,chunk", [
    (4, 2, 32, 16, 8, 8),      # GQA g=2
    (2, 2, 32, 16, 16, 16),    # MHA
    (6, 1, 48, 24, 8, 16),     # MQA g=6
    (8, 4, 64, 32, 32, 32),    # bigger
])
def test_fused_forward_matches_oracle(bh, bk, L, d, dv, chunk):
    cfg = _cfg(d=d)
    params = init_feature_params(jax.random.PRNGKey(0), cfg)
    q, k, v = _inputs(jax.random.PRNGKey(1), bh, bk, L, d, dv)
    got = slay_fused.fused_causal_attention(
        q, k, v, params["anchors"], params["omegas"], cfg,
        chunk_size=chunk, interpret=True)
    want = _oracle_headmajor(q, k, v, params, cfg, chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=ATOL, rtol=RTOL)


@pytest.mark.parametrize("g", [1, 2, 4])
@pytest.mark.parametrize("L", [17, 31, 64])
def test_fused_wrapper_gqa_and_ragged_lengths(g, L):
    """ops.slay_fused_attention: model layout, padding, GQA group sizes."""
    B, hkv, d, dv, chunk = 2, 2, 16, 16, 16
    H = hkv * g
    cfg = _cfg(d=d)
    params = init_feature_params(jax.random.PRNGKey(0), cfg)
    q = jax.random.normal(jax.random.PRNGKey(1), (B, L, H, d))
    k = jax.random.normal(jax.random.PRNGKey(2), (B, L, hkv, d))
    v = jax.random.normal(jax.random.PRNGKey(3), (B, L, hkv, dv))
    got = ops.slay_fused_attention(q, k, v, params, cfg, chunk_size=chunk,
                                   interpret=True)
    qf = slay_features(q, params, cfg)
    kf = slay_features(k, params, cfg)
    want = la.causal_chunked(qf, kf, v, chunk_size=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=ATOL, rtol=RTOL)


@pytest.mark.parametrize("fuse", [True, False])
def test_slay_attention_grad_use_kernel_matches_jnp(fuse):
    """Acceptance: jax.grad through slay_attention(use_kernel=True) ==
    the jnp path to fp32 tolerance (interpret mode)."""
    B, L, H, hkv, d = 2, 24, 4, 2, 16
    cfg = _cfg(d=d)
    params = init_feature_params(jax.random.PRNGKey(0), cfg)
    q = jax.random.normal(jax.random.PRNGKey(1), (B, L, H, d))
    k = jax.random.normal(jax.random.PRNGKey(2), (B, L, hkv, d))
    v = jax.random.normal(jax.random.PRNGKey(3), (B, L, hkv, d))
    w = jax.random.normal(jax.random.PRNGKey(4), (B, L, H, d))

    def loss(q, k, v, use_kernel):
        y = slay_attention(params, q, k, v, cfg, chunk_size=8,
                           use_kernel=use_kernel, fuse_features=fuse,
                           interpret=True if use_kernel else None)
        return jnp.sum(y * w)

    gk = jax.grad(lambda *a: loss(*a, True), argnums=(0, 1, 2))(q, k, v)
    gj = jax.grad(lambda *a: loss(*a, False), argnums=(0, 1, 2))(q, k, v)
    for name, a, b in zip("qkv", gk, gj):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=ATOL,
                                   rtol=RTOL, err_msg=f"d{name}")


def test_fused_vs_unfused_slay_attention():
    """slay_attention(use_kernel=True): fuse_features on/off agree."""
    B, L, H, hkv, d = 2, 24, 4, 2, 16
    cfg = _cfg(d=d)
    params = init_feature_params(jax.random.PRNGKey(0), cfg)
    q = jax.random.normal(jax.random.PRNGKey(1), (B, L, H, d))
    k = jax.random.normal(jax.random.PRNGKey(2), (B, L, hkv, d))
    v = jax.random.normal(jax.random.PRNGKey(3), (B, L, hkv, d))
    fused = slay_attention(params, q, k, v, cfg, chunk_size=8,
                           use_kernel=True, fuse_features=True,
                           interpret=True)
    unfused = slay_attention(params, q, k, v, cfg, chunk_size=8,
                             use_kernel=True, fuse_features=False,
                             interpret=True)
    jnp_path = slay_attention(params, q, k, v, cfg, chunk_size=8,
                              use_kernel=False)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               atol=ATOL, rtol=RTOL)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(jnp_path),
                               atol=ATOL, rtol=RTOL)


# ---------------------------------------------------------------------------
# Fused megakernel: gradient parity (custom VJP vs autodiff oracle)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bh,bk,L,d,dv,chunk", [
    (4, 2, 32, 16, 8, 8),
    (2, 2, 16, 16, 16, 16),
    (6, 1, 32, 24, 8, 16),
])
def test_fused_grad_matches_oracle(bh, bk, L, d, dv, chunk):
    cfg = _cfg(d=d)
    params = init_feature_params(jax.random.PRNGKey(0), cfg)
    q, k, v = _inputs(jax.random.PRNGKey(1), bh, bk, L, d, dv)
    w = jax.random.normal(jax.random.PRNGKey(2), (bh, L, dv))

    def loss_kernel(q, k, v, a, om):
        y = slay_fused.fused_causal_attention(q, k, v, a, om, cfg,
                                              chunk_size=chunk,
                                              interpret=True)
        return jnp.sum(y * w)

    def loss_oracle(q, k, v, a, om):
        y = _oracle_headmajor(q, k, v, {"anchors": a, "omegas": om}, cfg,
                              chunk)
        return jnp.sum(y * w)

    args = (q, k, v, params["anchors"], params["omegas"])
    gk = jax.grad(loss_kernel, argnums=(0, 1, 2, 3, 4))(*args)
    go = jax.grad(loss_oracle, argnums=(0, 1, 2, 3, 4))(*args)
    for name, a, b in zip("q k v anchors omegas".split(), gk, go):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=ATOL,
                                   rtol=RTOL, err_msg=f"d{name}")


def test_fused_grad_through_model_layout_with_padding():
    """jax.grad through ops.slay_fused_attention incl. ragged-L padding."""
    B, L, H, hkv, d, chunk = 1, 19, 2, 1, 16, 8
    cfg = _cfg(d=d)
    params = init_feature_params(jax.random.PRNGKey(0), cfg)
    q = jax.random.normal(jax.random.PRNGKey(1), (B, L, H, d))
    k = jax.random.normal(jax.random.PRNGKey(2), (B, L, hkv, d))
    v = jax.random.normal(jax.random.PRNGKey(3), (B, L, hkv, d))
    w = jax.random.normal(jax.random.PRNGKey(4), (B, L, H, d))

    def loss_kernel(q, k, v):
        y = ops.slay_fused_attention(q, k, v, params, cfg, chunk_size=chunk,
                                     interpret=True)
        return jnp.sum(y * w)

    def loss_oracle(q, k, v):
        qf = slay_features(q, params, cfg)
        kf = slay_features(k, params, cfg)
        return jnp.sum(la.causal_chunked(qf, kf, v, chunk_size=chunk) * w)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    go = jax.grad(loss_oracle, argnums=(0, 1, 2))(q, k, v)
    for name, a, b in zip("qkv", gk, go):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=ATOL,
                                   rtol=RTOL, err_msg=f"d{name}")


# ---------------------------------------------------------------------------
# slay_scan (feature-level) gradient parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bh,bk,L,m,dv,chunk", [
    (4, 2, 64, 48, 32, 16),
    (2, 2, 32, 16, 16, 8),
    (6, 1, 48, 24, 8, 16),
])
def test_scan_grad_matches_oracle(bh, bk, L, m, dv, chunk):
    qf = jax.random.uniform(jax.random.PRNGKey(0), (bh, L, m))
    kf = jax.random.uniform(jax.random.PRNGKey(1), (bk, L, m))
    v = jax.random.normal(jax.random.PRNGKey(2), (bk, L, dv))
    w = jax.random.normal(jax.random.PRNGKey(3), (bh, L, dv))

    def loss_kernel(qf, kf, v):
        y = slay_scan.causal_linear_attention(qf, kf, v, chunk_size=chunk,
                                              interpret=True)
        return jnp.sum(y * w)

    def loss_oracle(qf, kf, v):
        y = ref.causal_linear_attention_ref(qf, kf, v, chunk_size=chunk)
        return jnp.sum(y * w)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(qf, kf, v)
    go = jax.grad(loss_oracle, argnums=(0, 1, 2))(qf, kf, v)
    for name, a, b in zip(("qf", "kf", "v"), gk, go):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=ATOL,
                                   rtol=RTOL, err_msg=f"d{name}")


# ---------------------------------------------------------------------------
# feature_map gradient parity (two-dispatch path stays trainable)
# ---------------------------------------------------------------------------


def test_feature_map_grad_matches_oracle():
    from repro.kernels import feature_map
    cfg = _cfg(d=16, P=4, D=8, R=2)
    params = init_feature_params(jax.random.PRNGKey(0), cfg)
    u = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    w = jax.random.normal(jax.random.PRNGKey(2), (64, cfg.feature_dim))

    def loss_kernel(u, a, om):
        psi = feature_map.slay_feature_map(u, a, om, cfg, block_tokens=32,
                                           interpret=True)
        return jnp.sum(psi * w)

    def loss_oracle(u, a, om):
        psi = ref.slay_features_ref(u, {"anchors": a, "omegas": om}, cfg)
        return jnp.sum(psi * w)

    args = (u, params["anchors"], params["omegas"])
    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(*args)
    go = jax.grad(loss_oracle, argnums=(0, 1, 2))(*args)
    for name, a, b in zip("u anchors omegas".split(), gk, go):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=ATOL,
                                   rtol=RTOL, err_msg=f"d{name}")


# ---------------------------------------------------------------------------
# decode_step gradient parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bh,bk,m,dv", [(4, 2, 24, 16), (2, 2, 16, 8)])
def test_decode_grad_matches_oracle(bh, bk, m, dv):
    qf = jax.random.uniform(jax.random.PRNGKey(0), (bh, m))
    kf = jax.random.uniform(jax.random.PRNGKey(1), (bk, m))
    v = jax.random.normal(jax.random.PRNGKey(2), (bk, dv))
    s = jax.random.uniform(jax.random.PRNGKey(3), (bk, m, dv))
    z = jax.random.uniform(jax.random.PRNGKey(4), (bk, m)) + 1.0
    wy = jax.random.normal(jax.random.PRNGKey(5), (bh, dv))
    ws = jax.random.normal(jax.random.PRNGKey(6), (bk, m, dv))
    wz = jax.random.normal(jax.random.PRNGKey(7), (bk, m))

    def loss_kernel(qf, kf, v, s, z):
        y, s2, z2 = dk.decode_linear_attention(qf, kf, v, s, z,
                                               interpret=True)
        return jnp.sum(y * wy) + jnp.sum(s2 * ws) + jnp.sum(z2 * wz)

    def loss_oracle(qf, kf, v, s, z):
        y, s2, z2 = ref.decode_linear_attention_ref(qf, kf, v, s, z)
        return jnp.sum(y * wy) + jnp.sum(s2 * ws) + jnp.sum(z2 * wz)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2, 3, 4))(qf, kf, v, s, z)
    go = jax.grad(loss_oracle, argnums=(0, 1, 2, 3, 4))(qf, kf, v, s, z)
    for name, a, b in zip("qf kf v s z".split(), gk, go):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=ATOL,
                                   rtol=RTOL, err_msg=f"d{name}")


# ---------------------------------------------------------------------------
# Wrapper fallback / padding semantics (satellite fixes)
# ---------------------------------------------------------------------------


def test_explicit_interpret_false_falls_back_off_tpu():
    """interpret=False off-TPU must use the reference, not a compiled
    kernel (which would fail on CPU)."""
    if jax.default_backend() == "tpu":
        pytest.skip("only meaningful off-TPU")
    cfg = _cfg()
    params = init_feature_params(jax.random.PRNGKey(0), cfg)
    B, L, H, d = 1, 12, 2, 16
    qf = jax.random.uniform(jax.random.PRNGKey(1), (B, L, H, 64))
    kf = jax.random.uniform(jax.random.PRNGKey(2), (B, L, H, 64))
    v = jax.random.normal(jax.random.PRNGKey(3), (B, L, H, 8))
    y = ops.slay_causal_attention(qf, kf, v, chunk_size=8, interpret=False)
    want = la.causal_chunked(qf, kf, v, chunk_size=8)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=ATOL)
    u = jax.random.normal(jax.random.PRNGKey(4), (B, L, H, d))
    f = ops.slay_features(u, params, cfg, interpret=False)
    np.testing.assert_allclose(np.asarray(f),
                               np.asarray(ref.slay_features_ref(u, params,
                                                                cfg)),
                               atol=ATOL, rtol=RTOL)


def test_causal_attention_wrapper_pads_ragged_length():
    B, L, H, m, dv, chunk = 2, 21, 2, 24, 16, 8
    qf = jax.random.uniform(jax.random.PRNGKey(0), (B, L, H, m))
    kf = jax.random.uniform(jax.random.PRNGKey(1), (B, L, H, m))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, L, H, dv))
    got = ops.slay_causal_attention(qf, kf, v, chunk_size=chunk,
                                    interpret=True)
    want = la.causal_chunked(qf, kf, v, chunk_size=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL,
                               rtol=RTOL)


def test_features_wrapper_pads_ragged_token_count():
    cfg = _cfg()
    params = init_feature_params(jax.random.PRNGKey(0), cfg)
    u = jax.random.normal(jax.random.PRNGKey(1), (3, 37, 16))  # 111 tokens
    got = ops.slay_features(u, params, cfg, block_tokens=64, interpret=True)
    want = ref.slay_features_ref(u, params, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL,
                               rtol=RTOL)
