"""Serving engine: batched generation, greedy determinism, constant-state
decode (SLAY) vs KV-cache decode (softmax), prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch.mesh import make_host_mesh
from repro.models import api
from repro.serving.engine import Request, ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get_smoke_config("slayformer-124m")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    mesh = make_host_mesh()
    return cfg, params, mesh


def test_generate_batched(setup):
    cfg, params, mesh = setup
    eng = ServingEngine(cfg, params, mesh, max_len=64)
    reqs = [Request(np.array([1, 2, 3], np.int32), max_new_tokens=4),
            Request(np.array([4, 5], np.int32), max_new_tokens=6)]
    outs = eng.generate(reqs)
    assert len(outs) == 2
    assert outs[0].shape == (4,) and outs[1].shape == (6,)
    for o in outs:
        assert np.all((o >= 0) & (o < cfg.vocab_size))


def test_greedy_is_deterministic(setup):
    cfg, params, mesh = setup
    eng = ServingEngine(cfg, params, mesh, max_len=64)
    reqs = [Request(np.arange(1, 6, dtype=np.int32), max_new_tokens=5)]
    a = eng.generate(reqs)[0]
    b = eng.generate(reqs)[0]
    np.testing.assert_array_equal(a, b)


def test_eos_stops_early(setup):
    cfg, params, mesh = setup
    eng = ServingEngine(cfg, params, mesh, max_len=64)
    reqs = [Request(np.array([1, 2], np.int32), max_new_tokens=8)]
    first = eng.generate(reqs)[0][0]
    reqs_eos = [Request(np.array([1, 2], np.int32), max_new_tokens=8,
                        eos_id=int(first))]
    out = eng.generate(reqs_eos)[0]
    assert out[0] == first
    assert np.all(out[1:] == 0)      # masked after EOS


def test_decode_matches_forward(setup):
    """Teacher-forced decode logits must match the full forward pass —
    the constant-state SLAY path is an exact reformulation, not an
    approximation of the prefill math."""
    cfg, params, _ = setup
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (1, 12), 0, cfg.vocab_size)
    logits_full, _ = api.forward(params, cfg, {"tokens": toks})
    _, cache = api.prefill(params, cfg, {"tokens": toks[:, :6]})
    errs = []
    for t in range(6, 12):
        logits_t, cache = api.decode_step(params, cfg, cache, toks[:, t:t+1])
        errs.append(np.max(np.abs(
            np.asarray(logits_t[:, 0], np.float32)
            - np.asarray(logits_full[:, t], np.float32))))
    assert max(errs) < 0.15   # bf16 activations, fp32 state


def test_prefill_logits_match_forward(setup):
    cfg, params, _ = setup
    key = jax.random.PRNGKey(2)
    toks = jax.random.randint(key, (2, 10), 0, cfg.vocab_size)
    logits_full, _ = api.forward(params, cfg, {"tokens": toks})
    logits_pre, _ = api.prefill(params, cfg, {"tokens": toks})
    np.testing.assert_allclose(
        np.asarray(logits_pre[:, 0], np.float32),
        np.asarray(logits_full[:, -1], np.float32), atol=0.1)


def test_softmax_kv_cache_decode(setup):
    """The KV-ring-buffer path (softmax backend) also decodes consistently."""
    cfg = configs.get_smoke_config("slayformer-124m", attn_kind="softmax")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (1, 12), 0, cfg.vocab_size)
    logits_full, _ = api.forward(params, cfg, {"tokens": toks})
    _, cache = api.prefill(params, cfg, {"tokens": toks[:, :6]})
    errs = []
    for t in range(6, 12):
        logits_t, cache = api.decode_step(params, cfg, cache, toks[:, t:t+1])
        errs.append(np.max(np.abs(
            np.asarray(logits_t[:, 0], np.float32)
            - np.asarray(logits_full[:, t], np.float32))))
    assert max(errs) < 0.15


def test_linear_state_is_constant_size(setup):
    """The paper's long-context win: SLAY decode cache size is independent
    of max_len."""
    cfg, _, _ = setup
    c1 = api.abstract_cache(cfg, 2, 128)
    c2 = api.abstract_cache(cfg, 2, 4096)
    s1 = sum(np.prod(x.shape) for x in jax.tree.leaves(c1.attn))
    s2 = sum(np.prod(x.shape) for x in jax.tree.leaves(c2.attn))
    assert s1 == s2

    cfg_sm = configs.get_smoke_config("slayformer-124m",
                                      attn_kind="softmax")
    k1 = api.abstract_cache(cfg_sm, 2, 128)
    k2 = api.abstract_cache(cfg_sm, 2, 4096)
    b1 = sum(np.prod(x.shape) for x in jax.tree.leaves(k1.attn))
    b2 = sum(np.prod(x.shape) for x in jax.tree.leaves(k2.attn))
    assert b2 > 8 * b1                # KV cache grows with context
