"""Serving engines: lockstep reference (greedy determinism, eos actual
lengths, constant-state vs KV decode) and the continuous-batching engine
(staggered admission, eos eviction + slot reuse, streamed parity, chunked
prefill continuation, both cache regimes, serving bench JSON)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ServingConfig
from repro.launch.mesh import make_host_mesh
from repro.models import api
from repro.serving.engine import (ContinuousServingEngine, Request,
                                  ServingEngine)


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get_smoke_config("slayformer-124m")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    mesh = make_host_mesh()
    return cfg, params, mesh


@pytest.fixture(scope="module")
def setup_softmax():
    cfg = configs.get_smoke_config("slayformer-124m", attn_kind="softmax")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    mesh = make_host_mesh()
    return cfg, params, mesh


def _prompts(cfg, lengths, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(3, cfg.vocab_size, size=n).astype(np.int32)
            for n in lengths]


def test_generate_batched(setup):
    cfg, params, mesh = setup
    eng = ServingEngine(cfg, params, mesh, max_len=64)
    reqs = [Request(np.array([1, 2, 3], np.int32), max_new_tokens=4),
            Request(np.array([4, 5], np.int32), max_new_tokens=6)]
    outs = eng.generate(reqs)
    assert len(outs) == 2
    assert outs[0].shape == (4,) and outs[1].shape == (6,)
    for o in outs:
        assert np.all((o >= 0) & (o < cfg.vocab_size))


def test_greedy_is_deterministic(setup):
    cfg, params, mesh = setup
    eng = ServingEngine(cfg, params, mesh, max_len=64)
    reqs = [Request(np.arange(1, 6, dtype=np.int32), max_new_tokens=5)]
    a = eng.generate(reqs)[0]
    b = eng.generate(reqs)[0]
    np.testing.assert_array_equal(a, b)


def test_eos_returns_actual_length(setup):
    """EOS fix: the returned array ends at the eos token (inclusive) — no
    zero padding out to max_new_tokens."""
    cfg, params, mesh = setup
    eng = ServingEngine(cfg, params, mesh, max_len=64)
    reqs = [Request(np.array([1, 2], np.int32), max_new_tokens=8)]
    full = eng.generate(reqs)[0]
    assert full.shape == (8,)
    stop = int(full[2])              # this value becomes the EOS id
    cut = int(np.argmax(full == stop))   # its first occurrence
    reqs_eos = [Request(np.array([1, 2], np.int32), max_new_tokens=8,
                        eos_id=stop)]
    out = eng.generate(reqs_eos)[0]
    assert out.shape == (cut + 1,)   # through EOS inclusive, then stops
    np.testing.assert_array_equal(out, full[:cut + 1])


def test_decode_matches_forward(setup):
    """Teacher-forced decode logits must match the full forward pass —
    the constant-state SLAY path is an exact reformulation, not an
    approximation of the prefill math."""
    cfg, params, _ = setup
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (1, 12), 0, cfg.vocab_size)
    logits_full, _ = api.forward(params, cfg, {"tokens": toks})
    _, cache = api.prefill(params, cfg, {"tokens": toks[:, :6]})
    errs = []
    for t in range(6, 12):
        logits_t, cache = api.decode_step(params, cfg, cache, toks[:, t:t+1])
        errs.append(np.max(np.abs(
            np.asarray(logits_t[:, 0], np.float32)
            - np.asarray(logits_full[:, t], np.float32))))
    assert max(errs) < 0.15   # bf16 activations, fp32 state


def test_prefill_logits_match_forward(setup):
    cfg, params, _ = setup
    key = jax.random.PRNGKey(2)
    toks = jax.random.randint(key, (2, 10), 0, cfg.vocab_size)
    logits_full, _ = api.forward(params, cfg, {"tokens": toks})
    logits_pre, _ = api.prefill(params, cfg, {"tokens": toks})
    np.testing.assert_allclose(
        np.asarray(logits_pre[:, 0], np.float32),
        np.asarray(logits_full[:, -1], np.float32), atol=0.1)


def test_softmax_kv_cache_decode(setup_softmax):
    """The KV-ring-buffer path (softmax backend) also decodes consistently."""
    cfg, params, _ = setup_softmax
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (1, 12), 0, cfg.vocab_size)
    logits_full, _ = api.forward(params, cfg, {"tokens": toks})
    _, cache = api.prefill(params, cfg, {"tokens": toks[:, :6]})
    errs = []
    for t in range(6, 12):
        logits_t, cache = api.decode_step(params, cfg, cache, toks[:, t:t+1])
        errs.append(np.max(np.abs(
            np.asarray(logits_t[:, 0], np.float32)
            - np.asarray(logits_full[:, t], np.float32))))
    assert max(errs) < 0.15


def test_linear_state_is_constant_size(setup):
    """The paper's long-context win: SLAY decode cache size is independent
    of max_len."""
    cfg, _, _ = setup
    c1 = api.abstract_cache(cfg, 2, 128)
    c2 = api.abstract_cache(cfg, 2, 4096)
    s1 = sum(np.prod(x.shape) for x in jax.tree.leaves(c1.attn))
    s2 = sum(np.prod(x.shape) for x in jax.tree.leaves(c2.attn))
    assert s1 == s2

    cfg_sm = configs.get_smoke_config("slayformer-124m",
                                      attn_kind="softmax")
    k1 = api.abstract_cache(cfg_sm, 2, 128)
    k2 = api.abstract_cache(cfg_sm, 2, 4096)
    b1 = sum(np.prod(x.shape) for x in jax.tree.leaves(k1.attn))
    b2 = sum(np.prod(x.shape) for x in jax.tree.leaves(k2.attn))
    assert b2 > 8 * b1                # KV cache grows with context


# ---------------------------------------------------------------------------
# Chunked prefill + slot-pooled cache surface
# ---------------------------------------------------------------------------


@pytest.mark.serving
@pytest.mark.parametrize("kind", ["slay", "softmax"])
def test_chunked_prefill_matches_whole_prompt(kind):
    """Feeding a prompt chunk-by-chunk ends in the same logits/state as a
    whole-prompt prefill, for both cache regimes."""
    cfg = configs.get_smoke_config("slayformer-124m", attn_kind=kind)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 11), 0,
                              cfg.vocab_size)
    lg_full, cache_full = api.prefill(params, cfg, {"tokens": toks},
                                      max_len=64)
    cache = api.init_cache(cfg, 1, 64)
    for lo, hi in ((0, 4), (4, 8), (8, 11)):
        lg, cache = api.prefill_chunk(cfg, params, cache, toks[:, lo:hi])
    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               np.asarray(lg_full, np.float32), atol=0.1)
    assert np.asarray(cache.pos).tolist() == [11]
    # Decode continuation from both caches agrees token-for-token.
    tok = jnp.argmax(lg_full[:, -1], -1).astype(jnp.int32)[:, None]
    for _ in range(3):
        l1, cache_full = api.decode_step(params, cfg, cache_full, tok)
        l2, cache = api.decode_step(params, cfg, cache, tok)
        np.testing.assert_allclose(np.asarray(l1, np.float32),
                                   np.asarray(l2, np.float32), atol=0.1)
        tok = jnp.argmax(l1[:, -1], -1).astype(jnp.int32)[:, None]


@pytest.mark.serving
def test_chunked_prefill_local_global_mix():
    """gemma2-style local/global layer alternation chunks exactly too."""
    cfg = configs.get_smoke_config("gemma2-27b")
    assert api.supports_chunked_prefill(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 10), 0,
                              cfg.vocab_size)
    lg_full, _ = api.prefill(params, cfg, {"tokens": toks}, max_len=64)
    cache = api.init_cache(cfg, 1, 64)
    for lo, hi in ((0, 6), (6, 10)):
        lg, cache = api.prefill_chunk(cfg, params, cache, toks[:, lo:hi])
    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               np.asarray(lg_full, np.float32), atol=0.2)


@pytest.mark.serving
@pytest.mark.parametrize("kind", ["slay", "softmax"])
def test_slot_write_and_reset(kind):
    """Admission/eviction are single-slot overwrites: neighbours' bytes are
    bit-identical before and after."""
    cfg = configs.get_smoke_config("slayformer-124m", attn_kind=kind)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 7), 0,
                              cfg.vocab_size)
    pool = api.init_cache(cfg, 3, 32)
    _, req = api.prefill(params, cfg, {"tokens": toks}, max_len=32)
    # Put something nonzero in slot 2 first, then admit into slot 1.
    pool = api.write_slot(cfg, pool, req, 2)
    before = [np.asarray(x).copy() for x in jax.tree.leaves(pool)]
    pool = api.write_slot(cfg, pool, req, 1)
    assert np.asarray(pool.pos).tolist() == [0, 7, 7]
    for x_b, x_a in zip(before, jax.tree.leaves(pool)):
        a = np.asarray(x_a)
        if a.ndim >= 2 and a.shape[1] == 3:       # (nl, B, ...) leaves
            np.testing.assert_array_equal(x_b[:, 2], a[:, 2])
            np.testing.assert_array_equal(x_b[:, 0], a[:, 0])
    pool = api.reset_slot(cfg, pool, 1)
    assert np.asarray(pool.pos).tolist() == [0, 0, 7]
    zeroed = jax.tree.map(lambda x: np.all(np.asarray(x[:, 1]) == 0)
                          if np.asarray(x).ndim >= 2
                          and np.asarray(x).shape[1] == 3 else True, pool.attn)
    assert all(jax.tree.leaves(zeroed))


# ---------------------------------------------------------------------------
# Continuous batching engine
# ---------------------------------------------------------------------------


@pytest.mark.serving
def test_continuous_staggered_parity_and_slot_reuse(setup):
    """Requests with staggered arrivals and mixed lengths on a 2-slot pool:
    (a) token-level parity with the lockstep reference, (b) a finished slot
    is reused by a queued request."""
    cfg, params, mesh = setup
    prompts = _prompts(cfg, (5, 9, 3, 7, 4))
    reqs = [Request(p, max_new_tokens=6, arrival_time=float(2 * i))
            for i, p in enumerate(prompts)]
    eng = ContinuousServingEngine(
        cfg, params, mesh,
        serving=ServingConfig(num_slots=2, max_len=64, prefill_chunk=4))
    outs, summary = eng.run(reqs)
    assert summary["requests_completed"] == len(reqs)

    ref = ServingEngine(cfg, params, mesh, max_len=64)
    for i, p in enumerate(prompts):
        want = ref.generate([Request(p, max_new_tokens=6)])[0]
        np.testing.assert_array_equal(outs[i], want)

    # 5 requests over 2 slots: some slot must have served >= 2 requests,
    # and the later tenant was admitted only after the earlier finished.
    stats = eng.metrics.per_request
    by_slot = {}
    for st in stats.values():
        by_slot.setdefault(st.slot, []).append(st)
    assert max(len(v) for v in by_slot.values()) >= 2
    for tenants in by_slot.values():
        tenants.sort(key=lambda s: s.admitted)
        for prev, nxt in zip(tenants, tenants[1:]):
            assert nxt.admitted >= prev.finished


@pytest.mark.serving
def test_continuous_eos_eviction_immediate_reuse(setup):
    """An EOS hit evicts the slot and the next queued request takes it —
    on a 1-slot pool the second request can only complete via that reuse."""
    cfg, params, mesh = setup
    p0, p1 = _prompts(cfg, (4, 6), seed=3)
    ref = ServingEngine(cfg, params, mesh, max_len=64)
    first = ref.generate([Request(p0, max_new_tokens=8)])[0]
    eos = int(first[0])              # first greedy token of request 0
    reqs = [Request(p0, max_new_tokens=8, eos_id=eos),
            Request(p1, max_new_tokens=4, arrival_time=1.0)]
    eng = ContinuousServingEngine(
        cfg, params, mesh,
        serving=ServingConfig(num_slots=1, max_len=64, prefill_chunk=4))
    outs, summary = eng.run(reqs)
    assert summary["requests_completed"] == 2
    np.testing.assert_array_equal(outs[0], first[:1])   # eos inclusive
    want1 = ref.generate([Request(p1, max_new_tokens=4)])[0]
    np.testing.assert_array_equal(outs[1], want1)
    st = eng.metrics.per_request
    assert st[0].slot == st[1].slot == 0
    assert st[1].admitted >= st[0].finished


@pytest.mark.serving
def test_continuous_streaming_matches_one_shot(setup):
    """Per-request streamed tokens == the run() outputs == the lockstep
    one-shot generate."""
    cfg, params, mesh = setup
    prompts = _prompts(cfg, (6, 4), seed=5)
    streamed = {}

    def on_token(rid, tok):
        streamed.setdefault(rid, []).append(tok)

    reqs = [Request(p, max_new_tokens=5, on_token=on_token) for p in prompts]
    eng = ContinuousServingEngine(
        cfg, params, mesh,
        serving=ServingConfig(num_slots=2, max_len=64, prefill_chunk=0))
    outs, _ = eng.run(reqs)
    ref = ServingEngine(cfg, params, mesh, max_len=64)
    for rid, p in enumerate(prompts):
        want = ref.generate([Request(p, max_new_tokens=5)])[0]
        np.testing.assert_array_equal(np.asarray(streamed[rid], np.int32),
                                      want)
        np.testing.assert_array_equal(outs[rid], want)


@pytest.mark.serving
def test_continuous_kv_regime(setup_softmax):
    """The same scheduler drives the KV-ring regime (softmax backend)."""
    cfg, params, mesh = setup_softmax
    prompts = _prompts(cfg, (5, 3, 6), seed=7)
    reqs = [Request(p, max_new_tokens=4, arrival_time=float(i))
            for i, p in enumerate(prompts)]
    eng = ContinuousServingEngine(
        cfg, params, mesh,
        serving=ServingConfig(num_slots=2, max_len=64, prefill_chunk=4))
    outs, summary = eng.run(reqs)
    assert summary["requests_completed"] == 3
    ref = ServingEngine(cfg, params, mesh, max_len=64)
    for i, p in enumerate(prompts):
        want = ref.generate([Request(p, max_new_tokens=4)])[0]
        np.testing.assert_array_equal(outs[i], want)


@pytest.mark.serving
def test_out_of_order_arrival_not_blocked(setup):
    """A request submitted later but arriving earlier must not be
    head-of-line blocked by an earlier submission with a far-future
    arrival."""
    cfg, params, mesh = setup
    p0, p1 = _prompts(cfg, (4, 5), seed=11)
    eng = ContinuousServingEngine(
        cfg, params, mesh,
        serving=ServingConfig(num_slots=2, max_len=32, prefill_chunk=4))
    eng.submit(Request(p0, max_new_tokens=3, arrival_time=500.0))
    eng.submit(Request(p1, max_new_tokens=3, arrival_time=0.0))
    outs, summary = eng.run(max_ticks=50)
    assert len(outs[1]) == 3                       # rid 1 served immediately
    assert eng.metrics.per_request[1].first_token < 20
    assert len(outs[0]) == 0                       # rid 0 still waiting


@pytest.mark.serving
def test_engine_metrics_shape(setup):
    cfg, params, mesh = setup
    reqs = [Request(p, max_new_tokens=3)
            for p in _prompts(cfg, (4, 4), seed=9)]
    eng = ContinuousServingEngine(
        cfg, params, mesh,
        serving=ServingConfig(num_slots=2, max_len=32, prefill_chunk=4))
    _, summary = eng.run(reqs)
    for key in ("ticks", "decode_ticks", "prefill_ticks",
                "decode_tokens_per_s", "ttft_ticks_p50", "ttft_ticks_p95",
                "mean_queue_depth", "mean_slot_occupancy"):
        assert key in summary, key
    assert 0.0 <= summary["mean_slot_occupancy"] <= 1.0
    assert summary["tokens_generated"] == 6
    assert summary["ttft_ticks_p50"] is not None


@pytest.mark.serving
def test_serving_bench_smoke_emits_json(tmp_path, monkeypatch):
    """The serving bench writes BENCH_serving.json with throughput + TTFT
    at >= 2 load levels (the CI artifact contract)."""
    from benchmarks import serving_bench
    out = tmp_path / "BENCH_serving.json"
    monkeypatch.setattr(serving_bench, "_JSON_PATH", str(out))
    serving_bench.run(smoke=True)
    assert os.path.exists(out)
    import json
    payload = json.loads(out.read_text())
    rows = payload["results"]
    loads = {(r["regime"], r["load"]) for r in rows}
    assert len({ld for _, ld in loads}) >= 2          # >= 2 load levels
    assert {rg for rg, _ in loads} == {"constant_state", "kv_ring",
                                       "ssm_scan", "hybrid_scan",
                                       "constant_state_sharded",
                                       "kv_ring_paged", "prefix_cold",
                                       "prefix_cached", "exact_yat",
                                       "spec_constant_state"}
    # Scan-carry families serve via chunked prefill — fallback retired.
    for r in rows:
        if r["regime"] in ("ssm_scan", "hybrid_scan"):
            assert r["bucket_misses"] == 0 == r["bucket_hits"], r
    for r in rows:
        assert "decode_tokens_per_s" in r and "ttft_ticks_p50" in r
        assert "stream_digest" in r
    # §8 byte-identity: the sharded row replays the single-shard trace.
    sharded = next(r for r in rows
                   if r["regime"] == "constant_state_sharded")
    assert sharded["slot_shards"] > 1
    base = next(r for r in rows if r["regime"] == "constant_state"
                and r["load"] == sharded["load"])
    assert sharded["stream_digest"] == base["stream_digest"]
    # §11 byte-identity: the paged row replays the kv_ring trace, and the
    # prefix-cached replay full-hits every request of the cold run.
    paged = next(r for r in rows if r["regime"] == "kv_ring_paged")
    ring = next(r for r in rows if r["regime"] == "kv_ring"
                and r["load"] == paged["load"])
    assert paged["stream_digest"] == ring["stream_digest"]
    assert paged["pages_peak"] >= 1 and paged["final_pages_in_use"] == 0
    cold = next(r for r in rows if r["regime"] == "prefix_cold")
    warm = next(r for r in rows if r["regime"] == "prefix_cached")
    assert warm["stream_digest"] == cold["stream_digest"]
    assert cold["prefix_hit_rate"] == 0.0
    assert warm["prefix_hit_rate"] == 1.0
    assert warm["ttft_ticks_p50"] < cold["ttft_ticks_p50"]
    # §13 byte-identity: the draft-verify row's accepted streams replay
    # the exact-yat baseline on the pinned contract trace.
    spec = next(r for r in rows if r["regime"] == "spec_constant_state")
    exact = next(r for r in rows if r["regime"] == "exact_yat"
                 and r["load"] == spec["load"])
    assert spec["stream_digest"] == exact["stream_digest"]
    assert spec["draft_acceptance_rate"] >= 0.5
    assert spec["tokens_per_dispatch"] > exact["tokens_per_dispatch"]
