"""Fault-tolerant request lifecycle (DESIGN.md §10): construction-time
validation, typed admission errors + overload policies, cancellation at
every lifecycle stage (queued, mid-prefill, mid-macro-step), tick/wall
deadlines with EOS-wins ordering, NaN slot quarantine + retry, and the
deterministic chaos harness (parity of non-faulted streams, seeded
injector reproducibility)."""
import jax
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ServingConfig
from repro.launch.mesh import make_host_mesh
from repro.models import api
from repro.serving import faults
from repro.serving.engine import (AdmissionError, ContinuousServingEngine,
                                  QueueFullError, Request,
                                  RequestTooLargeError, ServingMetrics)

pytestmark = pytest.mark.serving


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get_smoke_config("slayformer-124m")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    mesh = make_host_mesh()
    return cfg, params, mesh


def _prompt(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(3, cfg.vocab_size, size=n).astype(np.int32)


class FakeClock:
    """Deterministic injectable wall clock: time moves only when the test
    says so, making wall-deadline expiry independent of host speed."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += float(dt)


def _engine(cfg, params, mesh, injector=None, clock=None, journal=None,
            **kw):
    sv = ServingConfig(**{"num_slots": 2, "max_len": 64,
                          "prefill_chunk": 4, "macro_ticks": 4, **kw})
    extra = {}
    if clock is not None:
        extra["clock"] = clock
    if journal is not None:
        extra["journal"] = journal
    return ContinuousServingEngine(cfg, params, mesh, serving=sv,
                                   fault_injector=injector, **extra)


# -- construction-time validation -------------------------------------------


def test_request_validation():
    with pytest.raises(ValueError, match="empty prompt"):
        Request(np.array([], np.int32))
    with pytest.raises(ValueError, match="max_new_tokens"):
        Request(np.array([1], np.int32), max_new_tokens=0)
    with pytest.raises(ValueError, match="arrival_time"):
        Request(np.array([1], np.int32), arrival_time=float("nan"))
    for field in ("ttft_deadline_ticks", "deadline_ticks",
                  "ttft_deadline_s", "deadline_s"):
        with pytest.raises(ValueError, match=field):
            Request(np.array([1], np.int32), **{field: 0.0})
        with pytest.raises(ValueError, match=field):
            Request(np.array([1], np.int32), **{field: float("inf")})
    # Valid deadlines construct fine.
    Request(np.array([1], np.int32), deadline_ticks=5.0,
            ttft_deadline_s=0.5)


def test_serving_config_validation():
    with pytest.raises(ValueError, match="temperature"):
        ServingConfig(temperature=float("nan"))
    with pytest.raises(ValueError, match="temperature"):
        ServingConfig(temperature=-1.0)
    with pytest.raises(ValueError, match="overload_policy"):
        ServingConfig(overload_policy="panic")
    with pytest.raises(ValueError, match="queue_wait_ticks"):
        ServingConfig(queue_wait_ticks=-1)
    with pytest.raises(ValueError, match="fault_retries"):
        ServingConfig(fault_retries=-1)


# -- typed admission + overload policies ------------------------------------


def test_reject_new_raises_typed_queue_full(setup):
    cfg, params, mesh = setup
    eng = _engine(cfg, params, mesh, max_queue=2)
    reqs = [Request(_prompt(cfg, 4, i), max_new_tokens=2) for i in range(3)]
    eng.submit(reqs[0])
    eng.submit(reqs[1])
    with pytest.raises(QueueFullError) as ei:
        eng.submit(reqs[2])
    assert isinstance(ei.value, AdmissionError)
    assert isinstance(ei.value, RuntimeError)
    assert ei.value.queue_depth == 2 and ei.value.max_queue == 2
    # The rejected request consumed no rid and left no orphan state; the
    # engine drains clean.
    outs, s = eng.run()
    assert set(outs) == {0, 1}
    assert s["requests_terminated"] == 2 and s["final_occupancy"] == 0


def test_too_large_is_admission_and_value_error(setup):
    """Bounded (KV ring) configs reject oversized requests with the typed
    error; constant-state configs have unbounded capacity (DESIGN.md §11)
    and only reject when chunked prefill is off (the one-shot fallback
    prefill cannot exceed the ring)."""
    cfg, params, mesh = setup
    bcfg = configs.get_smoke_config("slayformer-124m", attn_kind="softmax")
    bparams = api.init_params(bcfg, jax.random.PRNGKey(0))
    eng = _engine(bcfg, bparams, mesh)
    bad = Request(_prompt(bcfg, 8), max_new_tokens=1000)
    with pytest.raises(RequestTooLargeError) as ei:
        eng.submit(bad)
    assert isinstance(ei.value, AdmissionError)
    assert isinstance(ei.value, ValueError)   # pre-§10 contract preserved
    # The linear (constant-state) setup config admits the same request —
    # its decode state is O(1) in context — unless chunked prefill is off.
    assert api.context_capacity(cfg, 64) is None
    eng2 = _engine(cfg, params, mesh, prefill_chunk=0)
    with pytest.raises(RequestTooLargeError):
        eng2.submit(bad)


def test_shed_oldest_at_queue_boundary(setup):
    """All-arrive-at-once burst at exactly max_queue sheds nothing; one
    past the boundary sheds exactly the longest-waiting request."""
    cfg, params, mesh = setup
    reasons = {}
    n, q = 4, 2
    eng = _engine(cfg, params, mesh, max_queue=q,
                  overload_policy="shed_oldest")
    reqs = [Request(_prompt(cfg, 4, i), max_new_tokens=2,
                    on_finish=lambda rid, why: reasons.update({rid: why}))
            for i in range(n)]
    rids = [eng.submit(r) for r in reqs]       # never raises
    assert rids == list(range(n))
    outs, s = eng.run()
    # n=4 into a queue of 2: submissions 3 and 4 each shed the then-oldest
    # queued request (rids 0 and 1).
    assert reasons[0] == "shed" and reasons[1] == "shed"
    assert s["finish_reasons"]["shed"] == n - q
    assert s["requests_terminated"] == n
    assert len(outs[0]) == 0                    # shed pre-emission
    assert s["final_occupancy"] == 0 and s["final_queue_depth"] == 0
    assert s["shed_rate"] == pytest.approx((n - q) / n)


def test_queue_wait_sheds_stale_requests(setup):
    cfg, params, mesh = setup
    eng = _engine(cfg, params, mesh, num_slots=1, max_queue=2,
                  overload_policy="queue_wait", queue_wait_ticks=2)
    reqs = [Request(_prompt(cfg, 4, i), max_new_tokens=6)
            for i in range(4)]
    for r in reqs:
        eng.submit(r)                          # queue_wait never raises
    outs, s = eng.run()
    # One slot: the first request serves; the rest age out at > 2 ticks.
    assert s["finish_reasons"]["length"] == 1
    assert s["finish_reasons"]["shed"] == 3
    assert len(outs[0]) == 6
    assert s["final_occupancy"] == 0


# -- cancellation at every lifecycle stage ----------------------------------


def test_cancel_queued_is_idempotent(setup):
    cfg, params, mesh = setup
    fired = []
    eng = _engine(cfg, params, mesh, num_slots=1)
    r0 = eng.submit(Request(_prompt(cfg, 4, 0), max_new_tokens=4))
    r1 = eng.submit(Request(
        _prompt(cfg, 4, 1), max_new_tokens=4,
        on_finish=lambda rid, why: fired.append((rid, why))))
    assert eng.cancel(r1) is True
    assert eng.cancel(r1) is False              # already terminal
    assert eng.cancel(999) is False             # unknown rid
    assert fired == [(r1, "cancelled")]         # on_finish exactly once
    outs, s = eng.run()
    assert len(outs[r0]) == 4 and len(outs[r1]) == 0
    assert s["finish_reasons"] == {"cancelled": 1, "length": 1}
    assert eng.metrics.per_request[r1].ttft_ticks is None


def test_cancel_mid_prefill_frees_slot(setup):
    cfg, params, mesh = setup
    eng = _engine(cfg, params, mesh, prefill_chunk=4)
    rid = eng.submit(Request(_prompt(cfg, 12), max_new_tokens=4))
    eng.step()                                  # first prefill chunk only
    assert eng._prefill is not None and eng._prefill.rid == rid
    assert eng.cancel(rid) is True
    assert eng._prefill is None
    assert sorted(eng.sched.free) == list(range(2))   # slot returned
    outs, s = eng.run()
    assert len(outs[rid]) == 0
    assert s["finish_reasons"] == {"cancelled": 1}
    assert s["final_occupancy"] == 0


def test_cancel_mid_macro_step_from_stream_callback(setup):
    """An on_token callback cancelling its own request mid-replay drops
    the remaining buffered device ticks; a co-resident request is
    unaffected."""
    cfg, params, mesh = setup
    got = []

    def cb(rid, tok):
        got.append(tok)
        if len(got) == 3:
            assert eng.cancel(rid) is True

    eng = _engine(cfg, params, mesh, macro_ticks=8)
    ra = eng.submit(Request(_prompt(cfg, 4, 0), max_new_tokens=12,
                            on_token=cb))
    rb = eng.submit(Request(_prompt(cfg, 4, 1), max_new_tokens=12))
    outs, s = eng.run()
    assert len(outs[ra]) == 3                   # buffered suffix dropped
    assert len(outs[rb]) == 12                  # co-resident unaffected
    assert s["finish_reasons"] == {"cancelled": 1, "length": 1}
    assert s["final_occupancy"] == 0
    assert eng.metrics.per_request[ra].finish_reason == "cancelled"


# -- deadlines ---------------------------------------------------------------


def test_ttft_deadline_expires_queued_request(setup):
    cfg, params, mesh = setup
    eng = _engine(cfg, params, mesh, num_slots=1)
    r0 = eng.submit(Request(_prompt(cfg, 4, 0), max_new_tokens=8))
    r1 = eng.submit(Request(_prompt(cfg, 4, 1), max_new_tokens=8,
                            ttft_deadline_ticks=2.0))
    outs, s = eng.run()
    assert len(outs[r0]) == 8
    assert len(outs[r1]) == 0
    assert eng.metrics.per_request[r1].finish_reason == "deadline"
    assert s["deadline_miss_rate"] == pytest.approx(0.5)


def test_total_deadline_cuts_stream_mid_decode(setup):
    cfg, params, mesh = setup
    eng = _engine(cfg, params, mesh, num_slots=1)
    rid = eng.submit(Request(_prompt(cfg, 4), max_new_tokens=16,
                             deadline_ticks=3.0))
    outs, s = eng.run()
    assert 1 <= len(outs[rid]) < 16             # emitted, then expired
    assert eng.metrics.per_request[rid].finish_reason == "deadline"
    assert eng.metrics.per_request[rid].ttft_ticks is not None
    assert s["final_occupancy"] == 0


def test_natural_stop_beats_deadline_on_same_tick(setup):
    """A deadline expiring on the very tick of the natural stop loses:
    emissions are processed before the sweep and expiry is strict."""
    cfg, params, mesh = setup
    req = Request(_prompt(cfg, 4), max_new_tokens=3)
    eng = _engine(cfg, params, mesh)
    rid = eng.submit(req)
    baseline, _ = eng.run()
    finish_age = (eng.metrics.per_request[rid].finished
                  - eng.metrics.per_request[rid].arrival)
    eng2 = _engine(cfg, params, mesh)
    rid2 = eng2.submit(Request(_prompt(cfg, 4), max_new_tokens=3,
                               deadline_ticks=float(finish_age)))
    outs2, _ = eng2.run()
    assert eng2.metrics.per_request[rid2].finish_reason == "length"
    np.testing.assert_array_equal(outs2[rid2], baseline[rid])


def test_wall_clock_deadline_expires(setup):
    """Wall deadlines read the engine's injectable clock, so a fake clock
    makes expiry exact: the stream survives while the clock is under
    budget and cuts the moment the test advances it past, regardless of
    how slow (or fast) the host actually is."""
    cfg, params, mesh = setup
    fc = FakeClock()
    eng = _engine(cfg, params, mesh, clock=fc)
    rid = eng.submit(Request(_prompt(cfg, 4), max_new_tokens=8,
                             deadline_s=0.5))
    eng.step()                                  # clock frozen: no expiry
    assert eng.metrics.per_request[rid].finish_reason is None
    fc.advance(1.0)                             # blow the 0.5 s budget
    outs, s = eng.run()
    assert eng.metrics.per_request[rid].finish_reason == "deadline"
    assert len(outs[rid]) < 8
    assert s["final_occupancy"] == 0


def test_wall_clock_deadline_survives_when_clock_frozen(setup):
    """Control for the fake-clock test above: with the clock never
    advanced the same sub-second budget never expires and the request
    runs to its natural stop — proving expiry is driven by the injected
    clock, not real elapsed time."""
    cfg, params, mesh = setup
    eng = _engine(cfg, params, mesh, clock=FakeClock())
    rid = eng.submit(Request(_prompt(cfg, 4), max_new_tokens=8,
                             deadline_s=0.5))
    outs, s = eng.run()
    assert eng.metrics.per_request[rid].finish_reason == "length"
    assert len(outs[rid]) == 8


def test_ttft_wall_deadline_with_fake_clock(setup):
    """A queued request whose TTFT wall budget elapses (on the fake
    clock) before a slot frees expires without ever emitting."""
    cfg, params, mesh = setup
    fc = FakeClock()
    eng = _engine(cfg, params, mesh, num_slots=1, clock=fc)
    r0 = eng.submit(Request(_prompt(cfg, 4, 0), max_new_tokens=8))
    r1 = eng.submit(Request(_prompt(cfg, 4, 1), max_new_tokens=8,
                            ttft_deadline_s=0.25))
    fc.advance(1.0)
    outs, s = eng.run()
    assert len(outs[r0]) == 8
    assert len(outs[r1]) == 0
    assert eng.metrics.per_request[r1].finish_reason == "deadline"
    assert eng.metrics.per_request[r1].ttft_s is None


# -- metrics edge cases ------------------------------------------------------


def test_summary_with_no_emissions_and_empty_engine(setup):
    cfg, params, mesh = setup
    # A metrics object with zero requests summarizes without dividing by
    # zero anywhere.
    empty = ServingMetrics(num_slots=2).summary()
    assert empty["ttft_ticks_p50"] is None and empty["shed_rate"] == 0.0
    # A request cancelled before any token: excluded from TTFT
    # percentiles (not counted as 0), still in the terminated counters.
    eng = _engine(cfg, params, mesh)
    rid = eng.submit(Request(_prompt(cfg, 4), max_new_tokens=4))
    eng.cancel(rid)
    outs, s = eng.run()
    st = eng.metrics.per_request[rid]
    assert st.ttft_ticks is None and st.ttft_s is None
    assert s["ttft_ticks_p50"] is None
    assert s["requests_terminated"] == 1 and s["requests_completed"] == 0


# -- NaN quarantine + chaos harness -----------------------------------------


def _trace(cfg, n=3, max_new=8):
    rng = np.random.default_rng(7)
    return [Request(rng.integers(3, cfg.vocab_size,
                                 size=5).astype(np.int32),
                    max_new_tokens=max_new, arrival_time=float(i))
            for i in range(n)]


@pytest.mark.chaos
def test_nan_quarantine_retry_reproduces_stream(setup):
    """Inject NaNs into live slots; the macro-step fault lane detects
    them, the host quarantines + retries, and every successfully-finished
    stream is byte-identical to the fault-free run — retry-from-scratch
    is transparent under (seed, rid, idx)-keyed sampling."""
    cfg, params, mesh = setup
    base, _ = _engine(cfg, params, mesh,
                      temperature=0.7).run(_trace(cfg))
    inj = faults.FaultInjector(seed=7, nan_every=5)
    eng = _engine(cfg, params, mesh, temperature=0.7, injector=inj)
    outs, s = eng.run(_trace(cfg))
    assert s["faults_detected"] >= 1
    assert s["fault_retries"] >= 1
    assert s["fault_retries_succeeded"] >= 1
    assert s["final_occupancy"] == 0
    for rid, st in eng.metrics.per_request.items():
        assert st.finish_reason in ("eos", "length", "fault")
        assert st.retries <= 1
        if st.finish_reason in ("eos", "length"):
            np.testing.assert_array_equal(outs[rid], base[rid])
    lat = faults.detection_latencies(inj.log, eng.metrics.fault_events)
    assert lat and max(lat) <= 4 * eng.serving.macro_ticks


@pytest.mark.chaos
def test_fault_retries_exhausted_terminates_as_fault(setup):
    cfg, params, mesh = setup
    inj = faults.FaultInjector(seed=7, nan_every=1)
    eng = _engine(cfg, params, mesh, num_slots=1, fault_retries=0,
                  injector=inj)
    rid = eng.submit(Request(_prompt(cfg, 4), max_new_tokens=8))
    outs, s = eng.run()
    assert eng.metrics.per_request[rid].finish_reason == "fault"
    assert s["finish_reasons"] == {"fault": 1}
    assert s["fault_retries"] == 0
    assert s["final_occupancy"] == 0


@pytest.mark.chaos
def test_chaos_run_is_deterministic(setup):
    """Same trace + same injector seed => identical fault schedule,
    identical streams, identical degraded-mode counters."""
    cfg, params, mesh = setup

    def once():
        inj = faults.FaultInjector(seed=11, nan_every=4, cancel_every=9,
                                   delay_prob=0.5, max_delay_ticks=3)
        eng = _engine(cfg, params, mesh, injector=inj)
        outs, s = eng.run(_trace(cfg, n=4))
        return inj.log, outs, s["finish_reasons"]

    log_a, outs_a, fr_a = once()
    log_b, outs_b, fr_b = once()
    assert log_a == log_b
    assert fr_a == fr_b
    assert set(outs_a) == set(outs_b)
    for rid in outs_a:
        np.testing.assert_array_equal(outs_a[rid], outs_b[rid])
