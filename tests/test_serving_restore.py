"""Crash-safe serving (DESIGN.md §12): write-ahead journal framing with
tolerant torn-tail replay, atomic checkpoint save/load with corrupt-file
fallback, and the kill-and-restore byte-identity contract — crashes
mid-decode and mid-chunked-prefill, truncated/corrupt journal tails,
restore onto a different slot count, and exactly-once (re)delivery."""
import dataclasses
import os

import jax
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ServingConfig
from repro.launch.mesh import make_host_mesh
from repro.models import api
from repro.serving import checkpoint as ckpt_lib
from repro.serving import faults
from repro.serving import journal as journal_lib
from repro.serving.engine import ContinuousServingEngine, Request

pytestmark = pytest.mark.serving


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get_smoke_config("slayformer-124m")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    mesh = make_host_mesh()
    return cfg, params, mesh


@pytest.fixture(scope="module")
def ring_setup():
    cfg = configs.get_smoke_config("slayformer-124m", attn_kind="softmax")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _sv(**kw):
    return ServingConfig(**{"num_slots": 2, "max_len": 64,
                            "prefill_chunk": 4, "macro_ticks": 4,
                            "temperature": 0.7,
                            "checkpoint_every_ticks": 6, **kw})


def _trace(cfg, n=3, max_new=12, plen=5):
    rng = np.random.default_rng(7)
    return [Request(rng.integers(3, cfg.vocab_size,
                                 size=plen).astype(np.int32),
                    max_new_tokens=max_new, arrival_time=float(i))
            for i in range(n)]


def _baseline(cfg, params, mesh, sv, **tr):
    """Fault-free reference streams for the trace (no journal)."""
    eng = ContinuousServingEngine(
        cfg, params, mesh,
        serving=dataclasses.replace(sv, checkpoint_every_ticks=0))
    return eng.run(_trace(cfg, **tr))


def _crash_run(cfg, params, mesh, d, sv, *, crash_window=(8, 14), **tr):
    """Run the trace against a journaled engine until the injected crash
    kills it mid-flight; returns the dead engine."""
    jr = journal_lib.Journal(os.path.join(d, journal_lib.JOURNAL_NAME))
    inj = faults.FaultInjector(seed=3, crash_window=crash_window)
    eng = ContinuousServingEngine(cfg, params, mesh, serving=sv,
                                  fault_injector=inj, journal=jr)
    with pytest.raises(faults.EngineCrash):
        eng.run(_trace(cfg, **tr))
    return eng


# -- journal unit behavior ---------------------------------------------------


def test_journal_roundtrip_and_replay(tmp_path):
    p = str(tmp_path / "j.wal")
    with journal_lib.Journal(p) as j:
        j.append({"t": "meta", "v": 1, "seed": 0})
        j.append({"t": "admit", "rid": 0, "prompt": [1, 2, 3]})
        j.append({"t": "tok", "rid": 0, "tok": 42, "idx": 0})
        j.append({"t": "fin", "rid": 0, "reason": "length", "tick": 3})
        j.flush()
        assert j.flushes == 1 and not j.dirty
    st = journal_lib.replay(p)
    assert st.meta["seed"] == 0
    assert st.admits[0]["prompt"] == [1, 2, 3]
    assert st.tokens[0] == [42]
    assert st.fins[0] == "length"
    assert not st.dropped_tail
    assert st.valid_bytes == os.path.getsize(p)


def test_journal_torn_tail_dropped_and_truncated(tmp_path):
    """A torn (partial) final record is dropped by replay and physically
    truncated when the journal reopens for append — a corrupt tail can
    never shadow records written after recovery."""
    p = str(tmp_path / "j.wal")
    with journal_lib.Journal(p) as j:
        j.append({"t": "admit", "rid": 0, "prompt": [1]})
        j.append({"t": "tok", "rid": 0, "tok": 5, "idx": 0})
        j.flush()
    whole = os.path.getsize(p)
    with open(p, "ab") as f:                   # torn write: half a record
        f.write(b'deadbeef {"t": "tok", "rid": 0,')
    st = journal_lib.replay(p)
    assert st.dropped_tail and st.valid_bytes == whole
    assert st.tokens[0] == [5]                 # intact prefix survives
    with journal_lib.Journal(p, truncate_to=st.valid_bytes) as j2:
        j2.append({"t": "tok", "rid": 0, "tok": 6, "idx": 1})
        j2.flush()
    assert journal_lib.replay(p).tokens[0] == [5, 6]


def test_journal_crc_corruption_drops_suffix(tmp_path):
    """A bit-flip in the middle of the file invalidates that record's CRC;
    replay keeps only the records before it (suffix ordering after a bad
    record is no longer trustworthy)."""
    p = str(tmp_path / "j.wal")
    with journal_lib.Journal(p) as j:
        for i in range(4):
            j.append({"t": "tok", "rid": 0, "tok": i, "idx": i})
        j.flush()
    with open(p, "rb") as f:
        lines = f.readlines()
    lines[2] = lines[2].replace(b'"tok"', b'"toX"')
    with open(p, "wb") as f:
        f.writelines(lines)
    st = journal_lib.replay(p)
    assert st.dropped_tail
    assert st.tokens[0] == [0, 1]              # records 2, 3 both dropped


def test_journal_retry_record_resets_stream(tmp_path):
    p = str(tmp_path / "j.wal")
    with journal_lib.Journal(p) as j:
        j.append({"t": "tok", "rid": 1, "tok": 9, "idx": 0})
        j.append({"t": "retry", "rid": 1})
        j.append({"t": "tok", "rid": 1, "tok": 4, "idx": 0})
        j.flush()
    st = journal_lib.replay(p)
    assert st.tokens[1] == [4] and st.retries[1] == 1


# -- checkpoint unit behavior ------------------------------------------------


def _flip_last_byte(path):
    """Invert the final payload byte — the sha256 check must catch it."""
    with open(path, "r+b") as f:
        f.seek(-1, os.SEEK_END)
        b = f.read(1)[0]
        f.seek(-1, os.SEEK_END)
        f.write(bytes([b ^ 0xFF]))


def test_checkpoint_save_load_roundtrip(tmp_path):
    p = str(tmp_path / "ckpt-000000000007.ckpt")
    state = {"version": 1, "tick": 7,
             "arr": np.arange(12, dtype=np.float32).reshape(3, 4)}
    ckpt_lib.save(p, state)
    assert not any(f.endswith(".tmp") for f in os.listdir(str(tmp_path)))
    got = ckpt_lib.load(p)
    assert got["tick"] == 7
    np.testing.assert_array_equal(got["arr"], state["arr"])


def test_checkpoint_corruption_detected_and_skipped(tmp_path):
    d = str(tmp_path)
    ckpt_lib.save(ckpt_lib.checkpoint_path(d, 3), {"tick": 3})
    newest = ckpt_lib.checkpoint_path(d, 9)
    ckpt_lib.save(newest, {"tick": 9})
    _flip_last_byte(newest)
    with pytest.raises(ckpt_lib.CheckpointError):
        ckpt_lib.load(newest)
    # latest_valid falls back to the intact older checkpoint.
    assert ckpt_lib.latest_valid(d)["tick"] == 3
    assert [t for t, _ in ckpt_lib.list_checkpoints(d)] == [9, 3]


def test_checkpoint_latest_valid_empty_dir(tmp_path):
    assert ckpt_lib.latest_valid(str(tmp_path)) is None


# -- kill-and-restore byte-identity across regimes ---------------------------


@pytest.mark.chaos
@pytest.mark.parametrize("regime", ["constant_state", "kv_ring", "paged"])
def test_crash_restore_byte_identical(setup, ring_setup, regime, tmp_path):
    """Kill the engine mid-flight (seeded crash injector), restore from
    disk, finish — merged streams are byte-identical to a fault-free run,
    replay is observed and deduped, and nothing leaks."""
    cfg, params, mesh = setup
    kw = {}
    if regime != "constant_state":
        cfg, params = ring_setup
    if regime == "paged":
        kw["page_size"] = 16
    sv = _sv(**kw)
    base, _ = _baseline(cfg, params, mesh, sv)
    d = str(tmp_path)
    _crash_run(cfg, params, mesh, d, sv)
    got = {}
    eng2 = ContinuousServingEngine.restore(
        d, cfg, params, mesh, serving=sv,
        on_token=lambda rid, tok: got.setdefault(rid, []).append(tok))
    assert eng2.recovery["wall_s"] >= 0.0
    outs, s = eng2.run()
    assert set(outs) == set(base)
    for rid in base:
        np.testing.assert_array_equal(outs[rid], base[rid])
    assert s["tokens_replayed"] > 0
    assert s["final_occupancy"] == 0 and s["final_queue_depth"] == 0
    assert s["final_pages_in_use"] == 0
    # Exactly-once streaming: post-restore callbacks got precisely the
    # journal-horizon suffix of each stream, never a replayed token.
    for rid, toks in got.items():
        np.testing.assert_array_equal(
            toks, np.asarray(outs[rid])[len(outs[rid]) - len(toks):])


@pytest.mark.chaos
def test_crash_uses_checkpoint_and_resumes_residents(setup, tmp_path):
    """With the crash landing after the first periodic checkpoint, restore
    actually consumes it: device state comes back via the snapshot and at
    least the pre-crash terminations are known without re-decoding."""
    cfg, params, mesh = setup
    sv = _sv()
    d = str(tmp_path)
    eng = _crash_run(cfg, params, mesh, d, sv)
    assert eng.metrics.checkpoints_written >= 1
    eng2 = ContinuousServingEngine.restore(d, cfg, params, mesh, serving=sv)
    rec = eng2.recovery
    assert rec["checkpoint_used"] and rec["checkpoint_tick"] >= 1
    assert rec["resident_resumed"] + rec["requeued"] >= 1
    outs, s = eng2.run()
    base, _ = _baseline(cfg, params, mesh, sv)
    for rid in base:
        np.testing.assert_array_equal(outs[rid], base[rid])
    assert s["checkpoints_written"] >= 0 and s["journal_bytes"] > 0


@pytest.mark.chaos
def test_crash_mid_chunked_prefill_restores_byte_identical(setup, tmp_path):
    """Crash while a long prompt is mid-chunked-prefill: the checkpoint
    deliberately excludes the half-prefilled slot, so the request
    re-admits from its journaled prompt and re-runs the identical chunk
    schedule — streams still byte-identical."""
    cfg, params, mesh = setup
    sv = _sv(checkpoint_every_ticks=2)
    tr = dict(n=2, max_new=6, plen=24)          # 24/4 = 6 prefill chunks
    base, _ = _baseline(cfg, params, mesh, sv, **tr)
    d = str(tmp_path)
    eng = _crash_run(cfg, params, mesh, d, sv, crash_window=(3, 3), **tr)
    assert eng.tick <= 6                        # died inside prefill
    eng2 = ContinuousServingEngine.restore(d, cfg, params, mesh, serving=sv)
    outs, s = eng2.run()
    assert set(outs) == set(base)
    for rid in base:
        np.testing.assert_array_equal(outs[rid], base[rid])
    assert s["final_occupancy"] == 0 and s["final_pages_in_use"] == 0


@pytest.mark.chaos
def test_restore_with_truncated_journal_tail(setup, tmp_path):
    """Chop bytes off the journal's final record post-crash (a torn write
    at kill time). The lost suffix tokens simply regenerate — recovery
    falls back as far as needed and streams stay byte-identical."""
    cfg, params, mesh = setup
    sv = _sv()
    base, _ = _baseline(cfg, params, mesh, sv)
    d = str(tmp_path)
    _crash_run(cfg, params, mesh, d, sv)
    jpath = os.path.join(d, journal_lib.JOURNAL_NAME)
    with open(jpath, "r+b") as f:
        f.truncate(os.path.getsize(jpath) - 5)
    assert journal_lib.replay(jpath).dropped_tail
    eng2 = ContinuousServingEngine.restore(d, cfg, params, mesh, serving=sv)
    assert eng2.recovery["journal_dropped_tail"]
    outs, s = eng2.run()
    assert set(outs) == set(base)
    for rid in base:
        np.testing.assert_array_equal(outs[rid], base[rid])
    assert s["final_occupancy"] == 0


@pytest.mark.chaos
def test_restore_onto_different_slot_count(setup, tmp_path):
    """A checkpoint from a 2-slot engine is rejected wholesale when
    restoring with num_slots=3 (geometry gate) — recovery degrades to
    journal-only replay and the streams are still byte-identical."""
    cfg, params, mesh = setup
    sv = _sv()
    base, _ = _baseline(cfg, params, mesh, sv)
    d = str(tmp_path)
    eng = _crash_run(cfg, params, mesh, d, sv)
    assert eng.metrics.checkpoints_written >= 1
    sv3 = _sv(num_slots=3)
    eng2 = ContinuousServingEngine.restore(d, cfg, params, mesh, serving=sv3)
    assert not eng2.recovery["checkpoint_used"]
    outs, s = eng2.run()
    assert set(outs) == set(base)
    for rid in base:
        np.testing.assert_array_equal(outs[rid], base[rid])
    assert s["tokens_replayed"] > 0
    assert s["final_occupancy"] == 0


@pytest.mark.chaos
def test_restore_redelivers_exactly_once(setup, tmp_path):
    """redeliver=True re-fires on_token/on_finish for the journaled
    prefix at restore time; with the post-restore stream appended, a
    consumer that lost its own state sees every token exactly once."""
    cfg, params, mesh = setup
    sv = _sv()
    base, _ = _baseline(cfg, params, mesh, sv)
    d = str(tmp_path)
    _crash_run(cfg, params, mesh, d, sv)
    got, fins = {}, []
    eng2 = ContinuousServingEngine.restore(
        d, cfg, params, mesh, serving=sv, redeliver=True,
        on_token=lambda rid, tok: got.setdefault(rid, []).append(tok),
        on_finish=lambda rid, why: fins.append((rid, why)))
    outs, _ = eng2.run()
    for rid in base:
        np.testing.assert_array_equal(got.get(rid, []), outs[rid])
    assert sorted(rid for rid, _ in fins) == sorted(base)
    assert len(fins) == len(set(rid for rid, _ in fins))   # once per rid


def test_restore_refuses_mismatched_sampling_config(setup, tmp_path):
    """Byte-identity is only promised under the exact sampling config the
    journal was written with — a different seed/temperature at restore is
    a hard error, not silent divergence."""
    cfg, params, mesh = setup
    d = str(tmp_path)
    _crash_run(cfg, params, mesh, d, _sv())
    with pytest.raises(ValueError, match="seed"):
        ContinuousServingEngine.restore(d, cfg, params, mesh,
                                        serving=_sv(seed=123))
    with pytest.raises(ValueError, match="temperature"):
        ContinuousServingEngine.restore(d, cfg, params, mesh,
                                        serving=_sv(temperature=0.9))


@pytest.mark.chaos
def test_restore_skips_corrupt_newest_checkpoint(setup, tmp_path):
    """Corrupting the newest checkpoint on disk exercises latest_valid's
    fallback inside the real recovery path: the older intact checkpoint
    (or journal-only replay) still yields byte-identical streams."""
    cfg, params, mesh = setup
    sv = _sv(checkpoint_every_ticks=2)
    base, _ = _baseline(cfg, params, mesh, sv)
    d = str(tmp_path)
    eng = _crash_run(cfg, params, mesh, d, sv)
    assert eng.metrics.checkpoints_written >= 2
    ticks = [t for t, _ in ckpt_lib.list_checkpoints(d)]
    _flip_last_byte(ckpt_lib.checkpoint_path(d, ticks[0]))
    eng2 = ContinuousServingEngine.restore(d, cfg, params, mesh, serving=sv)
    assert eng2.recovery["checkpoint_used"]
    assert eng2.recovery["checkpoint_tick"] == ticks[1]
    outs, _ = eng2.run()
    for rid in base:
        np.testing.assert_array_equal(outs[rid], base[rid])
