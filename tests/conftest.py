"""Shared fixtures. NOTE: no XLA_FLAGS here — unit/smoke tests run on the
single real CPU device; only the dry-run sets the 512-device placeholder
flag (and only in its own process)."""
import os

import jax
import numpy as np
import pytest

# Every engine.run() in the test suite ends with the invariant audit
# (DESIGN.md §12): PagePool.check() + prefix-cache refcounts == live pins.
# setdefault so REPRO_DEBUG_AUDIT=0 can still switch it off locally.
os.environ.setdefault("REPRO_DEBUG_AUDIT", "1")

# Seed discipline: the byte-identity and sampling-contract suites
# (DESIGN.md §12-13) only mean anything if every random draw is pinned.
# This used to be a runtime monkeypatch of np.random.default_rng here
# (tests-only, and blind to src/ and benchmarks/); it is now the RNG001
# rule of the static jit-safety linter (repro.analysis.jitlint, DESIGN.md
# §14), which covers src/, benchmarks/, tests/ and tools/ at CI time via
# `tools/lint_contracts.py --all`. (jax.random needs no guard — PRNGKey
# requires an explicit seed by construction — and hypothesis is
# derandomized in test_properties.py.)


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
    config.addinivalue_line(
        "markers",
        "kernels: interpret-mode Pallas kernel tests (pytest -m kernels)")
    config.addinivalue_line(
        "markers",
        "serving: continuous-batching serving tests (pytest -m serving)")
    config.addinivalue_line(
        "markers",
        "chaos: fault-injection / degraded-mode serving tests "
        "(pytest -m chaos)")
