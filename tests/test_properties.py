"""Hypothesis property-based tests on the system's invariants:

* kernel positivity/boundedness for arbitrary inputs (Props. 3/4, §G),
* strictly positive attention denominators (the paper's key stability claim
  vs TensorSketch/RM — §L.2),
* chunk-size invariance of the causal linear attention,
* checkpoint roundtrip identity for arbitrary pytrees.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import linear_attention as la
from repro.core import quadrature as qd
from repro.core.features import (SlayFeatureConfig, init_feature_params,
                                 normalize, slay_features)

# derandomize: hypothesis otherwise draws fresh examples per run — the
# one unpinned randomness source the conftest seed guard can't see.
_settings = settings(max_examples=25, deadline=None, derandomize=True)


@given(x=st.floats(-1.0, 1.0), eps=st.floats(1e-4, 1.0))
@_settings
def test_kernel_bounds_pointwise(x, eps):
    k = float(qd.exact_spherical_yat(np.asarray([x]), eps)[0])
    assert 0.0 <= k <= 1.0 / eps + 1e-9


@given(x=st.floats(-1.0, 1.0), eps=st.floats(1e-3, 1.0),
       r=st.integers(1, 12))
@_settings
def test_quadrature_nonnegative_pointwise(x, eps, r):
    k = float(qd.quadrature_kernel(np.asarray([x]), r, eps)[0])
    assert k >= 0.0
    # Quadrature of a nonneg integrand with nonneg weights underestimates
    # near x->1 but must never exceed ~the true kernel by more than the
    # quadrature error bound; sanity: stays finite and below 2/eps.
    assert k <= 2.0 / eps


@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 12),
       d=st.integers(2, 32))
@_settings
def test_denominator_positivity(seed, n, d):
    """sum_j <Ψ(q_i), Ψ(k_j)> > 0 for any inputs — the anchor+PRF map is
    strictly positive-denominator (paper Fig. 7)."""
    key = jax.random.PRNGKey(seed)
    cfg = SlayFeatureConfig(head_dim=d, num_anchors=4, num_prf=4,
                            num_quad_nodes=2)
    params = init_feature_params(key, cfg)
    ks = jax.random.split(key, 2)
    q = jax.random.normal(ks[0], (n, d)) * 3.0
    k = jax.random.normal(ks[1], (n, d)) * 3.0
    fq = slay_features(q, params, cfg)
    fk = slay_features(k, params, cfg)
    den = np.asarray(jnp.einsum("im,jm->i", fq, fk))
    assert np.all(den > 0.0)


@given(seed=st.integers(0, 2**31 - 1))
@_settings
def test_normalize_idempotent(seed):
    u = jax.random.normal(jax.random.PRNGKey(seed), (5, 8)) * 10
    n1 = normalize(u)
    n2 = normalize(n1)
    np.testing.assert_allclose(np.asarray(n1), np.asarray(n2), atol=2e-3)


@given(seed=st.integers(0, 2**31 - 1), chunk_a=st.sampled_from([2, 4, 8]),
       chunk_b=st.sampled_from([3, 16, 24]))
@_settings
def test_chunk_invariance_property(seed, chunk_a, chunk_b):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    B, L, H, m, dv = 1, 24, 2, 6, 4
    qf = jax.random.uniform(ks[0], (B, L, H, m))
    kf = jax.random.uniform(ks[1], (B, L, H, m))
    v = jax.random.normal(ks[2], (B, L, H, dv))
    a = la.causal_chunked(qf, kf, v, chunk_size=chunk_a)
    b = la.causal_chunked(qf, kf, v, chunk_size=chunk_b)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5)


@given(seed=st.integers(0, 2**31 - 1))
@_settings
def test_rope_preserves_norm(seed):
    from repro.models.layers import rope
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 6, 2, 16))
    pos = jnp.arange(6, dtype=jnp.int32)[None, :]
    y = rope(x, pos)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-4)


@given(seed=st.integers(0, 2**31 - 1))
@_settings
def test_rope_relative_property(seed):
    """<rope(q,p1), rope(k,p2)> depends only on p1-p2."""
    from repro.models.layers import rope
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (1, 1, 1, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, 16))
    def dot_at(p1, p2):
        pq = jnp.asarray([[p1]], jnp.int32)
        pk = jnp.asarray([[p2]], jnp.int32)
        return float(jnp.sum(rope(q, pq) * rope(k, pk)))
    assert abs(dot_at(3, 1) - dot_at(7, 5)) < 1e-4


@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(0.1, 100.0))
@_settings
def test_spherical_kernel_scale_invariant(seed, scale):
    """Remark 3: uniform scaling prior to normalization leaves E_sph fixed."""
    from repro.core.kernels import spherical_yat_scores
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (1, 4, 1, 8))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 4, 1, 8))
    s1 = spherical_yat_scores(q, k)
    s2 = spherical_yat_scores(q * scale, k * scale)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-3,
                               rtol=2e-2)


@given(seed=st.integers(0, 2**31 - 1))
@_settings
def test_adamw_descends_on_quadratic(seed):
    """Optimizer property: on f(w) = ||w||^2/2, a step moves toward 0."""
    from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                      weight_decay=0.0)
    w = {"w": jax.random.normal(jax.random.PRNGKey(seed), (8,)) + 5.0}
    st_ = adamw_init(w, cfg)
    g = jax.tree.map(lambda x: x, w)   # grad of ||w||^2/2 is w
    w2, st2, _ = adamw_update(g, st_, w, cfg)
    assert float(jnp.linalg.norm(w2["w"])) < float(jnp.linalg.norm(w["w"]))
