"""Gauss-Laguerre quadrature of the Bernstein/Laplace representation
(paper §2.4.1, App. E/J/L.3)."""
import numpy as np
import pytest

from repro.core import quadrature as qd


def test_nodes_weights_integrate_one():
    # ∫ e^{-t} dt = 1  -> weights sum to 1.
    for r in (1, 2, 3, 8, 16):
        _, a = qd.laguerre_nodes(r)
        np.testing.assert_allclose(a.sum(), 1.0, rtol=1e-12)


def test_scaled_rule_reproduces_1_over_c():
    # ∫ e^{-Cs} ds = 1/C exactly for any R >= 1 (h == x^2 e^{2sx} with x=0
    # is not this; use h == 1).
    for eps in (1e-3, 1e-1, 1.0):
        c = 2.0 + eps
        s, w = qd.yat_quadrature(4, eps)
        np.testing.assert_allclose(np.sum(w), 1.0 / c, rtol=1e-12)


def test_quadrature_converges_to_kernel():
    """Error decreases with R and is small away from the x->1 boundary
    (paper Fig. 9: exponential convergence for smooth integrands)."""
    x = np.linspace(-1.0, 0.9, 101)
    exact = qd.exact_spherical_yat(x, 1e-1)
    errs = []
    for r in (1, 2, 4, 8, 16, 32):
        approx = qd.quadrature_kernel(x, r, 1e-1)
        errs.append(np.max(np.abs(approx - exact)))
    # monotone (weakly) decreasing and small at R=32
    assert errs[-1] < 2e-3
    assert errs[-1] < errs[0] / 50


def test_quadrature_kernel_nonnegative():
    x = np.linspace(-1, 1, 201)
    for r in (1, 3, 8):
        assert np.all(qd.quadrature_kernel(x, r, 1e-3) >= 0.0)


def test_exact_kernel_bounds():
    # Proposition 3: 0 <= E_sph <= 1/eps, max at x=1.
    x = np.linspace(-1, 1, 2001)
    for eps in (1e-3, 1e-2, 1.0):
        k = qd.exact_spherical_yat(x, eps)
        assert np.all(k >= 0)
        assert np.all(k <= 1.0 / eps + 1e-9)
        np.testing.assert_allclose(k[-1], 1.0 / eps, rtol=1e-9)


def test_invalid_args_raise():
    with pytest.raises(ValueError):
        qd.yat_quadrature(0, 1e-3)
    with pytest.raises(ValueError):
        qd.yat_quadrature(3, 0.0)


def test_exact_kernel_positive_definite_gram():
    """Theorem 2: E_sph is PD on the sphere — Gram matrices of random unit
    vectors must be PSD (up to numerical tolerance)."""
    rng = np.random.default_rng(7)
    for d in (2, 4, 16):
        u = rng.normal(size=(24, d))
        u /= np.linalg.norm(u, axis=1, keepdims=True)
        x = u @ u.T
        gram = qd.exact_spherical_yat(np.clip(x, -1, 1), 1e-2)
        evals = np.linalg.eigvalsh(gram)
        assert evals.min() > -1e-8 * max(1.0, evals.max())
