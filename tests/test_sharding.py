"""Logical-axis sharding rules: divisibility fallback, spec construction,
activation-constraint context (single-device mesh — the 512-device grid is
exercised by the dry-run in its own process)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh()  # (1, 1) ("data", "model")


def test_partition_spec_basic(mesh):
    spec = shd.partition_spec(mesh, shd.DEFAULT_RULES, (8, 4),
                              ("embed", "heads"))
    assert spec == P("data", "model")


def test_partition_spec_divisibility_fallback():
    """A dim not divisible by the mesh axis must drop the axis (replicate)
    and log the fallback."""
    import os
    rules = shd.ShardingRules()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    log = []
    # mesh axes are size 1 -> everything divisible; simulate via rule lookup
    spec = shd.partition_spec(mesh, rules, (7, 3), ("embed", "kv_heads"), log)
    assert spec == P("data", "model")   # size-1 axes always divide


def test_partition_spec_drops_reused_axis(mesh):
    """Two dims mapping to the same mesh axis: second occurrence drops."""
    spec = shd.partition_spec(mesh, shd.DEFAULT_RULES, (4, 4),
                              ("heads", "mlp"))
    # both map to 'model'; second is dropped
    assert spec == P("model")


def test_partition_spec_rank_mismatch_raises(mesh):
    with pytest.raises(ValueError):
        shd.partition_spec(mesh, shd.DEFAULT_RULES, (4, 4), ("embed",))


def test_logical_to_sharding_pytree(mesh):
    abstract = {"w": jax.ShapeDtypeStruct((8, 4), jnp.float32),
                "b": jax.ShapeDtypeStruct((4,), jnp.float32)}
    axes = {"w": ("embed", "mlp"), "b": (None,)}
    sh = shd.logical_to_sharding(mesh, shd.DEFAULT_RULES, abstract, axes)
    assert sh["w"].spec == P("data", "model")
    assert sh["b"].spec == P()


def test_constrain_noop_without_context():
    x = jnp.ones((4, 4))
    y = shd.constrain(x, ("act_batch", "act_embed"))
    assert y is x


def test_constrain_applies_in_context(mesh):
    x = jnp.ones((4, 4))
    with shd.activation_sharding(mesh):
        y = shd.constrain(x, ("act_batch", "act_embed"))
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_batch_sharding_spec(mesh):
    bs = shd.batch_sharding(mesh)
    assert bs.spec == P("data")  # 'pod' absent on the single-pod mesh


def test_shard_params_device_put(mesh):
    params = {"w": jnp.ones((8, 4))}
    axes = {"w": ("embed", "mlp")}
    out = shd.shard_params(mesh, shd.DEFAULT_RULES, params, axes)
    np.testing.assert_array_equal(np.asarray(out["w"]), 1.0)


def test_cache_sharding_specs(mesh):
    abstract = {
        "kv": jax.ShapeDtypeStruct((2, 4, 8, 2, 16), jnp.bfloat16),
        "state": jax.ShapeDtypeStruct((2, 4, 2, 24, 16), jnp.float32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    sh = shd.cache_sharding(mesh, shd.DEFAULT_RULES, abstract)
    for v in jax.tree.leaves(sh):
        assert v.mesh.shape == mesh.shape


def test_rules_are_swappable():
    """§Perf iterations swap whole rule sets without touching model code."""
    import dataclasses
    fsdp_only = dataclasses.replace(shd.DEFAULT_RULES, heads=None, mlp=None,
                                    vocab=None, act_heads=None, act_mlp=None)
    mesh = make_host_mesh()
    spec = shd.partition_spec(mesh, fsdp_only, (8, 4), ("embed", "heads"))
    assert spec == P("data")
