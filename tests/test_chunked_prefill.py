"""Exact chunked-prefill continuation for the scan-carry families and the
exact quadratic yat kinds (DESIGN.md §9): SSD ragged-tail regression vs a
loop oracle, chunked-vs-whole-prompt parity across ragged chunk schedules
for ssm/hybrid and yat, serving-engine stream equality between the new
chunked path and the retired bucketed fallback, and the admission-time
vision-prefix capacity rules — bounded rings still reject oversized
prompts, while unbounded (linear) vision configs absorb the patch prefix
chunk-by-chunk instead (DESIGN.md §11)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ServingConfig
from repro.launch.mesh import make_host_mesh
from repro.models import api, ssm
from repro.models.layers import realize
from repro.serving.engine import (ContinuousServingEngine, Request,
                                  ServingEngine)

# The ISSUE's two ragged schedules (prompt length 529) scaled down by 16x
# for the per-arch engine tests; the SSD unit tests use the full lengths.
_SCHEDULES = ([256, 256, 17], [129, 400])


def _ssd_kwargs():
    return dict(d_state=8, expand=2, head_dim=8, ngroups=1, conv_width=4)


def _ssd_params(key, d_model=16):
    kw = _ssd_kwargs()
    specs = ssm.ssd_specs(d_model, kw["d_state"], kw["expand"],
                          kw["head_dim"], kw["ngroups"], kw["conv_width"])
    return realize(specs, key, jnp.float32), kw


# ---------------------------------------------------------------------------
# SSD unit level
# ---------------------------------------------------------------------------


def test_ssd_chunked_ragged_tail_matches_loop_oracle(key):
    """Regression: L=257 with chunk=256 used to raise ValueError in
    _ssd_chunked; the zero-padded (dt=0) tail must match the per-token
    decode recurrence exactly."""
    params, kw = _ssd_params(key)
    B, L = 2, 257
    x = jax.random.normal(jax.random.PRNGKey(1), (B, L, 16)) * 0.3
    y_full = ssm.ssd_forward(params, x, chunk_size=256, **kw)
    state = ssm.ssd_init_state((B,), 16, kw["d_state"], kw["expand"],
                               kw["head_dim"], kw["ngroups"],
                               kw["conv_width"])

    def step(st, xt):
        y, st = ssm.ssd_decode_step(params, xt, st, **kw)
        return st, y

    _, y_dec = jax.lax.scan(step, state, jnp.moveaxis(x, 1, 0))
    np.testing.assert_allclose(np.asarray(jnp.moveaxis(y_dec, 0, 1)),
                               np.asarray(y_full), atol=2e-4, rtol=1e-3)


@pytest.mark.parametrize("schedule", _SCHEDULES, ids=["256-256-17",
                                                      "129-400"])
def test_ssd_prefill_chunk_schedule_invariant(key, schedule):
    """ssd_prefill_chunk absorbed chunk-by-chunk reproduces the whole-
    sequence forward (outputs) and a one-shot absorption (final scan state
    + conv tail), for ragged schedules."""
    params, kw = _ssd_params(key)
    B, L = 1, sum(schedule)
    x = jax.random.normal(jax.random.PRNGKey(2), (B, L, 16)) * 0.3
    y_full = ssm.ssd_forward(params, x, chunk_size=64, **kw)
    st = ssm.ssd_init_state((B,), 16, kw["d_state"], kw["expand"],
                            kw["head_dim"], kw["ngroups"], kw["conv_width"])
    ys, lo = [], 0
    for n in schedule:
        y, st = ssm.ssd_prefill_chunk(params, x[:, lo:lo + n], st,
                                      chunk_size=64, **kw)
        ys.append(y)
        lo += n
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_full), atol=1e-5, rtol=1e-4)
    st_one = ssm.ssd_init_state((B,), 16, kw["d_state"], kw["expand"],
                                kw["head_dim"], kw["ngroups"],
                                kw["conv_width"])
    _, st_one = ssm.ssd_prefill_chunk(params, x, st_one, chunk_size=64,
                                      **kw)
    np.testing.assert_allclose(np.asarray(st.h), np.asarray(st_one.h),
                               atol=1e-5, rtol=1e-4)
    np.testing.assert_array_equal(np.asarray(st.conv),
                                  np.asarray(st_one.conv))


# ---------------------------------------------------------------------------
# Model level: chunked vs whole-prompt prefill
# ---------------------------------------------------------------------------


def _chunk_parity(cfg, schedule, atol=5e-3):
    """fp32 activations so the check is tight: the continuation is exact
    math, and only fp summation order differs between schedules (bf16
    engine streams are covered token-exactly by the engine tests)."""
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    L = sum(schedule)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, L), 3,
                              cfg.vocab_size)
    lg_full, cache_full = api.prefill(params, cfg, {"tokens": toks},
                                      max_len=L + 16)
    cache = api.init_cache(cfg, 1, L + 16)
    lo = 0
    for n in schedule:
        lg, cache = api.prefill_chunk(cfg, params, cache,
                                      toks[:, lo:lo + n])
        lo += n
    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               np.asarray(lg_full, np.float32), atol=atol)
    assert np.asarray(cache.pos).tolist() == [L]
    tok = jnp.argmax(lg_full[:, -1], -1).astype(jnp.int32)[:, None]
    for _ in range(3):
        l1, cache_full = api.decode_step(params, cfg, cache_full, tok)
        l2, cache = api.decode_step(params, cfg, cache, tok)
        np.testing.assert_allclose(np.asarray(l2, np.float32),
                                   np.asarray(l1, np.float32), atol=atol)
        tok = jnp.argmax(l1[:, -1], -1).astype(jnp.int32)[:, None]


@pytest.mark.serving
@pytest.mark.parametrize("schedule", ([16, 16, 2], [9, 25]),
                         ids=["16-16-2", "9-25"])
@pytest.mark.parametrize("arch", ["mamba2-780m", "hymba-1.5b"])
def test_scan_carry_chunked_prefill_parity(arch, schedule):
    """ssm/hybrid: chunk-by-chunk prefill == whole-prompt prefill (logits,
    pos, decode continuation) across ragged chunk schedules."""
    cfg = configs.get_smoke_config(arch, dtype="float32")
    assert api.supports_chunked_prefill(cfg)
    _chunk_parity(cfg, schedule)


@pytest.mark.serving
@pytest.mark.parametrize("kind", ["yat", "yat_spherical"])
def test_exact_yat_chunked_prefill_parity(kind):
    """Exact quadratic yat kinds: ring-prefix continuation == whole-prompt
    prefill."""
    cfg = configs.get_smoke_config("slayformer-124m", attn_kind=kind,
                                   dtype="float32")
    assert api.supports_chunked_prefill(cfg)
    _chunk_parity(cfg, [4, 5, 2])


@pytest.mark.serving
def test_hybrid_kv_ring_chunked_prefill_parity():
    """Hybrid with a KV-ring attention backend (softmax) chunks exactly
    too — both carries (KV ring + SSD scan state) cross chunk bounds."""
    cfg = configs.get_smoke_config("hymba-1.5b", attn_kind="softmax",
                                   dtype="float32")
    assert api.supports_chunked_prefill(cfg)
    _chunk_parity(cfg, [7, 12, 3])


@pytest.mark.serving
def test_prefill_chunk_gate_errors_name_the_gate():
    """Only encdec still gates chunked prefill, and its error names the
    family. Vision decoders chunk now — the patch prefix feeds through
    ``prefill_chunk(embeds=)`` (DESIGN.md §11)."""
    cfg = configs.get_smoke_config("internvl2-76b")
    assert api.supports_chunked_prefill(cfg)

    wcfg = configs.get_smoke_config("whisper-small")
    assert not api.supports_chunked_prefill(wcfg)
    with pytest.raises(NotImplementedError, match="family='encdec'"):
        api.prefill_chunk(wcfg, None, None, jnp.zeros((1, 4), jnp.int32))


@pytest.mark.serving
def test_every_decoder_only_config_is_chunkable():
    """Acceptance: supports_chunked_prefill is True for every decoder-only
    config (ssm, hybrid, every attn kind, vision frontends); only encdec
    falls back."""
    for name in configs.ALL_ARCHS:
        cfg = configs.get_smoke_config(name)
        want = cfg.family != "encdec"
        assert api.supports_chunked_prefill(cfg) == want, name
    for kind in ("slay", "softmax", "yat", "yat_spherical", "favor",
                 "elu1", "cosformer"):
        cfg = configs.get_smoke_config("slayformer-124m", attn_kind=kind)
        assert api.supports_chunked_prefill(cfg), kind


# ---------------------------------------------------------------------------
# Serving engine level
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh()


def _prompts(cfg, lengths, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(3, cfg.vocab_size, size=n).astype(np.int32)
            for n in lengths]


@pytest.mark.serving
@pytest.mark.parametrize("arch", ["mamba2-780m", "hymba-1.5b"])
def test_engine_scan_carry_stream_parity(arch, mesh):
    """Continuous engine serves ssm/hybrid via chunked prefill (no bucketed
    fallback: bucket counters stay zero) with lockstep stream parity."""
    cfg = configs.get_smoke_config(arch)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    prompts = _prompts(cfg, (5, 9, 3), seed=1)
    reqs = [Request(p, max_new_tokens=5, arrival_time=float(i))
            for i, p in enumerate(prompts)]
    eng = ContinuousServingEngine(
        cfg, params, mesh,
        serving=ServingConfig(num_slots=2, max_len=64, prefill_chunk=4,
                              macro_ticks=4))
    outs, summary = eng.run(reqs)
    assert summary["requests_completed"] == 3
    assert summary["bucket_misses"] == 0 == summary["bucket_hits"]
    assert summary["prefill_ticks"] > 3          # chunked: > 1 per request
    ref = ServingEngine(cfg, params, mesh, max_len=64)
    for i, p in enumerate(prompts):
        want = ref.generate([Request(p, max_new_tokens=5)])[0]
        np.testing.assert_array_equal(outs[i], want)


@pytest.mark.serving
def test_engine_yat_chunked_vs_bucketed_fallback_streams(mesh):
    """Same requests through the new chunked path and the (retired-for-
    default) bucketed masked-prefill fallback produce identical token
    streams — the fallback was masking nothing but compile granularity."""
    cfg = configs.get_smoke_config("slayformer-124m",
                                   attn_kind="yat_spherical")
    assert api.supports_chunked_prefill(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    prompts = _prompts(cfg, (5, 9, 3, 12), seed=2)

    def run(prefill_chunk):
        # prefill_chunk=0 disables the chunked path, so the engine routes
        # through the pow-2 bucketed masked prefill (the old fallback).
        reqs = [Request(p, max_new_tokens=4, arrival_time=float(i))
                for i, p in enumerate(prompts)]
        eng = ContinuousServingEngine(
            cfg, params, mesh,
            serving=ServingConfig(num_slots=2, max_len=64,
                                  prefill_chunk=prefill_chunk,
                                  macro_ticks=4))
        return eng.run(reqs)

    outs_c, sum_c = run(prefill_chunk=4)
    outs_b, sum_b = run(prefill_chunk=0)
    assert sum_c["bucket_misses"] == 0 and sum_c["prefill_ticks"] > 4
    assert sum_b["bucket_misses"] >= 1           # fallback exercised
    for rid in outs_b:
        np.testing.assert_array_equal(outs_c[rid], outs_b[rid])


@pytest.mark.serving
def test_vision_prefix_cap_rejected_at_admission(mesh):
    """A prompt that fits max_len alone but not with the vision patch
    prefix must be rejected at submit() when the ring is bounded (softmax
    backend) — previously the padded bucket slice silently dropped the
    prompt tail."""
    cfg = configs.get_smoke_config("internvl2-76b",
                                   attn_kind="softmax")  # num_patches=8
    assert api.context_capacity(cfg, 32) is not None
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = ContinuousServingEngine(
        cfg, params, mesh,
        serving=ServingConfig(num_slots=1, max_len=32, prefill_chunk=4))
    over = np.ones(32 - 4 - cfg.num_patches + 1, np.int32)  # 1 over budget
    with pytest.raises(ValueError, match="vision-prefix"):
        eng.submit(Request(over, max_new_tokens=4))
    # At the budget it admits and serves.
    fit = np.ones(32 - 4 - cfg.num_patches, np.int32)
    outs, summary = eng.run([Request(fit, max_new_tokens=4)])
    assert summary["requests_completed"] == 1
    assert len(outs[0]) == 4


@pytest.mark.serving
def test_oversized_vision_prompt_served_by_chunked_absorption(mesh):
    """Regression (DESIGN.md §11): the same over-budget request on the
    *linear* backend (constant-state: capacity unbounded) used to be
    rejected too; it now admits, absorbs the patch prefix + prompt
    chunk-by-chunk, and streams exactly what a roomy lockstep reference
    produces. Without chunked prefill the one-shot fallback still cannot
    exceed the ring, so admission keeps rejecting there."""
    cfg = configs.get_smoke_config("internvl2-76b")   # slay: linear
    assert api.context_capacity(cfg, 32) is None
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    over = np.ones(32 - 4 - cfg.num_patches + 1, np.int32)  # 1 over budget
    eng = ContinuousServingEngine(
        cfg, params, mesh,
        serving=ServingConfig(num_slots=1, max_len=32, prefill_chunk=4))
    outs, summary = eng.run([Request(over, max_new_tokens=4)])
    assert summary["requests_completed"] == 1
    ref = ServingEngine(cfg, params, mesh, max_len=64)
    want = ref.generate([Request(over, max_new_tokens=4)])[0]
    np.testing.assert_array_equal(outs[0], want)
    # Chunked prefill is what makes the unbounded admission safe: with it
    # disabled the full-length one-shot prefill would overflow the ring.
    eng0 = ContinuousServingEngine(
        cfg, params, mesh,
        serving=ServingConfig(num_slots=1, max_len=32, prefill_chunk=0))
    with pytest.raises(ValueError, match="vision-prefix"):
        eng0.submit(Request(over, max_new_tokens=4))
