"""Feature maps: PRF unbiasedness, polynomial variants, fused Ψ (paper §2.4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quadrature as qd
from repro.core.features import (SlayFeatureConfig, init_feature_params,
                                 normalize, poly_features, prf_features,
                                 slay_features)


def _unit(key, n, d):
    return normalize(jax.random.normal(key, (n, d)))


def test_normalize_unit_norm(key):
    u = jax.random.normal(key, (32, 16)) * 10.0
    n = normalize(u)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(n), axis=-1), 1.0,
                               atol=1e-3)


def test_normalize_stable_at_zero():
    out = normalize(jnp.zeros((4, 8)))
    assert np.all(np.isfinite(np.asarray(out)))


def test_prf_unbiasedness(key):
    """Prop. 2: E[<phi(q;s), phi(k;s)>] = e^{2s q^T k} on the sphere."""
    d, D = 16, 60000
    q = _unit(jax.random.PRNGKey(1), 4, d)
    k = _unit(jax.random.PRNGKey(2), 4, d)
    # Antithetic pairs (the codebase default) — variance reduction keeps the
    # Monte Carlo error inside the tolerance at this sample count.
    half = jax.random.normal(key, (D // 2, d))
    omegas = jnp.concatenate([half, -half], axis=0)
    for s in (0.1, 0.5, 1.0):
        fq = prf_features(q, omegas, jnp.asarray(s))
        fk = prf_features(k, omegas, jnp.asarray(s))
        est = np.asarray(jnp.einsum("im,jm->ij", fq, fk))
        x = np.asarray(jnp.einsum("id,jd->ij", q, k))
        exact = np.exp(2 * s * x)
        np.testing.assert_allclose(est, exact, rtol=0.12)


def test_prf_strictly_positive(key):
    u = _unit(key, 8, 16)
    omegas = jax.random.normal(jax.random.PRNGKey(3), (32, 16))
    f = prf_features(u, omegas, jnp.asarray([0.2, 1.0]))
    assert f.shape == (8, 2, 32)
    assert np.all(np.asarray(f) > 0)


def test_exact_poly_reconstructs_squared_dot(key):
    d = 12
    cfg = SlayFeatureConfig(head_dim=d, poly_kind="exact")
    params = init_feature_params(key, cfg)
    q = _unit(jax.random.PRNGKey(1), 6, d)
    k = _unit(jax.random.PRNGKey(2), 6, d)
    fq, fk = poly_features(q, params, cfg), poly_features(k, params, cfg)
    est = np.asarray(jnp.einsum("im,jm->ij", fq, fk))
    x = np.asarray(jnp.einsum("id,jd->ij", q, k))
    np.testing.assert_allclose(est, x**2, atol=1e-5)


def test_anchor_features_nonnegative_inner_products(key):
    """Table 1: anchor features guarantee <phi(x),phi(y)> >= 0."""
    cfg = SlayFeatureConfig(head_dim=16, num_anchors=8)
    params = init_feature_params(key, cfg)
    q = _unit(jax.random.PRNGKey(1), 16, 16)
    k = _unit(jax.random.PRNGKey(2), 16, 16)
    fq, fk = poly_features(q, params, cfg), poly_features(k, params, cfg)
    est = np.asarray(jnp.einsum("im,jm->ij", fq, fk))
    assert np.all(est >= 0)


def test_rm_unbiased_for_squared_dot(key):
    """Random Maclaurin is unbiased (App. C) but signed."""
    d, P = 8, 40000
    cfg = SlayFeatureConfig(head_dim=d, num_anchors=P, poly_kind="rm")
    params = init_feature_params(key, cfg)
    q = _unit(jax.random.PRNGKey(1), 4, d)
    k = _unit(jax.random.PRNGKey(2), 4, d)
    fq, fk = poly_features(q, params, cfg), poly_features(k, params, cfg)
    est = np.asarray(jnp.einsum("im,jm->ij", fq, fk))
    x = np.asarray(jnp.einsum("id,jd->ij", q, k))
    np.testing.assert_allclose(est, x**2, atol=0.05)


@pytest.mark.parametrize("poly", ["anchor", "exact", "rm", "nystrom",
                                  "tensorsketch"])
def test_poly_variant_shapes(poly, key):
    cfg = SlayFeatureConfig(head_dim=8, num_anchors=6, poly_kind=poly)
    params = init_feature_params(key, cfg)
    u = _unit(jax.random.PRNGKey(1), 10, 8)
    f = poly_features(u, params, cfg)
    assert f.shape == (10, cfg.poly_dim)
    assert np.all(np.isfinite(np.asarray(f)))


@pytest.mark.parametrize("fusion", ["tensor", "hadamard", "subsample"])
def test_fused_feature_shapes(fusion, key):
    cfg = SlayFeatureConfig(head_dim=8, num_anchors=4, num_prf=6,
                            num_quad_nodes=3, fusion=fusion,
                            sketch_dim=12 if fusion == "subsample" else 0)
    params = init_feature_params(key, cfg)
    u = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 8))
    f = slay_features(u, params, cfg)
    assert f.shape == (2, 5, cfg.feature_dim)
    assert np.all(np.isfinite(np.asarray(f)))


def test_slay_feature_inner_products_nonnegative(key):
    """§G: anchor poly x positive PRF x nonneg quadrature weights => the
    estimated kernel (and hence attention denominators) are nonnegative."""
    cfg = SlayFeatureConfig(head_dim=16)
    params = init_feature_params(key, cfg)
    q = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    k = jax.random.normal(jax.random.PRNGKey(2), (32, 16))
    fq, fk = slay_features(q, params, cfg), slay_features(k, params, cfg)
    est = np.asarray(jnp.einsum("im,jm->ij", fq, fk))
    assert np.all(est >= 0)


def test_slay_estimates_quadrature_kernel(key):
    """With the exact poly map and a large PRF budget, <Ψ(q),Ψ(k)> matches
    the R-node quadrature kernel (Remark 1/2: unbiased for the discretized
    kernel)."""
    d, R = 16, 4
    cfg = SlayFeatureConfig(head_dim=d, poly_kind="exact", num_prf=4096,
                            num_quad_nodes=R, eps=1e-1)
    params = init_feature_params(key, cfg)
    q = _unit(jax.random.PRNGKey(1), 6, d)
    k = _unit(jax.random.PRNGKey(2), 6, d)
    fq, fk = slay_features(q, params, cfg), slay_features(k, params, cfg)
    est = np.asarray(jnp.einsum("im,jm->ij", fq, fk))
    x = np.asarray(jnp.einsum("id,jd->ij", q, k))
    quad = qd.quadrature_kernel(x, R, 1e-1)
    err = np.abs(est - quad) / (np.abs(quad) + 1e-3)
    assert np.median(err) < 0.25


def test_subsample_fusion_approximates_tensor(key):
    cfg_full = SlayFeatureConfig(head_dim=8, num_anchors=8, num_prf=16)
    cfg_sub = SlayFeatureConfig(head_dim=8, num_anchors=8, num_prf=16,
                                fusion="subsample", sketch_dim=96)
    params = init_feature_params(key, cfg_sub)
    q = _unit(jax.random.PRNGKey(1), 8, 8)
    k = _unit(jax.random.PRNGKey(2), 8, 8)
    full = np.asarray(jnp.einsum(
        "im,jm->ij", slay_features(q, params, cfg_full),
        slay_features(k, params, cfg_full)))
    sub = np.asarray(jnp.einsum(
        "im,jm->ij", slay_features(q, params, cfg_sub),
        slay_features(k, params, cfg_sub)))
    # Subsampled Kronecker is an unbiased sketch: close on average.
    assert np.abs(sub - full).mean() < 0.5 * np.abs(full).mean() + 1e-6
